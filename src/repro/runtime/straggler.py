"""Straggler detection & mitigation.

Mitigation is *native* to the paper's partitioner: a slowing group's λ-EWMA
drops, so eq. (4) automatically hands it smaller chunks — it starves itself
of work instead of stalling the fleet. This module adds detection/reporting
on top (for operators and for quarantine decisions), normalizing each group's
current λ by its own healthy baseline so heterogeneity (a LITTLE group being
slower than a BIG group) is not misread as straggling.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional

from repro.core.throughput import ThroughputTracker


@dataclass
class StragglerReport:
    group: str
    current: float
    baseline: float

    @property
    def slowdown(self) -> float:
        return self.current / self.baseline if self.baseline else 1.0


class StragglerDetector:
    def __init__(self, tracker: ThroughputTracker,
                 threshold: float = 0.5, warmup_chunks: int = 3):
        self.tracker = tracker
        self.threshold = threshold
        self.warmup = warmup_chunks
        self._baseline: Dict[str, float] = {}

    def observe(self) -> List[StragglerReport]:
        out = []
        for g, lam in self.tracker.snapshot().items():
            st = self.tracker.stats(g)
            if st is None or st.n < self.warmup:
                continue
            base = self._baseline.get(g)
            if base is None or lam > base:
                self._baseline[g] = base = lam
            if lam < self.threshold * base:
                out.append(StragglerReport(g, lam, base))
        return out
