"""Straggler detection & mitigation.

Mitigation is *native* to the paper's partitioner: a slowing group's λ-EWMA
drops, so eq. (4) automatically hands it smaller chunks — it starves itself
of work instead of stalling the fleet. This module adds detection/reporting
on top (for operators and for quarantine decisions), normalizing each group's
current λ by its own healthy baseline so heterogeneity (a LITTLE group being
slower than a BIG group) is not misread as straggling.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional

from repro.core.throughput import ThroughputTracker
from repro.policy.window import SlidingWindow


@dataclass
class StragglerReport:
    group: str
    current: float
    baseline: float

    @property
    def slowdown(self) -> float:
        return self.current / self.baseline if self.baseline else 1.0


class StragglerDetector:
    """Reports groups whose current λ fell below ``threshold`` × their
    healthy baseline.

    ``window_s=None`` (default) keeps the original running-max baseline:
    a group's best-ever λ, never forgotten. With a window, the baseline
    is the max λ observed within the last ``window_s`` seconds
    (``repro.policy.SlidingWindow``), so a *persistent* slowdown becomes
    the new normal after one horizon and the group stops being reported
    — derates decay instead of pinning a permanently-derated group to a
    stale best-case baseline."""

    def __init__(self, tracker: ThroughputTracker,
                 threshold: float = 0.5, warmup_chunks: int = 3,
                 window_s: Optional[float] = None, clock=None):
        self.tracker = tracker
        self.threshold = threshold
        self.warmup = warmup_chunks
        self.window_s = window_s
        self.clock = clock if clock is not None else time.monotonic
        self._baseline: Dict[str, float] = {}
        self._windows: Dict[str, SlidingWindow] = {}

    def _windowed_baseline(self, g: str, lam: float) -> float:
        w = self._windows.get(g)
        if w is None:
            w = self._windows[g] = SlidingWindow(self.window_s)
        now = self.clock()
        w.observe(now, lam)
        return w.max(now)

    def observe(self) -> List[StragglerReport]:
        out = []
        for g, lam in self.tracker.snapshot().items():
            st = self.tracker.stats(g)
            if st is None or st.n < self.warmup:
                continue
            if self.window_s is not None:
                base = self._windowed_baseline(g, lam)
            else:
                base = self._baseline.get(g)
                if base is None or lam > base:
                    self._baseline[g] = base = lam
            if lam < self.threshold * base:
                out.append(StragglerReport(g, lam, base))
        return out
