"""Elastic scaling: device groups join/leave the live scheduler runtime.

Join: DynamicScheduler.add_group spawns a dispatcher thread that enters the
oldest open epoch; the partitioner seeds the newcomer's λ and eq. (4)
immediately sizes its chunks — no global pause, no re-partitioning of
in-flight work. Leave: DynamicScheduler.remove_group drains the group out
*everywhere* (specs, executors, partitioner) so neither a scheduler rebuild
nor the persistent runtime's next epoch can resurrect it; ChunkFailure
(abrupt, chunk requeued) takes the same path in-band. This module is the
small policy layer: it owns GroupSpec construction and the λ seeding choice
for newcomers (median of current same-kind groups, so a new BIG node
doesn't start with a wildly wrong chunk size).

When an AdmissionController (repro.queue) is attached, join/leave events
flow to it so advertised capacity — and therefore the queue-delay
backpressure gate — tracks topology changes immediately.
"""
from __future__ import annotations

from typing import Dict, Optional

from repro.core.dispatch import ChunkExecutor
from repro.core.scheduler import DynamicScheduler
from repro.core.types import DeviceKind, GroupSpec


class ElasticController:
    def __init__(self, scheduler: DynamicScheduler, admission=None):
        self.scheduler = scheduler
        self.admission = admission      # Optional[AdmissionController]

    def _seed_lambda(self, kind: DeviceKind) -> Optional[float]:
        peers = [g for g in self.scheduler.specs.values() if g.kind == kind]
        lams = sorted(self.scheduler.tracker.get(g.name) for g in peers)
        if not lams:
            return None
        return lams[len(lams) // 2]

    def join(self, name: str, kind: DeviceKind, executor: ChunkExecutor,
             fixed_chunk: Optional[int] = None,
             min_chunk: int = 1) -> GroupSpec:
        lam = self._seed_lambda(kind) or 1.0
        spec = GroupSpec(name, kind, fixed_chunk=fixed_chunk,
                         min_chunk=min_chunk, init_throughput=lam)
        self.scheduler.add_group(spec, executor)
        if self.admission is not None:
            self.admission.on_group_join(name, lam)
        return spec

    def leave(self, name: str):
        # remove everywhere — leaving the group in scheduler.specs /
        # scheduler.executors would resurrect it on the next epoch (or on
        # any rebuild from those dicts)
        self.scheduler.remove_group(name)
        if self.admission is not None:
            self.admission.on_group_leave(name)
