"""Failure detection beyond in-band ChunkFailure: a watchdog that treats
chunk completions as heartbeats and declares a group dead when it has an
outstanding chunk for longer than ``timeout × expected_chunk_time``.

In-band failures (the executor raising ChunkFailure) are already handled by
DynamicScheduler (requeue + group removal); the watchdog covers *hangs* —
the failure mode in-band exceptions cannot see.
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

from repro.core.throughput import ThroughputTracker


@dataclass
class GroupHealth:
    last_heartbeat: float
    outstanding_since: Optional[float] = None
    expected_s: float = 1.0
    dead: bool = False


class Watchdog:
    def __init__(self, tracker: ThroughputTracker,
                 timeout_factor: float = 5.0, min_timeout_s: float = 2.0,
                 on_dead: Optional[Callable[[str], None]] = None,
                 clock=None):
        self.tracker = tracker
        self.timeout_factor = timeout_factor
        self.min_timeout_s = min_timeout_s
        self.on_dead = on_dead
        # injectable monotonic clock (tests/clock.py VirtualClock)
        self.clock = clock if clock is not None else time.monotonic
        self._health: Dict[str, GroupHealth] = {}
        self._lock = threading.Lock()

    def chunk_started(self, group: str, expected_items: float):
        lam = self.tracker.get(group)
        with self._lock:
            h = self._health.setdefault(group, GroupHealth(self.clock()))
            h.outstanding_since = self.clock()
            h.expected_s = expected_items / max(lam, 1e-9)

    def chunk_finished(self, group: str):
        with self._lock:
            h = self._health.setdefault(group, GroupHealth(self.clock()))
            h.last_heartbeat = self.clock()
            h.outstanding_since = None

    def revive(self, group: str) -> None:
        """Forget a group's dead verdict (its runtime was rebuilt from
        the factory). The dead flag is sticky by design — check() must
        not re-report a hang every poll — so a rebuild that brings the
        same group names back must clear it, or the fresh group would be
        condemned by its predecessor's hang."""
        with self._lock:
            self._health.pop(group, None)

    def check(self) -> List[str]:
        """Returns groups newly declared dead."""
        now = self.clock()
        newly = []
        with self._lock:
            for g, h in self._health.items():
                if h.dead or h.outstanding_since is None:
                    continue
                limit = max(self.min_timeout_s,
                            self.timeout_factor * h.expected_s)
                if now - h.outstanding_since > limit:
                    h.dead = True
                    newly.append(g)
        for g in newly:
            if self.on_dead:
                self.on_dead(g)
        return newly
