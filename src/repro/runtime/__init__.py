from repro.runtime.straggler import StragglerDetector, StragglerReport
from repro.runtime.fault_tolerance import Watchdog, GroupHealth
from repro.runtime.elastic import ElasticController

__all__ = ["StragglerDetector", "StragglerReport", "Watchdog", "GroupHealth",
           "ElasticController"]
