"""The pjit'd training step (loss + grad + AdamW update, remat'd layers)."""
from __future__ import annotations

from functools import partial
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import LMConfig
from repro.models import model as M
from repro.train.loss import chunked_cross_entropy, cross_entropy
from repro.train.optimizer import OptConfig, adamw_update


def loss_fn(cfg: LMConfig, params, batch: Dict) -> Tuple[jax.Array, Dict]:
    hidden, aux = M.forward(cfg, params, batch["tokens"],
                            batch.get("prefix_emb"), remat=True,
                            return_hidden=True)
    # loss on text positions only (modality prefixes carry no labels)
    if cfg.prefix_len:
        hidden = hidden[:, cfg.prefix_len:, :]
    loss, metrics = chunked_cross_entropy(
        hidden, M.unembed_weight(cfg, params), batch["labels"],
        batch.get("loss_mask"))
    metrics["aux_loss"] = aux
    return loss + aux, metrics


def train_step(cfg: LMConfig, oc: OptConfig, params, opt, batch):
    """One optimizer step. Returns (params', opt', metrics)."""
    (_, metrics), grads = jax.value_and_grad(
        lambda p: loss_fn(cfg, p, batch), has_aux=True)(params)
    params, opt, opt_metrics = adamw_update(oc, params, grads, opt)
    metrics.update(opt_metrics)
    return params, opt, metrics


def grad_step(cfg: LMConfig, params, batch):
    """Gradient-only step (used by the hetero trainer: groups compute grads
    on their chunks; the combine is example-count-weighted)."""
    (_, metrics), grads = jax.value_and_grad(
        lambda p: loss_fn(cfg, p, batch), has_aux=True)(params)
    return grads, metrics


def make_train_step(cfg: LMConfig, oc: OptConfig):
    return partial(train_step, cfg, oc)
