from repro.train.loss import cross_entropy
from repro.train.optimizer import (OptConfig, init_opt_state,
                                   abstract_opt_state, opt_state_axes,
                                   adamw_update, lr_at, global_norm)
from repro.train.train_step import train_step, grad_step, loss_fn, \
    make_train_step

__all__ = ["cross_entropy", "OptConfig", "init_opt_state",
           "abstract_opt_state", "opt_state_axes", "adamw_update", "lr_at",
           "global_norm", "train_step", "grad_step", "loss_fn",
           "make_train_step"]
