"""Losses and training metrics."""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp


def cross_entropy(logits: jax.Array, labels: jax.Array,
                  mask: Optional[jax.Array] = None) -> Tuple[jax.Array, Dict]:
    """Token-mean cross entropy in fp32. logits: (b, s, v); labels: (b, s)."""
    lf = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lf, axis=-1)
    # vocab-parallel label pick: one-hot contraction keeps the vocab dim
    # sharded (take_along_axis would all-gather the logits).
    onehot = jax.nn.one_hot(labels, lf.shape[-1], dtype=lf.dtype)
    ll = jnp.sum(lf * onehot, axis=-1)
    nll = lse - ll
    if mask is None:
        mask = jnp.ones_like(nll)
    mask = mask.astype(jnp.float32)
    denom = jnp.maximum(mask.sum(), 1.0)
    loss = (nll * mask).sum() / denom
    acc = ((jnp.argmax(lf, axis=-1) == labels).astype(jnp.float32)
           * mask).sum() / denom
    return loss, {"loss": loss, "accuracy": acc, "tokens": denom}


def chunked_cross_entropy(x: jax.Array, w: jax.Array, labels: jax.Array,
                          mask: Optional[jax.Array] = None,
                          chunk: int = 1024) -> Tuple[jax.Array, Dict]:
    """Sequence-chunked, rematerialized CE: logits are produced (and, in the
    backward pass, re-produced) one seq-chunk at a time, so the peak logits
    footprint is (b, chunk, vocab/TP) instead of (b, s, vocab/TP) — the
    dominant training temp for 100k-vocab archs.

    x: (b, s, d) final hidden states; w: (d, v) unembedding.
    """
    b, s, d = x.shape
    if mask is None:
        mask = jnp.ones((b, s), jnp.float32)
    mask = mask.astype(jnp.float32)
    c = min(chunk, s)
    pad = (-s) % c
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)))
        mask = jnp.pad(mask, ((0, 0), (0, pad)))
    nc = (s + pad) // c
    xc = x.reshape(b, nc, c, d).transpose(1, 0, 2, 3)
    lc = labels.reshape(b, nc, c).transpose(1, 0, 2)
    mc = mask.reshape(b, nc, c).transpose(1, 0, 2)

    @jax.checkpoint
    def body(carry, inp):
        nll_sum, acc_sum, cnt = carry
        xb, lb, mb = inp
        logits = jnp.einsum("bcd,dv->bcv", xb, w).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        onehot = jax.nn.one_hot(lb, logits.shape[-1], dtype=logits.dtype)
        ll = jnp.sum(logits * onehot, axis=-1)
        nll_sum = nll_sum + ((lse - ll) * mb).sum()
        acc_sum = acc_sum + ((jnp.argmax(logits, -1) == lb)
                             .astype(jnp.float32) * mb).sum()
        return (nll_sum, acc_sum, cnt + mb.sum()), None

    zero = jnp.zeros((), jnp.float32)
    (nll_sum, acc_sum, cnt), _ = jax.lax.scan(body, (zero, zero, zero),
                                              (xc, lc, mc))
    denom = jnp.maximum(cnt, 1.0)
    loss = nll_sum / denom
    return loss, {"loss": loss, "accuracy": acc_sum / denom, "tokens": denom}
