"""HeteroTrainer: the paper's Dynamic scheduler driving real JAX training.

Each optimizer step's global batch is the *iteration space* (sample indices);
device groups receive λ-proportional chunks of samples (the accelerator group
its tuned chunk G), compute gradients on them, and the trainer combines
gradients example-count-weighted before one AdamW update. This is synchronous
data parallelism with dynamic, heterogeneity-aware load balancing — stragglers
automatically receive smaller chunks; a failed group's chunk is re-queued.

Chunk sizes are bucketed to powers of two so the jit cache stays small (the
O_kl mitigation: no recompilation storms); padded rows carry loss_mask=0 and
do not bias the gradient (the combine weights use *real* example counts).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import LMConfig
from repro.core import (ChunkRecord, DeviceKind, DynamicScheduler,
                        EnergyModel, GroupSpec, JaxChunkExecutor, PowerSpec)
from repro.core.chunk_search import search_chunk
from repro.data.pipeline import SyntheticLMData, for_model
from repro.train.optimizer import OptConfig, adamw_update, init_opt_state
from repro.train.train_step import grad_step


def bucket(n: int) -> int:
    b = 1
    while b < n:
        b *= 2
    return b


@dataclass
class GroupDef:
    name: str
    kind: DeviceKind
    device: object = None          # jax device (or None = default)
    fixed_chunk: Optional[int] = None
    async_depth: int = 1
    priority_boost: bool = False
    slowdown: float = 1.0          # artificial slowdown for straggler tests
    fail_after_chunks: Optional[int] = None   # fault injection


@dataclass
class StepReport:
    step: int
    loss: float
    examples: int
    time_s: float
    per_group_items: Dict[str, int]
    overheads: Dict[str, Dict[str, float]]
    throughput: Dict[str, float]
    failed_groups: List[str] = field(default_factory=list)


class HeteroTrainer:
    def __init__(self, cfg: LMConfig, groups: List[GroupDef],
                 seq_len: int = 128, global_batch: int = 64,
                 oc: Optional[OptConfig] = None, seed: int = 0,
                 alpha: float = 0.5, repeat_data: bool = False):
        self.repeat_data = repeat_data
        self.cfg = cfg
        self.groups = groups
        self.seq_len = seq_len
        self.global_batch = global_batch
        self.oc = oc or OptConfig()
        self.alpha = alpha
        self.data = for_model(cfg, seq_len - cfg.prefix_len, seed)
        from repro.models import model as M
        self.params = M.init_params(cfg, jax.random.PRNGKey(seed))
        self.opt = init_opt_state(self.params)
        self.step_idx = 0
        self._grad_fns: Dict[int, callable] = {}
        self.history: List[StepReport] = []

    # ------------------------------------------------------------------
    def _grad_fn(self):
        cfg = self.cfg

        def fn(params, batch):
            grads, metrics = grad_step(cfg, params, batch)
            n = batch["loss_mask"][:, 0].sum()     # real examples in chunk
            grads = jax.tree.map(lambda g: g * n, grads)
            return grads, metrics["loss"] * n, n

        return jax.jit(fn)

    def _make_executor(self, g: GroupDef):
        fn = self._grad_fn()
        data = self.data
        params = lambda: self.params        # late binding per step
        slowdown = g.slowdown

        def make_inputs(token):
            # chunk bounds are absolute sample indices: any group can
            # materialize any range, and re-executed chunks are identical
            c = token.chunk
            return data.batch(c.begin, c.end, pad_to=bucket(c.size))

        counter = {"n": 0}

        def step(batch):
            if g.fail_after_chunks is not None:
                counter["n"] += 1
                if counter["n"] > g.fail_after_chunks:
                    from repro.core.dispatch import ChunkFailure
                    raise ChunkFailure(f"group {g.name} injected failure")
            if slowdown > 1.0:
                time.sleep((slowdown - 1.0) * 0.001 * batch["tokens"].shape[0])
            return fn(self.params, batch)

        def fetch(outs):
            grads, loss_n, n = outs
            return {"grads": grads, "loss_n": float(loss_n), "n": float(n)}

        return JaxChunkExecutor(step, make_inputs, fetch, device=g.device,
                                async_depth=g.async_depth,
                                priority_boost=g.priority_boost)

    # ------------------------------------------------------------------
    def tune_accel_chunk(self, seed_chunk: int = 4, multiples: int = 6) -> int:
        """§3.2 G-search over real measured throughput of the accel group."""
        accel = [g for g in self.groups if g.kind == DeviceKind.ACCEL]
        if not accel:
            return seed_chunk
        g = accel[0]
        ex = self._make_executor(g)
        self._space_offset = 0

        def measure(c: int) -> float:
            c = min(c, self.global_batch)
            from repro.core.types import Chunk, Token
            tok = Token(Chunk(0, c, 0), g.name, g.kind)
            rec = ChunkRecord(tok)
            t0 = time.monotonic()
            done = ex.execute(tok, rec) + ex.drain()
            dt = time.monotonic() - t0
            return c / max(dt, 1e-9)

        measure(min(seed_chunk, self.global_batch))   # compile warmup
        tr = search_chunk(measure, seed_chunk, multiples=multiples,
                          max_chunk=self.global_batch)
        g.fixed_chunk = tr.best_chunk
        return tr.best_chunk

    # ------------------------------------------------------------------
    def train_step(self) -> StepReport:
        specs = {}
        execs = {}
        for g in self.groups:
            specs[g.name] = GroupSpec(
                g.name, g.kind, fixed_chunk=g.fixed_chunk,
                min_chunk=1, max_chunk=self.global_batch,
                init_throughput=1.0)
            execs[g.name] = self._make_executor(g)
        sched = DynamicScheduler(specs, execs, alpha=self.alpha)
        self._space_offset = 0 if self.repeat_data \
            else self.step_idx * self.global_batch
        res = sched.run(self._space_offset,
                        self._space_offset + self.global_batch)

        # example-weighted gradient combine across groups
        total_g = None
        total_loss = 0.0
        total_n = 0.0
        for rec in res.records:
            r = rec.meta.get("result")
            if not r:
                continue
            total_loss += r["loss_n"]
            total_n += r["n"]
            g = r["grads"]
            total_g = g if total_g is None else \
                jax.tree.map(jnp.add, total_g, g)
        assert total_n > 0, "no gradients collected"
        total_g = jax.tree.map(lambda x: x / total_n, total_g)
        self.params, self.opt, _ = adamw_update(
            self.oc, self.params, total_g, self.opt)
        self.step_idx += 1
        rep = StepReport(
            step=self.step_idx, loss=total_loss / total_n,
            examples=int(total_n), time_s=res.total_time,
            per_group_items=res.per_group_items,
            overheads=res.overheads, throughput=res.throughput,
            failed_groups=res.failed_groups)
        self.history.append(rep)
        return rep

    def train(self, steps: int) -> List[StepReport]:
        return [self.train_step() for _ in range(steps)]
