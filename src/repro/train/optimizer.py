"""AdamW with fp32 master weights, global-norm clipping, and LR schedule.

Optimizer state shares the parameters' logical sharding (FSDP×TP ⇒ the state
is fully sharded across the mesh, ZeRO-style, with no extra machinery).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Dict, Tuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


def lr_at(oc: OptConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(oc.warmup_steps, 1)
    prog = jnp.clip((step - oc.warmup_steps)
                    / jnp.maximum(oc.total_steps - oc.warmup_steps, 1), 0, 1)
    cos = oc.min_lr_frac + (1 - oc.min_lr_frac) * 0.5 \
        * (1 + jnp.cos(jnp.pi * prog))
    return oc.lr * jnp.where(step < oc.warmup_steps, warm, cos)


def init_opt_state(params) -> Dict:
    f32 = lambda p: p.astype(jnp.float32)
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "master": jax.tree.map(f32, params),
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def abstract_opt_state(abstract_params) -> Dict:
    f32 = lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32)
    return {
        "master": jax.tree.map(f32, abstract_params),
        "m": jax.tree.map(f32, abstract_params),
        "v": jax.tree.map(f32, abstract_params),
        "step": jax.ShapeDtypeStruct((), jnp.int32),
    }


def opt_state_axes(p_axes) -> Dict:
    return {"master": p_axes, "m": p_axes, "v": p_axes, "step": ()}


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def adamw_update(oc: OptConfig, params, grads, opt: Dict):
    """Returns (new_params, new_opt, metrics)."""
    step = opt["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, oc.clip_norm / jnp.maximum(gnorm, 1e-9))
    lr = lr_at(oc, step)
    b1, b2 = oc.beta1, oc.beta2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(g, m, v, master, p):
        g = g.astype(jnp.float32) * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * jnp.square(g)
        update = (m / bc1) / (jnp.sqrt(v / bc2) + oc.eps) \
            + oc.weight_decay * master
        master = master - lr * update
        return m, v, master, master.astype(p.dtype)

    flat_g, treedef = jax.tree.flatten(grads)
    flat_m = jax.tree.leaves(opt["m"])
    flat_v = jax.tree.leaves(opt["v"])
    flat_w = jax.tree.leaves(opt["master"])
    flat_p = jax.tree.leaves(params)
    out = [upd(*t) for t in zip(flat_g, flat_m, flat_v, flat_w, flat_p)]
    new_m = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_w = jax.tree.unflatten(treedef, [o[2] for o in out])
    new_p = jax.tree.unflatten(treedef, [o[3] for o in out])
    new_opt = {"master": new_w, "m": new_m, "v": new_v, "step": step}
    return new_p, new_opt, {"grad_norm": gnorm, "lr": lr}
