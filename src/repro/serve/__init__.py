from repro.serve.engine import HeteroServeEngine, ServeReport

__all__ = ["HeteroServeEngine", "ServeReport"]
