"""HeteroServeEngine: the paper's scheduler applied to batched inference.

The iteration space is the request queue; a chunk is a batch of requests. The
accelerator group's tuned chunk G is the throughput-optimal serving batch
(found with the same §3.2 search — too small under-fills the MXU, too large
blows the KV-cache working set); other groups get λ-proportional batches.
Each chunk is prefill + a fixed decode burst; effective throughput is
generated tokens / wall time, which feeds eq. (4) exactly like training.
"""
from __future__ import annotations

import os
import tempfile
import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import telemetry as telemetry_mod
from repro.configs.base import LMConfig
from repro.core import (ChunkRecord, DeviceKind, DynamicScheduler, GroupSpec,
                        JaxChunkExecutor, OverheadLedger, ThroughputTracker)
from repro.core.energy import EnergyModel
from repro.models import model as M
from repro.queue import (AdmissionController, Job, JobService, JournalStore,
                         QueueManager, percentiles)
from repro.tenancy import (ShardedQueueManager, TenantAccountant,
                           TenantRegistry)
from repro.train.trainer import GroupDef, bucket


@dataclass
class ServeReport:
    requests: int
    new_tokens: int
    time_s: float
    per_group_items: Dict[str, int]
    overheads: Dict[str, Dict[str, float]]
    throughput: Dict[str, float]


@dataclass
class QueueServeReport:
    """Result of the queued-submission path (serve_jobs)."""
    jobs: int
    done: int
    failed: int
    cancelled: int
    requeues: int
    batches: int
    new_tokens: int
    time_s: float
    queue_delay: Dict[str, float]          # p50/p95/p99 seconds
    per_group_items: Dict[str, int]
    throughput: Dict[str, float]
    dead_groups: List[str] = field(default_factory=list)
    drained: bool = True
    # multi-tenant mode: per-tenant attributed usage (items, busy_s,
    # energy_j, edp, queue-delay percentiles) + admission counters
    per_tenant: Dict[str, Dict] = field(default_factory=dict)
    admission_per_tenant: Dict[str, Dict[str, int]] = \
        field(default_factory=dict)
    # latency tiers: per-tier deadline misses, express-lane batches, and
    # in-flight epochs cancelled (deadline preemption)
    deadline_misses: Dict[str, int] = field(default_factory=dict)
    express_batches: int = 0
    cancelled_batches: int = 0


@dataclass
class FederatedServeReport:
    """Result of the federated path (serve_jobs_federated): the
    federation-level report plus engine-level aggregates."""
    fed: "object"                          # repro.federation.FederationReport
    drained: bool
    per_tenant: Dict[str, Dict] = field(default_factory=dict)
    new_tokens: int = 0


class HeteroServeEngine:
    def __init__(self, cfg: LMConfig, groups: List[GroupDef],
                 prompt_len: int = 32, decode_tokens: int = 8,
                 max_len: Optional[int] = None, seed: int = 0,
                 alpha: float = 0.5, chunk_mode: str = "range",
                 telemetry=None, adaptive_refill: bool = True):
        self.cfg = cfg
        self.groups = groups
        self.prompt_len = prompt_len
        self.decode_tokens = decode_tokens
        self.max_len = max_len or bucket(prompt_len + decode_tokens)
        self.seed = seed
        self.alpha = alpha
        # history-driven refill sizing in the partitioner (steal-rate
        # feedback; see HeterogeneousPartitioner._refill_quota_locked)
        self.adaptive_refill = adaptive_refill
        # "range": zero-contention dispatch (private λ-share ranges with
        # work stealing); "paper": the lock-per-token baseline
        self.chunk_mode = chunk_mode
        # one Telemetry instance threaded through every layer the engine
        # builds (scheduler, partitioner, queue, admission, service) so
        # metrics and spans land in a single registry/tracer
        self.telemetry = telemetry_mod.resolve(telemetry)
        self.params = M.init_params(cfg, jax.random.PRNGKey(seed))
        self._fns: Dict[int, tuple] = {}
        # fail-injection counters persist across executors so an injected
        # group death stays dead over a queued multi-batch run
        self._fail_counters: Dict[str, Dict[str, int]] = {}
        # executors are built once per group and reused across epochs /
        # scheduler rebuilds (their jitted fns and inflight pipelines are
        # runtime-scoped, not batch-scoped)
        self._executors: Dict[str, JaxChunkExecutor] = {}

    # ------------------------------------------------------------------
    def _fns_for(self, b: int):
        if b in self._fns:
            return self._fns[b]
        cfg = self.cfg

        @jax.jit
        def prefill_fn(params, tokens, prefix):
            return M.prefill(cfg, params, tokens, prefix,
                             max_len=self.max_len)

        @jax.jit
        def decode_fn(params, cache, tokens):
            return M.decode_step(cfg, params, cache, tokens)

        self._fns[b] = (prefill_fn, decode_fn)
        return self._fns[b]

    def _prompt(self, idx: int, rng_salt: int = 0) -> np.ndarray:
        rng = np.random.Generator(np.random.PCG64(
            (self.seed << 32) ^ (idx + rng_salt)))
        return rng.integers(0, self.cfg.vocab, self.prompt_len,
                            dtype=np.int32)

    def _make_executor(self, g: GroupDef, key: Optional[str] = None):
        cfg = self.cfg

        def make_inputs(token):
            c = token.chunk
            pad = bucket(c.size)
            toks = np.stack([self._prompt(i) for i in range(c.begin, c.end)])
            if pad > c.size:
                toks = np.concatenate(
                    [toks, np.zeros((pad - c.size, self.prompt_len),
                                    np.int32)])
            out = {"tokens": toks}
            if cfg.prefix_len:
                rngp = np.random.Generator(np.random.PCG64(c.begin))
                out["prefix_emb"] = rngp.standard_normal(
                    (pad, cfg.prefix_len, cfg.d_model)).astype(np.float32) \
                    * 0.02
            return out

        counter = self._fail_counters.setdefault(key or g.name, {"n": 0})

        def step(batch):
            if g.fail_after_chunks is not None:
                counter["n"] += 1
                if counter["n"] > g.fail_after_chunks:
                    from repro.core.dispatch import ChunkFailure
                    raise ChunkFailure(f"group {g.name} injected failure")
            b = batch["tokens"].shape[0]
            prefill_fn, decode_fn = self._fns_for(b)
            if g.slowdown > 1.0:
                time.sleep((g.slowdown - 1.0) * 0.001 * b)
            logits, cache = prefill_fn(self.params, batch["tokens"],
                                       batch.get("prefix_emb"))
            tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
            toks = [tok]
            for _ in range(self.decode_tokens - 1):
                logits, cache = decode_fn(self.params, cache, tok)
                tok = jnp.argmax(logits[:, -1], -1)[:, None] \
                    .astype(jnp.int32)
                toks.append(tok)
            return jnp.concatenate(toks, axis=1)

        def fetch(outs):
            return {"tokens_out": np.asarray(outs)}

        return JaxChunkExecutor(step, make_inputs, fetch, device=g.device,
                                async_depth=g.async_depth,
                                priority_boost=g.priority_boost)

    def _executor_for(self, g: GroupDef,
                      namespace: str = "") -> JaxChunkExecutor:
        # executors (and fail-injection counters) are cached per
        # *namespaced* name: federated runtimes must not share one
        # executor's async pipeline across their dispatcher threads
        key = namespace + g.name
        ex = self._executors.get(key)
        if ex is None:
            ex = self._executors[key] = self._make_executor(g, key)
        return ex

    # ------------------------------------------------------------------
    def _build_scheduler(self, max_chunk: Optional[int] = None,
                         exclude: Optional[set] = None,
                         namespace: str = "",
                         telemetry=None,
                         wrap_executor: Optional[Callable] = None) \
            -> DynamicScheduler:
        """``namespace`` prefixes every group name (federation: runtime
        ``r1``'s accel group is ``r1/accel``), so per-runtime schedulers
        get private executors, distinct trace tracks, and unambiguous
        dead-group exclusion. ``wrap_executor(name, ex)`` decorates each
        group's executor (the chaos plane's injection point)."""
        specs, execs = {}, {}
        for g in self.groups:
            name = namespace + g.name
            if exclude and name in exclude:
                continue
            specs[name] = GroupSpec(name, g.kind,
                                    fixed_chunk=g.fixed_chunk,
                                    min_chunk=1, max_chunk=max_chunk,
                                    init_throughput=1.0)
            ex = self._executor_for(g, namespace)
            if wrap_executor is not None:
                ex = wrap_executor(name, ex)
            execs[name] = ex
        if not specs:
            raise RuntimeError("no live device groups")
        return DynamicScheduler(specs, execs, alpha=self.alpha,
                                chunk_mode=self.chunk_mode,
                                adaptive_refill=self.adaptive_refill,
                                telemetry=telemetry if telemetry is not None
                                else self._tel_arg())

    def _tel_arg(self):
        """Forward the engine's resolved telemetry to a component ctor
        (None after resolve means *uninstrumented*, so pass OFF, not
        None — None would re-resolve to the process default)."""
        return self.telemetry if self.telemetry is not None \
            else telemetry_mod.OFF

    def telemetry_snapshot(self) -> Optional[Dict]:
        """Merged metrics + trace snapshot, or None when uninstrumented."""
        if self.telemetry is None:
            return None
        return self.telemetry.snapshot()

    def serve(self, n_requests: int) -> ServeReport:
        sched = self._build_scheduler(max_chunk=n_requests)
        res = sched.run(0, n_requests)
        return ServeReport(
            requests=res.iterations,
            new_tokens=res.iterations * self.decode_tokens,
            time_s=res.total_time,
            per_group_items=res.per_group_items,
            overheads=res.overheads,
            throughput=res.throughput)

    # ------------------------------------------------------------------
    # queued-submission path: requests arrive as prioritized Jobs, pass
    # admission control, and are drained batch-wise by a JobService.
    # ------------------------------------------------------------------
    def serve_jobs(self, jobs: List[Job],
                   slo_delay_s: Optional[float] = None,
                   batch_jobs: int = 8,
                   journal_path: Optional[str] = None,
                   timeout_s: float = 300.0,
                   pipeline_depth: int = 2,
                   persistent: bool = True,
                   tenants: Optional[TenantRegistry] = None,
                   energy_model: Optional[EnergyModel] = None,
                   express: bool = True,
                   policy=None, idle_s: float = 0.0) \
            -> QueueServeReport:
        """Serve prioritized jobs through admission control + queue.

        Batches drain onto one *persistent* scheduler runtime: dispatcher
        threads and (cached) executors are built once and reused across
        epochs, and with ``pipeline_depth ≥ 2`` batch N+1 is dispatched
        while batch N is still in flight (continuous double-buffered
        drain — no inter-batch barrier, no per-batch rebuild).
        λ-estimates and overhead fractions are runtime-scoped (one
        ThroughputTracker / OverheadLedger for the whole session), so
        admission's capacity model and the partitioner both warm up once
        and stay warm. ``slo_delay_s=None`` disables the admission gate
        (every job is queued). Groups that die mid-run stay excluded for
        the rest of the session. ``persistent=False`` restores the old
        rebuild-per-batch behavior (benchmark baseline).

        Multi-tenant mode: pass a ``tenants`` registry and jobs are
        sharded per ``job.tenant`` with a DWRR weighted-fair drain,
        quota-aware admission (when an SLO enables the gate), and
        per-tenant accounting; with an ``energy_model`` each tenant's
        attributed joules/EDP are reported and soft energy budgets derate
        DWRR weights. Without a registry nothing changes.

        Latency tiers: urgent jobs drain through the service's express
        lane (``express=False`` disables it, the benchmark baseline),
        batches run at the tier of their most urgent member, and jobs
        with ``deadline_s`` are shed at pop or cooperatively cancelled in
        flight once the budget is spent.

        ``policy`` (repro.policy.AdaptivePolicy) smooths admission over a
        sliding window and cools down straggler rebalances. ``idle_s > 0``
        keeps the drain daemon parked for that long after the queue
        drains — the idle-efficiency probe scripts/smoke.sh uses to
        assert the event-driven drain isn't busy-polling.
        """
        tracker = ThroughputTracker(self.alpha)
        ledger = OverheadLedger()
        ledger.keep_records = False           # bounded memory for long runs
        dead: set = set()

        def make_scheduler() -> DynamicScheduler:
            # called once for the persistent runtime; again only if every
            # group died (or per batch with persistent=False)
            sched = self._build_scheduler(exclude=dead)
            sched.tracker = tracker           # runtime-scoped λ / §3.3
            sched.ledger = ledger
            return sched

        accountant = None
        if tenants is not None:
            queue = ShardedQueueManager(tenants, telemetry=self._tel_arg())
            accountant = TenantAccountant(tenants,
                                          energy_model=energy_model)
        else:
            queue = QueueManager()
        admission = None
        # the gate also turns on when any tenant spec carries an SLO or
        # quota — otherwise those contracts would be silently inert
        # without a global --slo; with no global SLO the global delay
        # band is infinite and only the per-tenant contracts bind
        if slo_delay_s is not None or (tenants is not None
                                       and tenants.any_gating()):
            admission = AdmissionController(
                queue, tracker, ledger,
                slo_delay_s=slo_delay_s if slo_delay_s is not None
                else float("inf"),
                registry=tenants, telemetry=self._tel_arg(),
                policy=policy)
            for g in self.groups:
                admission.on_group_join(g.name, 1.0)
        journal = JournalStore(journal_path) if journal_path else None
        service = JobService(make_scheduler, queue=queue,
                             admission=admission, journal=journal,
                             batch_jobs=batch_jobs,
                             on_group_failed=dead.add,
                             pipeline_depth=pipeline_depth,
                             persistent=persistent,
                             accountant=accountant,
                             telemetry=self._tel_arg(),
                             express=express)
        t0 = time.monotonic()
        for job in jobs:
            service.submit(job)
        drained = service.run_until_idle(timeout_s=timeout_s)
        dt = time.monotonic() - t0
        if idle_s > 0.0:
            # park the daemon on an empty queue: with the event-driven
            # drain it should accrue only fallback-timeout wakeups, at
            # ≤ 1/fallback_s per second (vs. 1/poll_s busy-polling)
            service.start()
            time.sleep(idle_s)
        service.close()
        if journal is not None:
            journal.close()
        st = service.stats
        cancelled = sum(1 for j in jobs if j.state.value == "cancelled")
        done_items = sum(j.items for j in jobs if j.state.value == "done")
        return QueueServeReport(
            jobs=len(jobs), done=st.done, failed=st.failed,
            cancelled=cancelled, requeues=st.requeues, batches=st.batches,
            new_tokens=done_items * self.decode_tokens, time_s=dt,
            queue_delay=percentiles(st.queue_delays),
            per_group_items=dict(st.per_group_items),
            throughput=tracker.snapshot(), dead_groups=sorted(dead),
            drained=drained,
            per_tenant=accountant.snapshot() if accountant else {},
            admission_per_tenant=dict(admission.per_tenant)
            if admission is not None else {},
            deadline_misses=dict(st.deadline_misses),
            express_batches=st.express_batches,
            cancelled_batches=st.cancelled_batches)

    # ------------------------------------------------------------------
    # federated path: N runtimes behind one front-end (repro.federation)
    # ------------------------------------------------------------------
    def serve_jobs_federated(self, jobs: List[Job],
                             runtimes: int = 3,
                             slo_delay_s: Optional[float] = None,
                             batch_jobs: int = 8,
                             journal_dir: Optional[str] = None,
                             timeout_s: float = 300.0,
                             pipeline_depth: int = 2,
                             tenants: Optional[TenantRegistry] = None,
                             energy_model: Optional[EnergyModel] = None,
                             express: bool = True,
                             heartbeat_s: float = 0.1,
                             kill_runtime: Optional[int] = None,
                             kill_after_frac: float = 0.5,
                             chaos_seed: Optional[int] = None,
                             chaos_plan: Optional[str] = None,
                             chaos_horizon_s: float = 2.0) \
            -> "FederatedServeReport":
        """Serve jobs through a ``FederatedService``: ``runtimes``
        independent JobService runtimes — each with its own persistent
        scheduler (namespaced device groups ``rK/<group>``, private
        executors), runtime-scoped λ-tracker/ledger, tenancy shards, and
        mirrored journal — behind one tenant-consistent-hash front door.
        Global tenant quotas / energy budgets bind fleet-wide via gossip.

        ``kill_runtime=K`` crashes runtime ``rK`` once ``kill_after_frac``
        of the jobs are done (failure drill: its replica replays onto a
        survivor; the report's ``recovered`` counts the requeued jobs).

        Chaos plane: ``chaos_seed`` generates a deterministic randomized
        ``FaultPlan`` over ``chaos_horizon_s`` seconds (same seed ⇒ same
        schedule); ``chaos_plan`` instead loads an explicit plan (a JSON
        string or a path to one). Executor faults wrap every group's
        executor; journal/federation faults are executed by the
        federation tier.
        """
        from repro.chaos import ChaosExecutor, ChaosInjector, FaultPlan
        from repro.federation import FederatedService
        if journal_dir is None:
            journal_dir = tempfile.mkdtemp(prefix="repro-fed-")
        rids = [f"r{i}" for i in range(max(1, runtimes))]

        chaos = None
        if chaos_plan is not None or chaos_seed is not None:
            if chaos_plan is not None:
                text = chaos_plan
                if os.path.exists(chaos_plan):
                    with open(chaos_plan, "r", encoding="utf-8") as fh:
                        text = fh.read()
                plan = FaultPlan.from_json(text)
            else:
                plan = FaultPlan.generate(
                    chaos_seed, chaos_horizon_s, rids,
                    [f"{rid}/{g.name}" for rid in rids
                     for g in self.groups])
            chaos = ChaosInjector(plan, telemetry=self._tel_arg())

        def make_service(rid: str, journal, telemetry) -> JobService:
            tracker = ThroughputTracker(self.alpha)
            ledger = OverheadLedger()
            ledger.keep_records = False
            dead: set = set()

            def make_scheduler() -> DynamicScheduler:
                wrap = None
                if chaos is not None:
                    def wrap(name, ex):
                        return ChaosExecutor(ex, name, chaos)
                sched = self._build_scheduler(exclude=dead,
                                              namespace=f"{rid}/",
                                              telemetry=telemetry,
                                              wrap_executor=wrap)
                sched.tracker = tracker
                sched.ledger = ledger
                return sched

            accountant = None
            if tenants is not None:
                queue = ShardedQueueManager(tenants, telemetry=telemetry)
                accountant = TenantAccountant(tenants,
                                              energy_model=energy_model)
            else:
                queue = QueueManager()
            admission = None
            if slo_delay_s is not None or (tenants is not None
                                           and tenants.any_gating()):
                admission = AdmissionController(
                    queue, tracker, ledger,
                    slo_delay_s=slo_delay_s if slo_delay_s is not None
                    else float("inf"),
                    registry=tenants, telemetry=telemetry)
                for g in self.groups:
                    admission.on_group_join(f"{rid}/{g.name}", 1.0)
            return JobService(make_scheduler, queue=queue,
                              admission=admission, journal=journal,
                              batch_jobs=batch_jobs,
                              on_group_failed=dead.add,
                              pipeline_depth=pipeline_depth,
                              accountant=accountant,
                              telemetry=telemetry, express=express)

        fed = FederatedService(make_service, rids, journal_dir,
                               tenants=tenants,
                               telemetry=self._tel_arg(),
                               heartbeat_s=heartbeat_s,
                               chaos=chaos)
        t0 = time.monotonic()
        fed.start()
        for job in jobs:
            fed.submit(job)
        victim = f"r{kill_runtime}" if kill_runtime is not None \
            and 0 <= kill_runtime < len(rids) else None
        if victim is not None:
            threshold = max(1, int(kill_after_frac * len(jobs)))
            deadline = time.monotonic() + timeout_s
            while time.monotonic() < deadline:
                done = sum(1 for j in jobs
                           if j.state.value in ("done", "failed",
                                                "cancelled"))
                if done >= threshold:
                    break
                time.sleep(0.01)
            fed.kill_runtime(victim)
        drained = fed.run_until_idle(timeout_s=timeout_s)
        rep = fed.report()
        rep.time_s = time.monotonic() - t0
        per_tenant: Dict[str, Dict] = {}
        for node in fed.nodes().values():
            acct = node.service.accountant
            if acct is None:
                continue
            for t, d in acct.snapshot().items():
                agg = per_tenant.setdefault(
                    t, {"items": 0, "busy_s": 0.0, "energy_j": 0.0,
                        "batches": 0})
                agg["items"] += d["items"]
                agg["busy_s"] += d["busy_s"]
                agg["energy_j"] += d["energy_j"]
                agg["batches"] += d["batches"]
        fed.close()
        return FederatedServeReport(
            fed=rep, drained=drained, per_tenant=per_tenant,
            new_tokens=sum(rep.per_tenant_items.values())
            * self.decode_tokens)
