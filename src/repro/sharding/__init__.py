from repro.sharding.rules import ShardingRules, DEFAULT_RULES, \
    LONG_CONTEXT_OVERRIDES, tree_shardings
from repro.sharding.partition import lshard, use_mesh_rules, active_mesh, \
    active_rules

__all__ = ["ShardingRules", "DEFAULT_RULES", "LONG_CONTEXT_OVERRIDES",
           "tree_shardings", "lshard", "use_mesh_rules", "active_mesh",
           "active_rules"]
