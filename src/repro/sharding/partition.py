"""Activation sharding-constraint helpers.

Model code calls :func:`lshard` with *logical* axis names. When a mesh context
is active (set by the launchers via :func:`use_mesh_rules`), this lowers to
``jax.lax.with_sharding_constraint``; otherwise it is a no-op so the same model
code runs un-meshed in unit tests.
"""
from __future__ import annotations

import threading
from contextlib import contextmanager
from typing import Optional, Sequence

import jax
from jax.sharding import Mesh

from repro.sharding.rules import ShardingRules

_state = threading.local()


def _ctx():
    return getattr(_state, "ctx", None)


@contextmanager
def use_mesh_rules(mesh: Mesh, rules: Optional[ShardingRules] = None):
    prev = _ctx()
    _state.ctx = (mesh, rules or ShardingRules())
    try:
        with mesh:
            yield
    finally:
        _state.ctx = prev


def active_mesh() -> Optional[Mesh]:
    c = _ctx()
    return c[0] if c else None


def active_rules() -> Optional[ShardingRules]:
    c = _ctx()
    return c[1] if c else None


def lshard(x: jax.Array, *axes: Optional[str]) -> jax.Array:
    """Constrain ``x`` to the sharding implied by logical ``axes`` (or no-op)."""
    c = _ctx()
    if c is None:
        return x
    mesh, rules = c
    spec = rules.spec(mesh, axes, x.shape)
    return jax.lax.with_sharding_constraint(
        x, jax.sharding.NamedSharding(mesh, spec))
