"""Logical-axis sharding rules (divisibility-aware).

Parameters and activations are annotated with *logical* axis names; a
:class:`ShardingRules` table maps those to physical mesh axes. A mapping is
applied to a tensor dimension only when the dimension size is divisible by the
product of the mapped mesh-axis sizes — otherwise the rule falls back to a
prefix of the mapped axes, and finally to replication (this is what lets e.g.
phi3-medium's 40 heads coexist with TP=16: the head axis falls back and the
row-parallel `embed`-axis sharding of the same weight keeps compute balanced).
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Dict, Optional, Sequence, Tuple, Union

import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

AxisMap = Union[None, str, Tuple[str, ...]]


def _as_tuple(v: AxisMap) -> Tuple[str, ...]:
    if v is None:
        return ()
    if isinstance(v, str):
        return (v,)
    return tuple(v)


# Default production rules: FSDP over `data`, TP/EP over `model`, DP over `pod`.
DEFAULT_RULES: Dict[str, AxisMap] = {
    # ---- parameters -------------------------------------------------
    "vocab": "model",
    "embed": "data",            # FSDP axis (weights gathered per layer)
    "heads": "model",
    "kv_heads": "model",
    "head_dim": None,
    "mlp": "model",
    "experts": "model",
    "layers": None,             # scan axis
    "ssm_inner": "model",
    "ssm_state": None,
    "ssm_heads": "model",
    "conv_dim": "model",
    "norm": None,
    "pos": None,
    # ---- activations ------------------------------------------------
    "act_batch": ("pod", "data"),
    "act_seq": None,
    # residual stream between blocks: sequence-parallel over `model`
    # (Megatron-SP): the per-layer carries saved by scan-backward shrink by
    # the TP degree; GSPMD inserts the all-gather/reduce-scatter pair.
    "act_res_seq": "model",
    "act_embed": None,
    "act_heads": "model",
    "act_kv_heads": "model",
    "act_mlp": "model",
    "act_vocab": "model",
    "act_experts": "model",
    "act_expert_cap": "data",
    "act_expert_group": ("pod", "data"),
    "act_ssm_inner": "model",
    # ---- decode caches ----------------------------------------------
    "cache_batch": ("pod", "data"),
    "cache_seq": None,
    "cache_kv_heads": "model",
}

# long_500k (global_batch=1): the batch axis cannot be sharded; shard the KV
# cache (and decode activations) along the sequence instead — flash-decode
# style partial-softmax merge is inserted automatically by GSPMD.
LONG_CONTEXT_OVERRIDES: Dict[str, AxisMap] = {
    "act_batch": None,
    "cache_batch": None,
    "cache_seq": ("pod", "data"),
}


@dataclass(frozen=True)
class ShardingRules:
    table: Dict[str, AxisMap] = field(default_factory=lambda: dict(DEFAULT_RULES))
    # logical axes that may shard UNEVENLY (GSPMD pads the last shard).
    # Perf variant for archs whose head counts don't divide the TP degree
    # (phi3-medium: 40 heads over TP=16 -> 3/chip instead of 40/chip
    # replicated); see EXPERIMENTS.md §Perf.
    allow_uneven: Tuple[str, ...] = ()

    def with_overrides(self, **overrides: AxisMap) -> "ShardingRules":
        t = dict(self.table)
        t.update(overrides)
        return ShardingRules(t, self.allow_uneven)

    def with_uneven(self, *axes: str) -> "ShardingRules":
        return ShardingRules(dict(self.table), tuple(axes))

    def for_shape_kind(self, kind: str) -> "ShardingRules":
        if kind == "long_decode":
            return self.with_overrides(**LONG_CONTEXT_OVERRIDES)
        return self

    # ------------------------------------------------------------------
    def spec(self, mesh: Mesh, axes: Sequence[Optional[str]],
             shape: Sequence[int]) -> P:
        """PartitionSpec for a tensor with given logical axes and shape."""
        if len(axes) != len(shape):
            raise ValueError(f"axes {axes} do not match shape {shape}")
        mesh_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
        used: set = set()
        out = []
        for dim, name in zip(shape, axes):
            entry: AxisMap = self.table.get(name) if name else None
            cand = tuple(a for a in _as_tuple(entry)
                         if a in mesh_sizes and a not in used)
            uneven_ok = name in self.allow_uneven
            # longest prefix that divides the dimension (or, for axes opted
            # into uneven sharding, merely fits: GSPMD pads the last shard)
            while cand:
                prod = int(np.prod([mesh_sizes[a] for a in cand]))
                if dim % prod == 0 or (uneven_ok and dim >= prod):
                    break
                cand = cand[:-1]
            if cand:
                used.update(cand)
                out.append(cand if len(cand) > 1 else cand[0])
            else:
                out.append(None)
        while out and out[-1] is None:
            out.pop()
        return P(*out)

    def sharding(self, mesh: Mesh, axes: Sequence[Optional[str]],
                 shape: Sequence[int]) -> NamedSharding:
        return NamedSharding(mesh, self.spec(mesh, axes, shape))


def tree_shardings(mesh: Mesh, shapes_tree, axes_tree,
                   rules: Optional[ShardingRules] = None):
    """Map (shape-tree, logical-axes-tree) -> NamedSharding tree."""
    import jax
    rules = rules or ShardingRules()

    def one(sds, axes):
        return rules.sharding(mesh, axes, sds.shape)

    return jax.tree.map(one, shapes_tree, axes_tree,
                        is_leaf=lambda x: isinstance(x, tuple) and
                        all(isinstance(e, (str, type(None))) for e in x))
