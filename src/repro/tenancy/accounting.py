"""Per-tenant accounting: busy-time, joules, and EDP attribution.

The scheduler runtime conserves iteration *count*, not identity — a
drained batch's chunks are not tenant-tagged. What is known exactly is
which jobs composed the batch and how many items each tenant contributed,
so a batch's busy seconds, wall time, and energy are attributed to
tenants proportionally to their item share (the same proportionality the
paper's eq. (4) uses to split time between devices). Attribution happens
at batch finalization, which keeps the accountant O(tenants) in memory on
a long-lived daemon.

Per-tenant EDP uses the tenant's *attributed* energy and wall time
(E_t · T_t, Gonzales & Horowitz per tenant): the number a per-tenant
energy bill / efficiency SLO would be written against.

Attributed joules are *marginal* (active-power × attributed busy time):
on the double-buffered drain consecutive batches overlap in wall-clock,
so charging each batch's idle/base energy over its own window would bill
the same idle seconds to several batches — the runtime-level idle/base
energy remains a platform cost, visible in EnergyModel.energy reports,
not in per-tenant bills. Wall time gets the same de-overlap treatment:
when the caller supplies the batch's monotonic window, only the part
past the previously accounted window is attributed, so Σ wall_s across
tenants tracks real elapsed pipeline time, not pipeline_depth× it. Only *completed* batches are attributed: a
failed batch's jobs are requeued and re-run in full, so attributing the
failed attempt too would double-count the tenant's items (and overstate
its fairness share); the energy a failed attempt burned is waste charged
to no tenant.

Soft energy budgets: ``derate_weights()`` maps each over-budget tenant to
``budget/spent`` (floored at ``derate_floor``) — the sharded queue applies
it as a multiplicative weight derate, so an energy hog keeps running but
at a shrunken share instead of being cut off (enforcement at the
arbitration layer, as in Dev et al.'s power-budgeted CPU-GPU chips).
"""
from __future__ import annotations

import threading
from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Optional, TYPE_CHECKING

if TYPE_CHECKING:                            # pragma: no cover
    from repro.core.energy import EnergyModel
    from repro.core.scheduler import ScheduleResult
    from repro.queue.job import Job


@dataclass
class TenantUsage:
    """Cumulative attributed usage for one tenant. ``queue_delays`` is a
    ring of the most recent DELAY_CAP samples (bounded memory on a
    daemon, but the percentiles stay live instead of freezing at the
    first DELAY_CAP jobs)."""
    items: int = 0
    busy_s: float = 0.0                      # attributed device-busy time
    wall_s: float = 0.0                      # attributed batch wall time
    energy_j: float = 0.0
    batches: int = 0
    queue_delays: List[float] = field(default_factory=list)
    delay_pos: int = 0                       # ring write cursor

    @property
    def edp(self) -> float:
        return self.energy_j * self.wall_s

    def as_dict(self) -> Dict:
        return {"items": self.items, "busy_s": self.busy_s,
                "wall_s": self.wall_s, "energy_j": self.energy_j,
                "edp": self.edp, "batches": self.batches}


class TenantAccountant:
    DELAY_CAP = 100_000                      # bounded memory on a daemon

    def __init__(self, registry=None,
                 energy_model: Optional["EnergyModel"] = None,
                 derate_floor: float = 0.1):
        self.registry = registry
        self.energy_model = energy_model
        self.derate_floor = derate_floor
        self._usage: Dict[str, TenantUsage] = {}
        self._window_end = float("-inf")     # monotonic de-overlap cursor
        # derates computed OUTSIDE this accountant (the federation tier's
        # global energy budgets); merged into derate_weights() by min()
        self._external: Dict[str, float] = {}
        self._lock = threading.Lock()

    def usage(self, tenant: str) -> TenantUsage:
        with self._lock:
            u = self._usage.get(tenant)
            if u is None:
                u = self._usage[tenant] = TenantUsage()
            return u

    # -- attribution ----------------------------------------------------
    def record_batch(self, jobs: Iterable["Job"],
                     result: Optional["ScheduleResult"],
                     window: Optional[tuple] = None,
                     count_items: bool = True) -> Dict[str, float]:
        """Attribute one finalized batch to its tenants by item share.

        ``window`` is the batch's monotonic ``(submitted_at, finished_at)``
        span; when given, only the part past the previously accounted
        window counts as wall time (overlapping pipelined batches must
        not each bill the full span). Returns the share map; each
        ChunkRecord in the batch gets the map stamped into
        ``meta["tenant_shares"]`` so downstream consumers of the record
        stream (ledgers, traces) can re-split per-chunk numbers without
        re-deriving batch composition.

        ``count_items=False`` charges busy time / wall / joules but NOT
        item counts: a *cancelled* (deadline-preempted) batch consumed
        real device time that no retry gives back, yet its unfinished
        jobs requeue and the completing attempt will charge the items —
        charging them here too would double-count the tenant's share.
        """
        items: Dict[str, int] = {}
        for j in jobs:
            items[j.tenant] = items.get(j.tenant, 0) + j.items
        total = sum(items.values())
        if total <= 0 or result is None:
            return {}
        shares = {t: n / total for t, n in items.items()}
        busy = result.busy_seconds()
        busy_total = sum(busy.values())
        energy_total = self.energy_model.busy_energy_j(busy) \
            if self.energy_model is not None else 0.0
        for rec in result.records:
            # independent copy per record: a consumer mutating one
            # record's stamp must not corrupt its batch-mates'
            rec.meta["tenant_shares"] = dict(shares)
        with self._lock:
            wall = result.total_time
            if window is not None:
                start, end = window
                wall = min(wall, max(0.0, end - max(start,
                                                    self._window_end)))
                self._window_end = max(self._window_end, end)
            for t, share in shares.items():
                u = self._usage.setdefault(t, TenantUsage())
                if count_items:
                    u.items += items[t]
                u.busy_s += share * busy_total
                u.wall_s += share * wall
                u.energy_j += share * energy_total
                u.batches += 1
        return dict(shares)

    def record_queue_delay(self, tenant: str, delay_s: float) -> None:
        with self._lock:
            u = self._usage.setdefault(tenant, TenantUsage())
            if len(u.queue_delays) < self.DELAY_CAP:
                u.queue_delays.append(delay_s)
            else:                            # overwrite oldest (ring)
                u.queue_delays[u.delay_pos % self.DELAY_CAP] = delay_s
            u.delay_pos += 1

    # -- soft energy budgets --------------------------------------------
    def set_external_derates(self, factors: Dict[str, float]) -> None:
        """Install weight derates computed by an outer enforcement tier
        (federation: a tenant's *fleet-wide* joules vs. its budget).
        Replaces the previous external map; ``derate_weights()`` merges
        by min(), so whichever enforcement is tighter — local attribution
        or the global aggregate — wins."""
        with self._lock:
            self._external = {
                t: min(1.0, max(self.derate_floor, float(f)))
                for t, f in factors.items()}

    def derate_weights(self) -> Dict[str, float]:
        """Weight factors for tenants over their soft energy budget:
        ``budget/spent`` clamped to [derate_floor, 1]; in-budget tenants
        are omitted (full weight). External (federation-global) derates
        merge in by min()."""
        out: Dict[str, float] = {}
        with self._lock:
            external = dict(self._external)
            if self.registry is not None:
                for t, u in self._usage.items():
                    budget = self.registry.get(t).energy_budget_j
                    if budget is None or u.energy_j <= budget:
                        continue
                    out[t] = max(self.derate_floor, budget / u.energy_j)
        for t, f in external.items():
            out[t] = min(out.get(t, 1.0), f)
        return out

    # -- reporting ------------------------------------------------------
    def snapshot(self) -> Dict[str, Dict]:
        from repro.queue.service import percentiles
        with self._lock:
            out = {}
            for t, u in sorted(self._usage.items()):
                d = u.as_dict()
                d["queue_delay_s"] = percentiles(u.queue_delays)
                out[t] = d
            return out
