"""ShardedQueueManager: one QueueManager shard per tenant, DWRR drain.

The tenant-blind QueueManager drains strict (priority, FIFO) order, so one
tenant's burst inflates every other tenant's queue delay. This manager
keeps the QueueManager surface (``put/pop/cancel/mark_running/
mark_finished/requeue/depth/backlog_items/...``) but shards jobs by
``job.tenant`` and interleaves ``pop()`` across tenants with
deficit-weighted round robin (DWRR, Shreedhar & Varghese):

  * each tenant carries a deficit counter (in job *items* — the unit the
    capacity model and the scheduler's iteration space both use);
  * on a tenant's turn its deficit grows by ``quantum × effective_weight``
    and its head job is served while the deficit covers the job's items;
  * a tenant whose shard empties leaves the round with its deficit reset
    (classic DWRR — an idle tenant banks no credit), so drained-work share
    converges to weight share among *backlogged* tenants and an
    underloaded tenant is never blocked by another tenant's backlog
    (work conservation: the rotation only ever skips empty or
    quota-capped shards);
  * burst credits: a tenant whose spec carries ``burst_quantum`` keeps up
    to that many items of deficit when its shard empties (bounded
    carry-over, a DWRR/token-bucket hybrid) so a spiky interactive tenant
    does not re-pay the ramp-up rounds on every burst; the default 0
    keeps the classic reset.

``pop_many(max_n)`` forms a whole batch under ONE lock acquisition (the
per-pop DWRR scan repeats while the lock is held, so deficits are
charged per item and fairness shares are identical to ``max_n`` single
pops) — which is what JobService uses to build its scheduler batches
without re-taking the shard lock per job.

Within a shard, the tenant's own priority/FIFO order is untouched.

Quota isolation: a tenant at its ``max_inflight`` (jobs popped but not yet
finished) is skipped by the drain until ``mark_finished`` frees a slot —
its backlog waits without consuming anyone else's turn.

Energy-budget derating: ``set_weight_derates`` scales effective weights by
the accounting layer's soft-budget factors, so a tenant burning past its
joule budget keeps running but at a derated share.

Single-tenant equivalence: with every job on the default tenant there is
one shard and DWRR degenerates to the shard's own heap order — identical
behavior to the PR 3 queue.
"""
from __future__ import annotations

import math
import threading
import time
from typing import Dict, List, Optional

from repro import telemetry as telemetry_mod
from repro.queue.job import Job, JobState
from repro.queue.manager import QueueManager, drain_with_deadline


class ShardedQueueManager:
    def __init__(self, registry=None, quantum: int = 64, telemetry=None):
        # ``registry`` is duck-typed (TenantRegistry: .get(name).weight /
        # .max_inflight); None means every tenant weighs 1 and has no quota
        self.registry = registry
        self.quantum = max(1, int(quantum))
        self._shards: Dict[str, QueueManager] = {}
        self._order: List[str] = []          # rotation order (first-seen)
        self._cursor = 0
        self._replenished = False            # current turn already credited
        self._deficit: Dict[str, float] = {}
        # popped-but-not-finished job ids (the quota denominator); ids,
        # not a counter, so cancel() of a popped-but-unbound job can
        # release its slot instead of leaking it
        self._popped: Dict[str, set] = {}
        self._derate: Dict[str, float] = {}      # energy-budget factors
        self._lock = threading.RLock()
        self._not_empty = threading.Condition(self._lock)
        # arrival listeners (JobService drain wakeup) — fired after
        # put/requeue, outside the manager lock; see QueueManager
        self._listeners: List = []
        # metrics: DWRR pick counters per tenant on the drain path, plus a
        # collector publishing per-tenant depth/backlog gauges at snapshot
        # time (pull, not push — depth reads never ride the hot path)
        self.telemetry = telemetry_mod.resolve(telemetry)
        self._tel: Dict[tuple, object] = {}
        if self.telemetry is not None:
            self.telemetry.registry.add_collector(self._collect)

    # -- telemetry plumbing ---------------------------------------------
    def _tel_pop(self, tenant: str, items: int) -> None:
        key = ("pop", tenant)
        c = self._tel.get(key)
        if c is None:
            reg = self.telemetry.registry
            c = self._tel[key] = (
                reg.counter("queue.dwrr_pops", tenant=tenant),
                reg.counter("queue.dwrr_items", tenant=tenant))
        c[0].add(1)
        c[1].add(items)

    def _collect(self) -> None:
        reg = self.telemetry.registry
        with self._lock:
            rows = [(t, self._shards[t].depth(),
                     self._shards[t].backlog_items(),
                     len(self._popped.get(t, ())))
                    for t in self._order]
        for tenant, depth, backlog, outstanding in rows:
            reg.gauge("queue.depth", tenant=tenant).set(depth)
            reg.gauge("queue.backlog_items", tenant=tenant).set(backlog)
            reg.gauge("queue.outstanding", tenant=tenant).set(outstanding)

    # -- tenant plumbing ------------------------------------------------
    def _shard(self, tenant: str) -> QueueManager:
        shard = self._shards.get(tenant)
        if shard is None:
            shard = self._shards[tenant] = QueueManager()
            self._order.append(tenant)
            self._deficit[tenant] = 0.0
            self._popped.setdefault(tenant, set())
        return shard

    def _spec(self, tenant: str):
        return self.registry.get(tenant) if self.registry is not None \
            else None

    def _weight(self, tenant: str) -> float:
        spec = self._spec(tenant)
        w = spec.weight if spec is not None else 1.0
        return max(1e-9, w * self._derate.get(tenant, 1.0))

    def effective_weight(self, tenant: str) -> float:
        """The weight the DWRR drain actually uses (spec weight × energy
        derate, floored) — the admission gate's fair-share capacity model
        asks this instead of re-deriving the policy."""
        with self._lock:
            return self._weight(tenant)

    def _under_quota(self, tenant: str) -> bool:
        spec = self._spec(tenant)
        if spec is None or spec.max_inflight is None:
            return True
        return len(self._popped.get(tenant, ())) < spec.max_inflight

    def set_weight_derates(self, factors: Dict[str, float]) -> None:
        """Replace the energy-budget derate map (factor ∈ (0, 1]); tenants
        not present recover full weight."""
        with self._lock:
            self._derate = {t: min(1.0, max(1e-6, f))
                            for t, f in factors.items()}

    def weight_derate(self, tenant: str) -> float:
        with self._lock:
            return self._derate.get(tenant, 1.0)

    def tenants(self) -> List[str]:
        with self._lock:
            return list(self._order)

    # -- admission side -------------------------------------------------
    def add_listener(self, fn) -> None:
        """Register ``fn()`` to run after each job arrival (put/requeue)."""
        with self._lock:
            self._listeners.append(fn)

    def _notify_listeners(self) -> None:
        with self._lock:
            listeners = list(self._listeners)
        for fn in listeners:
            fn()

    def put(self, job: Job) -> None:
        with self._not_empty:
            self._shard(job.tenant).put(job)
            self._not_empty.notify()
        self._notify_listeners()

    def cancel(self, job_id: str) -> bool:
        with self._not_empty:
            for tenant, shard in self._shards.items():
                if shard.cancel(job_id):
                    # a job cancelled in the popped-but-unbound window
                    # releases its quota slot (mark_finished will never
                    # run for it) and may unblock a capped shard
                    self._popped[tenant].discard(job_id)
                    self._not_empty.notify()
                    return True
            return False

    # -- scheduler side: the DWRR drain ---------------------------------
    def pop(self, timeout: Optional[float] = None) -> Optional[Job]:
        """Next job in deficit-weighted-round-robin tenant order (priority
        order within the tenant); same blocking contract as
        QueueManager.pop (``timeout=None`` → non-blocking). The wait is
        deadline-based: puts to a quota-capped shard notify without
        making anything eligible, and each such spurious wake-up must
        consume the remaining budget, not restart it — otherwise steady
        traffic to a capped shard pins the caller in pop() forever."""
        with self._not_empty:
            job = self._pop_locked()
            if job is not None or not timeout:
                return job
            deadline = time.monotonic() + timeout
            while True:
                remaining = deadline - time.monotonic()
                if remaining <= 0 or not self._not_empty.wait(remaining):
                    return self._pop_locked()
                job = self._pop_locked()
                if job is not None:
                    return job

    def pop_many(self, max_n: int,
                 timeout: Optional[float] = None) -> List[Job]:
        """Up to ``max_n`` jobs under ONE lock acquisition — the per-pop
        DWRR scan repeats with the lock held, so deficits are charged
        per item and drained shares match ``max_n`` single pops exactly.
        Same blocking contract as ``pop``; returns as soon as at least
        one job is eligible."""
        with self._not_empty:
            return drain_with_deadline(self._not_empty,
                                       self._pop_many_locked, max_n, timeout)

    def _pop_many_locked(self, max_n: int) -> List[Job]:
        jobs: List[Job] = []
        while len(jobs) < max_n:
            job = self._pop_locked()
            if job is None:
                break
            jobs.append(job)
        return jobs

    def pop_express(self, max_n: int) -> List[Job]:
        """Express-lane drain: up to ``max_n`` *urgent-tier* jobs,
        non-blocking, round-robin across shards so one tenant's urgent
        burst cannot monopolize the lane. Quota still binds (urgency does
        not override isolation), and each popped job's items are charged
        to the tenant's DWRR deficit — allowed to go negative, i.e. the
        tenant *borrows* against its future turns and pays the express
        service back in the regular rotation, so long-run fairness shares
        are preserved."""
        with self._lock:
            jobs: List[Job] = []
            if not self._order:
                return jobs
            start = self._cursor % len(self._order)
            idle_scans = 0
            i = start
            while len(jobs) < max_n and idle_scans < len(self._order):
                tenant = self._order[i % len(self._order)]
                i += 1
                job = None
                if self._under_quota(tenant):
                    job = self._shards[tenant].pop_express(1)
                    job = job[0] if job else None
                if job is None:
                    idle_scans += 1
                    continue
                idle_scans = 0
                self._deficit[tenant] -= job.items      # borrow
                self._popped[tenant].add(job.job_id)
                jobs.append(job)
                if self.telemetry is not None:
                    self._tel_pop(tenant, job.items)
                    self.telemetry.registry.counter(
                        "queue.express_pops", tenant=tenant).add()
            return jobs

    def express_backlog(self) -> int:
        """Queued urgent-tier jobs across all shards (quota-capped shards
        included — their urgency surfaces once a slot frees)."""
        with self._lock:
            return sum(s.express_backlog() for s in self._shards.values())

    def _burst_cap(self, tenant: str) -> float:
        spec = self._spec(tenant)
        return getattr(spec, "burst_quantum", 0.0) or 0.0 \
            if spec is not None else 0.0

    def _eligible_head(self, tenant: str) -> Optional[Job]:
        if not self._under_quota(tenant):
            return None
        return self._shards[tenant].peek()

    def _advance_locked(self) -> None:
        self._cursor = (self._cursor + 1) % max(1, len(self._order))
        self._replenished = False

    def _pop_locked(self) -> Optional[Job]:
        heads = {t: self._eligible_head(t) for t in self._order}
        active = [t for t in self._order if heads[t] is not None]
        if not active:
            return None
        # the tenant's turn persists across pop() calls: it keeps serving
        # while its deficit covers its head, is credited quantum×weight at
        # most once per turn, and the rotation moves on when it cannot
        # afford its head (or empties / hits quota). Rounds in which no
        # tenant can afford its head even after its turn's credit are
        # fast-forwarded in one step — every active tenant banks the same
        # per-round quantum×weight it would have accumulated iterating,
        # so the scan below is O(tenants), not O(head/(quantum·weight)),
        # while the drain order is unchanged.
        needed = {
            t: math.ceil(max(0.0, heads[t].items - self._deficit[t])
                         / (self.quantum * self._weight(t)))
            for t in active}
        skip = max(0, min(needed.values()) - 1)
        if skip:
            for t in active:
                self._deficit[t] += skip * self.quantum * self._weight(t)
        # ≤1 rotation to finish any mid-turn state + ≤1 to reach the
        # first affordable tenant (its residual need is now ≤1 quantum)
        for _ in range(2 * len(self._order) + 2):
            tenant = self._order[self._cursor % len(self._order)]
            head = self._eligible_head(tenant)
            if head is None:
                if self._shards[tenant].peek() is None:
                    # empty shard leaves the round: banked credit capped
                    # at the tenant's burst quantum (0 = classic reset)
                    self._deficit[tenant] = min(self._deficit[tenant],
                                                self._burst_cap(tenant))
                self._advance_locked()      # empty or quota-capped
                continue
            if self._deficit[tenant] < head.items:
                if self._replenished:       # turn's credit already spent
                    self._advance_locked()
                    continue
                self._deficit[tenant] += self.quantum * self._weight(tenant)
                self._replenished = True
                if self._deficit[tenant] < head.items:
                    self._advance_locked()  # keep banking across rounds
                    continue
            self._deficit[tenant] -= head.items
            job = self._shards[tenant].pop()
            if job is not None:
                self._popped[tenant].add(job.job_id)
                if self.telemetry is not None:
                    self._tel_pop(tenant, job.items)
            return job
        return None                         # unreachable by construction

    # -- lifecycle passthrough ------------------------------------------
    def mark_running(self, job: Job, group: str = "*") -> None:
        with self._lock:
            self._shard(job.tenant).mark_running(job, group)

    def mark_finished(self, job: Job, state: JobState) -> None:
        with self._not_empty:
            self._shard(job.tenant).mark_finished(job, state)
            self._popped[job.tenant].discard(job.job_id)
            # a freed quota slot may unblock a capped shard's drain
            self._not_empty.notify()

    def requeue(self, job: Job) -> None:
        with self._not_empty:
            self._shard(job.tenant).requeue(job)
            self._not_empty.notify()
        self._notify_listeners()

    # -- introspection --------------------------------------------------
    def get(self, job_id: str) -> Optional[Job]:
        with self._lock:
            for shard in self._shards.values():
                job = shard.get(job_id)
                if job is not None:
                    return job
            return None

    def depth(self, tenant: Optional[str] = None) -> int:
        with self._lock:
            if tenant is not None:
                shard = self._shards.get(tenant)
                return shard.depth() if shard else 0
            return sum(s.depth() for s in self._shards.values())

    def backlog_items(self, tenant: Optional[str] = None) -> int:
        with self._lock:
            if tenant is not None:
                shard = self._shards.get(tenant)
                return shard.backlog_items() if shard else 0
            return sum(s.backlog_items() for s in self._shards.values())

    def backlog_by_tenant(self) -> Dict[str, int]:
        """Queued items per tenant — admission's per-tenant backlog view."""
        with self._lock:
            return {t: self._shards[t].backlog_items() for t in self._order}

    def outstanding(self, tenant: str) -> int:
        """Jobs popped but not yet finished — the quota the admission gate
        and the drain both enforce."""
        with self._lock:
            return len(self._popped.get(tenant, ()))

    def queued(self, tenant: str) -> int:
        """ADMITTED jobs not yet handed to the drain. Popped jobs stay
        ADMITTED until mark_running (two-phase pop), so a plain depth()
        would count them twice against a quota that also counts
        outstanding() — this view excludes them."""
        with self._lock:
            return self._queued_locked(tenant)

    def _queued_locked(self, tenant: str) -> int:
        shard = self._shards.get(tenant)
        if shard is None:
            return 0
        popped = self._popped.get(tenant, ())
        return sum(1 for j in shard.jobs(JobState.ADMITTED)
                   if j.job_id not in popped)

    def unfinished(self, tenant: str) -> int:
        """Queued + popped-but-unfinished, in ONE lock acquisition — the
        admission quota's denominator. Reading queued() and outstanding()
        separately lets a concurrent pop move a job between the two views
        mid-read and undercount by one."""
        with self._lock:
            return self._queued_locked(tenant) \
                + len(self._popped.get(tenant, ()))

    def inflight(self, group: Optional[str] = None) -> int:
        with self._lock:
            return sum(s.inflight(group) for s in self._shards.values())

    def jobs(self, state: Optional[JobState] = None) -> List[Job]:
        with self._lock:
            out: List[Job] = []
            for t in self._order:
                out.extend(self._shards[t].jobs(state))
            return out

    def counts(self) -> Dict[str, int]:
        with self._lock:
            out: Dict[str, int] = {}
            for shard in self._shards.values():
                for k, v in shard.counts().items():
                    out[k] = out.get(k, 0) + v
            return out
