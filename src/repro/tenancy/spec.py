"""Tenant specs and the registry — the contract side of multi-tenancy.

A TenantSpec is the resource-arbitration contract for one tenant:

  weight           relative share of drained work under contention (the
                   DWRR weight; 10:1 weights → 10:1 drained-items share
                   while both tenants are backlogged)
  max_inflight     hard cap on jobs popped-but-not-finished at once; the
                   admission gate defers work beyond it and the sharded
                   queue will not drain past it (per-tenant concurrency
                   isolation)
  slo_delay_s      per-tenant queue-delay SLO used by quota-aware
                   admission instead of the controller's global SLO
  energy_budget_j  soft energy budget: a tenant whose attributed joules
                   exceed it gets its effective DWRR weight derated
                   (budget/spent, floored), not its jobs dropped
  burst_quantum    bounded deficit carry-over in items (DWRR/token-bucket
                   hybrid): when the tenant's shard empties it keeps up
                   to this much banked deficit instead of the classic
                   reset to zero, so a spiky interactive tenant does not
                   re-pay ramp-up each burst; 0 (default) keeps classic
                   DWRR behavior

The registry is deliberately permissive: get() auto-registers unknown
tenants with a default spec so a single-tenant deployment (everything
under ``tenant="default"``) needs zero configuration and behaves exactly
like the unsharded queue.

This module must stay import-free of ``repro.queue`` — admission imports
the registry type only lazily/duck-typed, and a spec file is parseable
without pulling the runtime in.
"""
from __future__ import annotations

import json
import threading
from dataclasses import dataclass
from typing import Dict, Iterable, List, Optional

DEFAULT_TENANT = "default"


@dataclass(frozen=True)
class TenantSpec:
    name: str
    weight: float = 1.0
    max_inflight: Optional[int] = None
    slo_delay_s: Optional[float] = None
    energy_budget_j: Optional[float] = None
    burst_quantum: float = 0.0

    def __post_init__(self):
        if not self.name:
            raise ValueError("tenant name must be non-empty")
        if self.weight <= 0.0:
            raise ValueError(f"tenant {self.name}: weight must be > 0")
        if self.max_inflight is not None and self.max_inflight < 1:
            raise ValueError(f"tenant {self.name}: max_inflight must be >= 1")
        if self.burst_quantum < 0.0:
            raise ValueError(
                f"tenant {self.name}: burst_quantum must be >= 0")

    def as_dict(self) -> Dict:
        return {"name": self.name, "weight": self.weight,
                "max_inflight": self.max_inflight,
                "slo_delay_s": self.slo_delay_s,
                "energy_budget_j": self.energy_budget_j,
                "burst_quantum": self.burst_quantum}


def _parse_one(token: str) -> TenantSpec:
    """``name[:weight=W][:quota=N][:slo=S][:energy=J][:burst=B]`` →
    TenantSpec."""
    parts = token.strip().split(":")
    name, kw = parts[0], {}
    keys = {"weight": ("weight", float),
            "quota": ("max_inflight", int),
            "slo": ("slo_delay_s", float),
            "energy": ("energy_budget_j", float),
            "burst": ("burst_quantum", float)}
    for p in parts[1:]:
        if "=" not in p:
            raise ValueError(f"tenant spec {token!r}: bad field {p!r}")
        k, v = p.split("=", 1)
        if k not in keys:
            raise ValueError(f"tenant spec {token!r}: unknown field {k!r}")
        attr, cast = keys[k]
        kw[attr] = cast(v)
    return TenantSpec(name, **kw)


class TenantRegistry:
    """Thread-safe name → TenantSpec map with auto-registration."""

    def __init__(self, specs: Iterable[TenantSpec] = ()):
        self._specs: Dict[str, TenantSpec] = {}
        self._lock = threading.Lock()
        for s in specs:
            self.register(s)

    # -- construction ---------------------------------------------------
    @classmethod
    def parse(cls, text: str) -> "TenantRegistry":
        """CLI form: ``gold:weight=10,free:weight=1:quota=8:slo=2.0``."""
        return cls(_parse_one(t) for t in text.split(",") if t.strip())

    @classmethod
    def from_file(cls, path: str) -> "TenantRegistry":
        """JSON spec file: a list of objects or ``{"tenants": [...]}``."""
        with open(path, "r", encoding="utf-8") as fh:
            data = json.load(fh)
        if isinstance(data, dict):
            data = data.get("tenants", [])

        def opt(value, cast):
            # cast here so a string value raises ValueError (which CLI
            # callers turn into a usage error), not a TypeError later
            # from a spec-validation comparison
            return None if value is None else cast(value)

        specs = []
        for d in data:
            specs.append(TenantSpec(
                name=d["name"], weight=float(d.get("weight", 1.0)),
                max_inflight=opt(d.get("max_inflight"), int),
                slo_delay_s=opt(d.get("slo_delay_s"), float),
                energy_budget_j=opt(d.get("energy_budget_j"), float),
                burst_quantum=float(d.get("burst_quantum", 0.0))))
        return cls(specs)

    # -- access ---------------------------------------------------------
    def register(self, spec: TenantSpec) -> TenantSpec:
        with self._lock:
            self._specs[spec.name] = spec
            return spec

    def get(self, name: str) -> TenantSpec:
        """Spec for ``name``; unknown tenants are auto-registered with the
        default contract (weight 1, no quota/SLO/budget) so single-tenant
        callers never have to touch the registry."""
        with self._lock:
            spec = self._specs.get(name)
            if spec is None:
                spec = self._specs[name] = TenantSpec(name)
            return spec

    def names(self) -> List[str]:
        with self._lock:
            return sorted(self._specs)

    def any_gating(self) -> bool:
        """True when any spec carries an admission-gate contract (SLO or
        in-flight quota) — callers use it to enable the admission
        controller even when no global SLO was configured, so a tenant's
        ``slo=``/``quota=`` is never silently inert."""
        with self._lock:
            return any(s.slo_delay_s is not None or s.max_inflight is not None
                       for s in self._specs.values())

    def __contains__(self, name: str) -> bool:
        with self._lock:
            return name in self._specs

    def __len__(self) -> int:
        with self._lock:
            return len(self._specs)

    def as_dict(self) -> Dict[str, Dict]:
        with self._lock:
            return {n: s.as_dict() for n, s in sorted(self._specs.items())}
