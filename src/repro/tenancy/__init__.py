"""Multi-tenant scheduling: sharded queues, weighted-fair drain, quotas,
per-tenant energy/EDP accounting.

The queue subsystem (`repro.queue`) arbitrates *jobs*; this package
arbitrates *tenants* on top of it: a TenantRegistry holds each tenant's
contract (DWRR weight, in-flight quota, queue-delay SLO, soft energy
budget), a ShardedQueueManager drains one QueueManager shard per tenant
in deficit-weighted-round-robin order, and a TenantAccountant attributes
each drained batch's busy time and joules back to tenants — closing the
loop by derating the weight of tenants past their energy budget. With a
single (default) tenant every piece degenerates to the unsharded PR 3
behavior.
"""
from repro.tenancy.spec import (DEFAULT_TENANT, TenantRegistry, TenantSpec)
from repro.tenancy.sharded_queue import ShardedQueueManager
from repro.tenancy.accounting import TenantAccountant, TenantUsage

__all__ = [
    "DEFAULT_TENANT", "TenantRegistry", "TenantSpec",
    "ShardedQueueManager", "TenantAccountant", "TenantUsage",
]
