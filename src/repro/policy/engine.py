"""AdaptivePolicy: windowed admission smoothing + rebalance cooldown.

Two decision surfaces, both driven by the same sliding-window history:

**Admission** (``admission_delay``): the gate's projected queueing delay
is a point estimate — one fast drain or one slow chunk flips ADMIT/DEFER
for everything behind it. Three history terms fix that:

* *smoothing* — ``max(point, window_ewma)`` reacts instantly when load
  rises (the point sample dominates) but decays slowly when it falls
  (the EWMA holds the gate up through the tail of a burst);
* *trend projection* — a positive least-squares slope over the window
  adds ``slope × lead_s`` to the estimate, so a ramping backlog starts
  deferring *before* it slams into the SLO edge (this is what lowers
  the admitted-tail p99, not just the flip count);
* *hysteresis* — when the caller passes its SLO, the policy is a
  Schmitt trigger: once the estimate crosses the SLO the gate latches
  DEFER and only re-admits after the windowed ``recovery_q`` quantile
  falls below ``slo × (1 - hysteresis)``. Without the latch a backlog
  hovering exactly at the band edge alternates ADMIT/DEFER on every
  sample (the point-gate's worst case).

A sample more than ``spike_threshold`` × the windowed median is counted
as a spike — the telemetry signal operators alarm on.

Gates are keyed: the admission controller passes ``key=`` the tenant
name (or ``"*"`` for the tenant-blind global gate), and each key gets
its own window and latch. One shared window would let a low-weight
tenant's enormous fair-share delay projections poison every other
tenant's smoothed estimate — observed as a high-weight tenant's jobs
being rejected outright the moment a starved tenant shares the gate.

**Rebalance** (``allow_rebalance``): straggler-driven derate maps can
flap when a group hovers around the detection threshold, and every flap
re-advertises capacity to the admission gate. A proposed map that
differs from the applied one by less than ``rebalance_epsilon`` on every
group is a no-op; a significant change lands immediately unless one
landed within the last ``cooldown_s`` — then it's suppressed (and
counted). A *persistent* change is therefore delayed at most one
cooldown period, never starved (property-tested).
"""
from __future__ import annotations

import math
import threading
import time
from typing import Dict, Optional

from repro.policy.window import SlidingWindow


class _GateState:
    """Per-key admission state: one sample window plus the Schmitt
    latch. Keys are admission populations (tenant name, or "*" for the
    global gate) — they share nothing, by design."""

    __slots__ = ("window", "deferring")

    def __init__(self, window_s: float, alpha: float):
        self.window = SlidingWindow(window_s, alpha=alpha)
        self.deferring = False


class AdaptivePolicy:
    def __init__(self, window_s: float = 5.0, spike_threshold: float = 3.0,
                 cooldown_s: float = 1.0, alpha: float = 0.3,
                 min_samples: int = 5, rebalance_epsilon: float = 0.05,
                 lead_s: float = 0.1, hysteresis: float = 0.1,
                 recovery_q: float = 0.9, telemetry=None, clock=None):
        assert window_s > 0.0
        assert spike_threshold >= 1.0
        assert cooldown_s >= 0.0
        assert lead_s >= 0.0
        assert 0.0 <= hysteresis < 1.0
        assert 0.0 <= recovery_q <= 1.0
        self.window_s = window_s
        self.spike_threshold = spike_threshold
        self.cooldown_s = cooldown_s
        self.min_samples = min_samples
        self.rebalance_epsilon = rebalance_epsilon
        self.lead_s = lead_s
        self.hysteresis = hysteresis
        self.recovery_q = recovery_q
        self.telemetry = telemetry
        self.clock = clock if clock is not None else time.monotonic
        self._alpha = alpha
        self._gates: Dict[str, _GateState] = {}
        self.spikes = 0
        self.rebalances = 0
        self.rebalances_suppressed = 0
        self.hysteresis_holds = 0
        self._last_rebalance: Optional[float] = None
        # serializes the rebalance check-then-act (straggler monitor and
        # manual update_stragglers calls can race)
        self._lock = threading.Lock()

    # -- admission -----------------------------------------------------
    def _gate_state(self, key: str) -> _GateState:
        st = self._gates.get(key)
        if st is None:
            st = self._gates[key] = _GateState(self.window_s, self._alpha)
        return st

    @property
    def delay_window(self) -> SlidingWindow:
        """The global ("*") gate's sample window — the only gate in
        registry-less deployments and the virtual-clock benchmarks."""
        return self._gate_state("*").window

    def admission_delay(self, now: float, point: float,
                        slo: Optional[float] = None,
                        key: str = "*") -> float:
        """Fold a point projected-delay sample into ``key``'s window and
        return the smoothed estimate the admission gate should act on.
        With ``slo`` the estimate includes the Schmitt latch: while
        latched, the returned value stays strictly above the SLO even
        when the point sample dips back under it, until the windowed
        ``recovery_q`` quantile clears ``slo × (1 - hysteresis)``.
        Not thread-safe on its own — the admission controller already
        serializes its gate."""
        st = self._gate_state(key)
        w = st.window
        if w.count >= self.min_samples:
            med = w.median(now)
            if med > 0.0 and point > self.spike_threshold * med:
                self.spikes += 1
                if self.telemetry is not None:
                    self.telemetry.registry.counter(
                        "policy.spikes", gate=key).add()
                    self.telemetry.tracer.instant(
                        "policy_spike", tid="policy", gate=key,
                        delay_s=round(point, 6), median_s=round(med, 6))
        w.observe(now, point)
        est = max(point, w.ewma)
        # trend projection — only once the window covers at least the
        # lead time: a slope fit over samples microseconds apart (a
        # submit burst) extrapolates far beyond its data and would
        # reject everything behind the first few arrivals
        if self.lead_s > 0.0 and w.span(now) >= self.lead_s:
            trend = w.slope(now)
            if trend > 0.0:
                est += trend * self.lead_s
        if slo is not None:
            if st.deferring:
                recent = w.quantile(self.recovery_q, now)
                if max(est, recent) > slo * (1.0 - self.hysteresis):
                    if est <= slo:        # the latch, not the estimate,
                        self.hysteresis_holds += 1   # is deciding
                        if self.telemetry is not None:
                            self.telemetry.registry.counter(
                                "policy.hysteresis_holds", gate=key).add()
                    est = max(est, math.nextafter(slo, math.inf))
                else:
                    st.deferring = False
            if est > slo:
                st.deferring = True
        return est

    # -- rebalance gating ----------------------------------------------
    def significant(self, new: Dict[str, float],
                    old: Dict[str, float]) -> bool:
        eps = self.rebalance_epsilon
        for g in set(new) | set(old):
            if abs(new.get(g, 1.0) - old.get(g, 1.0)) > eps:
                return True
        return False

    def allow_rebalance(self, now: float, new: Dict[str, float],
                        old: Dict[str, float]) -> bool:
        """True iff the proposed derate map should be applied now.
        Insignificant changes return False without counting (nothing to
        apply); significant ones inside the cooldown are suppressed and
        counted; otherwise the change is approved and the cooldown
        restarts."""
        if not self.significant(new, old):
            return False
        with self._lock:
            last = self._last_rebalance
            if last is not None and now - last < self.cooldown_s:
                self.rebalances_suppressed += 1
                if self.telemetry is not None:
                    self.telemetry.registry.counter(
                        "policy.rebalances_suppressed").add()
                    self.telemetry.tracer.instant(
                        "rebalance_suppressed", tid="policy",
                        wait_s=round(self.cooldown_s - (now - last), 6))
                return False
            self._last_rebalance = now
            self.rebalances += 1
        if self.telemetry is not None:
            self.telemetry.registry.counter("policy.rebalances").add()
        return True

    def stats(self) -> Dict[str, float]:
        return {
            "spikes": float(self.spikes),
            "rebalances": float(self.rebalances),
            "rebalances_suppressed": float(self.rebalances_suppressed),
            "hysteresis_holds": float(self.hysteresis_holds),
            "deferring": float(any(st.deferring
                                   for st in self._gates.values())),
            "delay_ewma": self.delay_window.ewma,
            "delay_samples": float(sum(st.window.count
                                       for st in self._gates.values())),
        }
