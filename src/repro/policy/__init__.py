"""Adaptive control policy: decisions from history, not point samples.

The control loops around the scheduler (admission backpressure,
straggler-driven capacity rebalance, refill sizing) originally gated on
point-in-time thresholds — one noisy sample could flip a decision, and
bursty traffic made them oscillate. This package is the GPUScheduler-style
policy/monitor split applied to our stack:

- ``SlidingWindow`` — bounded ring of timestamped samples with a horizon,
  EWMA, and windowed quantiles (``repro.policy.window``).
- ``AdaptivePolicy`` — the decision engine (``repro.policy.engine``):
  a windowed projected-delay view for the admission gate (up fast on
  spikes, down slowly — hysteresis kills decision flapping), spike
  detection counters, and a post-rebalance cooldown so straggler-derate
  churn cannot thrash capacity advertisements.

Consumers: ``AdmissionController(policy=...)``, ``StragglerDetector``
(windowed baselines), and the partitioner's adaptive ``refill_chunks``
sizing (which keeps its own refill/steal history — see
``HeterogeneousPartitioner``). Everything here is stdlib-only and
telemetry-instrumented.
"""
from repro.policy.engine import AdaptivePolicy
from repro.policy.window import SlidingWindow

__all__ = ["AdaptivePolicy", "SlidingWindow"]
