"""Sliding-window statistics over timestamped samples.

``SlidingWindow`` keeps a bounded deque of ``(t, v)`` pairs, evicting
samples older than ``horizon_s`` on every observation and read, plus a
running EWMA that survives eviction (the EWMA summarizes *all* history
with exponential decay; the window bounds the quantile/extreme views to
recent behavior). All methods are O(window) worst case with a hard
``max_samples`` cap so a traffic spike cannot grow memory unboundedly.

Not thread-safe by itself — every consumer in this repo already
serializes its observations (admission under ``_admit_lock``, straggler
observation on the monitor thread), so the window stays lock-free.
"""
from __future__ import annotations

from collections import deque
from typing import Deque, Optional, Tuple


class SlidingWindow:
    def __init__(self, horizon_s: float = 5.0, alpha: float = 0.3,
                 max_samples: int = 256):
        assert horizon_s > 0.0
        assert 0.0 < alpha <= 1.0
        assert max_samples >= 1
        self.horizon_s = horizon_s
        self.alpha = alpha
        self._buf: Deque[Tuple[float, float]] = deque(maxlen=max_samples)
        self._ewma: Optional[float] = None
        self._last: Optional[float] = None

    def observe(self, t: float, v: float) -> None:
        self._evict(t)
        self._buf.append((t, v))
        self._last = v
        self._ewma = v if self._ewma is None else \
            self.alpha * v + (1 - self.alpha) * self._ewma

    def _evict(self, now: float) -> None:
        cutoff = now - self.horizon_s
        buf = self._buf
        while buf and buf[0][0] < cutoff:
            buf.popleft()

    # -- reads ---------------------------------------------------------
    @property
    def count(self) -> int:
        return len(self._buf)

    @property
    def ewma(self) -> float:
        return 0.0 if self._ewma is None else self._ewma

    @property
    def last(self) -> float:
        return 0.0 if self._last is None else self._last

    def values(self, now: Optional[float] = None):
        if now is not None:
            self._evict(now)
        return [v for _, v in self._buf]

    def mean(self, now: Optional[float] = None) -> float:
        vs = self.values(now)
        return sum(vs) / len(vs) if vs else 0.0

    def min(self, now: Optional[float] = None) -> float:
        vs = self.values(now)
        return min(vs) if vs else 0.0

    def max(self, now: Optional[float] = None) -> float:
        vs = self.values(now)
        return max(vs) if vs else 0.0

    def quantile(self, q: float, now: Optional[float] = None) -> float:
        """Nearest-rank quantile of the windowed samples (0 when empty).
        Guaranteed within [window min, window max] for any q in [0, 1]."""
        assert 0.0 <= q <= 1.0
        vs = sorted(self.values(now))
        if not vs:
            return 0.0
        idx = min(len(vs) - 1, max(0, int(round(q * (len(vs) - 1)))))
        return vs[idx]

    def median(self, now: Optional[float] = None) -> float:
        return self.quantile(0.5, now)

    def span(self, now: Optional[float] = None) -> float:
        """Time covered by the windowed samples (0 with fewer than 2)."""
        if now is not None:
            self._evict(now)
        if len(self._buf) < 2:
            return 0.0
        return self._buf[-1][0] - self._buf[0][0]

    def slope(self, now: Optional[float] = None) -> float:
        """Least-squares slope (value units per second) of the windowed
        samples — the window's trend. 0 with fewer than two samples or
        when every sample shares one timestamp. Least-squares rather
        than endpoint difference: endpoints are exactly the noisiest
        samples, and a gate acting on the trend must not flap with them."""
        if now is not None:
            self._evict(now)
        buf = self._buf
        n = len(buf)
        if n < 2:
            return 0.0
        mt = sum(t for t, _ in buf) / n
        mv = sum(v for _, v in buf) / n
        num = sum((t - mt) * (v - mv) for t, v in buf)
        den = sum((t - mt) ** 2 for t, _ in buf)
        return num / den if den > 0.0 else 0.0
