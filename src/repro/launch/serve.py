"""Heterogeneous serving driver: batched requests scheduled across groups.

  PYTHONPATH=src python -m repro.launch.serve --arch yi-6b --reduced \\
      --requests 64 --prompt-len 32 --decode-tokens 8 \\
      --groups accel:chunk=8:async=2,cpu0:slow=2

Queued mode (admission control + priority queue + journal), drained onto
the persistent scheduler runtime with a double-buffered batch pipeline:

  PYTHONPATH=src python -m repro.launch.serve --arch yi-6b --reduced \\
      --queue --requests 64 --job-items 2 --slo 5.0 \\
      --pipeline-depth 2 --journal /tmp/serve.journal.jsonl

``--rebuild-per-batch`` restores the old build-run-teardown scheduler per
batch (the benchmarks/batch_boundary.py baseline).

Multi-tenant mode (requires --queue): jobs are spread round-robin across
the tenants and drained weighted-fair with per-tenant accounting:

  PYTHONPATH=src python -m repro.launch.serve --arch yi-6b --reduced \\
      --queue --requests 64 \\
      --tenants "gold:weight=10,free:weight=1:quota=8:slo=5.0" \\
      --power "accel=8:2,cpu0=4:1"

Federated mode (requires --queue): the same jobs drain across N
in-process scheduler runtimes behind one consistent-hash front door,
with mirrored journals; ``--kill-runtime K`` runs the failure drill:

  PYTHONPATH=src python -m repro.launch.serve --arch yi-6b --reduced \\
      --queue --requests 64 --runtimes 3 --kill-runtime 1 \\
      --tenants "gold:weight=10,free:weight=1:quota=8"

``--tenants-file spec.json`` loads the same specs from a JSON file
(``[{"name": ..., "weight": ..., "max_inflight": ..., "slo_delay_s": ...,
"energy_budget_j": ...}, ...]``); ``--power group=active_w:idle_w,...``
enables the energy model so per-tenant joules/EDP are reported and soft
energy budgets derate DWRR weights.
"""
from __future__ import annotations

import argparse
import json

from repro.configs.base import reduced
from repro.configs.registry import get_config
from repro.core.energy import EnergyModel, PowerSpec
from repro.core.types import TIERS
from repro.launch.train import parse_groups
from repro.policy import AdaptivePolicy
from repro.queue import Job
from repro.serve.engine import HeteroServeEngine
from repro.telemetry import MetricsExporter, Telemetry
from repro.tenancy import TenantRegistry


def parse_power(text: str) -> EnergyModel:
    """``group=active_w:idle_w,...`` → EnergyModel."""
    specs = {}
    for tok in text.split(","):
        name, _, watts = tok.strip().partition("=")
        active, _, idle = watts.partition(":")
        specs[name] = PowerSpec(active_w=float(active),
                                idle_w=float(idle) if idle else 0.0)
    return EnergyModel(specs)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--decode-tokens", type=int, default=8)
    ap.add_argument("--groups", default="accel:chunk=8:async=2,cpu0")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--chunk-mode", choices=["range", "paper"],
                    default="range",
                    help="dispatch hot path: 'range' = zero-contention "
                         "work-stealing range partitioner (default); "
                         "'paper' = the lock-per-token baseline")
    ap.add_argument("--queue", action="store_true",
                    help="submit requests as prioritized jobs through "
                         "admission control instead of one bare batch")
    ap.add_argument("--job-items", type=int, default=1,
                    help="requests per job in --queue mode")
    ap.add_argument("--batch-jobs", type=int, default=8,
                    help="jobs drained per scheduler run in --queue mode")
    ap.add_argument("--slo", type=float, default=None,
                    help="queue-delay SLO seconds (enables admission "
                         "backpressure in --queue mode)")
    ap.add_argument("--priority", default="standard",
                    choices=["urgent", "standard", "batch", "mix"],
                    help="latency tier for queued jobs; 'mix' cycles "
                         "urgent/standard/batch across jobs")
    ap.add_argument("--deadline-ms", type=float, default=None,
                    help="per-job latency budget in ms (--queue mode); "
                         "jobs past it are shed at pop and in-flight "
                         "batches past it are cancelled cooperatively")
    ap.add_argument("--no-express", action="store_true",
                    help="disable the urgent-tier express lane "
                         "(baseline: urgent jobs wait out the pipeline)")
    ap.add_argument("--journal", default=None,
                    help="JSONL journal path for durable job state")
    ap.add_argument("--pipeline-depth", type=int, default=2,
                    help="batches in flight on the persistent runtime "
                         "(2 = double-buffered continuous drain)")
    ap.add_argument("--rebuild-per-batch", action="store_true",
                    help="legacy mode: fresh scheduler + dispatcher "
                         "threads per batch (benchmark baseline)")
    ap.add_argument("--tenants", default=None,
                    help="tenant specs for --queue mode, e.g. "
                         "'gold:weight=10,free:weight=1:quota=8:slo=5.0'")
    ap.add_argument("--tenants-file", default=None,
                    help="JSON tenant spec file (alternative to --tenants)")
    ap.add_argument("--power", default=None,
                    help="per-group power 'group=active_w:idle_w,...' — "
                         "enables per-tenant energy/EDP accounting")
    ap.add_argument("--metrics-out", default=None,
                    help="JSONL metrics feed: one merged registry "
                         "snapshot per --metrics-interval (tail -f "
                         "friendly); a final snapshot is always written")
    ap.add_argument("--metrics-interval", type=float, default=1.0,
                    help="seconds between metric snapshots (<= 0: only "
                         "the final snapshot)")
    ap.add_argument("--trace-out", default=None,
                    help="Chrome trace-event JSON of chunk-lifecycle "
                         "spans (load in Perfetto / chrome://tracing)")
    ap.add_argument("--prom-out", default=None,
                    help="final Prometheus text-format dump")
    ap.add_argument("--sample-rate", type=float, default=1.0,
                    help="fraction of chunks traced (deterministic by "
                         "chunk seq)")
    ap.add_argument("--policy-window", type=float, default=5.0,
                    help="adaptive-policy sliding window seconds for "
                         "admission smoothing / spike detection in "
                         "--queue mode (0 disables the policy)")
    ap.add_argument("--spike-threshold", type=float, default=3.0,
                    help="a projected delay this many × the windowed "
                         "median counts as a load spike")
    ap.add_argument("--cooldown-s", type=float, default=1.0,
                    help="minimum seconds between applied straggler "
                         "capacity rebalances")
    ap.add_argument("--adaptive-refill",
                    action=argparse.BooleanOptionalAction, default=True,
                    help="steal-rate-driven refill sizing in the range "
                         "partitioner (--no-adaptive-refill: fixed "
                         "refill quota)")
    ap.add_argument("--idle-s", type=float, default=0.0,
                    help="keep the drain daemon alive this long after "
                         "the queue empties (idle-efficiency probe: "
                         "near-zero wakeups expected)")
    ap.add_argument("--runtimes", type=int, default=1,
                    help="federate the queued drain across this many "
                         "in-process scheduler runtimes (requires "
                         "--queue; 1 = the single-runtime path)")
    ap.add_argument("--kill-runtime", type=int, default=None,
                    help="failure drill (--runtimes > 1): crash runtime "
                         "rK once half the jobs are done and fail its "
                         "journal over to a survivor")
    ap.add_argument("--journal-dir", default=None,
                    help="directory for federated journals + replicas "
                         "(default: a fresh temp dir)")
    ap.add_argument("--chaos-seed", type=int, default=None,
                    help="fault injection (--runtimes > 1): generate a "
                         "deterministic randomized FaultPlan from this "
                         "seed (same seed => identical fault schedule)")
    ap.add_argument("--chaos-plan", default=None,
                    help="fault injection: an explicit FaultPlan (JSON "
                         "string or path); mutually exclusive with "
                         "--chaos-seed")
    ap.add_argument("--chaos-horizon-s", type=float, default=2.0,
                    help="horizon seconds for a --chaos-seed generated "
                         "plan")
    args = ap.parse_args()
    if args.runtimes < 1:
        ap.error("--runtimes must be >= 1")
    if args.runtimes > 1 and not args.queue:
        ap.error("--runtimes requires --queue")
    if args.kill_runtime is not None and \
            not 0 <= args.kill_runtime < args.runtimes:
        ap.error("--kill-runtime must name a runtime in "
                 f"[0, {args.runtimes})")
    if args.chaos_seed is not None and args.chaos_plan is not None:
        ap.error("--chaos-seed and --chaos-plan are mutually exclusive")
    if (args.chaos_seed is not None or args.chaos_plan is not None) \
            and args.runtimes < 2:
        ap.error("--chaos-seed/--chaos-plan require --runtimes >= 2")
    if args.job_items < 1:
        ap.error("--job-items must be >= 1")
    if args.deadline_ms is not None and args.deadline_ms <= 0:
        ap.error("--deadline-ms must be > 0")
    if args.requests < 1:
        ap.error("--requests must be >= 1")
    if (args.tenants or args.tenants_file) and not args.queue:
        ap.error("--tenants/--tenants-file require --queue")
    if args.tenants and args.tenants_file:
        ap.error("--tenants and --tenants-file are mutually exclusive")
    registry = None
    try:
        if args.tenants:
            registry = TenantRegistry.parse(args.tenants)
        elif args.tenants_file:
            registry = TenantRegistry.from_file(args.tenants_file)
    except (ValueError, KeyError, OSError) as e:
        ap.error(f"bad tenant spec: {e}")
    if registry is not None and not registry.names():
        ap.error("tenant spec defines no tenants")
    if args.power and registry is None:
        # per-tenant accounting is the only consumer of the energy model
        # on this path; silently dropping it would look like a no-op run
        ap.error("--power requires --tenants/--tenants-file")
    try:
        energy_model = parse_power(args.power) if args.power else None
    except ValueError as e:
        ap.error(f"bad --power spec: {e}")

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    groups = parse_groups(args.groups)
    if energy_model is not None:
        # a typo'd or missing group name would silently bill that
        # group's busy time at 0 W to every tenant
        group_names = {g.name for g in groups}
        unknown = set(energy_model.specs) - group_names
        missing = group_names - set(energy_model.specs)
        if unknown or missing:
            problems = []
            if unknown:
                problems.append(f"unknown group(s) {sorted(unknown)}")
            if missing:
                problems.append(f"uncovered group(s) {sorted(missing)}")
            ap.error(f"--power {'; '.join(problems)}; groups are "
                     f"{sorted(group_names)}")
    if not 0.0 <= args.sample_rate <= 1.0:
        ap.error("--sample-rate must be in [0, 1]")
    tel = Telemetry(sample_rate=args.sample_rate)
    exporter = MetricsExporter(tel, metrics_path=args.metrics_out,
                               interval_s=args.metrics_interval,
                               trace_path=args.trace_out,
                               prometheus_path=args.prom_out)
    eng = HeteroServeEngine(cfg, groups, prompt_len=args.prompt_len,
                            decode_tokens=args.decode_tokens,
                            seed=args.seed, chunk_mode=args.chunk_mode,
                            telemetry=tel,
                            adaptive_refill=args.adaptive_refill)
    exporter.start()
    try:
        _run(args, ap, eng, groups, registry, energy_model)
    finally:
        snap = exporter.stop()
        if args.metrics_out or args.trace_out or args.prom_out:
            print(json.dumps({
                "telemetry": {
                    "snapshots_written": exporter.snapshots_written,
                    "trace_events_written": exporter.trace_events_written,
                    "self_overhead_s":
                        round(snap["self"]["est_overhead_s"], 6),
                }}, indent=2))


def _run(args, ap, eng, groups, registry, energy_model):
    if args.queue:
        # cover --requests exactly: full jobs plus a remainder job
        full, rem = divmod(args.requests, args.job_items)
        sizes = [args.job_items] * full + ([rem] if rem else [])
        names = registry.names() if registry is not None else ["default"]
        deadline_s = args.deadline_ms / 1000.0 \
            if args.deadline_ms is not None else None
        jobs = [Job(items=n, priority=i % 3,
                    tier=TIERS[i % len(TIERS)] if args.priority == "mix"
                    else args.priority,
                    deadline_s=deadline_s,
                    tenant=names[i % len(names)])
                for i, n in enumerate(sizes)]
        if args.runtimes > 1:
            frep = eng.serve_jobs_federated(
                jobs, runtimes=args.runtimes, slo_delay_s=args.slo,
                batch_jobs=args.batch_jobs, journal_dir=args.journal_dir,
                pipeline_depth=args.pipeline_depth, tenants=registry,
                energy_model=energy_model, express=not args.no_express,
                kill_runtime=args.kill_runtime,
                chaos_seed=args.chaos_seed, chaos_plan=args.chaos_plan,
                chaos_horizon_s=args.chaos_horizon_s)
            fed = frep.fed
            out = {
                "runtimes": fed.runtimes, "alive": fed.alive,
                "jobs": fed.jobs, "done": fed.done,
                "failed": fed.failed, "cancelled": fed.cancelled,
                "requeues": fed.requeues, "recovered": fed.recovered,
                "failovers": fed.failovers, "killed": fed.killed,
                "gossip_rounds": fed.gossip_rounds,
                "drained": frep.drained,
                "new_tokens": frep.new_tokens,
                "time_s": round(fed.time_s, 3),
                "tok_per_s": round(
                    frep.new_tokens / max(fed.time_s, 1e-9), 1),
                "per_runtime": fed.per_runtime,
                "per_tenant_items": fed.per_tenant_items,
            }
            if frep.per_tenant:
                out["per_tenant"] = {
                    t: {k: round(v, 4) if isinstance(v, float) else v
                        for k, v in u.items()}
                    for t, u in frep.per_tenant.items()}
            print(json.dumps(out, indent=2))
            return
        policy = None
        if args.policy_window > 0:
            policy = AdaptivePolicy(window_s=args.policy_window,
                                    spike_threshold=args.spike_threshold,
                                    cooldown_s=args.cooldown_s,
                                    telemetry=eng.telemetry)
        rep = eng.serve_jobs(jobs, slo_delay_s=args.slo,
                             batch_jobs=args.batch_jobs,
                             journal_path=args.journal,
                             pipeline_depth=args.pipeline_depth,
                             persistent=not args.rebuild_per_batch,
                             tenants=registry, energy_model=energy_model,
                             express=not args.no_express,
                             policy=policy, idle_s=args.idle_s)
        out = {
            "jobs": rep.jobs, "done": rep.done, "failed": rep.failed,
            "cancelled": rep.cancelled, "requeues": rep.requeues,
            "batches": rep.batches, "new_tokens": rep.new_tokens,
            "time_s": round(rep.time_s, 3),
            "tok_per_s": round(rep.new_tokens / max(rep.time_s, 1e-9), 1),
            "queue_delay_s": {k: round(v, 4)
                              for k, v in rep.queue_delay.items()},
            "per_group": rep.per_group_items,
            "dead_groups": rep.dead_groups,
            "deadline_misses": rep.deadline_misses,
            "express_batches": rep.express_batches,
            "cancelled_batches": rep.cancelled_batches,
        }
        if rep.per_tenant:
            out["per_tenant"] = {
                t: {"items": u["items"],
                    "busy_s": round(u["busy_s"], 4),
                    "energy_j": round(u["energy_j"], 4),
                    "edp": round(u["edp"], 6),
                    "queue_delay_s": {k: round(v, 4) for k, v in
                                      u["queue_delay_s"].items()}}
                for t, u in rep.per_tenant.items()}
        if rep.admission_per_tenant:
            out["admission_per_tenant"] = rep.admission_per_tenant
        print(json.dumps(out, indent=2))
        return
    rep = eng.serve(args.requests)
    print(json.dumps({
        "requests": rep.requests,
        "new_tokens": rep.new_tokens,
        "time_s": round(rep.time_s, 3),
        "tok_per_s": round(rep.new_tokens / max(rep.time_s, 1e-9), 1),
        "per_group": rep.per_group_items,
        "accel_overheads": {k: round(v, 4) for k, v in
                            rep.overheads.get(groups[0].name, {}).items()},
    }, indent=2))


if __name__ == "__main__":
    main()
