"""Heterogeneous serving driver: batched requests scheduled across groups.

  PYTHONPATH=src python -m repro.launch.serve --arch yi-6b --reduced \\
      --requests 64 --prompt-len 32 --decode-tokens 8 \\
      --groups accel:chunk=8:async=2,cpu0:slow=2
"""
from __future__ import annotations

import argparse
import json

from repro.configs.base import reduced
from repro.configs.registry import get_config
from repro.launch.train import parse_groups
from repro.serve.engine import HeteroServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--decode-tokens", type=int, default=8)
    ap.add_argument("--groups", default="accel:chunk=8:async=2,cpu0")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    groups = parse_groups(args.groups)
    eng = HeteroServeEngine(cfg, groups, prompt_len=args.prompt_len,
                            decode_tokens=args.decode_tokens,
                            seed=args.seed)
    rep = eng.serve(args.requests)
    print(json.dumps({
        "requests": rep.requests,
        "new_tokens": rep.new_tokens,
        "time_s": round(rep.time_s, 3),
        "tok_per_s": round(rep.new_tokens / max(rep.time_s, 1e-9), 1),
        "per_group": rep.per_group_items,
        "accel_overheads": {k: round(v, 4) for k, v in
                            rep.overheads.get(groups[0].name, {}).items()},
    }, indent=2))


if __name__ == "__main__":
    main()
