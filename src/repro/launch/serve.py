"""Heterogeneous serving driver: batched requests scheduled across groups.

  PYTHONPATH=src python -m repro.launch.serve --arch yi-6b --reduced \\
      --requests 64 --prompt-len 32 --decode-tokens 8 \\
      --groups accel:chunk=8:async=2,cpu0:slow=2

Queued mode (admission control + priority queue + journal), drained onto
the persistent scheduler runtime with a double-buffered batch pipeline:

  PYTHONPATH=src python -m repro.launch.serve --arch yi-6b --reduced \\
      --queue --requests 64 --job-items 2 --slo 5.0 \\
      --pipeline-depth 2 --journal /tmp/serve.journal.jsonl

``--rebuild-per-batch`` restores the old build-run-teardown scheduler per
batch (the benchmarks/batch_boundary.py baseline).
"""
from __future__ import annotations

import argparse
import json

from repro.configs.base import reduced
from repro.configs.registry import get_config
from repro.launch.train import parse_groups
from repro.queue import Job
from repro.serve.engine import HeteroServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--decode-tokens", type=int, default=8)
    ap.add_argument("--groups", default="accel:chunk=8:async=2,cpu0")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--queue", action="store_true",
                    help="submit requests as prioritized jobs through "
                         "admission control instead of one bare batch")
    ap.add_argument("--job-items", type=int, default=1,
                    help="requests per job in --queue mode")
    ap.add_argument("--batch-jobs", type=int, default=8,
                    help="jobs drained per scheduler run in --queue mode")
    ap.add_argument("--slo", type=float, default=None,
                    help="queue-delay SLO seconds (enables admission "
                         "backpressure in --queue mode)")
    ap.add_argument("--journal", default=None,
                    help="JSONL journal path for durable job state")
    ap.add_argument("--pipeline-depth", type=int, default=2,
                    help="batches in flight on the persistent runtime "
                         "(2 = double-buffered continuous drain)")
    ap.add_argument("--rebuild-per-batch", action="store_true",
                    help="legacy mode: fresh scheduler + dispatcher "
                         "threads per batch (benchmark baseline)")
    args = ap.parse_args()
    if args.job_items < 1:
        ap.error("--job-items must be >= 1")
    if args.requests < 1:
        ap.error("--requests must be >= 1")

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    groups = parse_groups(args.groups)
    eng = HeteroServeEngine(cfg, groups, prompt_len=args.prompt_len,
                            decode_tokens=args.decode_tokens,
                            seed=args.seed)
    if args.queue:
        # cover --requests exactly: full jobs plus a remainder job
        full, rem = divmod(args.requests, args.job_items)
        sizes = [args.job_items] * full + ([rem] if rem else [])
        jobs = [Job(items=n, priority=i % 3)
                for i, n in enumerate(sizes)]
        rep = eng.serve_jobs(jobs, slo_delay_s=args.slo,
                             batch_jobs=args.batch_jobs,
                             journal_path=args.journal,
                             pipeline_depth=args.pipeline_depth,
                             persistent=not args.rebuild_per_batch)
        print(json.dumps({
            "jobs": rep.jobs, "done": rep.done, "failed": rep.failed,
            "cancelled": rep.cancelled, "requeues": rep.requeues,
            "batches": rep.batches, "new_tokens": rep.new_tokens,
            "time_s": round(rep.time_s, 3),
            "tok_per_s": round(rep.new_tokens / max(rep.time_s, 1e-9), 1),
            "queue_delay_s": {k: round(v, 4)
                              for k, v in rep.queue_delay.items()},
            "per_group": rep.per_group_items,
            "dead_groups": rep.dead_groups,
        }, indent=2))
        return
    rep = eng.serve(args.requests)
    print(json.dumps({
        "requests": rep.requests,
        "new_tokens": rep.new_tokens,
        "time_s": round(rep.time_s, 3),
        "tok_per_s": round(rep.new_tokens / max(rep.time_s, 1e-9), 1),
        "per_group": rep.per_group_items,
        "accel_overheads": {k: round(v, 4) for k, v in
                            rep.overheads.get(groups[0].name, {}).items()},
    }, indent=2))


if __name__ == "__main__":
    main()
