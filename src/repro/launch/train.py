"""End-to-end heterogeneous training driver.

Example (CPU container — reduced config, ~100M-class training run):
  PYTHONPATH=src python -m repro.launch.train --arch stablelm-1.6b --reduced \\
      --steps 200 --global-batch 32 --seq-len 64 \\
      --groups accel:async=2,cpu:slow=2.5 --tune-chunk --ckpt-dir /tmp/ck

Groups syntax: name[:k=v,...] where kind is inferred (first group = accel),
knobs: async=<depth>, slow=<factor>, chunk=<fixed>, pri=1.
"""
from __future__ import annotations

import argparse
import json
import time

import jax

from repro.checkpoint import Checkpointer
from repro.configs.base import reduced
from repro.configs.registry import get_config
from repro.core.types import DeviceKind
from repro.core.energy import EnergyModel, PowerSpec
from repro.train.optimizer import OptConfig
from repro.train.trainer import GroupDef, HeteroTrainer


def parse_groups(spec: str):
    out = []
    for i, part in enumerate(spec.split(",")):
        bits = part.split(":")
        name = bits[0]
        kind = DeviceKind.ACCEL if i == 0 else (
            DeviceKind.LITTLE if name.startswith("little")
            else DeviceKind.BIG)
        g = GroupDef(name, kind)
        for kv in bits[1:]:
            k, v = kv.split("=")
            if k == "async":
                g.async_depth = int(v)
            elif k == "slow":
                g.slowdown = float(v)
            elif k == "chunk":
                g.fixed_chunk = int(v)
            elif k == "pri":
                g.priority_boost = bool(int(v))
        out.append(g)
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true",
                    help="reduced same-family config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--global-batch", type=int, default=32)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--warmup", type=int, default=20)
    ap.add_argument("--groups", default="accel:async=2,cpu0")
    ap.add_argument("--tune-chunk", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    groups = parse_groups(args.groups)
    oc = OptConfig(lr=args.lr, warmup_steps=args.warmup,
                   total_steps=args.steps)
    trainer = HeteroTrainer(cfg, groups, seq_len=args.seq_len,
                            global_batch=args.global_batch, oc=oc,
                            seed=args.seed)

    ck = Checkpointer(args.ckpt_dir) if args.ckpt_dir else None
    if ck and args.resume and ck.latest_step() is not None:
        tree, meta = ck.restore()
        trainer.params = jax.tree.map(jax.numpy.asarray, tree["params"])
        trainer.opt = jax.tree.map(jax.numpy.asarray, tree["opt"])
        trainer.step_idx = meta["step"]
        print(f"resumed from step {meta['step']}")

    if args.tune_chunk:
        G = trainer.tune_accel_chunk()
        print(f"tuned accel chunk G = {G}")

    energy = EnergyModel({g.name: PowerSpec(200.0, 75.0) for g in groups})
    t0 = time.time()
    while trainer.step_idx < args.steps:
        rep = trainer.train_step()
        acc_ov = rep.overheads.get(groups[0].name, {})
        print(f"step {rep.step:4d} loss {rep.loss:.4f} "
              f"({rep.time_s:.2f}s, items {rep.per_group_items}, "
              f"O_td {acc_ov.get('O_td', 0) * 100:.1f}%)", flush=True)
        if ck and rep.step % args.ckpt_every == 0:
            ck.save_async(rep.step,
                          {"params": trainer.params, "opt": trainer.opt})
    if ck:
        ck.wait()
        ck.save(trainer.step_idx,
                {"params": trainer.params, "opt": trainer.opt})
    wall = time.time() - t0
    busy = {}
    for rep in trainer.history:
        for g, n in rep.per_group_items.items():
            busy[g] = busy.get(g, 0.0) + n * 1e-3
    erep = energy.energy(wall, busy)
    print(json.dumps({"wall_s": wall, "final_loss": trainer.history[-1].loss,
                      "energy_model_j": erep.total_j, "edp": erep.edp}))


if __name__ == "__main__":
    main()
