"""Production mesh construction.

Defined as FUNCTIONS (never module-level constants) so importing this module
never touches jax device state — smoke tests must keep seeing 1 CPU device.
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import AxisType, Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(AxisType.Auto,) * len(axes))


def make_mesh_from(devices: Sequence, shape: Tuple[int, ...],
                   axes: Tuple[str, ...]) -> Mesh:
    """Mesh over an explicit device subset (heterogeneous device groups)."""
    arr = np.asarray(devices).reshape(shape)
    return Mesh(arr, axes, axis_types=(AxisType.Auto,) * len(axes))


def make_group_meshes(group_sizes: Sequence[int],
                      model_axis: int = 1) -> list:
    """Split jax.devices() into disjoint submeshes — the big.LITTLE analogue.

    Each group becomes a (data, model) mesh over ``group_sizes[i]`` devices.
    Used by the hetero scheduler: one device group per paper-"device".
    """
    devs = jax.devices()
    assert sum(group_sizes) <= len(devs), (group_sizes, len(devs))
    meshes, off = [], 0
    for n in group_sizes:
        sub = devs[off:off + n]
        off += n
        data = n // model_axis
        meshes.append(make_mesh_from(sub, (data, model_axis),
                                     ("data", "model")))
    return meshes


# TPU v5e hardware constants (per chip) — roofline denominators.
PEAK_FLOPS_BF16 = 197e12        # FLOP/s
HBM_BW = 819e9                  # B/s
ICI_BW = 50e9                   # B/s per link (~per-device effective)
CHIP_ACTIVE_W = 200.0           # W, busy (roofline-power envelope)
CHIP_IDLE_W = 75.0              # W, idle
