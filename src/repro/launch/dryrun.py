import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture × input-shape × mesh)
cell against the production mesh, with NO device allocation (ShapeDtypeStruct
stand-ins), and record memory/cost/collective evidence for EXPERIMENTS.md.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch yi-6b --shape train_4k --mesh single
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both --skip-existing
"""
import argparse
import gzip
import json
import re
import time
import traceback
from collections import Counter
from pathlib import Path

import jax

from repro.configs.base import SHAPES_BY_NAME, shape_applicable
from repro.configs.registry import ARCHS, dryrun_cells, get_config
from repro.launch.mesh import make_production_mesh
from repro.models import model as M
from repro.sharding.partition import use_mesh_rules
from repro.sharding.rules import ShardingRules
from repro.train.optimizer import OptConfig, abstract_opt_state, \
    opt_state_axes
from repro.train.train_step import train_step

RESULTS_DIR = Path(__file__).resolve().parents[3] / "results" / "dryrun"

COLLECTIVE_RE = re.compile(
    r"\b(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)")


def build_cell(cfg, shape, oc: OptConfig):
    """Returns (step_fn, abstract_args, logical_axes_trees)."""
    specs = M.input_specs(cfg, shape)
    in_axes = M.input_axes(cfg, shape)
    if shape.kind == "train":
        p_abs = M.abstract_params(cfg)
        o_abs = abstract_opt_state(p_abs)

        def step(params, opt, batch):
            return train_step(cfg, oc, params, opt, batch)

        return step, (p_abs, o_abs, specs), \
            (M.param_axes(cfg), opt_state_axes(M.param_axes(cfg)), in_axes)
    if shape.kind == "prefill":
        p_abs = M.abstract_params(cfg)

        def step(params, batch):
            return M.prefill(cfg, params, batch["tokens"],
                             batch.get("prefix_emb"))

        return step, (p_abs, specs), (M.param_axes(cfg), in_axes)
    # decode / long_decode
    p_abs = M.abstract_params(cfg)

    def step(params, batch):
        return M.decode_step(cfg, params, batch["cache"], batch["tokens"])

    return step, (p_abs, specs), (M.param_axes(cfg), in_axes)


def shardings_for(mesh, rules, abstract_args, axes_trees):
    from repro.models.layers import ParamDef

    def is_axes_leaf(x):
        return isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x)

    def one(sds, axes):
        return rules.sharding(mesh, axes, sds.shape)

    out = []
    for abs_tree, ax_tree in zip(abstract_args, axes_trees):
        out.append(jax.tree.map(one, abs_tree, ax_tree,
                                is_leaf=lambda x: False))
    return tuple(out)


def summarize_collectives(hlo_text: str):
    ops = Counter()
    for m in COLLECTIVE_RE.finditer(hlo_text):
        ops[m.group(1)] += 1
    return dict(ops)


def run_cell(arch_id: str, shape_name: str, mesh_kind: str,
             block_skip: bool = False, save_hlo: bool = True,
             overrides=None, uneven_heads: bool = False) -> dict:
    cfg = get_config(arch_id)
    if block_skip:
        cfg = cfg.replace(causal_block_skip=True)
    if overrides:
        cfg = cfg.replace(**overrides)
    shape = SHAPES_BY_NAME[shape_name]
    ok, reason = shape_applicable(cfg, shape)
    if not ok:
        return {"arch": arch_id, "shape": shape_name, "mesh": mesh_kind,
                "status": "skipped", "reason": reason}
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    rules = ShardingRules().for_shape_kind(shape.kind)
    if uneven_heads:
        rules = rules.with_uneven("heads", "kv_heads", "act_heads",
                                  "act_kv_heads")
    oc = OptConfig()
    res = {"arch": arch_id, "shape": shape_name, "mesh": mesh_kind,
           "mesh_shape": list(mesh.devices.shape),
           "block_skip": block_skip, "status": "error"}
    t0 = time.time()
    try:
        step, abstract_args, axes_trees = build_cell(cfg, shape, oc)
        in_sh = shardings_for(mesh, rules, abstract_args, axes_trees)
        # decode: donate the cache so KV updates alias in place
        donate = (1,) if shape.is_decode else ()
        with use_mesh_rules(mesh, rules):
            lowered = jax.jit(step, in_shardings=in_sh,
                              donate_argnums=donate).lower(*abstract_args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
        ma = compiled.memory_analysis()
        ca = compiled.cost_analysis() or {}
        hlo = compiled.as_text()
        res.update({
            "status": "ok",
            "lower_s": round(t_lower, 2),
            "compile_s": round(t_compile, 2),
            "memory": {
                "argument_bytes": getattr(ma, "argument_size_in_bytes", None),
                "output_bytes": getattr(ma, "output_size_in_bytes", None),
                "temp_bytes": getattr(ma, "temp_size_in_bytes", None),
                "alias_bytes": getattr(ma, "alias_size_in_bytes", None),
            },
            "cost_analysis": {
                "flops_per_device_loopbody": ca.get("flops"),
                "bytes_accessed_loopbody": ca.get("bytes accessed"),
            },
            "collective_op_counts": summarize_collectives(hlo),
            "n_devices": mesh.devices.size,
        })
        if save_hlo:
            out = cell_path(arch_id, shape_name, mesh_kind, block_skip,
                            overrides)
            out.parent.mkdir(parents=True, exist_ok=True)
            with gzip.open(str(out) + ".hlo.gz", "wt") as f:
                f.write(hlo)
    except Exception as e:  # noqa: BLE001 — record the failure, keep matrix running
        res["error"] = f"{type(e).__name__}: {e}"
        res["traceback"] = traceback.format_exc(limit=10)
    res["total_s"] = round(time.time() - t0, 2)
    return res


def cell_path(arch, shape, mesh_kind, block_skip=False, overrides=None) -> Path:
    sfx = "__bs" if block_skip else ""
    if overrides:
        sfx += "__" + "_".join(f"{k}-{v}" for k, v in sorted(overrides.items()))
    return RESULTS_DIR / mesh_kind / f"{arch}__{shape}{sfx}.json"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--block-skip", action="store_true",
                    help="triangular causal schedule (perf variant)")
    ap.add_argument("--no-hlo", action="store_true")
    args = ap.parse_args()

    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    if args.all:
        cells = [(c.arch_id, s.name) for c, s, ok, _ in dryrun_cells()
                 if ok]
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape)]

    n_ok = n_fail = 0
    for mesh_kind in meshes:
        for arch, shape in cells:
            path = cell_path(arch, shape, mesh_kind, args.block_skip)
            if args.skip_existing and path.exists():
                print(f"[skip] {mesh_kind} {arch} {shape}")
                continue
            res = run_cell(arch, shape, mesh_kind, args.block_skip,
                           save_hlo=not args.no_hlo)
            path.parent.mkdir(parents=True, exist_ok=True)
            path.write_text(json.dumps(res, indent=2))
            tag = res["status"].upper()
            n_ok += res["status"] == "ok"
            n_fail += res["status"] == "error"
            print(f"[{tag}] {mesh_kind} {arch} {shape} "
                  f"({res.get('total_s')}s) "
                  f"{res.get('error', '')}", flush=True)
    print(f"done: {n_ok} ok, {n_fail} failed")
    return 1 if n_fail else 0


if __name__ == "__main__":
    raise SystemExit(main())
