"""Flash-decode Pallas TPU kernel: one query position against a long
(possibly padded) KV cache, KV-chunked with online-softmax merge.

Layout: q (B·H, D); k/v (B·KVH, S, D); kv_len (B,) valid lengths.
Grid = (B·H, S/bk) with the cache dimension innermost-sequential; partial
(m, l, acc) state lives in VMEM scratch. On a sequence-sharded cache the
shard-local partials are merged by the caller (log-sum-exp merge) — the same
math GSPMD inserts for the pure-JAX decode path.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            scale: float, bk: int, nk: int, n_heads: int):
    i = pl.program_id(0)
    kj = pl.program_id(1)

    @pl.when(kj == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    kv_len = len_ref[i // n_heads]

    @pl.when(kj * bk < kv_len)
    def _block():
        q = q_ref[0]                              # (1, d)
        k = k_ref[0]                              # (bk, d)
        v = v_ref[0]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale   # (1, bk)
        cols = kj * bk + jax.lax.broadcasted_iota(jnp.int32, (1, bk), 1)
        s = jnp.where(cols < kv_len, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + p.sum(axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(kj == nk - 1)
    def _flush():
        o_ref[0] = (acc_ref[...]
                    / jnp.maximum(l_ref[...], 1e-37)).astype(o_ref.dtype)


def flash_decode(q: jax.Array, k: jax.Array, v: jax.Array,
                 kv_len: jax.Array, *, block_k: int = 512,
                 n_heads: int, n_kv_heads: int,
                 interpret: bool = False) -> jax.Array:
    """q: (B·H, D); k, v: (B·KVH, S, D); kv_len: (B,) int32 -> (B·H, D)."""
    BH, d = q.shape
    BKV, S, _ = k.shape
    group = n_heads // n_kv_heads
    bk = min(block_k, S)
    assert S % bk == 0
    nk = S // bk
    scale = 1.0 / math.sqrt(d)

    def kv_head(i):
        return (i // n_heads) * n_kv_heads + (i % n_heads) // group

    kernel = functools.partial(_kernel, scale=scale, bk=bk, nk=nk,
                               n_heads=n_heads)
    out = pl.pallas_call(
        kernel,
        grid=(BH, nk),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),       # kv_len (prefetchable)
            pl.BlockSpec((1, 1, d), lambda i, kk: (i, 0, 0)),
            pl.BlockSpec((1, bk, d), lambda i, kk: (kv_head(i), kk, 0)),
            pl.BlockSpec((1, bk, d), lambda i, kk: (kv_head(i), kk, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, d), lambda i, kk: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, 1, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, d), jnp.float32),
        ],
        interpret=interpret,
    )(kv_len.astype(jnp.int32), q[:, None, :], k, v)
    return out[:, 0, :]
