"""Pallas TPU kernels for the perf-critical compute hot-spots.

Each kernel ships with a jit'd model-layout wrapper (ops.py) and a pure-jnp
oracle (ref.py); tests sweep shapes/dtypes in interpret mode on CPU. The
pure-JAX chunked implementations in repro.models are algorithmically
identical (same online-softmax / SSD blocking), so the dry-run lowering path
is representative of the kernelized system.
"""
from repro.kernels.flash_attention import flash_attention
from repro.kernels.flash_decode import flash_decode
from repro.kernels.ssd_scan import ssd_scan_kernel

__all__ = ["flash_attention", "flash_decode", "ssd_scan_kernel"]
