"""Jit'd model-layout wrappers around the Pallas kernels.

``use_pallas(cfg)`` decides per backend: TPU -> compiled kernels; CPU (this
container, and the dry-run's 512 host devices) -> the pure-JAX chunked paths
in repro.models, which implement the same algorithms (the kernels are
validated against them in interpret mode by tests/test_kernels_*.py).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import flash_attention
from repro.kernels.flash_decode import flash_decode
from repro.kernels.ssd_scan import ssd_scan_kernel


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@partial(jax.jit, static_argnames=("n_heads", "n_kv_heads", "causal",
                                   "block_q", "block_k", "interpret"))
def attention_bshd(q, k, v, *, n_heads, n_kv_heads, causal=True,
                   block_q=128, block_k=128, interpret=False):
    """Model layout: q (b, s, h, d); k/v (b, s, kvh, d) -> (b, s, h, d)."""
    b, s, h, d = q.shape
    skv = k.shape[1]
    qf = q.transpose(0, 2, 1, 3).reshape(b * h, s, d)
    kf = k.transpose(0, 2, 1, 3).reshape(b * n_kv_heads, skv, d)
    vf = v.transpose(0, 2, 1, 3).reshape(b * n_kv_heads, skv, d)
    of = flash_attention(qf, kf, vf, causal=causal, block_q=block_q,
                         block_k=block_k, n_heads=n_heads,
                         n_kv_heads=n_kv_heads, interpret=interpret)
    return of.reshape(b, h, s, d).transpose(0, 2, 1, 3)


@partial(jax.jit, static_argnames=("n_heads", "n_kv_heads", "block_k",
                                   "interpret"))
def decode_attention_bshd(q, k_cache, v_cache, kv_len, *, n_heads,
                          n_kv_heads, block_k=512, interpret=False):
    """q (b, 1, h, d); caches (b, S, kvh, d); kv_len (b,) -> (b, 1, h, d)."""
    b, _, h, d = q.shape
    S = k_cache.shape[1]
    qf = q[:, 0].reshape(b * h, d)
    kf = k_cache.transpose(0, 2, 1, 3).reshape(b * n_kv_heads, S, d)
    vf = v_cache.transpose(0, 2, 1, 3).reshape(b * n_kv_heads, S, d)
    of = flash_decode(qf, kf, vf, kv_len, block_k=block_k, n_heads=n_heads,
                      n_kv_heads=n_kv_heads, interpret=interpret)
    return of.reshape(b, 1, h, d)


@partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_bshn(x, dt, A, B, C, *, chunk=128, interpret=False):
    """Model layout: x (b, s, nh, p); dt (b, s, nh); A (nh,);
    B/C (b, s, g, n) -> (b, s, nh, p)."""
    b, s, nh, p = x.shape
    g, n = B.shape[2], B.shape[3]
    rep = nh // g
    xf = x.transpose(0, 2, 1, 3).reshape(b * nh, s, p)
    dtf = dt.transpose(0, 2, 1).reshape(b * nh, s)
    Bf = jnp.repeat(B, rep, axis=2).transpose(0, 2, 1, 3) \
        .reshape(b * nh, s, n)
    Cf = jnp.repeat(C, rep, axis=2).transpose(0, 2, 1, 3) \
        .reshape(b * nh, s, n)
    Af = jnp.tile(A, b)
    yf = ssd_scan_kernel(xf, dtf, Af, Bf, Cf, chunk=chunk,
                         interpret=interpret)
    return yf.reshape(b, nh, s, p).transpose(0, 2, 1, 3)
