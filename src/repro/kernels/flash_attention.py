"""Flash attention Pallas TPU kernel (train/prefill hot spot).

Layout: q (BH, Sq, D), k/v (BKV, Skv, D) with BH = batch·n_heads and
BKV = batch·n_kv_heads; the BlockSpec index maps implement GQA by routing
query-head block i to kv-head block i·n_kv // n_heads.

Grid = (BH, Sq/bq, Skv/bk); the kv dimension is innermost ("arbitrary"
sequential on TPU), so the online-softmax state lives in VMEM scratch and is
reset at kv==0 / flushed at kv==last. Causal blocks above the diagonal are
skipped with pl.when (the triangular schedule — this is where the ~2× FLOP
win over the masked rectangle comes from on TPU).

Tile guidance (v5e): bq, bk multiples of 128 lanes / 8 sublanes; D ≤ 256
keeps q/k/v/acc tiles ≈ (128·D·4B)·4 ≈ 0.5 MB in VMEM at bq=bk=128.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            scale: float, causal: bool, bq: int, bk: int, nk: int):
    qi = pl.program_id(1)
    kj = pl.program_id(2)

    @pl.when(kj == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    run = True
    if causal:
        run = kj * bk <= qi * bq + bq - 1      # block intersects lower tri

    @pl.when(run)
    def _block():
        q = q_ref[0]                            # (bq, d)
        k = k_ref[0]                            # (bk, d)
        v = v_ref[0]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale
        if causal:
            rows = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            cols = kj * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(rows >= cols, s, NEG_INF)
        m_prev = m_ref[...]
        l_prev = l_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_prev * corr + p.sum(axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(kj == nk - 1)
    def _flush():
        o_ref[0] = (acc_ref[...]
                    / jnp.maximum(l_ref[...], 1e-37)).astype(o_ref.dtype)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, block_q: int = 128,
                    block_k: int = 128, n_heads: int, n_kv_heads: int,
                    interpret: bool = False) -> jax.Array:
    """q: (B·H, Sq, D); k, v: (B·KVH, Skv, D). Returns (B·H, Sq, D)."""
    BH, sq, d = q.shape
    BKV, skv, _ = k.shape
    assert BH % n_heads == 0 and BKV % n_kv_heads == 0
    group = n_heads // n_kv_heads
    bq = min(block_q, sq)
    bk = min(block_k, skv)
    assert sq % bq == 0 and skv % bk == 0, (sq, bq, skv, bk)
    nq, nk = sq // bq, skv // bk
    scale = 1.0 / math.sqrt(d)

    def kv_head(i):
        b = i // n_heads
        h = i % n_heads
        return b * n_kv_heads + h // group

    kernel = functools.partial(_kernel, scale=scale, causal=causal,
                               bq=bq, bk=bk, nk=nk)
    return pl.pallas_call(
        kernel,
        grid=(BH, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda i, j, kk: (i, j, 0)),
            pl.BlockSpec((1, bk, d), lambda i, j, kk: (kv_head(i), kk, 0)),
            pl.BlockSpec((1, bk, d), lambda i, j, kk: (kv_head(i), kk, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda i, j, kk: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
