"""Mamba-2 SSD chunked-scan Pallas TPU kernel.

Layout (heads pre-expanded from B/C groups by the wrapper):
  x  (BH, NC, Q, P)   head streams, chunked
  dt (BH, NC, Q)      softplus'd step sizes
  B  (BH, NC, Q, N)   input projections
  C  (BH, NC, Q, N)   output projections
  A  (BH,)            per-head negative decay rate

Grid = (BH, NC) with the chunk dimension innermost-sequential; the running
inter-chunk state S (N×P) lives in VMEM scratch, reset at chunk 0. Each grid
step does the intra-chunk quadratic part (Q×Q decay-masked scores on the MXU)
plus the contribution of the incoming state — identical math to the pure-JAX
``repro.models.ssm.ssd_scan`` oracle.

VMEM at Q=128, N=64, P=64 fp32: x/B/C tiles ≈ 3·128·64·4 ≈ 96 KB, scores
128·128·4 = 64 KB, state 64·64·4 = 16 KB — comfortably inside 16 MB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(a_ref, x_ref, dt_ref, b_ref, c_ref, y_ref, s_ref, *, Q: int):
    i = pl.program_id(0)

    @pl.when(pl.program_id(1) == 0)
    def _init():
        s_ref[...] = jnp.zeros_like(s_ref)

    x = x_ref[0, 0].astype(jnp.float32)           # (Q, P)
    dt = dt_ref[0, 0].astype(jnp.float32)         # (Q,)
    B = b_ref[0, 0].astype(jnp.float32)           # (Q, N)
    C = c_ref[0, 0].astype(jnp.float32)           # (Q, N)
    A = a_ref[i]                                  # scalar (negative)

    dA = dt * A                                   # (Q,)
    cum = jnp.cumsum(dA)                          # (Q,)
    # intra-chunk: y[q] += sum_{j<=q} exp(cum_q - cum_j)·dt_j·(C_q·B_j)·x_j
    scores = jax.lax.dot_general(C, B, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)  # (Q,Q)
    L = cum[:, None] - cum[None, :]
    rows = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    L = jnp.where(rows >= cols, L, NEG_INF)
    wgt = jnp.exp(L) * scores * dt[None, :]
    y = jax.lax.dot_general(wgt, x, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    # inter-chunk: y[q] += exp(cum_q) · C_q · S_in
    y = y + jnp.exp(cum)[:, None] * jax.lax.dot_general(
        C, s_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    y_ref[0, 0] = y.astype(y_ref.dtype)
    # state update: S_out = exp(cum_last)·S_in + Σ_j exp(cum_last-cum_j)·dt_j·B_j⊗x_j
    decay_end = jnp.exp(cum[-1] - cum) * dt       # (Q,)
    s_ref[...] = s_ref[...] * jnp.exp(cum[-1]) + jax.lax.dot_general(
        B * decay_end[:, None], x, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)


def ssd_scan_kernel(x: jax.Array, dt: jax.Array, A: jax.Array, B: jax.Array,
                    C: jax.Array, *, chunk: int = 128,
                    interpret: bool = False) -> jax.Array:
    """x: (BH, S, P); dt: (BH, S); A: (BH,); B, C: (BH, S, N) -> (BH, S, P)."""
    BH, S, P = x.shape
    N = B.shape[-1]
    Q = min(chunk, S)
    assert S % Q == 0, (S, Q)
    NC = S // Q
    xs = x.reshape(BH, NC, Q, P)
    dts = dt.reshape(BH, NC, Q)
    Bs = B.reshape(BH, NC, Q, N)
    Cs = C.reshape(BH, NC, Q, N)
    kernel = functools.partial(_kernel, Q=Q)
    out = pl.pallas_call(
        kernel,
        grid=(BH, NC),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),                  # A
            pl.BlockSpec((1, 1, Q, P), lambda i, c: (i, c, 0, 0)),  # x
            pl.BlockSpec((1, 1, Q), lambda i, c: (i, c, 0)),        # dt
            pl.BlockSpec((1, 1, Q, N), lambda i, c: (i, c, 0, 0)),  # B
            pl.BlockSpec((1, 1, Q, N), lambda i, c: (i, c, 0, 0)),  # C
        ],
        out_specs=pl.BlockSpec((1, 1, Q, P), lambda i, c: (i, c, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, NC, Q, P), x.dtype),
        scratch_shapes=[pltpu.VMEM((N, P), jnp.float32)],
        interpret=interpret,
    )(A.astype(jnp.float32), xs, dts, Bs, Cs)
    return out.reshape(BH, S, P)
