"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def flash_attention_ref(q, k, v, *, causal=True, n_heads=None,
                        n_kv_heads=None):
    """q: (B·H, Sq, D); k, v: (B·KVH, Skv, D)."""
    BH, sq, d = q.shape
    BKV, skv, _ = k.shape
    group = n_heads // n_kv_heads
    b = BH // n_heads
    qh = q.reshape(b, n_heads, sq, d)
    kh = jnp.repeat(k.reshape(b, n_kv_heads, skv, d), group, axis=1)
    vh = jnp.repeat(v.reshape(b, n_kv_heads, skv, d), group, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", qh, kh,
                   preferred_element_type=jnp.float32) / math.sqrt(d)
    if causal:
        mask = jnp.tril(jnp.ones((sq, skv), bool), k=skv - sq)
        s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhqk,bhkd->bhqd", p.astype(v.dtype), vh,
                   preferred_element_type=jnp.float32)
    return o.reshape(BH, sq, d).astype(q.dtype)


def flash_decode_ref(q, k, v, kv_len, *, n_heads=None, n_kv_heads=None):
    """q: (B·H, D); k, v: (B·KVH, S, D); kv_len: (B,)."""
    BH, d = q.shape
    b = BH // n_heads
    group = n_heads // n_kv_heads
    S = k.shape[1]
    qh = q.reshape(b, n_heads, d)
    kh = jnp.repeat(k.reshape(b, n_kv_heads, S, d), group, axis=1)
    vh = jnp.repeat(v.reshape(b, n_kv_heads, S, d), group, axis=1)
    s = jnp.einsum("bhd,bhkd->bhk", qh, kh,
                   preferred_element_type=jnp.float32) / math.sqrt(d)
    mask = jnp.arange(S)[None, None, :] < kv_len[:, None, None]
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhk,bhkd->bhd", p.astype(v.dtype), vh,
                   preferred_element_type=jnp.float32)
    return o.reshape(BH, d).astype(q.dtype)


def ssd_scan_ref(x, dt, A, B, C, chunk: int = 128):
    """Sequential-recurrence oracle. x: (BH, S, P); dt: (BH, S); A: (BH,);
    B, C: (BH, S, N). Returns (BH, S, P)."""
    BH, S, P = x.shape
    N = B.shape[-1]

    def per_head(xh, dth, a, bh, ch):
        def step(s, inp):
            xt, dtt, bt, ct = inp
            da = jnp.exp(dtt * a)
            s = s * da + dtt * jnp.outer(bt, xt)          # (N, P)
            y = ct @ s                                    # (P,)
            return s, y

        s0 = jnp.zeros((N, P), jnp.float32)
        _, ys = jax.lax.scan(step, s0, (xh.astype(jnp.float32),
                                        dth.astype(jnp.float32),
                                        bh.astype(jnp.float32),
                                        ch.astype(jnp.float32)))
        return ys

    ys = jax.vmap(per_head)(x, dt, A, B, C)
    return ys.astype(x.dtype)
