"""Chunk-lifecycle span tracing: Tc1→Tc3 / Tg1→Tg5 as structured spans.

Every completed chunk becomes one host-side span (Filter₁ entry → host
resumed) with nested phase spans reconstructed from its ChunkRecord
timestamps — schedule (Tc1→Tc2), h2d (Tg1→Tg2), launch (Tg2→Tg3), kernel
(Tg3→Tg4), d2h (Tg4→Tg5) — tagged with group / epoch / chunk seq / item
count, plus the tenant composition of the batch the epoch drained
(JobService registers it via ``tag_epoch`` at submit time, before any of
the epoch's chunks complete). Queue/scheduler *events* — admission
decisions, DWRR picks, steals, refills, requeues, epoch submit/finalize —
are instant events on the same timeline.

Emission is designed for the dispatch hot path: a sampled chunk appends
ONE compact tuple to a ``collections.deque(maxlen=...)`` (GIL-atomic,
lock-free, bounded — old events fall off the front on overflow, counted);
all formatting (Chrome trace-event dicts, sorting, tid mapping) happens
at export time on the reader's thread. ``sample_rate`` (default 1.0)
deterministically keeps a chunk by hashing its seq, so two runs over the
same schedule sample the same chunks.

Export is Chrome trace-event JSON — ``chrome_trace()`` returns the
``{"traceEvents": [...]}`` object that chrome://tracing and Perfetto load
directly. Host spans for one group live on one track (tid), device-phase
spans on a sibling ``<group>/dev`` track, so pipelined executors
(async_depth ≥ 2) cannot break host-span stack nesting.
"""
from __future__ import annotations

import collections
import json
import threading
import time
from typing import Any, Dict, List, Optional, Tuple

clock = time.monotonic

#: Knuth multiplicative hash → uniform [0, 1) per chunk seq, so sampling
#: is deterministic for a given schedule and rate.
_HASH_MUL = 0x9E3779B1
_HASH_DEN = float(2 ** 32)

_CHUNK = 0        # chunk lifecycle (from a ChunkRecord)
_SPAN = 1         # generic duration span (service batches, exports)
_INSTANT = 2      # point event (steal, requeue, admission, epoch marks)


class SpanTracer:
    def __init__(self, sample_rate: float = 1.0,
                 max_events: int = 200_000,
                 max_epoch_tags: int = 4096):
        self.sample_rate = float(sample_rate)
        self.max_events = int(max_events)
        self._events: collections.deque = collections.deque(
            maxlen=self.max_events)
        self.emitted = 0                    # sampled-in events ever emitted
        self.sampled_out = 0                # chunks skipped by sampling
        self._epoch_tags: Dict[int, Dict[str, Any]] = {}
        self._max_epoch_tags = max_epoch_tags
        self._tag_lock = threading.Lock()

    # -- sampling -------------------------------------------------------
    def sampled(self, seq: int) -> bool:
        if self.sample_rate >= 1.0:
            return True
        if self.sample_rate <= 0.0:
            return False
        return ((seq * _HASH_MUL) & 0xFFFFFFFF) / _HASH_DEN \
            < self.sample_rate

    # -- epoch tagging (service layer knows tenants; scheduler doesn't) -
    def tag_epoch(self, index: int, tags: Dict[str, Any]) -> None:
        """Attach batch metadata (tenant item shares, job count) to an
        epoch index before its chunks complete; chunk spans pick it up at
        export. Bounded: oldest tags are dropped past ``max_epoch_tags``."""
        with self._tag_lock:
            self._epoch_tags[index] = tags
            while len(self._epoch_tags) > self._max_epoch_tags:
                self._epoch_tags.pop(next(iter(self._epoch_tags)))

    def epoch_tag(self, index: Optional[int]) -> Dict[str, Any]:
        with self._tag_lock:
            return dict(self._epoch_tags.get(index, ()))

    # -- emission (hot path: one tuple append) --------------------------
    def chunk(self, rec, epoch: Optional[int] = None) -> None:
        """Record one completed chunk's lifecycle (duck-typed
        ChunkRecord). Sampled by chunk seq; one deque append."""
        seq = rec.token.chunk.seq
        if not self.sampled(seq):
            self.sampled_out += 1
            return
        self.emitted += 1
        self._events.append((
            _CHUNK, rec.token.group, epoch, seq, rec.token.chunk.size,
            rec.tc1, rec.tc2, rec.tc3,
            rec.tg1, rec.tg2, rec.tg3, rec.tg4, rec.tg5))

    def span(self, name: str, tid: str, start: float, end: float,
             **args) -> None:
        self.emitted += 1
        self._events.append((_SPAN, name, tid, start, end, args or None))

    def instant(self, name: str, tid: str = "events",
                ts: Optional[float] = None, **args) -> None:
        self.emitted += 1
        self._events.append((_INSTANT, name, tid,
                             ts if ts is not None else clock(),
                             args or None))

    def __len__(self) -> int:
        return len(self._events)

    @property
    def dropped(self) -> int:
        """Events evicted from the bounded ring (emitted but no longer
        retained)."""
        return max(0, self.emitted - len(self._events))

    # -- export ---------------------------------------------------------
    def _chunk_events(self, ev: tuple, tids, out: List[dict]) -> None:
        (_, group, epoch, seq, size,
         tc1, tc2, tc3, tg1, tg2, tg3, tg4, tg5) = ev
        args: Dict[str, Any] = {"group": group, "seq": seq, "items": size}
        if epoch is not None:
            args["epoch"] = epoch
        tag = self.epoch_tag(epoch)
        if tag:
            args.update(tag)
        host_tid = tids(group)
        us = 1e6
        out.append({"name": f"chunk:{seq}", "cat": "chunk", "ph": "X",
                    "ts": tc1 * us, "dur": max(tc3 - tc1, 0.0) * us,
                    "pid": 0, "tid": host_tid, "args": args})
        out.append({"name": "schedule", "cat": "host", "ph": "X",
                    "ts": tc1 * us, "dur": max(tc2 - tc1, 0.0) * us,
                    "pid": 0, "tid": host_tid,
                    "args": {"seq": seq}})
        if tg5 > 0.0:                       # executor filled device stamps
            dev_tid = tids(f"{group}/dev")
            for name, a, b in (("h2d", tg1, tg2), ("launch", tg2, tg3),
                               ("kernel", tg3, tg4), ("d2h", tg4, tg5)):
                out.append({"name": name, "cat": "device", "ph": "X",
                            "ts": a * us, "dur": max(b - a, 0.0) * us,
                            "pid": 0, "tid": dev_tid,
                            "args": {"seq": seq}})

    def chrome_events(self) -> List[dict]:
        """Format the retained events as Chrome trace events (metadata
        thread-name rows first, then spans sorted by timestamp)."""
        snap = list(self._events)           # deque snapshot, GIL-atomic
        tid_of: Dict[str, int] = {}

        def tids(name: str) -> int:
            t = tid_of.get(name)
            if t is None:
                t = tid_of[name] = len(tid_of) + 1
            return t

        spans: List[dict] = []
        for ev in snap:
            if ev[0] == _CHUNK:
                self._chunk_events(ev, tids, spans)
            elif ev[0] == _SPAN:
                _, name, tid, start, end, args = ev
                spans.append({"name": name, "cat": "service", "ph": "X",
                              "ts": start * 1e6,
                              "dur": max(end - start, 0.0) * 1e6,
                              "pid": 0, "tid": tids(tid),
                              "args": args or {}})
            else:
                _, name, tid, ts, args = ev
                spans.append({"name": name, "cat": "event", "ph": "i",
                              "ts": ts * 1e6, "pid": 0, "tid": tids(tid),
                              "s": "t", "args": args or {}})
        spans.sort(key=lambda e: e["ts"])
        meta = [{"name": "thread_name", "ph": "M", "pid": 0, "tid": t,
                 "args": {"name": name}}
                for name, t in sorted(tid_of.items(), key=lambda kv: kv[1])]
        meta.insert(0, {"name": "process_name", "ph": "M", "pid": 0,
                        "args": {"name": "repro serving runtime"}})
        return meta + spans

    def chrome_trace(self) -> Dict[str, Any]:
        return {"traceEvents": self.chrome_events(),
                "displayTimeUnit": "ms",
                "otherData": {"emitted": self.emitted,
                              "dropped": self.dropped,
                              "sample_rate": self.sample_rate}}

    def write_chrome_trace(self, path: str) -> int:
        """Write the trace JSON; returns the number of trace events."""
        trace = self.chrome_trace()
        with open(path, "w", encoding="utf-8") as fh:
            json.dump(trace, fh)
            fh.write("\n")
        return len(trace["traceEvents"])


class LabeledTracer:
    """View over a base tracer namespacing one runtime's trace state.

    Track ids gain a ``<prefix>/`` path (each runtime's service /
    admission rows become separate Chrome-trace tracks) and epoch-tag
    keys are scoped to the prefix: N federated runtimes each count their
    epochs from 0, so raw integer keys would collide in the shared tag
    map and stamp one runtime's tenant composition onto another's chunk
    spans. Chunk tids need no prefix — federated group names are already
    namespaced (``r0/accel``) and flow through the ChunkRecord. Reader
    surface (``chrome_trace``, ``emitted``, ...) delegates to the base:
    one export covers every runtime."""

    def __init__(self, base: SpanTracer, prefix: str):
        self.base = base
        self.prefix = str(prefix)

    def _epoch_key(self, index) -> Optional[str]:
        return None if index is None else f"{self.prefix}:{index}"

    def chunk(self, rec, epoch=None) -> None:
        self.base.chunk(rec, epoch=self._epoch_key(epoch))

    def tag_epoch(self, index, tags: Dict[str, Any]) -> None:
        self.base.tag_epoch(self._epoch_key(index), tags)

    def epoch_tag(self, index) -> Dict[str, Any]:
        return self.base.epoch_tag(self._epoch_key(index))

    def span(self, name: str, tid: str, start: float, end: float,
             **args) -> None:
        self.base.span(name, f"{self.prefix}/{tid}", start, end, **args)

    def instant(self, name: str, tid: str = "events",
                ts: Optional[float] = None, **args) -> None:
        self.base.instant(name, f"{self.prefix}/{tid}", ts=ts, **args)

    def __getattr__(self, name):
        return getattr(self.base, name)
