"""Live exporters: JSONL metric snapshots, Prometheus text, Chrome trace.

``MetricsExporter`` is the always-on snapshot daemon: every
``interval_s`` it merges the registry shards and appends one JSON object
per line to ``metrics_path`` (a live tail-able feed: ``tail -f`` or
``jq`` work on a running server), and on ``stop()`` writes a final
snapshot plus, when configured, a Prometheus text-format dump and the
Chrome trace-event JSON of the span stream. All formatting runs on the
exporter thread — the dispatch hot path never pays for serialization.

``prometheus_text`` renders the registry in the Prometheus exposition
format (counters/gauges verbatim; histograms as the conventional
``_bucket``/``_sum``/``_count`` triplet with cumulative ``le`` buckets),
so a scrape endpoint or a textfile collector can serve it unchanged.
"""
from __future__ import annotations

import json
import threading
import time
from typing import Any, Dict, Optional

from repro.telemetry.registry import (MetricsRegistry, _NONPOS, format_key)


def prometheus_text(registry: MetricsRegistry) -> str:
    """Prometheus exposition text for every metric in the registry."""
    lines = []
    seen_types = set()
    for m in registry.metrics():
        base = m.name.replace(".", "_").replace("-", "_")
        if m.kind in ("counter", "gauge"):
            if base not in seen_types:
                lines.append(f"# TYPE {base} {m.kind}")
                seen_types.add(base)
            lines.append(f"{format_key(base, m.labels)} {m.value()}")
            continue
        # histogram: cumulative le buckets + _sum/_count
        if base not in seen_types:
            lines.append(f"# TYPE {base} histogram")
            seen_types.add(base)
        merged = m.merged()
        cum = 0
        for i in sorted(merged["buckets"]):
            cum += merged["buckets"][i]
            le = "0" if i == _NONPOS else repr(m.bucket_bounds(i)[1])
            labels = dict(m.labels)
            labels["le"] = le
            lines.append(f"{format_key(base + '_bucket', labels)} {cum}")
        labels = dict(m.labels)
        labels["le"] = "+Inf"
        lines.append(f"{format_key(base + '_bucket', labels)} "
                     f"{merged['count']}")
        lines.append(f"{format_key(base + '_sum', m.labels)} "
                     f"{merged['sum']}")
        lines.append(f"{format_key(base + '_count', m.labels)} "
                     f"{merged['count']}")
    return "\n".join(lines) + "\n"


class MetricsExporter:
    """Periodic snapshot thread: JSONL metrics feed + final Prometheus /
    Chrome-trace dumps. ``interval_s <= 0`` disables the periodic thread
    (final-only mode: one snapshot at ``stop()``)."""

    def __init__(self, telemetry, metrics_path: Optional[str] = None,
                 interval_s: float = 1.0,
                 trace_path: Optional[str] = None,
                 prometheus_path: Optional[str] = None):
        self.telemetry = telemetry
        self.metrics_path = metrics_path
        self.interval_s = interval_s
        self.trace_path = trace_path
        self.prometheus_path = prometheus_path
        self.snapshots_written = 0
        self.trace_events_written = 0
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        self._fh = None
        self._lock = threading.Lock()

    # -- one snapshot line ---------------------------------------------
    def _write_snapshot(self, final: bool = False) -> Dict[str, Any]:
        snap = self.telemetry.snapshot()
        if final:
            snap["final"] = True
        with self._lock:
            if self.metrics_path is not None:
                if self._fh is None:
                    self._fh = open(self.metrics_path, "a",
                                    encoding="utf-8")
                self._fh.write(json.dumps(snap) + "\n")
                self._fh.flush()
            self.snapshots_written += 1
        return snap

    # -- daemon ---------------------------------------------------------
    def start(self) -> "MetricsExporter":
        if self.interval_s > 0 and self._thread is None:
            self._stop.clear()
            self._thread = threading.Thread(target=self._loop,
                                            name="metrics-exporter",
                                            daemon=True)
            self._thread.start()
        return self

    def _loop(self) -> None:
        while not self._stop.wait(self.interval_s):
            try:
                self._write_snapshot()
            except Exception:       # a full disk must not kill the loop
                pass

    def stop(self) -> Dict[str, Any]:
        """Final snapshot + configured dumps; returns the final snapshot."""
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=5.0)
            self._thread = None
        snap = self._write_snapshot(final=True)
        if self.prometheus_path is not None:
            with open(self.prometheus_path, "w", encoding="utf-8") as fh:
                fh.write(prometheus_text(self.telemetry.registry))
        if self.trace_path is not None:
            self.trace_events_written = \
                self.telemetry.tracer.write_chrome_trace(self.trace_path)
        with self._lock:
            if self._fh is not None:
                self._fh.close()
                self._fh = None
        return snap

    def __enter__(self) -> "MetricsExporter":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()


def read_jsonl(path: str):
    """Parse a JSONL metrics feed (raises on an invalid line) — the smoke
    stage's validity check and a convenient test helper."""
    out = []
    with open(path, "r", encoding="utf-8") as fh:
        for line in fh:
            line = line.strip()
            if line:
                out.append(json.loads(line))
    return out
