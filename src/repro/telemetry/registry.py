"""Sharded metrics registry: counters, gauges, log-bucketed histograms.

The design constraint is PR 5's: instrumentation must not reintroduce the
shared-lock contention the dispatch hot path just shed. Every metric
therefore keeps one *cell* per writing thread (created lazily, registered
once under the registry lock) and the hot-path mutation is plain
arithmetic on that thread-private cell — no lock, no CAS, nothing another
dispatcher can wait behind. Readers (the exporter thread, tests,
``snapshot()``) merge the cells on demand; a merge may observe a cell
mid-update torn *across* fields (counts race ahead of sums by at most the
in-flight op) but each field is a single GIL-atomic slot, so totals are
always internally sane and monotone between snapshots.

Histograms are log-bucketed: bucket ``i`` covers ``[growth**i,
growth**(i+1))`` with ``growth = 2**0.25`` by default (≤ 19 % relative
quantile error, 4 buckets per octave). Merging shards is exact — bucket
counts add — so ``merge(shards) ≡ single-shard ingest`` (property-tested
in tests/test_telemetry.py).

The registry is self-measuring: every cell counts its ops, and
``snapshot()`` reports total ops, a calibrated per-op cost (measured once
on a scratch metric, off the hot path), the estimated cumulative overhead
seconds, and the measured cost of the snapshot itself — so "what does
telemetry cost?" is itself a metric (asserted end-to-end by
benchmarks/telemetry_overhead.py).
"""
from __future__ import annotations

import itertools
import math
import threading
import time
import weakref
from typing import Any, Callable, Dict, List, Optional, Tuple

clock = time.monotonic

#: global write-sequence for gauges: merge picks the latest write across
#: thread cells. itertools.count is GIL-atomic, so no lock on set().
_GAUGE_SEQ = itertools.count(1)

#: histogram bucket index for non-positive observations (log undefined)
_NONPOS = -(10 ** 9)

DEFAULT_GROWTH = 2 ** 0.25


def label_key(labels: Dict[str, Any]) -> Tuple[Tuple[str, str], ...]:
    return tuple(sorted((k, str(v)) for k, v in labels.items()))


def format_key(name: str, labels: Dict[str, str]) -> str:
    """Prometheus-style flat key: ``name{k="v",...}`` (plain ``name``
    when unlabeled) — the snapshot/JSONL key format."""
    if not labels:
        return name
    inner = ",".join(f'{k}="{v}"' for k, v in sorted(labels.items()))
    return f"{name}{{{inner}}}"


class _Metric:
    """Base: lazy per-thread cells, registered under the registry lock
    (rare — once per writing thread) and merged by readers."""

    kind = "?"

    def __init__(self, registry: "MetricsRegistry", name: str,
                 labels: Dict[str, str]):
        self._registry = registry
        self.name = name
        self.labels = labels
        self.key = format_key(name, labels)
        self._tl = threading.local()
        self._cells: List[Any] = []

    def _new_cell(self):                    # pragma: no cover - abstract
        raise NotImplementedError

    def _cell(self):
        try:
            return self._tl.cell
        except AttributeError:
            cell = self._new_cell()
            with self._registry._lock:
                self._cells.append(cell)
            self._tl.cell = cell
            return cell

    def ops(self) -> int:
        with self._registry._lock:
            cells = list(self._cells)
        return sum(self._cell_ops(c) for c in cells)

    @staticmethod
    def _cell_ops(cell) -> int:             # pragma: no cover - abstract
        raise NotImplementedError


class Counter(_Metric):
    kind = "counter"

    # cell = [value, ops]
    def _new_cell(self):
        return [0.0, 0]

    @staticmethod
    def _cell_ops(cell) -> int:
        return cell[1]

    def add(self, v: float = 1.0) -> None:
        c = self._cell()
        c[0] += v
        c[1] += 1

    inc = add

    def value(self) -> float:
        with self._registry._lock:
            cells = list(self._cells)
        return sum(c[0] for c in cells)


class Gauge(_Metric):
    kind = "gauge"

    # cell = [write_seq, value, ops]
    def _new_cell(self):
        return [0, 0.0, 0]

    @staticmethod
    def _cell_ops(cell) -> int:
        return cell[2]

    def set(self, v: float) -> None:
        c = self._cell()
        c[1] = v
        c[0] = next(_GAUGE_SEQ)   # value first: a torn read sees old seq
        c[2] += 1

    def add(self, v: float = 1.0) -> None:
        """Gauge delta (e.g. depth up/down from one thread); last-write-
        wins semantics still apply across threads."""
        c = self._cell()
        self.set(c[1] + v)

    def value(self) -> float:
        with self._registry._lock:
            cells = list(self._cells)
        best_seq, best = 0, 0.0
        for c in cells:
            if c[0] >= best_seq:
                best_seq, best = c[0], c[1]
        return best


class Histogram(_Metric):
    kind = "histogram"

    def __init__(self, registry: "MetricsRegistry", name: str,
                 labels: Dict[str, str], growth: float = DEFAULT_GROWTH):
        super().__init__(registry, name, labels)
        if not growth > 1.0:
            raise ValueError(f"growth must be > 1, got {growth}")
        self.growth = growth
        self._lg = math.log(growth)

    # cell = [count, sum, min, max, ops, buckets_dict]
    def _new_cell(self):
        return [0, 0.0, math.inf, -math.inf, 0, {}]

    @staticmethod
    def _cell_ops(cell) -> int:
        return cell[4]

    def bucket_index(self, v: float) -> int:
        if v <= 0.0:
            return _NONPOS
        return int(math.floor(math.log(v) / self._lg))

    def bucket_bounds(self, i: int) -> Tuple[float, float]:
        if i == _NONPOS:
            return (-math.inf, 0.0)
        return (self.growth ** i, self.growth ** (i + 1))

    def observe(self, v: float) -> None:
        c = self._cell()
        c[0] += 1
        c[1] += v
        if v < c[2]:
            c[2] = v
        if v > c[3]:
            c[3] = v
        c[4] += 1
        b = c[5]
        i = _NONPOS if v <= 0.0 \
            else int(math.floor(math.log(v) / self._lg))
        b[i] = b.get(i, 0) + 1

    def merged(self) -> Dict[str, Any]:
        """Merge-on-snapshot: sum the per-thread shards (exact — bucket
        counts and moments are all additive except min/max)."""
        with self._registry._lock:
            cells = list(self._cells)
        count, total = 0, 0.0
        mn, mx = math.inf, -math.inf
        buckets: Dict[int, int] = {}
        for c in cells:
            count += c[0]
            total += c[1]
            mn = min(mn, c[2])
            mx = max(mx, c[3])
            for i, n in list(c[5].items()):
                buckets[i] = buckets.get(i, 0) + n
        return {"count": count, "sum": total,
                "min": mn if count else 0.0, "max": mx if count else 0.0,
                "buckets": buckets}

    def quantile(self, q: float,
                 merged: Optional[Dict[str, Any]] = None) -> float:
        """Bucketed quantile estimate: the upper bound of the bucket
        holding the q-th observation, clamped to the observed [min, max]
        — so the estimate is within one bucket width (factor ``growth``)
        of the true order statistic."""
        m = merged if merged is not None else self.merged()
        count = m["count"]
        if count == 0:
            return 0.0
        rank = max(1, math.ceil(q * count))
        seen = 0
        for i in sorted(m["buckets"]):
            seen += m["buckets"][i]
            if seen >= rank:
                hi = 0.0 if i == _NONPOS else self.growth ** (i + 1)
                return min(max(hi, m["min"]), m["max"])
        return m["max"]

    def summary(self) -> Dict[str, Any]:
        m = self.merged()
        count = m["count"]
        return {
            "count": count, "sum": m["sum"],
            "min": m["min"], "max": m["max"],
            "mean": m["sum"] / count if count else 0.0,
            "p50": self.quantile(0.50, m),
            "p95": self.quantile(0.95, m),
            "p99": self.quantile(0.99, m),
        }


class LabeledRegistry:
    """View over a base registry stamping constant labels (e.g.
    ``runtime="r0"``) onto every metric it creates. N federated runtimes
    share one process registry; without the stamp their ``svc.*`` /
    scheduler families would interleave indistinguishably in snapshots,
    Prometheus text, and the JSONL feed. The stamped labels win on
    collision (a runtime cannot relabel itself per call site). Everything
    else — collectors, snapshot, metrics — delegates to the base, so one
    exporter drains every runtime's view."""

    def __init__(self, base: "MetricsRegistry", labels: Dict[str, Any]):
        self.base = base
        self.labels = {k: str(v) for k, v in labels.items()}

    def counter(self, name: str, **labels) -> Counter:
        return self.base.counter(name, **{**labels, **self.labels})

    def gauge(self, name: str, **labels) -> Gauge:
        return self.base.gauge(name, **{**labels, **self.labels})

    def histogram(self, name: str, growth: Optional[float] = None,
                  **labels) -> Histogram:
        return self.base.histogram(name, growth=growth,
                                   **{**labels, **self.labels})

    def __getattr__(self, name):
        return getattr(self.base, name)


class MetricsRegistry:
    """Get-or-create metric factory + merge-on-snapshot reader."""

    def __init__(self, growth: float = DEFAULT_GROWTH):
        self.growth = growth
        self._lock = threading.RLock()
        self._metrics: Dict[Tuple[str, str, tuple], _Metric] = {}
        # weak collector callbacks run at snapshot time (queue depth,
        # partitioner lock-wait, ...): weakrefs so a dead runtime's
        # collector does not pin it (or crash the exporter) forever
        self._collectors: List[weakref.ref] = []
        self._snapshots = 0
        self._snapshot_s = 0.0
        self._calib_ns: Optional[float] = None

    # -- factories ------------------------------------------------------
    def _get(self, cls, name: str, labels: Dict[str, Any], **kw) -> _Metric:
        key = (cls.kind, name, label_key(labels))
        with self._lock:
            m = self._metrics.get(key)
            if m is None:
                m = self._metrics[key] = cls(
                    self, name, dict(label_key(labels)), **kw)
            return m

    def counter(self, name: str, **labels) -> Counter:
        return self._get(Counter, name, labels)

    def gauge(self, name: str, **labels) -> Gauge:
        return self._get(Gauge, name, labels)

    def histogram(self, name: str, growth: Optional[float] = None,
                  **labels) -> Histogram:
        return self._get(Histogram, name, labels,
                         growth=growth or self.growth)

    def metrics(self) -> List[_Metric]:
        with self._lock:
            return list(self._metrics.values())

    # -- collectors -----------------------------------------------------
    def add_collector(self, fn: Callable[[], None]) -> None:
        """Register a callback run at every snapshot (set gauges from
        live state). Held weakly: bound methods via WeakMethod, so a
        collected runtime simply drops out of the snapshot loop."""
        ref = weakref.WeakMethod(fn) if hasattr(fn, "__self__") \
            else weakref.ref(fn)
        with self._lock:
            self._collectors.append(ref)

    def _run_collectors(self) -> None:
        with self._lock:
            refs = list(self._collectors)
        live = []
        for ref in refs:
            fn = ref()
            if fn is None:
                continue
            live.append(ref)
            try:
                fn()
            except Exception:       # a broken collector must not kill
                pass                # the exporter thread
        with self._lock:
            self._collectors = [r for r in self._collectors if r in live
                                or r() is not None]

    # -- self-measurement ----------------------------------------------
    def _calibrate(self, n: int = 2000) -> float:
        """ns per hot-path op, measured on scratch metrics of a scratch
        registry (never touches live cells)."""
        if self._calib_ns is not None:
            return self._calib_ns
        scratch = MetricsRegistry.__new__(MetricsRegistry)
        scratch._lock = threading.RLock()
        scratch._metrics = {}
        scratch._collectors = []
        c = Counter(scratch, "calib", {})
        h = Histogram(scratch, "calib_h", {}, growth=self.growth)
        t0 = time.perf_counter()
        for i in range(n):
            c.add(1.0)
            h.observe(1e-6 * (i + 1))
        dt = time.perf_counter() - t0
        self._calib_ns = dt / (2 * n) * 1e9
        return self._calib_ns

    # -- the merge-on-snapshot read ------------------------------------
    def snapshot(self) -> Dict[str, Any]:
        t0 = time.perf_counter()
        self._run_collectors()
        counters: Dict[str, float] = {}
        gauges: Dict[str, float] = {}
        hists: Dict[str, Dict[str, Any]] = {}
        ops = 0
        for m in self.metrics():
            ops += m.ops()
            if m.kind == "counter":
                counters[m.key] = m.value()
            elif m.kind == "gauge":
                gauges[m.key] = m.value()
            else:
                hists[m.key] = m.summary()
        calib = self._calibrate()
        dt = time.perf_counter() - t0
        self._snapshots += 1
        self._snapshot_s += dt
        return {
            "ts": time.time(), "mono": clock(),
            "counters": counters, "gauges": gauges, "histograms": hists,
            "self": {
                "ops": ops,
                "ns_per_op": round(calib, 1),
                "est_overhead_s": ops * calib * 1e-9,
                "snapshots": self._snapshots,
                "snapshot_s": self._snapshot_s,
            },
        }
