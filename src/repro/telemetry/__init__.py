"""Always-on observability for the serving stack.

The paper's whole §3.3 method is *measure the host-side overheads before
optimizing them*; this package makes that measurement continuous instead
of post-hoc. Three pieces:

  * ``MetricsRegistry`` — counters / gauges / log-bucketed histograms
    with per-thread shards (lock-free hot path, merge-on-snapshot), so
    instrumentation cannot reintroduce the shared-lock contention PR 5
    removed from dispatch;
  * ``SpanTracer`` — chunk-lifecycle spans (Tc1→Tc3 / Tg1→Tg5 plus
    queue/steal/requeue/admission events) behind a ``sample_rate`` knob,
    exported as Chrome trace-event JSON (Perfetto / chrome://tracing);
  * ``MetricsExporter`` — a periodic snapshot thread emitting JSONL,
    Prometheus text, and the trace file.

A ``Telemetry`` object bundles one registry + one tracer. Instrumented
components take ``telemetry=None`` (→ the process-wide default instance:
always-on) or an explicit instance; pass ``telemetry=repro.telemetry.OFF``
to run genuinely uninstrumented (the benchmark baseline). The registry is
self-measuring — ``snapshot()["self"]`` reports its own estimated
overhead — and benchmarks/telemetry_overhead.py asserts the instrumented
dispatch hot path stays within 1.15× of uninstrumented at 8 workers.
"""
from __future__ import annotations

import threading
from typing import Any, Dict, Optional

from repro.telemetry.registry import (Counter, Gauge, Histogram,
                                      LabeledRegistry, MetricsRegistry,
                                      format_key)
from repro.telemetry.spans import LabeledTracer, SpanTracer
from repro.telemetry.exporters import (MetricsExporter, prometheus_text,
                                       read_jsonl)

#: sentinel: run uninstrumented (resolve() maps it — and False — to None)
OFF = object()


class Telemetry:
    """One registry + one tracer: the unit components are wired with."""

    def __init__(self, sample_rate: float = 1.0,
                 max_trace_events: int = 200_000):
        self.registry = MetricsRegistry()
        self.tracer = SpanTracer(sample_rate=sample_rate,
                                 max_events=max_trace_events)

    def snapshot(self) -> Dict[str, Any]:
        snap = self.registry.snapshot()
        snap["trace"] = {"emitted": self.tracer.emitted,
                         "retained": len(self.tracer),
                         "dropped": self.tracer.dropped,
                         "sample_rate": self.tracer.sample_rate}
        return snap

    def labeled(self, **labels) -> "TelemetryView":
        """Per-runtime facet of this instance: same metric storage and
        trace ring, but every metric carries ``labels`` and every span /
        epoch tag is namespaced — how N federated runtimes share one
        exporter without interleaving their families (e.g.
        ``tel.labeled(runtime="r0")``)."""
        return TelemetryView(self, labels)


class TelemetryView:
    """A ``Telemetry`` facet with constant labels stamped on (see
    ``Telemetry.labeled``). ``resolve()`` passes it through like any
    instance; ``snapshot()`` is the base's merged view."""

    def __init__(self, base: Telemetry, labels: Dict[str, Any]):
        self.base = base
        self.labels = {k: str(v) for k, v in labels.items()}
        self.registry = LabeledRegistry(base.registry, self.labels)
        self.tracer = LabeledTracer(
            base.tracer, "/".join(self.labels.values()) or "view")

    def snapshot(self) -> Dict[str, Any]:
        return self.base.snapshot()

    def labeled(self, **labels) -> "TelemetryView":
        return TelemetryView(self.base, {**self.labels, **labels})


_default: Optional[Telemetry] = None
_default_lock = threading.Lock()


def default() -> Telemetry:
    """The process-wide always-on instance (created lazily). Long-lived:
    counters only ever grow; the tracer ring and epoch-tag map are
    bounded."""
    global _default
    with _default_lock:
        if _default is None:
            _default = Telemetry()
        return _default


def resolve(telemetry) -> Optional[Telemetry]:
    """Normalize a component's ``telemetry=`` argument: ``None`` → the
    always-on default, ``OFF``/``False`` → uninstrumented (None), an
    instance → itself."""
    if telemetry is None:
        return default()
    if telemetry is OFF or telemetry is False:
        return None
    return telemetry


__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry", "MetricsExporter",
    "LabeledRegistry", "LabeledTracer", "SpanTracer", "Telemetry",
    "TelemetryView", "OFF", "default", "resolve",
    "prometheus_text", "read_jsonl", "format_key",
]
