"""Chunk executors: how a device group processes a chunk (Filter₂).

Executors fill the device-side timestamps of ChunkRecord:
  tg1→tg2  host-to-device transfer (jax.device_put of the chunk's inputs)
  tg2→tg3  dispatch / launch (the jitted call returning — async under JAX)
  tg3→tg4  device execution (until outputs are ready)
  tg4→tg5  device-to-host fetch of (small) results/metrics

`async_depth` is the TPU-idiomatic *Dynamic Pri*: with depth ≥ 2 the next
chunk is dispatched before the previous completes, so the device never waits
for the host thread to be rescheduled (the paper's O_td collapses). Depth 1
reproduces the paper's baseline Dynamic (synchronous clFinish()).

`priority_boost` is the literal paper optimization: raise the host/dispatch
thread's OS priority (best-effort `os.nice`; needs privileges to raise).
"""
from __future__ import annotations

import collections
import os
import threading
import time
from dataclasses import dataclass
from typing import Any, Callable, Deque, Dict, List, Optional, Tuple

from repro.core.types import ChunkRecord, Token

clock = time.monotonic


class ChunkFailure(RuntimeError):
    """Raised by an executor when its device group dies mid-chunk."""


def try_boost_priority(delta: int = -10) -> bool:
    """Best-effort SetThreadPriority analogue. Lowering niceness requires
    privileges; returns whether the boost took effect."""
    try:
        os.nice(delta)
        return True
    except (PermissionError, OSError):
        return False


class ChunkExecutor:
    """Interface. execute() may complete earlier in-flight work; drain()
    flushes the pipeline at end-of-stream."""

    def on_worker_start(self) -> None:
        pass

    def execute(self, token: Token, rec: ChunkRecord) -> List[ChunkRecord]:
        raise NotImplementedError

    def drain(self) -> List[ChunkRecord]:
        return []


class CallableExecutor(ChunkExecutor):
    """Synchronous executor around fn(token) -> meta dict (or None)."""

    def __init__(self, fn: Callable[[Token], Optional[Dict]],
                 priority_boost: bool = False):
        self.fn = fn
        self.priority_boost = priority_boost
        self.boosted = False

    def on_worker_start(self) -> None:
        if self.priority_boost:
            self.boosted = try_boost_priority()

    def execute(self, token: Token, rec: ChunkRecord) -> List[ChunkRecord]:
        rec.tg1 = rec.tg2 = rec.tg3 = clock()
        meta = self.fn(token)
        rec.tg4 = rec.tg5 = clock()
        if meta:
            rec.meta.update(meta)
        return [rec]


class JaxChunkExecutor(ChunkExecutor):
    """Runs a jitted step on a JAX device group with measured offload phases.

    make_inputs(token) -> pytree of host (numpy) arrays for the chunk
    step(*device_inputs) -> outputs pytree (device)
    fetch(outputs) -> small host metrics (device-to-host phase)
    """

    def __init__(self, step: Callable, make_inputs: Callable[[Token], Any],
                 fetch: Optional[Callable[[Any], Any]] = None,
                 device=None, async_depth: int = 1,
                 priority_boost: bool = False):
        import jax
        self.jax = jax
        self.step = step
        self.make_inputs = make_inputs
        self.fetch = fetch or (lambda outs: None)
        self.device = device
        self.async_depth = max(1, async_depth)
        self.priority_boost = priority_boost
        self.boosted = False
        self._inflight: Deque[Tuple[ChunkRecord, Any]] = collections.deque()

    def on_worker_start(self) -> None:
        if self.priority_boost:
            self.boosted = try_boost_priority()

    def _complete_oldest(self) -> ChunkRecord:
        rec, outs = self._inflight.popleft()
        self.jax.block_until_ready(outs)
        rec.tg4 = clock()
        res = self.fetch(outs)
        rec.tg5 = clock()
        if res is not None:
            rec.meta["result"] = res
        return rec

    def execute(self, token: Token, rec: ChunkRecord) -> List[ChunkRecord]:
        done: List[ChunkRecord] = []
        while len(self._inflight) >= self.async_depth:
            done.append(self._complete_oldest())
        host_inputs = self.make_inputs(token)
        rec.tg1 = clock()
        dev_inputs = self.jax.device_put(host_inputs, self.device) \
            if self.device is not None else self.jax.device_put(host_inputs)
        rec.tg2 = clock()
        outs = self.step(*dev_inputs) if isinstance(dev_inputs, tuple) \
            else self.step(dev_inputs)
        rec.tg3 = clock()                       # dispatch returned (async)
        self._inflight.append((rec, outs))
        if self.async_depth == 1:
            done.append(self._complete_oldest())
        return done

    def drain(self) -> List[ChunkRecord]:
        out = []
        while self._inflight:
            out.append(self._complete_oldest())
        return out


class SleepExecutor(ChunkExecutor):
    """Deterministic executor for scheduler unit tests: service time is
    chunk.size / rate plus fixed per-phase overheads."""

    def __init__(self, rate: float, t_hd: float = 0.0, t_kl: float = 0.0,
                 t_dh: float = 0.0, fail_after: Optional[int] = None):
        self.rate = rate
        self.t_hd, self.t_kl, self.t_dh = t_hd, t_kl, t_dh
        self.fail_after = fail_after
        self._count = 0

    def execute(self, token: Token, rec: ChunkRecord) -> List[ChunkRecord]:
        self._count += 1
        if self.fail_after is not None and self._count > self.fail_after:
            raise ChunkFailure(f"group {token.group} died")
        rec.tg1 = clock()
        time.sleep(self.t_hd)
        rec.tg2 = clock()
        time.sleep(self.t_kl)
        rec.tg3 = clock()
        time.sleep(token.chunk.size / self.rate)
        rec.tg4 = clock()
        time.sleep(self.t_dh)
        rec.tg5 = clock()
        return [rec]
