"""Chunk executors: how a device group processes a chunk (Filter₂).

Executors fill the device-side timestamps of ChunkRecord:
  tg1→tg2  host-to-device transfer (jax.device_put of the chunk's inputs)
  tg2→tg3  dispatch / launch (the jitted call returning — async under JAX)
  tg3→tg4  device execution (until outputs are ready)
  tg4→tg5  device-to-host fetch of (small) results/metrics

`async_depth` is the TPU-idiomatic *Dynamic Pri*: with depth ≥ 2 the next
chunk is dispatched before the previous completes, so the device never waits
for the host thread to be rescheduled (the paper's O_td collapses). Depth 1
reproduces the paper's baseline Dynamic (synchronous clFinish()).

`priority_boost` is the literal paper optimization: raise the host/dispatch
thread's OS priority (best-effort `os.nice`; needs privileges to raise).
"""
from __future__ import annotations

import collections
import os
import threading
import time
from dataclasses import dataclass
from typing import Any, Callable, Deque, Dict, List, Optional, Tuple

from repro.core.types import Chunk, ChunkRecord, Token

clock = time.monotonic


class ChunkFailure(RuntimeError):
    """Raised by an executor when its device group dies mid-chunk."""


def try_boost_priority(delta: int = -10) -> bool:
    """Best-effort SetThreadPriority analogue. Lowering niceness requires
    privileges; returns whether the boost took effect."""
    try:
        os.nice(delta)
        return True
    except (PermissionError, OSError):
        return False


class ChunkExecutor:
    """Interface. execute() may complete earlier in-flight work; drain()
    flushes the pipeline at end-of-epoch.

    Executors are *reused across epochs* on the persistent scheduler
    runtime: on_worker_start() fires once per dispatcher thread (runtime
    lifetime), while drain() has per-epoch semantics — the dispatcher calls
    it when an epoch's space is exhausted so no in-flight work crosses an
    epoch boundary. abort() discards the pipeline after a group death and
    returns the abandoned chunks so the caller can requeue them."""

    def on_worker_start(self) -> None:
        pass

    def execute(self, token: Token, rec: ChunkRecord) -> List[ChunkRecord]:
        raise NotImplementedError

    def drain(self) -> List[ChunkRecord]:
        return []

    def cancel(self) -> List[ChunkRecord]:
        """Cooperative wind-down for epoch cancellation: return whatever
        already finished *without* waiting for the rest of the pipeline —
        still-running chunks stay in flight for ``abort()`` to hand back
        as requeue candidates. Synchronous executors have nothing in
        flight, so the default is a plain drain."""
        return self.drain()

    def abort(self) -> List[Chunk]:
        """Drop any in-flight work; returns the chunks to requeue."""
        return []

    def completed(self) -> List[ChunkRecord]:
        """Records that finished but were not yet returned when a failure
        interrupted execute()/drain(); the dispatcher collects them on the
        failure path so finished work is not discarded with the group."""
        return []


class CallableExecutor(ChunkExecutor):
    """Synchronous executor around fn(token) -> meta dict (or None)."""

    def __init__(self, fn: Callable[[Token], Optional[Dict]],
                 priority_boost: bool = False):
        self.fn = fn
        self.priority_boost = priority_boost
        self.boosted = False

    def on_worker_start(self) -> None:
        if self.priority_boost:
            self.boosted = try_boost_priority()

    def execute(self, token: Token, rec: ChunkRecord) -> List[ChunkRecord]:
        rec.tg1 = rec.tg2 = rec.tg3 = clock()
        meta = self.fn(token)
        rec.tg4 = rec.tg5 = clock()
        if meta:
            rec.meta.update(meta)
        return [rec]


class JaxChunkExecutor(ChunkExecutor):
    """Runs a jitted step on a JAX device group with measured offload phases.

    make_inputs(token) -> pytree of host (numpy) arrays for the chunk
    step(*device_inputs) -> outputs pytree (device)
    fetch(outputs) -> small host metrics (device-to-host phase)
    """

    #: bounded-backoff schedule for the readiness poll: a few free yields
    #: first (completion is usually imminent), then exponential sleeps
    #: capped so a long kernel costs at most POLL_MAX_S of detection lag
    POLL_MIN_S = 5e-5
    POLL_MAX_S = 1e-3

    def __init__(self, step: Callable, make_inputs: Callable[[Token], Any],
                 fetch: Optional[Callable[[Any], Any]] = None,
                 device=None, async_depth: int = 1,
                 priority_boost: bool = False,
                 completion_mode: str = "poll"):
        import jax
        if completion_mode not in ("poll", "block"):
            raise ValueError(f"completion_mode must be 'poll' or 'block', "
                             f"got {completion_mode!r}")
        self.jax = jax
        self.step = step
        self.make_inputs = make_inputs
        self.fetch = fetch or (lambda outs: None)
        self.device = device
        self.async_depth = max(1, async_depth)
        self.priority_boost = priority_boost
        self.completion_mode = completion_mode
        self.boosted = False
        self._inflight: Deque[Tuple[ChunkRecord, Any]] = collections.deque()
        self._lost_chunks: List[Chunk] = []       # popped, then failed
        self._pending_done: List[ChunkRecord] = []  # done, not yet returned
        # whether outputs carry a jax.Array.is_ready probe — decided on
        # the first dispatched output. On a jax too old to expose it,
        # "no probe" would read as "always ready" and the opportunistic
        # drain would block on every unfinished chunk (worse than the
        # depth-gated baseline), so poll mode degrades to block instead.
        self._poll_ok: Optional[bool] = None

    def on_worker_start(self) -> None:
        if self.priority_boost:
            self.boosted = try_boost_priority()

    # -- event-driven completion ---------------------------------------
    def _polling(self) -> bool:
        return self.completion_mode == "poll" and bool(self._poll_ok)

    def _is_ready(self, outs: Any) -> bool:
        """Non-blocking readiness probe over the output pytree. Leaves
        without ``is_ready`` (host arrays, scalars) are always ready."""
        for leaf in self.jax.tree_util.tree_leaves(outs):
            is_ready = getattr(leaf, "is_ready", None)
            if is_ready is not None and not is_ready():
                return False
        return True

    def _wait_ready(self, outs: Any) -> None:
        """Wait for the chunk's outputs without parking the dispatcher in
        a hard ``block_until_ready``: poll ``jax.Array`` readiness with a
        bounded-backoff yield (the paper's anti-oversubscription wait —
        an oversubscribed host core gives its slice away instead of
        spinning). ``completion_mode="block"`` restores the synchronous
        wait (the paper's baseline Dynamic / benchmark old path)."""
        if not self._polling():
            self.jax.block_until_ready(outs)
            return
        delay = 0.0
        while not self._is_ready(outs):
            time.sleep(delay)       # 0.0 first: yield, don't nap
            delay = min(max(delay * 2.0, self.POLL_MIN_S), self.POLL_MAX_S)
        # all pollable leaves are ready: this returns without blocking and
        # covers any leaves that had no is_ready probe
        self.jax.block_until_ready(outs)

    def _complete_oldest(self, known_ready: bool = False) -> ChunkRecord:
        rec, outs = self._inflight.popleft()
        try:
            if known_ready:     # readiness just probed by the caller:
                # skip the poll loop, keep the no-op barrier for leaves
                # without a probe
                self.jax.block_until_ready(outs)
            else:
                self._wait_ready(outs)
            rec.tg4 = clock()
            res = self.fetch(outs)
            rec.tg5 = clock()
        except BaseException:
            # the popped chunk is in neither _inflight nor the caller's
            # hands — remember it so abort() can hand it back for requeue
            self._lost_chunks.append(rec.token.chunk)
            raise
        # Tc3 (host resumed after completion) is stamped here, per record:
        # with async_depth ≥ 2 several records drain in one call, and a
        # single batch-level stamp would inflate O_td for all but the last
        rec.tc3 = clock()
        if res is not None:
            rec.meta["result"] = res
        return rec

    def execute(self, token: Token, rec: ChunkRecord) -> List[ChunkRecord]:
        done: List[ChunkRecord] = self._pending_done
        self._pending_done = []
        try:
            # opportunistic drain: anything already finished completes now
            # (no wait), so completion latency is hidden behind dispatch
            # instead of accumulating until the pipeline fills
            if self._polling():
                while self._inflight and self._is_ready(self._inflight[0][1]):
                    done.append(self._complete_oldest(known_ready=True))
            while len(self._inflight) >= self.async_depth:
                done.append(self._complete_oldest())
            host_inputs = self.make_inputs(token)
            rec.tg1 = clock()
            dev_inputs = self.jax.device_put(host_inputs, self.device) \
                if self.device is not None \
                else self.jax.device_put(host_inputs)
            rec.tg2 = clock()
            outs = self.step(*dev_inputs) if isinstance(dev_inputs, tuple) \
                else self.step(dev_inputs)
            rec.tg3 = clock()                   # dispatch returned (async)
            if self._poll_ok is None:
                self._poll_ok = any(
                    hasattr(leaf, "is_ready")
                    for leaf in self.jax.tree_util.tree_leaves(outs))
            self._inflight.append((rec, outs))
            if self.async_depth == 1:
                done.append(self._complete_oldest())
        except BaseException:
            # a failure anywhere (completion OR launch of the new chunk)
            # must not discard records that already finished in this call
            self._pending_done = done
            raise
        return done

    def drain(self) -> List[ChunkRecord]:
        out = self._pending_done
        self._pending_done = []
        try:
            while self._inflight:
                out.append(self._complete_oldest())
        except BaseException:
            self._pending_done = out      # keep finished records visible
            raise
        return out

    def cancel(self) -> List[ChunkRecord]:
        """Cancellation wind-down: complete only the chunks whose outputs
        are already ready (free — no wait), leaving genuinely in-flight
        device work queued for ``abort()``/requeue. Without a readiness
        probe (block mode / old jax) there is no way to tell done from
        running, so fall back to a full drain — the submitted work is
        finishing on the device either way; draining just keeps its
        records instead of discarding real results."""
        out = self._pending_done
        self._pending_done = []
        try:
            if not self._polling():
                while self._inflight:
                    out.append(self._complete_oldest())
            else:
                while self._inflight \
                        and self._is_ready(self._inflight[0][1]):
                    out.append(self._complete_oldest(known_ready=True))
        except BaseException:
            self._pending_done = out      # keep finished records visible
            raise
        return out

    def abort(self) -> List[Chunk]:
        chunks = self._lost_chunks
        chunks += [rec.token.chunk for rec, _ in self._inflight]
        self._lost_chunks = []
        self._inflight.clear()
        return chunks

    def completed(self) -> List[ChunkRecord]:
        done, self._pending_done = self._pending_done, []
        return done


class SleepExecutor(ChunkExecutor):
    """Deterministic executor for scheduler unit tests: service time is
    chunk.size / rate plus fixed per-phase overheads. ``fail_after`` kills
    the group after N chunks; ``slow_after`` divides the rate by
    ``slow_factor`` after N chunks (a mid-run straggler)."""

    def __init__(self, rate: float, t_hd: float = 0.0, t_kl: float = 0.0,
                 t_dh: float = 0.0, fail_after: Optional[int] = None,
                 slow_after: Optional[int] = None, slow_factor: float = 10.0,
                 clock: Optional[Callable[[], float]] = None,
                 sleep: Optional[Callable[[float], None]] = None):
        self.rate = rate
        self.t_hd, self.t_kl, self.t_dh = t_hd, t_kl, t_dh
        self.fail_after = fail_after
        self.slow_after = slow_after
        self.slow_factor = slow_factor
        # injectable time source/sink: the deterministic test harness
        # (tests/clock.py VirtualClock) substitutes both so simulated
        # service time advances a virtual timeline instead of the wall
        self.clock = clock if clock is not None else globals()["clock"]
        self.sleep = sleep if sleep is not None else time.sleep
        self._count = 0

    def execute(self, token: Token, rec: ChunkRecord) -> List[ChunkRecord]:
        self._count += 1
        if self.fail_after is not None and self._count > self.fail_after:
            raise ChunkFailure(f"group {token.group} died")
        rate = self.rate
        if self.slow_after is not None and self._count > self.slow_after:
            rate = self.rate / self.slow_factor
        # skip zero-duration sleeps: time.sleep(0.0) is still a syscall
        # (~µs each, up to four per chunk), real overhead a *simulated*
        # run must not pay on its host-path measurements
        service = token.chunk.size / rate
        rec.tg1 = self.clock()
        if self.t_hd:
            self.sleep(self.t_hd)
        rec.tg2 = self.clock()
        if self.t_kl:
            self.sleep(self.t_kl)
        rec.tg3 = self.clock()
        if service:
            self.sleep(service)
        rec.tg4 = self.clock()
        if self.t_dh:
            self.sleep(self.t_dh)
        rec.tg5 = self.clock()
        return [rec]
