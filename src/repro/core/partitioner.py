"""Heterogeneous dynamic partitioner — the paper's §3.2 (Partitioner_H).

Policy (Navarro et al. heuristic, eqs. 3–4):
  * an ACCEL group always receives its tuned optimal chunk G;
  * any other group receives C = G_ref · λ_self / λ_ref, where ref is the
    (fastest) accelerator group — i.e. every chunk is sized to take the same
    wall time as the accelerator's chunk, balancing load while every device
    runs at its throughput-optimal size;
  * if no accelerator exists, chunks are proportional to a base quantum.

The partitioner is work-conserving: it never hands out more iterations than
remain, and the final chunks shrink to exhaust the space exactly (property-
tested in tests/test_properties.py).

Two chunk modes (the dispatch hot path):

``chunk_mode="range"`` (default) — zero-contention dispatch. Each group
owns a private index *range* sized by its λ-share of the remaining space;
its dispatcher carves chunks out of it with plain arithmetic under a
private (and therefore uncontended in steady state) lock. The global lock
is touched only to *refill* an empty range from the unassigned space and
to *steal* from the largest remaining range once the space runs dry — so
a chunk grant never waits behind another group's Filter₁. Work
conservation and requeue semantics are identical to the paper path
(property-tested: same covered iteration set); the one behavioral
difference is that a group's chunk size is recomputed per *refill*, not
per token, so λ feedback quantizes to range granularity.

``chunk_mode="paper"`` — the original lock-per-token path (one global
lock serializing every ``next_token``), kept bit-compatible for
paper-faithful runs and as the dispatch-overhead benchmark baseline.

The global lock is wait-instrumented in both modes
(``contention_stats()``), which is what benchmarks/dispatch_overhead.py
reports as lock-wait time; the range-mode fast path never touches it.

The partitioner is *epoch-reusable*: one instance serves successive
iteration spaces on the persistent scheduler runtime. Group membership
(including groups removed by death or elastic leave), the accelerator
reference, and — via the shared ThroughputTracker — the λ-EWMAs all carry
across epochs; ``begin_epoch(space)`` swaps in the next space, and
``next_token``/``requeue`` accept an explicit space so overlapping epochs
(one group draining epoch N while another starts N+1) never mix ranges.
A group that dies or leaves returns its unconsumed ranges to their spaces
(count conservation, like ``requeue``), so no assigned-but-unrun work is
ever lost with its owner.
"""
from __future__ import annotations

import threading
import time
import weakref
from typing import Dict, Optional

from repro import telemetry as telemetry_mod
from repro.core.locks import TimedLock
from repro.core.throughput import ThroughputTracker
from repro.core.types import Chunk, DeviceKind, GroupSpec, IterationSpace, \
    Token

clock = time.monotonic

CHUNK_MODES = ("range", "paper")

# compat alias: the wait-instrumented lock moved to repro.core.locks so
# the throughput tracker can share it without a circular import
_TimedLock = TimedLock


class _GroupRange:
    """Private [lo, hi) slice of one space owned by one group. ``lock``
    is touched by the owner's dispatcher and, rarely, a thief — never by
    the other dispatchers' steady-state grants."""

    __slots__ = ("lo", "hi", "chunk", "lock")

    def __init__(self):
        self.lo = 0
        self.hi = 0
        self.chunk = 1              # per-refill chunk size (λ-sized)
        self.lock = threading.Lock()

    @property
    def remaining(self) -> int:
        return self.hi - self.lo


class HeterogeneousPartitioner:
    def __init__(self, space: IterationSpace, groups: Dict[str, GroupSpec],
                 tracker: ThroughputTracker,
                 base_quantum: int = 256, chunk_mode: str = "range",
                 refill_chunks: int = 8, adaptive_refill: bool = False,
                 telemetry=None):
        if chunk_mode not in CHUNK_MODES:
            raise ValueError(f"chunk_mode must be one of {CHUNK_MODES}, "
                             f"got {chunk_mode!r}")
        self.space = space
        self.groups = dict(groups)
        self.tracker = tracker
        self.base_quantum = base_quantum
        self.chunk_mode = chunk_mode
        self.refill_chunks = max(1, refill_chunks)
        # history-driven refill sizing (range mode): the refill quota
        # grows when the observed steal rate is low (well-sized grants —
        # amortize more per global-lock acquire) and shrinks when it is
        # high (grants keep getting clawed back — stop banking them),
        # and a grant near space exhaustion is capped at a fair share of
        # the tail so one group cannot hoard the end of the space and
        # straggle. Off by default at this level (the library contract
        # is plain λ-share refills); DynamicScheduler turns it on.
        self.adaptive_refill = adaptive_refill
        self._refills = 0           # mutated under the global lock only
        self._steals = 0
        self._lock = _TimedLock()
        # refill/steal/reclaim/requeue counters + a lock-wait collector;
        # all off the range-mode fast path (they fire only where the
        # global lock is already taken)
        self.telemetry = telemetry_mod.resolve(telemetry)
        self._tel_counters: Dict[str, object] = {}
        if self.telemetry is not None:
            self.telemetry.registry.add_collector(self._collect)
        # per-space, per-group private ranges (range mode). Weak keys: a
        # finalized epoch's space drops its range table with it, so a
        # long-lived daemon does not accumulate one table per batch.
        self._ranges: "weakref.WeakKeyDictionary[IterationSpace, Dict[str, _GroupRange]]" \
            = weakref.WeakKeyDictionary()
        accels = [g for g in self.groups.values()
                  if g.kind == DeviceKind.ACCEL]
        self._ref: Optional[GroupSpec] = accels[0] if accels else None
        for g in self.groups.values():
            tracker.seed(g.name, g.init_throughput)

    # ------------------------------------------------------------------
    def begin_epoch(self, space: IterationSpace) -> None:
        """Epoch reset: install the next iteration space, keeping group
        membership, the accel reference, and (tracker-held) λ state."""
        with self._lock:
            self.space = space

    # ------------------------------------------------------------------
    def add_group(self, spec: GroupSpec) -> None:
        """Elastic join: the group starts receiving λ-proportional chunks."""
        with self._lock:
            self.groups[spec.name] = spec
            self.tracker.seed(spec.name, spec.init_throughput)
            if spec.kind == DeviceKind.ACCEL and self._ref is None:
                self._ref = spec

    def remove_group(self, name: str) -> None:
        """Elastic leave / failure: stop scheduling to the group. Its
        unconsumed private ranges flow back to their spaces (count
        conservation — same semantics as ``requeue``), so a live
        dispatcher can absorb them."""
        with self._lock:
            self.groups.pop(name, None)
            if self._ref is not None and self._ref.name == name:
                accels = [g for g in self.groups.values()
                          if g.kind == DeviceKind.ACCEL]
                self._ref = accels[0] if accels else None
            for space, ranges in list(self._ranges.items()):
                st = ranges.get(name)
                if st is None:
                    continue
                with st.lock:
                    leftover = st.hi - st.lo
                    st.lo = st.hi
                if leftover > 0:
                    space.put_back(Chunk(0, leftover))
                    if self.telemetry is not None:
                        self._count("part.reclaims")
                        self._count("part.reclaimed_items", leftover)
                        self.telemetry.tracer.instant(
                            "range_reclaim", tid="partitioner",
                            group=name, items=leftover)

    def has_work(self, space: IterationSpace) -> bool:
        """Whether ``space`` still has takeable work: unassigned items or
        (range mode) an unconsumed private range some group could steal
        from. Lock-free racy read — the scheduler uses it only to decide
        where an idle dispatcher goes next, and next_token re-checks
        under the proper locks."""
        if space.remaining > 0:
            return True
        if self.chunk_mode == "paper":
            return False
        ranges = self._ranges.get(space)
        if not ranges:
            return False
        return any(st.hi > st.lo for st in ranges.values())

    def reclaim_space(self, space: IterationSpace) -> int:
        """Epoch cancellation: return *every* group's unconsumed private
        range for ``space`` back to it (count conservation, same semantics
        as ``requeue``/``remove_group``), so the cancelled epoch's
        unfinished tail is visible as ``space.remaining`` — the unit the
        service's requeue accounting works in. Groups keep their ranges in
        every *other* space; a chunk a dispatcher carved out concurrently
        is already out of the range and will simply complete (cooperative
        cancellation is chunk-granular). Returns the reclaimed item
        count."""
        total = 0
        with self._lock:
            ranges = self._ranges.get(space)
            if not ranges:
                return 0
            for name, st in ranges.items():
                with st.lock:
                    leftover = st.hi - st.lo
                    st.lo = st.hi
                if leftover > 0:
                    space.put_back(Chunk(0, leftover))
                    total += leftover
        if total and self.telemetry is not None:
            self._count("part.reclaims")
            self._count("part.reclaimed_items", total)
            self.telemetry.tracer.instant("cancel_reclaim",
                                          tid="partitioner", items=total)
        return total

    # ------------------------------------------------------------------
    def chunk_size_for(self, name: str) -> int:
        g = self.groups[name]
        if g.kind == DeviceKind.ACCEL and g.fixed_chunk:
            size = g.fixed_chunk
        elif self._ref is not None and self._ref.fixed_chunk:
            lam_ref = self.tracker.get(self._ref.name)
            lam = self.tracker.get(name)
            # eq. (4) compares like with like (both previous-interval
            # measurements). While the reference λ is still an unmeasured
            # seed, a *measured* λ here can be 100× the seed (a warm CPU
            # vs. a cold accel) and the ratio would hand this group the
            # rest of the space — hold it to its seed until the
            # reference has a real measurement. Range mode only: the
            # paper path reproduces the original behavior bit-for-bit.
            if self.chunk_mode == "range" \
                    and not self.tracker.measured(self._ref.name):
                lam = self.tracker.seed_of(name)
            size = int(round(self._ref.fixed_chunk * lam
                             / max(lam_ref, 1e-12)))          # eq. (4)
        else:
            # homogeneous fallback: quantum scaled by relative λ
            lams = self.tracker.snapshot()
            mx = max(lams.values()) if lams else 1.0
            size = int(round(self.base_quantum
                             * self.tracker.get(name) / max(mx, 1e-12)))
        size = max(size, g.min_chunk)
        if g.max_chunk:
            size = min(size, g.max_chunk)
        return size

    def next_token(self, name: str,
                   space: Optional[IterationSpace] = None) -> Optional[Token]:
        """Filter₁ body for a device that just became idle. ``space``
        selects the epoch to draw from (defaults to the current one)."""
        g = self.groups.get(name)
        if g is None:
            return None
        if self.chunk_mode == "paper":
            with self._lock:
                if name not in self.groups:
                    return None
                chunk = (space or self.space).take(self.chunk_size_for(name))
                if chunk is None:
                    return None
                return Token(chunk, g.name, g.kind)
        # -- range mode fast path: private arithmetic, no shared lock --
        sp = space if space is not None else self.space
        st = self._range_for(sp, name)
        with st.lock:
            lo = st.lo
            if lo < st.hi:
                n = st.chunk
                if lo + n > st.hi:
                    n = st.hi - lo
                st.lo = lo + n
                return Token(Chunk(lo, lo + n, sp.next_seq()), name, g.kind)
        return self._refill_or_steal(sp, name, st)

    def requeue(self, chunk: Chunk,
                space: Optional[IterationSpace] = None) -> None:
        """Fault tolerance: a failed/lost chunk re-enters its space."""
        with self._lock:
            (space or self.space).put_back(chunk)
        if self.telemetry is not None:
            self._count("part.requeues")
            self._count("part.requeued_items", chunk.size)
            self.telemetry.tracer.instant("chunk_requeue",
                                          tid="partitioner",
                                          items=chunk.size, seq=chunk.seq)

    # -- telemetry plumbing ---------------------------------------------
    def _count(self, name: str, n: float = 1.0) -> None:
        c = self._tel_counters.get(name)
        if c is None:
            c = self._tel_counters[name] = \
                self.telemetry.registry.counter(name)
        c.add(n)

    def _collect(self) -> None:
        """Snapshot-time collector: publish global-lock contention as
        gauges (the exporter thread pulls; the hot path never pushes)."""
        stats = self.contention_stats()
        reg = self.telemetry.registry
        reg.gauge("part.lock_wait_s").set(stats["lock_wait_s"])
        reg.gauge("part.lock_acquires").set(stats["lock_acquires"])

    # -- range machinery (global lock only here) ------------------------
    def _range_for(self, sp: IterationSpace, name: str) -> _GroupRange:
        ranges = self._ranges.get(sp)
        if ranges is not None:
            st = ranges.get(name)
            if st is not None:
                return st
        with self._lock:
            ranges = self._ranges.setdefault(sp, {})
            st = ranges.get(name)
            if st is None:
                st = ranges[name] = _GroupRange()
            return st

    def _refill_or_steal(self, sp: IterationSpace, name: str,
                         st: _GroupRange) -> Optional[Token]:
        """Slow path: the group's range ran dry. Refill it λ-share-sized
        from the unassigned space, or steal from the largest remaining
        range when the space is exhausted."""
        with self._lock:
            g = self.groups.get(name)
            if g is None:
                return None
            with st.lock:
                if st.lo < st.hi:       # raced with another refill/steal
                    n = min(st.chunk, st.hi - st.lo)
                    lo, st.lo = st.lo, st.lo + n
                    return Token(Chunk(lo, lo + n, sp.next_seq()),
                                 name, g.kind)
            chunk = self.chunk_size_for(name)
            stats = self.tracker.stats(name)
            quota = self._refill_quota_locked()
            if stats is None or stats.n == 0:
                # cold start: λ is still the seed, so a multi-chunk grant
                # would bank work on a guess (a slow group could hoard a
                # λ-share range it then crawls through). One chunk, like
                # the paper path, until the first real measurement.
                want = chunk
            else:
                lam = self.tracker.get(name)
                total_lam = sum(self.tracker.get(n_)
                                for n_ in self.groups) or 1.0
                # λ-share of the remaining space, at least one chunk, at
                # most the refill quota in chunks: big enough to amortize
                # the refill, small enough that a mis-sized grant is
                # cheap to steal back
                want = min(quota * chunk,
                           max(chunk, int(sp.remaining * lam / total_lam)))
                if self.adaptive_refill:
                    tail = sp.remaining
                    n_groups = max(1, len(self.groups))
                    if tail <= quota * chunk * n_groups:
                        # near exhaustion: a full λ-share grant here is
                        # tail hoarding (peers finish and must steal it
                        # back one half at a time) — cap at a fair share
                        want = max(chunk, min(want, tail // n_groups))
            c = sp.take(want)
            if c is None:
                c = self._steal_locked(sp, name, chunk)
                if c is None:
                    return None
                self._steals += 1
                if self.telemetry is not None:
                    self._count("part.steals")
                    self._count("part.stolen_items", c.size)
                    self.telemetry.tracer.instant(
                        "range_steal", tid="partitioner",
                        thief=name, items=c.size)
            else:
                self._refills += 1
                if self.telemetry is not None:
                    self._count("part.refills")
                    self._count("part.refill_items", c.size)
            with st.lock:
                st.chunk = chunk
                st.lo, st.hi = c.begin, c.end
                n = min(chunk, st.hi - st.lo)
                lo, st.lo = st.lo, st.lo + n
            return Token(Chunk(lo, lo + n, c.seq), name, g.kind)

    def _refill_quota_locked(self, min_total: int = 8,
                             low: float = 0.05, high: float = 0.25) -> int:
        """Effective refill size in chunks. Static (``refill_chunks``)
        unless adaptive: after ``min_total`` refill/steal events the
        observed steal rate steers it — ≤ ``low`` doubles the quota
        (grants are landing where the work is; amortize more per
        global-lock acquire), ≥ ``high`` halves it (grants keep getting
        stolen back; stop banking work on stale λ)."""
        if not self.adaptive_refill:
            return self.refill_chunks
        total = self._refills + self._steals
        if total >= min_total:
            rate = self._steals / total
            if rate >= high:
                return max(1, self.refill_chunks // 2)
            if rate <= low:
                return self.refill_chunks * 2
        return self.refill_chunks

    def refill_stats(self) -> Dict[str, float]:
        """Refill/steal event counts + the current effective refill quota
        (chunks) — the adaptive-refill feedback state, for benchmarks and
        tests. Read under the global lock (same consistency contract as
        ``contention_stats``)."""
        with self._lock._lock:
            return {"refills": float(self._refills),
                    "steals": float(self._steals),
                    "refill_quota": float(self._refill_quota_locked())}

    def _steal_locked(self, sp: IterationSpace, name: str,
                      chunk: int) -> Optional[Chunk]:
        """Take the tail half (≥ one chunk) of the largest remaining range
        of another group — exact load balancing at the end of the space,
        where a λ-share grant to a slow group would otherwise straggle."""
        ranges = self._ranges.get(sp)
        if not ranges:
            return None
        victims = sorted(
            ((st.remaining, n) for n, st in ranges.items() if n != name),
            reverse=True)
        for _, victim_name in victims:
            victim = ranges[victim_name]
            with victim.lock:
                avail = victim.hi - victim.lo
                if avail <= 0:
                    continue
                take = avail if avail <= chunk else max(chunk, avail // 2)
                victim.hi -= take
                return Chunk(victim.hi, victim.hi + take, sp.next_seq())
        return None

    # -- introspection ---------------------------------------------------
    def contention_stats(self) -> Dict[str, float]:
        """Global-lock wait time + acquire count. In paper mode every
        token grant goes through it; in range mode only refills, steals,
        requeues, and membership changes do. The pair is read under the
        raw lock so the two fields are from the same acquire (no torn
        snapshot), without the timed wrapper charging the read itself to
        ``wait_s``."""
        with self._lock._lock:
            return {"lock_wait_s": self._lock.wait_s,
                    "lock_acquires": float(self._lock.acquires)}
