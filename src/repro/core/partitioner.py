"""Heterogeneous dynamic partitioner — the paper's §3.2 (Partitioner_H).

Policy (Navarro et al. heuristic, eqs. 3–4):
  * an ACCEL group always receives its tuned optimal chunk G;
  * any other group receives C = G_ref · λ_self / λ_ref, where ref is the
    (fastest) accelerator group — i.e. every chunk is sized to take the same
    wall time as the accelerator's chunk, balancing load while every device
    runs at its throughput-optimal size;
  * if no accelerator exists, chunks are proportional to a base quantum.

The partitioner is work-conserving: it never hands out more iterations than
remain, and the final chunks shrink to exhaust the space exactly (property-
tested in tests/test_properties.py).

The partitioner is *epoch-reusable*: one instance serves successive
iteration spaces on the persistent scheduler runtime. Group membership
(including groups removed by death or elastic leave), the accelerator
reference, and — via the shared ThroughputTracker — the λ-EWMAs all carry
across epochs; ``begin_epoch(space)`` swaps in the next space, and
``next_token``/``requeue`` accept an explicit space so overlapping epochs
(one group draining epoch N while another starts N+1) never mix ranges.
"""
from __future__ import annotations

import threading
from typing import Dict, Optional

from repro.core.throughput import ThroughputTracker
from repro.core.types import Chunk, DeviceKind, GroupSpec, IterationSpace, \
    Token


class HeterogeneousPartitioner:
    def __init__(self, space: IterationSpace, groups: Dict[str, GroupSpec],
                 tracker: ThroughputTracker,
                 base_quantum: int = 256):
        self.space = space
        self.groups = dict(groups)
        self.tracker = tracker
        self.base_quantum = base_quantum
        self._lock = threading.Lock()
        accels = [g for g in self.groups.values()
                  if g.kind == DeviceKind.ACCEL]
        self._ref: Optional[GroupSpec] = accels[0] if accels else None
        for g in self.groups.values():
            tracker.seed(g.name, g.init_throughput)

    # ------------------------------------------------------------------
    def begin_epoch(self, space: IterationSpace) -> None:
        """Epoch reset: install the next iteration space, keeping group
        membership, the accel reference, and (tracker-held) λ state."""
        with self._lock:
            self.space = space

    # ------------------------------------------------------------------
    def add_group(self, spec: GroupSpec) -> None:
        """Elastic join: the group starts receiving λ-proportional chunks."""
        with self._lock:
            self.groups[spec.name] = spec
            self.tracker.seed(spec.name, spec.init_throughput)
            if spec.kind == DeviceKind.ACCEL and self._ref is None:
                self._ref = spec

    def remove_group(self, name: str) -> None:
        """Elastic leave / failure: stop scheduling to the group."""
        with self._lock:
            self.groups.pop(name, None)
            if self._ref is not None and self._ref.name == name:
                accels = [g for g in self.groups.values()
                          if g.kind == DeviceKind.ACCEL]
                self._ref = accels[0] if accels else None

    # ------------------------------------------------------------------
    def chunk_size_for(self, name: str) -> int:
        g = self.groups[name]
        if g.kind == DeviceKind.ACCEL and g.fixed_chunk:
            size = g.fixed_chunk
        elif self._ref is not None and self._ref.fixed_chunk:
            lam_ref = self.tracker.get(self._ref.name)
            lam = self.tracker.get(name)
            size = int(round(self._ref.fixed_chunk * lam
                             / max(lam_ref, 1e-12)))          # eq. (4)
        else:
            # homogeneous fallback: quantum scaled by relative λ
            lams = self.tracker.snapshot()
            mx = max(lams.values()) if lams else 1.0
            size = int(round(self.base_quantum
                             * self.tracker.get(name) / max(mx, 1e-12)))
        size = max(size, g.min_chunk)
        if g.max_chunk:
            size = min(size, g.max_chunk)
        return size

    def next_token(self, name: str,
                   space: Optional[IterationSpace] = None) -> Optional[Token]:
        """Filter₁ body for a device that just became idle. ``space``
        selects the epoch to draw from (defaults to the current one)."""
        with self._lock:
            if name not in self.groups:
                return None
            g = self.groups[name]
            chunk = (space or self.space).take(self.chunk_size_for(name))
            if chunk is None:
                return None
            return Token(chunk, g.name, g.kind)

    def requeue(self, chunk: Chunk,
                space: Optional[IterationSpace] = None) -> None:
        """Fault tolerance: a failed/lost chunk re-enters its space."""
        with self._lock:
            (space or self.space).put_back(chunk)
