"""Core library: the paper's dynamic heterogeneous chunk scheduler.

Paper: "Reducing overheads of dynamic scheduling on heterogeneous chips"
(Corbera et al., 2015), adapted for JAX/TPU fleets — see DESIGN.md §2.
"""
from repro.core.types import (Chunk, ChunkRecord, DeviceKind, GroupSpec,
                              IterationSpace, Token)
from repro.core.locks import TimedLock
from repro.core.throughput import (GroupStats, LockedThroughputTracker,
                                   ThroughputTracker)
from repro.core.partitioner import HeterogeneousPartitioner
from repro.core.chunk_search import SearchTrace, occupancy_seed, search_chunk
from repro.core.overheads import OverheadLedger, OverheadTotals
from repro.core.dispatch import (CallableExecutor, ChunkExecutor,
                                 ChunkFailure, JaxChunkExecutor,
                                 SleepExecutor, try_boost_priority)
from repro.core.scheduler import DynamicScheduler, EpochHandle, \
    ScheduleResult
from repro.core.energy import EnergyModel, EnergyReport, PowerSpec
from repro.core.oracle import BulkScheduler, BulkResult
from repro.core.platforms import IVY, HASWELL, EXYNOS, PLATFORMS, Platform
from repro.core.simulate import SimConfig, SimResult, simulate, run_config, \
    bulk_oracle

__all__ = [
    "Chunk", "ChunkRecord", "DeviceKind", "GroupSpec", "IterationSpace",
    "Token", "ThroughputTracker", "LockedThroughputTracker", "TimedLock",
    "GroupStats", "HeterogeneousPartitioner",
    "SearchTrace", "occupancy_seed", "search_chunk", "OverheadLedger",
    "OverheadTotals", "CallableExecutor", "ChunkExecutor", "ChunkFailure",
    "JaxChunkExecutor", "SleepExecutor", "try_boost_priority",
    "DynamicScheduler", "EpochHandle", "ScheduleResult", "EnergyModel",
    "EnergyReport",
    "PowerSpec", "BulkScheduler", "BulkResult", "IVY", "HASWELL", "EXYNOS",
    "PLATFORMS", "Platform", "SimConfig", "SimResult", "simulate",
    "run_config", "bulk_oracle",
]
