"""Core scheduler datatypes (paper §3.1).

The paper's iteration space is a [begin, end) range of parallel-loop
iterations; chunks are sub-ranges. Tokens mirror the paper's G_token/C_token:
a chunk tagged with the device(-group) that will process it.
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from enum import Enum
from typing import Any, Dict, List, Optional, Tuple


class DeviceKind(str, Enum):
    ACCEL = "accel"     # the paper's GPU: gets the tuned chunk G
    BIG = "big"         # the paper's CPU core / A15: λ-proportional chunks
    LITTLE = "little"   # the paper's A7


#: Latency tiers, best-first. An epoch's (or job's) tier decides queue
#: order everywhere a choice exists: the scheduler's epoch queue, the
#: per-tenant job heaps, and the service's express lane. Rank is the
#: comparison key (lower = more urgent).
TIERS = ("urgent", "standard", "batch")
TIER_RANK = {t: i for i, t in enumerate(TIERS)}


def tier_rank(tier: str) -> int:
    """Rank for a tier name; raises on unknown tiers so a typo'd job spec
    fails at submission, not as a silently mid-priority job."""
    try:
        return TIER_RANK[tier]
    except KeyError:
        raise ValueError(f"unknown tier {tier!r}; expected one of {TIERS}") \
            from None


@dataclass(frozen=True)
class Chunk:
    """A [begin, end) sub-range of the iteration space."""
    begin: int
    end: int
    seq: int = 0                      # monotonically increasing chunk id

    @property
    def size(self) -> int:
        return self.end - self.begin

    def __post_init__(self):
        if self.end < self.begin:
            raise ValueError(f"bad chunk [{self.begin}, {self.end})")


@dataclass(frozen=True)
class Token:
    """G_token / C_token: a chunk routed to a device group."""
    chunk: Chunk
    group: str                        # device-group name
    kind: DeviceKind

    @property
    def is_accel(self) -> bool:
        return self.kind == DeviceKind.ACCEL


@dataclass
class ChunkRecord:
    """Completion record for one processed chunk, with the paper's timestamps.

    Host side  (TBB tick_count analogues):  Tc1 Filter₁ entry, Tc2 submit
    complete (work enqueued on the device), Tc3 host resumed after completion.
    Device side (OpenCL profile analogues): Tg1 transfer-in start, Tg2 kernel
    launch, Tg3 kernel start, Tg4 kernel end / transfer-out start, Tg5 done.
    """
    token: Token
    tc1: float = 0.0
    tc2: float = 0.0
    tc3: float = 0.0
    tg1: float = 0.0
    tg2: float = 0.0
    tg3: float = 0.0
    tg4: float = 0.0
    tg5: float = 0.0
    ok: bool = True
    meta: Dict[str, Any] = field(default_factory=dict)

    @property
    def device_time(self) -> float:
        return self.tg5 - self.tg1

    @property
    def wall_time(self) -> float:
        return self.tc3 - self.tc1

    @property
    def throughput(self) -> float:
        """Effective λ = chunk/T — the paper's eqs (1)–(2); includes transfer
        and launch time, as the paper does (footnote 1)."""
        t = self.device_time if self.device_time > 0 else self.wall_time
        return self.token.chunk.size / max(t, 1e-12)


@dataclass
class GroupSpec:
    """A schedulable device group (the paper's 'computing device')."""
    name: str
    kind: DeviceKind
    # ACCEL groups use a fixed tuned chunk G; others are λ-proportional.
    fixed_chunk: Optional[int] = None
    min_chunk: int = 1                # TBB's ≥100k-cycles guidance analogue
    max_chunk: Optional[int] = None
    init_throughput: float = 1.0      # λ seed before first measurement
    meta: Dict[str, Any] = field(default_factory=dict)


class IterationSpace:
    """Thread-compatible remaining-range tracker (Filter₁'s shared state)."""

    def __init__(self, begin: int, end: int):
        self.begin0, self.end0 = begin, end
        self._next = begin
        self._end = end
        self._seq = itertools.count()

    @property
    def remaining(self) -> int:
        return self._end - self._next

    def next_seq(self) -> int:
        """Mint the next chunk sequence number. Atomic without a lock
        (itertools.count under the GIL), so the partitioner's range-mode
        fast path can tag chunks it carves out of a pre-assigned range
        without touching shared state."""
        return next(self._seq)

    def take(self, n: int) -> Optional[Chunk]:
        if self._next >= self._end:
            return None
        n = max(1, min(n, self._end - self._next))
        c = Chunk(self._next, self._next + n, next(self._seq))
        self._next += n
        return c

    def put_back(self, chunk: Chunk) -> None:
        """Re-queue a failed chunk (fault tolerance). Only supports returning
        the most recently taken trailing range or re-execution bookkeeping —
        we model re-execution by extending the end (work conservation is on
        iteration COUNT, asserted by tests)."""
        self._end += chunk.size
