"""Per-group effective-throughput tracking — the paper's eqs (1)–(2).

λ_G(tG_i) = G / T(tG_i),  λ_C(tC_i) = C(tC_i) / T(tC_i)

The paper uses the previous interval's throughput directly (eq. 3/4). At
fleet scale single-interval estimates are noisy and a slowing group must be
detected quickly (straggler mitigation), so we keep an EWMA with the raw
last-interval value available; ``alpha=1.0`` reproduces the paper exactly.

Sharded hot path (the default ``ThroughputTracker``): ``update`` /
``update_many`` run on every chunk completion, on every dispatcher thread
— the last shared lock on the completion path before this design. The
tracker now keeps one *cell* per (group, updating thread), same
bank-on-hot-path pattern as ``repro.telemetry``'s metric cells: an update
is plain arithmetic on the calling thread's own cell plus ONE atomic
reference store, no shared lock. Readers (``get`` / ``stats`` /
``snapshot`` — refill sizing, admission capacity, straggler observation;
all orders of magnitude rarer than updates) merge the cells: counts and
totals are summed, and the EWMA/last pair comes from the cell holding the
globally newest update (a monotonically increasing write-sequence stamped
into each cell's state tuple — the same merge-by-latest-seq trick the
telemetry gauges use).

Exactness: the scheduler's invariant is single-writer-per-group (a
group's records are fed only by its own dispatcher — stolen ranges
execute under the *thief's* group name), so each group normally has
exactly one live cell and the merged view is bit-identical to the old
single-lock tracker (property-tested in tests/test_policy.py). When a
group's writer thread changes (scheduler rebuild, elastic re-join), the
fresh cell seeds its EWMA chain from the merged view at creation time, so
the EWMA is continuous across the handoff; with ``alpha=1.0`` (paper
mode) the merged EWMA equals the newest record's λ under *any*
interleaving, single-writer or not.

The registration lock (first touch of a group by a thread) is a
``TimedLock``; ``contention_stats()`` exposes its wait time so the
dispatch benchmark can assert the completion path's shared-lock wait is
~0. ``LockedThroughputTracker`` keeps the original single-lock
implementation as the benchmark baseline and the property-test oracle.
"""
from __future__ import annotations

import itertools
import threading
from dataclasses import dataclass, replace
from typing import Dict, List, Optional

from repro.core.locks import TimedLock
from repro.core.types import ChunkRecord

#: global write sequence for merge-by-latest (shared across trackers is
#: fine — only the relative order of one tracker's cells matters)
_WRITE_SEQ = itertools.count(1)

#: cell state tuple layout: (ewma, last, n, total_items, total_time, seq)
_EMPTY = (0.0, 0.0, 0, 0, 0.0, 0)


@dataclass
class GroupStats:
    ewma: float = 0.0
    last: float = 0.0
    n: int = 0
    total_items: int = 0
    total_time: float = 0.0

    @property
    def lifetime(self) -> float:
        return self.total_items / self.total_time if self.total_time else 0.0


class _Cell:
    """One (group, thread) shard. ``data`` is the full state tuple,
    replaced with a single reference store per update — readers load it
    with one reference read, so a merge never sees a torn
    items/time/EWMA combination (the atomicity the old tracker bought
    with its lock). ``chain`` seeds the EWMA continuation when this cell
    takes over a group from a previous writer thread."""

    __slots__ = ("data", "chain")

    def __init__(self, chain: Optional[float] = None):
        self.data = _EMPTY
        self.chain = chain


class ThroughputTracker:
    def __init__(self, alpha: float = 1.0):
        """alpha=1.0 -> paper-faithful (previous interval only)."""
        assert 0.0 < alpha <= 1.0
        self.alpha = alpha
        self._seed: Dict[str, float] = {}
        # group -> every cell ever registered for it (cells of retired
        # threads keep contributing their totals to the merged view)
        self._cells: Dict[str, List[_Cell]] = {}
        self._local = threading.local()
        # registration/read lock — NOT on the update path (a thread
        # touches it once per group it ever updates, then never again)
        self._lock = TimedLock()

    # -- hot path (dispatcher threads) ---------------------------------
    def _cell(self, group: str) -> _Cell:
        try:
            mine = self._local.cells
        except AttributeError:
            mine = self._local.cells = {}
        c = mine.get(group)
        if c is None:
            with self._lock:
                merged = self._merged(group)
                chain = merged.ewma if merged is not None and merged.n \
                    else None
                c = _Cell(chain=chain)
                self._cells.setdefault(group, []).append(c)
            mine[group] = c
        return c

    def update(self, rec: ChunkRecord) -> float:
        lam = rec.throughput
        c = self._cell(rec.token.group)
        ewma, _, n, items, t, _ = c.data
        if n == 0:
            ewma = lam if c.chain is None else \
                self.alpha * lam + (1 - self.alpha) * c.chain
        else:
            ewma = self.alpha * lam + (1 - self.alpha) * ewma
        c.data = (ewma, lam, n + 1, items + rec.token.chunk.size,
                  t + max(rec.device_time, 1e-12), next(_WRITE_SEQ))
        return ewma

    def update_many(self, recs) -> None:
        """Batched update for a whole completion batch: the loop runs on
        thread-local values and publishes ONE state tuple at the end."""
        it = iter(recs)
        first = next(it, None)
        if first is None:
            return
        group = first.token.group
        c = self._cell(group)
        ewma, last, n, items, t, _ = c.data
        a = self.alpha
        for rec in itertools.chain((first,), it):
            g = rec.token.group
            if g != group:              # mixed batch: flush, switch cells
                c.data = (ewma, last, n, items, t, next(_WRITE_SEQ))
                group, c = g, self._cell(g)
                ewma, last, n, items, t, _ = c.data
            lam = rec.throughput
            if n == 0:
                ewma = lam if c.chain is None else \
                    a * lam + (1 - a) * c.chain
            else:
                ewma = a * lam + (1 - a) * ewma
            last = lam
            n += 1
            items += rec.token.chunk.size
            t += max(rec.device_time, 1e-12)
        c.data = (ewma, last, n, items, t, next(_WRITE_SEQ))

    # -- seeds ---------------------------------------------------------
    def seed(self, group: str, lam: float) -> None:
        with self._lock:
            self._seed[group] = lam

    def seed_of(self, group: str) -> float:
        return self._seed.get(group, 1.0)   # GIL-atomic dict read

    # -- merged reads (lock-free) --------------------------------------
    def _merged(self, group: str) -> Optional[GroupStats]:
        """Merge the group's cells WITHOUT the registration lock: the
        cell list only ever grows (list.append is GIL-atomic; a reader
        iterating concurrently at worst misses a cell registered after
        the read began — the same staleness any lock-free snapshot has),
        and each cell's state is one atomic tuple load. ``get`` rides the
        dispatch hot path (chunk sizing on every token grant), so reads
        must be as lock-free as updates."""
        cells = self._cells.get(group)
        if not cells:
            return None
        out = GroupStats()
        best_seq = 0
        for c in cells:
            ewma, last, n, items, t, seq = c.data   # one atomic load
            out.n += n
            out.total_items += items
            out.total_time += t
            if seq > best_seq:                      # newest writer wins
                best_seq = seq
                out.ewma, out.last = ewma, last
        return out

    def get(self, group: str) -> float:
        st = self._merged(group)
        if st is not None and st.n:
            return st.ewma
        return self._seed.get(group, 1.0)

    def measured(self, group: str) -> bool:
        """Whether ``get`` returns a real measurement (vs. a seed)."""
        st = self._merged(group)
        return bool(st is not None and st.n)

    def stats(self, group: str) -> Optional[GroupStats]:
        """Merged view of the group's stats — a fresh object, so callers
        can never mutate tracker state through it."""
        return self._merged(group)

    def snapshot(self) -> Dict[str, float]:
        out = dict(self._seed)              # GIL-atomic dict copy
        for g in list(self._cells):         # GIL-atomic key list
            st = self._merged(g)
            if st is not None and st.n:
                out[g] = st.ewma
        return out

    def contention_stats(self) -> Dict[str, float]:
        """Registration/read-lock wait + acquire count. The completion
        path touches this lock only on a thread's FIRST update for a
        group — steady-state updates never acquire it, which is what the
        dispatch benchmark asserts."""
        return self._lock.stats()


class LockedThroughputTracker:
    """The original single-lock tracker: every update serializes on one
    shared lock. Kept as the dispatch-overhead benchmark baseline and as
    the oracle for the sharded tracker's merge-equivalence property test
    (tests/test_policy.py). Same API as ``ThroughputTracker``."""

    def __init__(self, alpha: float = 1.0):
        assert 0.0 < alpha <= 1.0
        self.alpha = alpha
        self._stats: Dict[str, GroupStats] = {}
        self._seed: Dict[str, float] = {}
        self._lock = TimedLock()

    def seed(self, group: str, lam: float) -> None:
        with self._lock:
            self._seed[group] = lam

    def update(self, rec: ChunkRecord) -> float:
        with self._lock:
            return self._update_locked(rec)

    def update_many(self, recs) -> None:
        with self._lock:
            for rec in recs:
                self._update_locked(rec)

    def _update_locked(self, rec: ChunkRecord) -> float:
        lam = rec.throughput
        st = self._stats.setdefault(rec.token.group, GroupStats())
        st.last = lam
        st.ewma = lam if st.n == 0 else \
            self.alpha * lam + (1 - self.alpha) * st.ewma
        st.n += 1
        st.total_items += rec.token.chunk.size
        st.total_time += max(rec.device_time, 1e-12)
        return st.ewma

    def get(self, group: str) -> float:
        with self._lock:
            st = self._stats.get(group)
            if st and st.n:
                return st.ewma
            return self._seed.get(group, 1.0)

    def measured(self, group: str) -> bool:
        with self._lock:
            st = self._stats.get(group)
            return bool(st is not None and st.n)

    def seed_of(self, group: str) -> float:
        with self._lock:
            return self._seed.get(group, 1.0)

    def stats(self, group: str) -> Optional[GroupStats]:
        with self._lock:
            st = self._stats.get(group)
            return None if st is None else replace(st)

    def snapshot(self) -> Dict[str, float]:
        with self._lock:
            out = dict(self._seed)
            out.update({g: s.ewma for g, s in self._stats.items() if s.n})
            return out

    def contention_stats(self) -> Dict[str, float]:
        """Shared-lock wait + acquires — every update pays it here."""
        return self._lock.stats()
