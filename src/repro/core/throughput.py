"""Per-group effective-throughput tracking — the paper's eqs (1)–(2).

λ_G(tG_i) = G / T(tG_i),  λ_C(tC_i) = C(tC_i) / T(tC_i)

The paper uses the previous interval's throughput directly (eq. 3/4). At
fleet scale single-interval estimates are noisy and a slowing group must be
detected quickly (straggler mitigation), so we keep an EWMA with the raw
last-interval value available; ``alpha=1.0`` reproduces the paper exactly.
"""
from __future__ import annotations

import threading
from dataclasses import dataclass, field, replace
from typing import Dict, Optional

from repro.core.types import ChunkRecord


@dataclass
class GroupStats:
    ewma: float = 0.0
    last: float = 0.0
    n: int = 0
    total_items: int = 0
    total_time: float = 0.0

    @property
    def lifetime(self) -> float:
        return self.total_items / self.total_time if self.total_time else 0.0


class ThroughputTracker:
    def __init__(self, alpha: float = 1.0):
        """alpha=1.0 -> paper-faithful (previous interval only)."""
        assert 0.0 < alpha <= 1.0
        self.alpha = alpha
        self._stats: Dict[str, GroupStats] = {}
        self._seed: Dict[str, float] = {}
        self._lock = threading.Lock()

    def seed(self, group: str, lam: float) -> None:
        with self._lock:
            self._seed[group] = lam

    def update(self, rec: ChunkRecord) -> float:
        with self._lock:
            return self._update_locked(rec)

    def update_many(self, recs) -> None:
        """Batched update: one lock acquisition for a whole completion
        batch (the scheduler's per-worker finalize buffer)."""
        with self._lock:
            for rec in recs:
                self._update_locked(rec)

    def _update_locked(self, rec: ChunkRecord) -> float:
        lam = rec.throughput
        st = self._stats.setdefault(rec.token.group, GroupStats())
        st.last = lam
        st.ewma = lam if st.n == 0 else \
            self.alpha * lam + (1 - self.alpha) * st.ewma
        st.n += 1
        st.total_items += rec.token.chunk.size
        st.total_time += max(rec.device_time, 1e-12)
        return st.ewma

    def get(self, group: str) -> float:
        with self._lock:
            st = self._stats.get(group)
            if st and st.n:
                return st.ewma
            return self._seed.get(group, 1.0)

    def measured(self, group: str) -> bool:
        """Whether ``get`` returns a real measurement (vs. a seed)."""
        with self._lock:
            st = self._stats.get(group)
            return bool(st is not None and st.n)

    def seed_of(self, group: str) -> float:
        with self._lock:
            return self._seed.get(group, 1.0)

    def stats(self, group: str) -> Optional[GroupStats]:
        """A *copy* of the group's stats taken under the lock — returning
        the live object would let a reader see torn ``total_items`` /
        ``total_time`` pairs mid-update."""
        with self._lock:
            st = self._stats.get(group)
            return None if st is None else replace(st)

    def snapshot(self) -> Dict[str, float]:
        with self._lock:
            out = dict(self._seed)
            out.update({g: s.ewma for g, s in self._stats.items() if s.n})
            return out
