"""Bulk-Oracle baseline (paper §2) for real execution.

Static split: the accelerator group gets one bulk chunk of ``frac·N`` at the
start; the other groups dynamically share the rest. The *oracle* variant
sweeps ``frac`` offline (0..100% in 10% steps, as the paper does) and keeps
the best run.
"""
from __future__ import annotations

import threading
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional

from repro.core.dispatch import ChunkExecutor, clock
from repro.core.overheads import OverheadLedger
from repro.core.throughput import ThroughputTracker
from repro.core.types import Chunk, ChunkRecord, DeviceKind, GroupSpec, \
    IterationSpace, Token


@dataclass
class BulkResult:
    total_time: float
    frac: float
    records: List[ChunkRecord]
    per_group_items: Dict[str, int]


class BulkScheduler:
    """One static-split run."""

    def __init__(self, groups: Dict[str, GroupSpec],
                 executors: Dict[str, ChunkExecutor],
                 cpu_quantum: Optional[int] = None):
        self.specs = dict(groups)
        self.executors = dict(executors)
        self.cpu_quantum = cpu_quantum
        accels = [g for g in self.specs.values()
                  if g.kind == DeviceKind.ACCEL]
        assert len(accels) == 1, "BulkScheduler expects exactly one accel"
        self.accel = accels[0]

    def run(self, begin: int, end: int, frac: float) -> BulkResult:
        n = end - begin
        n_accel = int(n * frac)
        records: List[ChunkRecord] = []
        lock = threading.Lock()
        space = IterationSpace(begin + n_accel, end)
        quantum = self.cpu_quantum or max(
            1, (n - n_accel) // max(1, 8 * (len(self.specs) - 1) or 1))

        def run_one(name: str, token: Token):
            ex = self.executors[name]
            rec = ChunkRecord(token, tc1=clock(), tc2=clock())
            done = ex.execute(token, rec)
            done += ex.drain()
            t = clock()
            for r in done:
                r.tc3 = t
            with lock:
                records.extend(done)

        def accel_worker():
            if n_accel:
                tok = Token(Chunk(begin, begin + n_accel, 0),
                            self.accel.name, DeviceKind.ACCEL)
                run_one(self.accel.name, tok)

        def cpu_worker(name: str):
            ex = self.executors[name]
            while True:
                c = space.take(quantum)
                if c is None:
                    break
                tok = Token(c, name, self.specs[name].kind)
                rec = ChunkRecord(tok, tc1=clock(), tc2=clock())
                done = ex.execute(tok, rec)
                t = clock()
                for r in done:
                    r.tc3 = t
                with lock:
                    records.extend(done)
            with lock:
                records.extend(ex.drain())

        t0 = clock()
        threads = [threading.Thread(target=accel_worker, daemon=True)]
        for name, g in self.specs.items():
            if g.kind != DeviceKind.ACCEL:
                threads.append(threading.Thread(
                    target=cpu_worker, args=(name,), daemon=True))
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        total = clock() - t0
        items: Dict[str, int] = {}
        for r in records:
            items[r.token.group] = items.get(r.token.group, 0) \
                + r.token.chunk.size
        return BulkResult(total, frac, records, items)

    def oracle(self, begin: int, end: int, step: float = 0.1) -> BulkResult:
        best = None
        f = 0.0
        while f <= 1.0001:
            r = self.run(begin, end, f)
            if best is None or r.total_time < best.total_time:
                best = r
            f += step
        return best
