"""Calibrated platform models for the paper's three testbeds (§4.1).

Where the paper reports a number we use it directly (G, kernel-launch times,
transfer overhead fractions, Bulk-Oracle optimal splits, OS policy). Where it
reports only ratios (throughputs are never absolute for the Intel boxes) we
pick a scale and calibrate the free parameters so the paper's *measured
baselines* come out (Table 1, Fig. 5); the simulator then *predicts* the
optimization results (Fig. 2/6/7), which is what tests/test_paper_claims.py
asserts. Calibrated-vs-paper values are tabulated in EXPERIMENTS.md.

Throughput ratios derived from Table 1 (Bulk-Oracle split p with 3 cores):
λ_G/λ_C = 3p/(1-p):  Ivy p=50% → 3.0 ; Haswell p=70% → 7.0 ; Exynos p=20% → 0.75.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict

from repro.core.energy import PowerSpec


@dataclass(frozen=True)
class AccelCurve:
    """λ(chunk) for the accelerator: occupancy ramp below c_occ, cache-miss
    penalty beyond the knee (paper Fig. 1), floored (calibrated vs Fig. 2)."""
    peak: float                  # iters/ms at the sweet spot
    c_occ: int                   # minimal fully-occupying chunk (§3.2 seed)
    knee: int                    # chunk size where L3 misses start to bite
    floor: float                 # min fraction of peak at huge chunks

    def __call__(self, chunk: int) -> float:
        import math
        occ = min(1.0, chunk / self.c_occ)
        pen = 1.0
        if chunk > self.knee:
            pen = max(self.floor,
                      1.0 / (1.0 + 0.15 * math.log2(chunk / self.knee)))
        return self.peak * occ * pen


@dataclass(frozen=True)
class Platform:
    name: str
    n_big: int                   # CPU cores (A15s on Exynos)
    n_little: int
    lam_big: float               # iters/ms per big core (calibration scale)
    lam_little: float
    accel: AccelCurve
    G_opt: int                   # Table 1 tuned GPU chunk
    bulk_frac: Dict[str, float]  # Table 1 Bulk-Oracle optimal % {cfg: frac}
    t_kl_ms: float               # §4.2 measured kernel-launch time
    t_hd_ms: float               # per-chunk host->device time
    t_dh_ms: float
    os_policy: str               # "rr" (Windows) | "fair" (Linux wake boost)
    td_wait_ms: float            # calibrated RR dispatch wait (Fig. 5)
    td_wait_fair_ms: float = 0.0 # residual wake delay under fair+oversub
    eps_ms: float = 0.05         # context-switch / boosted-dispatch latency
    sp_ms: float = 0.01          # scheduling+partitioning per chunk (O_sp)
    power: Dict[str, PowerSpec] = field(default_factory=dict)
    base_w: float = 0.0


IVY = Platform(
    name="ivy",
    n_big=4, n_little=0,
    lam_big=25.0, lam_little=0.0,
    accel=AccelCurve(peak=75.0, c_occ=1536, knee=1536, floor=0.87),
    G_opt=1536,
    bulk_frac={"3+1": 0.5, "4+1": 0.4},
    t_kl_ms=1.8, t_hd_ms=0.05, t_dh_ms=0.05,
    os_policy="rr", td_wait_ms=6.3,
    power={"big": PowerSpec(11.0, 1.5), "accel": PowerSpec(15.0, 3.0)},
    base_w=10.0,
)

HASWELL = Platform(
    name="haswell",
    n_big=4, n_little=0,
    lam_big=22.0, lam_little=0.0,
    accel=AccelCurve(peak=154.0, c_occ=2048, knee=2048, floor=0.97),
    G_opt=2048,
    bulk_frac={"3+1": 0.7, "4+1": 0.7},
    t_kl_ms=1.0, t_hd_ms=0.05, t_dh_ms=0.05,
    os_policy="rr", td_wait_ms=7.1,
    power={"big": PowerSpec(12.0, 1.5), "accel": PowerSpec(14.0, 3.0)},
    base_w=10.0,
)

EXYNOS = Platform(
    name="exynos",
    n_big=4, n_little=4,
    lam_big=30.0, lam_little=12.0,
    accel=AccelCurve(peak=22.5, c_occ=2048, knee=2048, floor=0.9),
    G_opt=2048,
    bulk_frac={"3+1": 0.2, "4+1": 0.2, "7+1": 0.2, "8+1": 0.2},
    t_kl_ms=3.6, t_hd_ms=2.7, t_dh_ms=1.6,
    os_policy="fair", td_wait_ms=0.05, td_wait_fair_ms=1.5,
    power={"big": PowerSpec(1.6, 0.0125), "little": PowerSpec(0.15, 0.0125),
           "accel": PowerSpec(1.5, 0.15)},
    base_w=0.35,
)

PLATFORMS = {"ivy": IVY, "haswell": HASWELL, "exynos": EXYNOS}

# Paper workload: Barnes-Hut force phase, 100k bodies.
N_BODIES = 100_000
TIMESTEPS_FIG2 = 75
TIMESTEPS_FIG5 = 15
