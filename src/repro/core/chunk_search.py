"""Accelerator chunk-size search — the paper's §3.2 training phase.

Start from the smallest chunk that fully occupies the accelerator (the paper
reads CL_DEVICE_MAX_COMPUTE_UNITS × PREFERRED_WORK_GROUP_SIZE_MULTIPLE; our
TPU analogue is cores × per-dispatch occupancy quantum, e.g. the number of
sequences that saturate the MXU pipeline for one microbatch). Then try
multiples while throughput improves; stop when it decreases or stays flat
for ``patience`` sizes; return the argmax.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, List, Tuple


@dataclass
class SearchTrace:
    tried: List[Tuple[int, float]] = field(default_factory=list)
    best_chunk: int = 0
    best_lambda: float = 0.0


def occupancy_seed(n_units: int, per_unit_quantum: int) -> int:
    """The paper's initial chunk: #compute-units × preferred multiple."""
    return max(1, n_units * per_unit_quantum)


def search_chunk(measure: Callable[[int], float], seed: int,
                 *, multiples: int = 64, patience: int = 2,
                 rel_tol: float = 0.02, max_chunk: int = 1 << 22) \
        -> SearchTrace:
    """measure(chunk) -> effective throughput λ (items/s), including transfer
    and dispatch overheads (paper footnote 1). Returns the search trace."""
    tr = SearchTrace()
    flat = 0
    for k in range(1, multiples + 1):
        c = seed * k
        if c > max_chunk:
            break
        lam = measure(c)
        tr.tried.append((c, lam))
        if lam > tr.best_lambda * (1 + rel_tol):
            tr.best_chunk, tr.best_lambda = c, lam
            flat = 0
        else:
            flat += 1
            if flat >= patience:
                break
    if tr.best_chunk == 0 and tr.tried:
        tr.best_chunk, tr.best_lambda = max(tr.tried, key=lambda t: t[1])
    return tr
