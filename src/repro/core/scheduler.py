"""The Dynamic scheduler — the paper's §3.1 two-filter pipeline as a
thread-per-device-group runtime.

Each device group gets a host (dispatcher) thread. The thread repeatedly:
  Filter₁: asks the partitioner for a token (device pick + chunk extraction),
           timestamped Tc1→Tc2;
  Filter₂: hands the token to the group's executor (which fills the device
           timestamps Tg1..Tg5), finalizes at Tc3, and feeds the throughput
           tracker and overhead ledger.

Fault tolerance: a ChunkFailure re-queues the in-flight chunk and removes the
group; remaining groups absorb the work (work conservation is property-
tested). Elasticity: add_group() mid-run spawns a new dispatcher thread.
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional

from repro.core.dispatch import ChunkExecutor, ChunkFailure, clock
from repro.core.overheads import OverheadLedger
from repro.core.partitioner import HeterogeneousPartitioner
from repro.core.throughput import ThroughputTracker
from repro.core.types import ChunkRecord, GroupSpec, IterationSpace


@dataclass
class ScheduleResult:
    total_time: float
    iterations: int
    records: List[ChunkRecord]
    overheads: Dict[str, Dict[str, float]]
    throughput: Dict[str, float]
    per_group_items: Dict[str, int]
    failed_groups: List[str] = field(default_factory=list)

    def busy_seconds(self) -> Dict[str, float]:
        busy: Dict[str, float] = {}
        for r in self.records:
            busy[r.token.group] = busy.get(r.token.group, 0.0) \
                + max(r.device_time, 0.0)
        return busy


class DynamicScheduler:
    def __init__(self, groups: Dict[str, GroupSpec],
                 executors: Dict[str, ChunkExecutor],
                 alpha: float = 1.0, base_quantum: int = 256):
        assert set(groups) == set(executors)
        self.specs = dict(groups)
        self.executors = dict(executors)
        self.alpha = alpha
        self.base_quantum = base_quantum
        self.tracker = ThroughputTracker(alpha)
        self.ledger = OverheadLedger()
        self._threads: Dict[str, threading.Thread] = {}
        self._records: List[ChunkRecord] = []
        self._rec_lock = threading.Lock()
        self._failed: List[str] = []
        self.partitioner: Optional[HeterogeneousPartitioner] = None

    # ------------------------------------------------------------------
    def _worker(self, name: str):
        ex = self.executors[name]
        part = self.partitioner
        try:
            ex.on_worker_start()
        except Exception:
            pass
        try:
            while True:
                tc1 = clock()
                token = part.next_token(name)
                tc2 = clock()
                if token is None:
                    break
                rec = ChunkRecord(token, tc1=tc1, tc2=tc2)
                try:
                    done = ex.execute(token, rec)
                except ChunkFailure:
                    part.requeue(token.chunk)
                    part.remove_group(name)
                    with self._rec_lock:
                        self._failed.append(name)
                    return
                self._finalize(done)
            self._finalize(ex.drain())
        except Exception:
            # unexpected executor error: fail the group, requeue nothing more
            part.remove_group(name)
            with self._rec_lock:
                self._failed.append(name)
            raise

    def _finalize(self, recs: List[ChunkRecord]):
        t = clock()
        for rec in recs:
            rec.tc3 = t if rec.tc3 == 0.0 else rec.tc3
            self.tracker.update(rec)
            self.ledger.add(rec)
            with self._rec_lock:
                self._records.append(rec)

    # ------------------------------------------------------------------
    def add_group(self, spec: GroupSpec, executor: ChunkExecutor):
        """Elastic scale-up during run()."""
        self.specs[spec.name] = spec
        self.executors[spec.name] = executor
        if self.partitioner is not None:
            self.partitioner.add_group(spec)
            th = threading.Thread(target=self._worker, args=(spec.name,),
                                  name=f"dispatch-{spec.name}", daemon=True)
            self._threads[spec.name] = th
            th.start()

    def run(self, begin: int, end: int) -> ScheduleResult:
        space = IterationSpace(begin, end)
        self.partitioner = HeterogeneousPartitioner(
            space, self.specs, self.tracker, self.base_quantum)
        t0 = clock()
        for name in list(self.specs):
            th = threading.Thread(target=self._worker, args=(name,),
                                  name=f"dispatch-{name}", daemon=True)
            self._threads[name] = th
            th.start()
        while True:
            alive = [t for t in list(self._threads.values()) if t.is_alive()]
            if not alive:
                break
            alive[0].join(timeout=0.05)
        total = clock() - t0
        per_items: Dict[str, int] = {}
        for r in self._records:
            per_items[r.token.group] = per_items.get(r.token.group, 0) \
                + r.token.chunk.size
        overheads = {g: self.ledger.report(total, g)
                     for g in self.ledger.groups()}
        overheads["all"] = self.ledger.report(total)
        return ScheduleResult(
            total_time=total,
            iterations=sum(per_items.values()),
            records=list(self._records),
            overheads=overheads,
            throughput=self.tracker.snapshot(),
            per_group_items=per_items,
            failed_groups=list(self._failed),
        )
