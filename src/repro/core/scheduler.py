"""The Dynamic scheduler — the paper's §3.1 two-filter pipeline as a
*persistent* thread-per-device-group runtime.

Each device group gets a long-lived host (dispatcher) thread. Threads block
on an epoch queue and process successive IterationSpaces without teardown,
so the per-batch cost the paper attributes to the host side (thread
creation/wake-up, O_td, scheduler construction) is paid once per runtime,
not once per batch:

  start()                      spawn dispatcher threads once
  submit_epoch(space) -> EpochHandle
                               enqueue an iteration space; workers pick it
                               up as soon as their previous epoch's space
                               is exhausted (epochs overlap: a fast group
                               starts epoch N+1 while a slow group is still
                               draining epoch N — no global barrier)
  shutdown()                   drain queued epochs, then join threads
  run(begin, end)              one-shot compat wrapper (auto start; auto
                               shutdown if this call started the runtime)

Within an epoch each thread repeatedly runs the paper's pipeline:
  Filter₁: asks the partitioner for a token (device pick + chunk extraction),
           timestamped Tc1→Tc2;
  Filter₂: hands the token to the group's executor (which fills the device
           timestamps Tg1..Tg5), finalizes at Tc3, and feeds the throughput
           tracker and overhead ledger.

λ-EWMAs (ThroughputTracker), the partitioner's group membership, and
dead-group knowledge all live at runtime scope and carry across epochs.

Fault tolerance: a ChunkFailure re-queues the in-flight chunk(s) and removes
the group from the runtime (specs, executors, partitioner) — it stays
excluded in later epochs; remaining groups absorb the work (work
conservation is property-tested). Elasticity: add_group() mid-run spawns a
new dispatcher thread that joins the oldest open epoch; remove_group()
drains a group out everywhere.
"""
from __future__ import annotations

import collections
import logging
import threading
import traceback
from dataclasses import dataclass, field
from typing import Deque, Dict, List, Optional, Tuple, Union

from repro import telemetry as telemetry_mod
from repro.core.dispatch import ChunkExecutor, ChunkFailure, clock
from repro.core.overheads import OverheadLedger
from repro.core.partitioner import HeterogeneousPartitioner
from repro.core.throughput import ThroughputTracker
from repro.core.types import ChunkRecord, GroupSpec, IterationSpace, \
    tier_rank

logger = logging.getLogger(__name__)

#: rank sentinel meaning "no runnable epoch": above every real tier rank,
#: so the preempt check `_preempt_rank < epoch.rank` is always False
_NO_RANK = 1 << 10


@dataclass
class ScheduleResult:
    total_time: float
    iterations: int
    records: List[ChunkRecord]
    overheads: Dict[str, Dict[str, float]]
    throughput: Dict[str, float]
    per_group_items: Dict[str, int]
    failed_groups: List[str] = field(default_factory=list)
    # latency-tier support: a cooperatively cancelled epoch finalizes with
    # ``cancelled=True`` and its undone tail in ``unfinished`` (completed
    # + unfinished == submitted items when no chunk re-executed), so the
    # service can requeue exactly what was cut off
    cancelled: bool = False
    cancel_reason: str = ""
    unfinished: int = 0

    def busy_seconds(self) -> Dict[str, float]:
        busy: Dict[str, float] = {}
        for r in self.records:
            busy[r.token.group] = busy.get(r.token.group, 0.0) \
                + max(r.device_time, 0.0)
        return busy


class EpochHandle:
    """Ticket for one submitted IterationSpace on the persistent runtime.

    ``submitted_at`` / ``started_at`` (first token handed out) /
    ``finished_at`` are monotonic-clock stamps; the gap between one epoch's
    ``finished_at`` and the next epoch's ``started_at`` is the batch-boundary
    overhead benchmarks/batch_boundary.py measures.

    ``priority`` is a latency tier (core.types.TIERS): dispatchers always
    pick the best-(rank, index) open epoch with takeable work, so an
    urgent epoch jumps queued standard/batch work and *preempts* running
    lower-tier epochs at their next chunk boundary. ``deadline_s`` is an
    absolute scheduler-clock deadline; blowing it cancels the epoch
    cooperatively (see DynamicScheduler.cancel_epoch).
    """

    def __init__(self, index: int, space: IterationSpace,
                 priority: str = "standard",
                 deadline_s: Optional[float] = None,
                 now: Optional[float] = None):
        self.index = index
        self.space = space
        self.priority = priority
        self.rank = tier_rank(priority)
        self.deadline_s = deadline_s
        self.cancelled = False
        self.cancel_reason: Optional[str] = None
        self.submitted_at = now if now is not None else clock()
        self.started_at: Optional[float] = None
        self.finished_at: Optional[float] = None
        self.ledger = OverheadLedger()          # per-epoch §3.3 fractions
        self.ledger.keep_records = False        # records live in _records
        self._records: List[ChunkRecord] = []
        self._failed: List[str] = []
        self._event = threading.Event()
        self._result: Optional[ScheduleResult] = None
        self._cb_lock = threading.Lock()
        self._callbacks: List = []

    @property
    def finalized(self) -> bool:
        return self._event.is_set()

    def add_done_callback(self, fn) -> None:
        """Call ``fn(self)`` when the epoch finalizes (immediately if it
        already has). Callbacks run on the finalizing dispatcher thread
        while the runtime lock is held, so they must be cheap and
        non-blocking — setting an event, bumping a counter. The JobService
        drain loop uses this for event-driven wakeups on completion."""
        with self._cb_lock:
            if not self._event.is_set():
                self._callbacks.append(fn)
                return
        fn(self)

    def done(self) -> bool:
        return self._event.is_set()

    def wait(self, timeout: Optional[float] = None) -> bool:
        return self._event.wait(timeout)

    def result(self, timeout: Optional[float] = None) -> ScheduleResult:
        if not self._event.wait(timeout):
            raise TimeoutError(f"epoch {self.index} still in flight")
        return self._result


class DynamicScheduler:
    def __init__(self, groups: Dict[str, GroupSpec],
                 executors: Dict[str, ChunkExecutor],
                 alpha: float = 1.0, base_quantum: int = 256,
                 chunk_mode: str = "range", finalize_batch: int = 8,
                 telemetry=None, clock=None, adaptive_refill: bool = True):
        assert set(groups) == set(executors)
        self.specs = dict(groups)
        self.executors = dict(executors)
        # history-driven refill sizing (see HeterogeneousPartitioner.
        # _refill_quota_locked) — on by default for the runtime; "paper"
        # chunk mode takes per-token grants and never consults the quota,
        # so bit-compatibility is unaffected either way
        self.adaptive_refill = adaptive_refill
        # injectable time source (tests/clock.py VirtualClock): every
        # scheduler-side stamp and deadline comparison goes through it
        self.clock = clock if clock is not None else globals()["clock"]
        self.alpha = alpha
        self.base_quantum = base_quantum
        self.chunk_mode = chunk_mode
        # always-on observability: None → the process-wide default
        # Telemetry; repro.telemetry.OFF → uninstrumented (the
        # benchmarks/telemetry_overhead.py baseline). The dispatch hot
        # path only *banks* finished completion batches (one GIL-atomic
        # deque append per finalize batch); the per-record work —
        # histograms, counters, chunk spans — runs in _tel_drain on the
        # snapshot reader's thread, so instrumentation adds neither
        # shared-lock contention nor per-chunk GIL pressure.
        self.telemetry = telemetry_mod.resolve(telemetry)
        self._tel_group: Dict[str, tuple] = {}
        # banked (epoch_index, records) batches awaiting ingestion;
        # bounded so a daemon nobody ever snapshots cannot pin every
        # ChunkRecord forever — overflow evicts oldest (counted)
        self._tel_pending: collections.deque = collections.deque(
            maxlen=8192)
        self._tel_lost = 0
        if self.telemetry is not None:
            self.telemetry.registry.add_collector(self._tel_drain)
        # per-worker completion buffers flush into the (locked) tracker /
        # ledgers every finalize_batch records instead of per record;
        # paper mode keeps the original record-at-a-time behavior
        self.finalize_batch = 1 if chunk_mode == "paper" \
            else max(1, finalize_batch)
        self.tracker = ThroughputTracker(alpha)
        self.ledger = OverheadLedger()          # cumulative, runtime lifetime
        self.ledger.keep_records = False        # fractions only: a runtime-
        # lifetime record list would grow without bound on a serve daemon
        # (per-epoch records live in each ScheduleResult)
        self.partitioner: Optional[HeterogeneousPartitioner] = None
        self._threads: Dict[str, threading.Thread] = {}
        self._cv = threading.Condition()
        # open (and recently finalized) epochs; finalized handles are
        # pruned from the front once every worker is past them, so a
        # long-lived daemon does not accumulate one handle per batch.
        # _epoch_base is the absolute index of _epochs[0].
        self._epochs: Deque[EpochHandle] = collections.deque()
        self._epoch_base = 0
        # name -> index of the next epoch the dispatcher will work on; an
        # epoch E may finalize only once every live worker's position is
        # past E (otherwise a thread that has not reached E yet could still
        # absorb E's requeued work)
        self._worker_pos: Dict[str, int] = {}
        # best (lowest) tier rank among open epochs with takeable work —
        # the lock-free preemption hint workers read at every chunk
        # boundary. Recomputed under _cv at every queue-shape change and
        # repaired by _await_epoch, so staleness only costs a spurious
        # drain/re-enter, never a missed wakeup.
        self._preempt_rank = _NO_RANK
        self._failed: List[str] = []
        self._started = False
        self._shutdown = False

    # -- runtime lifecycle ---------------------------------------------
    def start(self) -> None:
        """Spawn the dispatcher threads (idempotent)."""
        with self._cv:
            if self._started:
                return
            self._started = True
            # the partitioner is runtime-scoped: group membership, the
            # accel reference, and (via the shared tracker) λ-EWMAs carry
            # across epochs; each epoch swaps in a fresh space
            self.partitioner = HeterogeneousPartitioner(
                IterationSpace(0, 0), self.specs, self.tracker,
                self.base_quantum, chunk_mode=self.chunk_mode,
                adaptive_refill=self.adaptive_refill,
                telemetry=self.telemetry
                if self.telemetry is not None else telemetry_mod.OFF)
            for name in list(self.specs):
                self._spawn_locked(name, 0)

    def _spawn_locked(self, name: str, start_idx: int) -> None:
        self._worker_pos[name] = start_idx
        th = threading.Thread(target=self._worker, args=(name, start_idx),
                              name=f"dispatch-{name}", daemon=True)
        self._threads[name] = th
        th.start()

    def submit_epoch(self, space: Union[IterationSpace, Tuple[int, int]],
                     priority: str = "standard",
                     deadline_s: Optional[float] = None) -> EpochHandle:
        """Enqueue an iteration space for the dispatcher threads.

        ``priority`` is a latency tier (``urgent``/``standard``/``batch``):
        dispatchers enter the best-(rank, submission-order) open epoch
        with work, so an urgent epoch overtakes queued lower-tier epochs
        and pulls workers out of running ones at their next chunk
        boundary. ``deadline_s`` is an absolute deadline on this
        scheduler's clock; an epoch past it is cancelled cooperatively
        and finalizes with its unfinished tail counted."""
        if isinstance(space, tuple):
            space = IterationSpace(*space)
        self.start()
        with self._cv:
            if self._shutdown:
                raise RuntimeError("scheduler runtime is shut down")
            handle = EpochHandle(self._epoch_base + len(self._epochs),
                                 space, priority=priority,
                                 deadline_s=deadline_s, now=self.clock())
            self._epochs.append(handle)
            self.partitioner.begin_epoch(space)
            self._recompute_preempt_locked()
            if self.telemetry is not None:
                self.telemetry.registry.counter(
                    "sched.epochs_submitted", tier=priority).add()
                self.telemetry.tracer.instant(
                    "epoch_submit", tid="epochs", epoch=handle.index,
                    items=space.remaining, tier=priority)
            if not self._worker_pos:        # every group already dead
                self._finalize_epoch_locked(handle)
                self._prune_epochs_locked()
            self._cv.notify_all()
        return handle

    def cancel_epoch(self, handle: EpochHandle,
                     reason: str = "cancelled") -> bool:
        """Cooperatively cancel an epoch: flag it, reclaim every group's
        unconsumed private range back into its space (the unfinished tail
        then shows up as ``space.remaining`` → ``result.unfinished``), and
        wake the dispatchers — workers inside notice at their next chunk
        boundary, wind down the executor pipeline (completing what is
        already finished, requeueing the rest), and leave. Completed work
        is never retracted: returns False if the epoch already finalized
        (or was already cancelled), and a chunk in flight at the flag
        check completes and is counted (cancellation is chunk-granular).
        """
        with self._cv:
            if handle.finalized or handle.cancelled:
                return False
            handle.cancelled = True
            handle.cancel_reason = reason
            if self.partitioner is not None:
                self.partitioner.reclaim_space(handle.space)
            self._recompute_preempt_locked()
            self._maybe_finalize_locked(handle)
            self._prune_epochs_locked()
            self._cv.notify_all()
        if self.telemetry is not None:
            self.telemetry.registry.counter("sched.epochs_cancelled",
                                            reason=reason).add()
            self.telemetry.tracer.instant(
                "epoch_cancel", tid="epochs", epoch=handle.index,
                reason=reason, tier=handle.priority,
                unfinished=handle.space.remaining)
        return True

    def shutdown(self, wait: bool = True) -> None:
        """Drain queued epochs, then stop and join dispatcher threads."""
        with self._cv:
            if not self._started:
                return
            self._shutdown = True
            self._cv.notify_all()
            threads = list(self._threads.values())
        if wait:
            for th in threads:
                th.join(timeout=30.0)
        with self._cv:
            for h in self._epochs:          # workers died / none left
                if not h.finalized:
                    self._finalize_epoch_locked(h)
        if self.telemetry is not None:
            # flush banked completion batches now: once this scheduler is
            # dropped its weak collector dies and they would be lost to
            # any later exporter snapshot
            self._tel_drain()

    # -- introspection -------------------------------------------------
    def dispatchers(self) -> Dict[str, threading.Thread]:
        """Live view of the dispatcher threads (for reuse assertions)."""
        with self._cv:
            return dict(self._threads)

    def live_groups(self) -> List[str]:
        with self._cv:
            return list(self.specs)

    @property
    def failed_groups(self) -> List[str]:
        with self._cv:
            return list(self._failed)

    # -- compat one-shot API -------------------------------------------
    def run(self, begin: int, end: int) -> ScheduleResult:
        """One-shot wrapper: submit a single epoch and wait for it.

        If this call started the runtime it also shuts it down, preserving
        the pre-persistent contract (no threads outlive the call); on an
        already-started runtime the threads are reused and stay up.
        """
        was_started = self._started
        handle = self.submit_epoch(IterationSpace(begin, end))
        res = handle.result()
        if not was_started:
            self.shutdown()
        return res

    # -- elasticity ----------------------------------------------------
    def add_group(self, spec: GroupSpec, executor: ChunkExecutor) -> None:
        """Elastic scale-up: the newcomer joins the oldest open epoch."""
        with self._cv:
            self.specs[spec.name] = spec
            self.executors[spec.name] = executor
            if not self._started or self._shutdown:
                return
            self.partitioner.add_group(spec)
            start_idx = next((h.index for h in self._epochs
                              if not h.finalized),
                             self._epoch_base + len(self._epochs))
            self._spawn_locked(spec.name, start_idx)
            self._cv.notify_all()

    def remove_group(self, name: str) -> None:
        """Elastic leave: remove the group everywhere (specs, executors,
        partitioner); its dispatcher thread drains and exits."""
        with self._cv:
            self.specs.pop(name, None)
            self.executors.pop(name, None)
            if self.partitioner is not None:
                self.partitioner.remove_group(name)
            self._cv.notify_all()

    # -- dispatcher thread ---------------------------------------------
    def _worker(self, name: str, start_idx: int) -> None:
        ex = self.executors.get(name)
        if ex is None:                      # removed before first epoch
            self._retire_worker(name)
            return
        try:
            ex.on_worker_start()
        except Exception:
            pass
        idx = start_idx
        epoch: Optional[EpochHandle] = None
        try:
            while True:
                epoch = self._await_epoch(name, idx)
                if epoch is None:
                    break
                idx = epoch.index + 1
                if not self._run_epoch(name, ex, epoch):
                    break                   # group failed: thread retires
        except BaseException as e:
            self._dispatcher_guard(name, epoch, e)
        finally:
            self._retire_worker(name)

    def _dispatcher_guard(self, name: str, epoch: Optional["EpochHandle"],
                          err: BaseException) -> None:
        """Last-resort handler for a non-ChunkFailure escape from a
        dispatcher thread: convert it to group death through the normal
        death path instead of a silent thread exit. Without this a
        poisoned executor (raising outside the in-band protocol) left
        the group registered but unserved, so every epoch touching it
        stalled forever. The traceback lands in the log and telemetry."""
        tb = traceback.format_exc()
        logger.error("dispatcher thread for group %r died: %s", name, tb)
        if name in self.specs:              # not yet marked by _run_epoch
            self._mark_failed(name, epoch)
        if self.telemetry is not None:
            self.telemetry.registry.counter(
                "sched.dispatcher_errors", group=name).add()
            self.telemetry.tracer.instant(
                "dispatcher_error", tid="events", group=name,
                error=repr(err), traceback=tb[-2000:])

    def _best_open_locked(self) -> Optional[EpochHandle]:
        """Best-(tier rank, submission order) open epoch with takeable
        work — where an idle dispatcher should go. "Takeable" includes
        another group's unconsumed private range (the end-of-space steal
        source), so priority never disables work stealing."""
        part = self.partitioner
        best = None
        for h in self._epochs:
            if h.finalized or h.cancelled:
                continue
            if best is not None and h.rank >= best.rank:
                continue                    # _epochs is submission-ordered
            if h.space.remaining > 0 or (part is not None
                                         and part.has_work(h.space)):
                best = h
        return best

    def _recompute_preempt_locked(self) -> None:
        best = self._best_open_locked()
        self._preempt_rank = best.rank if best is not None else _NO_RANK

    def _await_epoch(self, name: str, idx: int) -> Optional[EpochHandle]:
        """Block until an epoch is available; None on shutdown / group
        removal. Entering is atomic with the finalized check so no
        records land on a finalized epoch.

        Epoch choice is priority-first: the best-(rank, index) open epoch
        with takeable work wins, wherever it sits relative to this
        worker's last position — an urgent epoch submitted late overtakes
        queued standard work, and a worker *revisits* an older open epoch
        whose space regained work (a failure requeued chunks after this
        worker had already left it). With no runnable epoch the worker
        walks forward past finalized ones so exhausted-but-open epochs
        behind it can finalize."""
        with self._cv:
            while True:
                if name not in self.specs:
                    return None
                idx = max(idx, self._epoch_base)
                best = self._best_open_locked()
                self._preempt_rank = best.rank if best is not None \
                    else _NO_RANK
                if best is not None:
                    idx = best.index
                else:
                    while idx - self._epoch_base < len(self._epochs) \
                            and self._epochs[idx
                                             - self._epoch_base].finalized:
                        idx += 1
                self._worker_pos[name] = idx
                if idx - self._epoch_base < len(self._epochs):
                    epoch = self._epochs[idx - self._epoch_base]
                    if epoch.started_at is None:
                        epoch.started_at = self.clock()
                    return epoch
                if self._shutdown:
                    return None
                self._cv.wait()

    def _run_epoch(self, name: str, ex: ChunkExecutor,
                   epoch: EpochHandle) -> bool:
        """Process one epoch's tokens; returns False if the group died.

        Finished records are buffered per worker and flushed into the
        shared ledgers in batches of ``finalize_batch`` (one lock
        acquisition per batch instead of per record); every failure/exit
        path flushes its buffer before this worker leaves the epoch
        (the ``finally`` below), so no finished work is lost and no
        epoch finalizes with records still parked in a buffer."""
        part = self.partitioner
        space = epoch.space
        buf: List[ChunkRecord] = []
        ok = True
        preempted = False
        try:
            while True:
                # chunk-boundary checks, cheapest first: the cancellation
                # flag and the preemption hint are plain attribute reads
                # (no lock); the deadline comparison reads the clock only
                # when a deadline is actually set
                if epoch.cancelled:
                    return self._wind_down_cancelled(name, ex, epoch, buf)
                if epoch.deadline_s is not None \
                        and self.clock() > epoch.deadline_s:
                    self.cancel_epoch(epoch, reason="deadline")
                    continue                # re-check hits the cancel path
                if self._preempt_rank < epoch.rank:
                    preempted = True        # a more urgent epoch has work:
                    break                   # drain the pipeline and jump
                tc1 = self.clock()
                token = part.next_token(name, space)
                tc2 = self.clock()
                if token is None:
                    break
                rec = ChunkRecord(token, tc1=tc1, tc2=tc2)
                try:
                    done = ex.execute(token, rec)
                except ChunkFailure:
                    self._stamp_tc3(ex.completed(), buf)
                    part.requeue(token.chunk, space)
                    for chunk in ex.abort():
                        part.requeue(chunk, space)
                    self._finalize(buf, epoch)
                    self._mark_failed(name, epoch)
                    return False
                except Exception:
                    # out-of-protocol escape: conserve work like the
                    # in-band path (requeue the in-flight token and
                    # whatever the executor can abort) before the raise
                    # reaches the dispatcher guard — otherwise the epoch
                    # loses the token's items and never completes
                    try:
                        self._stamp_tc3(ex.completed(), buf)
                    except Exception:
                        pass
                    part.requeue(token.chunk, space)
                    try:
                        for chunk in ex.abort():
                            part.requeue(chunk, space)
                    except Exception:
                        pass
                    self._finalize(buf, epoch)
                    self._mark_failed(name, epoch)
                    raise
                self._stamp_tc3(done, buf)
                if len(buf) >= self.finalize_batch:
                    self._finalize(buf, epoch)
            try:
                self._stamp_tc3(ex.drain(), buf)
            except ChunkFailure:
                self._stamp_tc3(ex.completed(), buf)
                for chunk in ex.abort():
                    part.requeue(chunk, space)
                self._finalize(buf, epoch)
                self._mark_failed(name, epoch)
                return False
            if preempted and self.telemetry is not None:
                self.telemetry.registry.counter(
                    "sched.preemptions", group=name).add()
                self.telemetry.tracer.instant(
                    "preempt", tid="events", group=name, epoch=epoch.index,
                    tier=epoch.priority)
        except BaseException:
            ok = False
            raise
        finally:
            self._finalize(buf, epoch)
            self._leave_epoch(name, epoch)
        return ok

    def _wind_down_cancelled(self, name: str, ex: ChunkExecutor,
                             epoch: EpochHandle,
                             buf: List[ChunkRecord]) -> bool:
        """Cancellation wind-down at a chunk boundary: keep what already
        finished (ex.cancel completes ready work without waiting on the
        rest), requeue the still-in-flight chunks into the epoch's space
        — joining the tail cancel_epoch already reclaimed — and leave.
        Runs inside _run_epoch's try, so the caller's ``finally`` still
        flushes ``buf`` and leaves the epoch."""
        part = self.partitioner
        try:
            self._stamp_tc3(ex.cancel(), buf)
        except ChunkFailure:
            self._stamp_tc3(ex.completed(), buf)
            for chunk in ex.abort():
                part.requeue(chunk, epoch.space)
            self._finalize(buf, epoch)
            self._mark_failed(name, epoch)
            return False
        for chunk in ex.abort():
            part.requeue(chunk, epoch.space)
        return True

    def _stamp_tc3(self, done: List[ChunkRecord],
                   buf: List[ChunkRecord]) -> None:
        """Move completed records into the worker's buffer, stamping Tc3
        (host resumed) and feeding the λ-tracker *now* — at
        execute-return, not at the batched flush — so buffering neither
        inflates O_td nor lets a group size its next chunk/range from a
        λ that predates its own completions (the slow-group rebalance
        would lag an epoch otherwise). Pipelined executors stamp Tc3 per
        record at completion themselves
        (dispatch.JaxChunkExecutor._complete_oldest); the stamp here is
        the fallback for synchronous executors only."""
        if not done:
            return
        t = self.clock()
        for rec in done:
            if rec.tc3 == 0.0:
                rec.tc3 = t
        self.tracker.update_many(done)
        buf.extend(done)

    def _finalize(self, recs: List[ChunkRecord], epoch: EpochHandle) -> None:
        """Flush a batch of finished records into the shared ledgers and
        the epoch's record list (one lock acquisition per batch instead
        of per record). Every record arrives via _stamp_tc3, so Tc3 and
        the λ-tracker are already handled. Clears ``recs``."""
        if not recs:
            return
        self.ledger.add_many(recs)
        epoch.ledger.add_many(recs)
        epoch._records.extend(recs)
        if self.telemetry is not None:
            # bank the batch for snapshot-time ingestion: one atomic
            # append — the only telemetry cost on the dispatch hot path
            pending = self._tel_pending
            if len(pending) == pending.maxlen:
                self._tel_lost += 1
            pending.append((epoch.index, tuple(recs)))
        del recs[:]

    def _tel_handles(self, group: str) -> tuple:
        """Per-group metric handles, bound once (registry get-or-create
        takes a lock; the flush path must not)."""
        h = self._tel_group.get(group)
        if h is None:
            reg = self.telemetry.registry
            h = self._tel_group[group] = (
                reg.counter("sched.chunks", group=group),
                reg.counter("sched.items", group=group),
                reg.histogram("sched.chunk_host_s", group=group),
                reg.histogram("sched.chunk_device_s", group=group))
        return h

    def _tel_drain(self) -> None:
        """Snapshot-time collector: ingest banked completion batches into
        metrics + chunk spans. Runs on the snapshot reader's thread (the
        exporter daemon or a telemetry_snapshot caller) — never on a
        dispatcher. A worker's buffer is single-group, so one handle
        lookup covers each batch; concurrent snapshots are safe (popleft
        is atomic, each batch is ingested exactly once)."""
        pending = self._tel_pending
        tracer = self.telemetry.tracer
        while True:
            try:
                epoch_idx, recs = pending.popleft()
            except IndexError:
                break
            chunks, items, host_h, dev_h = self._tel_handles(
                recs[0].token.group)
            n = 0
            for rec in recs:
                n += rec.token.chunk.size
                host = (rec.tc2 - rec.tc1) + (max(rec.tc3 - rec.tg5, 0.0)
                                              if rec.tg5 > 0.0
                                              else max(rec.tc3 - rec.tc2,
                                                       0.0))
                host_h.observe(host)
                dev_h.observe(rec.device_time)
                tracer.chunk(rec, epoch_idx)
            chunks.add(len(recs))
            items.add(n)
        if self._tel_lost:
            self.telemetry.registry.gauge("sched.observe_lost_batches") \
                .set(self._tel_lost)

    def _mark_failed(self, name: str,
                     epoch: Optional[EpochHandle] = None) -> None:
        """In-band group death: exclude it from this and all later epochs.
        ``epoch`` is None when death is declared outside any epoch (the
        dispatcher guard caught an escape between epochs)."""
        with self._cv:
            self._failed.append(name)
            if epoch is not None:
                epoch._failed.append(name)
            self.specs.pop(name, None)
            self.executors.pop(name, None)
            if self.partitioner is not None:
                self.partitioner.remove_group(name)
            self._cv.notify_all()
        if self.telemetry is not None:
            self.telemetry.registry.counter("sched.group_failures",
                                            group=name).add()
            self.telemetry.tracer.instant(
                "group_failed", tid="events", group=name,
                epoch=epoch.index if epoch is not None else -1)

    def _leave_epoch(self, name: str, epoch: EpochHandle) -> None:
        with self._cv:
            self._worker_pos[name] = epoch.index + 1
            self._maybe_finalize_locked(epoch)
            self._prune_epochs_locked()
            self._recompute_preempt_locked()
            self._cv.notify_all()

    def _retire_worker(self, name: str) -> None:
        with self._cv:
            self._worker_pos.pop(name, None)
            if name not in self.specs:      # died/removed, not shutdown
                self._threads.pop(name, None)
            for h in self._epochs:
                if not h.finalized:
                    self._maybe_finalize_locked(h)
            self._prune_epochs_locked()
            self._recompute_preempt_locked()
            self._cv.notify_all()

    # -- epoch finalization --------------------------------------------
    def _maybe_finalize_locked(self, epoch: EpochHandle) -> None:
        if epoch.finalized:
            return
        if self._worker_pos and not epoch.cancelled \
                and (epoch.space.remaining > 0
                     or (self.partitioner is not None
                         and self.partitioner.has_work(epoch.space))):
            # Work is still reachable: a failure requeued items into the
            # space, or (range mode) a preempted dispatcher left its
            # claimed-but-unconsumed private range behind — invisible to
            # ``space.remaining`` but found by ``has_work``, the same
            # test _best_open_locked routes idle dispatchers with. A
            # live dispatcher will scan back and drain it (see
            # _await_epoch). A cancelled epoch finalizes *with* its
            # unfinished tail — that tail is the caller's to requeue,
            # not the dispatchers'.
            return
        if all(pos > epoch.index for pos in self._worker_pos.values()):
            self._finalize_epoch_locked(epoch)

    def _prune_epochs_locked(self) -> None:
        """Drop finalized leading epochs every worker is already past —
        keeps the epoch window (and its record lists) bounded on a
        long-running daemon."""
        min_pos = min(self._worker_pos.values(), default=None)
        while self._epochs and self._epochs[0].finalized \
                and (min_pos is None or min_pos > self._epochs[0].index):
            self._epochs.popleft()
            self._epoch_base += 1

    def _finalize_epoch_locked(self, h: EpochHandle) -> None:
        h.finished_at = self.clock()
        t0 = h.started_at if h.started_at is not None else h.submitted_at
        total = max(h.finished_at - t0, 0.0)
        per_items: Dict[str, int] = {}
        for r in h._records:
            per_items[r.token.group] = per_items.get(r.token.group, 0) \
                + r.token.chunk.size
        overheads = {g: h.ledger.report(total, g)
                     for g in h.ledger.groups()}
        overheads["all"] = h.ledger.report(total)
        h._result = ScheduleResult(
            total_time=total,
            iterations=sum(per_items.values()),
            records=list(h._records),
            overheads=overheads,
            throughput=self.tracker.snapshot(),
            per_group_items=per_items,
            failed_groups=list(h._failed),
            cancelled=h.cancelled,
            cancel_reason=h.cancel_reason or "",
            unfinished=h.space.remaining,
        )
        h._event.set()
        with h._cb_lock:
            cbs, h._callbacks = h._callbacks, []
        for fn in cbs:
            fn(h)
        if self.telemetry is not None:
            self.telemetry.registry.counter("sched.epochs_finalized").add()
            self.telemetry.tracer.span(
                f"epoch:{h.index}", "epochs", t0, h.finished_at,
                epoch=h.index, iterations=h._result.iterations,
                groups=list(per_items), tier=h.priority,
                cancelled=h.cancelled)

    # -- live observability --------------------------------------------
    def telemetry_snapshot(self) -> Optional[Dict]:
        """Merged metrics snapshot plus the partitioner's lock-contention
        stats — the ``runtime.telemetry_snapshot()`` live-introspection
        API (None when built with ``telemetry=repro.telemetry.OFF``)."""
        if self.telemetry is None:
            return None
        snap = self.telemetry.snapshot()
        if self.partitioner is not None:
            snap["contention"] = self.partitioner.contention_stats()
        return snap
