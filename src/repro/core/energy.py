"""Energy accounting and EDP — the paper's §4.1.1 methodology.

The paper integrates INA231 power samples over time per rail (A15/A7/GPU/
DRAM). We integrate the scheduler timeline instead: every device group has an
active and an idle power; energy = Σ_g (P_active·t_busy + P_idle·t_idle) +
P_base·T. EDP = E·T (Gonzales & Horowitz).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Iterable, Optional

from repro.core.types import ChunkRecord


@dataclass(frozen=True)
class PowerSpec:
    active_w: float
    idle_w: float


@dataclass
class EnergyReport:
    total_time_s: float
    per_group_j: Dict[str, float]
    base_j: float
    total_j: float = 0.0

    def __post_init__(self):
        self.total_j = self.base_j + sum(self.per_group_j.values())

    @property
    def edp(self) -> float:
        return self.total_j * self.total_time_s

    def as_dict(self) -> Dict:
        return {"time_s": self.total_time_s, "energy_j": self.total_j,
                "edp": self.edp, "per_group_j": dict(self.per_group_j)}


class EnergyModel:
    def __init__(self, specs: Dict[str, PowerSpec], base_w: float = 0.0):
        self.specs = dict(specs)
        self.base_w = base_w

    def energy(self, total_time_s: float,
               busy_s: Dict[str, float]) -> EnergyReport:
        per = {}
        for g, spec in self.specs.items():
            b = min(busy_s.get(g, 0.0), total_time_s)
            per[g] = spec.active_w * b + spec.idle_w * (total_time_s - b)
        return EnergyReport(total_time_s, per, self.base_w * total_time_s)

    def energy_from_records(self, total_time_s: float,
                            records: Iterable[ChunkRecord]) -> EnergyReport:
        busy: Dict[str, float] = {}
        for r in records:
            busy[r.token.group] = busy.get(r.token.group, 0.0) \
                + max(r.device_time, 0.0)
        return self.energy(total_time_s, busy)

    def busy_energy_j(self, busy_s: Dict[str, float]) -> float:
        """Active-power energy of the given busy seconds only — no idle or
        base term. This is the *marginal* energy of a slice of work, safe
        to sum across overlapping wall-clock windows (idle/base power is a
        cost of the window, so charging it per overlapping batch would
        double-bill it; see TenantAccountant)."""
        return sum(self.specs[g].active_w * b
                   for g, b in busy_s.items() if g in self.specs)

    def attribute(self, report: EnergyReport,
                  shares: Dict[str, float]) -> Dict[str, float]:
        """Split a report's joules across consumers (tenants) by share.

        Active, idle, and base energy are all attributed proportionally:
        during a shared batch every tenant's work keeps the package out of
        its low-power state, so idle/base joules are a cost of running the
        batch at all, borne in proportion to use (the per-rail integration
        of §4.1.1 has no finer tenant signal to offer). Shares should sum
        to 1; they are normalized defensively if they do not.
        """
        total_share = sum(shares.values())
        if total_share <= 0.0:
            return {}
        return {who: report.total_j * (s / total_share)
                for who, s in shares.items()}
