"""Wait-instrumented locking shared by the hot-path components.

``TimedLock`` is a plain ``threading.Lock`` that accumulates the time
callers spend *waiting* to acquire it — the lock-wait metric
benchmarks/dispatch_overhead.py and ``contention_stats()`` report. Two
clock reads per acquire; components keep it off their fast paths and pay
it only on slow paths (range refills, tracker cell registration), so the
instrumentation itself never becomes the contention it measures.
"""
from __future__ import annotations

import threading
import time

clock = time.monotonic


class TimedLock:
    """threading.Lock accumulating acquire-wait time."""

    __slots__ = ("_lock", "wait_s", "acquires")

    def __init__(self):
        self._lock = threading.Lock()
        self.wait_s = 0.0
        self.acquires = 0

    def __enter__(self) -> "TimedLock":
        t0 = clock()
        self._lock.acquire()
        # mutated under the lock just acquired: no torn updates
        self.wait_s += clock() - t0
        self.acquires += 1
        return self

    def __exit__(self, *exc) -> None:
        self._lock.release()

    def stats(self) -> dict:
        """``{lock_wait_s, lock_acquires}`` read under the raw lock so the
        pair comes from one acquire (no torn snapshot) without the timed
        wrapper charging the read itself to ``wait_s``."""
        with self._lock:
            return {"lock_wait_s": self.wait_s,
                    "lock_acquires": float(self.acquires)}
