"""Discrete-event simulator of the offload timeline (paper §§3–5).

Reproduces the paper's experiments without 2015 hardware: compute threads and
the accelerator's host thread advance on a shared event heap; the OS wake-up
policy ("rr" Windows vs "fair" Linux) governs the thread-dispatch delay the
paper identified as the dominant overhead; energy integrates per-rail power
over busy/idle intervals exactly like the paper's sampling library.

Scheduler modes:
  dynamic      the paper's Dynamic (per-device chunks, eqs. 3–4)
  bulk         static split: accelerator gets one bulk chunk of frac·N,
               CPU threads dynamically share the rest (Bulk baseline;
               the *oracle* sweeps frac and keeps the best: oracle.py)

Optimizations:
  priority     Dynamic Pri: host thread preempts on wake (eps dispatch)
  host_pin     "big" | "little": which core class hosts the dispatcher
  async_depth  ≥2 = TPU-idiomatic dispatch-ahead (beyond-paper; subsumes Pri)
"""
from __future__ import annotations

import heapq
import itertools
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from repro.core.energy import EnergyModel, EnergyReport, PowerSpec
from repro.core.overheads import OverheadLedger
from repro.core.platforms import Platform
from repro.core.types import Chunk, ChunkRecord, DeviceKind, GroupSpec, \
    IterationSpace, Token


@dataclass
class SimConfig:
    n_big: int = 3                 # compute threads on big cores
    n_little: int = 0              # compute threads on little cores
    host_pin: str = "big"          # where the host (dispatcher) thread lives
    priority: bool = False         # Dynamic Pri
    scheduler: str = "dynamic"     # dynamic | bulk
    bulk_frac: Optional[float] = None
    G: Optional[int] = None        # accelerator chunk (default platform G_opt)
    timesteps: int = 15
    n_iterations: int = 100_000
    async_depth: int = 1           # ≥2: dispatch-ahead (beyond-paper)

    @property
    def label(self) -> str:
        return f"{self.n_big + self.n_little}+1"


@dataclass
class SimResult:
    time_ms: float
    energy: EnergyReport
    overheads: Dict[str, float]
    per_device_items: Dict[str, int]
    n_gpu_chunks: int
    config: SimConfig

    @property
    def edp(self) -> float:
        return self.energy.edp

    def as_dict(self) -> Dict:
        return {"time_ms": self.time_ms, "energy_j": self.energy.total_j,
                "edp": self.edp, "overheads": self.overheads,
                "per_device_items": self.per_device_items}


def _oversubscribed(plat: Platform, cfg: SimConfig) -> bool:
    """Is there no idle core for the host thread to run on?"""
    cores = {"big": plat.n_big, "little": plat.n_little}
    used = {"big": cfg.n_big, "little": cfg.n_little}
    if cfg.host_pin == "little" and plat.n_little:
        return used["little"] >= cores["little"]
    return used["big"] >= cores["big"]


def _wake_delay(plat: Platform, cfg: SimConfig) -> float:
    """Host-thread dispatch latency after device completion (the O_td root
    cause, §4.2): under RR with no idle core and no priority boost the host
    waits ~a ready-queue slice; otherwise it dispatches in ~eps."""
    if cfg.priority:
        return plat.eps_ms
    if not _oversubscribed(plat, cfg):
        return plat.eps_ms
    if plat.os_policy == "fair":
        # Linux boosts awakened threads, but under full oversubscription a
        # small residual delay remains (the paper's Fig. 7: Pri still buys
        # ~4% at 7+1/8+1 on the Exynos)
        return plat.td_wait_fair_ms or plat.eps_ms
    return plat.td_wait_ms


def simulate(plat: Platform, cfg: SimConfig) -> SimResult:
    G = cfg.G or plat.G_opt
    lam_g = plat.accel(G)
    ledger = OverheadLedger()
    ledger.keep_records = False
    busy = {"accel": 0.0}
    items = {"accel": 0}
    threads: List[Tuple[str, float]] = []    # (class, per-thread λ)
    for i in range(cfg.n_big):
        threads.append(("big", plat.lam_big))
        busy.setdefault("big", 0.0)
        items.setdefault("big", 0)
    for i in range(cfg.n_little):
        threads.append(("little", plat.lam_little))
        busy.setdefault("little", 0.0)
        items.setdefault("little", 0)

    t_end = 0.0
    n_gpu_chunks = 0
    seq = itertools.count()

    for _ in range(cfg.timesteps):
        t0 = t_end
        if cfg.scheduler == "bulk":
            frac = plat.bulk_frac[cfg.label] if cfg.bulk_frac is None \
                else cfg.bulk_frac
            n_accel = int(cfg.n_iterations * frac)
            space = IterationSpace(0, cfg.n_iterations - n_accel)
            accel_done = t0
            if n_accel:
                lam_bulk = plat.accel(n_accel)
                tg1 = t0 + plat.sp_ms
                tg2 = tg1 + plat.t_hd_ms
                tg3 = tg2 + plat.t_kl_ms
                tg4 = tg3 + n_accel / lam_bulk
                tg5 = tg4 + plat.t_dh_ms
                rec = ChunkRecord(
                    Token(Chunk(0, n_accel, next(seq)), "accel",
                          DeviceKind.ACCEL),
                    tc1=t0 / 1e3, tc2=(t0 + plat.sp_ms) / 1e3,
                    tc3=(tg5 + _wake_delay(plat, cfg)) / 1e3,
                    tg1=tg1 / 1e3, tg2=tg2 / 1e3, tg3=tg3 / 1e3,
                    tg4=tg4 / 1e3, tg5=tg5 / 1e3)
                ledger.add(rec)
                busy["accel"] += (tg5 - tg1) / 1e3
                items["accel"] += n_accel
                n_gpu_chunks += 1
                accel_done = tg5
            # CPU threads dynamically share the rest (quantum = TBB-ish)
            quantum = max(64, (cfg.n_iterations - n_accel)
                          // max(1, 8 * len(threads)))
            tdone = t0
            clocks = [t0] * len(threads)
            while True:
                c = space.take(quantum)
                if c is None:
                    break
                i = min(range(len(threads)), key=lambda j: clocks[j])
                cls, lam = threads[i]
                dt = plat.sp_ms + c.size / lam
                clocks[i] += dt
                busy[cls] += (dt - plat.sp_ms) / 1e3
                items[cls] += c.size
            tdone = max(clocks) if threads else t0
            t_end = max(accel_done, tdone)
            continue

        # ---- dynamic (the paper's scheduler) --------------------------
        space = IterationSpace(0, cfg.n_iterations)
        lam_c_seen = {"big": plat.lam_big, "little": plat.lam_little}
        heap: List[Tuple[float, int, str, int]] = []
        # CPU threads bootstrap
        clocks = [t0] * len(threads)
        for i, (cls, lam) in enumerate(threads):
            heapq.heappush(heap, (t0, next(seq), "cpu", i))
        # accelerator host thread bootstraps
        heapq.heappush(heap, (t0, next(seq), "accel", -1))
        end_time = t0
        inflight_ready = t0    # when the device becomes free
        while heap:
            t, _, kind, idx = heapq.heappop(heap)
            if kind == "cpu":
                cls, lam = threads[idx]
                size = max(1, int(round(
                    G * lam / max(lam_g, 1e-9))))           # eq. (4)
                c = space.take(size)
                if c is None:
                    end_time = max(end_time, t)
                    continue
                dt = plat.sp_ms + c.size / lam
                busy[cls] += (dt - plat.sp_ms) / 1e3
                items[cls] += c.size
                heapq.heappush(heap, (t + dt, next(seq), "cpu", idx))
            else:
                c = space.take(G)
                if c is None:
                    end_time = max(end_time, t, inflight_ready)
                    continue
                tc1 = t
                tc2 = t + plat.sp_ms
                start = max(tc2, inflight_ready)
                tg1 = start
                tg2 = tg1 + plat.t_hd_ms
                tg3 = tg2 + plat.t_kl_ms
                tg4 = tg3 + c.size / plat.accel(c.size)
                tg5 = tg4 + plat.t_dh_ms
                inflight_ready = tg5
                wake = _wake_delay(plat, cfg)
                if cfg.async_depth >= 2:
                    # dispatch-ahead: the device never waits for the host;
                    # O_td measures device idle, which pipelining removes
                    tc1, tc2, wake = tg1, tg1, 0.0
                tc3 = tg5 + wake
                rec = ChunkRecord(
                    Token(c, "accel", DeviceKind.ACCEL),
                    tc1=tc1 / 1e3, tc2=tc2 / 1e3, tc3=tc3 / 1e3,
                    tg1=tg1 / 1e3, tg2=tg2 / 1e3, tg3=tg3 / 1e3,
                    tg4=tg4 / 1e3, tg5=tg5 / 1e3)
                ledger.add(rec)
                busy["accel"] += (tg5 - tg1) / 1e3
                items["accel"] += c.size
                n_gpu_chunks += 1
                # with dispatch-ahead the host enqueues the next chunk while
                # the device still runs; otherwise it redispatches after wake
                next_t = tg1 if cfg.async_depth >= 2 else tc3
                heapq.heappush(heap, (next_t, next(seq), "accel", -1))
        t_end = end_time

    total_s = t_end / 1e3
    # ---- energy -------------------------------------------------------
    # E_rail = idle_w·n_cores·T + (active_w − idle_w)·busy_core_seconds:
    # idle power burns on every core of the rail for the whole run; the
    # active-idle delta accrues per busy core-second (INA231 rail analogue).
    counts = {"big": plat.n_big, "little": plat.n_little, "accel": 1}
    per = {}
    for rail, spec in plat.power.items():
        n = counts.get(rail, 1)
        b = busy.get(rail, 0.0)                     # busy core-seconds
        per[rail] = spec.idle_w * n * total_s \
            + (spec.active_w - spec.idle_w) * b
    energy = EnergyReport(total_s, per, plat.base_w * total_s)
    ov = ledger.report(total_s, "accel")
    return SimResult(time_ms=t_end, energy=energy, overheads=ov,
                     per_device_items=items, n_gpu_chunks=n_gpu_chunks,
                     config=cfg)


# ---------------------------------------------------------------------------
# convenience runners for the paper's configurations
# ---------------------------------------------------------------------------

def run_config(plat: Platform, label: str, scheduler: str = "dynamic",
               priority: bool = False, host_pin: str = "big",
               timesteps: int = 15, async_depth: int = 1,
               bulk_frac: Optional[float] = None) -> SimResult:
    n_threads = int(label.split("+")[0])
    n_big = min(n_threads, plat.n_big)
    n_little = n_threads - n_big
    return simulate(plat, SimConfig(
        n_big=n_big, n_little=n_little, host_pin=host_pin,
        priority=priority, scheduler=scheduler, bulk_frac=bulk_frac,
        timesteps=timesteps, async_depth=async_depth))


def bulk_oracle(plat: Platform, label: str, timesteps: int = 15,
                step: float = 0.1) -> SimResult:
    """The paper's Bulk-Oracle: exhaustive offline sweep of the static split."""
    best = None
    f = 0.0
    while f <= 1.0001:
        r = run_config(plat, label, scheduler="bulk", bulk_frac=f,
                       timesteps=timesteps)
        if best is None or r.time_ms < best.time_ms:
            best = r
        f += step
    return best
