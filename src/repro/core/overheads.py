"""Offload-overhead ledger — the paper's §3.3, eqs. (5)–(9).

Given the per-chunk timestamps of ChunkRecord:

  O_sp = Σ (Tc2 − Tc1) / T_total            scheduling + partitioning
  O_hd = Σ (Tg2 − Tg1) / T_total            host→device transfer
  O_kl = Σ (Tg3 − Tg2) / T_total            kernel launch
  O_dh = Σ (Tg5 − Tg4) / T_total            device→host transfer
  O_td = Σ ((Tc3 − Tc2) − (Tg5 − Tg1)) / T_total   host-thread dispatch

All terms are fractions of total wall time, accumulated over accelerator
chunks only (the paper measures the offload path).
"""
from __future__ import annotations

import threading
from dataclasses import dataclass, field, replace
from typing import Dict, List, Optional

from repro.core.types import ChunkRecord, DeviceKind


@dataclass
class OverheadTotals:
    sp: float = 0.0
    hd: float = 0.0
    kl: float = 0.0
    dh: float = 0.0
    td: float = 0.0
    kernel: float = 0.0       # pure device-execute time (Tg4 − Tg3)
    n_chunks: int = 0

    def fractions(self, total_time: float) -> Dict[str, float]:
        t = max(total_time, 1e-12)
        return {"O_sp": self.sp / t, "O_hd": self.hd / t,
                "O_kl": self.kl / t, "O_dh": self.dh / t,
                "O_td": self.td / t, "kernel_frac": self.kernel / t,
                "n_chunks": self.n_chunks}


class OverheadLedger:
    def __init__(self):
        self._lock = threading.Lock()
        self._per_group: Dict[str, OverheadTotals] = {}
        self.records: List[ChunkRecord] = []
        self.keep_records: bool = True

    def add(self, rec: ChunkRecord) -> None:
        with self._lock:
            self._add_locked(rec)

    def add_many(self, recs) -> None:
        """Batched accumulate: one lock acquisition for a whole completion
        batch (the scheduler's per-worker finalize buffer)."""
        with self._lock:
            for rec in recs:
                self._add_locked(rec)

    def _add_locked(self, rec: ChunkRecord) -> None:
        tot = self._per_group.setdefault(rec.token.group,
                                         OverheadTotals())
        tot.sp += rec.tc2 - rec.tc1
        tot.hd += rec.tg2 - rec.tg1
        tot.kl += rec.tg3 - rec.tg2
        tot.dh += rec.tg5 - rec.tg4
        tot.td += max((rec.tc3 - rec.tc2) - (rec.tg5 - rec.tg1), 0.0)
        tot.kernel += rec.tg4 - rec.tg3
        tot.n_chunks += 1
        if self.keep_records:
            self.records.append(rec)

    def totals(self, group: Optional[str] = None) -> OverheadTotals:
        with self._lock:
            if group is not None:
                tot = self._per_group.get(group)
                # copy under the lock: handing out the live accumulator
                # would expose torn field pairs during a concurrent add
                return OverheadTotals() if tot is None else replace(tot)
            agg = OverheadTotals()
            for t in self._per_group.values():
                agg.sp += t.sp; agg.hd += t.hd; agg.kl += t.kl
                agg.dh += t.dh; agg.td += t.td; agg.kernel += t.kernel
                agg.n_chunks += t.n_chunks
            return agg

    def report(self, total_time: float, group: Optional[str] = None) \
            -> Dict[str, float]:
        return self.totals(group).fractions(total_time)

    def groups(self):
        with self._lock:
            return list(self._per_group)
