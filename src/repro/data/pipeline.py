"""Synthetic-token data pipeline with host-side prefetch.

The sample space is index-addressable and deterministic (sample i is a pure
function of (seed, i)), which is what makes the paper's scheduler idempotent:
a re-executed chunk reproduces exactly the same examples (fault tolerance),
and any group can materialize any [begin, end) range locally (no data
redistribution when chunks move between groups).

The prefetcher double-buffers batch materialization on a background thread —
the O_hd mitigation from DESIGN.md (host→device copy overlaps compute).
"""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Dict, Iterator, Optional

import numpy as np

from repro.configs.base import LMConfig
from repro.core.types import Chunk


@dataclass(frozen=True)
class DataConfig:
    seq_len: int
    vocab: int
    seed: int = 0
    prefix_len: int = 0
    d_model: int = 0               # for stubbed modality prefixes


class SyntheticLMData:
    """Deterministic synthetic LM stream: sample i -> (tokens, labels)."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg

    def sample(self, idx: int) -> Dict[str, np.ndarray]:
        rng = np.random.Generator(np.random.PCG64(
            (self.cfg.seed << 32) ^ idx))
        # markov-ish stream so loss actually decreases during training
        toks = rng.integers(0, self.cfg.vocab,
                            self.cfg.seq_len + 1, dtype=np.int32)
        toks[1::2] = (toks[0::2][:toks[1::2].shape[0]] * 7 + 3) \
            % self.cfg.vocab
        out = {"tokens": toks[:-1], "labels": toks[1:]}
        if self.cfg.prefix_len:
            out["prefix_emb"] = rng.standard_normal(
                (self.cfg.prefix_len, self.cfg.d_model)).astype(np.float32) \
                * 0.02
        return out

    def batch(self, begin: int, end: int,
              pad_to: Optional[int] = None) -> Dict[str, np.ndarray]:
        """Materialize samples [begin, end), optionally padded to a bucket
        size (padded rows are masked via loss_mask)."""
        n = end - begin
        rows = [self.sample(i) for i in range(begin, end)]
        out = {k: np.stack([r[k] for r in rows]) for k in rows[0]}
        out["loss_mask"] = np.ones((n, self.cfg.seq_len), np.float32)
        if pad_to and pad_to > n:
            pad = pad_to - n
            for k, v in list(out.items()):
                out[k] = np.concatenate(
                    [v, np.zeros((pad,) + v.shape[1:], v.dtype)], axis=0)
        return out

    def chunk_batch(self, chunk: Chunk,
                    pad_to: Optional[int] = None) -> Dict[str, np.ndarray]:
        return self.batch(chunk.begin, chunk.end, pad_to)


def for_model(cfg: LMConfig, seq_len: int, seed: int = 0) -> SyntheticLMData:
    return SyntheticLMData(DataConfig(
        seq_len=seq_len, vocab=cfg.vocab, seed=seed,
        prefix_len=cfg.prefix_len, d_model=cfg.d_model))


class Prefetcher:
    """Double-buffered background batch materialization."""

    def __init__(self, make_batch, depth: int = 2):
        self.make_batch = make_batch
        self.q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._idx = 0
        self._thread = threading.Thread(target=self._fill, daemon=True)
        self._thread.start()

    def _fill(self):
        while not self._stop.is_set():
            b = self.make_batch(self._idx)
            self._idx += 1
            while not self._stop.is_set():
                try:
                    self.q.put(b, timeout=0.1)
                    break
                except queue.Full:
                    continue

    def next(self, timeout: float = 60.0):
        return self.q.get(timeout=timeout)

    def stop(self):
        self._stop.set()
