from repro.data.pipeline import (DataConfig, Prefetcher, SyntheticLMData,
                                 for_model)

__all__ = ["DataConfig", "Prefetcher", "SyntheticLMData", "for_model"]
