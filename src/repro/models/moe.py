"""Mixture-of-Experts FFN with capacity-based gather/scatter dispatch.

Expert-parallel over the ``model`` mesh axis. Dispatch avoids the O(T·E·C)
GShard one-hot: per expert we select its top-C tokens by routing priority
(gather), run a grouped matmul over (E, C, d), and scatter-add results back.
Tokens routed beyond an expert's capacity are dropped (standard GShard/Switch
semantics); the combine weight of unrouted slots is zero so over-selection is
harmless. A Switch-style load-balancing auxiliary loss is returned.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import LMConfig
from repro.models.layers import ParamDef, act_fn
from repro.sharding.partition import lshard


def moe_defs(cfg: LMConfig) -> Dict[str, ParamDef]:
    d, ff, e = cfg.d_model, cfg.d_ff, cfg.moe.num_experts
    dt = cfg.dtype
    out = {
        "router": ParamDef((d, e), ("embed", "experts"), dtype="float32"),
        "wi": ParamDef((e, d, ff), ("experts", "embed", "mlp"), dtype=dt),
        "wo": ParamDef((e, ff, d), ("experts", "mlp", "embed"), dtype=dt),
    }
    if cfg.gated_mlp:
        out["wg"] = ParamDef((e, d, ff), ("experts", "embed", "mlp"), dtype=dt)
    return out


def expert_capacity(cfg: LMConfig, n_tokens: int) -> int:
    m = cfg.moe
    cap = int(n_tokens * m.top_k / m.num_experts * m.capacity_factor)
    cap = max(cap, 8)
    # round up to a multiple of 8 for clean tiling/sharding
    return min(n_tokens, (cap + 7) // 8 * 8)


def moe_fwd(cfg: LMConfig, p: Dict, x: jax.Array) \
        -> Tuple[jax.Array, jax.Array]:
    """x: (b, s, d) -> (out (b, s, d), aux_loss scalar)."""
    m = cfg.moe
    b, s, d = x.shape
    T = b * s
    C = expert_capacity(cfg, T)
    xf = x.reshape(T, d)

    logits = jnp.einsum("td,de->te", xf.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)                      # (T, E)
    top_p, top_idx = jax.lax.top_k(probs, m.top_k)               # (T, k)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    # dense routing-priority matrix: priority[t, e] = renormalized gate if
    # expert e is in token t's top-k else 0
    prio = jnp.zeros((T, m.num_experts), jnp.float32)
    prio = prio.at[jnp.arange(T)[:, None], top_idx].set(top_p)

    # capacity selection: G dispatch groups, capacity C/G per (group, expert).
    # G aligned with the data axis keeps gather/scatter shard-local, so the
    # combine reduces over the model axis only (no global-token all-reduce).
    G = max(1, min(m.dispatch_groups, T))
    Cg = max(1, C // G)
    prio_g = prio.reshape(G, T // G, m.num_experts)
    gates, tok_g = jax.lax.top_k(prio_g.transpose(0, 2, 1), Cg)  # (G, E, Cg)
    xg = xf.reshape(G, T // G, d)
    x_e = jax.vmap(lambda xs, idx: jnp.take(xs, idx, axis=0))(xg, tok_g)
    # x_e: (G, E, Cg, d) — with G=1 the capacity dim shards over `data`;
    # with G=data-aligned groups the group dim takes `data` and the rule
    # engine's no-axis-reuse drops it from the capacity dim automatically
    x_e = lshard(x_e, "act_expert_group", "act_experts", "act_expert_cap",
                 "act_embed")

    h = jnp.einsum("gecd,edf->gecf", x_e, p["wi"])
    if cfg.gated_mlp:
        h = act_fn(cfg.act)(jnp.einsum("gecd,edf->gecf", x_e, p["wg"])) * h
    else:
        h = act_fn(cfg.act)(h)
    h = lshard(h, "act_expert_group", "act_experts", "act_expert_cap",
               "act_mlp")
    y_e = jnp.einsum("gecf,efd->gecd", h, p["wo"])               # (G, E, Cg, d)
    y_e = y_e * gates[..., None].astype(y_e.dtype)

    out = jnp.zeros((G, T // G, d), y_e.dtype)
    out = jax.vmap(lambda o, idx, y: o.at[idx.reshape(-1)].add(
        y.reshape(-1, d)))(out, tok_g, y_e)
    out = lshard(out.reshape(b, s, d), "act_batch", "act_res_seq", "act_embed")

    # Switch aux loss: E * sum_e f_e * P_e  (f = token fraction, P = mean prob)
    routed = (prio > 0).astype(jnp.float32)
    f = routed.mean(axis=0) / m.top_k * m.num_experts
    P = probs.mean(axis=0)
    aux = m.num_experts * jnp.sum(f * P) * m.aux_loss_weight
    return out.astype(x.dtype), aux


def moe_fwd_reference(cfg: LMConfig, p: Dict, x: jax.Array) \
        -> Tuple[jax.Array, jax.Array]:
    """Loop-over-experts dense oracle (no capacity drops) for tests."""
    m = cfg.moe
    b, s, d = x.shape
    xf = x.reshape(-1, d)
    logits = jnp.einsum("td,de->te", xf.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_idx = jax.lax.top_k(probs, m.top_k)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)
    out = jnp.zeros_like(xf, dtype=jnp.float32)
    for e in range(m.num_experts):
        w = (jnp.where(top_idx == e, top_p, 0.0)).sum(-1)        # (T,)
        h = jnp.einsum("td,df->tf", xf, p["wi"][e])
        if cfg.gated_mlp:
            h = act_fn(cfg.act)(jnp.einsum("td,df->tf", xf, p["wg"][e])) * h
        else:
            h = act_fn(cfg.act)(h)
        y = jnp.einsum("tf,fd->td", h, p["wo"][e])
        out = out + y.astype(jnp.float32) * w[:, None]
    routed = jnp.zeros((xf.shape[0], m.num_experts), jnp.float32) \
        .at[jnp.arange(xf.shape[0])[:, None], top_idx].set(1.0)
    f = routed.mean(axis=0) / m.top_k * m.num_experts
    P = probs.mean(axis=0)
    aux = m.num_experts * jnp.sum(f * P) * m.aux_loss_weight
    return out.reshape(b, s, d).astype(x.dtype), aux
