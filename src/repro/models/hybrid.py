"""Zamba2-style hybrid backbone: Mamba-2 blocks + one parameter-shared
attention(+MLP) block applied every ``attn_every`` SSM blocks.

Layer layout for n_layers=38, attn_every=6:
  6 groups of [6 mamba blocks -> shared attn block] + 2 tail mamba blocks.
The shared block's *weights* are reused across applications (Zamba weight
sharing); each application has its own KV-cache entries at decode time.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import LMConfig
from repro.models import transformer as tfm
from repro.models.attention import (decode_attention, group_query_heads,
                                    ungroup_heads)
from repro.models.layers import ParamDef, apply_rope, norm, rope_freqs
from repro.models.ssm import (mamba2_block_fwd, mamba2_decode_step,
                              mamba2_defs, mamba2_dims)
from repro.sharding.partition import lshard


def hybrid_layout(cfg: LMConfig) -> Tuple[int, int, int]:
    k = cfg.hybrid.attn_every
    n_groups = cfg.n_layers // k
    tail = cfg.n_layers - n_groups * k
    return n_groups, k, tail


def hybrid_defs(cfg: LMConfig) -> Dict:
    n_groups, k, tail = hybrid_layout(cfg)
    blk = mamba2_defs(cfg)
    out = {
        "embed": ParamDef((cfg.vocab, cfg.d_model), ("vocab", "embed"),
                          scale=cfg.d_model ** 0.5, dtype=cfg.dtype),
        "groups": tfm.stacked(tfm.stacked(blk, k), n_groups),
        "shared_attn": tfm.block_defs(cfg),
        "final_norm": tfm.norm_defs(cfg.d_model, cfg.norm_type),
        "unembed": ParamDef((cfg.d_model, cfg.vocab), ("embed", "vocab"),
                            dtype=cfg.dtype),
    }
    if tail:
        out["tail"] = tfm.stacked(blk, tail)
    return out


def forward(cfg: LMConfig, params: Dict, tokens: jax.Array,
            prefix_emb: Optional[jax.Array] = None, remat: bool = False,
            return_hidden: bool = False):
    x, positions = tfm.embed_tokens(cfg, params, tokens, prefix_emb)

    def mamba_body(x, bp):
        return mamba2_block_fwd(cfg, bp, x), None

    def group_body(x, gp):
        x, _ = jax.lax.scan(mamba_body, x, gp)
        x = tfm.attn_block_fwd(cfg, params["shared_attn"], x, positions)
        x, _ = tfm.ffn_block_fwd(cfg, params["shared_attn"], x)
        return x, None

    if remat:
        group_body = jax.checkpoint(group_body, prevent_cse=False)
    x, _ = jax.lax.scan(group_body, x, params["groups"])
    if "tail" in params:
        x, _ = jax.lax.scan(mamba_body, x, params["tail"])
    x = norm(x, params["final_norm"], cfg.norm_type, cfg.norm_eps)
    if return_hidden:
        return x, jnp.zeros((), jnp.float32)
    return tfm.logits_fwd(cfg, params, x), jnp.zeros((), jnp.float32)


# ---------------------------------------------------------------------------
# caches
# ---------------------------------------------------------------------------

def init_cache(cfg: LMConfig, batch: int, max_len: int, abstract=False):
    n_groups, k, tail = hybrid_layout(cfg)
    s = cfg.ssm
    di, nh, conv_dim = mamba2_dims(cfg)
    g, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    dt = cfg.activation_dtype
    mk = (lambda sh, d: jax.ShapeDtypeStruct(sh, d)) if abstract \
        else (lambda sh, d: jnp.zeros(sh, d))
    cache = {
        "ssm_state": mk((n_groups, k, batch, nh, s.head_dim, s.d_state),
                        jnp.float32),
        "conv": mk((n_groups, k, batch, s.d_conv - 1, conv_dim), dt),
        "ak": mk((n_groups, batch, max_len, g, hd), dt),
        "av": mk((n_groups, batch, max_len, g, hd), dt),
        "pos": mk((batch,), jnp.int32),
    }
    if tail:
        cache["tail_state"] = mk((tail, batch, nh, s.head_dim, s.d_state),
                                 jnp.float32)
        cache["tail_conv"] = mk((tail, batch, s.d_conv - 1, conv_dim), dt)
    return cache


def cache_axes(cfg: LMConfig):
    n_groups, k, tail = hybrid_layout(cfg)
    ax = {
        "ssm_state": (None, None, "cache_batch", "ssm_heads", None, None),
        "conv": (None, None, "cache_batch", None, "conv_dim"),
        "ak": (None, "cache_batch", "cache_seq", "cache_kv_heads", None),
        "av": (None, "cache_batch", "cache_seq", "cache_kv_heads", None),
        "pos": ("cache_batch",),
    }
    if tail:
        ax["tail_state"] = (None, "cache_batch", "ssm_heads", None, None)
        ax["tail_conv"] = (None, "cache_batch", None, "conv_dim")
    return ax


def prefill(cfg: LMConfig, params: Dict, tokens: jax.Array,
            prefix_emb: Optional[jax.Array] = None,
            max_len: Optional[int] = None):
    x, positions = tfm.embed_tokens(cfg, params, tokens, prefix_emb)
    b, s = x.shape[0], x.shape[1]
    S = max_len or s
    n_groups, k, tail = hybrid_layout(cfg)

    def mamba_body(x, bp):
        out, st = mamba2_block_fwd(cfg, bp, x, return_state=True)
        return out, st

    def attn_apply(x):
        bp = params["shared_attn"]
        h = norm(x, bp["attn_norm"], cfg.norm_type, cfg.norm_eps)
        h = lshard(h, "act_batch", "act_seq", "act_embed")
        q, kk, vv = tfm._qkv(cfg, bp["attn"], h, positions)
        qg = group_query_heads(q, cfg.n_kv_heads)
        from repro.models.attention import chunked_attention
        o = chunked_attention(qg, kk, vv, causal=True, q_chunk=cfg.q_chunk,
                              kv_chunk=cfg.kv_chunk,
                              block_skip=cfg.causal_block_skip)
        o = jnp.einsum("bshk,hkd->bsd", ungroup_heads(o), bp["attn"]["wo"])
        x = x + lshard(o, "act_batch", "act_res_seq", "act_embed")
        x, _ = tfm.ffn_block_fwd(cfg, bp, x)
        if S > s:
            pad = [(0, 0), (0, S - s), (0, 0), (0, 0)]
            kk, vv = jnp.pad(kk, pad), jnp.pad(vv, pad)
        return x, kk, vv

    def group_body(x, gp):
        x, sts = jax.lax.scan(mamba_body, x, gp)
        x, kk, vv = attn_apply(x)
        return x, (sts, kk, vv)

    x, (g_states, ks, vs) = jax.lax.scan(group_body, x, params["groups"])
    cache = {
        "ssm_state": g_states[0], "conv": g_states[1],
        "ak": ks, "av": vs, "pos": jnp.full((b,), s, jnp.int32),
    }
    if "tail" in params:
        x, t_states = jax.lax.scan(mamba_body, x, params["tail"])
        cache["tail_state"], cache["tail_conv"] = t_states
    x = norm(x, params["final_norm"], cfg.norm_type, cfg.norm_eps)
    return tfm.logits_fwd(cfg, params, x[:, -1:, :]), cache


def decode_step(cfg: LMConfig, params: Dict, cache: Dict, tokens: jax.Array):
    b = tokens.shape[0]
    pos = cache["pos"]
    x = jnp.take(params["embed"], tokens, axis=0)
    x = lshard(x, "act_batch", "act_res_seq", "act_embed")
    positions = pos[:, None]
    inv, rot = rope_freqs(cfg.resolved_head_dim, cfg.rope_fraction,
                          cfg.rope_theta)

    def mamba_body(x, inp):
        bp, st, cb = inp
        out, st, cb = mamba2_decode_step(cfg, bp, x, st, cb)
        return out, (st, cb)

    def attn_apply(x, k_cache, v_cache):
        bp = params["shared_attn"]
        h = norm(x, bp["attn_norm"], cfg.norm_type, cfg.norm_eps)
        q = jnp.einsum("bsd,dhk->bshk", h, bp["attn"]["wq"])
        kk = jnp.einsum("bsd,dgk->bsgk", h, bp["attn"]["wk"])
        vv = jnp.einsum("bsd,dgk->bsgk", h, bp["attn"]["wv"])
        if cfg.pos_emb == "rope":
            q = apply_rope(q, positions, inv, rot)
            kk = apply_rope(kk, positions, inv, rot)
        upd = lambda c, new: jax.vmap(
            lambda cb_, nb, pb: jax.lax.dynamic_update_slice_in_dim(
                cb_, nb, pb, axis=0))(c, new, pos)
        k_cache, v_cache = upd(k_cache, kk), upd(v_cache, vv)
        k_cache = lshard(k_cache, "cache_batch", "cache_seq",
                         "cache_kv_heads", None)
        v_cache = lshard(v_cache, "cache_batch", "cache_seq",
                         "cache_kv_heads", None)
        qg = group_query_heads(q, cfg.n_kv_heads)
        o = decode_attention(qg, k_cache, v_cache, pos + 1)
        o = jnp.einsum("bshk,hkd->bsd", ungroup_heads(o), bp["attn"]["wo"])
        x = x + o
        x, _ = tfm.ffn_block_fwd(cfg, bp, x)
        return x, k_cache, v_cache

    def group_body(x, inp):
        gp, sts, cbs, kc, vc = inp
        x, st = jax.lax.scan(mamba_body, x, (gp, sts, cbs))
        x, kc, vc = attn_apply(x, kc, vc)
        return x, (st[0], st[1], kc, vc)

    x, (sst, scv, ks, vs) = jax.lax.scan(
        group_body, x, (params["groups"], cache["ssm_state"], cache["conv"],
                        cache["ak"], cache["av"]))
    new = {"ssm_state": sst, "conv": scv, "ak": ks, "av": vs, "pos": pos + 1}
    if "tail" in params:
        x, t = jax.lax.scan(mamba_body, x,
                            (params["tail"], cache["tail_state"],
                             cache["tail_conv"]))
        new["tail_state"], new["tail_conv"] = t
    x = norm(x, params["final_norm"], cfg.norm_type, cfg.norm_eps)
    return tfm.logits_fwd(cfg, params, x), new
