"""State-space / recurrent blocks: Mamba-2 (SSD) and xLSTM (mLSTM + sLSTM).

Mamba-2 uses the chunked SSD algorithm (intra-chunk quadratic + inter-chunk
state scan) — the same blocking the Pallas ``ssd_scan`` kernel implements, so
the pure-JAX path is both oracle and dry-run lowering path.

mLSTM uses the stabilized chunkwise-parallel form (exponential gating with a
running max-stabilizer carried across chunks). sLSTM is inherently sequential
(recurrent gate preactivations) and is implemented as a time scan.
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import LMConfig
from repro.models.layers import ParamDef, act_fn, mlp_defs, mlp_fwd, norm, \
    norm_defs, rmsnorm
from repro.sharding.partition import lshard

NEG_INF = -1e30


# ===========================================================================
# Mamba-2 (SSD)
# ===========================================================================

def mamba2_dims(cfg: LMConfig):
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    nheads = d_inner // s.head_dim
    conv_dim = d_inner + 2 * s.n_groups * s.d_state
    return d_inner, nheads, conv_dim


def mamba2_defs(cfg: LMConfig) -> Dict[str, ParamDef]:
    s = cfg.ssm
    d = cfg.d_model
    di, nh, conv_dim = mamba2_dims(cfg)
    proj_out = 2 * di + 2 * s.n_groups * s.d_state + nh
    dt = cfg.dtype
    return {
        "in_proj": ParamDef((d, proj_out), ("embed", "ssm_inner"), dtype=dt),
        "conv_w": ParamDef((s.d_conv, conv_dim), (None, "conv_dim"),
                           init="normal", dtype=dt),
        "conv_b": ParamDef((conv_dim,), ("conv_dim",), init="zeros", dtype=dt),
        "A_log": ParamDef((nh,), ("ssm_heads",), init="zeros", dtype="float32"),
        "dt_bias": ParamDef((nh,), ("ssm_heads",), init="zeros",
                            dtype="float32"),
        "D": ParamDef((nh,), ("ssm_heads",), init="ones", dtype="float32"),
        "norm": ParamDef((di,), ("ssm_inner",), init="ones", dtype="float32"),
        "out_proj": ParamDef((di, d), ("ssm_inner", "embed"), dtype=dt),
        "pre_norm": norm_defs(d, cfg.norm_type)["scale"],
    }


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv. x: (b, s, ch), w: (k, ch)."""
    k, ch = w.shape
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = jax.lax.conv_general_dilated(
        xp, w[:, None, :], window_strides=(1,), padding="VALID",
        dimension_numbers=("NWC", "WIO", "NWC"), feature_group_count=ch)
    return out + b


def ssd_scan(x: jax.Array, dt: jax.Array, A: jax.Array, B: jax.Array,
             C: jax.Array, chunk: int,
             init_state: Optional[jax.Array] = None):
    """Chunked state-space-dual scan.

    x: (b, s, nh, hd); dt: (b, s, nh); A: (nh,) (negative);
    B, C: (b, s, g, n) with nh % g == 0.
    Returns (y (b, s, nh, hd), final_state (b, nh, hd, n)).
    """
    b, s, nh, hd = x.shape
    g, n = B.shape[2], B.shape[3]
    rep = nh // g
    Q = min(chunk, s)
    s0 = s
    pad = (-s) % Q
    if pad:
        # dt=0 on padded steps => decay exp(0·A)=1 and zero state writes, so
        # the final state is exactly the state at s0 (padding is inert).
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0), (0, 0)))
        s += pad
    nc = s // Q
    Bh = jnp.repeat(B, rep, axis=2)            # (b, s, nh, n) broadcasted heads
    Ch = jnp.repeat(C, rep, axis=2)

    xc = x.reshape(b, nc, Q, nh, hd)
    dtc = dt.reshape(b, nc, Q, nh)
    Bc = Bh.reshape(b, nc, Q, nh, n)
    Cc = Ch.reshape(b, nc, Q, nh, n)

    dA = dtc * A                                # (b, nc, Q, nh) log-decay
    cum = jnp.cumsum(dA, axis=2)                # inclusive cumsum
    # intra-chunk: y[i] = sum_{j<=i} exp(cum_i - cum_j) dt_j (C_i·B_j) x_j
    Lmat = cum[:, :, :, None, :] - cum[:, :, None, :, :]      # (b,nc,Q,Q,nh)
    tri = jnp.tril(jnp.ones((Q, Q), bool))
    Lmat = jnp.where(tri[None, None, :, :, None], Lmat, NEG_INF)
    scores = jnp.einsum("bcqhn,bckhn->bcqkh", Cc, Bc,
                        preferred_element_type=jnp.float32)
    wgt = jnp.exp(Lmat) * scores * dtc[:, :, None, :, :]
    y_intra = jnp.einsum("bcqkh,bckhp->bcqhp", wgt.astype(x.dtype), xc,
                         preferred_element_type=jnp.float32)

    # chunk end-states: S_c = sum_j exp(cum_last - cum_j) dt_j B_j ⊗ x_j
    decay_end = jnp.exp(cum[:, :, -1:, :] - cum)              # (b,nc,Q,nh)
    sw = (decay_end * dtc).astype(x.dtype)
    states = jnp.einsum("bckhn,bckhp->bchnp", Bc * sw[..., None], xc,
                        preferred_element_type=jnp.float32)   # (b,nc,nh,n,hd)
    chunk_decay = jnp.exp(cum[:, :, -1, :])                   # (b, nc, nh)

    def carry_fn(S, inp):
        st, dec = inp                    # (b, nh, n, hd), (b, nh)
        S_new = S * dec[..., None, None] + st
        return S_new, S                  # emit state *entering* each chunk

    S0 = jnp.zeros((b, nh, n, hd), jnp.float32) if init_state is None \
        else init_state.transpose(0, 1, 3, 2)  # (b,nh,hd,n)->(b,nh,n,hd)
    Sf, S_in = jax.lax.scan(carry_fn, S0,
                            (states.transpose(1, 0, 2, 3, 4),
                             chunk_decay.transpose(1, 0, 2)))
    S_in = S_in.transpose(1, 0, 2, 3, 4)                      # (b,nc,nh,n,hd)

    y_inter = jnp.einsum("bcqhn,bchnp->bcqhp",
                         (Cc * jnp.exp(cum)[..., None]).astype(x.dtype), S_in,
                         preferred_element_type=jnp.float32)
    y = (y_intra + y_inter).reshape(b, s, nh, hd)[:, :s0]
    return y.astype(x.dtype), Sf.transpose(0, 1, 3, 2)        # (b,nh,hd,n)


def mamba2_split(cfg: LMConfig, zxbcdt: jax.Array):
    s = cfg.ssm
    di, nh, _ = mamba2_dims(cfg)
    gn = s.n_groups * s.d_state
    z, xin, B, C, dt = jnp.split(
        zxbcdt, [di, 2 * di, 2 * di + gn, 2 * di + 2 * gn], axis=-1)
    return z, xin, B, C, dt


def mamba2_block_fwd(cfg: LMConfig, p: Dict, x: jax.Array,
                     init_state: Optional[jax.Array] = None,
                     return_state: bool = False):
    """Full-sequence Mamba-2 block. x: (b, s, d)."""
    s_cfg = cfg.ssm
    b, s, d = x.shape
    di, nh, conv_dim = mamba2_dims(cfg)
    h = rmsnorm(x, p["pre_norm"], cfg.norm_eps)
    zxbcdt = jnp.einsum("bsd,dk->bsk", h, p["in_proj"])
    z, xin, B, C, dtr = mamba2_split(cfg, zxbcdt)
    xBC_raw = jnp.concatenate([xin, B, C], axis=-1)
    xBC = jax.nn.silu(_causal_conv(xBC_raw, p["conv_w"], p["conv_b"]))
    xin, B, C = jnp.split(xBC, [di, di + s_cfg.n_groups * s_cfg.d_state],
                          axis=-1)
    xh = xin.reshape(b, s, nh, s_cfg.head_dim)
    xh = lshard(xh, "act_batch", "act_seq", "act_ssm_inner", None)
    Bg = B.reshape(b, s, s_cfg.n_groups, s_cfg.d_state)
    Cg = C.reshape(b, s, s_cfg.n_groups, s_cfg.d_state)
    dt = jax.nn.softplus(dtr.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    y, Sf = ssd_scan(xh, dt, A, Bg, Cg, s_cfg.chunk_size, init_state)
    y = y + (p["D"][:, None] * xh.astype(jnp.float32)).astype(y.dtype)
    y = y.reshape(b, s, di)
    y = rmsnorm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    out = x + jnp.einsum("bsk,kd->bsd", y, p["out_proj"])
    out = lshard(out, "act_batch", "act_res_seq", "act_embed")
    if return_state:
        assert s >= s_cfg.d_conv - 1, "prefill shorter than conv window"
        conv_tail = xBC_raw[:, s - (s_cfg.d_conv - 1):, :]
        return out, (Sf, conv_tail)
    return out


def mamba2_decode_step(cfg: LMConfig, p: Dict, x: jax.Array,
                       state: jax.Array, conv_buf: jax.Array):
    """One-token Mamba-2 step. x: (b, 1, d); state: (b, nh, hd, n);
    conv_buf: (b, d_conv-1, conv_dim). Returns (out, state', conv_buf')."""
    s_cfg = cfg.ssm
    b = x.shape[0]
    di, nh, conv_dim = mamba2_dims(cfg)
    h = rmsnorm(x, p["pre_norm"], cfg.norm_eps)
    zxbcdt = jnp.einsum("bsd,dk->bsk", h, p["in_proj"])
    z, xin, B, C, dtr = mamba2_split(cfg, zxbcdt)
    xBC_new = jnp.concatenate([xin, B, C], axis=-1)           # (b, 1, conv_dim)
    win = jnp.concatenate([conv_buf, xBC_new], axis=1)        # (b, d_conv, ch)
    conv_out = jnp.einsum("bkc,kc->bc", win, p["conv_w"]) + p["conv_b"]
    xBC = jax.nn.silu(conv_out)[:, None, :]
    xin, B, C = jnp.split(xBC, [di, di + s_cfg.n_groups * s_cfg.d_state],
                          axis=-1)
    xh = xin.reshape(b, nh, s_cfg.head_dim)
    rep = nh // s_cfg.n_groups
    Bh = jnp.repeat(B.reshape(b, s_cfg.n_groups, s_cfg.d_state), rep, axis=1)
    Ch = jnp.repeat(C.reshape(b, s_cfg.n_groups, s_cfg.d_state), rep, axis=1)
    dt = jax.nn.softplus(dtr[:, 0].astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    dA = jnp.exp(dt * A)                                      # (b, nh)
    state = state * dA[..., None, None] + jnp.einsum(
        "bhp,bhn,bh->bhpn", xh.astype(jnp.float32), Bh.astype(jnp.float32), dt)
    y = jnp.einsum("bhpn,bhn->bhp", state, Ch.astype(jnp.float32))
    y = y + p["D"][:, None] * xh.astype(jnp.float32)
    y = y.reshape(b, 1, di).astype(x.dtype)
    y = rmsnorm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    out = x + jnp.einsum("bsk,kd->bsd", y, p["out_proj"])
    return out, state, win[:, 1:, :]


# ===========================================================================
# xLSTM — mLSTM (chunkwise parallel) + sLSTM (time scan)
# ===========================================================================

def xlstm_dims(cfg: LMConfig):
    x = cfg.xlstm
    di = x.proj_factor_m * cfg.d_model
    nh = cfg.n_heads
    return di, nh, di // nh


def mlstm_defs(cfg: LMConfig) -> Dict[str, ParamDef]:
    d = cfg.d_model
    di, nh, dh = xlstm_dims(cfg)
    dt = cfg.dtype
    return {
        "pre_norm": norm_defs(d, "rmsnorm"),
        "up": ParamDef((d, 2 * di), ("embed", "ssm_inner"), dtype=dt),
        "wq": ParamDef((di, di), ("ssm_inner", None), dtype=dt),
        "wk": ParamDef((di, di), ("ssm_inner", None), dtype=dt),
        "wv": ParamDef((di, di), ("ssm_inner", None), dtype=dt),
        "wif": ParamDef((di, 2 * nh), ("ssm_inner", None), dtype="float32"),
        "norm": ParamDef((di,), ("ssm_inner",), init="ones", dtype="float32"),
        "down": ParamDef((di, d), ("ssm_inner", "embed"), dtype=dt),
    }


def _headwise_rmsnorm(y: jax.Array, w: jax.Array, nh: int, eps: float):
    b, s, di = y.shape
    yh = y.reshape(b, s, nh, di // nh)
    yf = yh.astype(jnp.float32)
    var = jnp.mean(jnp.square(yf), axis=-1, keepdims=True)
    yn = (yf * jax.lax.rsqrt(var + eps)).reshape(b, s, di)
    return (yn * w.astype(jnp.float32)).astype(y.dtype)


def mlstm_chunkwise(q, k, v, li, lf, chunk: int, init=None):
    """Stabilized chunkwise mLSTM.

    q/k/v: (b, s, nh, dh); li/lf: (b, s, nh) log input/forget gates.
    Returns (h (b,s,nh,dh), (C, n, m) final states).
    """
    b, s, nh, dh = q.shape
    L = min(chunk, s)
    s0 = s
    pad = (-s) % L
    if pad:
        # li=-inf (no write), lf=0 (no decay) on padded steps keeps the final
        # (C, n, m) exactly equal to the state at s0.
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        li = jnp.pad(li, ((0, 0), (0, pad), (0, 0)),
                     constant_values=NEG_INF)
        lf = jnp.pad(lf, ((0, 0), (0, pad), (0, 0)))
        s += pad
    nc = s // L
    k = k / math.sqrt(dh)
    qc = q.reshape(b, nc, L, nh, dh)
    kc = k.reshape(b, nc, L, nh, dh)
    vc = v.reshape(b, nc, L, nh, dh)
    lic = li.reshape(b, nc, L, nh)
    lfc = lf.reshape(b, nc, L, nh)
    F = jnp.cumsum(lfc, axis=2)                                # inclusive
    Ftot = F[:, :, -1, :]                                      # (b, nc, nh)
    gvec = Ftot[:, :, None, :] - F + lic                       # (b, nc, L, nh)
    # intra-chunk decay D_ij = F_i - lf_i? -> F_i - F_j + li_j, j <= i
    Dm = F[:, :, :, None, :] - F[:, :, None, :, :] + lic[:, :, None, :, :]
    tri = jnp.tril(jnp.ones((L, L), bool))
    Dm = jnp.where(tri[None, None, :, :, None], Dm, NEG_INF)
    scores = jnp.einsum("bclhd,bcmhd->bclmh", qc, kc,
                        preferred_element_type=jnp.float32)

    if init is None:
        C0 = jnp.zeros((b, nh, dh, dh), jnp.float32)
        n0 = jnp.zeros((b, nh, dh), jnp.float32)
        m0 = jnp.full((b, nh), -jnp.inf, jnp.float32)
    else:
        C0, n0, m0 = init

    def chunk_step(carry, inp):
        C, n, m = carry
        q_c, k_c, v_c, F_c, Ftot_c, g_c, D_c, s_c = inp
        a = F_c + m[:, None, :]                                # (b, L, nh)
        m_intra = jnp.max(D_c, axis=2)                         # (b, L, nh)
        m_i = jnp.maximum(m_intra, a)
        w_inter = jnp.exp(a - m_i)                             # (b, L, nh)
        wgt = jnp.exp(D_c - m_i[:, :, None, :])                # (b, L, L, nh)
        qf = q_c.astype(jnp.float32)
        num = w_inter[..., None] * jnp.einsum("blhd,bhde->blhe", qf, C) \
            + jnp.einsum("blmh,bmhe->blhe", wgt * s_c,
                         v_c.astype(jnp.float32))
        den = w_inter * jnp.einsum("blhd,bhd->blh", qf, n) \
            + jnp.sum(wgt * s_c, axis=2)                       # (b, L, nh)
        h = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_i))[..., None]
        # state update to end of chunk
        m_new = jnp.maximum(m + Ftot_c, jnp.max(g_c, axis=1))  # (b, nh)
        sc_old = jnp.exp(m + Ftot_c - m_new)
        wg = jnp.exp(g_c - m_new[:, None, :])                  # (b, L, nh)
        C = C * sc_old[..., None, None] + jnp.einsum(
            "blhd,blhe->bhde", (k_c * wg[..., None]).astype(jnp.float32),
            v_c.astype(jnp.float32))
        n = n * sc_old[..., None] + jnp.einsum(
            "blhd->bhd", (k_c * wg[..., None]).astype(jnp.float32))
        return (C, n, m_new), h

    xs = (qc.transpose(1, 0, 2, 3, 4), kc.transpose(1, 0, 2, 3, 4),
          vc.transpose(1, 0, 2, 3, 4), F.transpose(1, 0, 2, 3),
          Ftot.transpose(1, 0, 2), gvec.transpose(1, 0, 2, 3),
          Dm.transpose(1, 0, 2, 3, 4), scores.transpose(1, 0, 2, 3, 4))
    (C, n, m), hs = jax.lax.scan(chunk_step, (C0, n0, m0), xs)
    h = hs.transpose(1, 0, 2, 3, 4).reshape(b, s, nh, dh)[:, :s0]
    return h.astype(q.dtype), (C, n, m)


def mlstm_block_fwd(cfg: LMConfig, p: Dict, x: jax.Array, init=None,
                    return_state: bool = False):
    di, nh, dh = xlstm_dims(cfg)
    b, s, d = x.shape
    h = norm(x, p["pre_norm"], "rmsnorm", cfg.norm_eps)
    up = jnp.einsum("bsd,dk->bsk", h, p["up"])
    xi, z = jnp.split(up, 2, axis=-1)
    xi = lshard(xi, "act_batch", "act_seq", "act_ssm_inner")
    q = jnp.einsum("bsk,kj->bsj", xi, p["wq"]).reshape(b, s, nh, dh)
    k = jnp.einsum("bsk,kj->bsj", xi, p["wk"]).reshape(b, s, nh, dh)
    v = jnp.einsum("bsk,kj->bsj", xi, p["wv"]).reshape(b, s, nh, dh)
    gates = jnp.einsum("bsk,kj->bsj", xi.astype(jnp.float32), p["wif"])
    li, lfr = jnp.split(gates, 2, axis=-1)                     # (b, s, nh)
    lf = jax.nn.log_sigmoid(lfr + 3.0)                         # forget bias +3
    y, state = mlstm_chunkwise(q, k, v, li, lf, cfg.xlstm.chunk_size, init)
    y = y.reshape(b, s, di) * jax.nn.silu(z)
    y = _headwise_rmsnorm(y, p["norm"], nh, cfg.norm_eps)
    out = x + jnp.einsum("bsk,kd->bsd", y, p["down"])
    out = lshard(out, "act_batch", "act_res_seq", "act_embed")
    if return_state:
        return out, state
    return out


def mlstm_decode_step(cfg: LMConfig, p: Dict, x: jax.Array, state):
    di, nh, dh = xlstm_dims(cfg)
    b = x.shape[0]
    C, n, m = state
    h = norm(x, p["pre_norm"], "rmsnorm", cfg.norm_eps)
    up = jnp.einsum("bsd,dk->bsk", h, p["up"])
    xi, z = jnp.split(up, 2, axis=-1)
    q = jnp.einsum("bsk,kj->bsj", xi, p["wq"]).reshape(b, nh, dh)
    k = jnp.einsum("bsk,kj->bsj", xi, p["wk"]).reshape(b, nh, dh) / math.sqrt(dh)
    v = jnp.einsum("bsk,kj->bsj", xi, p["wv"]).reshape(b, nh, dh)
    gates = jnp.einsum("bsk,kj->bsj", xi.astype(jnp.float32), p["wif"])[:, 0]
    li, lfr = jnp.split(gates, 2, axis=-1)                     # (b, nh)
    lf = jax.nn.log_sigmoid(lfr + 3.0)
    m_new = jnp.maximum(lf + m, li)
    iw = jnp.exp(li - m_new)
    fw = jnp.exp(lf + m - m_new)
    C = C * fw[..., None, None] + iw[..., None, None] * jnp.einsum(
        "bhd,bhe->bhde", k.astype(jnp.float32), v.astype(jnp.float32))
    n = n * fw[..., None] + iw[..., None] * k.astype(jnp.float32)
    num = jnp.einsum("bhd,bhde->bhe", q.astype(jnp.float32), C)
    den = jnp.einsum("bhd,bhd->bh", q.astype(jnp.float32), n)
    y = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_new))[..., None]
    y = y.reshape(b, 1, di).astype(x.dtype) * jax.nn.silu(z)
    y = _headwise_rmsnorm(y, p["norm"], nh, cfg.norm_eps)
    out = x + jnp.einsum("bsk,kd->bsd", y, p["down"])
    return out, (C, n, m_new)


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

def slstm_defs(cfg: LMConfig) -> Dict[str, ParamDef]:
    d = cfg.d_model
    nh = cfg.n_heads
    dh = d // nh
    ff = cfg.xlstm.ff_factor_s * d
    dt = cfg.dtype
    return {
        "pre_norm": norm_defs(d, "rmsnorm"),
        "W": ParamDef((d, 4 * d), ("embed", "ssm_inner"), dtype="float32"),
        "R": ParamDef((nh, dh, 4 * dh), ("ssm_heads", None, None),
                      scale=0.5, dtype="float32"),
        "b": ParamDef((4 * d,), ("ssm_inner",), init="zeros", dtype="float32"),
        "ffn_norm": norm_defs(d, "rmsnorm"),
        "ffn": mlp_defs(d, ff, True, dt),
    }


def slstm_cell_scan(cfg: LMConfig, p: Dict, x: jax.Array, init=None):
    """x: (b, s, d). Sequential exponential-gated sLSTM. Returns (y, states)."""
    b, s, d = x.shape
    nh = cfg.n_heads
    dh = d // nh
    pre = jnp.einsum("bsd,dk->bsk", x.astype(jnp.float32), p["W"]) + p["b"]
    if init is None:
        zeros = jnp.zeros((b, nh, dh), jnp.float32)
        init = (zeros, zeros + 1e-6, zeros,
                jnp.full((b, nh, dh), -jnp.inf, jnp.float32))

    def step(carry, u):
        c, n, h, m = carry                                    # (b, nh, dh)
        rec = jnp.einsum("bhd,hdk->bhk", h, p["R"])           # (b, nh, 4dh)
        u = u.reshape(b, nh, 4 * dh) + rec
        i_r, f_r, z_r, o_r = jnp.split(u, 4, axis=-1)
        z = jnp.tanh(z_r)
        o = jax.nn.sigmoid(o_r)
        lf = jax.nn.log_sigmoid(f_r + 3.0)
        m_new = jnp.maximum(lf + m, i_r)
        iw = jnp.exp(i_r - m_new)
        fw = jnp.exp(lf + m - m_new)
        c = fw * c + iw * z
        n = fw * n + iw
        h = o * c / jnp.maximum(n, 1e-6)
        return (c, n, h, m_new), h

    (c, n, h, m), ys = jax.lax.scan(step, init, pre.transpose(1, 0, 2))
    y = ys.transpose(1, 0, 2, 3).reshape(b, s, d)
    return y.astype(x.dtype), (c, n, h, m)


def slstm_block_fwd(cfg: LMConfig, p: Dict, x: jax.Array, init=None,
                    return_state: bool = False):
    h = norm(x, p["pre_norm"], "rmsnorm", cfg.norm_eps)
    y, state = slstm_cell_scan(cfg, p, h, init)
    x = x + y
    h = norm(x, p["ffn_norm"], "rmsnorm", cfg.norm_eps)
    x = x + mlp_fwd(p["ffn"], h, "silu", True)
    x = lshard(x, "act_batch", "act_res_seq", "act_embed")
    if return_state:
        return x, state
    return x


def slstm_decode_step(cfg: LMConfig, p: Dict, x: jax.Array, state):
    h = norm(x, p["pre_norm"], "rmsnorm", cfg.norm_eps)
    y, state = slstm_cell_scan(cfg, p, h, state)
    x = x + y
    h = norm(x, p["ffn_norm"], "rmsnorm", cfg.norm_eps)
    x = x + mlp_fwd(p["ffn"], h, "silu", True)
    return x, state
