"""Unified model API over all architecture families.

Functions are family-dispatched but share one signature so the scheduler,
trainer, server, dry-run and benchmarks are architecture-agnostic:

    defs   = param_defs(cfg)                 # ParamDef tree (shapes + axes)
    params = init_params(cfg, key)
    logits, aux = forward(cfg, params, tokens, prefix_emb, remat=...)
    logits, cache = prefill(cfg, params, tokens, prefix_emb, max_len=...)
    logits, cache = decode_step(cfg, params, cache, tokens)
    cache  = init_cache(cfg, batch, max_len, abstract=...)
    specs  = input_specs(cfg, shape)         # ShapeDtypeStruct stand-ins
"""
from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import LMConfig, ShapeSuite
from repro.models import hybrid as hyb
from repro.models import ssm as ssm_lib
from repro.models import transformer as tfm
from repro.models.layers import (ParamDef, abstract_from_defs, axes_from_defs,
                                 init_from_defs, norm)

ATTN_FAMILIES = ("dense", "vlm", "audio", "moe")


# ---------------------------------------------------------------------------
# xLSTM model assembly (blocks live in models/ssm.py)
# ---------------------------------------------------------------------------

def _xlstm_layout(cfg: LMConfig):
    every = cfg.xlstm.slstm_every
    assert cfg.n_layers % every == 0, (cfg.n_layers, every)
    return cfg.n_layers // every, every - 1   # (n_pairs, mlstm_per_pair)


def _xlstm_defs(cfg: LMConfig) -> Dict:
    n_pairs, n_m = _xlstm_layout(cfg)
    return {
        "embed": ParamDef((cfg.vocab, cfg.d_model), ("vocab", "embed"),
                          scale=cfg.d_model ** 0.5, dtype=cfg.dtype),
        "m": tfm.stacked(tfm.stacked(ssm_lib.mlstm_defs(cfg), n_m), n_pairs),
        "s": tfm.stacked(ssm_lib.slstm_defs(cfg), n_pairs),
        "final_norm": tfm.norm_defs(cfg.d_model, cfg.norm_type),
        "unembed": ParamDef((cfg.d_model, cfg.vocab), ("embed", "vocab"),
                            dtype=cfg.dtype),
    }


def _xlstm_forward(cfg, params, tokens, prefix_emb=None, remat=False,
                   return_hidden=False):
    x, _ = tfm.embed_tokens(cfg, params, tokens, prefix_emb)

    def pair_body(x, pp):
        mp, sp = pp

        def m_body(x, bp):
            return ssm_lib.mlstm_block_fwd(cfg, bp, x), None

        x, _ = jax.lax.scan(m_body, x, mp)
        x = ssm_lib.slstm_block_fwd(cfg, sp, x)
        return x, None

    if remat:
        pair_body = jax.checkpoint(pair_body, prevent_cse=False)
    x, _ = jax.lax.scan(pair_body, x, (params["m"], params["s"]))
    x = norm(x, params["final_norm"], cfg.norm_type, cfg.norm_eps)
    if return_hidden:
        return x, jnp.zeros((), jnp.float32)
    return tfm.logits_fwd(cfg, params, x), jnp.zeros((), jnp.float32)


def _xlstm_init_cache(cfg, batch, max_len, abstract=False):
    n_pairs, n_m = _xlstm_layout(cfg)
    di, nh, dh = ssm_lib.xlstm_dims(cfg)
    dh_s = cfg.d_model // cfg.n_heads
    mk = (lambda sh, d: jax.ShapeDtypeStruct(sh, d)) if abstract \
        else (lambda sh, d: jnp.zeros(sh, d))
    neg = (lambda sh, d: jax.ShapeDtypeStruct(sh, d)) if abstract \
        else (lambda sh, d: jnp.full(sh, -jnp.inf, d))
    return {
        "mC": mk((n_pairs, n_m, batch, nh, dh, dh), jnp.float32),
        "mn": mk((n_pairs, n_m, batch, nh, dh), jnp.float32),
        "mm": neg((n_pairs, n_m, batch, nh), jnp.float32),
        "sc": mk((n_pairs, batch, cfg.n_heads, dh_s), jnp.float32),
        "sn": mk((n_pairs, batch, cfg.n_heads, dh_s), jnp.float32),
        "sh": mk((n_pairs, batch, cfg.n_heads, dh_s), jnp.float32),
        "sm": neg((n_pairs, batch, cfg.n_heads, dh_s), jnp.float32),
        "pos": mk((batch,), jnp.int32),
    }


def _xlstm_cache_axes(cfg):
    return {
        "mC": (None, None, "cache_batch", "ssm_heads", None, None),
        "mn": (None, None, "cache_batch", "ssm_heads", None),
        "mm": (None, None, "cache_batch", "ssm_heads"),
        "sc": (None, "cache_batch", "ssm_heads", None),
        "sn": (None, "cache_batch", "ssm_heads", None),
        "sh": (None, "cache_batch", "ssm_heads", None),
        "sm": (None, "cache_batch", "ssm_heads", None),
        "pos": ("cache_batch",),
    }


def _xlstm_prefill(cfg, params, tokens, prefix_emb=None, max_len=None):
    x, _ = tfm.embed_tokens(cfg, params, tokens, prefix_emb)
    b, s = x.shape[0], x.shape[1]

    def pair_body(x, pp):
        mp, sp = pp

        def m_body(x, bp):
            x, st = ssm_lib.mlstm_block_fwd(cfg, bp, x, return_state=True)
            return x, st

        x, mst = jax.lax.scan(m_body, x, mp)
        x, sst = ssm_lib.slstm_block_fwd(cfg, sp, x, return_state=True)
        return x, (mst, sst)

    x, (mst, sst) = jax.lax.scan(pair_body, x, (params["m"], params["s"]))
    x = norm(x, params["final_norm"], cfg.norm_type, cfg.norm_eps)
    cache = {"mC": mst[0], "mn": mst[1], "mm": mst[2],
             "sc": sst[0], "sn": sst[1], "sh": sst[2], "sm": sst[3],
             "pos": jnp.full((b,), s, jnp.int32)}
    return tfm.logits_fwd(cfg, params, x[:, -1:, :]), cache


def _xlstm_decode(cfg, params, cache, tokens):
    x = jnp.take(params["embed"], tokens, axis=0)

    def pair_body(x, inp):
        mp, sp, mC, mn, mm, sc, sn, sh, sm = inp

        def m_body(x, minp):
            bp, C, n, m_ = minp
            x, st = ssm_lib.mlstm_decode_step(cfg, bp, x, (C, n, m_))
            return x, st

        x, mst = jax.lax.scan(m_body, x, (mp, mC, mn, mm))
        x, sst = ssm_lib.slstm_decode_step(cfg, sp, x, (sc, sn, sh, sm))
        return x, (mst, sst)

    x, (mst, sst) = jax.lax.scan(
        pair_body, x, (params["m"], params["s"], cache["mC"], cache["mn"],
                       cache["mm"], cache["sc"], cache["sn"], cache["sh"],
                       cache["sm"]))
    x = norm(x, params["final_norm"], cfg.norm_type, cfg.norm_eps)
    new = {"mC": mst[0], "mn": mst[1], "mm": mst[2],
           "sc": sst[0], "sn": sst[1], "sh": sst[2], "sm": sst[3],
           "pos": cache["pos"] + 1}
    return tfm.logits_fwd(cfg, params, x), new


# ---------------------------------------------------------------------------
# dispatch tables
# ---------------------------------------------------------------------------

def param_defs(cfg: LMConfig) -> Dict:
    if cfg.family in ATTN_FAMILIES:
        return tfm.transformer_defs(cfg)
    if cfg.family == "ssm":
        return _xlstm_defs(cfg)
    if cfg.family == "hybrid":
        return hyb.hybrid_defs(cfg)
    raise ValueError(cfg.family)


def init_params(cfg: LMConfig, key: jax.Array):
    return init_from_defs(param_defs(cfg), key)


def abstract_params(cfg: LMConfig):
    return abstract_from_defs(param_defs(cfg))


def param_axes(cfg: LMConfig):
    return axes_from_defs(param_defs(cfg))


def forward(cfg: LMConfig, params, tokens, prefix_emb=None, remat=False,
            return_hidden=False):
    if cfg.family in ATTN_FAMILIES:
        return tfm.forward(cfg, params, tokens, prefix_emb, remat,
                           return_hidden)
    if cfg.family == "ssm":
        return _xlstm_forward(cfg, params, tokens, prefix_emb, remat,
                              return_hidden)
    if cfg.family == "hybrid":
        return hyb.forward(cfg, params, tokens, prefix_emb, remat,
                           return_hidden)
    raise ValueError(cfg.family)


def unembed_weight(cfg: LMConfig, params):
    return params["embed"].T if cfg.tie_embeddings else params["unembed"]


def prefill(cfg: LMConfig, params, tokens, prefix_emb=None, max_len=None):
    if cfg.family in ATTN_FAMILIES:
        return tfm.prefill(cfg, params, tokens, prefix_emb, max_len)
    if cfg.family == "ssm":
        return _xlstm_prefill(cfg, params, tokens, prefix_emb, max_len)
    if cfg.family == "hybrid":
        return hyb.prefill(cfg, params, tokens, prefix_emb, max_len)
    raise ValueError(cfg.family)


def decode_step(cfg: LMConfig, params, cache, tokens):
    if cfg.family in ATTN_FAMILIES:
        return tfm.decode_step(cfg, params, cache, tokens)
    if cfg.family == "ssm":
        return _xlstm_decode(cfg, params, cache, tokens)
    if cfg.family == "hybrid":
        return hyb.decode_step(cfg, params, cache, tokens)
    raise ValueError(cfg.family)


def init_cache(cfg: LMConfig, batch: int, max_len: int, abstract=False):
    if cfg.family in ATTN_FAMILIES:
        return tfm.init_cache(cfg, batch, max_len, abstract)
    if cfg.family == "ssm":
        return _xlstm_init_cache(cfg, batch, max_len, abstract)
    if cfg.family == "hybrid":
        return hyb.init_cache(cfg, batch, max_len, abstract)
    raise ValueError(cfg.family)


def cache_axes(cfg: LMConfig):
    if cfg.family in ATTN_FAMILIES:
        return tfm.cache_axes(cfg)
    if cfg.family == "ssm":
        return _xlstm_cache_axes(cfg)
    if cfg.family == "hybrid":
        return hyb.cache_axes(cfg)
    raise ValueError(cfg.family)


# ---------------------------------------------------------------------------
# input specs (ShapeDtypeStruct stand-ins — never allocates)
# ---------------------------------------------------------------------------

def text_len(cfg: LMConfig, shape: ShapeSuite) -> int:
    return shape.seq_len - cfg.prefix_len


def input_specs(cfg: LMConfig, shape: ShapeSuite) -> Dict:
    """Abstract inputs for one (arch × shape) dry-run cell."""
    B = shape.global_batch
    i32 = jnp.int32
    if shape.kind == "train":
        s = text_len(cfg, shape)
        specs = {"tokens": jax.ShapeDtypeStruct((B, s), i32),
                 "labels": jax.ShapeDtypeStruct((B, s), i32)}
    elif shape.kind == "prefill":
        s = text_len(cfg, shape)
        specs = {"tokens": jax.ShapeDtypeStruct((B, s), i32)}
    else:  # decode / long_decode: one new token against a seq_len cache
        specs = {"tokens": jax.ShapeDtypeStruct((B, 1), i32),
                 "cache": init_cache(cfg, B, shape.seq_len, abstract=True)}
    if cfg.prefix_len and shape.kind in ("train", "prefill"):
        specs["prefix_emb"] = jax.ShapeDtypeStruct(
            (B, cfg.prefix_len, cfg.d_model), cfg.activation_dtype)
    return specs


def input_axes(cfg: LMConfig, shape: ShapeSuite) -> Dict:
    """Logical sharding axes matching :func:`input_specs`."""
    if shape.kind in ("train", "prefill"):
        axes = {"tokens": ("act_batch", "act_seq")}
        if shape.kind == "train":
            axes["labels"] = ("act_batch", "act_seq")
        if cfg.prefix_len:
            axes["prefix_emb"] = ("act_batch", "act_seq", "act_embed")
        return axes
    return {"tokens": ("act_batch", None), "cache": cache_axes(cfg)}
