from repro.models.model import (param_defs, init_params, abstract_params,
                                param_axes, forward, prefill, decode_step,
                                init_cache, cache_axes, input_specs,
                                input_axes, text_len)

__all__ = ["param_defs", "init_params", "abstract_params", "param_axes",
           "forward", "prefill", "decode_step", "init_cache", "cache_axes",
           "input_specs", "input_axes", "text_len"]
