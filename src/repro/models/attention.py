"""Chunked (flash-style) attention in pure JAX.

This is simultaneously:
  * the dry-run lowering path (algorithmically identical online-softmax
    chunking to the Pallas kernel, so HLO bytes are representative),
  * the numerical oracle for ``repro.kernels.flash_attention``,
  * the long-context path (memory is O(chunk), never O(seq²)).

Two causal schedules:
  * ``block_skip=False`` — rectangle schedule: every (q-chunk × kv-chunk) block
    is computed and masked. Simple; wastes ~2× FLOPs on causal masks.
  * ``block_skip=True`` — triangular schedule (beyond-paper §Perf
    optimization): only blocks with kv_chunk_start <= q_chunk_end are
    computed, recovering the ~2× for long sequences.

GQA layout convention: q is grouped as (b, s, g, m, hd) where g = n_kv_heads
and m = n_heads // n_kv_heads; k/v are (b, s, g, hd).
"""
from __future__ import annotations

import math
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

NEG_INF = -1e30


def group_query_heads(q: jax.Array, n_kv_heads: int) -> jax.Array:
    """(b, s, n_heads, hd) -> (b, s, g, m, hd)."""
    b, s, h, hd = q.shape
    return q.reshape(b, s, n_kv_heads, h // n_kv_heads, hd)


def ungroup_heads(o: jax.Array) -> jax.Array:
    b, s, g, m, hd = o.shape
    return o.reshape(b, s, g * m, hd)


def _block(q_blk, k_blk, v_blk, m_prev, l_prev, acc, row0, col0,
           causal: bool, kv_len, scale: float):
    """One online-softmax block update.

    q_blk: (b, qc, g, m, hd)   k_blk/v_blk: (b, kc, g, hd)
    m_prev/l_prev: (b, g, m, qc)  acc: (b, qc, g, m, hd) fp32
    """
    qc, kc = q_blk.shape[1], k_blk.shape[1]
    s = jnp.einsum("bqgmh,bkgh->bgmqk", q_blk, k_blk,
                   preferred_element_type=jnp.float32) * scale
    rows = row0 + jnp.arange(qc)
    cols = col0 + jnp.arange(kc)
    mask = None
    if causal:
        mask = rows[:, None] >= cols[None, :]
    if kv_len is not None:
        lm = cols[None, :] < jnp.reshape(kv_len, (-1, 1))        # (b, kc)
        lm = lm[:, None, None, None, :]                          # (b,1,1,1,kc)
        mask = lm if mask is None else jnp.logical_and(mask, lm)
    if mask is not None:
        s = jnp.where(mask, s, NEG_INF)
    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    p = jnp.exp(s - m_new[..., None])
    corr = jnp.exp(m_prev - m_new)
    l_new = l_prev * corr + p.sum(axis=-1)
    pv = jnp.einsum("bgmqk,bkgh->bqgmh", p.astype(v_blk.dtype), v_blk,
                    preferred_element_type=jnp.float32)
    acc = acc * corr.transpose(0, 3, 1, 2)[..., None] + pv
    return m_new, l_new, acc


def chunked_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                      causal: bool = True,
                      q_chunk: int = 512, kv_chunk: int = 1024,
                      kv_len: Optional[jax.Array] = None,
                      q_offset: int = 0,
                      block_skip: bool = False) -> jax.Array:
    """Online-softmax attention over (q, kv) chunks.

    q: (b, sq, g, m, hd); k, v: (b, skv, g, hd). Returns (b, sq, g, m, hd).
    ``kv_len`` (scalar or (b,)) masks cache positions >= kv_len.
    ``q_offset``: absolute position of q[0] (for decode-with-history).
    """
    b, sq, g, m, hd = q.shape
    skv = k.shape[1]
    sq0, skv0 = sq, skv
    qc = min(q_chunk, sq)
    kc = min(kv_chunk, skv)
    qpad, kpad = (-sq) % qc, (-skv) % kc
    if qpad:
        q = jnp.pad(q, ((0, 0), (0, qpad), (0, 0), (0, 0), (0, 0)))
        sq += qpad
    if kpad:
        k = jnp.pad(k, ((0, 0), (0, kpad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, kpad), (0, 0), (0, 0)))
        skv += kpad
        if kv_len is None:
            kv_len = jnp.full((b,), skv0, jnp.int32)
    nq, nk = sq // qc, skv // kc
    scale = 1.0 / math.sqrt(hd)
    qs = q.reshape(b, nq, qc, g, m, hd).transpose(1, 0, 2, 3, 4, 5)
    ks = k.reshape(b, nk, kc, g, hd).transpose(1, 0, 2, 3, 4)
    vs = v.reshape(b, nk, kc, g, hd).transpose(1, 0, 2, 3, 4)

    if kv_len is not None:
        kv_len = jnp.asarray(kv_len).reshape(-1)

    if not block_skip:
        def outer(_, inp):
            qi, q_blk = inp
            init = (jnp.full((b, g, m, qc), NEG_INF, jnp.float32),
                    jnp.zeros((b, g, m, qc), jnp.float32),
                    jnp.zeros((b, qc, g, m, hd), jnp.float32))

            @jax.checkpoint
            def inner(carry, kinp):
                # checkpointed: the (qc×kc) probability block is recomputed in
                # the backward pass instead of being stored per step
                kj, k_blk, v_blk = kinp
                mx, l, acc = _block(
                    q_blk, k_blk, v_blk, *carry,
                    row0=q_offset + qi * qc, col0=kj * kc,
                    causal=causal, kv_len=kv_len, scale=scale)
                return (mx, l, acc), None

            (mx, l, acc), _ = jax.lax.scan(
                inner, init, (jnp.arange(nk), ks, vs))
            out = acc / jnp.maximum(l, 1e-37).transpose(0, 3, 1, 2)[..., None]
            return None, out.astype(q.dtype)

        _, outs = jax.lax.scan(outer, None, (jnp.arange(nq), qs))
        out = outs.transpose(1, 0, 2, 3, 4, 5).reshape(b, sq, g, m, hd)
        return out[:, :sq0]

    # ---- triangular block schedule (causal only) ------------------------
    if not causal:
        raise ValueError("block_skip requires causal attention")
    pairs = [(qi, kj) for qi in range(nq) for kj in range(nk)
             if kj * kc <= q_offset + qi * qc + qc - 1]
    qi_arr = jnp.asarray(np.array([p[0] for p in pairs], np.int32))
    kj_arr = jnp.asarray(np.array([p[1] for p in pairs], np.int32))

    init = (jnp.full((nq, b, g, m, qc), NEG_INF, jnp.float32),
            jnp.zeros((nq, b, g, m, qc), jnp.float32),
            jnp.zeros((nq, b, qc, g, m, hd), jnp.float32))

    @jax.checkpoint
    def body(carry, inp):
        m_all, l_all, acc_all = carry
        qi, kj = inp
        q_blk = jax.lax.dynamic_index_in_dim(qs, qi, 0, keepdims=False)
        k_blk = jax.lax.dynamic_index_in_dim(ks, kj, 0, keepdims=False)
        v_blk = jax.lax.dynamic_index_in_dim(vs, kj, 0, keepdims=False)
        mx = jax.lax.dynamic_index_in_dim(m_all, qi, 0, keepdims=False)
        l = jax.lax.dynamic_index_in_dim(l_all, qi, 0, keepdims=False)
        acc = jax.lax.dynamic_index_in_dim(acc_all, qi, 0, keepdims=False)
        mx, l, acc = _block(q_blk, k_blk, v_blk, mx, l, acc,
                            row0=q_offset + qi * qc, col0=kj * kc,
                            causal=True, kv_len=kv_len, scale=scale)
        m_all = jax.lax.dynamic_update_index_in_dim(m_all, mx, qi, 0)
        l_all = jax.lax.dynamic_update_index_in_dim(l_all, l, qi, 0)
        acc_all = jax.lax.dynamic_update_index_in_dim(acc_all, acc, qi, 0)
        return (m_all, l_all, acc_all), None

    (m_all, l_all, acc_all), _ = jax.lax.scan(body, init, (qi_arr, kj_arr))
    out = acc_all / jnp.maximum(l_all, 1e-37).transpose(0, 1, 4, 2, 3)[..., None]
    out = out.transpose(1, 0, 2, 3, 4, 5).reshape(b, sq, g, m, hd) \
        .astype(q.dtype)
    return out[:, :sq0]


def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     kv_len: jax.Array) -> jax.Array:
    """Single-position attention against a (padded) KV cache.

    q: (b, 1, g, m, hd); caches: (b, S, g, hd); kv_len: scalar or (b,).
    Unchunked: XLA/GSPMD partitions the softmax over a sequence-sharded cache
    (flash-decode-style partial merge) without help.
    """
    b, _, g, m, hd = q.shape
    S = k_cache.shape[1]
    scale = 1.0 / math.sqrt(hd)
    s = jnp.einsum("bqgmh,bkgh->bgmqk", q, k_cache,
                   preferred_element_type=jnp.float32) * scale
    mask = jnp.arange(S)[None, :] < jnp.reshape(jnp.asarray(kv_len), (-1, 1))
    s = jnp.where(mask[:, None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bgmqk,bkgh->bqgmh", p.astype(v_cache.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# flash-style custom VJP (train-cell §Perf lever)
#
# The autodiff of the chunked forward either stores per-block probabilities
# (O(s²/chunk) residuals) or, checkpointed, recomputes whole blocks through
# HBM. The flash backward saves only (o, L=m+log l) per row and rebuilds each
# probability block in VMEM-sized tiles:  p = exp(qkᵀ·scale − L);
# dv += pᵀ do;  ds = p∘(do vᵀ − Δ);  dq += ds k;  dk += dsᵀ q,  Δ = Σ(do∘o).
# ---------------------------------------------------------------------------

from functools import partial as _partial


@_partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def flash_attention_jax(q: jax.Array, k: jax.Array, v: jax.Array,
                        causal: bool = True, q_chunk: int = 512,
                        kv_chunk: int = 1024) -> jax.Array:
    """chunked_attention with a flash backward. Same layout/semantics as
    :func:`chunked_attention` (no kv_len/q_offset: training path)."""
    out, _ = _flash_fwd_stats(q, k, v, causal, q_chunk, kv_chunk)
    return out


def _flash_fwd_stats(q, k, v, causal, q_chunk, kv_chunk):
    b, sq, g, m, hd = q.shape
    skv = k.shape[1]
    qc, kc = min(q_chunk, sq), min(kv_chunk, skv)
    assert sq % qc == 0 and skv % kc == 0, (sq, qc, skv, kc)
    nq, nk = sq // qc, skv // kc
    scale = 1.0 / math.sqrt(hd)
    qs = q.reshape(b, nq, qc, g, m, hd).transpose(1, 0, 2, 3, 4, 5)
    ks = k.reshape(b, nk, kc, g, hd).transpose(1, 0, 2, 3, 4)
    vs = v.reshape(b, nk, kc, g, hd).transpose(1, 0, 2, 3, 4)

    def outer(_, inp):
        qi, q_blk = inp
        init = (jnp.full((b, g, m, qc), NEG_INF, jnp.float32),
                jnp.zeros((b, g, m, qc), jnp.float32),
                jnp.zeros((b, qc, g, m, hd), jnp.float32))

        @jax.checkpoint
        def inner(carry, kinp):
            kj, k_blk, v_blk = kinp
            return _block(q_blk, k_blk, v_blk, *carry, row0=qi * qc,
                          col0=kj * kc, causal=causal, kv_len=None,
                          scale=scale), None

        (mx, l, acc), _ = jax.lax.scan(inner, init,
                                       (jnp.arange(nk), ks, vs))
        out = acc / jnp.maximum(l, 1e-37).transpose(0, 3, 1, 2)[..., None]
        L = mx + jnp.log(jnp.maximum(l, 1e-37))          # (b, g, m, qc)
        return None, (out.astype(q.dtype), L)

    _, (outs, Ls) = jax.lax.scan(outer, None, (jnp.arange(nq), qs))
    out = outs.transpose(1, 0, 2, 3, 4, 5).reshape(b, sq, g, m, hd)
    return out, Ls                                        # Ls: (nq, b, g, m, qc)


def _flash_fwd_rule(q, k, v, causal, q_chunk, kv_chunk):
    out, Ls = _flash_fwd_stats(q, k, v, causal, q_chunk, kv_chunk)
    return out, (q, k, v, out, Ls)


def _flash_bwd_rule(causal, q_chunk, kv_chunk, res, dout):
    q, k, v, out, Ls = res
    b, sq, g, m, hd = q.shape
    skv = k.shape[1]
    qc, kc = min(q_chunk, sq), min(kv_chunk, skv)
    nq, nk = sq // qc, skv // kc
    scale = 1.0 / math.sqrt(hd)
    qs = q.reshape(b, nq, qc, g, m, hd).transpose(1, 0, 2, 3, 4, 5)
    ks = k.reshape(b, nk, kc, g, hd).transpose(1, 0, 2, 3, 4)
    vs = v.reshape(b, nk, kc, g, hd).transpose(1, 0, 2, 3, 4)
    dos = dout.reshape(b, nq, qc, g, m, hd).transpose(1, 0, 2, 3, 4, 5)
    os_ = out.reshape(b, nq, qc, g, m, hd).transpose(1, 0, 2, 3, 4, 5)
    # Δ[i] = Σ_h do∘o  per row: (nq, b, g, m, qc)
    delta = jnp.einsum("nbqgmh,nbqgmh->nbgmq", dos.astype(jnp.float32),
                       os_.astype(jnp.float32))

    def outer(carry, inp):
        dk_acc, dv_acc = carry                 # (nk, b, kc, g, hd) fp32
        qi, q_blk, do_blk, L_blk, d_blk = inp

        @jax.checkpoint
        def inner(dq, kinp):
            kj, k_blk, v_blk = kinp
            s = jnp.einsum("bqgmh,bkgh->bgmqk", q_blk, k_blk,
                           preferred_element_type=jnp.float32) * scale
            if causal:
                rows = qi * qc + jnp.arange(qc)
                cols = kj * kc + jnp.arange(kc)
                s = jnp.where(rows[:, None] >= cols[None, :], s, NEG_INF)
            p = jnp.exp(s - L_blk[..., None])                 # (b,g,m,qc,kc)
            dv = jnp.einsum("bgmqk,bqgmh->bkgh", p,
                            do_blk.astype(jnp.float32))
            dp = jnp.einsum("bqgmh,bkgh->bgmqk",
                            do_blk.astype(jnp.float32), v_blk)
            ds = p * (dp - d_blk[..., None]) * scale
            dq = dq + jnp.einsum("bgmqk,bkgh->bqgmh", ds, k_blk)
            dk = jnp.einsum("bgmqk,bqgmh->bkgh", ds, q_blk)
            return dq, (dk, dv)

        dq0 = jnp.zeros((b, qc, g, m, hd), jnp.float32)
        dq, (dks, dvs) = jax.lax.scan(inner, dq0,
                                      (jnp.arange(nk), ks, vs))
        return (dk_acc + dks, dv_acc + dvs), dq

    zero_kv = jnp.zeros((nk, b, kc, g, hd), jnp.float32)
    (dk_all, dv_all), dqs = jax.lax.scan(
        outer, (zero_kv, zero_kv), (jnp.arange(nq), qs, dos, Ls, delta))
    dq = dqs.transpose(1, 0, 2, 3, 4, 5).reshape(b, sq, g, m, hd)
    dk = dk_all.transpose(1, 0, 2, 3, 4).reshape(b, skv, g, hd)
    dv = dv_all.transpose(1, 0, 2, 3, 4).reshape(b, skv, g, hd)
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


flash_attention_jax.defvjp(_flash_fwd_rule, _flash_bwd_rule)


def reference_attention(q, k, v, *, causal=True, kv_len=None, q_offset=0):
    """O(s²)-memory oracle used by tests (never by the system itself)."""
    b, sq, g, m, hd = q.shape
    skv = k.shape[1]
    s = jnp.einsum("bqgmh,bkgh->bgmqk", q, k,
                   preferred_element_type=jnp.float32) / math.sqrt(hd)
    rows = q_offset + jnp.arange(sq)
    cols = jnp.arange(skv)
    mask = jnp.ones((sq, skv), bool)
    if causal:
        mask = rows[:, None] >= cols[None, :]
    if kv_len is not None:
        lm = cols[None, :] < jnp.reshape(jnp.asarray(kv_len), (-1, 1))
        s = jnp.where(lm[:, None, None, None, :], s, NEG_INF)
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bgmqk,bkgh->bqgmh", p.astype(v.dtype), v,
                      preferred_element_type=jnp.float32).astype(q.dtype)
