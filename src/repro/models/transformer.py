"""Decoder-only transformer backbone (dense / vlm / audio / moe families).

Layers are stacked and iterated with ``jax.lax.scan`` so the lowered HLO stays
small at 512 partitions (the HLO-walking cost model in ``benchmarks.hlo_cost``
scales loop-body costs by trip count for the roofline).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import LMConfig
from repro.models import moe as moe_lib
from repro.models.attention import (chunked_attention, decode_attention,
                                    group_query_heads, ungroup_heads)
from repro.models.layers import (ParamDef, apply_rope, mlp_defs, mlp_fwd,
                                 norm, norm_defs, rope_freqs)
from repro.sharding.partition import lshard


# ---------------------------------------------------------------------------
# parameter specs
# ---------------------------------------------------------------------------

def attn_defs(cfg: LMConfig) -> Dict[str, ParamDef]:
    d, h, g, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    dt = cfg.dtype
    return {
        "wq": ParamDef((d, h, hd), ("embed", "heads", "head_dim"), dtype=dt),
        "wk": ParamDef((d, g, hd), ("embed", "kv_heads", "head_dim"), dtype=dt),
        "wv": ParamDef((d, g, hd), ("embed", "kv_heads", "head_dim"), dtype=dt),
        "wo": ParamDef((h, hd, d), ("heads", "head_dim", "embed"), dtype=dt),
    }


def block_defs(cfg: LMConfig) -> Dict:
    out = {
        "attn": attn_defs(cfg),
        "attn_norm": norm_defs(cfg.d_model, cfg.norm_type),
        "mlp_norm": norm_defs(cfg.d_model, cfg.norm_type),
    }
    if cfg.moe:
        out["moe"] = moe_lib.moe_defs(cfg)
    else:
        out["mlp"] = mlp_defs(cfg.d_model, cfg.d_ff, cfg.gated_mlp, cfg.dtype)
    return out


def stacked(defs, n: int):
    """Stack per-layer ParamDefs along a leading `layers` axis."""
    return jax.tree.map(
        lambda d: ParamDef((n,) + d.shape, ("layers",) + d.axes, d.init,
                           d.scale, d.dtype),
        defs, is_leaf=lambda x: isinstance(x, ParamDef))


def transformer_defs(cfg: LMConfig) -> Dict:
    d = cfg.d_model
    out = {
        "embed": ParamDef((cfg.vocab, d), ("vocab", "embed"), scale=d ** 0.5,
                          dtype=cfg.dtype),
        "blocks": stacked(block_defs(cfg), cfg.n_layers),
        "final_norm": norm_defs(d, cfg.norm_type),
    }
    if not cfg.tie_embeddings:
        out["unembed"] = ParamDef((d, cfg.vocab), ("embed", "vocab"),
                                  dtype=cfg.dtype)
    if cfg.pos_emb == "learned":
        out["pos_emb"] = ParamDef((cfg.max_seq_len, d), ("pos", "embed"),
                                  dtype=cfg.dtype)
    return out


# ---------------------------------------------------------------------------
# forward pieces
# ---------------------------------------------------------------------------

def _qkv(cfg: LMConfig, p: Dict, h: jax.Array, positions: jax.Array):
    inv, rot = rope_freqs(cfg.resolved_head_dim, cfg.rope_fraction,
                          cfg.rope_theta)
    q = jnp.einsum("bsd,dhk->bshk", h, p["wq"])
    k = jnp.einsum("bsd,dgk->bsgk", h, p["wk"])
    v = jnp.einsum("bsd,dgk->bsgk", h, p["wv"])
    q = lshard(q, "act_batch", "act_seq", "act_heads", None)
    k = lshard(k, "act_batch", "act_seq", "act_kv_heads", None)
    v = lshard(v, "act_batch", "act_seq", "act_kv_heads", None)
    if cfg.pos_emb == "rope":
        q = apply_rope(q, positions, inv, rot)
        k = apply_rope(k, positions, inv, rot)
    return q, k, v


def attn_block_fwd(cfg: LMConfig, p: Dict, x: jax.Array,
                   positions: jax.Array) -> jax.Array:
    h = norm(x, p["attn_norm"], cfg.norm_type, cfg.norm_eps)
    # SP boundary: re-gather the sequence on the bf16 normed tensor, BEFORE
    # the projections — otherwise GSPMD resolves the reshard as an fp32
    # all-reduce after the dots (measured 2.7 GB/layer; EXPERIMENTS §Perf)
    h = lshard(h, "act_batch", "act_seq", "act_embed")
    q, k, v = _qkv(cfg, p["attn"], h, positions)
    qg = group_query_heads(q, cfg.n_kv_heads)
    s = qg.shape[1]
    if cfg.attn_custom_vjp and s % min(cfg.q_chunk, s) == 0 \
            and k.shape[1] % min(cfg.kv_chunk, k.shape[1]) == 0:
        from repro.models.attention import flash_attention_jax
        o = flash_attention_jax(qg, k, v, True, cfg.q_chunk, cfg.kv_chunk)
    else:
        o = chunked_attention(qg, k, v, causal=True, q_chunk=cfg.q_chunk,
                              kv_chunk=cfg.kv_chunk,
                              block_skip=cfg.causal_block_skip)
    o = ungroup_heads(o)
    o = jnp.einsum("bshk,hkd->bsd", o, p["attn"]["wo"])
    return x + lshard(o, "act_batch", "act_res_seq", "act_embed")


def ffn_block_fwd(cfg: LMConfig, p: Dict, x: jax.Array) \
        -> Tuple[jax.Array, jax.Array]:
    h = norm(x, p["mlp_norm"], cfg.norm_type, cfg.norm_eps)
    h = lshard(h, "act_batch", "act_seq", "act_embed")   # bf16 SP boundary
    if cfg.moe:
        y, aux = moe_lib.moe_fwd(cfg, p["moe"], h)
    else:
        y, aux = mlp_fwd(p["mlp"], h, cfg.act, cfg.gated_mlp), jnp.zeros((), jnp.float32)
    return x + y, aux


def block_fwd(cfg: LMConfig, p: Dict, x: jax.Array, positions: jax.Array):
    x = attn_block_fwd(cfg, p, x, positions)
    return ffn_block_fwd(cfg, p, x)


# ---------------------------------------------------------------------------
# embedding / logits
# ---------------------------------------------------------------------------

def embed_tokens(cfg: LMConfig, params: Dict, tokens: jax.Array,
                 prefix_emb: Optional[jax.Array], pos0: int = 0):
    x = jnp.take(params["embed"], tokens, axis=0)
    if prefix_emb is not None:
        x = jnp.concatenate([prefix_emb.astype(x.dtype), x], axis=1)
    s = x.shape[1]
    positions = pos0 + jnp.arange(s)[None, :]
    if cfg.pos_emb == "learned":
        pe = jax.lax.dynamic_slice_in_dim(params["pos_emb"], pos0, s, axis=0)
        x = x + pe[None]
    x = lshard(x, "act_batch", "act_res_seq", "act_embed")
    return x, positions


def logits_fwd(cfg: LMConfig, params: Dict, x: jax.Array) -> jax.Array:
    w = params["embed"].T if cfg.tie_embeddings else params["unembed"]
    logits = jnp.einsum("bsd,dv->bsv", x, w)
    return lshard(logits, "act_batch", "act_seq", "act_vocab")


# ---------------------------------------------------------------------------
# full forward (train / prefill / decode)
# ---------------------------------------------------------------------------

def forward(cfg: LMConfig, params: Dict, tokens: jax.Array,
            prefix_emb: Optional[jax.Array] = None,
            remat: bool = False,
            return_hidden: bool = False) -> Tuple[jax.Array, jax.Array]:
    """Training/scoring forward. Returns (logits|hidden, aux_loss)."""
    x, positions = embed_tokens(cfg, params, tokens, prefix_emb)

    def body(carry, bp):
        x, aux = carry
        x, a = block_fwd(cfg, bp, x, positions)
        return (x, aux + a), None

    if remat:
        body = jax.checkpoint(body, prevent_cse=False)
    (x, aux), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                               params["blocks"])
    x = norm(x, params["final_norm"], cfg.norm_type, cfg.norm_eps)
    if return_hidden:
        return x, aux
    return logits_fwd(cfg, params, x), aux


def prefill(cfg: LMConfig, params: Dict, tokens: jax.Array,
            prefix_emb: Optional[jax.Array] = None,
            max_len: Optional[int] = None):
    """Forward + KV-cache emission. Returns (logits, cache)."""
    x, positions = embed_tokens(cfg, params, tokens, prefix_emb)
    b, s = x.shape[0], x.shape[1]
    S = max_len or s

    def body(x, bp):
        h = norm(x, bp["attn_norm"], cfg.norm_type, cfg.norm_eps)
        h = lshard(h, "act_batch", "act_seq", "act_embed")
        q, k, v = _qkv(cfg, bp["attn"], h, positions)
        qg = group_query_heads(q, cfg.n_kv_heads)
        o = chunked_attention(qg, k, v, causal=True, q_chunk=cfg.q_chunk,
                              kv_chunk=cfg.kv_chunk,
                              block_skip=cfg.causal_block_skip)
        o = jnp.einsum("bshk,hkd->bsd", ungroup_heads(o), bp["attn"]["wo"])
        x = x + lshard(o, "act_batch", "act_res_seq", "act_embed")
        x, _ = ffn_block_fwd(cfg, bp, x)
        if S > s:
            pad = [(0, 0), (0, S - s), (0, 0), (0, 0)]
            k, v = jnp.pad(k, pad), jnp.pad(v, pad)
        k = lshard(k, "cache_batch", "cache_seq", "cache_kv_heads", None)
        v = lshard(v, "cache_batch", "cache_seq", "cache_kv_heads", None)
        return x, (k, v)

    x, (ks, vs) = jax.lax.scan(body, x, params["blocks"])
    x = norm(x, params["final_norm"], cfg.norm_type, cfg.norm_eps)
    logits = logits_fwd(cfg, params, x[:, -1:, :])
    cache = {"k": ks, "v": vs,
             "pos": jnp.full((b,), s, jnp.int32)}
    return logits, cache


def init_cache(cfg: LMConfig, batch: int, max_len: int, abstract: bool = False):
    g, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    shape = (cfg.n_layers, batch, max_len, g, hd)
    dt = cfg.activation_dtype
    if abstract:
        mk = lambda s, d: jax.ShapeDtypeStruct(s, d)
    else:
        mk = lambda s, d: jnp.zeros(s, d)
    return {"k": mk(shape, dt), "v": mk(shape, dt),
            "pos": mk((batch,), jnp.int32)}


def cache_axes(cfg: LMConfig):
    ax = ("layers", "cache_batch", "cache_seq", "cache_kv_heads", None)
    return {"k": ax, "v": ax, "pos": ("cache_batch",)}


def decode_step(cfg: LMConfig, params: Dict, cache: Dict, tokens: jax.Array):
    """One decode step. tokens: (b, 1). Returns (logits, new_cache)."""
    b = tokens.shape[0]
    pos = cache["pos"]                                   # (b,)
    x = jnp.take(params["embed"], tokens, axis=0)        # (b, 1, d)
    if cfg.pos_emb == "learned":
        pe = jnp.take(params["pos_emb"], pos, axis=0)[:, None, :]
        x = x + pe
    x = lshard(x, "act_batch", "act_res_seq", "act_embed")
    positions = pos[:, None]
    inv, rot = rope_freqs(cfg.resolved_head_dim, cfg.rope_fraction,
                          cfg.rope_theta)

    def body(x, inp):
        bp, k_cache, v_cache = inp
        h = norm(x, bp["attn_norm"], cfg.norm_type, cfg.norm_eps)
        q = jnp.einsum("bsd,dhk->bshk", h, bp["attn"]["wq"])
        k = jnp.einsum("bsd,dgk->bsgk", h, bp["attn"]["wk"])
        v = jnp.einsum("bsd,dgk->bsgk", h, bp["attn"]["wv"])
        if cfg.pos_emb == "rope":
            q = apply_rope(q, positions, inv, rot)
            k = apply_rope(k, positions, inv, rot)
        # in-place cache update at per-sequence position
        upd = lambda c, new: jax.vmap(
            lambda cb, nb, pb: jax.lax.dynamic_update_slice_in_dim(
                cb, nb, pb, axis=0))(c, new, pos)
        k_cache = upd(k_cache, k)
        v_cache = upd(v_cache, v)
        k_cache = lshard(k_cache, "cache_batch", "cache_seq",
                         "cache_kv_heads", None)
        v_cache = lshard(v_cache, "cache_batch", "cache_seq",
                         "cache_kv_heads", None)
        qg = group_query_heads(q, cfg.n_kv_heads)
        o = decode_attention(qg, k_cache, v_cache, pos + 1)
        o = jnp.einsum("bshk,hkd->bsd", ungroup_heads(o), bp["attn"]["wo"])
        x = x + lshard(o, "act_batch", "act_res_seq", "act_embed")
        x, _ = ffn_block_fwd(cfg, bp, x)
        return x, (k_cache, v_cache)

    if cfg.decode_unroll:
        ck, cv = cache["k"], cache["v"]
        for i in range(cfg.n_layers):
            bp = jax.tree.map(lambda a: a[i], params["blocks"])
            ki = jax.lax.dynamic_index_in_dim(ck, i, 0, keepdims=False)
            vi = jax.lax.dynamic_index_in_dim(cv, i, 0, keepdims=False)
            x, (ki, vi) = body(x, (bp, ki, vi))
            ck = jax.lax.dynamic_update_index_in_dim(ck, ki, i, 0)
            cv = jax.lax.dynamic_update_index_in_dim(cv, vi, i, 0)
        ks, vs = ck, cv
    else:
        x, (ks, vs) = jax.lax.scan(body, x, (params["blocks"],
                                             cache["k"], cache["v"]))
    x = norm(x, params["final_norm"], cfg.norm_type, cfg.norm_eps)
    logits = logits_fwd(cfg, params, x)
    return logits, {"k": ks, "v": vs, "pos": pos + 1}
