"""Shared layers: param-spec system, norms, activations, RoPE, MLP."""
from __future__ import annotations

import math
from typing import Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


class ParamDef(NamedTuple):
    """Declarative parameter: shape + logical sharding axes + initializer."""
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]
    init: str = "normal"          # normal | zeros | ones | small_normal
    scale: float = 1.0            # stddev multiplier for normal inits
    dtype: str = "bfloat16"


def is_param_def(x) -> bool:
    return isinstance(x, ParamDef)


def init_from_defs(defs, key: jax.Array):
    """Materialize a pytree of ParamDef into concrete arrays."""
    leaves, treedef = jax.tree.flatten(defs, is_leaf=is_param_def)
    keys = jax.random.split(key, len(leaves))
    out = []
    for k, d in zip(keys, leaves):
        dt = jnp.dtype(d.dtype)
        if d.init == "zeros":
            out.append(jnp.zeros(d.shape, dt))
        elif d.init == "ones":
            out.append(jnp.ones(d.shape, dt))
        else:
            fan_in = d.shape[0] if d.shape else 1
            std = d.scale / math.sqrt(max(fan_in, 1))
            out.append((jax.random.normal(k, d.shape, jnp.float32) * std)
                       .astype(dt))
    return jax.tree.unflatten(treedef, out)


def abstract_from_defs(defs):
    """ShapeDtypeStruct tree (no allocation) — used by the dry-run."""
    return jax.tree.map(
        lambda d: jax.ShapeDtypeStruct(d.shape, jnp.dtype(d.dtype)),
        defs, is_leaf=is_param_def)


def axes_from_defs(defs):
    return jax.tree.map(lambda d: d.axes, defs, is_leaf=is_param_def)


# ---------------------------------------------------------------------------
# norms / activations
# ---------------------------------------------------------------------------

def rmsnorm(x: jax.Array, w: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * w.astype(jnp.float32)) \
        .astype(x.dtype)


def layernorm(x: jax.Array, w: jax.Array, b: jax.Array,
              eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    return ((xf - mu) * jax.lax.rsqrt(var + eps) * w.astype(jnp.float32)
            + b.astype(jnp.float32)).astype(x.dtype)


def norm(x, p: Dict, kind: str, eps: float):
    if kind == "layernorm":
        return layernorm(x, p["scale"], p["bias"], eps)
    return rmsnorm(x, p["scale"], eps)


def norm_defs(d_model: int, kind: str) -> Dict[str, ParamDef]:
    out = {"scale": ParamDef((d_model,), ("norm",), "ones", dtype="float32")}
    if kind == "layernorm":
        out["bias"] = ParamDef((d_model,), ("norm",), "zeros", dtype="float32")
    return out


def act_fn(name: str):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu,
            "relu": jax.nn.relu}[name]


# ---------------------------------------------------------------------------
# RoPE (with partial-rotary support, e.g. stablelm rope_fraction=0.25)
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, fraction: float, theta: float):
    rot = int(head_dim * fraction) // 2 * 2
    inv = 1.0 / (theta ** (np.arange(0, rot, 2, dtype=np.float64) / rot))
    return jnp.asarray(inv, jnp.float32), rot


def apply_rope(x: jax.Array, positions: jax.Array, inv_freq: jax.Array,
               rot: int) -> jax.Array:
    """x: (..., seq, n_heads, head_dim); positions: broadcastable to (..., seq)."""
    if rot == 0:
        return x
    angles = positions[..., :, None].astype(jnp.float32) * inv_freq  # (..., s, rot/2)
    cos = jnp.cos(angles)[..., :, None, :]
    sin = jnp.sin(angles)[..., :, None, :]
    x_rot, x_pass = x[..., :rot], x[..., rot:]
    x1, x2 = x_rot[..., 0::2], x_rot[..., 1::2]
    r1 = x1 * cos - x2 * sin
    r2 = x2 * cos + x1 * sin
    rotated = jnp.stack([r1, r2], axis=-1).reshape(x_rot.shape)
    return jnp.concatenate([rotated.astype(x.dtype), x_pass], axis=-1) \
        if x_pass.shape[-1] else rotated.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLP (gated or plain)
# ---------------------------------------------------------------------------

def mlp_defs(d_model: int, d_ff: int, gated: bool, dtype: str):
    out = {
        "wi": ParamDef((d_model, d_ff), ("embed", "mlp"), dtype=dtype),
        "wo": ParamDef((d_ff, d_model), ("mlp", "embed"), dtype=dtype),
    }
    if gated:
        out["wg"] = ParamDef((d_model, d_ff), ("embed", "mlp"), dtype=dtype)
    return out


def mlp_fwd(p: Dict, x: jax.Array, act: str, gated: bool) -> jax.Array:
    from repro.sharding.partition import lshard
    h = jnp.einsum("...d,df->...f", x, p["wi"])
    if gated:
        h = act_fn(act)(jnp.einsum("...d,df->...f", x, p["wg"])) * h
    else:
        h = act_fn(act)(h)
    h = lshard(h, "act_batch", "act_seq", "act_mlp")
    return jnp.einsum("...f,fd->...d", h, p["wo"])
