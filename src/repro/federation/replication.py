"""Journal replication: each runtime's JSONL journal mirrored to a peer.

The single-runtime journal already gives crash recovery *if the file
survives*; federation needs recovery when the runtime (and, in a real
deployment, its disk) is gone. The scheme is ring replication: runtime
``i``'s journal is mirrored, line by line, to a replica file owned by
peer ``(i+1) % N`` (``ReplicaSink`` attached via
``JournalStore.attach_mirror`` — every durable primary write is forwarded
under the journal lock, so the replica is always an ordered prefix of
the primary). On ``kill_runtime`` the federation replays the replica
through the survivor's ``JobService.recover``, which rewinds RUNNING →
REQUEUED and re-gates PENDING — conserving work and deadline/tier
metadata, deduplicated by job id.

Compaction coherence: when the primary compacts, the sink rewrites the
replica to the same compacted line set (temp file + atomic rename, like
the primary), so a replica never diverges past one in-flight record.
"""
from __future__ import annotations

import os
import threading
from typing import Dict, List, Sequence


class ReplicaSink:
    """Mirror target for one runtime's journal (see
    ``JournalStore.attach_mirror``): ``append`` forwards one record line,
    ``rewrite`` replaces the replica with a compacted line set."""

    def __init__(self, path: str):
        self.path = str(path)
        directory = os.path.dirname(self.path)
        if directory:
            os.makedirs(directory, exist_ok=True)
        self._lock = threading.Lock()
        self._fh = open(self.path, "a", encoding="utf-8")

    def append(self, line: str) -> None:
        with self._lock:
            self._fh.write(line + "\n")
            self._fh.flush()

    def rewrite(self, lines: Sequence[str]) -> None:
        with self._lock:
            self._fh.close()
            tmp = self.path + ".compact"
            with open(tmp, "w", encoding="utf-8") as fh:
                for line in lines:
                    fh.write(line + "\n")
                fh.flush()
            os.replace(tmp, self.path)
            self._fh = open(self.path, "a", encoding="utf-8")

    def close(self) -> None:
        with self._lock:
            if not self._fh.closed:
                self._fh.close()


class ReplicationRing:
    """The who-holds-whose-replica layout: runtime ``i``'s journal is
    mirrored to peer ``(i+1) % N``. Pure bookkeeping — paths and peer
    ids — so the federation service and tests agree on where a victim's
    replica lives after any subset of kills."""

    def __init__(self, runtime_ids: Sequence[str], directory: str):
        self.runtime_ids = list(runtime_ids)
        self.directory = str(directory)
        os.makedirs(self.directory, exist_ok=True)
        self._peer: Dict[str, str] = {}
        n = len(self.runtime_ids)
        for i, rid in enumerate(self.runtime_ids):
            self._peer[rid] = self.runtime_ids[(i + 1) % n] if n > 1 \
                else rid

    def journal_path(self, rid: str) -> str:
        return os.path.join(self.directory, f"{rid}.journal.jsonl")

    def replica_path(self, rid: str) -> str:
        """Where ``rid``'s mirror lives (owned by its peer)."""
        return os.path.join(self.directory, f"{rid}.replica.jsonl")

    def peer_of(self, rid: str) -> str:
        return self._peer[rid]

    def make_sink(self, rid: str) -> ReplicaSink:
        return ReplicaSink(self.replica_path(rid))

    def recovery_source(self, rid: str) -> str:
        """The journal to replay for a dead ``rid``: the replica its peer
        holds when present, else the primary (single-runtime rings, or a
        mirror that never attached)."""
        replica = self.replica_path(rid)
        if os.path.exists(replica):
            return replica
        return self.journal_path(rid)

    def recovery_sources(self, rid: str) -> List[str]:
        """Every journal worth consulting for a dead ``rid``, replica
        first. The two can disagree in both directions: after a mirror
        detach the replica is a stale prefix of the primary, and after a
        torn/corrupted primary write the replica holds the true record
        the primary lost. Failover merges them (terminal verdicts win)
        instead of trusting either alone."""
        out: List[str] = []
        replica = self.replica_path(rid)
        if os.path.exists(replica):
            out.append(replica)
        primary = self.journal_path(rid)
        if os.path.exists(primary):
            out.append(primary)
        return out or [primary]
