"""Heartbeat gossip between federated runtimes, with stale derating.

Each runtime periodically publishes a ``Heartbeat`` — its λ-aggregate
useful capacity (AdmissionController.capacity_items_s, i.e. the sharded
ThroughputTracker EWMAs derated by §3.3 overhead fractions and straggler
reports), queue depth/backlog, queue-delay quantiles, and per-tenant
unfinished-work / attributed-joule counts. The ``GossipBus`` is the
in-process stand-in for the gossip mesh: publish replaces the runtime's
latest view, readers aggregate across views.

Staleness is first-class: a runtime that stops heartbeating (crashed,
wedged, partitioned) must stop attracting work *before* anyone declares
it dead. ``effective_capacity`` derates a runtime's advertised capacity
linearly with heartbeat age past ``stale_after_s`` — full trust inside
the window, decaying to a floor by ``2 × stale_after_s`` — so the router
sheds load off a silent runtime on the same gradient a straggler derate
uses, rather than a binary alive/dead cliff.
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Dict, Optional


@dataclass
class Heartbeat:
    runtime_id: str
    ts: float                                  # bus-clock publish stamp
    capacity_items_s: float = 0.0
    queue_depth: int = 0
    backlog_items: int = 0
    delay_p50_s: float = 0.0
    delay_p95_s: float = 0.0
    done: int = 0
    failed: int = 0
    # per-tenant views for global quota / energy enforcement
    unfinished_jobs: Dict[str, int] = field(default_factory=dict)
    energy_j: Dict[str, float] = field(default_factory=dict)


class GossipBus:
    #: capacity trust floor for an arbitrarily stale heartbeat — nonzero
    #: so a runtime recovering from a GC-length stall still drains its
    #: routed backlog instead of being starved into a second incident
    STALE_FLOOR = 0.1

    def __init__(self, stale_after_s: float = 2.0, clock=None):
        self.stale_after_s = float(stale_after_s)
        self.clock = clock if clock is not None else time.monotonic
        self._lock = threading.Lock()
        self._latest: Dict[str, Heartbeat] = {}
        self.published = 0

    # -- write side ----------------------------------------------------
    def publish(self, hb: Heartbeat) -> None:
        with self._lock:
            self._latest[hb.runtime_id] = hb
            self.published += 1

    def drop(self, runtime_id: str) -> None:
        """Forget a runtime (killed / removed) — its heartbeats must not
        keep counting toward global quota or capacity."""
        with self._lock:
            self._latest.pop(runtime_id, None)

    # -- read side -----------------------------------------------------
    def view(self) -> Dict[str, Heartbeat]:
        with self._lock:
            return dict(self._latest)

    def get(self, runtime_id: str) -> Optional[Heartbeat]:
        with self._lock:
            return self._latest.get(runtime_id)

    def stale_factor(self, hb: Heartbeat,
                     now: Optional[float] = None) -> float:
        age = (self.clock() if now is None else now) - hb.ts
        if age <= self.stale_after_s:
            return 1.0
        over = (age - self.stale_after_s) / max(self.stale_after_s, 1e-9)
        return max(self.STALE_FLOOR, 1.0 - over)

    def effective_capacity(self, runtime_id: str,
                           now: Optional[float] = None) -> float:
        """Advertised capacity × stale derate (0.0 for an unknown id)."""
        hb = self.get(runtime_id)
        if hb is None:
            return 0.0
        return hb.capacity_items_s * self.stale_factor(hb, now)

    # -- fleet aggregates ----------------------------------------------
    def unfinished(self, tenant: str) -> int:
        """Fleet-wide unfinished jobs for one tenant (global quota
        numerator)."""
        with self._lock:
            return sum(hb.unfinished_jobs.get(tenant, 0)
                       for hb in self._latest.values())

    def energy(self, tenant: str) -> float:
        """Fleet-wide attributed joules for one tenant (global energy
        budget numerator)."""
        with self._lock:
            return sum(hb.energy_j.get(tenant, 0.0)
                       for hb in self._latest.values())

    def tenants(self) -> set:
        with self._lock:
            out = set()
            for hb in self._latest.values():
                out.update(hb.unfinished_jobs)
                out.update(hb.energy_j)
            return out
