"""FederatedService: N JobService runtimes behind one submit/serve front.

The single-runtime stack (admission → DWRR shards → persistent scheduler
runtime) scales one process on one chip; this tier federates N of them —
in-process simulated "hosts", each with its own scheduler runtime,
tenancy shards, and journal — behind the existing submit interface:

  * routing — jobs place by tenant consistent-hashing with bounded loads
    (``Router``), corrected by live per-runtime capacity gossiped from
    each runtime's λ-trackers (``GossipBus``, stale-derated), so a hot
    tenant sticks to a home runtime until it is genuinely overloaded,
    then spills deterministically;
  * replication — each runtime's journal mirrors to a ring peer
    (``ReplicationRing``); ``kill_runtime`` replays the victim's replica
    through a survivor's ``JobService.recover``, requeueing 100 % of its
    in-flight/queued jobs with tier/deadline metadata intact;
  * global contracts — tenant in-flight quotas and energy budgets are
    enforced against the *fleet-wide* gossip aggregate, so a tenant
    cannot multiply its quota by the number of runtimes.

The host-side overheads the paper measures per chunk reappear here one
level up as routing/gossip/handoff overheads per job; the federation
metrics (``fed.*``) make them observable the same way.
"""
from __future__ import annotations

import os
import threading
import time
from dataclasses import dataclass, field, replace
from typing import Callable, Dict, List, Optional, Sequence

from repro import telemetry as telemetry_mod
from repro.chaos.injector import ChaosInjector, ChaosSink
from repro.federation.gossip import GossipBus, Heartbeat
from repro.federation.replication import ReplicaSink, ReplicationRing
from repro.federation.router import Router
from repro.queue.admission import AdmissionDecision, Decision
from repro.queue.job import Job, JobState
from repro.queue.journal import JournalStore, _entry_line

clock = time.monotonic


@dataclass
class RuntimeNode:
    """One federated runtime: its service, journal, and the mirror sink
    feeding its ring peer's replica of this journal."""
    runtime_id: str
    service: JobService
    journal: JournalStore
    sink: ReplicaSink
    alive: bool = True
    # submissions routed here since the last gossip round — the router's
    # load view and the global-quota gate must see them before the next
    # heartbeat does (reset when the heartbeat captures the queue state)
    routed_items: float = 0.0
    pending_jobs: Dict[str, int] = field(default_factory=dict)


@dataclass
class FederationReport:
    runtimes: int
    alive: int
    jobs: int
    done: int
    failed: int
    cancelled: int
    requeues: int
    recovered: int
    failovers: int
    gossip_rounds: int
    time_s: float = 0.0
    killed: List[str] = field(default_factory=list)
    per_runtime: Dict[str, Dict[str, float]] = field(default_factory=dict)
    per_tenant_items: Dict[str, int] = field(default_factory=dict)


class FederatedService:
    def __init__(self,
                 make_service: Callable[..., JobService],
                 runtime_ids: Sequence[str],
                 journal_dir: str,
                 tenants=None,
                 telemetry=None,
                 heartbeat_s: float = 0.2,
                 stale_after_s: Optional[float] = None,
                 bound: float = 1.25,
                 vnodes: int = 64,
                 max_deferred: int = 10_000,
                 spread_after: int = 32,
                 auto_compact_lines: Optional[int] = None,
                 chaos: Optional[ChaosInjector] = None):
        """``make_service(runtime_id, journal, telemetry) -> JobService``
        builds one runtime (scheduler factory, queue, admission wired by
        the caller); the federation owns journals + replication + the
        per-runtime telemetry namespace. ``tenants`` is a duck-typed
        TenantRegistry enabling the global quota / energy-budget tier.
        ``chaos`` attaches a fault-injection plane (repro.chaos): journal
        write filters, mirror-failure sinks, gossip drop/delay/partition,
        and plan-scheduled runtime kills, all executed here."""
        if not runtime_ids:
            raise ValueError("federation needs at least one runtime")
        self.tenants = tenants
        self.heartbeat_s = max(1e-3, float(heartbeat_s))
        self.max_deferred = max_deferred
        # hot-tenant fan-out threshold (jobs): a tenant whose fleet-wide
        # unfinished count exceeds k × spread_after routes over k+1
        # virtual ring keys, up to the live-runtime count (0 disables)
        self.spread_after = max(0, int(spread_after))
        self.telemetry = telemetry_mod.resolve(telemetry)
        self.ring = ReplicationRing(runtime_ids, journal_dir)
        self.bus = GossipBus(
            stale_after_s=stale_after_s if stale_after_s is not None
            else max(4 * self.heartbeat_s, 0.5))
        self.router = Router(vnodes=vnodes, bound=bound)
        self._lock = threading.Lock()
        self._jobs: Dict[str, Job] = {}       # latest materialization
        self._placement: Dict[str, str] = {}  # job_id -> runtime_id
        self._deferred: List[Job] = []        # blocked on GLOBAL quota
        self._tenant_seq: Dict[str, int] = {}  # fan-out round-robin
        self._killed: List[str] = []
        self.recovered = 0
        self.quota_defers = 0
        self._started = False
        self._stop_evt = threading.Event()
        self._hb_thread: Optional[threading.Thread] = None
        self._t0: Optional[float] = None
        self._chaos = chaos
        # kill_runtime is serialized: two concurrent kills may otherwise
        # each pick the other as survivor mid-crash and replay into a
        # runtime that is already dying, losing the replayed jobs
        self._kill_lock = threading.Lock()

        self._nodes: Dict[str, RuntimeNode] = {}
        for rid in runtime_ids:
            journal = JournalStore(
                self.ring.journal_path(rid),
                auto_compact_lines=auto_compact_lines,
                write_filter=chaos.journal_write_filter(rid)
                if chaos is not None else None)
            sink = self._wrap_sink(self.ring.make_sink(rid), rid)
            journal.attach_mirror(sink)
            tel_arg = self.telemetry.labeled(runtime=rid) \
                if self.telemetry is not None else telemetry_mod.OFF
            service = make_service(rid, journal, tel_arg)
            if service.journal is None:
                service.journal = journal
            self._nodes[rid] = RuntimeNode(rid, service, journal, sink)
            self.router.add_runtime(rid)
            # fleet-wide quota view for each runtime's own admission gate
            adm = service.admission
            if adm is not None \
                    and getattr(adm, "global_unfinished", None) is None:
                adm.global_unfinished = self.global_unfinished

    def _wrap_sink(self, sink: ReplicaSink, rid: str):
        """Replica sinks pass through the chaos plane when one is
        attached, so ``mirror_fail`` windows hit mirror writes."""
        if self._chaos is None:
            return sink
        return ChaosSink(sink, rid, self._chaos)

    # -- telemetry ------------------------------------------------------
    def _counter(self, name: str, **labels):
        if self.telemetry is None:
            return None
        return self.telemetry.registry.counter(name, **labels)

    def _count(self, name: str, v: float = 1.0, **labels) -> None:
        c = self._counter(name, **labels)
        if c is not None:
            c.add(v)

    # -- fleet views ----------------------------------------------------
    def nodes(self) -> Dict[str, RuntimeNode]:
        return dict(self._nodes)

    def alive_nodes(self) -> List[RuntimeNode]:
        return [n for n in self._nodes.values() if n.alive]

    def global_unfinished(self, tenant: str) -> int:
        """Fleet-wide unfinished jobs for a tenant: the gossip aggregate
        plus submissions routed since the last heartbeat (so a burst
        between rounds cannot slip past the quota)."""
        with self._lock:
            pending = sum(n.pending_jobs.get(tenant, 0)
                          for n in self._nodes.values() if n.alive)
        return self.bus.unfinished(tenant) + pending

    def _loads(self) -> Dict[str, float]:
        """Router load view: gossiped backlog items corrected by
        un-gossiped local placements."""
        out: Dict[str, float] = {}
        for rid, node in self._nodes.items():
            if not node.alive:
                continue
            hb = self.bus.get(rid)
            base = float(hb.backlog_items) if hb is not None else 0.0
            out[rid] = base + node.routed_items
        return out

    # -- submission -----------------------------------------------------
    def submit(self, job: Job) -> AdmissionDecision:
        """Route one job onto a runtime. The *global* tenant quota gates
        first (gossip-aggregated — N runtimes' local gates each allow a
        full quota); within budget, placement is bounded-load consistent
        hashing on the tenant and the runtime's own admission runs."""
        spec = self.tenants.get(job.tenant) \
            if self.tenants is not None else None
        if spec is not None and spec.max_inflight is not None \
                and self.global_unfinished(job.tenant) \
                >= spec.max_inflight:
            with self._lock:
                full = len(self._deferred) >= self.max_deferred
                if not full:
                    self._deferred.append(job)
                    self.quota_defers += 1
            if full:
                job.transition(JobState.CANCELLED)
                return AdmissionDecision(
                    Decision.REJECT, 0.0, 0.0, tenant=job.tenant,
                    reason=f"federation deferred pool at capacity "
                           f"({self.max_deferred})")
            self._count("fed.quota_defers", tenant=job.tenant)
            return AdmissionDecision(
                Decision.DEFER, 0.0, 0.0, tenant=job.tenant,
                reason=f"tenant {job.tenant} at global in-flight quota "
                       f"{spec.max_inflight}")
        rid = self.router.place(self._route_key(job), self._loads(),
                                weight=float(job.items))
        if rid is None:
            job.transition(JobState.CANCELLED)
            return AdmissionDecision(Decision.REJECT, 0.0, 0.0,
                                     tenant=job.tenant,
                                     reason="no live runtimes")
        node = self._nodes[rid]
        with self._lock:
            self._jobs[job.job_id] = job
            self._placement[job.job_id] = rid
        self._count("fed.routed", runtime=rid)
        dec = node.service.submit(job)
        # the un-gossiped correction is recorded AFTER the runtime's own
        # admission ran: recording first would make the quota gate count
        # the job against itself (max(local, global) with global already
        # including it), turning quota N into N-1
        if dec.decision != Decision.REJECT:
            with self._lock:
                node.routed_items += float(job.items)
                node.pending_jobs[job.tenant] = \
                    node.pending_jobs.get(job.tenant, 0) + 1
        return dec

    def _route_key(self, job: Job) -> str:
        """Ring key for one job. Normally the tenant (full stickiness: a
        tenant's jobs share a runtime's cache, journal, and DWRR shard).
        A *saturating* tenant — fleet-wide unfinished count past
        ``spread_after`` per fanned key — routes round-robin over enough
        virtual keys (``tenant#k``) to span the backlog, so competing hot
        tenants co-locate on every runtime and weighted DWRR arbitration
        holds fleet-wide instead of degenerating into tenant-exclusive
        runtimes (where local weights arbitrate nothing)."""
        if not self.spread_after:
            return job.tenant
        fan = 1 + self.global_unfinished(job.tenant) // self.spread_after
        fan = min(max(1, len(self.alive_nodes())), fan)
        if fan <= 1:
            return job.tenant
        with self._lock:
            seq = self._tenant_seq.get(job.tenant, 0)
            self._tenant_seq[job.tenant] = seq + 1
        return f"{job.tenant}#{seq % fan}"

    def retry_deferred(self) -> int:
        """Re-offer globally-deferred jobs; returns how many routed."""
        with self._lock:
            waiting, self._deferred = self._deferred, []
        routed = 0
        for job in waiting:
            if job.state != JobState.PENDING:
                continue
            dec = self.submit(job)
            routed += dec.decision == Decision.ADMIT
        return routed

    # -- gossip ---------------------------------------------------------
    def _heartbeat(self, node: RuntimeNode) -> Heartbeat:
        svc = node.service
        queue = svc.queue
        unfinished: Dict[str, int] = {}
        unfinished_fn = getattr(queue, "unfinished", None)
        names = list(self.tenants.names()) \
            if self.tenants is not None else []
        if unfinished_fn is not None and names:
            for t in names:
                unfinished[t] = unfinished_fn(t)
        else:
            for j in queue.jobs():
                if j.state in (JobState.ADMITTED, JobState.RUNNING):
                    unfinished[j.tenant] = unfinished.get(j.tenant, 0) + 1
        energy: Dict[str, float] = {}
        if svc.accountant is not None:
            for t, u in svc.accountant.snapshot().items():
                energy[t] = u["energy_j"]
        if svc.admission is not None:
            capacity = svc.admission.capacity_items_s()
        else:
            sched = svc.scheduler()
            tracker = getattr(sched, "tracker", None) if sched else None
            capacity = sum(tracker.snapshot().values()) \
                if tracker is not None else 1.0
        delays = svc.stats.delay_percentiles()
        return Heartbeat(
            runtime_id=node.runtime_id, ts=self.bus.clock(),
            capacity_items_s=capacity,
            queue_depth=queue.depth(),
            backlog_items=queue.backlog_items(),
            delay_p50_s=delays.get("p50", 0.0),
            delay_p95_s=delays.get("p95", 0.0),
            done=svc.stats.done, failed=svc.stats.failed,
            unfinished_jobs=unfinished, energy_j=energy)

    def gossip_round(self) -> None:
        """One heartbeat exchange: every live runtime publishes, the
        router refreshes stale-derated capacities, global energy budgets
        re-derate DWRR weights, and the globally-deferred pool re-gates.
        With a chaos plane attached this is also where its federation
        faults execute: plan-scheduled kills fire here, a runtime inside
        a ``gossip_drop``/``partition`` window publishes nothing (the
        bus's stale derate takes over), a ``gossip_delay`` window lags
        the heartbeat timestamp by ``magnitude`` seconds, and mirrors
        detached during a ``mirror_fail`` window are re-synced once the
        window has passed."""
        now = self.bus.clock()
        chaos = self._chaos
        if chaos is not None:
            for rid in chaos.take_kills(
                    [n.runtime_id for n in self.alive_nodes()]):
                self.kill_runtime(rid)
        for node in self.alive_nodes():
            if chaos is not None and (
                    chaos.active("federation", "gossip_drop",
                                 node.runtime_id) is not None
                    or chaos.active("federation", "partition",
                                    node.runtime_id) is not None):
                continue    # heartbeat lost; routed_items correction
            hb = self._heartbeat(node)      # stays banked for later
            if chaos is not None:
                ev = chaos.active("federation", "gossip_delay",
                                  node.runtime_id)
                if ev is not None and ev.magnitude > 0.0:
                    hb = replace(hb, ts=hb.ts - ev.magnitude)
            self.bus.publish(hb)
            with self._lock:
                # the heartbeat just captured this queue's state; the
                # un-gossiped correction window restarts
                node.routed_items = 0.0
                node.pending_jobs.clear()
        self._heal_mirrors()
        for node in self.alive_nodes():
            self.router.set_capacity(
                node.runtime_id,
                self.bus.effective_capacity(node.runtime_id, now))
        self._apply_energy_budgets()
        self._count("fed.gossip_rounds")
        if self.telemetry is not None:
            self.telemetry.registry.gauge("fed.runtimes_alive") \
                .set(len(self.alive_nodes()))
        self.retry_deferred()

    def _heal_mirrors(self) -> None:
        """Re-attach replication for any journal whose mirror detached
        (a sink write error — under chaos, a ``mirror_fail`` window).
        Detachment is the journal's self-protection, but a runtime
        running unmirrored is a replication gap: a later kill would lose
        whatever the replica missed. Healing rewrites a fresh sink from
        the primary's current per-job state and resumes forwarding; the
        heal is skipped while the fault window is still open (it would
        just detach again)."""
        for node in self.alive_nodes():
            if node.journal.has_mirror():
                continue
            if self._chaos is not None and self._chaos.active(
                    "federation", "mirror_fail",
                    node.runtime_id) is not None:
                continue
            node.sink.close()
            sink = self._wrap_sink(
                self.ring.make_sink(node.runtime_id), node.runtime_id)
            try:
                node.journal.resync_mirror(sink)
            except Exception:       # window raced the resync; next round
                sink.close()
                continue
            node.sink = sink
            self._count("fed.mirror_resyncs", runtime=node.runtime_id)

    def _apply_energy_budgets(self) -> None:
        """Global energy enforcement: a tenant's fleet-wide attributed
        joules vs. its budget → weight derate pushed into every runtime's
        accountant (merged by min() with the local derates) and applied
        to the DWRR shards immediately."""
        if self.tenants is None:
            return
        derates: Dict[str, float] = {}
        for t in self.tenants.names():
            budget = self.tenants.get(t).energy_budget_j
            if budget is None:
                continue
            spent = self.bus.energy(t)
            if spent > budget > 0:
                derates[t] = budget / spent
        for node in self.alive_nodes():
            acct = node.service.accountant
            if acct is None:
                continue
            acct.set_external_derates(derates)
            set_derates = getattr(node.service.queue,
                                  "set_weight_derates", None)
            if set_derates is not None:
                set_derates(acct.derate_weights())

    # -- failure / handoff ----------------------------------------------
    def kill_runtime(self, rid: str) -> List[Job]:
        """Crash one runtime (unclean: in-flight batches die un-finalized)
        and fail its work over: the victim's replica and primary journals
        are merged (terminal verdicts win — a replica that is a stale
        prefix must not resurrect a finished job, and a primary whose
        final write was torn must not lose one) and the merge replays
        through a survivor's ``recover`` — RUNNING rewinds to REQUEUED,
        queued jobs re-enter a live queue, PENDING re-gates — conserving
        deadline/tier metadata, deduplicated by job id. Kills are
        serialized (``_kill_lock``): two racing kills could otherwise
        each pick the other as survivor mid-crash and replay into a
        dying runtime. Returns the re-materialized jobs (empty when no
        survivor remains)."""
        with self._kill_lock:
            node = self._nodes[rid]
            if not node.alive:
                return []
            node.alive = False
            self._killed.append(rid)
            self.router.remove_runtime(rid)
            self.bus.drop(rid)
            with self._lock:
                node.routed_items = 0.0
                node.pending_jobs.clear()
            node.service.crash()
            if self._chaos is not None and self._chaos.take(
                    "journal", "torn_write", rid) is not None:
                node.journal.tear_tail()
            node.journal.close()
            node.sink.close()
            self._count("fed.failovers")
            survivor = self._survivor_for(rid)
            if survivor is None:
                return []
            recovered = survivor.service.recover(
                self._merged_recovery_source(rid))
            with self._lock:
                for job in recovered:
                    self._jobs[job.job_id] = job
                    self._placement[job.job_id] = survivor.runtime_id
                self.recovered += len(recovered)
            self._count("fed.recovered_jobs", len(recovered),
                        runtime=survivor.runtime_id)
            return recovered

    def _merged_recovery_source(self, rid: str) -> str:
        """Merge every recovery source for ``rid`` into one replayable
        journal. Sources are consulted replica-then-primary with
        later-source-wins per job — the primary is the newer view when
        both parsed — EXCEPT that a terminal verdict from any source
        sticks: the one unsafe disagreement is a stale non-terminal
        record shadowing a DONE/FAILED/CANCELLED one, which would requeue
        (and re-execute) a job that already finished."""
        sources = self.ring.recovery_sources(rid)
        if len(sources) == 1:
            return sources[0]
        merged: Dict[str, Job] = {}
        order: List[str] = []
        for path in sources:
            for jid, job in JournalStore.replay(path).items():
                cur = merged.get(jid)
                if cur is None:
                    merged[jid] = job
                    order.append(jid)
                elif not cur.terminal:
                    merged[jid] = job
        out = os.path.join(self.ring.directory, f"{rid}.recovery.jsonl")
        with open(out, "w", encoding="utf-8") as fh:
            for jid in order:
                fh.write(_entry_line(merged[jid],
                                     merged[jid].state.value) + "\n")
        return out

    def _survivor_for(self, rid: str) -> Optional[RuntimeNode]:
        """The victim's ring peer, walking past peers that are themselves
        dead (cascading failures hand off transitively)."""
        seen = {rid}
        cur = self.ring.peer_of(rid)
        while cur not in seen:
            node = self._nodes.get(cur)
            if node is not None and node.alive:
                return node
            seen.add(cur)
            cur = self.ring.peer_of(cur)
        return None

    # -- lifecycle ------------------------------------------------------
    def start(self) -> None:
        if self._started:
            return
        self._started = True
        self._t0 = clock()
        if self._chaos is not None:
            self._chaos.start()     # fault clock origin = fleet start
        for node in self.alive_nodes():
            node.service.start()
        self.gossip_round()            # seed the router before any wait
        self._stop_evt.clear()
        self._hb_thread = threading.Thread(
            target=self._hb_loop, name="fed-gossip", daemon=True)
        self._hb_thread.start()

    def _hb_loop(self) -> None:
        while not self._stop_evt.wait(self.heartbeat_s):
            self.gossip_round()

    def _idle(self) -> bool:
        with self._lock:
            if self._deferred:
                return False
        for node in self.alive_nodes():
            svc = node.service
            # queue.jobs() holds every non-terminal job (terminal ones
            # are evicted), which covers the popped-but-not-yet-submitted
            # window a depth() check would miss
            if svc._inflight or svc.queue.jobs():
                return False
            with svc._lock:
                if svc._deferred:
                    return False
        return True

    def run_until_idle(self, timeout_s: float = 60.0) -> bool:
        """Drain every runtime (daemons + gossip) until no live work
        remains anywhere; False on timeout."""
        self.start()
        deadline = clock() + timeout_s
        while clock() < deadline:
            if self._idle():
                return True
            time.sleep(min(self.heartbeat_s, 0.02))
        return False

    def stop(self) -> None:
        self._stop_evt.set()
        if self._hb_thread is not None:
            self._hb_thread.join(timeout=5.0)
            self._hb_thread = None
        self._started = False

    def close(self) -> None:
        self.stop()
        for node in self._nodes.values():
            if node.alive:
                node.service.close()
                node.journal.close()
            node.sink.close()

    # -- reporting ------------------------------------------------------
    def gossip_rounds(self) -> int:
        if self.telemetry is None:
            return 0
        return int(self.telemetry.registry.counter(
            "fed.gossip_rounds").value())

    def report(self) -> FederationReport:
        with self._lock:
            jobs = list(self._jobs.values())
        by_state: Dict[JobState, int] = {}
        per_tenant: Dict[str, int] = {}
        for j in jobs:
            by_state[j.state] = by_state.get(j.state, 0) + 1
            if j.state == JobState.DONE:
                per_tenant[j.tenant] = per_tenant.get(j.tenant, 0) + j.items
        per_runtime = {}
        for rid, node in self._nodes.items():
            st = node.service.stats
            per_runtime[rid] = {
                "alive": float(node.alive), "done": float(st.done),
                "batches": float(st.batches),
                "items": float(sum(st.per_group_items.values()))}
        return FederationReport(
            runtimes=len(self._nodes), alive=len(self.alive_nodes()),
            jobs=len(jobs),
            done=by_state.get(JobState.DONE, 0),
            failed=by_state.get(JobState.FAILED, 0),
            cancelled=by_state.get(JobState.CANCELLED, 0),
            requeues=sum(n.service.stats.requeues
                         for n in self._nodes.values()),
            recovered=self.recovered,
            failovers=len(self._killed),
            gossip_rounds=self.gossip_rounds(),
            time_s=(clock() - self._t0) if self._t0 is not None else 0.0,
            killed=list(self._killed),
            per_runtime=per_runtime,
            per_tenant_items=per_tenant)
