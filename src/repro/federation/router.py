"""Tenant-consistent-hash routing with bounded loads.

Placement is consistent hashing with bounded loads (Mirrokni et al.): a
tenant's jobs walk the vnode ring clockwise from ``hash(tenant)`` and
land on the first runtime whose current load, plus the new job, stays
within ``bound ×`` its *capacity share* of the total — so a hot tenant
sticks to its home runtime (cache/journal locality, stable DWRR shard)
until that runtime is genuinely over-loaded relative to the fleet, then
spills along its own deterministic ring walk. Capacity shares come from
gossiped per-runtime λ-aggregates (stale-derated by the GossipBus), so a
slow or silent runtime attracts proportionally less work without any
explicit drain command.

Properties the tests pin down:

  * bounded balance — no runtime's load exceeds ``bound`` × its capacity
    share of (total+1), up to the one-job granularity;
  * minimal remapping — adding/removing a runtime moves only the keys
    whose ring walk hits the changed vnodes (≈ K/N expected), and on a
    join every moved key moves TO the joiner, never between survivors;
  * determinism — identical ring + loads + capacities place identically
    (no RNG anywhere), so N front-ends sharing gossip state agree.
"""
from __future__ import annotations

import bisect
import hashlib
import threading
from typing import Dict, List, Optional, Sequence, Tuple


def _hash(key: str) -> int:
    """Stable 64-bit point on the ring (process-seed-independent, unlike
    builtin hash())."""
    return int.from_bytes(
        hashlib.blake2b(key.encode("utf-8"), digest_size=8).digest(),
        "big")


class Router:
    def __init__(self, runtimes: Sequence[str] = (), vnodes: int = 64,
                 bound: float = 1.25):
        if not bound > 1.0:
            raise ValueError(f"bound must be > 1, got {bound}")
        self.vnodes = max(1, int(vnodes))
        self.bound = float(bound)
        self._lock = threading.Lock()
        self._points: List[Tuple[int, str]] = []   # sorted (hash, rid)
        self._capacity: Dict[str, float] = {}
        for rid in runtimes:
            self.add_runtime(rid)

    # -- membership ----------------------------------------------------
    def add_runtime(self, rid: str, capacity: float = 1.0) -> None:
        with self._lock:
            if rid in self._capacity:
                return
            self._capacity[rid] = max(0.0, float(capacity))
            for i in range(self.vnodes):
                bisect.insort(self._points, (_hash(f"{rid}#{i}"), rid))

    def remove_runtime(self, rid: str) -> None:
        with self._lock:
            if self._capacity.pop(rid, None) is None:
                return
            self._points = [p for p in self._points if p[1] != rid]

    def runtimes(self) -> List[str]:
        with self._lock:
            return sorted(self._capacity)

    # -- capacity (gossip-fed) -----------------------------------------
    def set_capacity(self, rid: str, capacity: float) -> None:
        with self._lock:
            if rid in self._capacity:
                self._capacity[rid] = max(0.0, float(capacity))

    def capacity_share(self, rid: str) -> float:
        with self._lock:
            return self._share_locked(rid)

    def _share_locked(self, rid: str) -> float:
        total = sum(self._capacity.values())
        if total <= 0.0:                   # no gossip yet: equal shares
            return 1.0 / max(1, len(self._capacity))
        return self._capacity.get(rid, 0.0) / total

    # -- placement -----------------------------------------------------
    def place(self, key: str, loads: Optional[Dict[str, float]] = None,
              weight: float = 1.0) -> Optional[str]:
        """Place one unit of ``weight`` for ``key`` (the tenant). The
        ring walk from ``hash(key)`` skips runtimes whose load would
        exceed ``bound × share × (total + weight)``; the per-candidate
        ``max(weight, …)`` floor guarantees progress (the first candidate
        can always take the first unit). Returns None with no members."""
        with self._lock:
            if not self._points:
                return None
            loads = loads or {}
            total = sum(loads.values()) + weight
            start = bisect.bisect_left(self._points, (_hash(key), ""))
            n = len(self._points)
            seen = set()
            fallback, fallback_head = None, 0.0
            for off in range(n):
                rid = self._points[(start + off) % n][1]
                if rid in seen:
                    continue
                seen.add(rid)
                limit = max(weight,
                            self.bound * self._share_locked(rid) * total)
                load = loads.get(rid, 0.0)
                if load + weight <= limit + 1e-9:
                    return rid
                # headroom-relative fallback if every runtime is over its
                # bound (can only happen when the caller's load map
                # includes weight the ring never placed)
                head = limit - load
                if fallback is None or head > fallback_head:
                    fallback, fallback_head = rid, head
            return fallback

    def place_many(self, keys: Sequence[str],
                   loads: Optional[Dict[str, float]] = None,
                   weight: float = 1.0) -> Dict[str, str]:
        """Place a batch, threading the load increments through — the
        water-filling the property tests exercise."""
        loads = dict(loads or {})
        out: Dict[str, str] = {}
        for key in keys:
            rid = self.place(key, loads, weight=weight)
            if rid is None:
                break
            out[key] = rid
            loads[rid] = loads.get(rid, 0.0) + weight
        return out
