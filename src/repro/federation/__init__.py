"""Multi-runtime federation: one service, many scheduler runtimes.

See ``repro.federation.service`` for the architecture overview.
"""
from repro.federation.gossip import GossipBus, Heartbeat
from repro.federation.replication import ReplicaSink, ReplicationRing
from repro.federation.router import Router
from repro.federation.service import (FederatedService, FederationReport,
                                      RuntimeNode)

__all__ = [
    "FederatedService", "FederationReport", "GossipBus", "Heartbeat",
    "ReplicaSink", "ReplicationRing", "Router", "RuntimeNode",
]
