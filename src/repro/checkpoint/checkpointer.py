"""Atomic checkpointing (the restart half of fault tolerance).

Layout: <dir>/step_<n>/ {meta.json, arrays.npz}; writes go to a tmp dir that
is os.rename()'d into place (atomic on POSIX), so a crash mid-save never
corrupts the latest checkpoint. Optional async save on a background thread
(training continues while the previous step serializes). keep_n garbage
collection. Trees are flattened with '/'-joined key paths.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from pathlib import Path
from typing import Any, Dict, Optional, Tuple

import ml_dtypes
import numpy as np

# numpy cannot natively serialize these; store a viewed array + dtype sidecar
_EXOTIC_DTYPES = {
    "bfloat16": (ml_dtypes.bfloat16, np.uint16),
    "float8_e4m3fn": (ml_dtypes.float8_e4m3fn, np.uint8),
    "float8_e5m2": (ml_dtypes.float8_e5m2, np.uint8),
}


def _flatten(tree, prefix="") -> Dict[str, Any]:
    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_flatten(v, f"{prefix}{k}/"))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}{i}/"))
    else:
        out[prefix[:-1]] = tree
    return out


def _unflatten(flat: Dict[str, Any]):
    root: Dict = {}
    for key, v in flat.items():
        parts = key.split("/")
        d = root
        for p in parts[:-1]:
            d = d.setdefault(p, {})
        d[parts[-1]] = v

    def fix(node):
        if isinstance(node, dict) and node and \
                all(k.isdigit() for k in node):
            return tuple(fix(node[str(i)]) for i in range(len(node)))
        if isinstance(node, dict):
            return {k: fix(v) for k, v in node.items()}
        return node

    return fix(root)


class Checkpointer:
    def __init__(self, directory: str, keep_n: int = 3):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep_n = keep_n
        self._async_thread: Optional[threading.Thread] = None

    # ------------------------------------------------------------------
    def save(self, step: int, tree: Dict, meta: Optional[Dict] = None):
        flat = _flatten(tree)
        arrays = {}
        dtype_sidecar = {}
        for k, v in flat.items():
            a = np.asarray(v)
            for name, (dt, carrier) in _EXOTIC_DTYPES.items():
                if a.dtype == dt:
                    dtype_sidecar[k] = name
                    a = a.view(carrier)
                    break
            arrays[k] = a
        tmp = self.dir / f".tmp_step_{step}_{os.getpid()}_{time.time_ns()}"
        tmp.mkdir(parents=True)
        try:
            np.savez(tmp / "arrays.npz", **arrays)
            (tmp / "meta.json").write_text(json.dumps(
                {"step": step, "time": time.time(),
                 "_dtypes": dtype_sidecar, **(meta or {})}))
            final = self.dir / f"step_{step}"
            if final.exists():
                shutil.rmtree(final)
            os.rename(tmp, final)
        finally:
            if tmp.exists():
                shutil.rmtree(tmp, ignore_errors=True)
        self._gc()
        return self.dir / f"step_{step}"

    def save_async(self, step: int, tree: Dict,
                   meta: Optional[Dict] = None) -> threading.Thread:
        self.wait()
        # materialize to host BEFORE backgrounding so the device buffers are
        # free to be donated by the next step
        flat = {k: np.asarray(v) for k, v in _flatten(tree).items()}
        th = threading.Thread(
            target=lambda: self.save(step, flat, meta), daemon=True)
        self._async_thread = th
        th.start()
        return th

    def wait(self):
        if self._async_thread is not None:
            self._async_thread.join()
            self._async_thread = None

    # ------------------------------------------------------------------
    def steps(self):
        out = []
        for p in self.dir.glob("step_*"):
            try:
                out.append(int(p.name.split("_")[1]))
            except ValueError:
                continue
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        s = self.steps()
        return s[-1] if s else None

    def restore(self, step: Optional[int] = None) -> Tuple[Dict, Dict]:
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        d = self.dir / f"step_{step}"
        meta = json.loads((d / "meta.json").read_text())
        sidecar = meta.get("_dtypes", {})
        with np.load(d / "arrays.npz") as z:
            flat = {}
            for k in z.files:
                a = z[k]
                if k in sidecar:
                    a = a.view(_EXOTIC_DTYPES[sidecar[k]][0])
                flat[k] = a
        return _unflatten(flat), meta

    def _gc(self):
        steps = self.steps()
        for s in steps[:-self.keep_n]:
            shutil.rmtree(self.dir / f"step_{s}", ignore_errors=True)
