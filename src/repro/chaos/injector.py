"""ChaosInjector: the runtime half of the fault plane.

One injector instance is shared by every hook in a run. It owns the
plan's clock origin (``start()``), answers "is fault X active / due for
target Y" queries, consumes one-shot events exactly once, and counts
every injection under ``chaos.injected{layer,kind}`` so a soak's metrics
feed shows exactly which faults actually fired.

Hooks shipped here:

  * ``ChaosExecutor`` — a ChunkExecutor decorator injecting executor
    faults (chunk_exception → in-band ChunkFailure, hang → a sleep long
    enough to trip the Watchdog, slowdown → added per-chunk latency).
    It also heartbeats an attached Watchdog around every chunk — the
    wiring that makes hang detection live on any executor, not just the
    hand-written drill.
  * ``ChaosSink`` — a ReplicaSink decorator that fails mirror writes
    while a ``mirror_fail`` window is active (the journal detaches; the
    federation's gossip loop re-syncs when the window passes).
  * ``journal_write_filter`` — JournalStore write hook corrupting or
    stalling the next primary record (the mirror always gets the true
    line: chaos models a bad local disk, not a bad wire).
  * ``skewed_clock`` / ``wrap_queue`` — queue-layer faults: admission
    clock skew and swallowed arrival notifications (the drain's
    fallback timeout is the liveness backstop under test).

Every query is cheap (a scan over a small event list under one lock);
the hot executor path only pays it per *chunk*, not per item.
"""
from __future__ import annotations

import threading
import time
from typing import Callable, List, Optional, Sequence, Tuple

from repro import telemetry as telemetry_mod
from repro.chaos.plan import FaultEvent, FaultPlan
from repro.core.dispatch import ChunkFailure
from repro.core.types import ChunkRecord, Token


class ChaosInjector:
    def __init__(self, plan: FaultPlan, clock=None, sleep=None,
                 telemetry=None):
        self.plan = plan
        self.clock = clock if clock is not None else time.monotonic
        self._sleep = sleep if sleep is not None else time.sleep
        self.telemetry = telemetry_mod.resolve(telemetry)
        self._lock = threading.Lock()
        self._events: List[Tuple[int, FaultEvent]] = \
            list(enumerate(plan.events))
        self._consumed: set = set()      # one-shot event ids fired
        self._seen: set = set()          # window event ids counted once
        self._t0: Optional[float] = None
        self.injected = 0                # total injections (tests)

    # -- lifecycle -----------------------------------------------------
    def start(self, now: Optional[float] = None) -> None:
        with self._lock:
            if self._t0 is None:
                self._t0 = self.clock() if now is None else now

    def started(self) -> bool:
        return self._t0 is not None

    def now_s(self) -> float:
        """Seconds since start() (0.0 before it — no fault fires until
        the harness opens the window)."""
        if self._t0 is None:
            return 0.0
        return self.clock() - self._t0

    def done(self) -> bool:
        """Past the horizon with every one-shot consumed or expired."""
        return self.started() and self.now_s() >= self.plan.horizon_s

    # -- queries -------------------------------------------------------
    def active(self, layer: str, kind: str,
               target: Optional[str] = None) -> Optional[FaultEvent]:
        """The first *windowed* event of (layer, kind) covering now and
        matching target, or None. Counted once per event."""
        if self._t0 is None:
            return None
        t = self.now_s()
        with self._lock:
            for idx, ev in self._events:
                if ev.layer != layer or ev.kind != kind \
                        or ev.duration_s <= 0.0:
                    continue
                if ev.at_s <= t < ev.end_s and ev.matches(target):
                    if idx not in self._seen:
                        self._seen.add(idx)
                        self._count(ev)
                    return ev
        return None

    def take(self, layer: str, kind: str,
             target: Optional[str] = None) -> Optional[FaultEvent]:
        """Consume one due *one-shot* event of (layer, kind) for target.
        Exactly-once: the first hook to observe it due gets it."""
        if self._t0 is None:
            return None
        t = self.now_s()
        with self._lock:
            for idx, ev in self._events:
                if ev.layer != layer or ev.kind != kind \
                        or ev.duration_s > 0.0 or idx in self._consumed:
                    continue
                if ev.at_s <= t and ev.matches(target):
                    self._consumed.add(idx)
                    self._count(ev)
                    return ev
        return None

    def take_kills(self, alive: Sequence[str]) -> List[str]:
        """Due, unconsumed kill events whose target is still alive."""
        out = []
        for rid in alive:
            if self.take("federation", "kill", rid) is not None:
                out.append(rid)
        return out

    def _count(self, ev: FaultEvent) -> None:
        self.injected += 1
        if self.telemetry is not None:
            self.telemetry.registry.counter(
                "chaos.injected", layer=ev.layer, kind=ev.kind).add(1)
            self.telemetry.tracer.instant(
                "chaos", tid="chaos", layer=ev.layer, kind=ev.kind,
                target=ev.target, at_s=ev.at_s)

    # -- queue-layer hooks ---------------------------------------------
    def skewed_clock(self, target: str, base=None) -> Callable[[], float]:
        """A clock that reads ``base()`` plus the magnitude of any
        active clock_skew window for ``target`` — hand it to an
        AdmissionController to skew its deadline/delay arithmetic."""
        base = base if base is not None else time.monotonic

        def clk() -> float:
            t = base()
            ev = self.active("queue", "clock_skew", target)
            return t + ev.magnitude if ev is not None else t
        return clk

    def wrap_queue(self, queue, target: str):
        """Decorate ``queue.add_listener`` so listeners registered after
        this call silently drop notifications while a listener_drop
        window is active (the drain's fallback timeout must cover)."""
        orig_add = getattr(queue, "add_listener", None)
        if orig_add is None:
            return queue
        inj = self

        def add_listener(fn):
            def guarded(*a, **k):
                if inj.active("queue", "listener_drop", target) is not None:
                    return None
                return fn(*a, **k)
            orig_add(guarded)
        queue.add_listener = add_listener
        return queue

    # -- journal-layer hook ----------------------------------------------
    def journal_write_filter(self, rid: str) \
            -> Callable[[str], Optional[str]]:
        """JournalStore write hook: stalls or corrupts the next primary
        record when a due journal fault targets ``rid``. Returns the
        exact string to write (None → unmodified ``line + "\\n"``); the
        mirror always receives the true line."""

        def filt(line: str) -> Optional[str]:
            ev = self.take("journal", "fsync_stall", rid)
            if ev is not None and ev.magnitude > 0.0:
                self._sleep(ev.magnitude)
            ev = self.take("journal", "corrupt_record", rid)
            if ev is not None:
                mid = len(line) // 2
                return line[:mid] + "#CHAOS#" + line[mid:] + "\n"
            return None
        return filt


class ChaosExecutor:
    """ChunkExecutor decorator: executor-layer faults + Watchdog wiring.

    Faults fire at chunk granularity on the owning dispatcher thread:
    ``chunk_exception`` raises in-band ChunkFailure (the scheduler's
    requeue + group-removal path), ``hang`` sleeps ``magnitude`` seconds
    before executing — long enough that an attached Watchdog times the
    group out mid-sleep (the chunk still completes afterwards, so no
    items are lost; the group is simply declared dead while wedged) — and
    ``slowdown`` adds ``magnitude`` seconds per chunk inside its window.

    When a ``watchdog`` is attached, every chunk is bracketed by
    ``chunk_started`` / ``chunk_finished`` — the heartbeat feed
    fault_tolerance.Watchdog needs, previously wired only in tests.
    """

    def __init__(self, inner, group: str, injector: ChaosInjector,
                 watchdog=None, sleep=None):
        self.inner = inner
        self.group = group
        self.injector = injector
        self.watchdog = watchdog
        self._sleep = sleep if sleep is not None else time.sleep

    # pass-throughs ----------------------------------------------------
    def on_worker_start(self) -> None:
        self.inner.on_worker_start()

    def drain(self):
        return self.inner.drain()

    def cancel(self):
        return self.inner.cancel()

    def abort(self):
        return self.inner.abort()

    def completed(self):
        return self.inner.completed()

    # fault-injecting execute ------------------------------------------
    def execute(self, token: Token, rec: ChunkRecord):
        inj = self.injector
        ev = inj.take("executor", "chunk_exception", self.group)
        if ev is not None:
            raise ChunkFailure(
                f"chaos: injected chunk exception on {self.group}")
        if self.watchdog is not None:
            self.watchdog.chunk_started(self.group, token.chunk.size)
        ev = inj.take("executor", "hang", self.group)
        if ev is not None and ev.magnitude > 0.0:
            self._sleep(ev.magnitude)
        ev = inj.active("executor", "slowdown", self.group)
        if ev is not None and ev.magnitude > 0.0:
            self._sleep(ev.magnitude)
        done = self.inner.execute(token, rec)
        if self.watchdog is not None:
            self.watchdog.chunk_finished(self.group)
        return done


class ChaosSink:
    """ReplicaSink decorator failing writes during mirror_fail windows.
    The journal detaches on the raised error (its contract for any bad
    sink); the federation heals by re-syncing once the window passes."""

    def __init__(self, inner, rid: str, injector: ChaosInjector):
        self.inner = inner
        self.rid = rid
        self.injector = injector
        self.path = getattr(inner, "path", None)

    def _gate(self) -> None:
        ev = self.injector.active("federation", "mirror_fail", self.rid)
        if ev is not None:
            raise OSError(
                f"chaos: mirror write failure for {self.rid}")

    def append(self, line: str) -> None:
        self._gate()
        self.inner.append(line)

    def rewrite(self, lines) -> None:
        self._gate()
        self.inner.rewrite(lines)

    def close(self) -> None:
        self.inner.close()
