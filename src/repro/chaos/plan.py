"""FaultPlan: one seedable, deterministic spec for layered fault drills.

A plan is a flat, time-ordered list of ``FaultEvent``s generated from a
single ``random.Random(seed)`` stream, so the same seed always yields a
byte-identical schedule (``to_json`` round-trips exactly — the
reproduction workflow is "re-run with the seed from the failing soak
row"). Events name a *layer* (executor / journal / federation / queue),
a *kind* within it, a target (group name, runtime id, or ``"*"``), an
onset relative to plan start, and either a window (``duration_s > 0``)
or a one-shot trigger (``duration_s == 0``, consumed once by the first
hook that observes it due).

Kinds by layer (hooks live in repro.chaos.injector and the layers
themselves):

  executor    chunk_exception (one-shot → ChunkFailure), hang (one-shot,
              one chunk sleeps ``magnitude`` seconds so the Watchdog
              trips), slowdown (window, +``magnitude`` seconds per
              chunk)
  journal     corrupt_record / fsync_stall (one-shot, applied to the
              next primary write via the journal's write filter),
              torn_write (one-shot, applied by ``kill_runtime`` as the
              crash-mid-write artifact)
  federation  gossip_drop / gossip_delay / partition (windows on a
              runtime's heartbeat publish), mirror_fail (window on its
              replica sink), kill (one-shot runtime crash)
  queue       clock_skew (window, admission clock + ``magnitude``),
              listener_drop (window, queue arrival notifies swallowed)

The generator keeps three safety constraints so randomized plans stay
inside the no-loss envelope the soak asserts (each is a *real* coverage
gap, documented in README — synchronous replication ack would be the
fix, out of scope here): per runtime, a ``mirror_fail`` window never
overlaps a ``kill``, ``torn_write``, or ``corrupt_record`` on the same
runtime; at most ``len(runtimes) - 1`` kills total; kills land in the
middle 60 % of the horizon so there is work to fail over.
"""
from __future__ import annotations

import json
import random
from dataclasses import asdict, dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

LAYERS = ("executor", "journal", "federation", "queue")

KINDS: Dict[str, Tuple[str, ...]] = {
    "executor": ("chunk_exception", "hang", "slowdown"),
    "journal": ("corrupt_record", "fsync_stall", "torn_write"),
    "federation": ("gossip_drop", "gossip_delay", "partition",
                   "mirror_fail", "kill"),
    "queue": ("clock_skew", "listener_drop"),
}


@dataclass(frozen=True)
class FaultEvent:
    at_s: float                 # onset, seconds from ChaosInjector.start()
    layer: str                  # one of LAYERS
    kind: str                   # one of KINDS[layer]
    target: str                 # group / runtime id / "*"
    duration_s: float = 0.0     # 0 → one-shot, else active window length
    magnitude: float = 0.0      # kind-specific (skew s, lag s, per-chunk s)

    def __post_init__(self):
        if self.layer not in KINDS:
            raise ValueError(f"unknown fault layer {self.layer!r}")
        if self.kind not in KINDS[self.layer]:
            raise ValueError(
                f"unknown {self.layer} fault kind {self.kind!r}")

    @property
    def end_s(self) -> float:
        return self.at_s + self.duration_s

    def matches(self, target: Optional[str]) -> bool:
        return target is None or self.target == "*" \
            or self.target == target


@dataclass
class FaultPlan:
    seed: int
    horizon_s: float
    events: List[FaultEvent] = field(default_factory=list)

    # -- serialization (byte-stable) -----------------------------------
    def to_json(self) -> str:
        """Deterministic: same plan → same bytes (sorted keys, floats
        already rounded by generate())."""
        return json.dumps(
            {"seed": self.seed, "horizon_s": self.horizon_s,
             "events": [asdict(e) for e in self.events]},
            sort_keys=True)

    @classmethod
    def from_json(cls, s: str) -> "FaultPlan":
        d = json.loads(s)
        return cls(seed=int(d["seed"]), horizon_s=float(d["horizon_s"]),
                   events=[FaultEvent(**e) for e in d["events"]])

    # -- hand-authored plans -------------------------------------------
    @classmethod
    def compose(cls, events: Sequence[FaultEvent], horizon_s: float,
                seed: int = -1) -> "FaultPlan":
        """Explicitly composed plan (smoke drills, regression repros);
        ``seed=-1`` marks it as not generator-derived."""
        evs = sorted(events, key=lambda e: (e.at_s, e.layer, e.kind,
                                            e.target))
        return cls(seed=seed, horizon_s=horizon_s, events=evs)

    # -- seeded generation ---------------------------------------------
    @classmethod
    def generate(cls, seed: int, horizon_s: float,
                 runtimes: Sequence[str], groups: Sequence[str],
                 events_per_s: float = 2.0,
                 kinds: Optional[Sequence[Tuple[str, str]]] = None) \
            -> "FaultPlan":
        """Randomized layered schedule from one seeded stream.

        ``kinds`` restricts the (layer, kind) pool; default is every
        hookable kind except ``torn_write`` paired automatically with
        kills (the torn tail is a crash artifact, meaningless without
        one). Deterministic: all randomness comes from
        ``random.Random(seed)``, and floats are rounded to µs so the
        JSON form is byte-stable across platforms.
        """
        rng = random.Random(seed)
        runtimes = list(runtimes)
        groups = list(groups)
        pool = list(kinds) if kinds is not None else [
            ("executor", "chunk_exception"), ("executor", "hang"),
            ("executor", "slowdown"),
            ("journal", "corrupt_record"), ("journal", "fsync_stall"),
            ("federation", "gossip_drop"), ("federation", "gossip_delay"),
            ("federation", "partition"), ("federation", "mirror_fail"),
            ("federation", "kill"),
            ("queue", "clock_skew"), ("queue", "listener_drop"),
        ]
        n_events = max(1, int(events_per_s * horizon_s))
        events: List[FaultEvent] = []
        kills: List[Tuple[str, float]] = []        # (runtime, at_s)
        mirror_windows: List[Tuple[str, float, float]] = []
        max_kills = max(0, len(runtimes) - 1)

        def overlaps_mirror(rid: str, t0: float, t1: float) -> bool:
            return any(r == rid and t0 <= we and t1 >= wb
                       for r, wb, we in mirror_windows)

        for _ in range(n_events):
            layer, kind = pool[rng.randrange(len(pool))]
            at = round(rng.uniform(0.0, horizon_s), 6)
            if layer == "executor":
                target = groups[rng.randrange(len(groups))] if groups \
                    else "*"
                if kind == "chunk_exception":
                    events.append(FaultEvent(at, layer, kind, target))
                elif kind == "hang":
                    # one-shot (duration_s=0): ONE chunk wedges for
                    # ``magnitude`` seconds — long enough to trip a
                    # 0.25s-floor watchdog, short enough to drain past
                    mag = round(rng.uniform(0.3, 0.8), 6)
                    events.append(FaultEvent(at, layer, kind, target,
                                             magnitude=mag))
                else:                              # slowdown
                    dur = round(rng.uniform(0.2, 0.6), 6)
                    mag = round(rng.uniform(0.002, 0.01), 6)
                    events.append(FaultEvent(at, layer, kind, target,
                                             duration_s=dur,
                                             magnitude=mag))
            elif layer == "journal":
                rid = runtimes[rng.randrange(len(runtimes))]
                if kind == "corrupt_record" \
                        and overlaps_mirror(rid, at, at):
                    continue                       # keep a surviving copy
                mag = round(rng.uniform(0.01, 0.05), 6) \
                    if kind == "fsync_stall" else 0.0
                events.append(FaultEvent(at, layer, kind, rid,
                                         magnitude=mag))
            elif layer == "federation":
                rid = runtimes[rng.randrange(len(runtimes))]
                if kind == "kill":
                    if len(kills) >= max_kills \
                            or any(k[0] == rid for k in kills):
                        continue
                    at = round(rng.uniform(0.2 * horizon_s,
                                           0.8 * horizon_s), 6)
                    if overlaps_mirror(rid, at, at):
                        continue                   # replica must be whole
                    kills.append((rid, at))
                    events.append(FaultEvent(at, layer, kind, rid))
                    # crash-mid-write artifact rides along half the time
                    if rng.random() < 0.5:
                        events.append(FaultEvent(at, "journal",
                                                 "torn_write", rid))
                elif kind == "mirror_fail":
                    dur = round(rng.uniform(0.2, 0.5), 6)
                    if any(k[0] == rid and at <= k[1] <= at + dur
                           for k in kills):
                        continue
                    mirror_windows.append((rid, at, at + dur))
                    events.append(FaultEvent(at, layer, kind, rid,
                                             duration_s=dur))
                elif kind == "gossip_delay":
                    dur = round(rng.uniform(0.2, 0.6), 6)
                    mag = round(rng.uniform(0.5, 2.0), 6)
                    events.append(FaultEvent(at, layer, kind, rid,
                                             duration_s=dur,
                                             magnitude=mag))
                else:                              # gossip_drop/partition
                    dur = round(rng.uniform(0.2, 0.6), 6)
                    events.append(FaultEvent(at, layer, kind, rid,
                                             duration_s=dur))
            else:                                  # queue
                rid = runtimes[rng.randrange(len(runtimes))]
                dur = round(rng.uniform(0.2, 0.6), 6)
                mag = round(rng.uniform(-0.5, 0.5), 6) \
                    if kind == "clock_skew" else 0.0
                events.append(FaultEvent(at, layer, kind, rid,
                                         duration_s=dur, magnitude=mag))
        events.sort(key=lambda e: (e.at_s, e.layer, e.kind, e.target))
        return cls(seed=seed, horizon_s=horizon_s, events=events)
