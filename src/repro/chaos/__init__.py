"""repro.chaos — seedable, deterministic fault injection at every layer.

``FaultPlan.generate(seed, ...)`` builds a byte-stable schedule of
layered faults; a shared ``ChaosInjector`` answers active/due queries
for the hooks (``ChaosExecutor``, ``ChaosSink``, the journal write
filter, skewed clocks, listener drops) and counts every injection under
``chaos.injected{layer,kind}``. benchmarks/chaos_soak.py drives N seeded
plans against a federated serve and hard-fails on job loss, duplicate
completion, or slow recovery; ``--chaos-seed`` / ``--chaos-plan`` wire
the same plane into the serve CLI.
"""
from repro.chaos.plan import KINDS, LAYERS, FaultEvent, FaultPlan
from repro.chaos.injector import ChaosExecutor, ChaosInjector, ChaosSink

__all__ = ["FaultEvent", "FaultPlan", "ChaosExecutor", "ChaosInjector",
           "ChaosSink", "KINDS", "LAYERS"]
