"""Job model and lifecycle state machine for the admission/queue layer.

A Job is the unit of *admission*: a contiguous slab of ``items`` iterations
(requests, samples) that enters the system with a priority and flows

    PENDING → ADMITTED → RUNNING → {DONE, FAILED, REQUEUED, CANCELLED}
                 ↑______________________________|
                        (REQUEUED → ADMITTED)

Transitions are validated — an illegal transition raises IllegalTransition
rather than silently corrupting queue accounting (the GPUScheduler lesson:
state drift between heap and store is the classic queue bug). Each
transition stamps the timestamp the metric layer needs (queue delay is
``started_at − created_at``; service time is ``finished_at − started_at``).
"""
from __future__ import annotations

import json
import time
import uuid
from dataclasses import asdict, dataclass, field
from enum import Enum
from typing import Any, Dict, Optional

from repro.core.types import TIERS, tier_rank


def now() -> float:
    """Wall-clock source for every job lifecycle stamp. Module-level so
    the deterministic test harness (tests/clock.py) can substitute a
    virtual clock (``repro.queue.job.now = vclock.now``) and make queue
    delays / deadlines exact instead of sleep-raced."""
    return time.time()


class JobState(str, Enum):
    PENDING = "pending"        # submitted, awaiting admission decision
    ADMITTED = "admitted"      # accepted, sitting in the priority queue
    RUNNING = "running"        # drained into a DynamicScheduler run
    DONE = "done"              # all items completed
    FAILED = "failed"          # exhausted attempts / rejected fatally
    REQUEUED = "requeued"      # failed in-flight, eligible for re-admission
    CANCELLED = "cancelled"    # withdrawn by caller or rejected at admission


#: legal state graph; anything not listed raises IllegalTransition.
TRANSITIONS: Dict[JobState, frozenset] = {
    JobState.PENDING: frozenset({JobState.ADMITTED, JobState.FAILED,
                                 JobState.CANCELLED}),
    JobState.ADMITTED: frozenset({JobState.RUNNING, JobState.CANCELLED}),
    JobState.RUNNING: frozenset({JobState.DONE, JobState.FAILED,
                                 JobState.REQUEUED, JobState.CANCELLED}),
    JobState.REQUEUED: frozenset({JobState.ADMITTED, JobState.FAILED,
                                  JobState.CANCELLED}),
    JobState.DONE: frozenset(),
    JobState.FAILED: frozenset(),
    JobState.CANCELLED: frozenset(),
}

TERMINAL = frozenset({JobState.DONE, JobState.FAILED, JobState.CANCELLED})


class IllegalTransition(ValueError):
    """Raised on a state change the lifecycle graph does not allow."""


@dataclass
class Job:
    """One admitted slab of work: ``items`` iterations at ``priority``.

    Lower ``priority`` is more urgent (heap order); ties break FIFO on the
    queue's admission sequence number, not on wall-clock, so two jobs
    admitted in the same clock tick still have a deterministic order.

    ``tier`` is the latency class (core.types.TIERS): it orders the heap
    *above* ``priority`` (any urgent job beats any standard job), selects
    the express lane in the service, and sets the epoch priority its
    batch runs at. ``deadline_s`` is a relative latency budget from
    ``created_at``; a job past ``deadline_at`` is shed at admission or
    pop, and an in-flight batch past it is cancelled cooperatively.
    """
    items: int = 1
    priority: int = 10
    tier: str = "standard"
    deadline_s: Optional[float] = None
    job_id: str = field(default_factory=lambda: uuid.uuid4().hex)
    tenant: str = "default"
    state: JobState = JobState.PENDING
    created_at: float = field(default_factory=lambda: now())
    admitted_at: Optional[float] = None
    started_at: Optional[float] = None        # latest dispatch
    first_started_at: Optional[float] = None  # first dispatch (SLO metric)
    finished_at: Optional[float] = None
    attempts: int = 0
    max_attempts: int = 3
    meta: Dict[str, Any] = field(default_factory=dict)

    def __post_init__(self):
        if self.items <= 0:
            raise ValueError(f"job {self.job_id}: items must be > 0")
        if not self.tenant:
            # the tenant is a routing key (queue shard, DWRR weight,
            # accounting bucket) — an empty one would silently create a
            # phantom shard
            raise ValueError(f"job {self.job_id}: tenant must be non-empty")
        if isinstance(self.state, str) and not isinstance(self.state,
                                                          JobState):
            self.state = JobState(self.state)
        tier_rank(self.tier)    # unknown tier names fail at submission
        if self.deadline_s is not None and self.deadline_s <= 0:
            raise ValueError(f"job {self.job_id}: deadline_s must be > 0")

    # -- lifecycle -----------------------------------------------------
    def transition(self, new: JobState) -> "Job":
        if new not in TRANSITIONS[self.state]:
            raise IllegalTransition(
                f"job {self.job_id}: {self.state.value} -> {new.value}")
        t = now()
        if new == JobState.ADMITTED:
            self.admitted_at = t
        elif new == JobState.RUNNING:
            self.started_at = t
            if self.first_started_at is None:
                self.first_started_at = t
            self.attempts += 1
        elif new in TERMINAL or new == JobState.REQUEUED:
            self.finished_at = t
        self.state = new
        return self

    @property
    def rank(self) -> int:
        """Tier comparison key (lower = more urgent)."""
        return tier_rank(self.tier)

    @property
    def deadline_at(self) -> Optional[float]:
        """Absolute deadline on the job clock, or None (no deadline)."""
        if self.deadline_s is None:
            return None
        return self.created_at + self.deadline_s

    @property
    def terminal(self) -> bool:
        return self.state in TERMINAL

    @property
    def queue_delay(self) -> Optional[float]:
        """Submission-to-first-dispatch latency (the SLO the admission
        controller protects). Uses the *first* dispatch so a requeued
        job's earlier service time does not inflate the queue metric."""
        if self.first_started_at is None:
            return None
        return self.first_started_at - self.created_at

    @property
    def attempts_left(self) -> int:
        return max(0, self.max_attempts - self.attempts)

    # -- serialization (journal lines) ---------------------------------
    def to_dict(self) -> Dict[str, Any]:
        d = asdict(self)
        d["state"] = self.state.value
        return d

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "Job":
        job = cls(items=int(d.get("items", 1)),
                  priority=int(d.get("priority", 10)),
                  tier=d.get("tier", "standard"),
                  deadline_s=d.get("deadline_s"),
                  job_id=d.get("job_id", uuid.uuid4().hex),
                  tenant=d.get("tenant", "default"),
                  state=JobState(d.get("state", "pending")),
                  created_at=float(d.get("created_at", time.time())),
                  admitted_at=d.get("admitted_at"),
                  started_at=d.get("started_at"),
                  first_started_at=d.get("first_started_at"),
                  finished_at=d.get("finished_at"),
                  attempts=int(d.get("attempts", 0)),
                  max_attempts=int(d.get("max_attempts", 3)),
                  meta=dict(d.get("meta") or {}))
        return job

    def to_json(self) -> str:
        return json.dumps(self.to_dict(), sort_keys=True)

    @classmethod
    def from_json(cls, s: str) -> "Job":
        return cls.from_dict(json.loads(s))
