"""Job queue & admission control feeding the dynamic scheduler.

The §3.1 pipeline schedules work it is handed; this package is the layer
in front of it for a scheduling *service*: admission (backpressure against
a queue-delay SLO), prioritization (thread-safe heap), durability
(append-only JSONL journal with crash recovery), and a daemon loop that
drains admitted jobs into DynamicScheduler runs and requeues work lost to
group failures.
"""
from repro.queue.job import (TERMINAL, TRANSITIONS, IllegalTransition, Job,
                             JobState)
from repro.queue.manager import EXPRESS_RANK, QueueManager
from repro.queue.admission import (AdmissionController, AdmissionDecision,
                                   Decision)
from repro.queue.journal import JournalStore
from repro.queue.service import (BatchReport, JobService, ServiceStats,
                                 percentiles)

__all__ = [
    "TERMINAL", "TRANSITIONS", "IllegalTransition", "Job", "JobState",
    "EXPRESS_RANK", "QueueManager",
    "AdmissionController", "AdmissionDecision", "Decision",
    "JournalStore", "BatchReport", "JobService", "ServiceStats",
    "percentiles",
]
