"""JobService: continuous drain of the queue into the persistent runtime.

Each batch pops up to ``batch_jobs`` jobs (priority order; one
``pop_many`` lock acquisition / DWRR pass when the queue supports the
batched drain), concatenates
their items into one iteration space, and submits it as an *epoch* on a
long-lived DynamicScheduler runtime — the paper's §3.1 pipeline is the
*execution* layer; this is the *admission-to-execution* bridge. The drain
is double-buffered (``pipeline_depth``, default 2): batch N+1 is popped,
marked RUNNING, and submitted while batch N's chunks are still in flight,
so the inter-batch barrier (scheduler rebuild + thread spawn + join) that
the rebuild-per-batch design paid disappears; benchmarks/batch_boundary.py
quantifies the difference. ``persistent=False`` restores the old
build-run-teardown behavior per batch (the benchmark baseline).

When a device group dies mid-epoch the scheduler's own chunk requeue
(work conservation on iteration count) still completes the epoch, so jobs
are DONE; an epoch that loses *all* groups completes only part of its
count, and since the runtime conserves count, not iteration identity,
there is no way to attribute the partial completion to specific jobs —
the whole batch is REQUEUED (at-least-once semantics, bounded by
``max_attempts``). A runtime with no live groups left is rebuilt from
``make_scheduler`` before the next batch. This is the ChunkFailure →
requeue conversion the fault-tolerance layer promises.

Group failures observed in an epoch (in-band ChunkFailure) and hangs
caught by the runtime Watchdog both flow to the AdmissionController as
on_group_leave events, shrinking advertised capacity immediately; a
StragglerDetector, when attached, derates a slowing group's advertised
capacity *before* it is declared dead.
"""
from __future__ import annotations

import collections
import logging
import math
import random
import threading
import time
from dataclasses import dataclass, field
from typing import Callable, Deque, Dict, List, Optional, Sequence, Tuple

from repro import telemetry as telemetry_mod
from repro.core.scheduler import DynamicScheduler, EpochHandle, \
    ScheduleResult
from repro.core.types import IterationSpace, TIERS
from repro.queue import job as job_mod
from repro.queue.job import IllegalTransition, Job, JobState
from repro.queue.admission import AdmissionController, AdmissionDecision, \
    Decision
from repro.queue.journal import JournalStore
from repro.queue.manager import QueueManager

try:                                    # optional hang detection
    from repro.runtime.fault_tolerance import Watchdog
except Exception:                       # pragma: no cover
    Watchdog = None                     # type: ignore

try:                                    # optional straggler derating
    from repro.runtime.straggler import StragglerDetector
except Exception:                       # pragma: no cover
    StragglerDetector = None            # type: ignore

logger = logging.getLogger(__name__)

clock = time.monotonic


def percentiles(xs: Sequence[float],
                ps: Sequence[float] = (50.0, 95.0, 99.0)) \
        -> Dict[str, float]:
    """Nearest-rank percentiles, {"p50": ..} — no numpy dependency here."""
    out: Dict[str, float] = {}
    if not xs:
        return {f"p{p:g}": 0.0 for p in ps}
    s = sorted(xs)
    for p in ps:
        k = max(0, min(len(s) - 1, math.ceil(p / 100.0 * len(s)) - 1))
        out[f"p{p:g}"] = s[k]
    return out


@dataclass
class BatchReport:
    jobs: List[Job]
    completed_items: int
    total_items: int
    failed_groups: List[str]
    schedule: Optional[ScheduleResult] = None
    submitted_at: float = 0.0
    finished_at: float = 0.0


@dataclass
class ServiceStats:
    batches: int = 0
    done: int = 0
    failed: int = 0
    requeues: int = 0
    queue_delays: List[float] = field(default_factory=list)
    per_group_items: Dict[str, int] = field(default_factory=dict)
    errors: List[str] = field(default_factory=list)
    # batches submitted before the previous batch finished — the
    # double-buffered drain working (counted incrementally)
    overlapped: int = 0
    # latency-tier bookkeeping: per-tier deadline misses (shed at pop or
    # cancelled in flight), express-lane batches, cancelled batches
    deadline_misses: Dict[str, int] = field(default_factory=dict)
    express_batches: int = 0
    cancelled_batches: int = 0
    # (submitted_at, finished_at) monotonic stamps of recent batches;
    # capped so a long-lived daemon's memory stays bounded
    batch_windows: List[Tuple[float, float]] = field(default_factory=list)
    WINDOW_CAP = 10_000

    def delay_percentiles(self) -> Dict[str, float]:
        return percentiles(self.queue_delays)

    def overlapped_batches(self) -> int:
        """Batches submitted before the previous batch finished."""
        return self.overlapped

    def record_window(self, submitted_at: float, finished_at: float) -> None:
        if self.batch_windows and submitted_at < self.batch_windows[-1][1]:
            self.overlapped += 1
        if len(self.batch_windows) < self.WINDOW_CAP:
            self.batch_windows.append((submitted_at, finished_at))


class DrainWakeup:
    """Event-driven wakeup for the drain loop — replaces the fixed
    ``poll_s`` sleep that made the service trade idle CPU burn against
    dispatch latency. ``notify`` is fan-in from every source of new
    drain work: queue arrival listeners (put/requeue), epoch
    done-callbacks (completion frees a pipeline slot), submit(), and
    stop(). ``wait`` parks the drain thread until a notify or a fallback
    timeout (liveness backstop for duck-typed queues without listeners).

    Lost-notify safety: every notify happens AFTER its state change is
    visible, and the loop always pumps after waking — so a notify that
    races the event-clear can at worst cause one extra (cheap) pump, never
    a missed job. Counters are plain ints (GIL-atomic +=, observability
    only): ``event_wakeups`` vs ``timeout_wakeups`` is the idle-efficiency
    signal scripts/smoke.sh asserts on.
    """

    def __init__(self):
        self._event = threading.Event()
        self.notified = 0
        self.event_wakeups = 0
        self.timeout_wakeups = 0

    def notify(self, *_args) -> None:
        """Signal work. Extra args ignored so the same bound method serves
        as a queue listener (no args) and an epoch done-callback (handle)."""
        self.notified += 1
        self._event.set()

    def wait(self, timeout: float) -> bool:
        """Block until notified (True) or ``timeout`` elapses (False);
        consumes the notification."""
        woke = self._event.wait(timeout)
        if woke:
            self._event.clear()
            self.event_wakeups += 1
        else:
            self.timeout_wakeups += 1
        return woke

    def consume(self) -> bool:
        """Non-blocking: consume a pending notification if present. The
        injected-sleep (virtual-clock) drain path uses this so event
        arrival short-circuits the virtual sleep deterministically."""
        if self._event.is_set():
            self._event.clear()
            self.event_wakeups += 1
            return True
        return False

    def stats(self) -> Dict[str, float]:
        return {"notified": float(self.notified),
                "event_wakeups": float(self.event_wakeups),
                "timeout_wakeups": float(self.timeout_wakeups)}


@dataclass
class _InflightBatch:
    jobs: List[Job]
    total: int
    submitted_at: float
    handle: Optional[EpochHandle] = None
    error: Optional[BaseException] = None
    tier: str = "standard"
    # earliest member deadline on the *service* monotonic clock (the job
    # clock and scheduler clock are different domains; bridged at submit)
    deadline_mono: Optional[float] = None
    express: bool = False


class JobService:
    def __init__(self, make_scheduler: Callable[[], DynamicScheduler],
                 queue: Optional[QueueManager] = None,
                 admission: Optional[AdmissionController] = None,
                 journal: Optional[JournalStore] = None,
                 batch_jobs: int = 8, poll_s: float = 0.05,
                 watchdog: Optional["Watchdog"] = None,
                 on_group_failed: Optional[Callable[[str], None]] = None,
                 pipeline_depth: int = 2, persistent: bool = True,
                 straggler: Optional["StragglerDetector"] = None,
                 accountant=None, max_deferred: int = 10_000,
                 telemetry=None, express: bool = True,
                 express_slots: int = 1, clock=None, sleep=None,
                 fallback_s: float = 2.0,
                 health_poll_s: Optional[float] = None,
                 retry_budget: int = 20, retry_base_s: float = 0.02,
                 retry_max_s: float = 1.0,
                 brownout_factor: Optional[float] = None,
                 brownout_after_s: float = 1.0):
        self.make_scheduler = make_scheduler
        # monotonic clock / sleep seams for the deterministic test
        # harness; the ctor arg shadows the module global, hence the
        # globals() reach-around for the default
        self.clock = clock if clock is not None else globals()["clock"]
        self._sleep = sleep if sleep is not None else time.sleep
        # express lane: urgent-tier jobs bypass the pipeline-depth gate
        # (up to express_slots extra batches in flight beyond depth)
        self.express = express
        self.express_slots = max(1, express_slots)
        self.queue = queue or QueueManager()
        self.admission = admission
        self.journal = journal
        self.batch_jobs = max(1, batch_jobs)
        self.poll_s = poll_s
        # event-driven drain: the loop parks on ``wakeup`` and is woken
        # by queue arrivals, epoch completions, and submit/stop;
        # ``fallback_s`` is the liveness backstop (large — events are the
        # primary mechanism), tightened to ``health_poll_s`` when a
        # watchdog/straggler monitor is attached because hangs generate
        # no events and must be caught by polling
        self.fallback_s = fallback_s
        self.health_poll_s = health_poll_s if health_poll_s is not None \
            else max(poll_s, 0.1)
        self.wakeup = DrainWakeup()
        # with an injected sleep (virtual-clock harness) the drain stays
        # on the deterministic sleep path: virtual-time advance IS the
        # wakeup, a real Event.wait would deadlock run_until_idle
        self._injected_sleep = sleep is not None
        add_listener = getattr(self.queue, "add_listener", None)
        if add_listener is not None:
            add_listener(self.wakeup.notify)
        self.watchdog = watchdog
        self.on_group_failed = on_group_failed
        self.pipeline_depth = max(1, pipeline_depth)
        self.persistent = persistent
        self.straggler = straggler
        # duck-typed repro.tenancy.TenantAccountant: attributes each
        # finalized batch's busy time / joules to tenants and feeds soft
        # energy-budget weight derates back into a sharded queue (kept
        # untyped so repro.queue never imports repro.tenancy)
        self.accountant = accountant
        # ceiling on the deferred pool: every deferred job is re-gated
        # each poll, so an unbounded pool is both a memory leak and O(n)
        # lock-held work per loop — beyond the cap, DEFER becomes REJECT
        self.max_deferred = max_deferred
        # bounded deferred-retry policy: each re-offer that DEFERs again
        # backs off exponentially (base * 2^n, capped, jittered so a
        # burst of deferrals doesn't re-offer in lockstep); after
        # ``retry_budget`` failed re-offers the job goes terminal FAILED
        # instead of looping forever against a gate that will never open
        self.retry_budget = max(1, retry_budget)
        self.retry_base_s = retry_base_s
        self.retry_max_s = retry_max_s
        self._retry_rng = random.Random(0xC0FFEE)   # jitter only; seeded
        self._retry_at: Dict[str, float] = {}       # job_id -> eligible at
        # graceful brownout: when admission's projected delay exceeds
        # ``brownout_factor × slo`` continuously for ``brownout_after_s``,
        # shed queued batch-tier work; another sustained interval sheds
        # standard; urgent is shed last. None disables the controller.
        self.brownout_factor = brownout_factor
        self.brownout_after_s = brownout_after_s
        self._brownout_since: Optional[float] = None
        self._brownout_level = 0
        self.stats = ServiceStats()
        self._deferred: List[Job] = []
        # job ids already replayed by recover(): a journal recovered twice
        # (or two replicas overlapping after a messy failover) must not
        # double-enqueue the same job. Bounded by jobs ever recovered.
        self._recovered_ids: set = set()
        self._lock = threading.Lock()
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        self._sched: Optional[DynamicScheduler] = None
        self._inflight: Deque[_InflightBatch] = collections.deque()
        # service-layer metrics: batch throughput counters, per-tenant
        # queue-delay histograms, and snapshot-time gauges for the
        # deferred pool / in-flight pipeline / queue depth
        self.telemetry = telemetry_mod.resolve(telemetry)
        self._tel: Dict[str, object] = {}
        if self.telemetry is not None:
            self.telemetry.registry.add_collector(self._collect)

    # -- telemetry plumbing --------------------------------------------
    def _counter(self, name: str, **labels):
        key = (name,) + tuple(sorted(labels.items()))
        c = self._tel.get(key)
        if c is None:
            c = self._tel[key] = self.telemetry.registry.counter(
                name, **labels)
        return c

    def _histogram(self, name: str, **labels):
        key = ("h", name) + tuple(sorted(labels.items()))
        h = self._tel.get(key)
        if h is None:
            h = self._tel[key] = self.telemetry.registry.histogram(
                name, **labels)
        return h

    def _collect(self) -> None:
        reg = self.telemetry.registry
        with self._lock:
            deferred = len(self._deferred)
        reg.gauge("svc.deferred_jobs").set(deferred)
        reg.gauge("svc.inflight_batches").set(len(self._inflight))
        try:
            reg.gauge("svc.queue_depth").set(self.queue.depth())
        except Exception:       # duck-typed queue without depth()
            pass

    def telemetry_snapshot(self) -> Optional[Dict]:
        """Merged metrics snapshot, or None when uninstrumented."""
        if self.telemetry is None:
            return None
        return self.telemetry.snapshot()

    # -- journaling ----------------------------------------------------
    def _journal(self, job: Job, event: Optional[str] = None) -> None:
        if self.journal is not None:
            self.journal.record(job, event)

    # -- submission ----------------------------------------------------
    def submit(self, job: Job) -> AdmissionDecision:
        """Admission-gate a PENDING job. DEFERred jobs are retried by the
        service loop as backlog drains; REJECTed jobs come back CANCELLED."""
        self._journal(job, "submitted")
        if self.admission is None:
            self.queue.put(job)
            self._journal(job)
            self.wakeup.notify()    # covers duck-typed queues without
            return AdmissionDecision(   # arrival listeners
                Decision.ADMIT, 0.0, float("inf"))
        dec = self.admission.admit(job)
        if dec.decision == Decision.ADMIT:
            self.wakeup.notify()
        if dec.decision == Decision.DEFER:
            with self._lock:
                full = len(self._deferred) >= self.max_deferred
                if not full:
                    self._deferred.append(job)
            if full:                        # shed: a flood (e.g. against
                job.meta["rejected_delay_s"] = dec.projected_delay_s
                job.transition(JobState.CANCELLED)   # a quota-capped
                self.admission.shed_deferred(job)    # tenant) must not
                self._journal(job, "rejected")       # bank unboundedly
                return AdmissionDecision(
                    Decision.REJECT, dec.projected_delay_s,
                    dec.capacity_items_s, tenant=job.tenant,
                    reason=f"deferred pool at capacity "
                           f"({self.max_deferred})")
        self._journal(job, "rejected" if dec.decision == Decision.REJECT
                      else None)
        return dec

    def retry_deferred(self) -> int:
        """Re-offer deferred jobs to the admission gate; returns #admitted.

        Bounded: a job is re-offered only once its backoff window has
        passed (first retry immediately; each further DEFER doubles the
        wait, capped at ``retry_max_s`` and jittered ±50 % so deferred
        floods don't re-offer in lockstep). A job whose ``retry_budget``
        is exhausted goes terminal FAILED — unbounded immediate retry
        against a gate that never opens was both a livelock and O(pool)
        lock-held work per poll.
        """
        if self.admission is None:
            return 0
        now = self.clock()
        with self._lock:
            waiting, self._deferred = self._deferred, []
        if waiting and self._sched is not None \
                and not self._sched.live_groups():
            # every group died while the backlog sat deferred: with
            # nothing queued, no batch start will rebuild the runtime,
            # admission capacity stays pinned at min_capacity, and the
            # re-offer loop would burn its whole retry budget against a
            # gate that can never open — rebuild before re-offering
            self._scheduler()
        admitted = 0
        still: List[Job] = []
        for job in waiting:
            if job.state != JobState.PENDING:      # cancelled while waiting
                self._retry_at.pop(job.job_id, None)
                continue
            if self._retry_at.get(job.job_id, -math.inf) > now:
                still.append(job)                  # backoff not elapsed
                continue
            dec = self.admission.admit(job)
            if dec.decision != Decision.DEFER:
                self._retry_at.pop(job.job_id, None)
                self._journal(job)
                admitted += dec.decision == Decision.ADMIT
                continue
            n = int(job.meta.get("retries", 0)) + 1
            job.meta["retries"] = n
            if self.telemetry is not None:
                self._counter("svc.retries", cause="deferred").add(1)
            if n >= self.retry_budget:
                job.meta["failure"] = \
                    f"deferred retry budget exhausted ({n})"
                job.transition(JobState.FAILED)
                self.admission.shed_deferred(job)
                self.stats.failed += 1
                self._retry_at.pop(job.job_id, None)
                self._journal(job, "retry-exhausted")
                if self.telemetry is not None:
                    self._counter("svc.retries", cause="exhausted").add(1)
                continue
            back = min(self.retry_max_s,
                       self.retry_base_s * (2 ** (n - 1)))
            back *= 0.5 + self._retry_rng.random()
            self._retry_at[job.job_id] = now + back
            still.append(job)
        if still:
            with self._lock:
                self._deferred.extend(still)
        if admitted:
            self.wakeup.notify()
        return admitted

    # -- brownout (graceful overload shedding) -------------------------
    def _shed_tier(self, tier: str) -> int:
        """Cancel every queued (ADMITTED) job of one tier. In-flight
        batches are left to finish — brownout sheds *waiting* load."""
        shed = 0
        try:
            queued = self.queue.jobs(state=JobState.ADMITTED)
        except TypeError:               # duck-typed queue without filter
            queued = [j for j in self.queue.jobs()
                      if j.state == JobState.ADMITTED]
        for j in queued:
            if j.tier != tier:
                continue
            if not self.queue.cancel(j.job_id):
                continue
            j.meta["brownout"] = True
            self._journal(j, "brownout-shed")
            shed += 1
        if shed and self.telemetry is not None:
            self._counter("svc.brownout", tier=tier).add(shed)
        return shed

    def _check_brownout(self) -> None:
        """Overload controller: sustained projected delay beyond
        ``brownout_factor × slo`` sheds queued tiers lowest-value-first
        (batch → standard → urgent), one tier per sustained
        ``brownout_after_s`` interval; recovery (delay back within slo)
        resets fully. ``svc.brownout{tier=}`` counts shed jobs and the
        ``svc.brownout_level`` gauge exposes the current level."""
        if self.admission is None or self.brownout_factor is None:
            return
        slo = getattr(self.admission, "slo_delay_s", math.inf)
        if not math.isfinite(slo):
            return
        now = self.clock()
        delay = self.admission.projected_delay_s()
        if delay > self.brownout_factor * slo:
            if self._brownout_since is None:
                self._brownout_since = now
            level = min(len(TIERS), int((now - self._brownout_since)
                                        / self.brownout_after_s))
            while self._brownout_level < level:
                # shed lowest-value first: batch, then standard, urgent
                tier = TIERS[len(TIERS) - 1 - self._brownout_level]
                n = self._shed_tier(tier)
                self._brownout_level += 1
                logger.warning("brownout level %d: shed %d %s-tier "
                               "job(s) (projected delay %.3fs, slo "
                               "%.3fs)", self._brownout_level, n, tier,
                               delay, slo)
                if self.telemetry is not None:
                    self.telemetry.tracer.instant(
                        "brownout", tid="service",
                        level=self._brownout_level, tier=tier, shed=n)
        elif delay <= slo and self._brownout_level:
            logger.info("brownout cleared (projected delay %.3fs)", delay)
            self._brownout_level = 0
            self._brownout_since = None
        elif delay <= slo:
            self._brownout_since = None
        if self.telemetry is not None:
            self.telemetry.registry.gauge("svc.brownout_level") \
                .set(self._brownout_level)

    # -- replay-driven restart -----------------------------------------
    def recover(self, journal_path: str) -> List[Job]:
        """Rebuild queue state from a crashed process's journal into THIS
        (live) service: in-flight jobs of the dead process re-enter the
        queue — routed to their tenant's shard when the queue is sharded —
        and PENDING jobs get a fresh admission decision. Safe to call
        while the drain daemon is running (the queue is thread-safe and
        the daemon simply starts popping recovered work). Returns the
        re-materialized jobs; terminal history stays in the journal.

        A RUNNING job at crash time comes back REQUEUED (its attempt died
        with the process — at-least-once, bounded by max_attempts); the
        per-tenant in-flight view starts clean because nothing recovered
        is actually on a scheduler yet.

        Replay is deduplicated by job id: recovering the same journal
        twice, or a journal whose jobs this service already holds (e.g.
        a replica overlapping the primary), skips the duplicates instead
        of double-enqueueing them.
        """
        to_requeue, _ = JournalStore.recover(journal_path)
        get = getattr(self.queue, "get", None)
        restored: List[Job] = []
        for job in to_requeue:
            if job.job_id in self._recovered_ids \
                    or (get is not None and get(job.job_id) is not None):
                continue
            self._recovered_ids.add(job.job_id)
            if job.state == JobState.REQUEUED:
                if job.attempts_left <= 0:
                    job.transition(JobState.FAILED)
                    self.stats.failed += 1
                    self._journal(job, "recovery-exhausted")
                    continue
                self.queue.requeue(job)
            elif job.state == JobState.ADMITTED:
                self.queue.put(job)
            else:                              # PENDING: re-gate it
                self.submit(job)
                restored.append(job)
                continue
            self._journal(job, "recovered")
            restored.append(job)
        return restored

    # -- the persistent runtime ----------------------------------------
    def _scheduler(self) -> DynamicScheduler:
        """Live runtime, rebuilt from the factory only when every group
        has died (the persistent-runtime analogue of per-batch rebuild)."""
        s = self._sched
        if s is not None and s.live_groups():
            return s
        rebuilt = s is not None
        if s is not None:
            s.shutdown()
        s = self.make_scheduler()
        s.start()
        self._sched = s
        if rebuilt:
            # the factory brings the same group names back: clear the
            # watchdog's sticky dead verdicts and restore admission
            # capacity for groups whose death was observed (without this
            # the rebuilt runtime serves at zero advertised capacity and
            # one hang per group name is terminal for the service)
            for g in s.live_groups():
                if self.watchdog is not None:
                    self.watchdog.revive(g)
                if self.admission is not None \
                        and g not in self.admission.groups():
                    # rejoin at the λ-tracker's estimate (measurement or
                    # seed), not a blind 1.0: if the group died before
                    # its first chunk completed, a 1.0 seed projects a
                    # huge delay, every deferred re-offer re-defers,
                    # nothing queues, and λ can never be measured — a
                    # deadlock broken only by retry-budget exhaustion
                    tracker = getattr(s, "tracker", None)
                    lam = tracker.get(g) if tracker is not None else 1.0
                    self.admission.on_group_join(g, lam)
        return s

    def scheduler(self) -> Optional[DynamicScheduler]:
        """The live runtime, if one has been built."""
        return self._sched

    # -- health signals ------------------------------------------------
    def _poll_health(self) -> None:
        if self.watchdog is not None:
            for g in self.watchdog.check():
                if self._sched is not None:
                    self._sched.remove_group(g)
                if self.admission is not None:
                    self.admission.on_group_leave(g)
                if self.on_group_failed is not None:
                    self.on_group_failed(g)
        if self.straggler is not None and self.admission is not None:
            reports = self.straggler.observe()
            self.admission.update_stragglers(
                {r.group: r.slowdown for r in reports})

    # -- batch pipeline ------------------------------------------------
    def _pop_batch(self, block_s: float = 0.0) -> List[Job]:
        """Form one scheduler batch. Queues with a batched drain
        (``pop_many``: one lock acquisition / one DWRR pass for the whole
        batch) are preferred; job-at-a-time pop is the fallback for
        duck-typed queues without it."""
        pop_many = getattr(self.queue, "pop_many", None)
        if pop_many is not None:
            return pop_many(self.batch_jobs, timeout=block_s or None)
        jobs: List[Job] = []
        first = self.queue.pop(timeout=block_s or None)
        if first is None:
            return jobs
        jobs.append(first)
        while len(jobs) < self.batch_jobs:
            nxt = self.queue.pop()
            if nxt is None:
                break
            jobs.append(nxt)
        return jobs

    def _record_deadline_miss(self, job: Job, where: str) -> None:
        """Per-tier deadline-miss bookkeeping (stats + telemetry +
        journal). ``where`` is the enforcement point: "pop" (expired
        before dispatch) or "cancel" (in-flight epoch cancelled)."""
        job.meta["deadline_missed"] = True
        self.stats.deadline_misses[job.tier] = \
            self.stats.deadline_misses.get(job.tier, 0) + 1
        if self.telemetry is not None:
            self._counter("svc.deadline_misses", tier=job.tier).add(1)
            self.telemetry.tracer.instant(
                "deadline_miss", tid="service", job=job.job_id,
                tier=job.tier, where=where)
        self._journal(job, "deadline-miss")

    def _submit_batch(self, jobs: List[Job],
                      express: bool = False) -> Optional[BatchReport]:
        """Mark a batch RUNNING and submit its epoch. On submit failure the
        batch is finalized immediately (returns its report); otherwise it
        joins the in-flight pipeline and None is returned. Jobs cancelled
        in the pop-to-dispatch window (two-phase pop leaves them ADMITTED
        and cancellable) are dropped here, not crashed on; jobs already
        past their deadline are shed here (CANCELLED, counted as misses)
        rather than burning device time on work nobody can use."""
        live = []
        jnow = job_mod.now()
        for j in jobs:
            if j.deadline_at is not None and jnow > j.deadline_at:
                try:                        # expired while queued
                    self.queue.mark_finished(j, JobState.CANCELLED)
                except IllegalTransition:
                    pass                    # already terminal elsewhere
                else:
                    self._record_deadline_miss(j, where="pop")
                continue
            try:
                self.queue.mark_running(j)
            except IllegalTransition:       # cancelled while popped
                self._journal(j)
                continue
            self._journal(j)
            live.append(j)
        if not live:
            return None
        jobs = live
        total = sum(j.items for j in jobs)
        # the batch runs at the tier of its most urgent member, and its
        # epoch inherits the earliest member deadline, bridged from the
        # job (wall) clock to the scheduler (monotonic) clock
        tier = TIERS[min(j.rank for j in jobs)]
        deadlines = [j.deadline_at for j in jobs
                     if j.deadline_at is not None]
        deadline_mono = self.clock() + (min(deadlines) - jnow) \
            if deadlines else None
        ib = _InflightBatch(jobs=jobs, total=total,
                            submitted_at=self.clock(), tier=tier,
                            deadline_mono=deadline_mono, express=express)
        if express:
            self.stats.express_batches += 1
            if self.telemetry is not None:
                self._counter("svc.express_batches").add(1)
        if not self.persistent:
            return self._run_batch_sync(ib)
        try:
            sched = self._scheduler()
            ib.handle = sched.submit_epoch(IterationSpace(0, total),
                                           priority=tier,
                                           deadline_s=deadline_mono)
            # completion wakes the drain (frees a pipeline slot / lets a
            # finalized batch's backlog re-gate deferred jobs)
            add_cb = getattr(ib.handle, "add_done_callback", None)
            if add_cb is not None:
                add_cb(self.wakeup.notify)
            if self.telemetry is not None:
                # register the batch's tenant composition against the
                # epoch index BEFORE any chunk completes, so chunk spans
                # carry tenant tags at export time (the scheduler itself
                # conserves iteration count, not job identity)
                tenants: Dict[str, int] = {}
                for j in jobs:
                    tenants[j.tenant] = tenants.get(j.tenant, 0) + j.items
                self.telemetry.tracer.tag_epoch(
                    ib.handle.index, {"tenants": tenants,
                                      "jobs": len(jobs), "tier": tier})
        except Exception as e:          # broken factory / submit: fail the
            ib.error = e                # batch, not the daemon
            logger.exception("batch of %d jobs failed to submit", len(jobs))
            return self._finalize_batch(ib)
        self._inflight.append(ib)
        return None

    def _run_batch_sync(self, ib: _InflightBatch) -> BatchReport:
        """Rebuild-per-batch compat mode: fresh scheduler, one-shot run
        (thread spawn + join per batch — the benchmark baseline)."""
        try:
            sched = self.make_scheduler()
            res = sched.run(0, ib.total)
            ib.handle = _DoneHandle(res, ib.submitted_at)
        except Exception as e:
            ib.error = e
            logger.exception("batch of %d jobs failed to run", len(ib.jobs))
        return self._finalize_batch(ib)

    def _finalize_batch(self, ib: _InflightBatch) -> BatchReport:
        res: Optional[ScheduleResult] = None
        completed, failed_groups = 0, []
        if ib.error is not None:
            if len(self.stats.errors) < 100:
                self.stats.errors.append(repr(ib.error))
            for j in ib.jobs:
                j.meta["last_error"] = repr(ib.error)
        else:
            res = ib.handle.result()
            completed, failed_groups = res.iterations, res.failed_groups
            for g, n in res.per_group_items.items():
                self.stats.per_group_items[g] = \
                    self.stats.per_group_items.get(g, 0) + n

        for g in failed_groups:
            if self.admission is not None:
                self.admission.on_group_leave(g)
            if self.on_group_failed is not None:
                self.on_group_failed(g)

        # all-or-nothing per batch: the runtime conserves iteration COUNT,
        # not identity (a re-executed chunk is fresh range at the end of
        # the space), so a partial count cannot be attributed to specific
        # jobs — never mark a job DONE whose items may not have run
        done = completed >= ib.total
        cancelled = res is not None and res.cancelled
        if cancelled:
            self.stats.cancelled_batches += 1

        # per-tenant attribution + soft energy-budget weight derating
        # (before job finalization so the very next DWRR pop sees it).
        # Completed batches only: a failed batch's jobs requeue and run
        # again in full, so attributing the failed attempt too would
        # double-count the tenant's items and inflate its fairness share.
        # A *cancelled* batch DID consume device time and joules that no
        # retry gives back, so those are charged — but without the item
        # counts, which the eventual completing attempt will charge
        if self.accountant is not None and res is not None \
                and (done or cancelled):
            self.accountant.record_batch(
                ib.jobs, res, window=(ib.submitted_at, self.clock()),
                count_items=done)
            derates = self.accountant.derate_weights()
            set_derates = getattr(self.queue, "set_weight_derates", None)
            if set_derates is not None:
                set_derates(derates)
        tel = self.telemetry
        jnow = job_mod.now()
        for j in ib.jobs:
            if done:
                self.queue.mark_finished(j, JobState.DONE)
                self.stats.done += 1
                if j.queue_delay is not None:
                    self.stats.queue_delays.append(j.queue_delay)
                    if self.accountant is not None:
                        self.accountant.record_queue_delay(j.tenant,
                                                           j.queue_delay)
                    if tel is not None:
                        self._histogram("queue.queue_delay_s",
                                        tenant=j.tenant) \
                            .observe(j.queue_delay)
                        self._histogram("svc.latency_s", tier=j.tier) \
                            .observe(max(0.0, jnow - j.created_at))
                state = "done"
            elif cancelled and j.deadline_at is not None \
                    and jnow >= j.deadline_at:
                # the epoch was cancelled and this job's own budget is
                # spent: retrying cannot meet it — shed, not requeue
                self.queue.mark_finished(j, JobState.CANCELLED)
                self._record_deadline_miss(j, where="cancel")
                state = "cancelled"
            elif j.attempts_left > 0:
                self.queue.mark_finished(j, JobState.REQUEUED)
                self.queue.requeue(j)
                self.stats.requeues += 1
                if tel is not None:
                    self._counter("svc.retries",
                                  cause="batch_failure").add(1)
                state = "requeued"
            else:
                self.queue.mark_finished(j, JobState.FAILED)
                self.stats.failed += 1
                state = "failed"
            if tel is not None:
                self._counter("svc.jobs", state=state, tenant=j.tenant) \
                    .add(1)
            self._journal(j)
        self.stats.batches += 1
        finished = self.clock()
        self.stats.record_window(ib.submitted_at, finished)
        if tel is not None:
            self._counter("svc.batches").add(1)
            self._counter("svc.batch_items").add(min(completed, ib.total))
            tel.tracer.span(f"batch:{self.stats.batches}", tid="service",
                            start=ib.submitted_at, end=finished,
                            jobs=len(ib.jobs), items=ib.total, done=done,
                            tier=ib.tier, cancelled=cancelled)
        return BatchReport(ib.jobs, min(completed, ib.total), ib.total,
                           list(failed_groups), res,
                           submitted_at=ib.submitted_at,
                           finished_at=finished)

    def _pump_express(self) -> bool:
        """Express lane: drain urgent-tier jobs PAST the pipeline-depth
        gate (up to ``express_slots`` extra batches in flight). The
        urgent epoch preempts queued standard work inside the scheduler,
        so a cold-arriving urgent job is served within one batch boundary
        instead of waiting out the full double-buffered pipeline."""
        if not self.express or not self.persistent:
            return False
        pop_express = getattr(self.queue, "pop_express", None)
        if pop_express is None:
            return False
        progressed = False
        while sum(1 for ib in self._inflight if ib.express) \
                < self.express_slots:
            jobs = pop_express(self.batch_jobs)
            if not jobs:
                break
            self._submit_batch(jobs, express=True)
            progressed = True
        return progressed

    def _enforce_deadlines(self) -> None:
        """Cooperatively cancel in-flight epochs whose batch deadline has
        passed — workers wind down at the next chunk boundary and the
        unfinished tail requeues via finalization."""
        if self._sched is None:
            return
        now = self.clock()
        for ib in self._inflight:
            if ib.deadline_mono is None or now <= ib.deadline_mono:
                continue
            if isinstance(ib.handle, EpochHandle) and not ib.handle.done():
                self._sched.cancel_epoch(ib.handle, reason="deadline")

    def _pump(self, block_s: float = 0.0) -> bool:
        """One pipeline step: keep up to ``pipeline_depth`` batches in
        flight (plus the express lane), enforce batch deadlines, finalize
        completed ones. Returns whether any batch was submitted or
        finalized. Express batches finalize out of order (they finish
        early by design — never leave one blocked behind a long batch
        epoch at the pipeline head)."""
        progressed = self._pump_express()
        self._enforce_deadlines()
        while sum(1 for ib in self._inflight if not ib.express) \
                < self.pipeline_depth:
            jobs = self._pop_batch(0.0 if (self._inflight or progressed)
                                   else block_s)
            if not jobs:
                break
            rep = self._submit_batch(jobs)
            progressed = True
            self._pump_express()            # urgent work that arrived
            self._enforce_deadlines()       # while we blocked in pop
            if rep is not None:             # sync mode / submit failure
                break
        for ib in list(self._inflight):     # out-of-order completions
            if ib is not self._inflight[0] and ib.handle is not None \
                    and ib.handle.done():
                self._inflight.remove(ib)
                self._finalize_batch(ib)
                progressed = True
        while self._inflight:
            # block only when no new batch can be submitted anyway (full
            # pipeline, or an idle pass) — otherwise just poll
            full = len(self._inflight) >= self.pipeline_depth
            timeout = block_s if (full or not progressed) else 0.0
            if not self._inflight[0].handle.wait(timeout):
                break
            self._finalize_batch(self._inflight.popleft())
            progressed = True
        return progressed

    # -- one-shot drains (compat + tests) ------------------------------
    def drain_once(self, block_s: float = 0.0) -> Optional[BatchReport]:
        """Pop one batch, run it to completion, finalize. Any batches
        already in the pipeline are finalized first (submission order)."""
        while self._inflight:
            ib = self._inflight.popleft()
            ib.handle.wait()
            self._finalize_batch(ib)
        jobs = self._pop_batch(block_s)
        if not jobs:
            return None
        rep = self._submit_batch(jobs)
        if rep is not None:
            return rep
        if not self._inflight:              # whole batch cancelled in the
            return None                     # pop-to-dispatch window
        ib = self._inflight.popleft()
        ib.handle.wait()
        return self._finalize_batch(ib)

    def run_until_idle(self, timeout_s: float = 60.0) -> bool:
        """Drain (pipelined) until queue + deferred + in-flight are empty;
        False on timeout."""
        deadline = self.clock() + timeout_s
        while self.clock() < deadline:
            self.retry_deferred()
            self._poll_health()
            self._check_brownout()
            if self._pump(block_s=0.0):
                continue
            if not self._inflight:
                with self._lock:
                    idle = not self._deferred
                if idle and self.queue.depth() == 0:
                    return True
            self._wait_for_work(limit=deadline - self.clock())
        return False

    # -- daemon mode ---------------------------------------------------
    def start(self) -> None:
        if self._thread is not None:
            return
        self._stop.clear()
        self._thread = threading.Thread(target=self._loop,
                                        name="job-service", daemon=True)
        self._thread.start()

    def stop(self, join: bool = True) -> None:
        self._stop.set()
        self.wakeup.notify()        # unpark the drain immediately
        if join and self._thread is not None:
            self._thread.join(timeout=10.0)
        self._thread = None
        # finalize whatever the daemon left in flight (runtime is alive)
        while self._inflight:
            ib = self._inflight.popleft()
            if ib.handle is not None and not ib.handle.wait(10.0):
                ib.error = TimeoutError("epoch unfinished at stop()")
            self._finalize_batch(ib)

    def close(self) -> None:
        """Stop the daemon (if running) and shut the runtime down."""
        self.stop()
        if self._sched is not None:
            self._sched.shutdown()
            self._sched = None

    def crash(self) -> None:
        """Kill this runtime the unclean way (failover tests, federation
        ``kill_runtime``): stop the drain WITHOUT finalizing in-flight
        batches — their jobs stay RUNNING, exactly the state a process
        death leaves in the journal — and tear the scheduler down,
        cancelling live epochs at the next chunk boundary so worker
        threads wind up. Recovery is a survivor's job: replay the
        (mirrored) journal via ``recover`` on a live service."""
        self._stop.set()
        self.wakeup.notify()
        if self._thread is not None:
            self._thread.join(timeout=10.0)
            self._thread = None
        sched = self._sched
        if sched is not None:
            for ib in self._inflight:
                if isinstance(ib.handle, EpochHandle) \
                        and not ib.handle.done():
                    sched.cancel_epoch(ib.handle, reason="crash")
            sched.shutdown()
            self._sched = None
        self._inflight.clear()

    def _next_deadline_delay(self) -> Optional[float]:
        """Seconds until the earliest in-flight batch deadline (service
        clock), or None — bounds the drain's park time so deadline
        enforcement never waits on an unrelated event."""
        best: Optional[float] = None
        if self._inflight:
            now = self.clock()
            for ib in self._inflight:
                if ib.deadline_mono is None:
                    continue
                d = ib.deadline_mono - now
                if best is None or d < best:
                    best = d
        return best

    def _wait_for_work(self, limit: Optional[float] = None) -> None:
        """Park the drain until new work can arrive: a wakeup event
        (arrival/completion/submit/stop) or a fallback timeout. The
        timeout is ``fallback_s`` tightened by the health-poll cadence
        (watchdog/straggler attached — hangs emit no events), the nearest
        in-flight deadline, and the caller's ``limit``."""
        timeout = self.fallback_s
        if self.watchdog is not None or self.straggler is not None:
            timeout = min(timeout, self.health_poll_s)
        d = self._next_deadline_delay()
        if d is not None:
            timeout = min(timeout, max(d, 1e-4))
        if limit is not None:
            timeout = min(timeout, max(limit, 0.0))
        if self._injected_sleep:
            # deterministic harness: consuming a pending event replaces
            # the virtual sleep; otherwise advance virtual time one poll
            if not self.wakeup.consume():
                self._sleep(self.poll_s)
                self.wakeup.consume()
            return
        woke = self.wakeup.wait(timeout)
        if self.telemetry is not None:
            self._counter("svc.drain_wakeups",
                          cause="event" if woke else "timeout").add(1)

    def _loop(self) -> None:
        while not self._stop.is_set():
            self.retry_deferred()
            self._poll_health()
            self._check_brownout()
            if self._pump(block_s=0.0):
                continue
            self._wait_for_work()


class _DoneHandle:
    """Adapter giving a completed one-shot run the EpochHandle surface."""

    def __init__(self, res: ScheduleResult, submitted_at: float):
        self._res = res
        self.submitted_at = submitted_at
        self.started_at = submitted_at
        self.finished_at = clock()

    def done(self) -> bool:
        return True

    def wait(self, timeout: Optional[float] = None) -> bool:
        return True

    def result(self, timeout: Optional[float] = None) -> ScheduleResult:
        return self._res
