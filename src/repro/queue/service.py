"""JobService: the daemon loop that drains the queue into scheduler runs.

Each drain pops up to ``batch_jobs`` jobs (priority order), concatenates
their items into one iteration space, and hands it to a fresh
DynamicScheduler run — the paper's §3.1 pipeline is the *execution* layer;
this is the *admission-to-execution* bridge. When a device group dies
mid-run the scheduler's own chunk requeue (work conservation on iteration
count) still completes the batch, so jobs are DONE; a run that loses
*all* groups completes only part of its count, and since the runtime
conserves count, not iteration identity, there is no way to attribute the
partial completion to specific jobs — the whole batch is REQUEUED
(at-least-once semantics, bounded by ``max_attempts``). This is the
ChunkFailure → requeue conversion the fault-tolerance layer promises.

Group failures observed in a run (in-band ChunkFailure) and hangs caught
by the runtime Watchdog both flow to the AdmissionController as
on_group_leave events, shrinking advertised capacity immediately.
"""
from __future__ import annotations

import logging
import math
import threading
import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence

from repro.core.scheduler import DynamicScheduler, ScheduleResult
from repro.queue.admission import AdmissionController, AdmissionDecision, \
    Decision
from repro.queue.job import Job, JobState
from repro.queue.journal import JournalStore
from repro.queue.manager import QueueManager

try:                                    # optional hang detection
    from repro.runtime.fault_tolerance import Watchdog
except Exception:                       # pragma: no cover
    Watchdog = None                     # type: ignore

logger = logging.getLogger(__name__)


def percentiles(xs: Sequence[float],
                ps: Sequence[float] = (50.0, 95.0, 99.0)) \
        -> Dict[str, float]:
    """Nearest-rank percentiles, {"p50": ..} — no numpy dependency here."""
    out: Dict[str, float] = {}
    if not xs:
        return {f"p{p:g}": 0.0 for p in ps}
    s = sorted(xs)
    for p in ps:
        k = max(0, min(len(s) - 1, math.ceil(p / 100.0 * len(s)) - 1))
        out[f"p{p:g}"] = s[k]
    return out


@dataclass
class BatchReport:
    jobs: List[Job]
    completed_items: int
    total_items: int
    failed_groups: List[str]
    schedule: Optional[ScheduleResult] = None


@dataclass
class ServiceStats:
    batches: int = 0
    done: int = 0
    failed: int = 0
    requeues: int = 0
    queue_delays: List[float] = field(default_factory=list)
    per_group_items: Dict[str, int] = field(default_factory=dict)
    errors: List[str] = field(default_factory=list)

    def delay_percentiles(self) -> Dict[str, float]:
        return percentiles(self.queue_delays)


class JobService:
    def __init__(self, make_scheduler: Callable[[], DynamicScheduler],
                 queue: Optional[QueueManager] = None,
                 admission: Optional[AdmissionController] = None,
                 journal: Optional[JournalStore] = None,
                 batch_jobs: int = 8, poll_s: float = 0.05,
                 watchdog: Optional["Watchdog"] = None,
                 on_group_failed: Optional[Callable[[str], None]] = None):
        self.make_scheduler = make_scheduler
        self.queue = queue or QueueManager()
        self.admission = admission
        self.journal = journal
        self.batch_jobs = max(1, batch_jobs)
        self.poll_s = poll_s
        self.watchdog = watchdog
        self.on_group_failed = on_group_failed
        self.stats = ServiceStats()
        self._deferred: List[Job] = []
        self._lock = threading.Lock()
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None

    # -- journaling ----------------------------------------------------
    def _journal(self, job: Job, event: Optional[str] = None) -> None:
        if self.journal is not None:
            self.journal.record(job, event)

    # -- submission ----------------------------------------------------
    def submit(self, job: Job) -> AdmissionDecision:
        """Admission-gate a PENDING job. DEFERred jobs are retried by the
        service loop as backlog drains; REJECTed jobs come back CANCELLED."""
        self._journal(job, "submitted")
        if self.admission is None:
            self.queue.put(job)
            self._journal(job)
            return AdmissionDecision(Decision.ADMIT, 0.0, float("inf"))
        dec = self.admission.admit(job)
        if dec.decision == Decision.DEFER:
            with self._lock:
                self._deferred.append(job)
        self._journal(job, "rejected" if dec.decision == Decision.REJECT
                      else None)
        return dec

    def retry_deferred(self) -> int:
        """Re-offer deferred jobs to the admission gate; returns #admitted."""
        if self.admission is None:
            return 0
        with self._lock:
            waiting, self._deferred = self._deferred, []
        admitted = 0
        for job in waiting:
            if job.state != JobState.PENDING:      # cancelled while waiting
                continue
            dec = self.admission.admit(job)
            if dec.decision == Decision.DEFER:
                with self._lock:
                    self._deferred.append(job)
            else:
                self._journal(job)
                admitted += dec.decision == Decision.ADMIT
        return admitted

    # -- the drain -----------------------------------------------------
    def drain_once(self, block_s: float = 0.0) -> Optional[BatchReport]:
        """Pop a batch, run it through one DynamicScheduler, finalize."""
        jobs: List[Job] = []
        first = self.queue.pop(timeout=block_s or None)
        if first is None:
            return None
        jobs.append(first)
        while len(jobs) < self.batch_jobs:
            nxt = self.queue.pop()
            if nxt is None:
                break
            jobs.append(nxt)

        total = sum(j.items for j in jobs)
        for j in jobs:
            self.queue.mark_running(j)
            self._journal(j)
        try:
            sched = self.make_scheduler()
            res = sched.run(0, total)
            completed, failed_groups = res.iterations, res.failed_groups
            for g, n in res.per_group_items.items():
                self.stats.per_group_items[g] = \
                    self.stats.per_group_items.get(g, 0) + n
        except Exception as e:          # broken factory / run: fail the
            res, completed, failed_groups = None, 0, []   # batch, not the
            logger.exception("batch of %d jobs failed to run", len(jobs))
            if len(self.stats.errors) < 100:              # daemon
                self.stats.errors.append(repr(e))
            for j in jobs:
                j.meta["last_error"] = repr(e)

        for g in failed_groups:
            if self.admission is not None:
                self.admission.on_group_leave(g)
            if self.on_group_failed is not None:
                self.on_group_failed(g)

        # all-or-nothing per batch: the runtime conserves iteration COUNT,
        # not identity (a re-executed chunk is fresh range at the end of
        # the space), so a partial count cannot be attributed to specific
        # jobs — never mark a job DONE whose items may not have run
        done = completed >= total
        for j in jobs:
            if done:
                self.queue.mark_finished(j, JobState.DONE)
                self.stats.done += 1
                if j.queue_delay is not None:
                    self.stats.queue_delays.append(j.queue_delay)
            elif j.attempts_left > 0:
                self.queue.mark_finished(j, JobState.REQUEUED)
                self.queue.requeue(j)
                self.stats.requeues += 1
            else:
                self.queue.mark_finished(j, JobState.FAILED)
                self.stats.failed += 1
            self._journal(j)
        self.stats.batches += 1
        return BatchReport(jobs, min(completed, total), total,
                           list(failed_groups), res)

    def run_until_idle(self, timeout_s: float = 60.0) -> bool:
        """Drain until queue + deferred list are empty; False on timeout."""
        deadline = time.monotonic() + timeout_s
        while time.monotonic() < deadline:
            self.retry_deferred()
            rep = self.drain_once()
            if rep is not None:
                continue
            with self._lock:
                idle = not self._deferred
            if idle and self.queue.depth() == 0:
                return True
            time.sleep(self.poll_s)
        return False

    # -- daemon mode ---------------------------------------------------
    def start(self) -> None:
        if self._thread is not None:
            return
        self._stop.clear()
        self._thread = threading.Thread(target=self._loop,
                                        name="job-service", daemon=True)
        self._thread.start()

    def stop(self, join: bool = True) -> None:
        self._stop.set()
        if join and self._thread is not None:
            self._thread.join(timeout=10.0)
        self._thread = None

    def _loop(self) -> None:
        while not self._stop.is_set():
            self.retry_deferred()
            if self.watchdog is not None:
                for g in self.watchdog.check():
                    if self.admission is not None:
                        self.admission.on_group_leave(g)
                    if self.on_group_failed is not None:
                        self.on_group_failed(g)
            if self.drain_once(block_s=self.poll_s) is None:
                time.sleep(self.poll_s)
