"""Append-only JSONL journal: durability + crash recovery for the queue.

Every state transition appends one line ``{"ts", "event", "job", "crc"}``
where ``crc`` is the CRC-32 of the canonical (sorted-keys) JSON of the
other three fields; the file is the source of truth after a crash.
Replay is last-write-wins per job id, with two hardening layers flushed
out by the chaos soak:

  * a torn final line (crash mid-write) is *truncated on open* — the
    classic artifact must not poison the next process's appends by
    gluing its first record onto the fragment; and
  * a line whose checksum does not match (bit rot, a corrupted flush)
    is skipped and counted, never trusted.

``recover()`` re-materializes the queue: jobs that were in flight
(ADMITTED / RUNNING / PENDING / REQUEUED) when the process died come back
as re-queueable jobs; terminal jobs come back as history.
"""
from __future__ import annotations

import json
import logging
import os
import threading
import time
import zlib
from typing import Callable, Dict, List, Optional, Tuple

from repro.queue.job import Job, JobState

logger = logging.getLogger(__name__)

# how far back from EOF we look for the last newline when truncating a
# torn tail; a journal line is well under this
_TAIL_SCAN_BYTES = 65536


def _entry_line(job: Job, event: str, ts: Optional[float] = None) -> str:
    """One canonical journal line, checksum included.

    The crc covers the sorted-keys JSON of the payload *without* the crc
    field, so verification is: pop "crc", re-dump sorted, compare.
    (json round-trips float repr exactly, so re-dumping a parsed payload
    reproduces the original bytes.)
    """
    payload = {"ts": time.time() if ts is None else ts,
               "event": event, "job": job.to_dict()}
    body = json.dumps(payload, sort_keys=True)
    payload["crc"] = zlib.crc32(body.encode("utf-8")) & 0xFFFFFFFF
    return json.dumps(payload, sort_keys=True)


class JournalStore:
    def __init__(self, path: str, fsync: bool = False,
                 auto_compact_lines: Optional[int] = None,
                 write_filter: Optional[Callable[[str],
                                                Optional[str]]] = None):
        """``auto_compact_lines``: when set, record() triggers compact()
        once the journal holds at least that many lines — a long-lived
        daemon's journal stays O(live+finished jobs) instead of O(state
        transitions) with no operator cron job. None disables it.

        ``write_filter``: fault-injection seam (repro.chaos). Called with
        each canonical line; a non-None return is written to the primary
        file *verbatim* in its place (torn / corrupted bytes). The mirror
        always receives the true line — the filter models a bad local
        disk, not a bad wire.
        """
        self.path = str(path)
        self.fsync = fsync
        self.auto_compact_lines = auto_compact_lines
        self.compactions = 0                 # observability / tests
        self.torn_truncations = 0            # torn tails cut on open
        self.mirror_detaches = 0             # sinks dropped on write error
        self._write_filter = write_filter
        self._lock = threading.Lock()
        directory = os.path.dirname(self.path)
        if directory:
            os.makedirs(directory, exist_ok=True)
        self._truncate_torn_tail()
        self._lines = 0
        # the line count only feeds the auto-compaction trigger; don't
        # pay an O(journal) scan on open when the feature is off
        if auto_compact_lines is not None and os.path.exists(self.path):
            with open(self.path, "r", encoding="utf-8") as fh:
                self._lines = sum(1 for _ in fh)
        # moving trigger: after a compaction that keeps k lines the next
        # one fires at max(threshold, 2k), so a journal whose *live* set
        # exceeds the threshold cannot thrash a full rewrite per record
        self._next_compact = auto_compact_lines
        self._mirror = None
        self._fh = open(self.path, "a", encoding="utf-8")

    def _truncate_torn_tail(self) -> None:
        """Cut an unterminated final line before opening for append.

        A crash mid-write leaves a fragment with no trailing newline;
        appending after it would weld the next record onto the fragment
        and lose *that* record too. Truncating back to the last newline
        confines the damage to the torn line itself.
        """
        if not os.path.exists(self.path):
            return
        with open(self.path, "rb+") as fh:
            fh.seek(0, os.SEEK_END)
            size = fh.tell()
            if size == 0:
                return
            fh.seek(size - 1)
            if fh.read(1) == b"\n":
                return
            scan = min(size, _TAIL_SCAN_BYTES)
            fh.seek(size - scan)
            tail = fh.read(scan)
            cut = tail.rfind(b"\n")
            keep = size - scan + cut + 1 if cut >= 0 else 0
            fh.truncate(keep)
        self.torn_truncations += 1
        logger.warning("journal %s: truncated torn final line "
                       "(%d bytes dropped)", self.path, size - keep)

    # -- replication ---------------------------------------------------
    def attach_mirror(self, mirror) -> None:
        """Attach a replication sink (duck-typed: ``append(line)`` plus
        optional ``rewrite(lines)`` applied on compaction). Each record
        is forwarded after its local write under the journal lock, so the
        sink always holds an ordered prefix of the primary — the
        guarantee federation failover replays against. A failing sink is
        detached rather than taking journaling (and the drain daemon
        above it) down."""
        self._mirror = mirror

    def has_mirror(self) -> bool:
        return self._mirror is not None

    def resync_mirror(self, mirror) -> int:
        """Re-attach a (replacement) sink after a detach: rewrite it from
        the primary's current per-job final state so it again holds a
        replayable copy, then resume forwarding. Returns lines synced."""
        with self._lock:
            jobs = self.replay(self.path)
            lines = [_entry_line(j, j.state.value)
                     for j in sorted(jobs.values(),
                                     key=lambda j: (j.created_at,
                                                    j.job_id))]
            mirror.rewrite(lines)
            self._mirror = mirror
            return len(lines)

    def _mirror_call(self, method: str, arg) -> None:
        mirror = self._mirror
        if mirror is None:
            return
        fn = getattr(mirror, method, None)
        if fn is None:
            return
        try:
            fn(arg)
        except Exception:
            logger.exception("journal mirror %s failed; detaching", method)
            self._mirror = None
            self.mirror_detaches += 1

    # -- write path ----------------------------------------------------
    def record(self, job: Job, event: Optional[str] = None) -> None:
        line = _entry_line(job, event or job.state.value)
        out = None
        if self._write_filter is not None:
            out = self._write_filter(line)
        with self._lock:
            self._fh.write(line + "\n" if out is None else out)
            self._fh.flush()
            if self.fsync:
                os.fsync(self._fh.fileno())
            self._lines += 1
            self._mirror_call("append", line)
            over = self._next_compact is not None \
                and self._lines >= self._next_compact
        if over:
            # outside the lock: compact() re-acquires it; a concurrent
            # second trigger just runs a cheap no-op rewrite. The record
            # itself is already durable — a failing compaction must not
            # take journaling (and the drain daemon above it) down with
            # it, so the trigger is disabled and appends continue
            try:
                self.compact()
            except OSError:
                logger.exception("journal auto-compaction failed; "
                                 "disabling the trigger")
                with self._lock:
                    self._next_compact = None

    def tear_tail(self) -> None:
        """Simulate a crash mid-write: append a partial record with no
        trailing newline (fault-injection hook used by ``kill_runtime``
        under a ``torn_write`` event). The next open truncates it."""
        with self._lock:
            self._fh.write('{"ts": 0, "event": "torn')
            self._fh.flush()

    def close(self) -> None:
        with self._lock:
            if not self._fh.closed:
                self._fh.close()

    # -- compaction ----------------------------------------------------
    def compact(self) -> int:
        """Rewrite the journal keeping only the latest record per job.

        A long-lived daemon's journal grows by one line per transition;
        replay only ever uses the last line per job id, so everything
        before it is dead weight. The rewrite goes to a temp file that is
        atomically renamed over the journal (a crash mid-compaction leaves
        either the old or the new file, never a mix); the append handle is
        reopened on the compacted file — or, if the rewrite fails, on the
        untouched original, so journaling survives a failed compaction
        (e.g. ENOSPC on the temp file). Returns the number of jobs kept.
        """
        with self._lock:
            if not self._fh.closed:
                self._fh.close()
            try:
                jobs = self.replay(self.path)
                lines = [_entry_line(job, job.state.value)
                         for job in sorted(jobs.values(),
                                           key=lambda j: (j.created_at,
                                                          j.job_id))]
                tmp = self.path + ".compact"
                with open(tmp, "w", encoding="utf-8") as fh:
                    for line in lines:
                        fh.write(line + "\n")
                    fh.flush()
                    if self.fsync:
                        os.fsync(fh.fileno())
                os.replace(tmp, self.path)
                self._mirror_call("rewrite", lines)
            finally:
                self._fh = open(self.path, "a", encoding="utf-8")
            self._lines = len(jobs)
            if self.auto_compact_lines is not None:
                self._next_compact = max(self.auto_compact_lines,
                                         2 * len(jobs))
            self.compactions += 1
            return len(jobs)

    def __enter__(self) -> "JournalStore":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # -- read path -----------------------------------------------------
    @classmethod
    def replay_stats(cls, path: str) \
            -> Tuple[Dict[str, Job], Dict[str, int]]:
        """replay() plus integrity counters.

        Returns ``(jobs, {"lines", "skipped", "crc_failures"})``.
        ``skipped`` counts every rejected line (unparseable or bad
        checksum); ``crc_failures`` counts the subset that parsed but
        failed verification. Lines without a "crc" field (journals from
        before checksumming) are accepted as-is.
        """
        jobs: Dict[str, Job] = {}
        stats = {"lines": 0, "skipped": 0, "crc_failures": 0}
        if not os.path.exists(path):
            return jobs, stats
        with open(path, "r", encoding="utf-8") as fh:
            for raw in fh:
                raw = raw.strip()
                if not raw:
                    continue
                stats["lines"] += 1
                try:
                    entry = json.loads(raw)
                    crc = entry.pop("crc", None)
                    if crc is not None:
                        body = json.dumps(entry, sort_keys=True)
                        if zlib.crc32(body.encode("utf-8")) \
                                & 0xFFFFFFFF != crc:
                            stats["crc_failures"] += 1
                            raise ValueError("journal crc mismatch")
                    job = Job.from_dict(entry["job"])
                except (json.JSONDecodeError, KeyError, TypeError,
                        ValueError, AttributeError):
                    stats["skipped"] += 1
                    continue
                jobs[job.job_id] = job
        if stats["skipped"]:
            logger.warning(
                "journal %s: skipped %d corrupt line(s) of %d "
                "(%d checksum failure(s))", path, stats["skipped"],
                stats["lines"], stats["crc_failures"])
        return jobs, stats

    @classmethod
    def replay(cls, path: str) -> Dict[str, Job]:
        """Reconstruct the final state of every journaled job.

        Corrupt / torn lines are skipped, not fatal: an append-only log's
        only legal corruption is a truncated tail or a bad flush, and
        checksums catch the latter.
        """
        jobs, _ = cls.replay_stats(path)
        return jobs

    @classmethod
    def recover(cls, path: str) -> Tuple[List[Job], Dict[str, Job]]:
        """Crash recovery: (jobs to re-admit, full final-state map).

        In-flight jobs are rewound to a re-queueable state: a RUNNING job
        becomes REQUEUED (its attempt died with the process); ADMITTED and
        REQUEUED jobs keep their state; PENDING jobs are returned as-is
        for a fresh admission decision.
        """
        jobs = cls.replay(path)
        to_requeue: List[Job] = []
        for job in jobs.values():
            if job.terminal:
                continue
            if job.state == JobState.RUNNING:
                job.transition(JobState.REQUEUED)
            to_requeue.append(job)
        to_requeue.sort(key=lambda j: (j.priority, j.created_at))
        return to_requeue, jobs
