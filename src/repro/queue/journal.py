"""Append-only JSONL journal: durability + crash recovery for the queue.

Every state transition appends one line ``{"ts", "event", "job"}``; the
file is the source of truth after a crash. Replay is last-write-wins per
job id; a torn final line (the classic crash-mid-write artifact) is
skipped, matching what GPUScheduler's sqliteStore gets from SQLite's
atomic commits — but with zero dependencies and human-greppable storage.

``recover()`` re-materializes the queue: jobs that were in flight
(ADMITTED / RUNNING / PENDING / REQUEUED) when the process died come back
as re-queueable jobs; terminal jobs come back as history.
"""
from __future__ import annotations

import json
import logging
import os
import threading
import time
from typing import Dict, List, Optional, Tuple

from repro.queue.job import Job, JobState

logger = logging.getLogger(__name__)

_TRUNCATE_SENTINEL = object()


class JournalStore:
    def __init__(self, path: str, fsync: bool = False,
                 auto_compact_lines: Optional[int] = None):
        """``auto_compact_lines``: when set, record() triggers compact()
        once the journal holds at least that many lines — a long-lived
        daemon's journal stays O(live+finished jobs) instead of O(state
        transitions) with no operator cron job. None disables it."""
        self.path = str(path)
        self.fsync = fsync
        self.auto_compact_lines = auto_compact_lines
        self.compactions = 0                 # observability / tests
        self._lock = threading.Lock()
        directory = os.path.dirname(self.path)
        if directory:
            os.makedirs(directory, exist_ok=True)
        self._lines = 0
        # the line count only feeds the auto-compaction trigger; don't
        # pay an O(journal) scan on open when the feature is off
        if auto_compact_lines is not None and os.path.exists(self.path):
            with open(self.path, "r", encoding="utf-8") as fh:
                self._lines = sum(1 for _ in fh)
        # moving trigger: after a compaction that keeps k lines the next
        # one fires at max(threshold, 2k), so a journal whose *live* set
        # exceeds the threshold cannot thrash a full rewrite per record
        self._next_compact = auto_compact_lines
        self._mirror = None
        self._fh = open(self.path, "a", encoding="utf-8")

    # -- replication ---------------------------------------------------
    def attach_mirror(self, mirror) -> None:
        """Attach a replication sink (duck-typed: ``append(line)`` plus
        optional ``rewrite(lines)`` applied on compaction). Each record
        is forwarded after its local write under the journal lock, so the
        sink always holds an ordered prefix of the primary — the
        guarantee federation failover replays against. A failing sink is
        detached rather than taking journaling (and the drain daemon
        above it) down."""
        self._mirror = mirror

    def _mirror_call(self, method: str, arg) -> None:
        mirror = self._mirror
        if mirror is None:
            return
        fn = getattr(mirror, method, None)
        if fn is None:
            return
        try:
            fn(arg)
        except Exception:
            logger.exception("journal mirror %s failed; detaching", method)
            self._mirror = None

    # -- write path ----------------------------------------------------
    def record(self, job: Job, event: Optional[str] = None) -> None:
        line = json.dumps({"ts": time.time(),
                           "event": event or job.state.value,
                           "job": job.to_dict()}, sort_keys=True)
        with self._lock:
            self._fh.write(line + "\n")
            self._fh.flush()
            if self.fsync:
                os.fsync(self._fh.fileno())
            self._lines += 1
            self._mirror_call("append", line)
            over = self._next_compact is not None \
                and self._lines >= self._next_compact
        if over:
            # outside the lock: compact() re-acquires it; a concurrent
            # second trigger just runs a cheap no-op rewrite. The record
            # itself is already durable — a failing compaction must not
            # take journaling (and the drain daemon above it) down with
            # it, so the trigger is disabled and appends continue
            try:
                self.compact()
            except OSError:
                logger.exception("journal auto-compaction failed; "
                                 "disabling the trigger")
                with self._lock:
                    self._next_compact = None

    def close(self) -> None:
        with self._lock:
            if not self._fh.closed:
                self._fh.close()

    # -- compaction ----------------------------------------------------
    def compact(self) -> int:
        """Rewrite the journal keeping only the latest record per job.

        A long-lived daemon's journal grows by one line per transition;
        replay only ever uses the last line per job id, so everything
        before it is dead weight. The rewrite goes to a temp file that is
        atomically renamed over the journal (a crash mid-compaction leaves
        either the old or the new file, never a mix); the append handle is
        reopened on the compacted file — or, if the rewrite fails, on the
        untouched original, so journaling survives a failed compaction
        (e.g. ENOSPC on the temp file). Returns the number of jobs kept.
        """
        with self._lock:
            if not self._fh.closed:
                self._fh.close()
            try:
                jobs = self.replay(self.path)
                lines = [json.dumps(
                    {"ts": time.time(), "event": job.state.value,
                     "job": job.to_dict()}, sort_keys=True)
                    for job in sorted(jobs.values(),
                                      key=lambda j: (j.created_at,
                                                     j.job_id))]
                tmp = self.path + ".compact"
                with open(tmp, "w", encoding="utf-8") as fh:
                    for line in lines:
                        fh.write(line + "\n")
                    fh.flush()
                    if self.fsync:
                        os.fsync(fh.fileno())
                os.replace(tmp, self.path)
                self._mirror_call("rewrite", lines)
            finally:
                self._fh = open(self.path, "a", encoding="utf-8")
            self._lines = len(jobs)
            if self.auto_compact_lines is not None:
                self._next_compact = max(self.auto_compact_lines,
                                         2 * len(jobs))
            self.compactions += 1
            return len(jobs)

    def __enter__(self) -> "JournalStore":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # -- read path -----------------------------------------------------
    @classmethod
    def replay(cls, path: str) -> Dict[str, Job]:
        """Reconstruct the final state of every journaled job.

        Corrupt / torn lines are skipped, not fatal: an append-only log's
        only legal corruption is a truncated tail.
        """
        jobs: Dict[str, Job] = {}
        if not os.path.exists(path):
            return jobs
        with open(path, "r", encoding="utf-8") as fh:
            for raw in fh:
                raw = raw.strip()
                if not raw:
                    continue
                try:
                    entry = json.loads(raw)
                    job = Job.from_dict(entry["job"])
                except (json.JSONDecodeError, KeyError, TypeError,
                        ValueError):
                    continue
                jobs[job.job_id] = job
        return jobs

    @classmethod
    def recover(cls, path: str) -> Tuple[List[Job], Dict[str, Job]]:
        """Crash recovery: (jobs to re-admit, full final-state map).

        In-flight jobs are rewound to a re-queueable state: a RUNNING job
        becomes REQUEUED (its attempt died with the process); ADMITTED and
        REQUEUED jobs keep their state; PENDING jobs are returned as-is
        for a fresh admission decision.
        """
        jobs = cls.replay(path)
        to_requeue: List[Job] = []
        for job in jobs.values():
            if job.terminal:
                continue
            if job.state == JobState.RUNNING:
                job.transition(JobState.REQUEUED)
            to_requeue.append(job)
        to_requeue.sort(key=lambda j: (j.priority, j.created_at))
        return to_requeue, jobs
