"""Append-only JSONL journal: durability + crash recovery for the queue.

Every state transition appends one line ``{"ts", "event", "job"}``; the
file is the source of truth after a crash. Replay is last-write-wins per
job id; a torn final line (the classic crash-mid-write artifact) is
skipped, matching what GPUScheduler's sqliteStore gets from SQLite's
atomic commits — but with zero dependencies and human-greppable storage.

``recover()`` re-materializes the queue: jobs that were in flight
(ADMITTED / RUNNING / PENDING / REQUEUED) when the process died come back
as re-queueable jobs; terminal jobs come back as history.
"""
from __future__ import annotations

import json
import os
import threading
import time
from typing import Dict, List, Optional, Tuple

from repro.queue.job import Job, JobState

_TRUNCATE_SENTINEL = object()


class JournalStore:
    def __init__(self, path: str, fsync: bool = False):
        self.path = str(path)
        self.fsync = fsync
        self._lock = threading.Lock()
        directory = os.path.dirname(self.path)
        if directory:
            os.makedirs(directory, exist_ok=True)
        self._fh = open(self.path, "a", encoding="utf-8")

    # -- write path ----------------------------------------------------
    def record(self, job: Job, event: Optional[str] = None) -> None:
        line = json.dumps({"ts": time.time(),
                           "event": event or job.state.value,
                           "job": job.to_dict()}, sort_keys=True)
        with self._lock:
            self._fh.write(line + "\n")
            self._fh.flush()
            if self.fsync:
                os.fsync(self._fh.fileno())

    def close(self) -> None:
        with self._lock:
            if not self._fh.closed:
                self._fh.close()

    # -- compaction ----------------------------------------------------
    def compact(self) -> int:
        """Rewrite the journal keeping only the latest record per job.

        A long-lived daemon's journal grows by one line per transition;
        replay only ever uses the last line per job id, so everything
        before it is dead weight. The rewrite goes to a temp file that is
        atomically renamed over the journal (a crash mid-compaction leaves
        either the old or the new file, never a mix); the append handle is
        reopened on the compacted file. Returns the number of jobs kept.
        """
        with self._lock:
            if not self._fh.closed:
                self._fh.close()
            jobs = self.replay(self.path)
            tmp = self.path + ".compact"
            with open(tmp, "w", encoding="utf-8") as fh:
                for job in sorted(jobs.values(),
                                  key=lambda j: (j.created_at, j.job_id)):
                    fh.write(json.dumps(
                        {"ts": time.time(), "event": job.state.value,
                         "job": job.to_dict()}, sort_keys=True) + "\n")
                fh.flush()
                if self.fsync:
                    os.fsync(fh.fileno())
            os.replace(tmp, self.path)
            self._fh = open(self.path, "a", encoding="utf-8")
            return len(jobs)

    def __enter__(self) -> "JournalStore":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # -- read path -----------------------------------------------------
    @classmethod
    def replay(cls, path: str) -> Dict[str, Job]:
        """Reconstruct the final state of every journaled job.

        Corrupt / torn lines are skipped, not fatal: an append-only log's
        only legal corruption is a truncated tail.
        """
        jobs: Dict[str, Job] = {}
        if not os.path.exists(path):
            return jobs
        with open(path, "r", encoding="utf-8") as fh:
            for raw in fh:
                raw = raw.strip()
                if not raw:
                    continue
                try:
                    entry = json.loads(raw)
                    job = Job.from_dict(entry["job"])
                except (json.JSONDecodeError, KeyError, TypeError,
                        ValueError):
                    continue
                jobs[job.job_id] = job
        return jobs

    @classmethod
    def recover(cls, path: str) -> Tuple[List[Job], Dict[str, Job]]:
        """Crash recovery: (jobs to re-admit, full final-state map).

        In-flight jobs are rewound to a re-queueable state: a RUNNING job
        becomes REQUEUED (its attempt died with the process); ADMITTED and
        REQUEUED jobs keep their state; PENDING jobs are returned as-is
        for a fresh admission decision.
        """
        jobs = cls.replay(path)
        to_requeue: List[Job] = []
        for job in jobs.values():
            if job.terminal:
                continue
            if job.state == JobState.RUNNING:
                job.transition(JobState.REQUEUED)
            to_requeue.append(job)
        to_requeue.sort(key=lambda j: (j.priority, j.created_at))
        return to_requeue, jobs
