"""Thread-safe priority queue over Jobs, feeding the dynamic scheduler.

Heap entries are ``(tier rank, priority, seq, job_id)`` — the latency
tier dominates (any urgent job drains before any standard job, which
drains before any batch job), ``priority`` orders within a tier, and
``seq`` is a monotonically increasing admission counter so equal
priorities drain FIFO and a requeued job re-enters *behind* equal-rank
work admitted while it was running (no starvation of fresh traffic by a
crash-looping job). Cancellation is lazy: the entry stays in the heap and
is skipped at pop() when its job is no longer ADMITTED, which keeps
cancel() O(1). ``pop_express`` pops *only* urgent-tier heads — the
service's express lane, which must never accidentally drag standard work
past the pipeline-depth gate.

Per-group in-flight tracking (``mark_running`` / ``mark_finished``) gives
the admission controller and the watchdog a live view of which groups hold
work, mirroring GPUScheduler's running-by-GPU map.

Terminal jobs are evicted from the live map (their counts survive in
``counts()``), so a long-lived daemon's backlog scans stay O(live jobs)
and memory stays bounded — durability of finished state is the journal's
job, not the queue's.
"""
from __future__ import annotations

import heapq
import itertools
import threading
import time
from typing import Dict, List, Optional, Set, Tuple

from repro.core.types import TIER_RANK
from repro.queue.job import Job, JobState

#: tier ranks at or below this drain through the express lane
EXPRESS_RANK = TIER_RANK["urgent"]


def drain_with_deadline(cond: threading.Condition, pop_many_locked,
                        max_n: int, timeout: Optional[float]) -> List[Job]:
    """Shared blocking loop for batched pops (QueueManager and the
    tenancy ShardedQueueManager): returns as soon as at least one job is
    eligible, and a wakeup that loses the race to another consumer
    consumes the *remaining* budget instead of restarting it. Caller
    must already hold ``cond``'s lock."""
    jobs = pop_many_locked(max_n)
    if jobs or not timeout:
        return jobs
    deadline = time.monotonic() + timeout
    while True:
        remaining = deadline - time.monotonic()
        if remaining <= 0 or not cond.wait(remaining):
            return pop_many_locked(max_n)
        jobs = pop_many_locked(max_n)
        if jobs:
            return jobs


class QueueManager:
    def __init__(self):
        self._heap: List[Tuple[int, int, int, str]] = []
        self._jobs: Dict[str, Job] = {}
        self._inflight: Dict[str, Set[str]] = {}     # group -> job ids
        self._terminal_counts: Dict[str, int] = {}   # evicted-job history
        self._seq = itertools.count()
        self._lock = threading.RLock()
        self._not_empty = threading.Condition(self._lock)
        # arrival listeners (JobService drain wakeup): fired after every
        # put/requeue, OUTSIDE the queue lock — a listener that acquires
        # its own lock can never deadlock against a concurrent pop
        self._listeners: List = []

    def add_listener(self, fn) -> None:
        """Register ``fn()`` to run after each job arrival (put/requeue).
        Must be cheap and exception-free — typically ``Event.set``."""
        with self._lock:
            self._listeners.append(fn)

    def _evict_if_terminal(self, job: Job) -> None:
        if job.terminal:
            self._jobs.pop(job.job_id, None)
            self._terminal_counts[job.state.value] = \
                self._terminal_counts.get(job.state.value, 0) + 1

    # -- admission side ------------------------------------------------
    def put(self, job: Job) -> None:
        """Enqueue a PENDING or REQUEUED job (transitions it to ADMITTED)."""
        with self._lock:
            if job.state in (JobState.PENDING, JobState.REQUEUED):
                job.transition(JobState.ADMITTED)
            elif job.state != JobState.ADMITTED:
                raise ValueError(
                    f"cannot enqueue job {job.job_id} in state "
                    f"{job.state.value}")
            self._jobs[job.job_id] = job
            heapq.heappush(self._heap, (job.rank, job.priority,
                                        next(self._seq), job.job_id))
            self._not_empty.notify()
            listeners = list(self._listeners)
        for fn in listeners:
            fn()

    def cancel(self, job_id: str) -> bool:
        """Cancel a queued (ADMITTED) job; heap entry removed lazily."""
        with self._lock:
            job = self._jobs.get(job_id)
            if job is None or job.state != JobState.ADMITTED:
                return False
            job.transition(JobState.CANCELLED)
            self._evict_if_terminal(job)
            return True

    # -- scheduler side ------------------------------------------------
    def pop(self, timeout: Optional[float] = None) -> Optional[Job]:
        """Highest-priority ADMITTED job, or None after ``timeout``.

        ``timeout=None`` means non-blocking; the returned job stays
        ADMITTED — the service marks it RUNNING once it is bound to a
        scheduler run (two-phase, so a crash between pop and dispatch is
        recoverable from the journal as a still-queued job).
        """
        with self._not_empty:
            while True:
                job = self._pop_admitted_locked()
                if job is not None:
                    return job
                if not timeout:
                    return None
                if not self._not_empty.wait(timeout):
                    return self._pop_admitted_locked()

    def pop_many(self, max_n: int,
                 timeout: Optional[float] = None) -> List[Job]:
        """Up to ``max_n`` highest-priority ADMITTED jobs in ONE lock
        acquisition — the batched drain. Same blocking contract as
        ``pop`` (``timeout=None`` → non-blocking); returns as soon as at
        least one job is available rather than waiting for a full batch.
        Jobs stay ADMITTED (two-phase pop, see ``pop``)."""
        with self._not_empty:
            return drain_with_deadline(self._not_empty,
                                       self._pop_many_locked, max_n, timeout)

    def _pop_many_locked(self, max_n: int) -> List[Job]:
        jobs: List[Job] = []
        while len(jobs) < max_n:
            job = self._pop_admitted_locked()
            if job is None:
                break
            jobs.append(job)
        return jobs

    def _pop_admitted_locked(self, max_rank: Optional[int] = None) \
            -> Optional[Job]:
        """Pop the best ADMITTED job; with ``max_rank``, only if its tier
        rank is at most that (the heap is rank-first, so a too-lazy head
        means no eligible job exists — nothing is popped)."""
        while self._heap:
            rank, _, _, job_id = self._heap[0]
            job = self._jobs.get(job_id)
            if job is None or job.state != JobState.ADMITTED:
                heapq.heappop(self._heap)       # stale entry
                continue
            if max_rank is not None and rank > max_rank:
                return None
            heapq.heappop(self._heap)
            return job
        return None

    def pop_express(self, max_n: int) -> List[Job]:
        """Up to ``max_n`` *urgent-tier* ADMITTED jobs, non-blocking —
        the service's express lane drain. Jobs stay ADMITTED (two-phase
        pop, see ``pop``)."""
        with self._lock:
            jobs: List[Job] = []
            while len(jobs) < max_n:
                job = self._pop_admitted_locked(max_rank=EXPRESS_RANK)
                if job is None:
                    break
                jobs.append(job)
            return jobs

    def express_backlog(self) -> int:
        """Urgent-tier jobs an express pop could take *now* — scanned
        from the heap, not the job map, because two-phase pop leaves
        already-popped jobs ADMITTED (they are the service's to run, not
        the express lane's)."""
        with self._lock:
            seen = set()
            for rank, _, _, job_id in self._heap:
                if rank > EXPRESS_RANK or job_id in seen:
                    continue
                job = self._jobs.get(job_id)
                if job is not None and job.state == JobState.ADMITTED:
                    seen.add(job_id)
            return len(seen)

    def peek(self) -> Optional[Job]:
        """Best ADMITTED job without removing it (stale heap entries for
        cancelled/evicted jobs are dropped on the way) — the DWRR drain
        needs the head job's cost before deciding to serve it."""
        with self._lock:
            while self._heap:
                _, _, _, job_id = self._heap[0]
                job = self._jobs.get(job_id)
                if job is not None and job.state == JobState.ADMITTED:
                    return job
                heapq.heappop(self._heap)
            return None

    def mark_running(self, job: Job, group: str = "*") -> None:
        with self._lock:
            job.transition(JobState.RUNNING)
            self._inflight.setdefault(group, set()).add(job.job_id)

    def mark_finished(self, job: Job, state: JobState) -> None:
        """Terminal (or REQUEUED) transition + in-flight release."""
        with self._lock:
            job.transition(state)
            for ids in self._inflight.values():
                ids.discard(job.job_id)
            self._evict_if_terminal(job)

    def requeue(self, job: Job) -> None:
        """Put a REQUEUED job back on the heap (→ ADMITTED)."""
        with self._lock:
            if job.state != JobState.REQUEUED:
                raise ValueError(
                    f"requeue expects REQUEUED, got {job.state.value}")
            self.put(job)

    # -- introspection -------------------------------------------------
    def get(self, job_id: str) -> Optional[Job]:
        with self._lock:
            return self._jobs.get(job_id)

    def depth(self) -> int:
        """Number of jobs currently waiting (ADMITTED)."""
        with self._lock:
            return sum(1 for j in self._jobs.values()
                       if j.state == JobState.ADMITTED)

    def backlog_items(self) -> int:
        """Total queued iterations — the admission controller's backlog."""
        with self._lock:
            return sum(j.items for j in self._jobs.values()
                       if j.state == JobState.ADMITTED)

    def inflight(self, group: Optional[str] = None) -> int:
        with self._lock:
            if group is not None:
                return len(self._inflight.get(group, ()))
            return len(set().union(*self._inflight.values())) \
                if self._inflight else 0

    def jobs(self, state: Optional[JobState] = None) -> List[Job]:
        with self._lock:
            if state is None:
                return list(self._jobs.values())
            return [j for j in self._jobs.values() if j.state == state]

    def counts(self) -> Dict[str, int]:
        with self._lock:
            out = dict(self._terminal_counts)
            for j in self._jobs.values():
                out[j.state.value] = out.get(j.state.value, 0) + 1
            return out
