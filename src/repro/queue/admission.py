"""Admission control: backpressure before work reaches the scheduler.

Capacity is estimated from the signals the runtime already produces — the
per-group λ-estimates of ThroughputTracker (eqs. 1–2) derated by the §3.3
overhead fractions of OverheadLedger (an accelerator spending 30% of its
busy time in O_hd/O_kl/O_dh is not a λ-worth of useful capacity). The
projected queue delay for a new job is then

    delay ≈ (backlog_items + job.items) / Σ_G λ_G · useful_G

and the decision is a three-way gate against the delay SLO:

    delay ≤ slo              → ADMIT
    delay ≤ defer_factor·slo → DEFER   (caller should retry; bounded queue)
    otherwise                → REJECT  (shed load instead of building an
                                        unbounded backlog — the queue
                                        stays inside the SLO envelope)

Group membership is event-driven: ElasticController join/leave and
scheduler group failures call on_group_join/on_group_leave, so capacity
reacts to topology changes without polling.

Straggler awareness: update_stragglers() feeds StragglerDetector reports
into the capacity model — a group observed slowing to fraction f of its
healthy baseline advertises only f of its λ-worth of capacity, so the
admission gate backs off *before* the watchdog declares the group dead
(the λ-EWMA alone reacts with the EWMA's lag; the derate is immediate
and baseline-relative).
"""
from __future__ import annotations

import threading
from dataclasses import dataclass
from enum import Enum
from typing import Dict, Optional

from repro.core.overheads import OverheadLedger
from repro.core.throughput import ThroughputTracker
from repro.queue.job import Job, JobState
from repro.queue.manager import QueueManager


class Decision(str, Enum):
    ADMIT = "admit"
    DEFER = "defer"
    REJECT = "reject"


@dataclass
class AdmissionDecision:
    decision: Decision
    projected_delay_s: float
    capacity_items_s: float
    reason: str = ""

    def __bool__(self) -> bool:
        return self.decision == Decision.ADMIT


class AdmissionController:
    def __init__(self, queue: QueueManager,
                 tracker: Optional[ThroughputTracker] = None,
                 ledger: Optional[OverheadLedger] = None,
                 slo_delay_s: float = 1.0,
                 defer_factor: float = 4.0,
                 min_capacity: float = 1e-6):
        self.queue = queue
        self.tracker = tracker
        self.ledger = ledger
        self.slo_delay_s = slo_delay_s
        self.defer_factor = defer_factor
        self.min_capacity = min_capacity
        self._groups: Dict[str, float] = {}      # name -> λ seed
        self._derate: Dict[str, float] = {}      # name -> straggler factor
        self._lock = threading.Lock()
        # counters for observability / tests
        self.admitted = 0
        self.deferred = 0
        self.rejected = 0

    # -- topology events (ElasticController / scheduler failures) ------
    def on_group_join(self, name: str, lam_seed: float = 1.0) -> None:
        with self._lock:
            self._groups[name] = lam_seed

    def on_group_leave(self, name: str) -> None:
        with self._lock:
            self._groups.pop(name, None)
            self._derate.pop(name, None)

    def groups(self) -> Dict[str, float]:
        with self._lock:
            return dict(self._groups)

    # -- straggler derating (StragglerDetector reports) ----------------
    def update_stragglers(self, slowdowns: Dict[str, float]) -> None:
        """Replace the derate map from a detector observation: groups
        reported straggling advertise ``slowdown`` (current λ / healthy
        baseline, clamped to [0.05, 1.0]) of their capacity; groups no
        longer reported recover full weight."""
        with self._lock:
            self._derate = {
                name: min(1.0, max(0.05, f))
                for name, f in slowdowns.items() if name in self._groups}

    def derate(self, name: str) -> float:
        with self._lock:
            return self._derate.get(name, 1.0)

    # -- capacity model ------------------------------------------------
    def _useful_fraction(self, group: str) -> float:
        """1 − offload-overhead share of the group's busy time."""
        if self.ledger is None:
            return 1.0
        tot = self.ledger.totals(group)
        if tot.n_chunks == 0:
            return 1.0
        busy = tot.kernel + tot.sp + tot.hd + tot.kl + tot.dh + tot.td
        if busy <= 0.0:
            return 1.0
        return max(0.1, tot.kernel / busy)

    def capacity_items_s(self) -> float:
        """Aggregate useful throughput (items/s) of live groups."""
        cap = 0.0
        for name, seed in self.groups().items():
            lam = self.tracker.get(name) if self.tracker is not None else seed
            if lam <= 0.0:
                lam = seed
            cap += lam * self._useful_fraction(name) * self.derate(name)
        return max(cap, self.min_capacity)

    def projected_delay_s(self, extra_items: int = 0) -> float:
        backlog = self.queue.backlog_items() + extra_items
        return backlog / self.capacity_items_s()

    # -- the gate ------------------------------------------------------
    def admit(self, job: Job) -> AdmissionDecision:
        """Decide on a PENDING job; ADMIT enqueues it, REJECT cancels it,
        DEFER leaves it PENDING for the caller to retry."""
        cap = self.capacity_items_s()
        delay = (self.queue.backlog_items() + job.items) / cap
        if delay <= self.slo_delay_s:
            self.queue.put(job)
            self.admitted += 1
            return AdmissionDecision(Decision.ADMIT, delay, cap)
        if delay <= self.defer_factor * self.slo_delay_s:
            self.deferred += 1
            return AdmissionDecision(
                Decision.DEFER, delay, cap,
                reason=f"projected delay {delay:.3f}s > SLO "
                       f"{self.slo_delay_s:.3f}s")
        job.meta["rejected_delay_s"] = delay
        job.transition(JobState.CANCELLED)
        self.rejected += 1
        return AdmissionDecision(
            Decision.REJECT, delay, cap,
            reason=f"projected delay {delay:.3f}s > "
                   f"{self.defer_factor:.1f}×SLO")
