"""Admission control: backpressure before work reaches the scheduler.

Capacity is estimated from the signals the runtime already produces — the
per-group λ-estimates of ThroughputTracker (eqs. 1–2) derated by the §3.3
overhead fractions of OverheadLedger (an accelerator spending 30% of its
busy time in O_hd/O_kl/O_dh is not a λ-worth of useful capacity). The
projected queue delay for a new job is then

    delay ≈ (backlog_items + job.items) / Σ_G λ_G · useful_G

and the decision is a three-way gate against the delay SLO:

    delay ≤ slo              → ADMIT
    delay ≤ defer_factor·slo → DEFER   (caller should retry; bounded queue)
    otherwise                → REJECT  (shed load instead of building an
                                        unbounded backlog — the queue
                                        stays inside the SLO envelope)

Group membership is event-driven: ElasticController join/leave and
scheduler group failures call on_group_join/on_group_leave, so capacity
reacts to topology changes without polling.

Straggler awareness: update_stragglers() feeds StragglerDetector reports
into the capacity model — a group observed slowing to fraction f of its
healthy baseline advertises only f of its λ-worth of capacity, so the
admission gate backs off *before* the watchdog declares the group dead
(the λ-EWMA alone reacts with the EWMA's lag; the derate is immediate
and baseline-relative).

Multi-tenant mode: with a ``registry`` (TenantRegistry) the gate becomes
per-tenant. A job is deferred when its tenant is at its in-flight quota
(outstanding + queued jobs ≥ max_inflight), and the delay gate projects
the *tenant's* queue delay against the *tenant's* SLO using the tenant's
DWRR fair-share of capacity:

    delay_t ≈ (backlog_t + job.items) / (capacity · w_t / Σ_{active} w)

where "active" is the set of currently backlogged tenants plus the
candidate — so an underloaded tenant admitting into an empty shard sees
(up to weighted contention) the full capacity, never another tenant's
backlog (work conservation at the admission gate, mirroring the DWRR
drain). Without a registry the legacy global gate is unchanged.

Idle probing (policy mode only): the capacity estimate is *measured* —
λ-EWMAs only move when chunks complete. A gate that defers everything
therefore freezes its own evidence: nothing runs, λ never refreshes, and
a stale-low estimate (e.g. one compile-polluted first batch) projects
every future job past the SLO forever. When the smoothed gate says
defer/reject but the gate's population is completely idle (zero backlog
AND zero unfinished work), the projection is unfalsifiable and the job
would start immediately — a queue-delay SLO cannot be violated — so the
gate admits it as a probe to refresh the estimate. Exactly one probe is
in flight per population (the probe itself becomes unfinished work, so
the next candidate defers normally).
"""
from __future__ import annotations

import threading
from dataclasses import dataclass
from enum import Enum
from typing import Dict, Optional

from repro import telemetry as telemetry_mod
from repro.core.overheads import OverheadLedger
from repro.core.throughput import ThroughputTracker
from repro.queue import job as job_mod
from repro.queue.job import Job, JobState
from repro.queue.manager import QueueManager


class Decision(str, Enum):
    ADMIT = "admit"
    DEFER = "defer"
    REJECT = "reject"


@dataclass
class AdmissionDecision:
    decision: Decision
    projected_delay_s: float
    capacity_items_s: float
    reason: str = ""
    tenant: str = "default"

    def __bool__(self) -> bool:
        return self.decision == Decision.ADMIT


class AdmissionController:
    def __init__(self, queue: QueueManager,
                 tracker: Optional[ThroughputTracker] = None,
                 ledger: Optional[OverheadLedger] = None,
                 slo_delay_s: float = 1.0,
                 defer_factor: float = 4.0,
                 min_capacity: float = 1e-6,
                 registry=None, telemetry=None, clock=None, policy=None,
                 global_unfinished=None):
        self.queue = queue
        self.tracker = tracker
        self.ledger = ledger
        self.slo_delay_s = slo_delay_s
        self.defer_factor = defer_factor
        self.min_capacity = min_capacity
        # optional repro.policy.AdaptivePolicy (duck-typed): smooths the
        # gate's projected delay over a sliding window (hysteresis — the
        # gate rises with a spike instantly, decays slowly) and gates
        # straggler rebalances behind a cooldown. None → point-in-time
        # decisions, the original behavior.
        self.policy = policy
        # injectable job-clock (tests/clock.py); default follows
        # repro.queue.job.now at call time so a monkeypatched job clock
        # and the deadline gate can never disagree on "now"
        self._clock = clock
        # duck-typed TenantRegistry (repro.tenancy.spec); None → tenant-
        # blind legacy gate. Kept untyped so repro.queue never imports
        # repro.tenancy at module scope (tenancy builds on queue).
        self.registry = registry
        # federation hook: callable(tenant) -> unfinished jobs FLEET-wide
        # (gossip-aggregated). The quota gate takes max(local, global) so
        # a tenant cannot multiply its in-flight quota by the number of
        # runtimes it spans. None → single-runtime behavior.
        self.global_unfinished = global_unfinished
        self._groups: Dict[str, float] = {}      # name -> λ seed
        self._derate: Dict[str, float] = {}      # name -> straggler factor
        self._lock = threading.Lock()
        # serializes admit(): the quota/delay gates are check-then-act
        # against queue state, and concurrent admits (submit vs. the
        # service loop's retry_deferred, or recover on a live daemon)
        # must not both pass a quota with one slot left — and the
        # decision counters must not lose updates
        self._admit_lock = threading.Lock()
        # counters for observability / tests
        self.admitted = 0
        self.deferred = 0
        self.rejected = 0
        # rejects whose cause was an unmeetable deadline (dead-on-arrival
        # shedding — serving them would burn capacity on a guaranteed
        # deadline miss); subset of ``rejected``
        self.deadline_rejects = 0
        # admits forced through a defer/reject verdict because the gate's
        # population was idle (see module docstring); subset of ``admitted``
        self.idle_probes = 0
        self.per_tenant: Dict[str, Dict[str, int]] = {}
        # metrics: admission.decisions{decision,tenant} counters plus a
        # projected-delay histogram (the gate's own view of backlog)
        self.telemetry = telemetry_mod.resolve(telemetry)
        self._tel: Dict[tuple, object] = {}

    def _tel_decision(self, decision: Decision, tenant: str,
                      delay: float) -> None:
        if self.telemetry is None:
            return
        key = (decision.value, tenant)
        c = self._tel.get(key)
        if c is None:
            c = self._tel[key] = self.telemetry.registry.counter(
                "admission.decisions", decision=decision.value,
                tenant=tenant)
        c.add(1)
        h = self._tel.get("delay")
        if h is None:
            h = self._tel["delay"] = self.telemetry.registry.histogram(
                "admission.projected_delay_s")
        h.observe(delay)

    def now(self) -> float:
        """Job-domain clock (see ``clock=``)."""
        return self._clock() if self._clock is not None else job_mod.now()

    # -- topology events (ElasticController / scheduler failures) ------
    def on_group_join(self, name: str, lam_seed: float = 1.0) -> None:
        with self._lock:
            self._groups[name] = lam_seed

    def on_group_leave(self, name: str) -> None:
        with self._lock:
            self._groups.pop(name, None)
            self._derate.pop(name, None)

    def groups(self) -> Dict[str, float]:
        with self._lock:
            return dict(self._groups)

    # -- straggler derating (StragglerDetector reports) ----------------
    def update_stragglers(self, slowdowns: Dict[str, float]) -> None:
        """Replace the derate map from a detector observation: groups
        reported straggling advertise ``slowdown`` (current λ / healthy
        baseline, clamped to [0.05, 1.0]) of their capacity; groups no
        longer reported recover full weight.

        With a policy attached the proposed map must clear its rebalance
        gate first: insignificant changes are dropped, and significant
        ones inside the post-rebalance cooldown are suppressed (counted)
        so a group flapping around the straggler threshold cannot thrash
        the advertised capacity."""
        with self._lock:
            new = {name: min(1.0, max(0.05, f))
                   for name, f in slowdowns.items() if name in self._groups}
            old = dict(self._derate)
        if self.policy is not None and \
                not self.policy.allow_rebalance(self.now(), new, old):
            return
        with self._lock:
            self._derate = new

    def derate(self, name: str) -> float:
        with self._lock:
            return self._derate.get(name, 1.0)

    # -- capacity model ------------------------------------------------
    def _useful_fraction(self, group: str) -> float:
        """1 − offload-overhead share of the group's busy time."""
        if self.ledger is None:
            return 1.0
        tot = self.ledger.totals(group)
        if tot.n_chunks == 0:
            return 1.0
        busy = tot.kernel + tot.sp + tot.hd + tot.kl + tot.dh + tot.td
        if busy <= 0.0:
            return 1.0
        return max(0.1, tot.kernel / busy)

    def capacity_items_s(self) -> float:
        """Aggregate useful throughput (items/s) of live groups."""
        cap = 0.0
        for name, seed in self.groups().items():
            lam = self.tracker.get(name) if self.tracker is not None else seed
            if lam <= 0.0:
                lam = seed
            cap += lam * self._useful_fraction(name) * self.derate(name)
        return max(cap, self.min_capacity)

    def projected_delay_s(self, extra_items: int = 0) -> float:
        backlog = self.queue.backlog_items() + extra_items
        return backlog / self.capacity_items_s()

    # -- per-tenant views ----------------------------------------------
    def _tenant_weight(self, tenant: str) -> float:
        """The tenant's effective DWRR weight, as the queue drains it —
        delegated so admission's fair-share model can never drift from
        the drain's derate/floor policy."""
        effective = getattr(self.queue, "effective_weight", None)
        if effective is not None:
            return effective(tenant)
        return max(1e-9, self.registry.get(tenant).weight)

    def tenant_capacity_items_s(self, tenant: str) -> float:
        """The tenant's DWRR fair-share of aggregate useful capacity:
        full capacity when no other tenant is backlogged, its weight share
        among backlogged tenants otherwise."""
        cap = self.capacity_items_s()
        if self.registry is None:
            return cap
        by_tenant = getattr(self.queue, "backlog_by_tenant", None)
        if by_tenant is None:                # unsharded queue: no view
            return cap
        active = {t for t, b in by_tenant().items() if b > 0}
        active.add(tenant)
        wsum = sum(self._tenant_weight(t) for t in active)
        return max(cap * self._tenant_weight(tenant) / wsum,
                   self.min_capacity)

    def _tenant_backlog_items(self, tenant: str) -> int:
        if self.registry is not None \
                and hasattr(self.queue, "backlog_by_tenant"):
            return self.queue.backlog_items(tenant)
        return self.queue.backlog_items()

    def tenant_projected_delay_s(self, tenant: str,
                                 extra_items: int = 0) -> float:
        return (self._tenant_backlog_items(tenant) + extra_items) \
            / self.tenant_capacity_items_s(tenant)

    _COUNTER = {Decision.ADMIT: "admitted", Decision.DEFER: "deferred",
                Decision.REJECT: "rejected"}

    def _count(self, tenant: str, decision: Decision) -> None:
        bucket = self.per_tenant.setdefault(
            tenant, {"admitted": 0, "deferred": 0, "rejected": 0})
        bucket[self._COUNTER[decision]] += 1

    def _tenant_quota_free(self, job: Job) -> bool:
        """True while the tenant's unfinished admitted work (popped but
        unfinished + still queued) stays under its in-flight quota. The
        queued() view excludes popped jobs (which stay ADMITTED until
        mark_running) so work in the pop-to-dispatch window is not
        counted against the quota twice."""
        spec = self.registry.get(job.tenant)
        if spec.max_inflight is None:
            return True
        unfinished_fn = getattr(self.queue, "unfinished", None)
        if unfinished_fn is not None:
            # one atomic snapshot — a concurrent pop moving a job from
            # queued to popped between two separate reads would make the
            # gate undercount and admit past the quota
            unfinished = unfinished_fn(job.tenant)
        else:
            # unsharded queue: count THIS tenant's live jobs directly —
            # another tenant's backlog must never consume this tenant's
            # quota (and its own RUNNING jobs must)
            unfinished = sum(1 for j in self.queue.jobs()
                             if j.tenant == job.tenant
                             and j.state in (JobState.ADMITTED,
                                             JobState.RUNNING))
        if self.global_unfinished is not None:
            # the fleet view is one heartbeat stale and may lag the local
            # count it already includes — max() never double-counts and
            # enforces whichever bound is tighter
            unfinished = max(unfinished,
                             self.global_unfinished(job.tenant))
        return unfinished < spec.max_inflight

    def shed_deferred(self, job: Job) -> None:
        """Reclassify one DEFERred job as rejected — the service calls
        this when it sheds a deferred job (pool at capacity) so the
        counters report the job's final outcome, not the gate's initial
        answer."""
        with self._admit_lock:
            self.deferred -= 1
            self.rejected += 1
            if self.registry is not None:
                bucket = self.per_tenant.get(job.tenant)
                if bucket is not None:
                    bucket["deferred"] -= 1
                    bucket["rejected"] += 1

    # -- the gate ------------------------------------------------------
    def admit(self, job: Job) -> AdmissionDecision:
        """Decide on a PENDING job; ADMIT enqueues it, REJECT cancels it,
        DEFER leaves it PENDING for the caller to retry. With a tenant
        registry the delay gate is per-tenant (fair-share capacity vs. the
        tenant's own SLO) and an in-flight quota breach defers."""
        with self._admit_lock:
            return self._admit_locked(job)

    def _admit_locked(self, job: Job) -> AdmissionDecision:
        if job.deadline_s is not None:
            # deadline stamping: the absolute deadline rides the job's
            # metadata into the journal, so a recovered daemon enforces
            # the original budget, not one restarted at replay time
            job.meta.setdefault("deadline_at", job.deadline_at)
        if self.registry is None:
            return self._gate(job, self.capacity_items_s(),
                              self.queue.backlog_items(),
                              self.slo_delay_s, prefix="")
        spec = self.registry.get(job.tenant)
        cap_t = self.tenant_capacity_items_s(job.tenant)
        slo = spec.slo_delay_s if spec.slo_delay_s is not None \
            else self.slo_delay_s
        if not self._tenant_quota_free(job):
            delay = (self._tenant_backlog_items(job.tenant) + job.items) \
                / cap_t
            infeasible = self._deadline_infeasible(job, delay, cap_t)
            if infeasible is not None:
                return infeasible
            at_quota = f"tenant {job.tenant} at in-flight quota " \
                       f"{spec.max_inflight}"
            # the reject band still applies at quota — otherwise a flood
            # against a capped tenant is deferred forever and the
            # deferred pool (re-gated every service poll) grows without
            # bound instead of being shed like the tenant-blind gate does
            if delay > self.defer_factor * slo:
                return self._reject(
                    job, delay, cap_t,
                    f"{at_quota} and projected delay {delay:.3f}s > "
                    f"{self.defer_factor:.1f}×SLO")
            return self._defer(job, delay, cap_t, at_quota)
        return self._gate(job, cap_t,
                          self._tenant_backlog_items(job.tenant), slo,
                          prefix=f"tenant {job.tenant} ",
                          key=job.tenant)

    # shared decision bookkeeping: counters, per-tenant counters (registry
    # mode only), lifecycle transition and rejection metadata live here so
    # the global gate, the per-tenant gate, and the quota branch cannot
    # drift apart
    def _defer(self, job: Job, delay: float, cap: float,
               reason: str) -> AdmissionDecision:
        self.deferred += 1
        if self.registry is not None:
            self._count(job.tenant, Decision.DEFER)
        self._tel_decision(Decision.DEFER, job.tenant, delay)
        return AdmissionDecision(Decision.DEFER, delay, cap,
                                 tenant=job.tenant, reason=reason)

    def _reject(self, job: Job, delay: float, cap: float,
                reason: str) -> AdmissionDecision:
        job.meta["rejected_delay_s"] = delay
        job.transition(JobState.CANCELLED)
        self.rejected += 1
        if self.registry is not None:
            self._count(job.tenant, Decision.REJECT)
        self._tel_decision(Decision.REJECT, job.tenant, delay)
        return AdmissionDecision(Decision.REJECT, delay, cap,
                                 tenant=job.tenant, reason=reason)

    def _deadline_infeasible(self, job: Job, delay: float,
                             cap: float) -> Optional[AdmissionDecision]:
        """REJECT a deadline job whose projected queue delay already
        exceeds its remaining budget — admitting it could only produce a
        deadline miss after burning real capacity. None when feasible
        (or deadline-less)."""
        if job.deadline_s is None:
            return None
        remaining = job.deadline_at - self.now()
        if delay <= max(0.0, remaining):
            return None
        self.deadline_rejects += 1
        if self.telemetry is not None:
            self.telemetry.registry.counter(
                "admission.deadline_rejects", tenant=job.tenant).add()
            self.telemetry.tracer.instant(
                "deadline_reject", tid="admission", tenant=job.tenant,
                tier=job.tier, remaining_s=round(remaining, 6))
        job.meta["deadline_missed"] = True
        return self._reject(
            job, delay, cap,
            f"projected delay {delay:.3f}s exceeds remaining deadline "
            f"budget {remaining:.3f}s")

    def _population_unfinished(self, job: Job) -> int:
        """Unfinished (admitted-or-running) jobs in the gate population
        that would decide ``job`` — its tenant's shard in registry mode,
        the whole queue otherwise. Only consulted on the idle-probe path
        (gate said defer/reject AND backlog is zero), so the unsharded
        fallback scan is off the admit hot path."""
        if self.registry is not None:
            unfinished_fn = getattr(self.queue, "unfinished", None)
            if unfinished_fn is not None:
                return unfinished_fn(job.tenant)
            return sum(1 for j in self.queue.jobs()
                       if j.tenant == job.tenant
                       and j.state in (JobState.ADMITTED, JobState.RUNNING))
        return sum(1 for j in self.queue.jobs()
                   if j.state in (JobState.ADMITTED, JobState.RUNNING))

    def _gate(self, job: Job, cap: float, backlog: int, slo: float,
              prefix: str, key: str = "*") -> AdmissionDecision:
        """The three-band ADMIT/DEFER/REJECT ladder, shared by the legacy
        global gate and the per-tenant gate (which differ only in which
        capacity/backlog/SLO feed it — and, with a policy attached, in
        ``key``: each gate population smooths over its own window)."""
        delay = (backlog + job.items) / cap
        if self.policy is not None:
            # windowed smoothing (serialized by _admit_lock): reacts
            # instantly to rising load, projects the window's trend
            # forward, and — given the SLO — latches DEFER until the
            # recent high-water clears the band, killing ADMIT/DEFER
            # flapping on point-sample noise
            delay = self.policy.admission_delay(self.now(), delay,
                                                slo=slo, key=key)
        infeasible = self._deadline_infeasible(job, delay, cap)
        if infeasible is not None:
            return infeasible
        probe = False
        if delay > slo and self.policy is not None and backlog == 0 \
                and self._population_unfinished(job) == 0:
            # idle probe: with zero backlog and nothing unfinished the
            # stale-low λ that produced this verdict can never refresh —
            # deferring would livelock the population (see module
            # docstring). The job starts immediately, so the queue-delay
            # SLO is safe by construction.
            probe = True
            self.idle_probes += 1
            if self.telemetry is not None:
                self.telemetry.registry.counter(
                    "admission.idle_probes", tenant=job.tenant).add()
        if delay <= slo or probe:
            self.queue.put(job)
            self.admitted += 1
            if self.registry is not None:
                self._count(job.tenant, Decision.ADMIT)
            self._tel_decision(Decision.ADMIT, job.tenant, delay)
            return AdmissionDecision(Decision.ADMIT, delay, cap,
                                     tenant=job.tenant,
                                     reason="idle probe" if probe else "")
        if delay <= self.defer_factor * slo:
            return self._defer(job, delay, cap,
                               f"{prefix}projected delay {delay:.3f}s "
                               f"> SLO {slo:.3f}s")
        return self._reject(job, delay, cap,
                            f"{prefix}projected delay {delay:.3f}s > "
                            f"{self.defer_factor:.1f}×SLO")
