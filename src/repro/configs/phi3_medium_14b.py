"""phi3-medium-14b — dense RoPE SwiGLU GQA [arXiv:2404.14219; unverified].

40L d_model=5120 40H (GQA kv=10) d_ff=17920 vocab=100352.

Note: 40 heads / kv=10 are NOT divisible by the production TP degree (16); the
sharding rules fall back to row-parallel attention for this arch (see
repro/sharding/rules.py and DESIGN.md §Arch-applicability).
"""
from repro.configs.base import LMConfig

CONFIG = LMConfig(
    arch_id="phi3-medium-14b",
    family="dense",
    n_layers=40,
    d_model=5120,
    n_heads=40,
    n_kv_heads=10,
    head_dim=128,
    d_ff=17920,
    vocab=100352,
)
