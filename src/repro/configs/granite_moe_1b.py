"""granite-moe-1b-a400m — MoE 32 experts top-8 [hf:ibm-granite/granite-3.0-1b-a400m-base; hf].

24L d_model=1024 16H (GQA kv=8) d_ff=512 vocab=49155, MoE 32e top-8.
High top-k (8/32) stresses the dispatch all-to-all — the most
collective-bound MoE cell in the assignment.
"""
from repro.configs.base import LMConfig, MoEConfig

CONFIG = LMConfig(
    arch_id="granite-moe-1b-a400m",
    family="moe",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=8,
    head_dim=64,
    d_ff=512,
    vocab=49155,
    moe=MoEConfig(num_experts=32, top_k=8, capacity_factor=1.25),
)
