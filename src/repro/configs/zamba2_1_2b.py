"""zamba2-1.2b — hybrid Mamba2 + shared attention blocks [arXiv:2411.15242; hf].

38L d_model=2048 32H (GQA kv=32) d_ff=8192 vocab=32000, ssm_state=64.
38 Mamba-2 blocks; a single parameter-shared attention+MLP block is applied
every `attn_every` SSM blocks (Zamba-style weight sharing). Sub-quadratic:
runs the long_500k cell with a sequence-sharded KV cache for the shared
attention block.
"""
from repro.configs.base import LMConfig, SSMConfig, HybridConfig

CONFIG = LMConfig(
    arch_id="zamba2-1.2b",
    family="hybrid",
    n_layers=38,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    head_dim=64,
    d_ff=8192,
    vocab=32000,
    ssm=SSMConfig(d_state=64, d_conv=4, expand=2, head_dim=64, n_groups=1,
                  chunk_size=128),
    hybrid=HybridConfig(attn_every=6),
    subquadratic=True,
)
