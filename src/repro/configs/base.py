"""Model/architecture configuration system.

Every assigned architecture is expressed as an :class:`LMConfig`. The config is a
plain frozen dataclass so it can be hashed into jit static args and serialized
into dry-run / checkpoint metadata.

Families
--------
``dense``   decoder-only transformer (GQA + RoPE + gated MLP)
``vlm``     dense backbone + stubbed patch-embedding prefix (frontend is a stub)
``audio``   dense backbone over EnCodec-token streams (frontend is a stub)
``moe``     dense attention + mixture-of-experts FFN (top-k routing, EP-sharded)
``ssm``     xLSTM: alternating mLSTM / sLSTM blocks
``hybrid``  Zamba2-style: Mamba-2 backbone with a shared attention block
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple

import jax.numpy as jnp


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    router_jitter: float = 0.0
    # load-balancing auxiliary loss weight (Switch-style)
    aux_loss_weight: float = 0.01
    # expert-dispatch locality: 1 = global top-C per expert (simplest);
    # N > 1 = capacity enforced per dispatch group (align with the data-
    # parallel axis so the combine scatter stays shard-local and the
    # cross-shard all-reduce of the full token array disappears —
    # EXPERIMENTS.md §Perf, phi3.5-moe iteration 1)
    dispatch_groups: int = 1


@dataclass(frozen=True)
class SSMConfig:
    """Mamba-2 (SSD) block hyper-parameters."""
    d_state: int = 64
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64          # SSD head dim (d_inner / head_dim heads)
    n_groups: int = 1           # B/C groups (GQA-analogue for SSD)
    chunk_size: int = 128       # SSD chunked-scan block length


@dataclass(frozen=True)
class XLSTMConfig:
    """xLSTM block hyper-parameters (alternating mLSTM / sLSTM)."""
    proj_factor_m: int = 2       # mLSTM up-projection factor
    ff_factor_s: int = 2         # sLSTM post-cell GLU FFN factor
    chunk_size: int = 128        # mLSTM chunkwise-parallel block length
    slstm_every: int = 2         # every k-th block is sLSTM (rest mLSTM)


@dataclass(frozen=True)
class HybridConfig:
    """Zamba2-style hybrid: Mamba-2 backbone + shared attention block."""
    attn_every: int = 6          # apply the shared attention block every k SSM blocks


@dataclass(frozen=True)
class LMConfig:
    arch_id: str
    family: str                  # dense | vlm | audio | moe | ssm | hybrid
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0            # 0 -> d_model // n_heads
    norm_type: str = "rmsnorm"   # rmsnorm | layernorm
    act: str = "silu"            # silu | gelu
    gated_mlp: bool = True
    rope_fraction: float = 1.0   # fraction of head_dim that is rotated
    rope_theta: float = 10_000.0
    pos_emb: str = "rope"        # rope | learned | none
    tie_embeddings: bool = False
    prefix_len: int = 0          # stubbed modality prefix (vlm/audio conditioning)
    norm_eps: float = 1e-5
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    xlstm: Optional[XLSTMConfig] = None
    hybrid: Optional[HybridConfig] = None
    dtype: str = "bfloat16"      # activation/param compute dtype
    # sub-quadratic? full-attention archs must skip long_500k
    subquadratic: bool = False
    # attention chunking (pure-JAX flash-style path)
    q_chunk: int = 512
    kv_chunk: int = 1024
    # causal block-sparse attention: skip fully-masked kv blocks (beyond-paper perf opt)
    causal_block_skip: bool = False
    # flash custom-VJP attention for training (saves only (o, L) row stats;
    # backward rebuilds probability tiles — EXPERIMENTS.md §Perf)
    attn_custom_vjp: bool = False
    # unroll the decode layer loop: each layer's KV-cache update becomes an
    # independent in-place dynamic-update-slice (with donation), instead of
    # the scan threading full stacked caches through every iteration
    # (EXPERIMENTS.md §Perf, decode iteration 1)
    decode_unroll: bool = False
    max_seq_len: int = 32_768

    # ---- derived -------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim else self.d_model // self.n_heads

    @property
    def activation_dtype(self):
        return jnp.dtype(self.dtype)

    def replace(self, **kw) -> "LMConfig":
        return dataclasses.replace(self, **kw)

    # ------------------------------------------------------------------
    def param_count(self) -> int:
        """Analytic parameter count (used for 6·N·D model-FLOP accounting)."""
        d, hd = self.d_model, self.resolved_head_dim
        n_emb = self.vocab * d * (1 if self.tie_embeddings else 2)
        per_layer = 0
        if self.family in ("dense", "vlm", "audio", "moe"):
            attn = d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd \
                + self.n_heads * hd * d
            if self.moe:
                mlp = d * self.moe.num_experts  # router
                mlp += self.moe.num_experts * (
                    d * self.d_ff * (3 if self.gated_mlp else 2))
            else:
                mlp = d * self.d_ff * (3 if self.gated_mlp else 2)
            per_layer = attn + mlp + 2 * d
        elif self.family == "ssm":
            x = self.xlstm or XLSTMConfig()
            di = d * x.proj_factor_m
            nh = self.n_heads
            dh = d // nh
            # mLSTM block: pre_norm + up(d,2di) + q/k/v(di,di) + wif(di,2nh)
            #              + headwise norm + down(di,d)
            m = d + 2 * d * di + 3 * di * di + 2 * di * nh + di + di * d
            # sLSTM block: pre_norm + W(d,4d) + R(nh,dh,4dh) + b(4d)
            #              + ffn_norm + gated FFN(3·d·ff)
            ff = x.ff_factor_s * d
            s = d + 4 * d * d + nh * dh * 4 * dh + 4 * d + d + 3 * d * ff
            n_s = self.n_layers // x.slstm_every
            total = n_s * s + (self.n_layers - n_s) * m
            return n_emb + total + d
        elif self.family == "hybrid":
            s = self.ssm or SSMConfig()
            d_inner = s.expand * d
            nheads = d_inner // s.head_dim
            in_proj = d * (2 * d_inner + 2 * s.n_groups * s.d_state + nheads)
            blk = in_proj + s.d_conv * (d_inner + 2 * s.n_groups * s.d_state) \
                + d_inner * d + 2 * d
            shared_attn = d * self.n_heads * hd * 2 \
                + 2 * d * self.n_kv_heads * hd + d * self.d_ff * 3
            return n_emb + self.n_layers * blk + shared_attn
        total = n_emb + self.n_layers * per_layer + d
        return total

    def active_param_count(self) -> int:
        """Active params per token (MoE: only top-k experts count)."""
        if not self.moe:
            return self.param_count()
        d = self.d_model
        expert_p = d * self.d_ff * (3 if self.gated_mlp else 2)
        inactive = self.n_layers * (self.moe.num_experts - self.moe.top_k) * expert_p
        return self.param_count() - inactive


@dataclass(frozen=True)
class ShapeSuite:
    """One assigned (seq_len, global_batch) input-shape cell."""
    name: str
    seq_len: int
    global_batch: int
    kind: str                    # train | prefill | decode | long_decode

    @property
    def is_decode(self) -> bool:
        return self.kind in ("decode", "long_decode")


SHAPES: Tuple[ShapeSuite, ...] = (
    ShapeSuite("train_4k", 4_096, 256, "train"),
    ShapeSuite("prefill_32k", 32_768, 32, "prefill"),
    ShapeSuite("decode_32k", 32_768, 128, "decode"),
    ShapeSuite("long_500k", 524_288, 1, "long_decode"),
)

SHAPES_BY_NAME = {s.name: s for s in SHAPES}


def shape_applicable(cfg: LMConfig, shape: ShapeSuite) -> Tuple[bool, str]:
    """Whether a shape cell applies to an architecture (per assignment rules)."""
    if shape.kind == "long_decode" and not cfg.subquadratic:
        return False, "long_500k needs sub-quadratic attention; " \
                      f"{cfg.arch_id} is pure full-attention (skip per assignment)"
    return True, ""


def reduced(cfg: LMConfig) -> LMConfig:
    """A tiny same-family config for CPU smoke tests (shapes asserted, no NaNs)."""
    kw = dict(
        n_layers=2 if cfg.family not in ("ssm", "hybrid") else 4,
        d_model=64,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 2),
        head_dim=16,
        d_ff=128 if cfg.d_ff else 0,
        vocab=256,
        prefix_len=min(cfg.prefix_len, 4),
        q_chunk=16,
        kv_chunk=16,
        max_seq_len=128,
    )
    if cfg.moe:
        kw["moe"] = dataclasses.replace(
            cfg.moe, num_experts=4, top_k=min(cfg.moe.top_k, 2))
    if cfg.ssm:
        kw["ssm"] = dataclasses.replace(
            cfg.ssm, d_state=8, head_dim=16, expand=2, chunk_size=16)
    if cfg.xlstm:
        kw["xlstm"] = dataclasses.replace(cfg.xlstm, chunk_size=16)
    if cfg.hybrid:
        kw["hybrid"] = dataclasses.replace(cfg.hybrid, attn_every=2)
    return cfg.replace(**kw)
