"""phi3.5-moe-42b-a6.6b — MoE 16 experts top-2 [hf:microsoft/Phi-3.5-MoE-instruct; hf].

32L d_model=4096 32H (GQA kv=8) d_ff=6400 vocab=32064, MoE 16e top-2.
Expert-parallel over the 'model' mesh axis (1 expert per TP shard at TP=16).
"""
from repro.configs.base import LMConfig, MoEConfig

CONFIG = LMConfig(
    arch_id="phi3.5-moe-42b-a6.6b",
    family="moe",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=6400,
    vocab=32064,
    moe=MoEConfig(num_experts=16, top_k=2, capacity_factor=1.25),
)
