"""xlstm-350m — sLSTM + mLSTM blocks [arXiv:2405.04517; unverified].

24L d_model=1024 4H (GQA kv=4) d_ff=0 vocab=50304.
d_ff=0 per assignment: all FFN capacity lives inside the blocks (mLSTM
up-projection factor 2; sLSTM post-cell GLU factor 2). Sub-quadratic:
runs the long_500k cell (recurrent O(1)-state decode).
"""
from repro.configs.base import LMConfig, XLSTMConfig

CONFIG = LMConfig(
    arch_id="xlstm-350m",
    family="ssm",
    n_layers=24,
    d_model=1024,
    n_heads=4,
    n_kv_heads=4,
    head_dim=256,
    d_ff=0,
    vocab=50304,
    pos_emb="none",
    xlstm=XLSTMConfig(proj_factor_m=2, ff_factor_s=2, chunk_size=128,
                      slstm_every=2),
    subquadratic=True,
)
