"""musicgen-large — audio decoder-only over EnCodec tokens [arXiv:2306.05284; hf].

48L d_model=2048 32H (GQA kv=32) d_ff=8192 vocab=2048.
Backbone only per assignment: EnCodec/ T5-conditioning frontends are STUBS;
``input_specs()`` provides precomputed conditioning-frame embeddings as a
prefix and the token stream is the (delay-interleaved) codebook stream.
MusicGen uses learned positional embeddings and non-gated GELU MLPs.
"""
from repro.configs.base import LMConfig

CONFIG = LMConfig(
    arch_id="musicgen-large",
    family="audio",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    head_dim=64,
    d_ff=8192,
    vocab=2048,
    act="gelu",
    gated_mlp=False,
    norm_type="layernorm",
    pos_emb="learned",
    prefix_len=64,    # stubbed conditioning prefix
)
