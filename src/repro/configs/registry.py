"""Architecture registry: ``--arch <id>`` resolution for all launchers."""
from __future__ import annotations

from typing import Dict, List

from repro.configs.base import LMConfig, ShapeSuite, SHAPES, SHAPES_BY_NAME, \
    shape_applicable, reduced

from repro.configs import yi_6b, deepseek_7b, phi3_medium_14b, stablelm_1_6b, \
    phi3_vision_4_2b, musicgen_large, xlstm_350m, phi35_moe_42b, \
    granite_moe_1b, zamba2_1_2b

_MODULES = (
    yi_6b, deepseek_7b, phi3_medium_14b, stablelm_1_6b, phi3_vision_4_2b,
    musicgen_large, xlstm_350m, phi35_moe_42b, granite_moe_1b, zamba2_1_2b,
)

ARCHS: Dict[str, LMConfig] = {m.CONFIG.arch_id: m.CONFIG for m in _MODULES}


def get_config(arch_id: str) -> LMConfig:
    if arch_id not in ARCHS:
        raise KeyError(
            f"unknown arch {arch_id!r}; available: {sorted(ARCHS)}")
    return ARCHS[arch_id]


def get_reduced_config(arch_id: str) -> LMConfig:
    return reduced(get_config(arch_id))


def list_archs() -> List[str]:
    return sorted(ARCHS)


def dryrun_cells(include_skips: bool = False):
    """All (arch, shape) dry-run cells; skipped cells carry their reason."""
    cells = []
    for arch_id in list_archs():
        cfg = ARCHS[arch_id]
        for shape in SHAPES:
            ok, reason = shape_applicable(cfg, shape)
            if ok or include_skips:
                cells.append((cfg, shape, ok, reason))
    return cells


__all__ = ["ARCHS", "get_config", "get_reduced_config", "list_archs",
           "dryrun_cells", "SHAPES", "SHAPES_BY_NAME"]
