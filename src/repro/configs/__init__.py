from repro.configs.base import (LMConfig, MoEConfig, SSMConfig, XLSTMConfig,
                                HybridConfig, ShapeSuite, SHAPES,
                                SHAPES_BY_NAME, shape_applicable, reduced)

__all__ = ["LMConfig", "MoEConfig", "SSMConfig", "XLSTMConfig", "HybridConfig",
           "ShapeSuite", "SHAPES", "SHAPES_BY_NAME", "shape_applicable",
           "reduced"]
