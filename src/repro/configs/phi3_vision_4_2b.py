"""phi-3-vision-4.2b — VLM backbone [hf:microsoft/Phi-3-vision-128k-instruct; hf].

32L d_model=3072 32H (GQA kv=32) d_ff=8192 vocab=32064.
phi3-mini backbone + CLIP frontend. Per assignment the modality frontend is a
STUB: ``input_specs()`` provides precomputed patch embeddings that the backbone
consumes as a sequence prefix; loss is computed on text positions only.
"""
from repro.configs.base import LMConfig

CONFIG = LMConfig(
    arch_id="phi-3-vision-4.2b",
    family="vlm",
    n_layers=32,
    d_model=3072,
    n_heads=32,
    n_kv_heads=32,
    head_dim=96,
    d_ff=8192,
    vocab=32064,
    prefix_len=144,   # stubbed CLIP patch-embedding prefix (12x12 pooled patches)
)
