"""stablelm-1.6b — dense [hf:stabilityai/stablelm-2-1_6b; unverified].

24L d_model=2048 32H (GQA kv=32) d_ff=5632 vocab=100352.
StableLM-2 uses LayerNorm and partial rotary embeddings (25% of head_dim).
"""
from repro.configs.base import LMConfig

CONFIG = LMConfig(
    arch_id="stablelm-1.6b",
    family="dense",
    n_layers=24,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    head_dim=64,
    d_ff=5632,
    vocab=100352,
    norm_type="layernorm",
    rope_fraction=0.25,
)
