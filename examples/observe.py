"""Observability tour: serve 50 jobs through the queued engine with a
fresh Telemetry instance, stream JSONL snapshots while it runs, then
print the live registry snapshot and where the exported artifacts landed.

Run:  PYTHONPATH=src python examples/observe.py
Then open trace at https://ui.perfetto.dev (or chrome://tracing).
"""
import json
import sys
import tempfile
from pathlib import Path

sys.path.insert(0, "src")

from repro.configs.registry import get_reduced_config
from repro.core.types import DeviceKind
from repro.queue import Job
from repro.serve.engine import HeteroServeEngine
from repro.telemetry import MetricsExporter, Telemetry, read_jsonl
from repro.tenancy import TenantRegistry
from repro.train.trainer import GroupDef


def main():
    cfg = get_reduced_config("yi-6b")
    groups = [
        GroupDef("accel", DeviceKind.ACCEL, fixed_chunk=8, async_depth=2),
        GroupDef("cpu0", DeviceKind.BIG, slowdown=2.0),
    ]
    tenants = TenantRegistry.parse("gold:weight=4,free:weight=1")
    jobs = [Job(items=2, priority=i % 3,
                tenant="gold" if i % 2 else "free") for i in range(50)]

    out = Path(tempfile.mkdtemp(prefix="repro-observe-"))
    tel = Telemetry(sample_rate=1.0)
    eng = HeteroServeEngine(cfg, groups, prompt_len=24, decode_tokens=6,
                            telemetry=tel)
    with MetricsExporter(tel, metrics_path=str(out / "metrics.jsonl"),
                         interval_s=0.25,
                         trace_path=str(out / "trace.json"),
                         prometheus_path=str(out / "prom.txt")):
        rep = eng.serve_jobs(jobs, batch_jobs=8, tenants=tenants)

    print(f"{rep.jobs} jobs ({rep.done} done) -> {rep.new_tokens} tokens "
          f"in {rep.time_s:.2f}s")

    snap = eng.telemetry_snapshot()
    chunks = {k: v for k, v in snap["counters"].items()
              if k.startswith("sched.chunks")}
    host = {k: round(v["mean"] * 1e6, 1) for k, v in
            snap["histograms"].items() if k.startswith("sched.chunk_host")}
    print("\nlive snapshot highlights")
    print("  chunks per group:   ", chunks)
    print("  host overhead (us): ", host)
    print("  DWRR pops:          ",
          {k: v for k, v in snap["counters"].items()
           if k.startswith("queue.dwrr_pops")})
    print("  registry self-cost: ",
          f"{snap['self']['ns_per_op']:.0f} ns/op, "
          f"{snap['self']['est_overhead_s'] * 1e3:.2f} ms total")

    snaps = read_jsonl(out / "metrics.jsonl")
    trace = json.loads((out / "trace.json").read_text())
    print(f"\nexported to {out}")
    print(f"  metrics.jsonl  {len(snaps)} snapshots "
          f"(last is final={snaps[-1]['final']})")
    print(f"  trace.json     {len(trace['traceEvents'])} events — load in "
          f"Perfetto")
    print(f"  prom.txt       Prometheus text format")


if __name__ == "__main__":
    main()
