"""End-to-end example: train a (reduced) stablelm-family LM for a few hundred
steps with the heterogeneous dynamic scheduler — an accelerator group with
dispatch-ahead (the TPU-idiomatic Dynamic Pri) plus a slower CPU group, with
checkpointing and automatic straggler rebalancing.

Run:  PYTHONPATH=src python examples/train_hetero_lm.py [--steps 200]
"""
import argparse
import sys
import tempfile

sys.path.insert(0, "src")

from repro.checkpoint import Checkpointer
from repro.configs.registry import get_reduced_config
from repro.core.types import DeviceKind
from repro.train.optimizer import OptConfig
from repro.train.trainer import GroupDef, HeteroTrainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--arch", default="stablelm-1.6b")
    args = ap.parse_args()

    cfg = get_reduced_config(args.arch)
    groups = [
        GroupDef("accel", DeviceKind.ACCEL, async_depth=2),
        GroupDef("cpu0", DeviceKind.BIG, slowdown=2.5),
    ]
    tr = HeteroTrainer(cfg, groups, seq_len=64, global_batch=32,
                       oc=OptConfig(lr=1e-3, warmup_steps=10,
                                    total_steps=args.steps),
                       repeat_data=False)
    G = tr.tune_accel_chunk(seed_chunk=4)
    print(f"tuned accelerator chunk G = {G}")

    ckdir = tempfile.mkdtemp(prefix="hetero_ck_")
    ck = Checkpointer(ckdir)
    for _ in range(args.steps):
        rep = tr.train_step()
        if rep.step % 10 == 0 or rep.step == 1:
            print(f"step {rep.step:4d}  loss {rep.loss:.4f}  "
                  f"split {rep.per_group_items}  "
                  f"λ {{{', '.join(f'{k}:{v:.0f}' for k, v in rep.throughput.items())}}}")
        if rep.step % 20 == 0:
            ck.save_async(rep.step, {"params": tr.params, "opt": tr.opt})
    ck.wait()
    print(f"final loss {tr.history[-1].loss:.4f} "
          f"(start {tr.history[0].loss:.4f}); checkpoints in {ckdir}")
    assert tr.history[-1].loss < tr.history[0].loss


if __name__ == "__main__":
    main()
