"""Serving example: batched requests scheduled across heterogeneous groups
(prefill + decode bursts), with the accelerator batch tuned like the paper's
GPU chunk.

Run:  PYTHONPATH=src python examples/serve_hetero.py
"""
import sys

sys.path.insert(0, "src")

from repro.configs.registry import get_reduced_config
from repro.core.types import DeviceKind
from repro.serve.engine import HeteroServeEngine
from repro.train.trainer import GroupDef


def main():
    cfg = get_reduced_config("yi-6b")
    groups = [
        GroupDef("accel", DeviceKind.ACCEL, fixed_chunk=8, async_depth=2),
        GroupDef("cpu0", DeviceKind.BIG, slowdown=2.0),
    ]
    eng = HeteroServeEngine(cfg, groups, prompt_len=24, decode_tokens=6)
    rep = eng.serve(48)
    print(f"{rep.requests} requests -> {rep.new_tokens} tokens "
          f"in {rep.time_s:.2f}s "
          f"({rep.new_tokens / rep.time_s:.1f} tok/s)")
    print("split:", rep.per_group_items)
    ov = rep.overheads.get("accel", {})
    print("accel offload overheads:",
          {k: round(v, 4) for k, v in ov.items()})


if __name__ == "__main__":
    main()
