"""Reproduce the paper's overhead study (Figs. 2, 5, 6, 7) with the
calibrated simulator: Dynamic vs Bulk-Oracle, 3+1 vs 4+1, priority boost,
and big.LITTLE, on Ivy Bridge / Haswell / Exynos models.

Run:  PYTHONPATH=src python examples/overhead_analysis.py
"""
import sys

sys.path.insert(0, "src")

from repro.core import PLATFORMS, bulk_oracle, run_config


def main():
    for plat_name, labels in [("ivy", ["3+1", "4+1"]),
                              ("haswell", ["3+1", "4+1"]),
                              ("exynos", ["3+1", "4+1", "7+1", "8+1"])]:
        plat = PLATFORMS[plat_name]
        base = bulk_oracle(plat, "3+1")
        print(f"\n=== {plat_name} (normalized to Bulk-Oracle 3+1) ===")
        print(f"{'config':24s} {'time':>6s} {'energy':>7s} {'EDP':>6s} "
              f"{'O_td':>6s} {'O_kl':>6s} {'O_hd':>6s}")
        for lbl in labels:
            for mode, kw in [("bulk-oracle", {}),
                             ("dynamic", {}),
                             ("dynamic-pri", {"priority": True}),
                             ("dynamic-async2", {"async_depth": 2})]:
                if mode == "bulk-oracle":
                    r = bulk_oracle(plat, lbl)
                else:
                    r = run_config(plat, lbl, **kw)
                ov = r.overheads
                print(f"{mode + ' ' + lbl:24s} "
                      f"{r.time_ms / base.time_ms:6.3f} "
                      f"{r.energy.total_j / base.energy.total_j:7.3f} "
                      f"{r.edp / base.edp:6.3f} "
                      f"{ov['O_td'] * 100:5.1f}% "
                      f"{ov['O_kl'] * 100:5.1f}% "
                      f"{ov['O_hd'] * 100:5.1f}%")


if __name__ == "__main__":
    main()
