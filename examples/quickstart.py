"""Quickstart: the paper's Dynamic scheduler in 60 lines.

Schedules a 20k-iteration parallel loop across one "accelerator" group and
two CPU groups (one deliberately slow), prints the throughput-proportional
split and the §3.3 overhead ledger, then shows the §3.2 chunk search and the
energy/EDP report.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import sys

sys.path.insert(0, "src")

from repro.core import (DeviceKind, DynamicScheduler, EnergyModel, GroupSpec,
                        PowerSpec, SleepExecutor, search_chunk,
                        occupancy_seed)

# --- 1. device groups: one accel (fixed tuned chunk G) + two CPU groups ---
groups = {
    "tpu": GroupSpec("tpu", DeviceKind.ACCEL, fixed_chunk=512,
                     init_throughput=400_000),
    "cpu0": GroupSpec("cpu0", DeviceKind.BIG, init_throughput=100_000,
                      min_chunk=8),
    "cpu1": GroupSpec("cpu1", DeviceKind.BIG, init_throughput=100_000,
                      min_chunk=8),
}
executors = {
    "tpu": SleepExecutor(rate=400_000, t_kl=0.0005),   # 0.5ms launch cost
    "cpu0": SleepExecutor(rate=100_000),
    "cpu1": SleepExecutor(rate=50_000),                # straggler!
}

sched = DynamicScheduler(groups, executors, alpha=0.5)
res = sched.run(0, 20_000)

print(f"scheduled {res.iterations} iterations in {res.total_time:.3f}s")
print("split:", res.per_group_items)
print("measured λ:", {k: f"{v:,.0f}/s" for k, v in res.throughput.items()})
print("accel overheads (fractions of total time):")
for k, v in res.overheads["tpu"].items():
    print(f"  {k:12s} {v:.4f}")

# --- 2. the §3.2 chunk-size search (occupancy-seeded hill climb) ----------
seed = occupancy_seed(n_units=8, per_unit_quantum=16)   # = 128


def measured_throughput(chunk):      # synthetic λ(chunk) curve, peak at 512
    occ = min(1.0, chunk / 512)
    cache = 1.0 if chunk <= 512 else 1.0 / (1 + 0.4 * (chunk / 512 - 1))
    return 400_000 * occ * cache


trace = search_chunk(measured_throughput, seed)
print(f"\nchunk search: tried {[c for c, _ in trace.tried]} "
      f"-> G = {trace.best_chunk}")

# --- 3. energy / EDP ------------------------------------------------------
model = EnergyModel({"tpu": PowerSpec(200, 75), "cpu0": PowerSpec(30, 10),
                     "cpu1": PowerSpec(30, 10)})
rep = model.energy_from_records(res.total_time, res.records)
print(f"\nenergy {rep.total_j:.1f} J, EDP {rep.edp:.2f} J·s")

# split a one-shot run's bill across consumers (for the pipelined serve
# drain, TenantAccountant does this continuously with marginal energy)
bill = model.attribute(rep, {"team-a": 0.75, "team-b": 0.25})
print("attributed: " + ", ".join(f"{who} {j:.1f} J"
                                 for who, j in sorted(bill.items())))
