#!/usr/bin/env bash
# Fast contributor signal (<60s).
# Stage 1 fails fast on the scheduler/queue core (the fast unit tests for
# the persistent runtime, partitioner, and queue subsystem); stage 2 is
# the tenancy stage — a 2-tenant skewed-weight DWRR drain plus quota /
# accounting / recovery units — so multi-tenant regressions surface
# before the slow integration stages; stage 3 is the dispatch-overhead
# benchmark in its tiny --quick profile, which fails hard on a
# schedule-result mismatch between the lock-per-token and range/steal
# hot paths; stage 4 runs everything else except the slow-marked
# integration / model-compile tests.
# Full suite: `python -m pytest -q`.
set -euo pipefail
cd "$(dirname "$0")/.."
python -m pytest -q -x -m "not slow" \
  tests/test_scheduler.py tests/test_partitioner.py tests/test_queue.py \
  tests/test_dispatch_hotpath.py
python -m pytest -q -x -m "not slow" tests/test_tenancy.py
python -m benchmarks.run --quick
exec python -m pytest -q -m "not slow" \
  --ignore=tests/test_scheduler.py --ignore=tests/test_partitioner.py \
  --ignore=tests/test_queue.py --ignore=tests/test_tenancy.py "$@"
