#!/usr/bin/env bash
# Fast contributor signal (<60s): everything except the slow-marked
# integration / model-compile tests. Full suite: `python -m pytest -q`.
set -euo pipefail
cd "$(dirname "$0")/.."
exec python -m pytest -q -m "not slow" "$@"
