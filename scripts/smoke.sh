#!/usr/bin/env bash
# Fast contributor signal (<60s).
# Stage 1 fails fast on the scheduler/queue core (the fast unit tests for
# the persistent runtime, partitioner, and queue subsystem); stage 2 is
# the tenancy stage — a 2-tenant skewed-weight DWRR drain plus quota /
# accounting / recovery units — so multi-tenant regressions surface
# before the slow integration stages; stage 3 is the dispatch-overhead
# benchmark in its tiny --quick profile, which fails hard on a
# schedule-result mismatch between the lock-per-token and range/steal
# hot paths (and the telemetry-overhead ratio gate, which fails hard if
# instrumentation cost creeps back onto the hot path), checked against
# the committed BENCH_9.json snapshot so a perf regression past 3× on
# any quick-profile row fails the build; stage 4 is the
# telemetry stage — a queued serve with --metrics-out whose JSONL feed is
# validated for the key metric families; stage 5 is the preemption stage
# — a mixed-tier queued serve (express lane on) whose metrics must show
# express batches forming, then a tight-deadline serve whose metrics
# must show the deadline-miss counter firing; stage 6 is the
# idle-efficiency stage — a queued serve parked on an empty queue for
# 1.5s whose drain must accrue only fallback-timeout wakeups (the
# event-driven drain's liveness backstop, ≤ 1/fallback_s per second —
# a busy-poll regression shows up as hundreds); stage 7 is the
# federation stage — a 3-runtime queued serve with one runtime killed
# mid-drain, whose metrics must show the failover firing and gossip
# rounds accruing while every job still reaches a terminal state;
# stage 8 is the chaos stage — the composed fault drill (2 runtimes,
# gossip delay on r1 + an executor hang on r0's group + r1 killed
# outright) run through the chaos-soak harness, whose journals must
# show every job terminal with zero duplicate completions and whose
# metrics must show the injections firing; stage 9 runs everything else
# except the slow-marked integration / model-compile tests.
# Full suite: `python -m pytest -q`.
set -euo pipefail
cd "$(dirname "$0")/.."
python -m pytest -q -x -m "not slow" \
  tests/test_scheduler.py tests/test_partitioner.py tests/test_queue.py \
  tests/test_dispatch_hotpath.py
python -m pytest -q -x -m "not slow" tests/test_tenancy.py
python -m benchmarks.run --quick --check BENCH_9.json
SMOKE_TMP="$(mktemp -d)"
trap 'rm -rf "$SMOKE_TMP"' EXIT
# pytest picks src/ up from pyproject pythonpath and benchmarks.run
# inserts it itself; the serve CLI and the inline validator need it set
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
python -m repro.launch.serve --arch yi-6b --reduced --queue \
  --requests 16 --job-items 2 --tenants "gold:weight=4,free:weight=1" \
  --metrics-out "$SMOKE_TMP/metrics.jsonl" --metrics-interval 0.2 \
  --trace-out "$SMOKE_TMP/trace.json" > /dev/null
python - "$SMOKE_TMP" <<'EOF'
import json, sys
from pathlib import Path
from repro.telemetry import read_jsonl
tmp = Path(sys.argv[1])
snaps = read_jsonl(tmp / "metrics.jsonl")
assert snaps and snaps[-1]["final"] is True, "no final snapshot"
c = snaps[-1]["counters"]
for fam in ("sched.chunks", "sched.epochs_finalized", "svc.batches",
            "queue.dwrr_pops"):
    assert any(k.startswith(fam) for k in c), f"missing {fam} in {sorted(c)}"
h = snaps[-1]["histograms"]
assert any(k.startswith("sched.chunk_host_s") for k in h), "no host hist"
trace = json.loads((tmp / "trace.json").read_text())
assert any(e.get("cat") == "chunk" for e in trace["traceEvents"]), \
    "no chunk spans in trace"
print(f"telemetry smoke ok: {len(snaps)} snapshots, "
      f"{len(trace['traceEvents'])} trace events")
EOF
python -m repro.launch.serve --arch yi-6b --reduced --queue \
  --requests 16 --job-items 2 --priority mix \
  --metrics-out "$SMOKE_TMP/preempt.jsonl" --metrics-interval 0.2 \
  > /dev/null
python -m repro.launch.serve --arch yi-6b --reduced --queue \
  --requests 16 --job-items 2 --deadline-ms 0.5 \
  --metrics-out "$SMOKE_TMP/deadline.jsonl" --metrics-interval 0.2 \
  > /dev/null
python - "$SMOKE_TMP" <<'EOF'
import sys
from pathlib import Path
from repro.telemetry import read_jsonl
tmp = Path(sys.argv[1])
c = read_jsonl(tmp / "preempt.jsonl")[-1]["counters"]
express = sum(v for k, v in c.items() if k.startswith("svc.express_batches"))
assert express > 0, f"mixed-tier serve formed no express batches: {sorted(c)}"
c = read_jsonl(tmp / "deadline.jsonl")[-1]["counters"]
misses = sum(v for k, v in c.items() if k.startswith("svc.deadline_misses"))
assert misses > 0, f"0.5ms-deadline serve missed no deadlines: {sorted(c)}"
print(f"preemption smoke ok: {express:.0f} express batches, "
      f"{misses:.0f} deadline misses")
EOF
python -m repro.launch.serve --arch yi-6b --reduced --queue \
  --requests 8 --job-items 2 --idle-s 1.5 \
  --metrics-out "$SMOKE_TMP/idle.jsonl" --metrics-interval 0.2 \
  > /dev/null
python - "$SMOKE_TMP" <<'EOF'
import sys
from pathlib import Path
from repro.telemetry import read_jsonl
c = read_jsonl(Path(sys.argv[1]) / "idle.jsonl")[-1]["counters"]
timeouts = sum(v for k, v in c.items()
               if k.startswith("svc.drain_wakeups") and "timeout" in k)
events = sum(v for k, v in c.items()
             if k.startswith("svc.drain_wakeups") and "event" in k)
# 1.5s idle + the serve itself: an event-driven drain times out at most
# once per fallback_s (2s) plus a couple of bounded run_until_idle waits
assert timeouts <= 5, \
    f"idle drain busy-polling: {timeouts:.0f} timeout wakeups " \
    f"(event wakeups: {events:.0f})"
assert events > 0, "drain never woke on an event"
print(f"idle-efficiency smoke ok: {events:.0f} event wakeups, "
      f"{timeouts:.0f} fallback timeouts over a 1.5s idle tail")
EOF
python -m repro.launch.serve --arch yi-6b --reduced --queue \
  --requests 24 --job-items 2 --runtimes 3 --kill-runtime 1 \
  --journal-dir "$SMOKE_TMP/fedjournal" \
  --metrics-out "$SMOKE_TMP/fed.jsonl" --metrics-interval 0.1 \
  > "$SMOKE_TMP/fed-report.json"
python - "$SMOKE_TMP" <<'EOF'
import json, sys
from pathlib import Path
from repro.telemetry import read_jsonl
tmp = Path(sys.argv[1])
# stdout holds the fed report followed by the telemetry summary doc
rep = json.JSONDecoder().raw_decode((tmp / "fed-report.json").read_text())[0]
terminal = rep["done"] + rep["failed"] + rep["cancelled"]
assert terminal == rep["jobs"], \
    f"non-terminal jobs after federated drain: {rep['jobs'] - terminal}"
assert rep["killed"] == ["r1"] and rep["failovers"] >= 1, \
    f"kill drill did not fire: {rep}"
c = read_jsonl(tmp / "fed.jsonl")[-1]["counters"]
for fam in ("fed.failovers", "fed.gossip_rounds"):
    assert any(k.startswith(fam) for k in c), \
        f"missing {fam} in {sorted(k for k in c if k.startswith('fed'))}"
print(f"federation smoke ok: {rep['jobs']} jobs terminal across "
      f"{rep['runtimes']} runtimes, killed={rep['killed']}, "
      f"recovered={rep['recovered']}, "
      f"gossip_rounds={rep['gossip_rounds']}")
EOF
python -m benchmarks.chaos_soak --composed \
  --journal-dir "$SMOKE_TMP/chaosjournal" \
  --metrics-out "$SMOKE_TMP/chaos.jsonl" > "$SMOKE_TMP/chaos-report.json"
python - "$SMOKE_TMP" <<'EOF'
import json, sys
from pathlib import Path
from repro.telemetry import read_jsonl
tmp = Path(sys.argv[1])
rep = json.loads((tmp / "chaos-report.json").read_text())
terminal = rep["done"] + rep["failed"] + rep["cancelled"]
assert terminal == rep["jobs"], \
    f"non-terminal jobs after chaos drill: {rep['jobs'] - terminal}"
assert rep["kills"] == 1, f"kill fault never fired: {rep}"
# zero duplicate completions across the primaries: the failover replay
# dedup guard under composed gossip-delay + hang + kill
done = {}
for p in (tmp / "chaosjournal").glob("*.journal.jsonl"):
    for line in p.read_text().splitlines():
        try:
            r = json.loads(line)
        except ValueError:
            continue            # chaos corruption artifact
        if r.get("event") == "done":
            jid = r["job"]["job_id"]
            done[jid] = done.get(jid, 0) + 1
dupes = {j: c for j, c in done.items() if c > 1}
assert not dupes, f"duplicate completions: {dupes}"
c = read_jsonl(tmp / "chaos.jsonl")[-1]["counters"]
injected = sum(v for k, v in c.items() if k.startswith("chaos.injected"))
assert injected >= 3, \
    f"composed plan under-injected: {injected} of 3 faults " \
    f"({sorted(k for k in c if k.startswith('chaos'))})"
assert any(k.startswith("fed.failovers") for k in c), "no failover counted"
print(f"chaos smoke ok: {rep['jobs']} jobs terminal, "
      f"{injected:.0f} faults injected, {len(done)} unique completions, "
      f"dupes=0, recovery_s={rep['recovery_s']:.3f}")
EOF
exec python -m pytest -q -m "not slow" \
  --ignore=tests/test_scheduler.py --ignore=tests/test_partitioner.py \
  --ignore=tests/test_queue.py --ignore=tests/test_tenancy.py "$@"
