"""Validation against the paper's own claims (calibrated simulator).

Each test names the paper section/figure it validates. Tolerances are stated
per claim; deviations are also tabulated in EXPERIMENTS.md §Paper-validation.
Note the paper's Haswell EDP claim (−84%) is internally inconsistent with its
own time/energy claims (−37%, −33% ⇒ EDP −58%); we assert the consistent
derivation and document the discrepancy.
"""
import pytest

from repro.core import EXYNOS, HASWELL, IVY, bulk_oracle, run_config


@pytest.fixture(scope="module")
def sims():
    out = {}
    for plat in (IVY, HASWELL, EXYNOS):
        labels = ["3+1", "4+1"] + (["7+1", "8+1"] if plat.n_little else [])
        for lbl in labels:
            out[(plat.name, "dyn", lbl)] = run_config(plat, lbl)
            out[(plat.name, "pri", lbl)] = run_config(plat, lbl,
                                                      priority=True)
            out[(plat.name, "bulk", lbl)] = bulk_oracle(plat, lbl)
        out[(plat.name, "async", "4+1")] = run_config(plat, "4+1",
                                                      async_depth=2)
    return out


# ---- §4.2 / Fig. 5: overhead magnitudes ---------------------------------

def test_otd_dominates_under_rr_oversubscription(sims):
    # paper: 22% (Ivy) and 33% (Haswell) of total time at 4+1 under Windows
    assert sims[("ivy", "dyn", "4+1")].overheads["O_td"] == \
        pytest.approx(0.22, abs=0.06)
    assert sims[("haswell", "dyn", "4+1")].overheads["O_td"] == \
        pytest.approx(0.33, abs=0.07)


def test_otd_negligible_without_oversubscription(sims):
    for p in ("ivy", "haswell"):
        assert sims[(p, "dyn", "3+1")].overheads["O_td"] < 0.02


def test_otd_negligible_under_linux(sims):
    # paper: <0.09% on Exynos in all cases (Linux wake boost)
    assert sims[("exynos", "dyn", "4+1")].overheads["O_td"] < 0.03


def test_exynos_transfer_overheads_order_of_magnitude_higher(sims):
    # paper: O_hd=2.8%, O_dh=1.6% on Exynos vs <0.3% on the Intel boxes
    exy = sims[("exynos", "dyn", "4+1")].overheads
    ivy = sims[("ivy", "dyn", "4+1")].overheads
    assert exy["O_hd"] == pytest.approx(0.028, abs=0.012)
    assert exy["O_dh"] == pytest.approx(0.016, abs=0.008)
    assert ivy["O_hd"] < 0.003
    assert exy["O_hd"] > 5 * ivy["O_hd"]


def test_osp_is_smallest_overhead(sims):
    for p in ("ivy", "haswell", "exynos"):
        ov = sims[(p, "dyn", "4+1")].overheads
        assert ov["O_sp"] <= min(ov["O_hd"] + 1e-9, ov["O_kl"] + 1e-9)


# ---- §2 / Fig. 2: Dynamic vs Bulk-Oracle --------------------------------

def test_dynamic_beats_bulk_except_haswell_4p1(sims):
    # paper: "the dynamic strategy outperforms the static one except in the
    # case of Haswell for 4+1"
    assert sims[("ivy", "dyn", "3+1")].time_ms \
        < sims[("ivy", "bulk", "3+1")].time_ms * 1.02
    assert sims[("exynos", "dyn", "4+1")].time_ms \
        < sims[("exynos", "bulk", "4+1")].time_ms * 1.02
    assert sims[("haswell", "dyn", "4+1")].time_ms \
        > sims[("haswell", "bulk", "4+1")].time_ms


def test_ivy_oversubscription_faster_but_more_energy(sims):
    # paper §2: on Ivy, Dynamic 4+1 is faster than 3+1 but uses more energy
    d3, d4 = sims[("ivy", "dyn", "3+1")], sims[("ivy", "dyn", "4+1")]
    assert d4.time_ms < d3.time_ms
    assert d4.energy.total_j > d3.energy.total_j


# ---- §5.1 / Fig. 6: Dynamic Pri -----------------------------------------

def test_pri_removes_otd(sims):
    assert sims[("ivy", "pri", "4+1")].overheads["O_td"] < 0.02
    assert sims[("haswell", "pri", "4+1")].overheads["O_td"] < 0.02


def test_pri_edp_reduction_ivy(sims):
    # paper: time/energy/EDP −10%/−7%/−18% on Ivy (4+1)
    d, p = sims[("ivy", "dyn", "4+1")], sims[("ivy", "pri", "4+1")]
    assert 1 - p.time_ms / d.time_ms == pytest.approx(0.10, abs=0.05)
    assert 1 - p.energy.total_j / d.energy.total_j == \
        pytest.approx(0.07, abs=0.05)
    assert 1 - p.edp / d.edp == pytest.approx(0.18, abs=0.08)


def test_pri_edp_reduction_haswell(sims):
    # paper: −37%/−33% time/energy ⇒ EDP −58% (the quoted −84% is
    # inconsistent with the quoted time/energy; see module docstring)
    d, p = sims[("haswell", "dyn", "4+1")], sims[("haswell", "pri", "4+1")]
    assert 1 - p.time_ms / d.time_ms == pytest.approx(0.37, abs=0.17)
    assert 1 - p.edp / d.edp == pytest.approx(0.50, abs=0.20)


def test_pri_noop_without_oversubscription(sims):
    # paper: "boosting priority has almost no impact for 3+1"
    d, p = sims[("ivy", "dyn", "3+1")], sims[("ivy", "pri", "3+1")]
    assert p.time_ms == pytest.approx(d.time_ms, rel=0.02)


def test_async_dispatch_subsumes_priority(sims):
    # beyond-paper: depth-2 dispatch-ahead ≥ as good as the priority fix
    pri = sims[("haswell", "pri", "4+1")]
    asy = sims[("haswell", "async", "4+1")]
    assert asy.time_ms <= pri.time_ms * 1.02
    assert asy.overheads["O_td"] < 0.02


# ---- §5.2 / Fig. 7: big.LITTLE ------------------------------------------

def test_biglittle_gains(sims):
    # paper: Dynamic 8+1 vs Dynamic 4+1: time −22%, energy −19%, EDP −46%
    d4, d8 = sims[("exynos", "dyn", "4+1")], sims[("exynos", "dyn", "8+1")]
    assert 1 - d8.time_ms / d4.time_ms == pytest.approx(0.22, abs=0.08)
    assert 1 - d8.energy.total_j / d4.energy.total_j == \
        pytest.approx(0.19, abs=0.08)
    assert 1 - d8.edp / d4.edp == pytest.approx(0.46, abs=0.12)


def test_biglittle_pri_edp_headline(sims):
    # paper headline: Dynamic Pri 8+1 reduces EDP by 57% w.r.t. Dynamic 4+1.
    # Our model reproduces the big.LITTLE component (−46%±) but not the full
    # extra Pri-under-GTS gain (the paper's own component claims compound to
    # ~50%, and the CFS/GTS interaction behind the remainder is outside the
    # wake-delay model) — so we assert the reproducible band and record the
    # deviation in EXPERIMENTS.md §Paper-validation.
    d4 = sims[("exynos", "dyn", "4+1")]
    p8 = sims[("exynos", "pri", "8+1")]
    gain = 1 - p8.edp / d4.edp
    assert 0.35 <= gain <= 0.60
    # and Pri at 8+1 must not be worse than plain Dynamic 8+1
    assert p8.edp <= sims[("exynos", "dyn", "8+1")].edp * 1.01


def test_a7_energy_an_order_below_a15(sims):
    r = sims[("exynos", "dyn", "8+1")]
    per = r.energy.per_group_j
    assert per["little"] < per["big"] / 4
