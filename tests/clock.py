"""Deterministic virtual clock for timing-sensitive tests.

Real ``time.sleep`` in tests buys flakiness: an assertion like "the
urgent job was served within 0.2 s" races the host's load. VirtualClock
replaces both the clock *and* the sleep with a shared virtual timeline:

- ``now()`` returns virtual seconds (starts at 0.0).
- ``sleep(dt)`` registers the caller as a sleeper and blocks until the
  virtual time reaches ``now() + dt``. Crucially, a sleeper *advances*
  the clock itself when it holds the **earliest** pending wake-up — so a
  set of threads that are all sleeping make progress deterministically,
  in wake-up order, with no wall-clock dependence.
- ``advance(dt)`` force-advances the timeline (for drivers that never
  sleep themselves).

Every component in the runtime takes a clock/sleep seam
(``DynamicScheduler(clock=...)``, ``SleepExecutor(clock=..., sleep=...)``,
``JobService(clock=..., sleep=...)``, ``repro.queue.job.now``), so a test
can pin the whole stack to one virtual timeline and assert *exact*
timestamps.

The ``cond.wait(0.05)`` in the sleeper loop is a liveness backstop, not a
timing dependence: when some thread is busy between sleeps (e.g. holding
the minimum wake but still executing), the other sleepers re-check
periodically instead of deadlocking on a missed notify.
"""
from __future__ import annotations

import itertools
import threading
from typing import Dict


class VirtualClock:
    def __init__(self, start: float = 0.0):
        self._t = float(start)
        self._cond = threading.Condition()
        self._sleepers: Dict[int, float] = {}
        self._ids = itertools.count()

    def now(self) -> float:
        with self._cond:
            return self._t

    def advance(self, dt: float) -> float:
        """Force the timeline forward by ``dt`` virtual seconds."""
        with self._cond:
            self._t += float(dt)
            self._cond.notify_all()
            return self._t

    def sleep(self, dt: float) -> None:
        if dt <= 0:
            return
        with self._cond:
            sid = next(self._ids)
            wake = self._t + float(dt)
            self._sleepers[sid] = wake
            self._cond.notify_all()
            try:
                while self._t < wake:
                    # advance time ourselves only while we hold the
                    # earliest pending wake-up — later sleepers must not
                    # leapfrog an earlier one
                    if wake <= min(self._sleepers.values()):
                        self._t = wake
                        self._cond.notify_all()
                        break
                    self._cond.wait(0.05)
            finally:
                del self._sleepers[sid]
                self._cond.notify_all()

    def sleeping(self) -> int:
        """Number of threads currently blocked in ``sleep`` (test
        introspection)."""
        with self._cond:
            return len(self._sleepers)
