"""Unit tests: eqs. (1)–(2) throughput and eqs. (5)–(9) overhead ledger."""
import pytest

from repro.core import (Chunk, ChunkRecord, DeviceKind, OverheadLedger,
                        ThroughputTracker, Token)


def rec(group="g", size=100, tc1=0.0, tc2=0.01, tc3=1.2,
        tg1=0.02, tg2=0.05, tg3=0.10, tg4=1.0, tg5=1.1,
        kind=DeviceKind.ACCEL):
    return ChunkRecord(Token(Chunk(0, size), group, kind),
                       tc1=tc1, tc2=tc2, tc3=tc3, tg1=tg1, tg2=tg2,
                       tg3=tg3, tg4=tg4, tg5=tg5)


def test_throughput_eq_1():
    r = rec(size=540)
    # λ = G / T(tG_i) with T = Tg5 − Tg1 (includes transfers, footnote 1)
    assert r.throughput == pytest.approx(540 / (1.1 - 0.02))


def test_ewma_alpha_one_is_paper_faithful():
    tr = ThroughputTracker(alpha=1.0)
    tr.update(rec(size=100, tg1=0.0, tg5=1.0))    # λ=100
    tr.update(rec(size=300, tg1=0.0, tg5=1.0))    # λ=300
    assert tr.get("g") == pytest.approx(300)      # previous interval only


def test_ewma_smoothing():
    tr = ThroughputTracker(alpha=0.5)
    tr.update(rec(size=100, tg1=0.0, tg5=1.0))
    tr.update(rec(size=300, tg1=0.0, tg5=1.0))
    assert tr.get("g") == pytest.approx(200)


def test_overhead_fractions_eqs_5_to_9():
    led = OverheadLedger()
    led.add(rec())
    tot = 2.0
    f = led.report(tot, "g")
    assert f["O_sp"] == pytest.approx((0.01 - 0.0) / tot)
    assert f["O_hd"] == pytest.approx((0.05 - 0.02) / tot)
    assert f["O_kl"] == pytest.approx((0.10 - 0.05) / tot)
    assert f["O_dh"] == pytest.approx((1.1 - 1.0) / tot)
    # O_td = (Tc3−Tc2) − (Tg5−Tg1)
    assert f["O_td"] == pytest.approx(((1.2 - 0.01) - (1.1 - 0.02)) / tot)
    assert f["n_chunks"] == 1


def test_ledger_aggregates_groups():
    led = OverheadLedger()
    led.add(rec(group="a"))
    led.add(rec(group="b"))
    assert led.totals().n_chunks == 2
    assert set(led.groups()) == {"a", "b"}
