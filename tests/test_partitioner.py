"""Unit tests: §3.2 partitioning policy (eqs. 3–4)."""
import pytest

from repro.core import (DeviceKind, GroupSpec, HeterogeneousPartitioner,
                        IterationSpace, ThroughputTracker)


def make(groups, n=10_000, alpha=1.0):
    tr = ThroughputTracker(alpha)
    space = IterationSpace(0, n)
    return HeterogeneousPartitioner(space, groups, tr), tr, space


def test_accel_gets_fixed_chunk():
    p, tr, _ = make({"a": GroupSpec("a", DeviceKind.ACCEL, fixed_chunk=640)})
    tok = p.next_token("a")
    assert tok.chunk.size == 640
    assert tok.is_accel


def test_cpu_chunk_is_lambda_proportional():
    groups = {
        "a": GroupSpec("a", DeviceKind.ACCEL, fixed_chunk=1536,
                       init_throughput=75.0),
        "c": GroupSpec("c", DeviceKind.BIG, init_throughput=25.0),
    }
    p, tr, _ = make(groups)
    tok = p.next_token("c")
    # eq. (4): C = G·λ_C/λ_G = 1536·25/75 = 512
    assert tok.chunk.size == 512


def test_min_chunk_respected():
    groups = {
        "a": GroupSpec("a", DeviceKind.ACCEL, fixed_chunk=1000,
                       init_throughput=1000.0),
        "c": GroupSpec("c", DeviceKind.BIG, init_throughput=0.001,
                       min_chunk=17),
    }
    p, _, _ = make(groups)
    assert p.next_token("c").chunk.size == 17


def test_final_chunk_shrinks_to_exhaust():
    p, _, space = make(
        {"a": GroupSpec("a", DeviceKind.ACCEL, fixed_chunk=640)}, n=1000)
    sizes = []
    while True:
        t = p.next_token("a")
        if t is None:
            break
        sizes.append(t.chunk.size)
    assert sum(sizes) == 1000
    assert sizes == [640, 360]


def test_elastic_add_remove():
    groups = {"a": GroupSpec("a", DeviceKind.ACCEL, fixed_chunk=100,
                             init_throughput=10.0)}
    p, tr, _ = make(groups)
    p.add_group(GroupSpec("new", DeviceKind.LITTLE, init_throughput=5.0))
    tok = p.next_token("new")
    assert tok.chunk.size == 50          # 100 · 5/10
    p.remove_group("new")
    assert p.next_token("new") is None


def test_requeue_restores_work():
    p, _, space = make(
        {"a": GroupSpec("a", DeviceKind.ACCEL, fixed_chunk=600)}, n=600)
    tok = p.next_token("a")
    assert space.remaining == 0
    p.requeue(tok.chunk)
    assert space.remaining == 600
    tok2 = p.next_token("a")
    assert tok2.chunk.size == 600
