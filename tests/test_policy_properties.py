"""Hypothesis property tests for the adaptive-policy layer.

Mirrors tests/test_policy.py with generated inputs:
- sharded λ-tracker merged stats ≡ single-lock oracle for any per-group
  record sequence split across writer threads (the scheduler's
  single-writer-per-group invariant);
- sliding-window invariants: quantiles bounded by windowed min/max and
  monotone in q; EWMA converges to a constant tail;
- rebalance cooldown never starves a persistently-proposed change.

Skipped wholesale when hypothesis is not installed (repo convention —
see tests/test_properties.py).
"""
import threading

import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import (Chunk, ChunkRecord, DeviceKind,
                        LockedThroughputTracker, ThroughputTracker, Token)
from repro.policy import AdaptivePolicy, SlidingWindow


def _rec(group, size, t0, t1):
    return ChunkRecord(Token(Chunk(0, size), group, DeviceKind.BIG),
                       tg1=t0, tg5=t1, tc1=t0, tc2=t0, tc3=t1)


def _feed(tracker, group, lams):
    t = 0.0
    for lam in lams:
        dt = 8 / lam
        tracker.update(_rec(group, 8, t, t + dt))
        t += dt


lam_seqs = st.lists(st.floats(0.5, 1e4, allow_nan=False), min_size=1,
                    max_size=30)


@settings(max_examples=25, deadline=None)
@given(
    per_group=st.dictionaries(
        st.sampled_from(["g0", "g1", "g2", "g3"]), lam_seqs,
        min_size=1, max_size=4),
    alpha=st.sampled_from([1.0, 0.7, 0.3]),
)
def test_sharded_tracker_equiv_locked_any_single_writer_interleaving(
        per_group, alpha):
    shard, oracle = ThroughputTracker(alpha), \
        LockedThroughputTracker(alpha)
    for g, lams in per_group.items():
        _feed(oracle, g, lams)
    threads = [threading.Thread(target=_feed, args=(shard, g, lams))
               for g, lams in per_group.items()]
    for th in threads:
        th.start()
    for th in threads:
        th.join()
    for g in per_group:
        a, b = shard.stats(g), oracle.stats(g)
        assert a.n == b.n
        assert a.total_items == b.total_items
        assert abs(a.total_time - b.total_time) <= 1e-9 * max(
            1.0, b.total_time)
        assert abs(a.ewma - b.ewma) <= 1e-6 * max(1.0, abs(b.ewma))
        assert a.last == b.last


@settings(max_examples=50, deadline=None)
@given(
    samples=st.lists(
        st.tuples(st.floats(0.0, 100.0, allow_nan=False),
                  st.floats(-1e6, 1e6, allow_nan=False)),
        min_size=1, max_size=60),
    horizon=st.floats(0.1, 50.0, allow_nan=False),
    q=st.floats(0.0, 1.0, allow_nan=False),
)
def test_window_quantile_bounded_by_extremes(samples, horizon, q):
    w = SlidingWindow(horizon_s=horizon)
    for t, v in sorted(samples):
        w.observe(t, v)
    if w.count:
        assert w.min() <= w.quantile(q) <= w.max()
        assert w.min() <= w.mean() <= w.max()
        qs = [w.quantile(x / 10.0) for x in range(11)]
        assert qs == sorted(qs)


@settings(max_examples=30, deadline=None)
@given(
    head=st.lists(st.floats(-1e3, 1e3, allow_nan=False), max_size=20),
    target=st.floats(-100.0, 100.0, allow_nan=False),
    alpha=st.floats(0.05, 1.0, allow_nan=False),
)
def test_window_ewma_converges_to_constant_tail(head, target, alpha):
    w = SlidingWindow(horizon_s=1e9, alpha=alpha)
    t = 0.0
    for v in head:
        w.observe(t, v)
        t += 1.0
    for _ in range(400):
        w.observe(t, target)
        t += 1.0
    assert abs(w.ewma - target) <= 1e-3 * max(1.0, abs(target)) + 1e-6


@settings(max_examples=40, deadline=None)
@given(
    points=st.lists(st.floats(0.0, 2.0, allow_nan=False), min_size=1,
                    max_size=50),
    slo=st.floats(0.1, 1.5, allow_nan=False),
)
def test_admission_estimate_latch_consistency(points, slo):
    """Two gate invariants for any sample sequence: the smoothed
    estimate never discounts the point sample, and the latch state
    after a call is exactly (estimate > slo)."""
    p = AdaptivePolicy(window_s=1.0, alpha=0.5, hysteresis=0.1,
                       recovery_q=0.9)
    t = 0.0
    for v in points:
        est = p.admission_delay(t, v, slo=slo)
        assert est >= v
        assert (est > slo) == bool(p.stats()["deferring"])
        t += 0.05


@settings(max_examples=30, deadline=None)
@given(
    cooldown=st.floats(0.0, 5.0, allow_nan=False),
    tick=st.floats(0.01, 1.0, allow_nan=False),
    first_at=st.floats(0.0, 3.0, allow_nan=False),
)
def test_cooldown_never_starves_persistent_change(cooldown, tick,
                                                  first_at):
    p = AdaptivePolicy(cooldown_s=cooldown)
    assert p.allow_rebalance(first_at, {"g": 0.5}, {})
    t, applied = first_at + tick, None
    while t < first_at + cooldown + 2 * tick + 1e-9:
        if p.allow_rebalance(t, {"g": 0.2}, {"g": 0.5}):
            applied = t
            break
        t += tick
    assert applied is not None
    assert applied <= first_at + cooldown + tick + 1e-6
