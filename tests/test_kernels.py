"""Pallas kernel validation: shape/dtype sweeps vs the pure-jnp oracles,
executed in interpret mode on CPU (the kernels' TPU lowering target is
documented in each kernel header)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.flash_decode import flash_decode
from repro.kernels.ssd_scan import ssd_scan_kernel

KEY = jax.random.PRNGKey(3)


def tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("b,h,kvh,s,d", [
    (1, 4, 4, 128, 32),     # MHA
    (2, 8, 2, 128, 64),     # GQA 4:1
    (1, 8, 1, 256, 64),     # MQA
    (2, 4, 2, 64, 128),     # wide head
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_sweep(b, h, kvh, s, d, dtype, causal):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b * h, s, d), dtype)
    k = jax.random.normal(ks[1], (b * kvh, s, d), dtype)
    v = jax.random.normal(ks[2], (b * kvh, s, d), dtype)
    out = flash_attention(q, k, v, causal=causal, block_q=64, block_k=64,
                          n_heads=h, n_kv_heads=kvh, interpret=True)
    exp = ref.flash_attention_ref(q, k, v, causal=causal, n_heads=h,
                                  n_kv_heads=kvh)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32), **tol(dtype))


@pytest.mark.parametrize("blocks", [(32, 128), (128, 32), (128, 128)])
def test_flash_attention_block_shape_invariance(blocks):
    bq, bk = blocks
    q = jax.random.normal(KEY, (4, 256, 64))
    k = jax.random.normal(KEY, (2, 256, 64))
    v = jax.random.normal(KEY, (2, 256, 64))
    out = flash_attention(q, k, v, causal=True, block_q=bq, block_k=bk,
                          n_heads=2, n_kv_heads=1, interpret=True)
    exp = ref.flash_attention_ref(q, k, v, causal=True, n_heads=2,
                                  n_kv_heads=1)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("b,h,kvh,S,d", [
    (2, 4, 4, 256, 32),
    (2, 8, 2, 512, 64),
    (1, 4, 1, 128, 128),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_decode_sweep(b, h, kvh, S, d, dtype):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b * h, d), dtype)
    k = jax.random.normal(ks[1], (b * kvh, S, d), dtype)
    v = jax.random.normal(ks[2], (b * kvh, S, d), dtype)
    kv_len = jnp.asarray(
        np.random.default_rng(0).integers(1, S + 1, b), jnp.int32)
    out = flash_decode(q, k, v, kv_len, block_k=64, n_heads=h,
                       n_kv_heads=kvh, interpret=True)
    exp = ref.flash_decode_ref(q, k, v, kv_len, n_heads=h, n_kv_heads=kvh)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32), **tol(dtype))


@pytest.mark.parametrize("BH,S,P,N,Q", [
    (2, 128, 32, 16, 32),
    (4, 256, 64, 64, 128),
    (3, 96, 16, 8, 32),
])
def test_ssd_scan_sweep(BH, S, P, N, Q):
    ks = jax.random.split(KEY, 5)
    x = jax.random.normal(ks[0], (BH, S, P)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (BH, S)))
    A = -jnp.exp(jax.random.normal(ks[2], (BH,)) * 0.3)
    B = jax.random.normal(ks[3], (BH, S, N)) * 0.5
    C = jax.random.normal(ks[4], (BH, S, N)) * 0.5
    out = ssd_scan_kernel(x, dt, A, B, C, chunk=Q, interpret=True)
    exp = ref.ssd_scan_ref(x, dt, A, B, C)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               rtol=2e-4, atol=2e-4)


def test_ops_wrappers_match_model_layout():
    from repro.kernels.ops import attention_bshd, ssd_bshn
    from repro.models.attention import (chunked_attention,
                                        group_query_heads, ungroup_heads)
    b, s, h, kvh, d = 2, 64, 4, 2, 32
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, s, h, d))
    k = jax.random.normal(ks[1], (b, s, kvh, d))
    v = jax.random.normal(ks[2], (b, s, kvh, d))
    out = attention_bshd(q, k, v, n_heads=h, n_kv_heads=kvh, block_q=32,
                         block_k=32, interpret=True)
    exp = ungroup_heads(chunked_attention(
        group_query_heads(q, kvh), k, v, causal=True, q_chunk=32,
        kv_chunk=32))
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               rtol=2e-5, atol=2e-5)
