"""Tests for repro.telemetry: sharded registry merge semantics, span
tracer determinism, exporters, the always-on component wiring, and the
torn-snapshot fixes in throughput/overheads introspection.

The hypothesis-based shard-merge properties live in
tests/test_telemetry_properties.py (skipped when hypothesis is absent);
everything here is deterministic and runs in the fast suite.
"""
import json
import threading
import time

import pytest

from repro import telemetry as telemetry_mod
from repro.core import (ChunkRecord, DeviceKind, DynamicScheduler,
                        GroupSpec, SleepExecutor)
from repro.core.overheads import OverheadLedger
from repro.core.throughput import ThroughputTracker
from repro.core.types import Chunk, Token
from repro.queue import Job, JobService
from repro.telemetry import (MetricsExporter, MetricsRegistry, OFF,
                             SpanTracer, Telemetry, prometheus_text,
                             read_jsonl, resolve)


# ---------------------------------------------------------------------------
# registry: sharded merge semantics
# ---------------------------------------------------------------------------

def _in_threads(n, fn):
    threads = [threading.Thread(target=fn, args=(i,)) for i in range(n)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()


def test_counter_merges_across_thread_shards():
    reg = MetricsRegistry()
    c = reg.counter("hits")

    def work(i):
        for _ in range(1000):
            c.add(1)

    _in_threads(4, work)
    assert c.value() == 4000
    snap = reg.snapshot()
    assert snap["counters"]["hits"] == 4000


def test_counter_labels_are_distinct_series():
    reg = MetricsRegistry()
    reg.counter("jobs", tenant="a").add(2)
    reg.counter("jobs", tenant="b").add(3)
    snap = reg.snapshot()["counters"]
    assert snap['jobs{tenant="a"}'] == 2
    assert snap['jobs{tenant="b"}'] == 3


def test_gauge_last_write_wins_across_threads():
    reg = MetricsRegistry()
    g = reg.gauge("depth")
    g.set(1.0)

    def work(i):
        g.set(10.0 + i)

    _in_threads(2, work)
    g.set(99.0)                      # highest global sequence number
    assert g.value() == 99.0


def test_histogram_merge_equals_single_shard_ingest():
    values = [0.00001 * (i + 1) for i in range(400)] + [0.0, -1.0, 5.0]
    ref = MetricsRegistry().histogram("ref")
    for v in values:
        ref.observe(v)

    sharded = MetricsRegistry().histogram("sharded")
    quarters = [values[i::4] for i in range(4)]

    def work(i):
        for v in quarters[i]:
            sharded.observe(v)

    _in_threads(4, work)
    a, b = ref.merged(), sharded.merged()
    assert a["buckets"] == b["buckets"]
    assert a["count"] == b["count"] == len(values)
    assert a["min"] == b["min"] and a["max"] == b["max"]
    assert a["sum"] == pytest.approx(b["sum"])


def test_histogram_quantile_error_bound():
    # log-bucketed with growth 2**0.25: a quantile comes back as its
    # bucket's upper bound, within 2**0.25 - 1 (~19%) above the true value
    reg = MetricsRegistry()
    h = reg.histogram("lat")
    values = [1e-6 * (1.19 ** i) for i in range(200)]
    for v in values:
        h.observe(v)
    for q in (0.5, 0.95, 0.99):
        true = sorted(values)[int(q * (len(values) - 1))]
        est = h.quantile(q)
        assert true <= est * 1.0000001
        assert est <= true * (2 ** 0.25) * 1.0000001
    # quantiles clamp to observed extremes
    assert h.quantile(0.0) >= min(values)
    assert h.quantile(1.0) <= max(values)


def test_histogram_nonpositive_values_bucketed():
    reg = MetricsRegistry()
    h = reg.histogram("x")
    h.observe(0.0)
    h.observe(-3.0)
    h.observe(1.0)
    m = h.merged()
    assert m["count"] == 3 and m["min"] == -3.0
    text = prometheus_text(reg)
    assert 'le="0"' in text and "x_count 3" in text


def test_snapshot_is_self_measuring():
    reg = MetricsRegistry()
    c = reg.counter("n")
    for _ in range(100):
        c.add(1)
    snap = reg.snapshot()
    self_ = snap["self"]
    assert self_["ops"] >= 100
    assert self_["ns_per_op"] > 0
    assert self_["est_overhead_s"] >= 0.0
    assert self_["snapshots"] == 1


def test_collectors_run_at_snapshot_and_prune_dead():
    reg = MetricsRegistry()

    class Src:
        def collect(self):
            reg.gauge("live").set(7.0)

    src = Src()
    reg.add_collector(src.collect)
    assert reg.snapshot()["gauges"]["live"] == 7.0
    del src
    reg.snapshot()                   # dead weakref pruned, no error


# ---------------------------------------------------------------------------
# span tracer
# ---------------------------------------------------------------------------

def _record(group="g0", seq=0, size=8, base=100.0):
    rec = ChunkRecord(token=Token(Chunk(0, size, seq), group,
                                  DeviceKind.BIG))
    rec.tc1 = base
    rec.tc2 = base + 0.001
    rec.tg1 = base + 0.002
    rec.tg2 = base + 0.003
    rec.tg3 = base + 0.004
    rec.tg4 = base + 0.005
    rec.tg5 = base + 0.006
    rec.tc3 = base + 0.007
    return rec


def test_sampling_is_deterministic_by_seq():
    a = SpanTracer(sample_rate=0.5)
    b = SpanTracer(sample_rate=0.5)
    picks_a = [a.sampled(i) for i in range(1000)]
    picks_b = [b.sampled(i) for i in range(1000)]
    assert picks_a == picks_b
    assert 300 < sum(picks_a) < 700          # roughly the requested rate
    assert all(SpanTracer(sample_rate=1.0).sampled(i) for i in range(50))
    assert not any(SpanTracer(sample_rate=0.0).sampled(i)
                   for i in range(50))


def test_tracer_ring_is_bounded_and_counts_drops():
    tr = SpanTracer(max_events=10)
    for i in range(25):
        tr.instant("e", ts=float(i))
    assert len(tr) == 10
    assert tr.emitted == 25 and tr.dropped == 15


def test_epoch_tags_attach_to_chunk_spans():
    tr = SpanTracer()
    tr.tag_epoch(3, {"tenants": {"gold": 8}})
    tr.chunk(_record(seq=1), epoch=3)
    ev = [e for e in tr.chrome_events() if e.get("cat") == "chunk"]
    assert len(ev) == 1
    assert ev[0]["args"]["tenants"] == {"gold": 8}
    assert ev[0]["args"]["epoch"] == 3


def test_epoch_tag_map_is_bounded():
    tr = SpanTracer(max_epoch_tags=100)
    for i in range(500):
        tr.tag_epoch(i, {"i": i})
    assert len(tr._epoch_tags) == 100
    assert tr.epoch_tag(499) == {"i": 499}   # newest kept
    assert tr.epoch_tag(0) == {}             # oldest evicted


def test_chrome_trace_structure_and_nesting():
    tr = SpanTracer()
    for i in range(3):
        tr.chunk(_record(seq=i, base=100.0 + i), epoch=0)
    trace = tr.chrome_trace()
    evs = trace["traceEvents"]
    assert evs[0]["ph"] == "M" and evs[0]["name"] == "process_name"
    meta = [e for e in evs if e["ph"] == "M"]
    spans = [e for e in evs if e["ph"] != "M"]
    # timestamps monotonic non-decreasing after the metadata prologue
    ts = [e["ts"] for e in spans]
    assert ts == sorted(ts)
    # host phases nest inside their chunk span; device phases sit on the
    # sibling <group>/dev track and stay inside [tg1, tg5]
    names = {e["name"] for e in meta}
    assert "thread_name" in names
    for seq in range(3):
        chunk = next(e for e in spans if e["name"] == f"chunk:{seq}")
        sched = [e for e in spans
                 if e["name"] == "schedule"
                 and e["args"]["seq"] == seq][0]
        assert sched["tid"] == chunk["tid"]
        assert sched["ts"] >= chunk["ts"] - 1e-6
        assert sched["ts"] + sched["dur"] \
            <= chunk["ts"] + chunk["dur"] + 1e-6
        dev = [e for e in spans
               if e.get("cat") == "device" and e["args"]["seq"] == seq]
        assert [d["name"] for d in dev] == ["h2d", "launch", "kernel",
                                            "d2h"]
        assert all(d["tid"] != chunk["tid"] for d in dev)
        lo, hi = dev[0]["ts"], dev[-1]["ts"] + dev[-1]["dur"]
        assert lo >= chunk["ts"] - 1e-6
        assert hi <= chunk["ts"] + chunk["dur"] + 1e-6


# ---------------------------------------------------------------------------
# exporters
# ---------------------------------------------------------------------------

def test_exporter_writes_jsonl_prom_and_trace(tmp_path):
    tel = Telemetry()
    tel.registry.counter("reqs").add(5)
    tel.tracer.chunk(_record(), epoch=0)
    metrics = str(tmp_path / "metrics.jsonl")
    prom = str(tmp_path / "prom.txt")
    trace = str(tmp_path / "trace.json")
    exp = MetricsExporter(tel, metrics_path=metrics, interval_s=0.02,
                          trace_path=trace, prometheus_path=prom)
    with exp:
        # condition-based liveness wait (no fixed sleep): hold the
        # exporter open until it has written at least two periodic
        # snapshots, bounded so a dead exporter fails fast
        deadline = time.monotonic() + 5.0
        while exp.snapshots_written < 2 and time.monotonic() < deadline:
            time.sleep(0.005)
    snaps = read_jsonl(metrics)
    assert len(snaps) >= 2                       # periodic + final
    assert snaps[-1]["final"] is True
    assert snaps[-1]["counters"]["reqs"] == 5
    assert "reqs 5" in open(prom).read()
    loaded = json.load(open(trace))
    assert any(e.get("cat") == "chunk" for e in loaded["traceEvents"])
    assert exp.trace_events_written == len(loaded["traceEvents"])


def test_exporter_final_only_mode(tmp_path):
    tel = Telemetry()
    metrics = str(tmp_path / "m.jsonl")
    exp = MetricsExporter(tel, metrics_path=metrics, interval_s=0)
    exp.start()                                  # no thread in final-only
    assert exp._thread is None
    exp.stop()
    assert len(read_jsonl(metrics)) == 1


# ---------------------------------------------------------------------------
# always-on wiring
# ---------------------------------------------------------------------------

def test_resolve_semantics():
    assert resolve(OFF) is None
    assert resolve(False) is None
    t = Telemetry()
    assert resolve(t) is t
    assert resolve(None) is telemetry_mod.default()


def _two_group_sched(telemetry):
    groups = {
        "big": GroupSpec("big", DeviceKind.BIG, init_throughput=4000.0),
        "lil": GroupSpec("lil", DeviceKind.LITTLE, init_throughput=2000.0),
    }
    execs = {"big": SleepExecutor(rate=4000.0),
             "lil": SleepExecutor(rate=2000.0)}
    return DynamicScheduler(groups, execs, alpha=0.5, base_quantum=32,
                            telemetry=telemetry)


def test_scheduler_telemetry_snapshot_counts_chunks():
    tel = Telemetry()
    sched = _two_group_sched(tel)
    res = sched.run(0, 512)
    assert res.iterations == 512
    snap = sched.telemetry_snapshot()
    counters = snap["counters"]
    chunks = sum(v for k, v in counters.items()
                 if k.startswith("sched.chunks"))
    items = sum(v for k, v in counters.items()
                if k.startswith("sched.items"))
    assert chunks == len(res.records)
    assert items == 512
    # epochs_submitted carries a tier label since the latency-tier work
    assert sum(v for k, v in counters.items()
               if k.startswith("sched.epochs_submitted")) == 1
    assert counters["sched.epochs_finalized"] == 1
    assert "contention" in snap
    hists = snap["histograms"]
    per_group = [k for k in hists if k.startswith("sched.chunk_host_s")]
    assert per_group and all(hists[k]["count"] > 0 for k in per_group)
    # chunk spans reached the tracer with epoch + group tags
    chunk_events = [e for e in tel.tracer.chrome_events()
                    if e.get("cat") == "chunk"]
    assert len(chunk_events) == len(res.records)
    assert {e["args"]["group"] for e in chunk_events} == {"big", "lil"}
    sched.shutdown()


def test_scheduler_off_means_uninstrumented():
    sched = _two_group_sched(OFF)
    res = sched.run(0, 128)
    assert res.iterations == 128
    assert sched.telemetry_snapshot() is None
    sched.shutdown()


def test_serve_trace_golden_two_group_run():
    """2-group serve run through JobService: the exported Chrome trace is
    structurally valid (metadata prologue, monotonic timestamps, chunk
    spans tagged with tenant composition + epoch)."""
    tel = Telemetry()

    def make_scheduler():
        return _two_group_sched(tel)

    svc = JobService(make_scheduler, batch_jobs=4, telemetry=tel)
    jobs = [Job(items=64, tenant="gold" if i % 2 else "free")
            for i in range(8)]
    for j in jobs:
        svc.submit(j)
    assert svc.run_until_idle(timeout_s=30)
    # snapshot BEFORE close: the scheduler's banked completion batches
    # drain through a weak collector that dies with the scheduler
    snap = tel.snapshot()
    svc.close()
    trace = tel.tracer.chrome_trace()
    evs = trace["traceEvents"]
    assert evs[0] == {"name": "process_name", "ph": "M", "pid": 0,
                      "args": {"name": "repro serving runtime"}}
    spans = [e for e in evs if e["ph"] != "M"]
    ts = [e["ts"] for e in spans]
    assert ts == sorted(ts)
    chunk_events = [e for e in spans if e.get("cat") == "chunk"]
    assert chunk_events
    for e in chunk_events:
        assert e["args"]["group"] in ("big", "lil")
        assert e["args"]["epoch"] >= 0
        assert set(e["args"]["tenants"]) <= {"gold", "free"}
    # service-layer metrics landed in the same registry
    counters = snap["counters"]
    assert counters["svc.batches"] >= 1
    done = sum(v for k, v in counters.items()
               if k.startswith('svc.jobs{state="done"'))
    assert done == 8
    assert any(k.startswith("queue.queue_delay_s")
               for k in snap["histograms"])


# ---------------------------------------------------------------------------
# torn-snapshot fixes (satellite)
# ---------------------------------------------------------------------------

def test_throughput_stats_returns_copy():
    tr = ThroughputTracker(alpha=0.5)
    rec = _record()
    tr.update(rec)
    st = tr.stats("g0")
    st.total_items += 10_000          # mutate the returned snapshot
    st.n += 5
    fresh = tr.stats("g0")
    assert fresh.total_items == rec.token.chunk.size
    assert fresh.n == 1


def test_overhead_totals_returns_copy():
    led = OverheadLedger()
    led.add(_record())
    tot = led.totals("g0")
    tot.sp += 100.0
    tot.n_chunks += 7
    fresh = led.totals("g0")
    assert fresh.n_chunks == 1
    assert fresh.sp < 100.0


def test_partitioner_contention_stats_consistent_pair():
    sched = _two_group_sched(OFF)
    sched.run(0, 256)
    stats = sched.partitioner.contention_stats()
    assert set(stats) == {"lock_wait_s", "lock_acquires"}
    assert stats["lock_acquires"] >= 1.0
    sched.shutdown()
