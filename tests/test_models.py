"""Model-layer correctness: chunked attention vs O(s²) oracle (both causal
schedules), MoE dispatch vs dense loop oracle, SSD scan vs recurrence, and
prefill+decode == full forward for every family."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_reduced_config
from repro.models import model as M
from repro.models.attention import (chunked_attention, decode_attention,
                                    group_query_heads, reference_attention)

pytestmark = pytest.mark.slow

KEY = jax.random.PRNGKey(7)


def qkv(b=2, sq=48, skv=48, g=2, m=2, hd=16, dtype=jnp.float32):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, sq, g, m, hd), dtype)
    k = jax.random.normal(ks[1], (b, skv, g, hd), dtype)
    v = jax.random.normal(ks[2], (b, skv, g, hd), dtype)
    return q, k, v


@pytest.mark.parametrize("qc,kc", [(16, 16), (16, 32), (48, 48), (13, 7)])
def test_chunked_attention_matches_reference(qc, kc):
    q, k, v = qkv()
    out = chunked_attention(q, k, v, causal=True, q_chunk=qc, kv_chunk=kc)
    ref = reference_attention(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_block_skip_schedule_identical():
    q, k, v = qkv(sq=64, skv=64)
    base = chunked_attention(q, k, v, causal=True, q_chunk=16, kv_chunk=16)
    skip = chunked_attention(q, k, v, causal=True, q_chunk=16, kv_chunk=16,
                             block_skip=True)
    np.testing.assert_allclose(np.asarray(base), np.asarray(skip),
                               rtol=2e-5, atol=2e-5)


def test_kv_len_masking():
    q, k, v = qkv(sq=8, skv=32)
    out = chunked_attention(q, k, v, causal=False, q_chunk=8, kv_chunk=8,
                            kv_len=jnp.array([20, 32]))
    ref = reference_attention(q, k, v, causal=False,
                              kv_len=jnp.array([20, 32]))
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_decode_attention_matches_reference():
    q, k, v = qkv(sq=1, skv=40)
    kv_len = jnp.array([17, 40])
    out = decode_attention(q, k, v, kv_len)
    ref = reference_attention(q, k, v, causal=False, kv_len=kv_len)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_moe_matches_dense_oracle_at_high_capacity():
    from repro.models import moe as moe_lib
    cfg = get_reduced_config("phi3.5-moe-42b-a6.6b").replace(dtype="float32")
    cfg = cfg.replace(moe=cfg.moe.__class__(
        num_experts=4, top_k=2, capacity_factor=8.0))  # no drops
    defs = moe_lib.moe_defs(cfg)
    from repro.models.layers import init_from_defs
    p = init_from_defs(defs, KEY)
    x = jax.random.normal(KEY, (2, 16, cfg.d_model), jnp.float32) * 0.3
    out, aux = moe_lib.moe_fwd(cfg, p, x)
    ref, aux_ref = moe_lib.moe_fwd_reference(cfg, p, x)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)
    assert float(aux) == pytest.approx(float(aux_ref), rel=1e-4)


def test_moe_local_dispatch_matches_oracle_at_high_capacity():
    """The dispatch_groups>1 perf path must agree with the dense oracle when
    capacity is unconstrained (no drops in any group)."""
    from repro.models import moe as moe_lib
    cfg = get_reduced_config("granite-moe-1b-a400m").replace(dtype="float32")
    cfg = cfg.replace(moe=cfg.moe.__class__(
        num_experts=4, top_k=2, capacity_factor=8.0, dispatch_groups=4))
    from repro.models.layers import init_from_defs
    p = init_from_defs(moe_lib.moe_defs(cfg), KEY)
    x = jax.random.normal(KEY, (2, 16, cfg.d_model), jnp.float32) * 0.3
    out, aux = moe_lib.moe_fwd(cfg, p, x)
    ref, _ = moe_lib.moe_fwd_reference(cfg, p, x)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


def test_decode_unroll_matches_scan_decode():
    cfg = get_reduced_config("yi-6b").replace(dtype="float32")
    params = M.init_params(cfg, KEY)
    tokens = jax.random.randint(KEY, (2, 16), 0, cfg.vocab)
    _, cache = M.prefill(cfg, params, tokens, max_len=32)
    tok = jnp.ones((2, 1), jnp.int32)
    lg_scan, c_scan = M.decode_step(cfg, params, cache, tok)
    cfg_u = cfg.replace(decode_unroll=True)
    lg_unroll, c_unroll = M.decode_step(cfg_u, params, cache, tok)
    np.testing.assert_allclose(np.asarray(lg_scan), np.asarray(lg_unroll),
                               rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(c_scan["k"]),
                               np.asarray(c_unroll["k"]), rtol=1e-5,
                               atol=1e-5)


def test_ssd_scan_matches_recurrence():
    from repro.models.ssm import ssd_scan
    from repro.kernels.ref import ssd_scan_ref
    b, s, nh, hd, n = 2, 40, 3, 8, 6
    ks = jax.random.split(KEY, 5)
    x = jax.random.normal(ks[0], (b, s, nh, hd)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, nh)))
    A = -jnp.exp(jax.random.normal(ks[2], (nh,)) * 0.3)
    B = jax.random.normal(ks[3], (b, s, 1, n)) * 0.5
    C = jax.random.normal(ks[4], (b, s, 1, n)) * 0.5
    y, _ = ssd_scan(x, dt, A, B, C, chunk=16)
    # oracle layout: (BH, S, ...) with heads flattened
    xf = x.transpose(0, 2, 1, 3).reshape(b * nh, s, hd)
    dtf = dt.transpose(0, 2, 1).reshape(b * nh, s)
    Af = jnp.tile(A, b)
    Bf = jnp.repeat(B, nh, 2).transpose(0, 2, 1, 3).reshape(b * nh, s, n)
    Cf = jnp.repeat(C, nh, 2).transpose(0, 2, 1, 3).reshape(b * nh, s, n)
    ref = ssd_scan_ref(xf, dtf, Af, Bf, Cf) \
        .reshape(b, nh, s, hd).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref),
                               rtol=3e-4, atol=3e-4)


@pytest.mark.parametrize("arch", ["yi-6b", "deepseek-7b", "phi3-medium-14b",
                                  "stablelm-1.6b", "musicgen-large",
                                  "phi3.5-moe-42b-a6.6b",
                                  "granite-moe-1b-a400m", "xlstm-350m",
                                  "zamba2-1.2b", "phi-3-vision-4.2b"])
def test_prefill_decode_matches_forward(arch):
    cfg = get_reduced_config(arch).replace(dtype="float32")
    params = M.init_params(cfg, KEY)
    B, S = 2, 32
    s_text = S - cfg.prefix_len
    tokens = jax.random.randint(KEY, (B, s_text), 0, cfg.vocab)
    prefix = (jax.random.normal(KEY, (B, cfg.prefix_len, cfg.d_model),
                                jnp.float32) * 0.1
              if cfg.prefix_len else None)
    logits_full, _ = M.forward(cfg, params, tokens, prefix)
    lg_pre, cache = M.prefill(cfg, params, tokens[:, :-1], prefix,
                              max_len=64)
    a = np.asarray(lg_pre[:, -1], np.float32)
    b_ = np.asarray(logits_full[:, -2], np.float32)
    assert np.abs(a - b_).max() / (np.abs(b_).max() + 1e-9) < 2e-3
    lg_dec, _ = M.decode_step(cfg, params, cache, tokens[:, -1:])
    c = np.asarray(lg_dec[:, 0], np.float32)
    d = np.asarray(logits_full[:, -1], np.float32)
    assert np.abs(c - d).max() / (np.abs(d).max() + 1e-9) < 2e-3


@pytest.mark.parametrize("causal", [True, False])
def test_flash_vjp_grads(causal):
    from repro.models.attention import flash_attention_jax
    b, s, g, m, hd, qc, kc = 2, 64, 2, 2, 16, 16, 16
    ks = jax.random.split(KEY, 4)
    q = jax.random.normal(ks[0], (b, s, g, m, hd))
    k = jax.random.normal(ks[1], (b, s, g, hd))
    v = jax.random.normal(ks[2], (b, s, g, hd))
    do = jax.random.normal(ks[3], (b, s, g, m, hd))
    f = lambda q, k, v: (flash_attention_jax(q, k, v, causal, qc, kc)
                         * do).sum()
    r = lambda q, k, v: (reference_attention(q, k, v, causal=causal)
                         * do).sum()
    gf = jax.grad(f, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(r, argnums=(0, 1, 2))(q, k, v)
    for a, b_ in zip(gf, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_),
                                   rtol=1e-4, atol=1e-5)


def test_chunked_ce_matches_plain():
    from repro.train.loss import chunked_cross_entropy, cross_entropy
    b, s, d, v = 2, 24, 16, 64
    ks = jax.random.split(KEY, 3)
    x = jax.random.normal(ks[0], (b, s, d))
    w = jax.random.normal(ks[1], (d, v)) * 0.3
    labels = jax.random.randint(ks[2], (b, s), 0, v)
    loss_c, m_c = chunked_cross_entropy(x, w, labels, chunk=7)
    logits = jnp.einsum("bsd,dv->bsv", x, w)
    loss_p, m_p = cross_entropy(logits, labels)
    assert float(loss_c) == pytest.approx(float(loss_p), rel=1e-5)
    # gradients too (the remat'd backward)
    g_c = jax.grad(lambda xx: chunked_cross_entropy(xx, w, labels,
                                                    chunk=7)[0])(x)
    g_p = jax.grad(lambda xx: cross_entropy(
        jnp.einsum("bsd,dv->bsv", xx, w), labels)[0])(x)
    np.testing.assert_allclose(np.asarray(g_c), np.asarray(g_p),
                               rtol=1e-4, atol=1e-5)
