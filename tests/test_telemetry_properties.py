"""Property tests for the sharded histogram (requires hypothesis).

The container image may not ship hypothesis; these skip cleanly then —
the deterministic equivalents in tests/test_telemetry.py always run.
"""
import threading

import pytest

pytest.importorskip("hypothesis")

from hypothesis import given, settings, strategies as st

from repro.telemetry import MetricsRegistry

finite = st.floats(min_value=1e-9, max_value=1e6, allow_nan=False,
                   allow_infinity=False)


@settings(max_examples=50, deadline=None)
@given(st.lists(finite, min_size=1, max_size=200),
       st.integers(min_value=1, max_value=8))
def test_shard_merge_equals_single_shard_ingest(values, n_threads):
    ref = MetricsRegistry().histogram("ref")
    for v in values:
        ref.observe(v)

    sharded = MetricsRegistry().histogram("sharded")
    parts = [values[i::n_threads] for i in range(n_threads)]

    def work(part):
        for v in part:
            sharded.observe(v)

    threads = [threading.Thread(target=work, args=(p,)) for p in parts]
    for t in threads:
        t.start()
    for t in threads:
        t.join()

    a, b = ref.merged(), sharded.merged()
    assert a["buckets"] == b["buckets"]
    assert a["count"] == b["count"] == len(values)
    assert a["min"] == b["min"] and a["max"] == b["max"]
    assert a["sum"] == pytest.approx(b["sum"])


@settings(max_examples=50, deadline=None)
@given(st.lists(finite, min_size=1, max_size=200),
       st.floats(min_value=0.0, max_value=1.0))
def test_quantile_within_bucket_error_bound(values, q):
    h = MetricsRegistry().histogram("lat")
    for v in values:
        h.observe(v)
    true = sorted(values)[int(q * (len(values) - 1))]
    est = h.quantile(q)
    # log-bucketed growth 2**0.25: the estimate is the upper bound of the
    # true value's bucket — never below it, at most one growth factor above
    assert est >= true * (1 - 1e-9)
    assert est <= true * (2 ** 0.25) * (1 + 1e-9)
