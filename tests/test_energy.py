"""Energy model + EDP accounting."""
import pytest

from repro.core import (Chunk, ChunkRecord, DeviceKind, EnergyModel,
                        PowerSpec, Token)


def test_busy_idle_integration():
    m = EnergyModel({"g": PowerSpec(active_w=100.0, idle_w=10.0)},
                    base_w=5.0)
    rep = m.energy(total_time_s=10.0, busy_s={"g": 4.0})
    # 4s·100W + 6s·10W + 10s·5W = 400 + 60 + 50
    assert rep.total_j == pytest.approx(510.0)
    assert rep.edp == pytest.approx(5100.0)


def test_energy_from_records():
    m = EnergyModel({"g": PowerSpec(100.0, 0.0)})
    r = ChunkRecord(Token(Chunk(0, 10), "g", DeviceKind.ACCEL),
                    tg1=1.0, tg5=3.0)
    rep = m.energy_from_records(5.0, [r])
    assert rep.per_group_j["g"] == pytest.approx(200.0)


def test_busy_clamped_to_total():
    m = EnergyModel({"g": PowerSpec(100.0, 0.0)})
    rep = m.energy(1.0, {"g": 99.0})
    assert rep.total_j == pytest.approx(100.0)
