"""Per-architecture smoke tests (assignment requirement): every arch
instantiates a REDUCED same-family config and runs one forward + one
gradient step on CPU, asserting output shapes and no NaNs."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs.registry import list_archs, get_reduced_config
from repro.models import model as M
from repro.train.train_step import loss_fn

pytestmark = pytest.mark.slow

KEY = jax.random.PRNGKey(0)


@pytest.mark.parametrize("arch", list_archs())
def test_forward_and_grad_step(arch):
    cfg = get_reduced_config(arch)
    params = M.init_params(cfg, KEY)
    B, S = 2, 32
    s_text = S - cfg.prefix_len
    batch = {
        "tokens": jax.random.randint(KEY, (B, s_text), 0, cfg.vocab),
        "labels": jax.random.randint(KEY, (B, s_text), 0, cfg.vocab),
    }
    if cfg.prefix_len:
        batch["prefix_emb"] = jax.random.normal(
            KEY, (B, cfg.prefix_len, cfg.d_model), cfg.activation_dtype) * 0.1

    logits, aux = M.forward(cfg, params, batch["tokens"],
                            batch.get("prefix_emb"))
    assert logits.shape == (B, S, cfg.vocab)
    assert not bool(jnp.isnan(logits.astype(jnp.float32)).any())

    (loss, metrics), grads = jax.value_and_grad(
        lambda p: loss_fn(cfg, p, batch), has_aux=True)(params)
    assert jnp.isfinite(loss)
    flat = jax.tree.leaves(grads)
    assert all(bool(jnp.all(jnp.isfinite(g.astype(jnp.float32))))
               for g in flat)
    assert any(float(jnp.abs(g.astype(jnp.float32)).max()) > 0
               for g in flat), "all-zero gradients"


@pytest.mark.parametrize("arch", list_archs())
def test_param_count_analytic_close_to_actual(arch):
    cfg = get_reduced_config(arch)
    params = M.init_params(cfg, KEY)
    actual = sum(p.size for p in jax.tree.leaves(params))
    analytic = cfg.param_count()
    assert abs(analytic - actual) / actual < 0.35, (analytic, actual)
