"""Adaptive-policy layer: sliding-window stats, spike/cooldown decisions,
sharded λ-tracker equivalence, adaptive refill sizing, event-driven drain.

Deterministic + seeded-random coverage that always runs; the Hypothesis
property-test mirror lives in tests/test_policy_properties.py (skipped
when hypothesis is absent, per repo convention).
"""
import random
import threading
import time

from repro.core import (Chunk, ChunkRecord, DeviceKind, GroupSpec,
                        IterationSpace, LockedThroughputTracker,
                        SleepExecutor, ThroughputTracker, Token)
from repro.core.partitioner import HeterogeneousPartitioner
from repro.core.scheduler import DynamicScheduler
from repro.policy import AdaptivePolicy, SlidingWindow
from repro.queue import Job, JobService
from repro.queue.manager import QueueManager


def _rec(group, size, t0, t1):
    return ChunkRecord(Token(Chunk(0, size), group, DeviceKind.BIG),
                       tg1=t0, tg5=t1, tc1=t0, tc2=t0, tc3=t1)


# ---------------------------------------------------------------------------
# SlidingWindow
# ---------------------------------------------------------------------------

def test_window_evicts_past_horizon():
    w = SlidingWindow(horizon_s=1.0)
    w.observe(0.0, 5.0)
    w.observe(0.5, 7.0)
    assert w.count == 2 and w.max() == 7.0 and w.min() == 5.0
    w.observe(1.4, 3.0)                  # evicts the t=0.0 sample
    assert w.count == 2
    assert w.max() == 7.0 and w.min() == 3.0
    assert w.max(now=2.0) == 3.0         # read-side eviction too


def test_window_quantiles_bounded_and_ordered():
    rng = random.Random(3)
    w = SlidingWindow(horizon_s=100.0)
    for i in range(200):
        w.observe(float(i) * 0.01, rng.uniform(-5, 5))
    qs = [w.quantile(q) for q in (0.0, 0.25, 0.5, 0.75, 0.99, 1.0)]
    assert qs == sorted(qs)
    assert qs[0] == w.min() and qs[-1] == w.max()
    assert w.min() <= w.mean() <= w.max()
    assert w.median() == w.quantile(0.5)


def test_window_ewma_converges_to_constant():
    w = SlidingWindow(horizon_s=10.0, alpha=0.3)
    w.observe(0.0, 100.0)
    for i in range(1, 60):
        w.observe(i * 0.1, 2.0)
    assert abs(w.ewma - 2.0) < 1e-6
    assert w.last == 2.0


def test_window_bounded_samples():
    w = SlidingWindow(horizon_s=1e9, max_samples=16)
    for i in range(100):
        w.observe(float(i), float(i))
    assert w.count == 16
    assert w.min() == 84.0               # oldest evicted by cap


def test_window_empty_reads():
    w = SlidingWindow(horizon_s=1.0)
    assert w.count == 0 and w.ewma == 0.0
    assert w.mean() == w.min() == w.max() == w.quantile(0.5) == 0.0


# ---------------------------------------------------------------------------
# AdaptivePolicy: admission smoothing + spikes
# ---------------------------------------------------------------------------

def test_admission_delay_rises_fast_decays_slow():
    p = AdaptivePolicy(window_s=10.0, alpha=0.5, min_samples=2)
    for i in range(5):
        p.admission_delay(float(i), 1.0)
    # spike: the point sample dominates immediately (trend projection
    # may push the estimate even higher — never lower)
    assert p.admission_delay(5.0, 50.0) >= 50.0
    # after the burst, the smoothed view decays instead of snapping back
    eased = p.admission_delay(6.0, 1.0)
    assert 1.0 < eased < 50.0


def test_spike_detection_counts_only_outliers():
    p = AdaptivePolicy(window_s=100.0, spike_threshold=3.0, min_samples=3)
    t = 0.0
    for _ in range(10):
        p.admission_delay(t, 1.0)
        t += 0.1
    assert p.spikes == 0
    p.admission_delay(t, 10.0)           # 10× the median
    assert p.spikes == 1
    p.admission_delay(t + 0.1, 1.1)      # back to normal: no spike
    assert p.spikes == 1


def test_spike_needs_min_samples():
    p = AdaptivePolicy(window_s=100.0, spike_threshold=2.0, min_samples=5)
    p.admission_delay(0.0, 1.0)
    p.admission_delay(0.1, 100.0)        # huge, but window too thin
    assert p.spikes == 0


def test_window_slope_tracks_trend():
    w = SlidingWindow(horizon_s=10.0)
    assert w.slope() == 0.0              # empty
    w.observe(0.0, 1.0)
    assert w.slope() == 0.0              # single sample
    for i in range(1, 6):
        w.observe(float(i), 1.0 + 2.0 * i)
    assert abs(w.slope() - 2.0) < 1e-9   # exact on a clean ramp
    w2 = SlidingWindow(horizon_s=10.0)
    for i in range(6):
        w2.observe(float(i), 7.0)
    assert w2.slope() == 0.0             # flat
    # eviction: only the windowed tail counts
    w3 = SlidingWindow(horizon_s=2.0)
    w3.observe(0.0, 100.0)               # stale outlier
    w3.observe(10.0, 1.0)
    w3.observe(11.0, 2.0)
    assert abs(w3.slope(now=11.0) - 1.0) < 1e-9


def test_trend_projection_defers_before_the_edge():
    """A ramping backlog must cross the gate *early*: the projected
    estimate exceeds the point sample by slope × lead_s."""
    p = AdaptivePolicy(window_s=10.0, alpha=1.0, lead_s=0.5)
    for i in range(5):
        p.admission_delay(float(i) * 0.1, 0.1 + 0.1 * i)  # +1.0/s ramp
    est = p.admission_delay(0.5, 0.6)
    assert est > 0.6 + 0.25              # ≈ point + 1.0 × lead_s
    # a falling trend must NOT discount below the point sample
    p2 = AdaptivePolicy(window_s=10.0, alpha=1.0, lead_s=0.5)
    for i in range(5):
        p2.admission_delay(float(i) * 0.1, 1.0 - 0.1 * i)
    assert p2.admission_delay(0.5, 0.5) >= 0.5


def test_hysteresis_latches_defer_until_recovery():
    """Once the estimate crosses the SLO the gate stays shut — even for
    point samples back inside the band — until the windowed high-water
    clears slo × (1 - hysteresis)."""
    slo = 1.0
    p = AdaptivePolicy(window_s=1.0, alpha=1.0, lead_s=0.0,
                       hysteresis=0.1, recovery_q=1.0)
    assert p.admission_delay(0.0, 0.5, slo=slo) <= slo
    assert p.admission_delay(0.1, 1.2, slo=slo) > slo    # latches
    # point back under the SLO, but the 1.2 sample is still in-window
    held = p.admission_delay(0.2, 0.5, slo=slo)
    assert held > slo
    assert p.hysteresis_holds == 1
    assert p.stats()["deferring"] == 1.0
    # window drains past the horizon: recovery re-opens the gate
    eased = p.admission_delay(2.0, 0.5, slo=slo)
    assert eased <= slo
    assert p.stats()["deferring"] == 0.0


def test_no_latch_without_slo():
    p = AdaptivePolicy(window_s=1.0, alpha=1.0, lead_s=0.0)
    p.admission_delay(0.0, 5.0)
    assert p.admission_delay(2.0, 0.5) == 0.5
    assert p.stats()["deferring"] == 0.0


def test_gate_keys_isolate_tenant_windows():
    """A starved tenant's huge fair-share projections must not poison
    another tenant's smoothed estimate (regression: one shared window
    rejected a high-weight tenant's whole burst the moment a low-weight
    tenant shared the gate)."""
    p = AdaptivePolicy(window_s=10.0, alpha=1.0, lead_s=0.0)
    for i in range(5):
        p.admission_delay(float(i), 200.0, slo=5.0, key="free")
    # gold's first sample sees a fresh window, not free's 200s EWMA
    assert p.admission_delay(5.0, 0.5, slo=5.0, key="gold") == 0.5
    assert p.stats()["delay_samples"] == 6.0


def test_trend_needs_window_span():
    """A submit burst lands many samples within ~0 time; a slope fit
    over that span extrapolates far beyond its data, so the trend term
    must stay off until the window covers at least lead_s."""
    p = AdaptivePolicy(window_s=10.0, alpha=1.0, lead_s=0.5)
    t = 0.0
    for d in (0.1, 0.5, 1.0, 2.0, 4.0):     # steep ramp, microseconds apart
        est = p.admission_delay(t, d)
        assert est == d                       # no projection yet
        t += 1e-6
    # same ramp spread over real time: projection kicks in
    p2 = AdaptivePolicy(window_s=10.0, alpha=1.0, lead_s=0.5)
    t = 0.0
    for d in (0.1, 0.5, 1.0, 2.0):
        p2.admission_delay(t, d)
        t += 0.25
    assert p2.admission_delay(1.0, 4.0) > 4.0


# ---------------------------------------------------------------------------
# AdaptivePolicy: rebalance cooldown
# ---------------------------------------------------------------------------

def test_insignificant_rebalance_is_noop():
    p = AdaptivePolicy(cooldown_s=1.0, rebalance_epsilon=0.05)
    assert not p.allow_rebalance(0.0, {"g": 0.98}, {"g": 1.0})
    assert p.rebalances == 0 and p.rebalances_suppressed == 0


def test_first_significant_rebalance_applies_then_cooldown():
    p = AdaptivePolicy(cooldown_s=1.0)
    assert p.allow_rebalance(0.0, {"g": 0.5}, {})
    assert p.rebalances == 1
    # flap back within the cooldown: suppressed
    assert not p.allow_rebalance(0.4, {"g": 1.0}, {"g": 0.5})
    assert p.rebalances_suppressed == 1
    # cooldown elapsed: applies
    assert p.allow_rebalance(1.1, {"g": 1.0}, {"g": 0.5})
    assert p.rebalances == 2


def test_persistent_change_never_starved():
    """A change that keeps being proposed lands within one cooldown."""
    p = AdaptivePolicy(cooldown_s=1.0)
    assert p.allow_rebalance(0.0, {"g": 0.5}, {})
    t, applied = 0.1, None
    while t < 5.0:
        if p.allow_rebalance(t, {"g": 0.2}, {"g": 0.5}):
            applied = t
            break
        t += 0.1
    assert applied is not None and applied <= 1.0 + 0.1 + 1e-9


def test_missing_groups_default_to_full_weight():
    p = AdaptivePolicy(rebalance_epsilon=0.05)
    # {"g": 1.0} vs {} is no change at all
    assert not p.significant({"g": 1.0}, {})
    assert p.significant({}, {"g": 0.5})     # recovery IS a change


# ---------------------------------------------------------------------------
# Sharded tracker ≡ locked tracker
# ---------------------------------------------------------------------------

def _feed(tracker, group, lams, t0=0.0):
    t = t0
    for lam in lams:
        size = 8
        dt = size / lam
        tracker.update(_rec(group, size, t, t + dt))
        t += dt


def test_sharded_matches_locked_single_writer_per_group():
    """The scheduler invariant: each group fed by one thread. Merged
    stats must be bit-identical to the single-lock oracle for any alpha."""
    rng = random.Random(11)
    groups = {f"g{i}": [rng.uniform(1.0, 500.0) for _ in range(40)]
              for i in range(4)}
    for alpha in (1.0, 0.5, 0.3):
        shard = ThroughputTracker(alpha)
        oracle = LockedThroughputTracker(alpha)
        for g, lams in groups.items():
            _feed(oracle, g, lams)
        threads = [threading.Thread(target=_feed, args=(shard, g, lams))
                   for g, lams in groups.items()]
        for th in threads:
            th.start()
        for th in threads:
            th.join()
        for g in groups:
            a, b = shard.stats(g), oracle.stats(g)
            assert a.n == b.n
            assert a.total_items == b.total_items
            assert abs(a.total_time - b.total_time) < 1e-12
            assert abs(a.ewma - b.ewma) < 1e-9
            assert a.last == b.last
            assert abs(shard.get(g) - oracle.get(g)) < 1e-9
        assert set(shard.snapshot()) == set(oracle.snapshot())


def test_sharded_update_many_matches_locked_mixed_batches():
    rng = random.Random(7)
    recs = []
    t = 0.0
    for i in range(200):
        g = f"g{rng.randrange(3)}"
        size = rng.randrange(1, 64)
        dt = rng.uniform(1e-4, 1e-2)
        recs.append(_rec(g, size, t, t + dt))
        t += dt
    for alpha in (1.0, 0.4):
        shard, oracle = ThroughputTracker(alpha), \
            LockedThroughputTracker(alpha)
        # single thread: update_many must equal record-at-a-time oracle
        shard.update_many(recs)
        for r in recs:
            oracle.update(r)
        for g in ("g0", "g1", "g2"):
            a, b = shard.stats(g), oracle.stats(g)
            assert (a.n, a.total_items) == (b.n, b.total_items)
            assert abs(a.ewma - b.ewma) < 1e-9
            assert a.last == b.last


def test_sharded_alpha1_multiwriter_conserves_counts():
    """alpha=1.0 (paper mode), many writers on ONE group: totals are
    conserved exactly and the merged ewma/last is some thread's real
    observation (merge-by-latest-seq; no invariant on which)."""
    shard = ThroughputTracker(1.0)
    lams_by_thread = [[float(100 + t * 17 + i) for i in range(50)]
                      for t in range(6)]
    threads = [threading.Thread(target=_feed, args=(shard, "g", lams))
               for lams in lams_by_thread]
    for th in threads:
        th.start()
    for th in threads:
        th.join()
    st = shard.stats("g")
    assert st.n == 6 * 50
    assert st.total_items == 6 * 50 * 8
    everything = {lam for lams in lams_by_thread for lam in lams}
    assert any(abs(st.ewma - lam) < 1e-6 for lam in everything)
    assert any(abs(st.last - lam) < 1e-6 for lam in everything)


def test_sharded_ewma_chain_survives_writer_handoff():
    """A group whose writer thread changes seeds the new cell's EWMA from
    the merged view — continuous, not restarted."""
    shard = ThroughputTracker(0.5)
    oracle = LockedThroughputTracker(0.5)
    first, second = [100.0, 200.0], [50.0, 25.0]
    th = threading.Thread(target=_feed, args=(shard, "g", first))
    th.start(), th.join()
    th2 = threading.Thread(target=_feed, args=(shard, "g", second, 100.0))
    th2.start(), th2.join()
    _feed(oracle, "g", first)
    _feed(oracle, "g", second, 100.0)
    assert abs(shard.stats("g").ewma - oracle.stats("g").ewma) < 1e-9


def test_sharded_registration_lock_untouched_steady_state():
    tr = ThroughputTracker(1.0)
    _feed(tr, "g", [10.0] * 5)
    before = tr.contention_stats()["lock_acquires"]
    _feed(tr, "g", [10.0] * 100, t0=100.0)   # same thread: no registration
    assert tr.contention_stats()["lock_acquires"] == before


# ---------------------------------------------------------------------------
# Adaptive refill sizing
# ---------------------------------------------------------------------------

def _part(adaptive, n=10_000, refill=8, warm=False):
    tr = ThroughputTracker(1.0)
    groups = {"a": GroupSpec("a", DeviceKind.BIG, init_throughput=1e6),
              "b": GroupSpec("b", DeviceKind.LITTLE, init_throughput=1.0)}
    part = HeterogeneousPartitioner(IterationSpace(0, n), groups, tr,
                                    base_quantum=64, refill_chunks=refill,
                                    adaptive_refill=adaptive)
    if warm:
        # one measurement per group at its seed λ: activates λ-share
        # refills (cold groups refill a single chunk)
        for g in groups.values():
            tr.update(_rec(g.name, 1000, 0.0, 1000 / g.init_throughput))
    return part


def test_refill_quota_static_without_flag():
    p = _part(adaptive=False)
    p._steals, p._refills = 100, 1
    assert p._refill_quota_locked() == 8


def test_refill_quota_shrinks_on_heavy_stealing():
    p = _part(adaptive=True)
    p._refills, p._steals = 4, 4          # steal rate 0.5 ≥ high
    assert p._refill_quota_locked() == 4
    assert p.refill_stats()["refill_quota"] == 4.0


def test_refill_quota_grows_when_steals_rare():
    p = _part(adaptive=True)
    p._refills, p._steals = 100, 2        # rate ~0.02 ≤ low
    assert p._refill_quota_locked() == 16


def test_refill_quota_needs_history():
    p = _part(adaptive=True)
    p._refills, p._steals = 2, 2          # only 4 events < min_total
    assert p._refill_quota_locked() == 8


def test_adaptive_near_exhaustion_caps_hoarding():
    """With heavy stealing history and a nearly-drained space, a fast
    group's λ-share refill is capped instead of hoarding the tail."""
    p = _part(adaptive=True, n=400, warm=True)
    p._refills, p._steals = 4, 8          # steal rate 2/3: quota → 4
    tok = p.next_token("a")               # λ-share want would be ~400
    assert tok is not None
    # tail (400) ≤ quota(4)×chunk(64)×2 groups → capped at tail/2 = 200
    assert p.space.remaining >= 150


def test_static_partitioner_keeps_hoarding_behavior():
    """Same near-exhausted setup WITHOUT the flag: the fast group's
    λ-share refill takes (almost) the whole space — PR 5 behavior."""
    p = _part(adaptive=False, n=400, warm=True)
    p._refills, p._steals = 4, 8
    assert p.next_token("a") is not None
    assert p.space.remaining <= 1


def test_scheduler_runs_with_adaptive_refill_both_modes():
    for adaptive in (True, False):
        specs = {g: GroupSpec(g, DeviceKind.BIG) for g in ("x", "y")}
        execs = {g: SleepExecutor(rate=100_000.0) for g in specs}
        sched = DynamicScheduler(specs, execs, chunk_mode="range",
                                 adaptive_refill=adaptive)
        res = sched.run(0, 2048)
        sched.shutdown()
        assert res.iterations == 2048
        assert sum(res.per_group_items.values()) == 2048


# ---------------------------------------------------------------------------
# Event-driven drain
# ---------------------------------------------------------------------------

def _make_service(**kw):
    specs = {"g": GroupSpec("g", DeviceKind.BIG)}

    def make():
        return DynamicScheduler(
            specs, {"g": SleepExecutor(rate=100_000.0)})

    return JobService(make, queue=QueueManager(), **kw)


def test_submit_wakes_parked_daemon_quickly():
    svc = _make_service(poll_s=0.01, fallback_s=30.0)
    svc.start()
    try:
        time.sleep(0.05)                  # daemon parks on the event
        t0 = time.monotonic()
        svc.submit(Job(items=64))
        deadline = time.monotonic() + 5.0
        while svc.stats.done == 0 and time.monotonic() < deadline:
            time.sleep(0.002)
        latency = time.monotonic() - t0
        # fallback is 30s: completing this fast proves the event woke it
        assert svc.stats.done == 1
        assert latency < 5.0
        assert svc.wakeup.event_wakeups >= 1
    finally:
        svc.close()


def test_idle_daemon_accrues_only_fallback_timeouts():
    svc = _make_service(poll_s=0.01, fallback_s=0.05)
    svc.start()
    try:
        time.sleep(0.4)
        stats = svc.wakeup.stats()
        # ≈ 0.4/0.05 = 8 expected; generous ceiling, but far below the
        # 40 a poll_s busy-loop would log
        assert stats["timeout_wakeups"] <= 20
    finally:
        svc.close()


def test_queue_listener_fires_on_put_and_requeue():
    q = QueueManager()
    hits = []
    q.add_listener(lambda: hits.append(1))
    q.put(Job(items=1))
    assert len(hits) == 1


def test_epoch_done_callback_fires():
    specs = {"g": GroupSpec("g", DeviceKind.BIG)}
    sched = DynamicScheduler(specs, {"g": SleepExecutor(rate=100_000.0)})
    sched.start()
    try:
        fired = threading.Event()
        h = sched.submit_epoch((0, 256))
        h.add_done_callback(lambda _h: fired.set())
        assert h.wait(10.0)
        assert fired.wait(5.0)
        # late registration on a finalized handle: immediate callback
        late = threading.Event()
        h.add_done_callback(lambda _h: late.set())
        assert late.is_set()
    finally:
        sched.shutdown()


def test_stop_unparks_daemon_immediately():
    svc = _make_service(poll_s=0.01, fallback_s=60.0)
    svc.start()
    time.sleep(0.05)
    t0 = time.monotonic()
    svc.stop()
    assert time.monotonic() - t0 < 5.0    # did not wait out fallback_s


# ---------------------------------------------------------------------------
# Idle probing (stale-capacity livelock)
# ---------------------------------------------------------------------------

def _stale_capacity_controller(policy, slo=1.0, registry=None, queue=None):
    from repro.queue.admission import AdmissionController
    q = queue if queue is not None else QueueManager()
    adm = AdmissionController(q, slo_delay_s=slo, defer_factor=4.0,
                              registry=registry, policy=policy)
    # measured-stale capacity: 0.1 items/s makes even a 2-item job
    # project 20s — far past the reject band for a 1s SLO
    adm.on_group_join("g0", 0.1)
    return q, adm


def test_idle_probe_breaks_stale_capacity_livelock():
    from repro.queue.admission import Decision
    q, adm = _stale_capacity_controller(AdaptivePolicy(window_s=5.0))
    first = adm.admit(Job(items=2))
    # idle population: the 20s projection is unfalsifiable; probe admits
    assert first.decision == Decision.ADMIT
    assert first.reason == "idle probe"
    assert adm.idle_probes == 1
    # the probe is now unfinished work: the next candidate gates normally
    second = adm.admit(Job(items=2))
    assert second.decision != Decision.ADMIT
    assert adm.idle_probes == 1


def test_no_idle_probe_without_policy():
    from repro.queue.admission import Decision
    q, adm = _stale_capacity_controller(policy=None)
    assert adm.admit(Job(items=2)).decision == Decision.REJECT
    assert adm.idle_probes == 0


def test_idle_probe_waits_for_popped_work():
    from repro.queue.admission import Decision
    from repro.queue.job import JobState
    q, adm = _stale_capacity_controller(AdaptivePolicy(window_s=5.0))
    probe = adm.admit(Job(items=2))
    assert probe.decision == Decision.ADMIT
    popped = q.pop()                     # backlog 0, but ADMITTED in flight
    assert popped is not None
    assert adm.admit(Job(items=2)).decision != Decision.ADMIT
    q.mark_running(popped)               # RUNNING still blocks probing
    assert adm.admit(Job(items=2)).decision != Decision.ADMIT
    q.mark_finished(popped, JobState.DONE)
    nxt = adm.admit(Job(items=2))        # idle again: probe resumes
    assert nxt.decision == Decision.ADMIT and nxt.reason == "idle probe"


def test_idle_probe_is_per_tenant():
    from repro.queue.admission import Decision
    from repro.tenancy import ShardedQueueManager, TenantRegistry
    reg = TenantRegistry.parse("gold:weight=10,free:weight=1")
    q = ShardedQueueManager(reg)
    _, adm = _stale_capacity_controller(
        AdaptivePolicy(window_s=5.0), registry=reg, queue=q)
    gold = adm.admit(Job(items=2, tenant="gold"))
    assert gold.decision == Decision.ADMIT and gold.reason == "idle probe"
    # gold's probe occupies gold's shard only: free still probes
    free = adm.admit(Job(items=2, tenant="free"))
    assert free.decision == Decision.ADMIT and free.reason == "idle probe"
    # but a second gold candidate sees gold's unfinished probe
    assert adm.admit(Job(items=2, tenant="gold")).decision != Decision.ADMIT
    assert adm.idle_probes == 2
