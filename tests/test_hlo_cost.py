"""Validate the HLO-walking cost model against XLA's own cost_analysis on
loop-free modules, and its trip-count scaling on scans (the reason the
walker exists: cost_analysis counts while bodies once)."""
import jax
import jax.numpy as jnp
import pytest

from benchmarks.hlo_cost import HloCostModel, analyze_text


def _compile(fn, *args):
    return jax.jit(fn).lower(*args).compile()


def test_matmul_flops_exact():
    a = jax.ShapeDtypeStruct((512, 256), jnp.float32)
    b = jax.ShapeDtypeStruct((256, 128), jnp.float32)
    comp = _compile(lambda a, b: a @ b, a, b)
    w = analyze_text(comp.as_text())
    assert w["flops"] == 2 * 512 * 256 * 128


def test_loop_free_module_matches_cost_analysis():
    def f(c, xs):
        for i in range(8):
            c = jnp.tanh(c @ xs[i])
        return c

    c = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    xs = jax.ShapeDtypeStruct((8, 256, 256), jnp.float32)
    comp = _compile(f, c, xs)
    w = analyze_text(comp.as_text())
    ca = comp.cost_analysis()
    assert w["flops"] == pytest.approx(ca["flops"], rel=0.05)


def test_scan_trip_count_scaling():
    def body(c, x):
        return jnp.tanh(c @ x), ()

    def f_scan(c, xs):
        return jax.lax.scan(body, c, xs)[0]

    def f_unroll(c, xs):
        for i in range(8):
            c = jnp.tanh(c @ xs[i])
        return c

    c = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    xs = jax.ShapeDtypeStruct((8, 256, 256), jnp.float32)
    comp_s = _compile(f_scan, c, xs)
    comp_u = _compile(f_unroll, c, xs)
    # cost_analysis counts the while body ONCE (the motivating defect)
    assert comp_s.cost_analysis()["flops"] < \
        comp_u.cost_analysis()["flops"] / 4
    # the walker scales by trip count
    ws = analyze_text(comp_s.as_text())
    wu = analyze_text(comp_u.as_text())
    assert ws["flops"] == pytest.approx(wu["flops"], rel=0.02)


def test_nested_scan_scaling():
    def inner(c, x):
        return c @ x, ()

    def outer(c, xs):
        def obody(c, _):
            c2, _ = jax.lax.scan(inner, c, xs)
            return c2, ()
        return jax.lax.scan(obody, c, None, length=3)[0]

    c = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    xs = jax.ShapeDtypeStruct((5, 128, 128), jnp.float32)
    comp = _compile(outer, c, xs)
    w = analyze_text(comp.as_text())
    assert w["flops"] == pytest.approx(3 * 5 * 2 * 128 ** 3, rel=0.05)


def test_scan_slice_bytes_not_inflated():
    """A scan slicing one row per step must count ~one row per step of
    traffic, not the whole stacked operand each iteration."""
    def f(c, xs):
        def body(c, x):
            return c + x @ x, ()
        return jax.lax.scan(body, c, xs)[0]

    c = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    xs = jax.ShapeDtypeStruct((64, 128, 128), jnp.float32)
    w = analyze_text(_compile(f, c, xs).as_text())
    full = 64 * 128 * 128 * 4
    # per-iter x slice traffic ≈ 64 × one slice (plus carry); far below
    # 64 × full stacked array
    assert w["bytes"] < 10 * full


def test_collective_bytes_detected():
    import os
    # (this test runs on whatever device count the session has; a 1-device
    # "mesh" produces no collectives, so only assert the field exists)
    a = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    w = analyze_text(_compile(lambda a: a * 2, a).as_text())
    assert "collective_bytes" in w and w["collective_bytes"] == 0
