"""Latency tiers: epoch preemption, deadline enforcement, the express
lane, and cancellation edge cases — all on the deterministic virtual
clock (tests/clock.py), so every latency assertion is an exact statement
about the virtual timeline, not a race against the host."""
import threading
import time

import pytest

from repro.core import (DeviceKind, DynamicScheduler, GroupSpec,
                        SleepExecutor)
from repro.core.types import TIERS, tier_rank
from repro.queue import (EXPRESS_RANK, AdmissionController, Decision, Job,
                         JobService, JobState, QueueManager)
from repro.tenancy import ShardedQueueManager, TenantRegistry


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------

def _sched1(vc, rate=1000.0, fixed_chunk=100):
    """One-group scheduler on the virtual timeline: each chunk is
    fixed_chunk/rate virtual seconds."""
    return DynamicScheduler(
        {"g": GroupSpec("g", DeviceKind.ACCEL, fixed_chunk=fixed_chunk,
                        init_throughput=rate)},
        {"g": SleepExecutor(rate=rate, clock=vc.now, sleep=vc.sleep)},
        clock=vc.now)


class _GateExecutor(SleepExecutor):
    """SleepExecutor that signals after its first chunk and then blocks
    until released — the deterministic 'mid-flight' injection point: the
    test submits/cancels while chunk 1 is provably still in flight
    (virtual time otherwise outruns the test thread in real time)."""

    def __init__(self, started, gate, **kw):
        super().__init__(**kw)
        self._started = started
        self._gate = gate

    def execute(self, token, rec):
        out = super().execute(token, rec)
        self._started.set()
        assert self._gate.wait(10.0)
        return out


class _StepExecutor(SleepExecutor):
    """SleepExecutor the test can single-step: it starts *halted* — the
    dispatcher parks at every chunk entry (signalling ``parked``) with
    the virtual clock frozen, giving the test a drift-free injection
    point for latency assertions. ``step()`` releases exactly one chunk
    and waits for the dispatcher to park again; ``resume()`` lets chunks
    flow freely (teardown / conservation phases)."""

    def __init__(self, **kw):
        super().__init__(**kw)
        self.free_run = threading.Event()
        self.parked = threading.Event()
        self._permits = threading.Semaphore(0)

    def execute(self, token, rec):
        if not self.free_run.is_set():
            self.parked.set()
            while not self.free_run.is_set():
                if self._permits.acquire(timeout=0.01):
                    break
        return super().execute(token, rec)

    def step(self, n=1, timeout=10.0):
        for _ in range(n):
            self.parked.clear()
            self._permits.release()
            assert self.parked.wait(timeout), "dispatcher never re-parked"

    def resume(self):
        self.free_run.set()


def _spin(predicate, timeout=30.0, step=None):
    """Real-time-bounded wait for a condition driven by virtual-clock
    threads (the timeline advances autonomously; real time only bounds a
    genuinely hung test)."""
    deadline = time.monotonic() + timeout
    while not predicate():
        if time.monotonic() > deadline:
            raise AssertionError("condition not reached in time")
        if step is not None:
            step()
        time.sleep(0.001)


# ---------------------------------------------------------------------------
# scheduler-level: priority preemption
# ---------------------------------------------------------------------------

def test_urgent_epoch_preempts_running_standard(vclock):
    started, gate = threading.Event(), threading.Event()
    s = DynamicScheduler(
        {"g": GroupSpec("g", DeviceKind.ACCEL, fixed_chunk=100,
                        init_throughput=1000.0)},
        {"g": _GateExecutor(started, gate, rate=1000.0, clock=vclock.now,
                            sleep=vclock.sleep)},
        clock=vclock.now)
    s.start()
    try:
        h1 = s.submit_epoch((0, 1000))              # 1.0 virtual s of work
        assert started.wait(10.0)                   # chunk 1 in flight
        h2 = s.submit_epoch((0, 50), priority="urgent")
        gate.set()
        r2 = h2.result(timeout=30)
        r1 = h1.result(timeout=30)
        # work conservation: preemption pauses, never drops
        assert r1.iterations == 1000 and not r1.cancelled
        assert r2.iterations == 50
        # the urgent epoch was served at the very next chunk boundary
        # (0.05 virtual s of urgent work after a 0.1 s chunk), not after
        # the 1.0 s standard epoch drained
        assert h2.finished_at < h1.finished_at
        assert h2.finished_at - h2.submitted_at < 0.5
    finally:
        gate.set()
        s.shutdown()


def test_preempted_private_range_tail_keeps_epoch_open(vclock):
    """Regression: once λ is warm, one range-mode refill can swallow an
    epoch's whole remaining space into the dispatcher's private range
    (``space.remaining == 0`` while work remains). A preemption at that
    point used to finalize the epoch incomplete at _leave_epoch — the
    service layer then saw a not-done batch and re-executed every job in
    it. The epoch must stay open (has_work sees the private range) until
    the preempted dispatcher scans back and drains its tail."""
    ex = _StepExecutor(rate=1000.0, clock=vclock.now, sleep=vclock.sleep)
    s = DynamicScheduler(
        {"g": GroupSpec("g", DeviceKind.ACCEL, fixed_chunk=100,
                        init_throughput=1000.0)},
        {"g": ex}, clock=vclock.now)
    try:
        h = s.submit_epoch((0, 500))
        assert ex.parked.wait(10.0)     # chunk 1 carved, dispatcher frozen
        # Force the warm-grant state deterministically: hand the rest of
        # the space to the dispatcher's private range, as a λ-sized
        # refill would (grant sizing itself rounds non-deterministically,
        # so the test builds the state instead of coaxing it).
        st = s.partitioner._ranges[h.space]["g"]
        with st.lock:
            c = h.space.take(h.space.remaining)
            st.lo, st.hi = c.begin, c.end
        assert h.space.remaining == 0
        u = s.submit_epoch((0, 50), priority="urgent")
        ex.resume()                     # chunk 1 completes → preempt break
        assert u.result(timeout=30).iterations == 50
        r = h.result(timeout=30)
        assert r.iterations == 500 and r.unfinished == 0
        assert not r.cancelled
    finally:
        ex.resume()
        s.shutdown()


def test_urgent_epoch_jumps_queued_standard_epochs(vclock):
    started, gate = threading.Event(), threading.Event()
    s = DynamicScheduler(
        {"g": GroupSpec("g", DeviceKind.ACCEL, fixed_chunk=100,
                        init_throughput=1000.0)},
        {"g": _GateExecutor(started, gate, rate=1000.0, clock=vclock.now,
                            sleep=vclock.sleep)},
        clock=vclock.now)
    try:
        h1 = s.submit_epoch((0, 300))
        assert started.wait(10.0)                   # h1 provably running
        h2 = s.submit_epoch((0, 300))               # queued behind h1
        h3 = s.submit_epoch((0, 100), priority="urgent")
        gate.set()
        for h in (h1, h2, h3):
            h.result(timeout=30)
        # the urgent epoch finished before the queued standard epoch
        assert h3.finished_at < h2.finished_at
    finally:
        gate.set()
        s.shutdown()


def test_batch_not_starved_after_urgent_drains(vclock):
    """Preemption is not starvation: once urgent work drains, the
    lower tiers run to completion."""
    s = _sched1(vclock)
    s.start()
    try:
        hb = s.submit_epoch((0, 200), priority="batch")
        hu = s.submit_epoch((0, 200), priority="urgent")
        assert hu.result(timeout=30).iterations == 200
        assert hb.result(timeout=30).iterations == 200
    finally:
        s.shutdown()


# ---------------------------------------------------------------------------
# scheduler-level: deadlines and cancellation
# ---------------------------------------------------------------------------

def test_epoch_deadline_cancels_and_conserves_count(vclock):
    s = _sched1(vclock)                             # 0.1 s per chunk
    s.start()
    try:
        h = s.submit_epoch((0, 1000),
                           deadline_s=vclock.now() + 0.25)
        res = h.result(timeout=30)
        assert res.cancelled and res.cancel_reason == "deadline"
        # chunk-granular: some work completed before the boundary check
        assert 0 < res.iterations < 1000
        assert res.unfinished > 0
        # conservation: nothing both completed and requeued, nothing lost
        assert res.iterations + res.unfinished == 1000
    finally:
        s.shutdown()


def test_explicit_cancel_mid_flight_conserves_count(vclock):
    started, gate = threading.Event(), threading.Event()
    s = DynamicScheduler(
        {"g": GroupSpec("g", DeviceKind.ACCEL, fixed_chunk=100,
                        init_throughput=1000.0)},
        {"g": _GateExecutor(started, gate, rate=1000.0, clock=vclock.now,
                            sleep=vclock.sleep)},
        clock=vclock.now)
    s.start()
    try:
        h = s.submit_epoch((0, 1000))
        assert started.wait(10.0)                   # chunk 1 in flight
        assert s.cancel_epoch(h, reason="caller")
        gate.set()
        res = h.result(timeout=30)
        assert res.cancelled and res.cancel_reason == "caller"
        assert res.iterations + res.unfinished == 1000
        assert res.iterations >= 100                # first chunk counted
    finally:
        gate.set()
        s.shutdown()


def test_cancel_of_completed_epoch_is_noop(vclock):
    s = _sched1(vclock)
    s.start()
    try:
        h = s.submit_epoch((0, 200))
        res = h.result(timeout=30)
        assert res.iterations == 200 and not res.cancelled
        # cancel after finalization: refused, result unchanged
        assert s.cancel_epoch(h) is False
        assert s.cancel_epoch(h) is False           # idempotent
        assert h.result().iterations == 200
        assert not h.result().cancelled
    finally:
        s.shutdown()


def test_double_cancel_returns_false_second_time(vclock):
    started, gate = threading.Event(), threading.Event()
    s = DynamicScheduler(
        {"g": GroupSpec("g", DeviceKind.ACCEL, fixed_chunk=100,
                        init_throughput=1000.0)},
        {"g": _GateExecutor(started, gate, rate=1000.0, clock=vclock.now,
                            sleep=vclock.sleep)},
        clock=vclock.now)
    s.start()
    try:
        h = s.submit_epoch((0, 100_000))
        assert started.wait(10.0)
        assert s.cancel_epoch(h) is True
        assert s.cancel_epoch(h) is False
        gate.set()
        res = h.result(timeout=30)
        assert res.cancelled
        assert res.iterations + res.unfinished == 100_000
    finally:
        gate.set()
        s.shutdown()


def test_cancel_races_group_death_without_losing_count(vclock):
    """The cancelled group's executor dies (ChunkFailure) while the
    cancel is landing — deterministically: the in-flight chunk blocks
    until the cancel has been flagged, then raises. The epoch must
    still finalize as cancelled, with every item either completed or in
    the unfinished tail."""
    from repro.core.dispatch import ChunkExecutor, ChunkFailure

    started, gate = threading.Event(), threading.Event()

    class DieOnReleaseExecutor(ChunkExecutor):
        def execute(self, token, rec):
            started.set()
            assert gate.wait(10.0)
            raise ChunkFailure("group died while cancel was landing")

    s = DynamicScheduler(
        {"g": GroupSpec("g", DeviceKind.ACCEL, fixed_chunk=100,
                        init_throughput=1000.0)},
        {"g": DieOnReleaseExecutor()},
        clock=vclock.now)
    s.start()
    try:
        h = s.submit_epoch((0, 1000))
        assert started.wait(10.0)           # chunk 1 in flight
        assert s.cancel_epoch(h, reason="caller")
        gate.set()                          # now the group dies
        res = h.result(timeout=30)
        assert res.cancelled
        assert "g" in res.failed_groups
        assert res.iterations + res.unfinished == 1000
    finally:
        gate.set()
        s.shutdown()


def test_deadline_mid_steal_conserves_count(vclock):
    """Range mode with a fast and a slow group: the fast group ends up
    stealing from the slow group's private range; a deadline landing in
    that regime must still account every item exactly once."""
    s = DynamicScheduler(
        {"fast": GroupSpec("fast", DeviceKind.BIG, init_throughput=4000.0,
                           min_chunk=4),
         "slow": GroupSpec("slow", DeviceKind.BIG, init_throughput=400.0,
                           min_chunk=4)},
        {"fast": SleepExecutor(rate=4000.0, clock=vclock.now,
                               sleep=vclock.sleep),
         "slow": SleepExecutor(rate=400.0, clock=vclock.now,
                               sleep=vclock.sleep)},
        chunk_mode="range", clock=vclock.now)
    s.start()
    try:
        h = s.submit_epoch((0, 2000),
                           deadline_s=vclock.now() + 0.25)
        res = h.result(timeout=30)
        assert res.cancelled and res.cancel_reason == "deadline"
        assert res.iterations + res.unfinished == 2000
    finally:
        s.shutdown()


# ---------------------------------------------------------------------------
# service-level: express lane + deadline enforcement (virtual clock)
# ---------------------------------------------------------------------------

def _make_service(vc, express=True, batch_jobs=2, pipeline_depth=2,
                  rate=1000.0, fixed_chunk=50, executor=None, **kw):
    def make_scheduler():
        ex = executor if executor is not None else \
            SleepExecutor(rate=rate, clock=vc.now, sleep=vc.sleep)
        return DynamicScheduler(
            {"g": GroupSpec("g", DeviceKind.ACCEL, fixed_chunk=fixed_chunk,
                            init_throughput=rate)},
            {"g": ex},
            clock=vc.now)
    return JobService(make_scheduler, queue=QueueManager(),
                      batch_jobs=batch_jobs, pipeline_depth=pipeline_depth,
                      clock=vc.now, sleep=vc.sleep, express=express, **kw)


def _drive_until(svc, predicate, timeout=30.0):
    """Drive the service synchronously (no daemon thread): pump until
    the predicate holds, bounded by real time."""
    _spin(predicate, timeout=timeout, step=lambda: svc._pump(0.0))


def test_express_lane_serves_urgent_within_one_batch_boundary(vclock):
    # step-controlled executor: the dispatcher parks at every chunk
    # entry with the clock frozen, so the injection point and the
    # latency measurement are exact virtual instants (no drift)
    ex = _StepExecutor(rate=1000.0, clock=vclock.now, sleep=vclock.sleep)
    svc = _make_service(vclock, executor=ex)
    try:
        # saturate: 6 batch-tier jobs × 100 items; batch_jobs=2 →
        # 200-item batches = 0.2 virtual s each, pipeline_depth=2
        batch = [Job(items=100, tier="batch") for _ in range(6)]
        for j in batch:
            svc.submit(j)
        _drive_until(svc, lambda: len(svc._inflight) == 2)
        assert ex.parked.wait(10.0)         # chunk 1 in hand, t frozen
        urgent = Job(items=10, tier="urgent")
        t_in = vclock.now()
        svc.submit(urgent)
        svc._pump(0.0)                      # express dispatch while frozen
        assert svc.stats.express_batches == 1
        # one in-hand batch chunk (0.05 s) + the urgent chunk (0.01 s):
        # the urgent epoch preempts at the very next chunk boundary
        ex.step(2)
        _drive_until(svc, lambda: urgent.state == JobState.DONE)
        # served within one batch boundary (0.2 s batch service time),
        # NOT after the 2-deep pipeline (≥ 0.4 s) — express lane +
        # preemption at work; exact: 0.06 virtual s
        assert urgent.finished_at - t_in < 0.2
        # work conservation: the preempted batch work still completes
        ex.resume()
        _drive_until(svc, lambda: all(j.state == JobState.DONE
                                      for j in batch), timeout=60.0)
        assert svc.stats.done == 7
    finally:
        ex.resume()
        svc.close()


def test_express_off_urgent_waits_out_the_pipeline(vclock):
    svc = _make_service(vclock, express=False)
    try:
        batch = [Job(items=100, tier="batch") for _ in range(6)]
        for j in batch:
            svc.submit(j)
        _drive_until(svc, lambda: len(svc._inflight) == 2)
        urgent = Job(items=10, tier="urgent")
        svc.submit(urgent)
        _drive_until(svc, lambda: urgent.state == JobState.DONE,
                     timeout=60.0)
        # without the express lane the urgent job waits for a pipeline
        # slot: the head batch must fully finalize (its jobs DONE)
        # before the urgent job is even dispatched — an ordering
        # assertion, immune to virtual-time drift between drive steps
        assert sum(1 for j in batch if j.state == JobState.DONE) >= 2
        assert svc.stats.express_batches == 0
        _drive_until(svc, lambda: all(j.state == JobState.DONE
                                      for j in batch), timeout=60.0)
    finally:
        svc.close()


def test_expired_job_shed_at_pop_counts_deadline_miss(vclock):
    svc = _make_service(vclock)
    try:
        job = Job(items=10, deadline_s=0.05)
        svc.submit(job)
        vclock.advance(0.1)                 # budget spent while queued
        _drive_until(svc, lambda: job.state == JobState.CANCELLED)
        assert job.meta.get("deadline_missed") is True
        assert svc.stats.deadline_misses == {"standard": 1}
        assert svc.stats.done == 0          # never dispatched
    finally:
        svc.close()


def test_inflight_deadline_cancels_batch_and_sheds_job(vclock):
    svc = _make_service(vclock, batch_jobs=1)
    try:
        job = Job(items=1000, deadline_s=0.2)   # needs 1.0 virtual s
        svc.submit(job)
        _drive_until(svc, lambda: job.state == JobState.CANCELLED,
                     timeout=60.0)
        assert job.meta.get("deadline_missed") is True
        assert svc.stats.deadline_misses == {"standard": 1}
        assert svc.stats.cancelled_batches == 1
        assert svc.stats.requeues == 0      # budget spent: shed, not retried
    finally:
        svc.close()


def test_cancelled_batch_requeues_deadline_free_jobs(vclock):
    """A batch cancelled for one job's deadline requeues its
    deadline-free members, which complete on retry (work conservation
    at the job level)."""
    svc = _make_service(vclock, batch_jobs=2)
    try:
        doomed = Job(items=900, deadline_s=0.2, priority=0)
        survivor = Job(items=100, priority=1)
        svc.submit(doomed)
        svc.submit(survivor)
        _drive_until(svc, lambda: doomed.state == JobState.CANCELLED,
                     timeout=60.0)
        _drive_until(svc, lambda: survivor.state == JobState.DONE,
                     timeout=60.0)
        assert svc.stats.deadline_misses == {"standard": 1}
        assert svc.stats.requeues >= 1
        assert svc.stats.done == 1
    finally:
        svc.close()


# ---------------------------------------------------------------------------
# queue-level: express pops, sharded express, admission deadline gate
# ---------------------------------------------------------------------------

def test_queue_pop_express_only_pops_urgent():
    q = QueueManager()
    u1, s1 = Job(tier="urgent"), Job(tier="standard")
    q.put(s1)
    q.put(u1)
    assert q.express_backlog() == 1
    assert q.pop_express(4) == [u1]
    assert q.pop_express(4) == []           # standard head: nothing popped
    assert q.express_backlog() == 0
    assert q.pop() is s1


def test_queue_heap_orders_tier_above_priority():
    q = QueueManager()
    s_hot = Job(tier="standard", priority=0)
    u_cold = Job(tier="urgent", priority=99)
    q.put(s_hot)
    q.put(u_cold)
    # tier dominates: the worst-priority urgent job beats the best
    # priority standard job
    assert q.pop() is u_cold
    assert q.pop() is s_hot


def test_sharded_pop_express_respects_quota_and_tier():
    reg = TenantRegistry.parse("a:weight=1,capped:weight=1:quota=1")
    q = ShardedQueueManager(reg)
    ua = Job(tier="urgent", tenant="a")
    uc1 = Job(tier="urgent", tenant="capped")
    uc2 = Job(tier="urgent", tenant="capped")
    sa = Job(tier="standard", tenant="a")
    for j in (sa, ua, uc1, uc2):
        q.put(j)
    assert q.express_backlog() == 3
    got = q.pop_express(8)
    # urgent jobs only; the capped tenant contributes exactly its quota
    assert all(j.tier == "urgent" for j in got)
    assert sorted(j.tenant for j in got) == ["a", "capped"]
    assert q.pop_express(8) == []           # capped at quota, "a" drained
    assert q.pop() is sa


def test_admission_rejects_infeasible_deadline():
    q = QueueManager()
    adm = AdmissionController(q, slo_delay_s=100.0)
    adm.on_group_join("g0", 10.0)           # 10 items/s capacity
    # 100 queued items → ~10 s projected delay; a 1 s budget cannot fit
    assert adm.admit(Job(items=100)).decision == Decision.ADMIT
    dec = adm.admit(Job(items=10, deadline_s=1.0))
    assert dec.decision == Decision.REJECT
    assert "deadline" in dec.reason
    assert adm.deadline_rejects == 1
    # same job without the deadline is happily admitted (SLO is 100 s)
    assert adm.admit(Job(items=10)).decision == Decision.ADMIT


def test_job_tier_validation_and_roundtrip():
    with pytest.raises(ValueError):
        Job(tier="vip")
    with pytest.raises(ValueError):
        Job(deadline_s=0.0)
    j = Job(tier="urgent", deadline_s=2.5)
    assert j.rank == tier_rank("urgent") == EXPRESS_RANK
    assert j.deadline_at == pytest.approx(j.created_at + 2.5)
    back = Job.from_json(j.to_json())
    assert back.tier == "urgent" and back.deadline_s == 2.5


# ---------------------------------------------------------------------------
# end-to-end preemption (the acceptance scenario)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_preemption_e2e_urgent_mid_flight_work_conserved(vclock):
    """Saturate the service with batch jobs, inject an urgent job
    mid-flight, and assert (a) it is served within one batch boundary
    and (b) the preempted batch work is fully requeued/absorbed — every
    job DONE, total completed items == total submitted items."""
    ex = _StepExecutor(rate=1000.0, clock=vclock.now, sleep=vclock.sleep)
    svc = _make_service(vclock, executor=ex, batch_jobs=4,
                        pipeline_depth=2)
    try:
        batch = [Job(items=50, tier="batch") for _ in range(16)]
        for j in batch:
            svc.submit(j)
        # 4-job batches × 50 items = 200 items = 0.2 virtual s per batch
        _drive_until(svc, lambda: len(svc._inflight) == 2)
        assert ex.parked.wait(10.0)         # chunk 1 in hand, t frozen
        urgent = Job(items=10, tier="urgent", deadline_s=5.0)
        t_in = vclock.now()
        svc.submit(urgent)
        svc._pump(0.0)                      # express dispatch while frozen
        ex.step(2)          # in-hand batch chunk (0.05) + urgent (0.01)
        _drive_until(svc, lambda: urgent.state == JobState.DONE,
                     timeout=60.0)
        assert urgent.finished_at - t_in < 0.2      # ≤ 1 batch boundary
        ex.resume()
        _drive_until(svc, lambda: all(j.state == JobState.DONE
                                      for j in batch), timeout=120.0)
        assert svc.stats.done == 17
        assert svc.stats.failed == 0
        assert svc.stats.deadline_misses == {}
        done_items = sum(j.items for j in batch) + urgent.items
        assert done_items == 16 * 50 + 10
        # scheduler-level conservation: completed item count across all
        # batches covers every submitted item
        per_group = svc.stats.per_group_items
        assert sum(per_group.values()) >= done_items
    finally:
        ex.resume()
        svc.close()


# ---------------------------------------------------------------------------
# deterministic conservation checks (the hypothesis variants live in
# tests/test_latency_tiers_properties.py behind importorskip)
# ---------------------------------------------------------------------------

def test_reclaim_conserves_item_count_deterministic():
    """Partitioner take/steal then reclaim (the cancellation path): every
    item is either in a taken chunk or back in the space — none lost,
    none duplicated. Deterministic sweep of the hypothesis property for
    environments without hypothesis installed."""
    from repro.core.partitioner import HeterogeneousPartitioner
    from repro.core.throughput import ThroughputTracker
    from repro.core.types import IterationSpace

    for total, takes in [(1, 0), (17, 3), (500, 7), (5000, 40),
                         (64, 100)]:
        specs = {
            "a": GroupSpec("a", DeviceKind.BIG, init_throughput=1000.0,
                           min_chunk=2),
            "b": GroupSpec("b", DeviceKind.BIG, init_throughput=250.0,
                           min_chunk=1),
        }
        space = IterationSpace(0, total)
        part = HeterogeneousPartitioner(space, specs,
                                        ThroughputTracker(0.5),
                                        base_quantum=64,
                                        chunk_mode="range")
        part.begin_epoch(space)
        taken = 0
        names = ["a", "b"]
        for i in range(takes):
            tok = part.next_token(names[i % 2], space)
            if tok is None:
                break
            taken += tok.chunk.size
        assert part.reclaim_space(space) >= 0
        assert taken + space.remaining == total
        # reclaim is idempotent: a second pass finds nothing left
        assert part.reclaim_space(space) == 0
        assert taken + space.remaining == total
