"""Infrastructure tests: checkpoint, data pipeline, sharding rules, runtime
(watchdog / straggler / elastic)."""
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import Checkpointer
from repro.core import (Chunk, ChunkRecord, DeviceKind, GroupSpec,
                        ThroughputTracker, Token)
from repro.data.pipeline import DataConfig, Prefetcher, SyntheticLMData
from repro.runtime import StragglerDetector, Watchdog
from repro.sharding.rules import ShardingRules
from jax.sharding import PartitionSpec as P


# ---------------------------------------------------------------------------
# checkpoint
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip(tmp_path):
    ck = Checkpointer(tmp_path, keep_n=2)
    tree = {"params": {"w": np.arange(6, dtype=np.float32).reshape(2, 3),
                       "blocks": (np.ones(2), np.zeros(3))},
            "step": np.int32(7)}
    ck.save(7, tree, meta={"loss": 1.5})
    out, meta = ck.restore()
    assert meta["step"] == 7 and meta["loss"] == 1.5
    np.testing.assert_array_equal(out["params"]["w"], tree["params"]["w"])
    np.testing.assert_array_equal(out["params"]["blocks"][0], np.ones(2))


def test_checkpoint_gc_and_latest(tmp_path):
    ck = Checkpointer(tmp_path, keep_n=2)
    for s in (1, 2, 3, 4):
        ck.save(s, {"x": np.ones(1) * s})
    assert ck.steps() == [3, 4]
    assert ck.latest_step() == 4
    out, _ = ck.restore(3)
    assert out["x"][0] == 3.0


def test_checkpoint_async(tmp_path):
    ck = Checkpointer(tmp_path)
    ck.save_async(1, {"x": np.ones(4)})
    ck.wait()
    assert ck.latest_step() == 1


def test_checkpoint_jax_arrays(tmp_path):
    ck = Checkpointer(tmp_path)
    ck.save(2, {"w": jnp.ones((3, 3), jnp.bfloat16)})
    out, _ = ck.restore()
    assert out["w"].dtype == jnp.bfloat16
    np.testing.assert_array_equal(np.asarray(out["w"], np.float32),
                                  np.ones((3, 3), np.float32))


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------

def test_data_deterministic_and_idempotent():
    d = SyntheticLMData(DataConfig(seq_len=16, vocab=100, seed=3))
    b1 = d.batch(10, 14)
    b2 = d.batch(10, 14)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    # chunk identity: any group materializes the same range identically
    sub = d.batch(12, 14)
    np.testing.assert_array_equal(b1["tokens"][2:], sub["tokens"])


def test_data_padding_masked():
    d = SyntheticLMData(DataConfig(seq_len=8, vocab=50, seed=0))
    b = d.batch(0, 3, pad_to=8)
    assert b["tokens"].shape == (8, 8)
    assert b["loss_mask"][:3].all() and not b["loss_mask"][3:].any()


def test_prefetcher_double_buffers():
    calls = []

    def make(i):
        calls.append(i)
        return {"i": i}

    pf = Prefetcher(make, depth=2)
    got = [pf.next()["i"] for _ in range(5)]
    pf.stop()
    assert got == [0, 1, 2, 3, 4]


# ---------------------------------------------------------------------------
# sharding rules
# ---------------------------------------------------------------------------

class FakeMesh:
    def __init__(self, shape, names):
        self.axis_names = names
        self.devices = np.zeros(shape)


def test_rules_basic_mapping():
    r = ShardingRules()
    mesh = FakeMesh((16, 16), ("data", "model"))
    spec = r.spec(mesh, ("vocab", "embed"), (64000, 4096))
    assert spec == P("model", "data")


def test_rules_divisibility_fallback():
    r = ShardingRules()
    mesh = FakeMesh((16, 16), ("data", "model"))
    # 40 heads % 16 != 0 -> head axis replicated
    spec = r.spec(mesh, ("embed", "heads", "head_dim"), (5120, 40, 128))
    assert spec == P("data")


def test_rules_multi_axis_prefix_fallback():
    r = ShardingRules()
    mesh = FakeMesh((2, 16, 16), ("pod", "data", "model"))
    # batch 32 divisible by pod·data=32 -> both axes used
    assert r.spec(mesh, ("act_batch", None), (32, 7)) == P(("pod", "data"))
    # batch 2 only divisible by pod -> prefix fallback
    assert r.spec(mesh, ("act_batch", None), (2, 7)) == P("pod")
    # batch 1 -> replicated
    assert r.spec(mesh, ("act_batch", None), (1, 7)) == P()


def test_rules_no_axis_reuse():
    r = ShardingRules()
    mesh = FakeMesh((16, 16), ("data", "model"))
    # both dims map to model -> second falls back (no double use)
    spec = r.spec(mesh, ("vocab", "mlp"), (1600, 1600))
    assert spec == P("model")


def test_long_context_overrides():
    r = ShardingRules().for_shape_kind("long_decode")
    mesh = FakeMesh((2, 16, 16), ("pod", "data", "model"))
    spec = r.spec(mesh, ("cache_batch", "cache_seq", "cache_kv_heads", None),
                  (1, 524288, 32, 64))
    assert spec == P(None, ("pod", "data"), "model")


# ---------------------------------------------------------------------------
# runtime: watchdog + straggler
# ---------------------------------------------------------------------------

def _rec(group, size, t0, t1):
    return ChunkRecord(Token(Chunk(0, size), group, DeviceKind.BIG),
                       tg1=t0, tg5=t1, tc1=t0, tc2=t0, tc3=t1)


def test_watchdog_flags_hung_group(vclock):
    tr = ThroughputTracker()
    tr.seed("g", 1000.0)
    dead = []
    wd = Watchdog(tr, timeout_factor=1.0, min_timeout_s=0.05,
                  on_dead=dead.append, clock=vclock.now)
    wd.chunk_started("g", expected_items=10)   # expected 0.01s
    vclock.advance(0.12)
    assert wd.check() == ["g"]
    assert dead == ["g"]
    assert wd.check() == []                    # only reported once


def test_watchdog_heartbeat_clears(vclock):
    tr = ThroughputTracker()
    tr.seed("g", 1000.0)
    wd = Watchdog(tr, timeout_factor=1.0, min_timeout_s=0.05,
                  clock=vclock.now)
    wd.chunk_started("g", 10)
    wd.chunk_finished("g")
    vclock.advance(0.12)
    assert wd.check() == []


def test_straggler_detector_normalizes_by_own_baseline():
    tr = ThroughputTracker(alpha=1.0)
    det = StragglerDetector(tr, threshold=0.5, warmup_chunks=1)
    # healthy: λ=100 for "fast", λ=10 for "slow-but-steady"
    for t in range(3):
        tr.update(_rec("fast", 100, t, t + 1.0))
        tr.update(_rec("steady", 10, t, t + 1.0))
    assert det.observe() == []
    # fast degrades to 30 (<50% of its own 100 baseline)
    tr.update(_rec("fast", 30, 10, 11.0))
    reports = det.observe()
    assert [r.group for r in reports] == ["fast"]
    assert reports[0].slowdown < 0.5
