"""End-to-end integration: hetero trainer (loss decreases, straggler
rebalances, checkpoint resume) and serve engine."""
import jax
import numpy as np
import pytest

from repro.checkpoint import Checkpointer
from repro.configs.registry import get_reduced_config
from repro.core.types import DeviceKind
from repro.serve.engine import HeteroServeEngine
from repro.train.optimizer import OptConfig
from repro.train.trainer import GroupDef, HeteroTrainer

pytestmark = pytest.mark.slow


@pytest.fixture(scope="module")
def tiny_cfg():
    return get_reduced_config("stablelm-1.6b").replace(
        n_layers=2, dtype="float32")


def test_trainer_loss_decreases_and_rebalances(tiny_cfg):
    groups = [
        GroupDef("accel", DeviceKind.ACCEL, fixed_chunk=8, async_depth=2),
        GroupDef("cpu0", DeviceKind.BIG, slowdown=4.0),
    ]
    tr = HeteroTrainer(tiny_cfg, groups, seq_len=32, global_batch=32,
                       oc=OptConfig(lr=1e-3, warmup_steps=1),
                       repeat_data=True)
    reps = tr.train(4)
    assert reps[-1].loss < reps[0].loss
    # every step processed the full global batch (work conservation)
    for r in reps:
        assert sum(r.per_group_items.values()) >= 32
    # the slowed group should receive the minority of samples by the end.
    # Aggregate over the post-warmup steps: a single 32-item epoch is 4
    # chunks, and one OS/JIT hiccup on the accel thread can flip any one
    # step's split regardless of scheduler quality (pre-existing flake)
    accel = sum(r.per_group_items.get("accel", 0) for r in reps[1:])
    cpu0 = sum(r.per_group_items.get("cpu0", 0) for r in reps[1:])
    assert accel > cpu0


def test_trainer_checkpoint_resume(tiny_cfg, tmp_path):
    groups = [GroupDef("accel", DeviceKind.ACCEL, fixed_chunk=16)]
    tr = HeteroTrainer(tiny_cfg, groups, seq_len=32, global_batch=16,
                       oc=OptConfig(lr=1e-3, warmup_steps=1), seed=1)
    tr.train(2)
    ck = Checkpointer(tmp_path)
    ck.save(tr.step_idx, {"params": tr.params, "opt": tr.opt})

    tr2 = HeteroTrainer(tiny_cfg, groups, seq_len=32, global_batch=16,
                        oc=OptConfig(lr=1e-3, warmup_steps=1), seed=1)
    tree, meta = ck.restore()
    tr2.params = jax.tree.map(jax.numpy.asarray, tree["params"])
    tr2.opt = jax.tree.map(jax.numpy.asarray, tree["opt"])
    tr2.step_idx = meta["step"]
    rep = tr2.train_step()
    assert rep.step == 3
    assert np.isfinite(rep.loss)


def test_trainer_survives_group_failure(tiny_cfg):
    """A group dying mid-step must not lose samples: its in-flight chunk is
    re-queued and absorbed by the survivors (end-to-end fault tolerance)."""
    # fail_after_chunks=0: cpu0 dies on its very first chunk — with
    # fail_after_chunks=1 the test raced accel draining the space before
    # cpu0 could reach a second chunk (flaky on loaded hosts)
    groups = [
        GroupDef("accel", DeviceKind.ACCEL, fixed_chunk=8),
        GroupDef("cpu0", DeviceKind.BIG, fail_after_chunks=0),
    ]
    tr = HeteroTrainer(tiny_cfg, groups, seq_len=32, global_batch=32,
                       oc=OptConfig(lr=1e-3, warmup_steps=1))
    rep = tr.train_step()
    assert "cpu0" in rep.failed_groups
    assert rep.examples >= 32          # full batch despite the failure
    assert np.isfinite(rep.loss)
    # next step proceeds on the surviving group alone
    groups[1].fail_after_chunks = 0
    rep2 = tr.train_step()
    assert rep2.examples >= 32


def test_serve_engine_completes_all_requests(tiny_cfg):
    groups = [
        GroupDef("accel", DeviceKind.ACCEL, fixed_chunk=4, async_depth=2),
        GroupDef("cpu0", DeviceKind.BIG, slowdown=2.0),
    ]
    eng = HeteroServeEngine(tiny_cfg, groups, prompt_len=16,
                            decode_tokens=4)
    rep = eng.serve(12)
    assert rep.requests == 12
    assert rep.new_tokens == 48
    assert set(rep.per_group_items) <= {"accel", "cpu0"}
    assert sum(rep.per_group_items.values()) == 12
