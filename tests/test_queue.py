"""Unit tests for repro.queue: state machine legality, heap ordering with
requeue, admission backpressure under synthetic overload, straggler
derating, journal crash-recovery replay + compaction, and the JobService
continuous double-buffered drain."""
import json
import os

import pytest

from repro.core import DeviceKind, DynamicScheduler, GroupSpec, SleepExecutor
from repro.queue import (AdmissionController, Decision, IllegalTransition,
                         Job, JobService, JobState, JournalStore,
                         QueueManager, percentiles)
from repro.core.throughput import ThroughputTracker
from repro.runtime.elastic import ElasticController
from repro.runtime.straggler import StragglerDetector


# ---------------------------------------------------------------------------
# Job state machine
# ---------------------------------------------------------------------------

def test_legal_lifecycle_stamps_timestamps():
    j = Job(items=4)
    assert j.state == JobState.PENDING and j.queue_delay is None
    j.transition(JobState.ADMITTED)
    assert j.admitted_at is not None
    j.transition(JobState.RUNNING)
    assert j.started_at is not None and j.attempts == 1
    assert j.queue_delay is not None and j.queue_delay >= 0.0
    j.transition(JobState.DONE)
    assert j.terminal and j.finished_at is not None


@pytest.mark.parametrize("start,bad", [
    (JobState.PENDING, JobState.RUNNING),
    (JobState.PENDING, JobState.DONE),
    (JobState.ADMITTED, JobState.DONE),
    (JobState.ADMITTED, JobState.REQUEUED),
    (JobState.RUNNING, JobState.ADMITTED),
    (JobState.REQUEUED, JobState.RUNNING),
    (JobState.REQUEUED, JobState.DONE),
    (JobState.DONE, JobState.RUNNING),
    (JobState.FAILED, JobState.ADMITTED),
    (JobState.CANCELLED, JobState.PENDING),
])
def test_illegal_transitions_raise(start, bad):
    j = Job()
    j.state = start
    with pytest.raises(IllegalTransition):
        j.transition(bad)
    assert j.state == start        # unchanged on failure


def test_requeue_cycle_counts_attempts():
    j = Job(max_attempts=3)
    for expect in (1, 2, 3):
        j.transition(JobState.ADMITTED)
        j.transition(JobState.RUNNING)
        assert j.attempts == expect
        if expect < 3:
            j.transition(JobState.REQUEUED)
    assert j.attempts_left == 0
    j.transition(JobState.DONE)


def test_job_json_round_trip():
    j = Job(items=7, priority=2, tenant="t1", meta={"k": 1})
    j.transition(JobState.ADMITTED)
    back = Job.from_json(j.to_json())
    assert back.job_id == j.job_id and back.state == JobState.ADMITTED
    assert back.items == 7 and back.priority == 2 and back.meta == {"k": 1}
    assert back.admitted_at == j.admitted_at


def test_invalid_items_rejected():
    with pytest.raises(ValueError):
        Job(items=0)


# ---------------------------------------------------------------------------
# QueueManager heap
# ---------------------------------------------------------------------------

def test_priority_order_with_fifo_ties():
    q = QueueManager()
    lo1, hi, lo2 = Job(priority=5), Job(priority=0), Job(priority=5)
    for j in (lo1, hi, lo2):
        q.put(j)
    assert q.pop() is hi
    assert q.pop() is lo1          # FIFO among equal priorities
    assert q.pop() is lo2
    assert q.pop() is None


def test_requeue_goes_behind_equal_priority_work():
    q = QueueManager()
    a, b = Job(priority=1), Job(priority=1)
    q.put(a)
    q.mark_running(q.pop(), "g0")
    q.put(b)                                   # admitted while a runs
    q.mark_finished(a, JobState.REQUEUED)
    q.requeue(a)
    assert q.pop() is b and q.pop() is a       # a re-enters behind b
    # but higher priority still preempts older queued work
    urgent = Job(priority=0)
    q.mark_running(b, "g0")
    q.mark_finished(b, JobState.REQUEUED)
    q.requeue(b)
    q.put(urgent)
    assert q.pop() is urgent


def test_cancel_is_lazy_and_skipped_at_pop():
    q = QueueManager()
    a, b = Job(priority=0), Job(priority=1)
    q.put(a), q.put(b)
    assert q.cancel(a.job_id)
    assert not q.cancel(a.job_id)              # already cancelled
    assert a.state == JobState.CANCELLED
    assert q.pop() is b                        # b stays ADMITTED until
    assert q.pop() is None                     # mark_running binds it


def test_backlog_and_inflight_accounting():
    q = QueueManager()
    jobs = [Job(items=10), Job(items=20), Job(items=30)]
    for j in jobs:
        q.put(j)
    assert q.backlog_items() == 60 and q.depth() == 3
    j = q.pop()
    q.mark_running(j, "accel")
    assert q.backlog_items() == 50 and q.inflight("accel") == 1
    q.mark_finished(j, JobState.DONE)
    assert q.inflight() == 0
    assert q.counts()["done"] == 1 and q.counts()["admitted"] == 2


# ---------------------------------------------------------------------------
# Admission backpressure
# ---------------------------------------------------------------------------

def _controller(lam=100.0, slo=1.0):
    q = QueueManager()
    adm = AdmissionController(q, slo_delay_s=slo, defer_factor=4.0)
    adm.on_group_join("g0", lam)
    return q, adm


def test_admit_defer_reject_bands():
    q, adm = _controller(lam=100.0, slo=1.0)       # capacity 100 items/s
    assert adm.admit(Job(items=50)).decision == Decision.ADMIT
    # backlog 50 + 60 = 110 -> 1.1s > SLO, < 4×SLO
    d = adm.admit(Job(items=60))
    assert d.decision == Decision.DEFER and d.projected_delay_s > 1.0
    # a monster job lands beyond 4×SLO and is shed
    big = Job(items=1000)
    assert adm.admit(big).decision == Decision.REJECT
    assert big.state == JobState.CANCELLED
    assert "rejected_delay_s" in big.meta
    assert (adm.admitted, adm.deferred, adm.rejected) == (1, 1, 1)


def test_backpressure_bounds_queue_under_overload():
    q, adm = _controller(lam=10.0, slo=1.0)        # capacity 10 items/s
    decisions = [adm.admit(Job(items=5)) for _ in range(100)]
    admitted = sum(d.decision == Decision.ADMIT for d in decisions)
    # projected delay caps the backlog at slo×capacity items
    assert q.backlog_items() <= 10
    assert admitted == 2
    # with the backlog pinned at the SLO bound, the rest sit in the defer
    # band (retryable), none sneak into the queue
    assert sum(d.decision == Decision.DEFER for d in decisions) == 98
    # a job too large for even the defer band is shed outright
    assert adm.admit(Job(items=500)).decision == Decision.REJECT


def test_capacity_follows_group_leave_and_tracker():
    q = QueueManager()
    tr = ThroughputTracker()
    adm = AdmissionController(q, tracker=tr, slo_delay_s=1.0)
    adm.on_group_join("g0", 100.0)
    adm.on_group_join("g1", 100.0)
    tr.seed("g0", 100.0), tr.seed("g1", 100.0)
    assert adm.capacity_items_s() == pytest.approx(200.0)
    adm.on_group_leave("g1")
    assert adm.capacity_items_s() == pytest.approx(100.0)


def test_straggler_derates_capacity_before_death():
    """A group slowing mid-run advertises less capacity via the detector →
    admission derate path, while still being a live (not dead) group."""
    groups = {
        "fast": GroupSpec("fast", DeviceKind.BIG, init_throughput=50_000,
                          min_chunk=64),
        "slow": GroupSpec("slow", DeviceKind.BIG, init_throughput=50_000,
                          min_chunk=64),
    }
    execs = {
        "fast": SleepExecutor(rate=50_000),
        # healthy through all of epoch 1 (~8 chunks), then 10x slower
        # partway through epoch 2 — a mid-run straggler
        "slow": SleepExecutor(rate=50_000, slow_after=30, slow_factor=10.0),
    }
    # EWMA (not last-interval) so a shrunken final chunk's noisy λ cannot
    # flag the healthy group; 0.4 threshold leaves margin for sleep jitter
    sched = DynamicScheduler(groups, execs, alpha=0.5)
    q = QueueManager()
    adm = AdmissionController(q, tracker=sched.tracker, slo_delay_s=1.0)
    adm.on_group_join("fast", 50_000)
    adm.on_group_join("slow", 50_000)
    det = StragglerDetector(sched.tracker, threshold=0.4, warmup_chunks=3)
    sched.start()
    try:
        sched.submit_epoch((0, 4_096)).result(timeout=30)
        det.observe()                       # records healthy baselines
        sched.submit_epoch((0, 24_000)).result(timeout=30)
        cap_before = adm.capacity_items_s()
        reports = det.observe()
        assert any(r.group == "slow" for r in reports)
        assert all(r.group != "fast" for r in reports)
        adm.update_stragglers({r.group: r.slowdown for r in reports})
        # capacity drops, but the group is derated, not declared dead
        assert adm.capacity_items_s() < cap_before
        assert adm.derate("slow") < 1.0 and adm.derate("fast") == 1.0
        assert "slow" in adm.groups() and "slow" in sched.live_groups()
    finally:
        sched.shutdown()


def test_elastic_controller_notifies_admission():
    groups = {"g0": GroupSpec("g0", DeviceKind.BIG, init_throughput=50.0)}
    execs = {"g0": SleepExecutor(rate=50.0)}
    sched = DynamicScheduler(groups, execs)
    q = QueueManager()
    adm = AdmissionController(q, slo_delay_s=1.0)
    adm.on_group_join("g0", 50.0)
    ec = ElasticController(sched, admission=adm)
    ec.join("g1", DeviceKind.BIG, SleepExecutor(rate=50.0))
    assert "g1" in adm.groups()
    ec.leave("g1")
    assert "g1" not in adm.groups()


# ---------------------------------------------------------------------------
# Journal replay / crash recovery
# ---------------------------------------------------------------------------

def test_journal_replay_last_write_wins(tmp_path):
    path = str(tmp_path / "journal.jsonl")
    a, b = Job(items=1), Job(items=2)
    with JournalStore(path) as js:
        js.record(a, "submitted")
        a.transition(JobState.ADMITTED); js.record(a)
        a.transition(JobState.RUNNING); js.record(a)
        a.transition(JobState.DONE); js.record(a)
        b.transition(JobState.ADMITTED); js.record(b)
    final = JournalStore.replay(path)
    assert final[a.job_id].state == JobState.DONE
    assert final[b.job_id].state == JobState.ADMITTED


def test_journal_tolerates_torn_tail(tmp_path):
    path = str(tmp_path / "journal.jsonl")
    a = Job()
    with JournalStore(path) as js:
        a.transition(JobState.ADMITTED); js.record(a)
    with open(path, "a") as fh:                  # crash mid-write
        fh.write('{"ts": 1.0, "event": "running", "job": {"job_id"')
    final = JournalStore.replay(path)
    assert final[a.job_id].state == JobState.ADMITTED


def test_journal_compact_keeps_latest_record_per_job(tmp_path):
    path = str(tmp_path / "journal.jsonl")
    jobs = [Job(items=i + 1) for i in range(5)]
    js = JournalStore(path)
    for j in jobs:
        js.record(j, "submitted")
        j.transition(JobState.ADMITTED); js.record(j)
    for j in jobs[:3]:                     # three full lifecycles
        j.transition(JobState.RUNNING); js.record(j)
        j.transition(JobState.DONE); js.record(j)
    before = JournalStore.replay(path)
    n_lines_before = sum(1 for _ in open(path))
    assert n_lines_before == 5 * 2 + 3 * 2

    kept = js.compact()
    assert kept == 5
    n_lines_after = sum(1 for _ in open(path))
    assert n_lines_after == 5              # one line per job

    # replay after compaction matches replay before
    after = JournalStore.replay(path)
    assert set(after) == set(before)
    for jid, job in before.items():
        assert after[jid].state == job.state
        assert after[jid].items == job.items
        assert after[jid].attempts == job.attempts

    # the store keeps appending fine after compaction
    jobs[3].transition(JobState.RUNNING); js.record(jobs[3])
    js.close()
    assert JournalStore.replay(path)[jobs[3].job_id].state \
        == JobState.RUNNING


def test_recover_requeues_inflight_jobs(tmp_path):
    path = str(tmp_path / "journal.jsonl")
    running, queued, done = Job(), Job(), Job()
    with JournalStore(path) as js:
        for j in (running, queued, done):
            j.transition(JobState.ADMITTED); js.record(j)
        running.transition(JobState.RUNNING); js.record(running)
        done.transition(JobState.RUNNING)
        done.transition(JobState.DONE); js.record(done)
    to_requeue, final = JournalStore.recover(path)
    ids = {j.job_id for j in to_requeue}
    assert ids == {running.job_id, queued.job_id}
    states = {j.job_id: j.state for j in to_requeue}
    assert states[running.job_id] == JobState.REQUEUED
    assert states[queued.job_id] == JobState.ADMITTED
    assert final[done.job_id].state == JobState.DONE
    # recovered jobs slot straight back into a queue
    q = QueueManager()
    for j in to_requeue:
        if j.state == JobState.REQUEUED:
            q.requeue(j)
        else:
            q.put(j)
    assert q.depth() == 2


# ---------------------------------------------------------------------------
# JobService drain loop (SleepExecutor-backed scheduler)
# ---------------------------------------------------------------------------

def _make_sched():
    groups = {
        "accel": GroupSpec("accel", DeviceKind.ACCEL, fixed_chunk=64,
                           init_throughput=50_000),
        "cpu0": GroupSpec("cpu0", DeviceKind.BIG, init_throughput=10_000),
    }
    execs = {"accel": SleepExecutor(rate=50_000),
             "cpu0": SleepExecutor(rate=10_000)}
    return DynamicScheduler(groups, execs)


def test_service_drains_all_jobs(tmp_path):
    path = str(tmp_path / "journal.jsonl")
    svc = JobService(_make_sched, journal=JournalStore(path), batch_jobs=4)
    jobs = [Job(items=32, priority=i % 3) for i in range(12)]
    for j in jobs:
        svc.submit(j)
    assert svc.run_until_idle(timeout_s=30)
    assert all(j.state == JobState.DONE for j in jobs)
    assert svc.stats.done == 12 and svc.stats.failed == 0
    assert sum(svc.stats.per_group_items.values()) >= 12 * 32
    final = JournalStore.replay(path)
    assert all(final[j.job_id].state == JobState.DONE for j in jobs)


def test_service_requeues_after_total_run_failure():
    calls = {"n": 0}

    def flaky_sched():
        calls["n"] += 1
        if calls["n"] == 1:        # every group dies on its first chunk
            groups = {"g0": GroupSpec("g0", DeviceKind.BIG,
                                      init_throughput=1000)}
            execs = {"g0": SleepExecutor(rate=1000, fail_after=0)}
            return DynamicScheduler(groups, execs)
        return _make_sched()

    svc = JobService(flaky_sched, batch_jobs=8)
    jobs = [Job(items=16) for _ in range(4)]
    for j in jobs:
        svc.submit(j)
    assert svc.run_until_idle(timeout_s=30)
    assert all(j.state == JobState.DONE for j in jobs)
    assert svc.stats.requeues >= 1
    assert all(j.attempts >= 2 for j in jobs)


def test_service_fails_job_when_attempts_exhausted():
    def dead_sched():
        groups = {"g0": GroupSpec("g0", DeviceKind.BIG,
                                  init_throughput=1000)}
        execs = {"g0": SleepExecutor(rate=1000, fail_after=0)}
        return DynamicScheduler(groups, execs)

    svc = JobService(dead_sched, batch_jobs=2)
    job = Job(items=8, max_attempts=2)
    svc.submit(job)
    assert svc.run_until_idle(timeout_s=30)
    assert job.state == JobState.FAILED
    assert job.attempts == 2


def test_deferred_jobs_admitted_as_backlog_drains():
    q = QueueManager()
    adm = AdmissionController(q, slo_delay_s=1.0, defer_factor=50.0)
    adm.on_group_join("accel", 50_000)
    adm.on_group_join("cpu0", 10_000)
    svc = JobService(_make_sched, queue=q, admission=adm, batch_jobs=4)
    # 60k-item SLO budget; 40k-item jobs: first admits, second defers
    jobs = [Job(items=40_000) for _ in range(2)]
    decisions = [svc.submit(j) for j in jobs]
    assert decisions[0].decision == Decision.ADMIT
    assert decisions[1].decision == Decision.DEFER
    assert svc.run_until_idle(timeout_s=60)
    assert all(j.state == JobState.DONE for j in jobs)


def test_service_double_buffered_drain_overlaps_batches():
    """The continuous drain dispatches batch N+1 while batch N is still in
    flight: submission/finish windows of consecutive batches overlap."""
    svc = JobService(_make_sched, batch_jobs=2, pipeline_depth=2)
    jobs = [Job(items=2_000) for _ in range(8)]    # 4 batches, ~40ms each
    for j in jobs:
        svc.submit(j)
    assert svc.run_until_idle(timeout_s=60)
    assert all(j.state == JobState.DONE for j in jobs)
    windows = svc.stats.batch_windows
    assert len(windows) == 4
    # batch k+1 was submitted before batch k finished, at least once
    # (with a warm pipeline, every boundary overlaps)
    assert svc.stats.overlapped_batches() >= 1
    svc.close()


def test_service_runtime_persists_across_batches():
    """The persistent JobService builds the scheduler once: same runtime
    object and same dispatcher threads across batches."""
    built = []

    def factory():
        s = _make_sched()
        built.append(s)
        return s

    svc = JobService(factory, batch_jobs=1)
    jobs = [Job(items=512) for _ in range(6)]
    for j in jobs:
        svc.submit(j)
    assert svc.run_until_idle(timeout_s=30)
    assert all(j.state == JobState.DONE for j in jobs)
    assert svc.stats.batches == 6
    assert len(built) == 1                 # no per-batch rebuild
    svc.close()


def test_percentiles_nearest_rank():
    xs = list(range(1, 101))
    p = percentiles(xs)
    assert p["p50"] == 50 and p["p95"] == 95 and p["p99"] == 99
    assert percentiles([]) == {"p50": 0.0, "p95": 0.0, "p99": 0.0}
