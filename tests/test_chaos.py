"""Chaos plane: deterministic fault plans, injector semantics, and the
hardening the soak flushed out (torn-tail truncation, per-line CRCs,
bounded deferred retry, brownout shedding, dispatcher exception guard).
"""
import json
import threading
import time

import pytest

from repro import telemetry as telemetry_mod
from repro.chaos import (ChaosExecutor, ChaosInjector, ChaosSink,
                         FaultEvent, FaultPlan, KINDS)
from repro.core import (Chunk, ChunkFailure, ChunkRecord, DeviceKind,
                        DynamicScheduler, GroupSpec, SleepExecutor, Token)
from repro.core.throughput import ThroughputTracker
from repro.federation import ReplicaSink
from repro.queue import (AdmissionController, Job, JobService, JobState,
                         JournalStore, QueueManager)
from repro.queue.admission import Decision
from repro.runtime.fault_tolerance import Watchdog

RIDS = ["r0", "r1", "r2"]
GROUPS = [f"{r}/accel" for r in RIDS]


# ---------------------------------------------------------------------------
# plans: determinism + generator safety envelope
# ---------------------------------------------------------------------------

def test_same_seed_produces_byte_identical_plan():
    a = FaultPlan.generate(11, 2.0, RIDS, GROUPS).to_json()
    b = FaultPlan.generate(11, 2.0, RIDS, GROUPS).to_json()
    assert a == b                      # replayability: --chaos-seed
    assert a != FaultPlan.generate(12, 2.0, RIDS, GROUPS).to_json()


def test_plan_json_roundtrip():
    plan = FaultPlan.generate(3, 1.5, RIDS, GROUPS, events_per_s=4.0)
    back = FaultPlan.from_json(plan.to_json())
    assert back.events == plan.events
    assert back.seed == plan.seed and back.horizon_s == plan.horizon_s


def test_generator_respects_safety_envelope():
    for seed in range(40):
        plan = FaultPlan.generate(seed, 2.0, RIDS, GROUPS,
                                  events_per_s=6.0)
        kills = [e for e in plan.events
                 if e.layer == "federation" and e.kind == "kill"]
        assert len(kills) <= len(RIDS) - 1
        assert len({k.target for k in kills}) == len(kills)
        for k in kills:                # middle 60% — work exists to lose
            assert 0.2 * plan.horizon_s <= k.at_s <= 0.8 * plan.horizon_s
        mirrors = [e for e in plan.events if e.kind == "mirror_fail"]
        for m in mirrors:              # replica gap never overlaps a
            for k in kills:            # kill of the same runtime
                if k.target == m.target:
                    assert not (m.at_s <= k.at_s <= m.end_s)
        for e in plan.events:
            assert e.kind in KINDS[e.layer]


# ---------------------------------------------------------------------------
# injector: one-shot vs window semantics
# ---------------------------------------------------------------------------

def _fake_clock():
    t = [0.0]
    return t, (lambda: t[0])


def test_one_shot_consumed_exactly_once():
    t, clk = _fake_clock()
    plan = FaultPlan.compose(
        [FaultEvent(at_s=0.5, layer="executor", kind="chunk_exception",
                    target="g")], horizon_s=1.0)
    inj = ChaosInjector(plan, clock=clk)
    inj.start()
    assert inj.take("executor", "chunk_exception", "g") is None  # not due
    t[0] = 0.6
    assert inj.take("executor", "chunk_exception", "other") is None
    assert inj.take("executor", "chunk_exception", "g") is not None
    assert inj.take("executor", "chunk_exception", "g") is None  # consumed
    assert inj.injected == 1


def test_window_active_inside_range_counted_once():
    t, clk = _fake_clock()
    plan = FaultPlan.compose(
        [FaultEvent(at_s=1.0, layer="executor", kind="slowdown",
                    target="g", duration_s=0.5, magnitude=0.01)],
        horizon_s=2.0)
    inj = ChaosInjector(plan, clock=clk)
    inj.start()
    t[0] = 0.9
    assert inj.active("executor", "slowdown", "g") is None
    t[0] = 1.2
    assert inj.active("executor", "slowdown", "g") is not None
    assert inj.active("executor", "slowdown", "g") is not None
    assert inj.injected == 1           # window counted once, not per query
    t[0] = 1.6
    assert inj.active("executor", "slowdown", "g") is None
    t[0] = 2.1
    assert inj.done()


def test_nothing_fires_before_start():
    plan = FaultPlan.compose(
        [FaultEvent(at_s=0.0, layer="executor", kind="chunk_exception",
                    target="g")], horizon_s=1.0)
    inj = ChaosInjector(plan)
    assert inj.take("executor", "chunk_exception", "g") is None
    assert inj.active("executor", "chunk_exception", "g") is None


def test_skewed_clock_applies_inside_window_only():
    t, clk = _fake_clock()
    plan = FaultPlan.compose(
        [FaultEvent(at_s=1.0, layer="queue", kind="clock_skew",
                    target="r0", duration_s=1.0, magnitude=0.25)],
        horizon_s=3.0)
    inj = ChaosInjector(plan, clock=clk)
    inj.start()
    base_t = [100.0]
    skewed = inj.skewed_clock("r0", base=lambda: base_t[0])
    assert skewed() == 100.0
    t[0] = 1.5
    assert skewed() == pytest.approx(100.25)
    t[0] = 2.5
    assert skewed() == 100.0


def test_wrap_queue_swallows_notifies_inside_window():
    t, clk = _fake_clock()
    plan = FaultPlan.compose(
        [FaultEvent(at_s=1.0, layer="queue", kind="listener_drop",
                    target="r0", duration_s=1.0)], horizon_s=3.0)
    inj = ChaosInjector(plan, clock=clk)
    inj.start()
    queue = inj.wrap_queue(QueueManager(), "r0")
    hits = []
    queue.add_listener(lambda *a: hits.append(1))
    j = Job(items=4)
    j.transition(JobState.ADMITTED)
    queue.put(j)
    assert len(hits) == 1              # outside the window: delivered
    t[0] = 1.5
    j2 = Job(items=4)
    j2.transition(JobState.ADMITTED)
    queue.put(j2)
    assert len(hits) == 1              # swallowed inside the window


# ---------------------------------------------------------------------------
# executor faults
# ---------------------------------------------------------------------------

def _token(group="g", size=16):
    return Token(Chunk(0, size), group, DeviceKind.ACCEL)


def test_chunk_exception_raises_in_band_failure():
    plan = FaultPlan.compose(
        [FaultEvent(at_s=0.0, layer="executor", kind="chunk_exception",
                    target="g")], horizon_s=1.0)
    inj = ChaosInjector(plan)
    inj.start()
    cx = ChaosExecutor(SleepExecutor(rate=1e6), "g", inj)
    tok = _token()
    with pytest.raises(ChunkFailure):
        cx.execute(tok, ChunkRecord(tok))
    cx.execute(tok, ChunkRecord(tok))  # one-shot: next chunk is clean


def test_hang_trips_watchdog_mid_sleep():
    plan = FaultPlan.compose(
        [FaultEvent(at_s=0.0, layer="executor", kind="hang",
                    target="g", magnitude=0.5)], horizon_s=1.0)
    inj = ChaosInjector(plan)
    inj.start()
    tracker = ThroughputTracker()
    tracker.seed("g", 1e6)
    wd = Watchdog(tracker, timeout_factor=1.0, min_timeout_s=0.05)
    cx = ChaosExecutor(SleepExecutor(rate=1e6), "g", inj, watchdog=wd)
    tok = _token()
    th = threading.Thread(target=cx.execute, args=(tok, ChunkRecord(tok)))
    th.start()
    dead = []
    deadline = time.monotonic() + 2.0
    while not dead and time.monotonic() < deadline:
        dead = wd.check()
        time.sleep(0.01)
    th.join()
    assert dead == ["g"]               # declared dead while wedged
    wd.revive("g")                     # rebuild path: verdict cleared
    assert wd.check() == []


# ---------------------------------------------------------------------------
# journal hardening: torn tails, CRCs, mirror detach/resync
# ---------------------------------------------------------------------------

def _write_journal(path, n=3):
    journal = JournalStore(str(path))
    jobs = []
    for i in range(n):
        j = Job(items=8, tenant=f"t{i}")
        journal.record(j, "submitted")
        j.transition(JobState.ADMITTED)
        journal.record(j)
        jobs.append(j)
    return journal, jobs


def test_torn_final_line_truncated_on_reopen(tmp_path):
    path = tmp_path / "j.jsonl"
    journal, jobs = _write_journal(path)
    journal.tear_tail()                # crash artifact: no newline
    journal.close()
    raw = path.read_bytes()
    assert not raw.endswith(b"\n")
    re = JournalStore(str(path))
    assert re.torn_truncations == 1
    replayed = JournalStore.replay(str(path))
    assert set(replayed) == {j.job_id for j in jobs}
    assert all(j.state == JobState.ADMITTED for j in replayed.values())
    j = Job(items=4)                   # journal still appendable after
    re.record(j, "submitted")
    re.close()
    assert path.read_bytes().endswith(b"\n")


def test_crc_mismatch_skips_line_and_counts(tmp_path):
    path = tmp_path / "j.jsonl"
    journal, jobs = _write_journal(path)
    journal.close()
    lines = path.read_text().splitlines()
    # valid JSON, stale CRC: flip the recorded state of the last record
    rec = json.loads(lines[-1])
    rec["job"]["state"] = "failed"
    lines[-1] = json.dumps(rec, sort_keys=True)
    path.write_text("\n".join(lines) + "\n")
    replayed, stats = JournalStore.replay_stats(str(path))
    assert stats["crc_failures"] == 1
    assert stats["skipped"] == 1
    # the tampered line is ignored: the job keeps its last intact state
    # (the "submitted" record, written while it was still PENDING)
    assert replayed[jobs[-1].job_id].state == JobState.PENDING


def test_unreadable_garbage_line_skipped(tmp_path):
    path = tmp_path / "j.jsonl"
    journal, jobs = _write_journal(path)
    journal.close()
    with open(path, "a", encoding="utf-8") as fh:
        fh.write("#CHAOS# not json at all\n")
    replayed, stats = JournalStore.replay_stats(str(path))
    assert stats["skipped"] == 1
    assert set(replayed) == {j.job_id for j in jobs}


class _FailingSink:
    path = None

    def append(self, line):
        raise OSError("chaos: mirror down")

    def rewrite(self, lines):
        raise OSError("chaos: mirror down")

    def close(self):
        pass


def test_mirror_write_failure_detaches_then_resyncs(tmp_path):
    journal = JournalStore(str(tmp_path / "p.jsonl"))
    journal.attach_mirror(_FailingSink())
    assert journal.has_mirror()
    j = Job(items=8)
    journal.record(j, "submitted")     # sink raises -> detach, not crash
    assert not journal.has_mirror()
    assert journal.mirror_detaches == 1
    j.transition(JobState.ADMITTED)
    journal.record(j)                  # unmirrored writes keep working
    sink = ReplicaSink(str(tmp_path / "replica.jsonl"))
    journal.resync_mirror(sink)
    assert journal.has_mirror()
    journal.close()
    replica = JournalStore.replay(str(tmp_path / "replica.jsonl"))
    primary = JournalStore.replay(str(tmp_path / "p.jsonl"))
    assert {jid: jb.state for jid, jb in replica.items()} \
        == {jid: jb.state for jid, jb in primary.items()}


def test_chaos_sink_fails_only_inside_window(tmp_path):
    t, clk = _fake_clock()
    plan = FaultPlan.compose(
        [FaultEvent(at_s=1.0, layer="federation", kind="mirror_fail",
                    target="r0", duration_s=1.0)], horizon_s=3.0)
    inj = ChaosInjector(plan, clock=clk)
    inj.start()
    sink = ChaosSink(ReplicaSink(str(tmp_path / "r.jsonl")), "r0", inj)
    sink.append("ok-line")
    t[0] = 1.5
    with pytest.raises(OSError):
        sink.append("dropped")
    t[0] = 2.5
    sink.append("ok-again")
    sink.close()
    assert (tmp_path / "r.jsonl").read_text().splitlines() \
        == ["ok-line", "ok-again"]


# ---------------------------------------------------------------------------
# service hardening: bounded deferred retry, brownout, transitions
# ---------------------------------------------------------------------------

def test_pending_to_failed_is_legal():
    j = Job(items=1)
    j.transition(JobState.FAILED)      # retry-budget exhaustion path
    assert j.state == JobState.FAILED


def test_retry_budget_exhaustion_goes_terminal_failed(vclock):
    tel = telemetry_mod.Telemetry()
    queue = QueueManager()
    # no groups joined -> capacity pinned at min -> always DEFER (the
    # infinite defer_factor keeps the gate from rejecting outright)
    adm = AdmissionController(queue, slo_delay_s=0.001,
                              defer_factor=float("inf"),
                              clock=vclock.now, telemetry=tel)
    svc = JobService(lambda: None, queue=queue, admission=adm,
                     retry_budget=4, retry_base_s=0.01, retry_max_s=0.05,
                     clock=vclock.now, sleep=vclock.sleep, telemetry=tel)
    blocker = Job(items=500)           # standing backlog: delay >> slo
    blocker.transition(JobState.ADMITTED)
    queue.put(blocker)
    job = Job(items=100)
    dec = svc.submit(job)
    assert dec.decision == Decision.DEFER
    for _ in range(10):
        svc.retry_deferred()
        vclock.advance(0.2)            # past any jittered backoff
    assert job.state == JobState.FAILED
    assert "retry budget exhausted" in job.meta["failure"]
    assert job.meta["retries"] == 4
    c = tel.snapshot()["counters"]
    assert c.get('svc.retries{cause="exhausted"}') == 1
    assert c.get('svc.retries{cause="deferred"}') == 4


def test_retry_backoff_gates_reoffers(vclock):
    queue = QueueManager()
    adm = AdmissionController(queue, slo_delay_s=0.001,
                              defer_factor=float("inf"), clock=vclock.now)
    svc = JobService(lambda: None, queue=queue, admission=adm,
                     retry_budget=50, retry_base_s=1.0, retry_max_s=8.0,
                     clock=vclock.now, sleep=vclock.sleep)
    blocker = Job(items=500)
    blocker.transition(JobState.ADMITTED)
    queue.put(blocker)
    job = Job(items=100)
    svc.submit(job)
    svc.retry_deferred()               # first re-offer: immediate
    assert job.meta["retries"] == 1
    svc.retry_deferred()               # backoff window not elapsed
    assert job.meta["retries"] == 1
    vclock.advance(2.0)                # base 1s, jitter <= 1.5x
    svc.retry_deferred()
    assert job.meta["retries"] == 2


def test_brownout_sheds_batch_then_standard_then_urgent(vclock):
    tel = telemetry_mod.Telemetry()
    queue = QueueManager()
    adm = AdmissionController(queue, slo_delay_s=0.01, clock=vclock.now,
                              telemetry=tel)
    svc = JobService(lambda: None, queue=queue, admission=adm,
                     brownout_factor=2.0, brownout_after_s=0.5,
                     clock=vclock.now, sleep=vclock.sleep, telemetry=tel)
    jobs = {}
    for tier in ("urgent", "standard", "batch"):
        j = Job(items=300, tier=tier)
        j.transition(JobState.ADMITTED)
        queue.put(j)
        jobs[tier] = j
    svc._check_brownout()              # arms the sustained-overload timer
    assert all(j.state == JobState.ADMITTED for j in jobs.values())
    vclock.advance(0.6)
    svc._check_brownout()              # level 1: batch shed first
    assert jobs["batch"].state == JobState.CANCELLED
    assert jobs["batch"].meta["brownout"] is True
    assert jobs["standard"].state == JobState.ADMITTED
    assert jobs["urgent"].state == JobState.ADMITTED
    vclock.advance(0.5)
    svc._check_brownout()              # level 2: standard
    assert jobs["standard"].state == JobState.CANCELLED
    assert jobs["urgent"].state == JobState.ADMITTED
    vclock.advance(0.5)
    svc._check_brownout()              # level 3: urgent last
    assert jobs["urgent"].state == JobState.CANCELLED
    c = tel.snapshot()["counters"]
    assert c.get('svc.brownout{tier="batch"}') == 1
    assert c.get('svc.brownout{tier="urgent"}') == 1
    svc._check_brownout()              # queue empty -> delay 0 -> reset
    assert svc._brownout_level == 0 and svc._brownout_since is None


# ---------------------------------------------------------------------------
# dispatcher exception guard: a poisoned executor kills its group, not
# the service
# ---------------------------------------------------------------------------

class _PoisonedExecutor(SleepExecutor):
    def execute(self, token, rec):
        raise RuntimeError("poisoned: not a ChunkFailure")


def test_poisoned_executor_fails_group_and_service_survives():
    tel = telemetry_mod.Telemetry()
    name = "g0"

    def make_sched():
        groups = {name: GroupSpec(name, DeviceKind.ACCEL, fixed_chunk=16,
                                  init_throughput=1000.0)}
        return DynamicScheduler(groups,
                                {name: _PoisonedExecutor(rate=1000.0)},
                                telemetry=tel)

    svc = JobService(make_sched, batch_jobs=1, poll_s=0.002,
                     telemetry=tel)
    job = Job(items=32, max_attempts=2)
    svc.submit(job)
    assert svc.run_until_idle(timeout_s=20)
    svc.close()
    # work conserved into a terminal verdict, not stuck or lost
    assert job.state == JobState.FAILED
    c = tel.snapshot()["counters"]
    assert c.get(f'sched.dispatcher_errors{{group="{name}"}}', 0) >= 1


def test_run_seed_composed_drill_invariants(tmp_path):
    """End-to-end: the smoke drill (gossip delay + hang + kill) under
    the soak harness's zero-loss / zero-dupe / bounded-recovery checks."""
    chaos_soak = pytest.importorskip("benchmarks.chaos_soak")
    r = chaos_soak.run_seed(-1, runtimes=2, n_jobs=12,
                            plan=chaos_soak.composed_plan(),
                            directory=str(tmp_path))
    assert r["done"] + r["failed"] + r["cancelled"] == r["jobs"] == 12
    assert r["kills"] == 1
