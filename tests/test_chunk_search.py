"""Unit tests: §3.2 accelerator chunk-size search."""
import pytest

from repro.core import occupancy_seed, search_chunk


def curve(peak_at, peak=100.0):
    def f(c):
        occ = min(1.0, c / peak_at)
        pen = 1.0 if c <= peak_at else 1.0 / (1 + 0.5 * (c / peak_at - 1))
        return peak * occ * pen
    return f


def test_occupancy_seed_matches_paper_example():
    # Haswell iGPU: 20 EUs × SIMD-16 = 320 (paper §3.2)
    assert occupancy_seed(20, 16) == 320


def test_search_finds_peak_on_multiple():
    tr = search_chunk(curve(1280), seed=320)
    assert tr.best_chunk == 1280


def test_search_stops_after_patience():
    calls = []

    def f(c):
        calls.append(c)
        return curve(640)(c)

    search_chunk(f, seed=320, patience=2)
    # 320, 640 (peak), then two non-improving -> stop at 1280
    assert calls == [320, 640, 960, 1280]


def test_search_monotone_curve_respects_max():
    tr = search_chunk(lambda c: float(c), seed=100, max_chunk=1000)
    assert tr.best_chunk == 1000


def test_flat_curve_returns_first():
    tr = search_chunk(lambda c: 5.0, seed=64)
    assert tr.best_chunk == 64
    assert len(tr.tried) == 3  # seed + patience(2)
