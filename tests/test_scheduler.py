"""Threaded Dynamic scheduler runtime: conservation, balance, faults,
elasticity, async drain."""
import time

import pytest

from repro.core import (DeviceKind, DynamicScheduler, GroupSpec,
                        SleepExecutor)
from repro.core.dispatch import CallableExecutor
from repro.runtime.elastic import ElasticController


def groups3(g=400):
    return {
        "accel": GroupSpec("accel", DeviceKind.ACCEL, fixed_chunk=g,
                           init_throughput=400_000),
        "cpu0": GroupSpec("cpu0", DeviceKind.BIG, init_throughput=100_000,
                          min_chunk=4),
        "cpu1": GroupSpec("cpu1", DeviceKind.BIG, init_throughput=100_000,
                          min_chunk=4),
    }


def execs3(fail=None):
    return {
        "accel": SleepExecutor(rate=400_000),
        "cpu0": SleepExecutor(rate=100_000),
        "cpu1": SleepExecutor(rate=100_000,
                              fail_after=fail),
    }


def test_work_conservation_and_split():
    s = DynamicScheduler(groups3(), execs3(), alpha=0.5)
    res = s.run(0, 20_000)
    assert res.iterations == 20_000
    assert sum(res.per_group_items.values()) == 20_000
    # accel is 4x one cpu: expect roughly 2/3 of the work (loose band)
    assert res.per_group_items["accel"] > 10_000


def test_failed_group_work_is_absorbed():
    s = DynamicScheduler(groups3(), execs3(fail=2), alpha=0.5)
    res = s.run(0, 20_000)
    assert "cpu1" in res.failed_groups
    assert res.iterations >= 20_000           # requeued chunk re-executed
    assert res.per_group_items["accel"] + res.per_group_items["cpu0"] \
        + res.per_group_items.get("cpu1", 0) == res.iterations


def test_elastic_join_mid_run():
    s = DynamicScheduler(
        {"accel": GroupSpec("accel", DeviceKind.ACCEL, fixed_chunk=100,
                            init_throughput=50_000)},
        {"accel": SleepExecutor(rate=50_000)})
    ctl = ElasticController(s)
    import threading

    def join_later():
        time.sleep(0.05)
        ctl.join("late", DeviceKind.BIG, SleepExecutor(rate=50_000),
                 min_chunk=4)

    th = threading.Thread(target=join_later)
    th.start()
    res = s.run(0, 30_000)
    th.join()
    assert res.iterations == 30_000
    assert res.per_group_items.get("late", 0) > 0


def test_async_depth_records_all_chunks():
    from repro.core import JaxChunkExecutor
    import jax.numpy as jnp
    import numpy as np

    def step(x):
        return x * 2.0

    ex = JaxChunkExecutor(step, lambda tok: np.ones(tok.chunk.size,
                                                    np.float32),
                          fetch=lambda o: float(jnp.sum(o)),
                          async_depth=3)
    s = DynamicScheduler(
        {"a": GroupSpec("a", DeviceKind.ACCEL, fixed_chunk=64)}, {"a": ex})
    res = s.run(0, 1000)
    assert res.iterations == 1000
    assert all(r.tg5 >= r.tg3 for r in res.records)
    assert all("result" in r.meta for r in res.records)


def test_overheads_measured_positive():
    s = DynamicScheduler(groups3(), {
        "accel": SleepExecutor(rate=400_000, t_hd=0.001, t_kl=0.002,
                               t_dh=0.001),
        "cpu0": SleepExecutor(rate=100_000),
        "cpu1": SleepExecutor(rate=100_000),
    }, alpha=0.5)
    res = s.run(0, 10_000)
    ov = res.overheads["accel"]
    assert ov["O_kl"] > ov["O_hd"] > 0
    assert ov["kernel_frac"] > 0
