"""Threaded Dynamic scheduler runtime: conservation, balance, faults,
elasticity, async drain."""
import time

import pytest

from repro.core import (DeviceKind, DynamicScheduler, GroupSpec,
                        SleepExecutor)
from repro.core.dispatch import CallableExecutor
from repro.runtime.elastic import ElasticController


def groups3(g=400):
    return {
        "accel": GroupSpec("accel", DeviceKind.ACCEL, fixed_chunk=g,
                           init_throughput=400_000),
        "cpu0": GroupSpec("cpu0", DeviceKind.BIG, init_throughput=100_000,
                          min_chunk=4),
        "cpu1": GroupSpec("cpu1", DeviceKind.BIG, init_throughput=100_000,
                          min_chunk=4),
    }


def execs3(fail=None):
    return {
        "accel": SleepExecutor(rate=400_000),
        "cpu0": SleepExecutor(rate=100_000),
        "cpu1": SleepExecutor(rate=100_000,
                              fail_after=fail),
    }


def test_work_conservation_and_split():
    s = DynamicScheduler(groups3(), execs3(), alpha=0.5)
    res = s.run(0, 20_000)
    assert res.iterations == 20_000
    assert sum(res.per_group_items.values()) == 20_000
    # accel is 4x one cpu: expect roughly 2/3 of the work (loose band)
    assert res.per_group_items["accel"] > 10_000


def test_failed_group_work_is_absorbed():
    s = DynamicScheduler(groups3(), execs3(fail=2), alpha=0.5)
    res = s.run(0, 20_000)
    assert "cpu1" in res.failed_groups
    assert res.iterations >= 20_000           # requeued chunk re-executed
    assert res.per_group_items["accel"] + res.per_group_items["cpu0"] \
        + res.per_group_items.get("cpu1", 0) == res.iterations


def test_elastic_join_mid_run(vclock):
    # deterministically mid-run: the first chunk gates the run until the
    # join has landed (no racing a real 50 ms sleep against the epoch)
    import threading
    started, gate = threading.Event(), threading.Event()
    late_got_chunk = threading.Event()

    class GateExecutor(SleepExecutor):
        def execute(self, token, rec):
            out = super().execute(token, rec)
            started.set()
            if not gate.is_set():
                assert gate.wait(10.0)
            return out

    class LateExecutor(SleepExecutor):
        def execute(self, token, rec):
            late_got_chunk.set()
            return super().execute(token, rec)

    s = DynamicScheduler(
        {"accel": GroupSpec("accel", DeviceKind.ACCEL, fixed_chunk=100,
                            init_throughput=50_000)},
        {"accel": GateExecutor(rate=50_000, clock=vclock.now,
                               sleep=vclock.sleep)},
        clock=vclock.now)
    ctl = ElasticController(s)

    def join_later():
        assert started.wait(10.0)
        ctl.join("late", DeviceKind.BIG,
                 LateExecutor(rate=50_000, clock=vclock.now,
                              sleep=vclock.sleep),
                 min_chunk=4)
        # hold accel at the gate until the joined group has provably
        # taken a chunk — accel otherwise drains the whole space in the
        # real microseconds the new dispatcher thread needs to spawn
        assert late_got_chunk.wait(10.0)
        gate.set()

    th = threading.Thread(target=join_later)
    th.start()
    res = s.run(0, 30_000)
    th.join()
    assert res.iterations == 30_000
    assert res.per_group_items.get("late", 0) > 0


def test_async_depth_records_all_chunks():
    from repro.core import JaxChunkExecutor
    import jax.numpy as jnp
    import numpy as np

    def step(x):
        return x * 2.0

    ex = JaxChunkExecutor(step, lambda tok: np.ones(tok.chunk.size,
                                                    np.float32),
                          fetch=lambda o: float(jnp.sum(o)),
                          async_depth=3)
    s = DynamicScheduler(
        {"a": GroupSpec("a", DeviceKind.ACCEL, fixed_chunk=64)}, {"a": ex})
    res = s.run(0, 1000)
    assert res.iterations == 1000
    assert all(r.tg5 >= r.tg3 for r in res.records)
    assert all("result" in r.meta for r in res.records)


def test_overheads_measured_positive():
    s = DynamicScheduler(groups3(), {
        "accel": SleepExecutor(rate=400_000, t_hd=0.001, t_kl=0.002,
                               t_dh=0.001),
        "cpu0": SleepExecutor(rate=100_000),
        "cpu1": SleepExecutor(rate=100_000),
    }, alpha=0.5)
    res = s.run(0, 10_000)
    ov = res.overheads["accel"]
    assert ov["O_kl"] > ov["O_hd"] > 0
    assert ov["kernel_frac"] > 0


# ---------------------------------------------------------------------------
# persistent runtime: epoch reuse without thread teardown
# ---------------------------------------------------------------------------

def test_persistent_runtime_reuses_threads_across_epochs():
    s = DynamicScheduler(groups3(), execs3(), alpha=0.5)
    s.start()
    try:
        idents0 = {n: th.ident for n, th in s.dispatchers().items()}
        assert len(idents0) == 3
        chunks_seen = 0
        for _ in range(3):
            res = s.submit_epoch((0, 5_000)).result(timeout=30)
            assert res.iterations == 5_000
            # same OS threads, still alive: no re-spawn between epochs
            live = s.dispatchers()
            assert {n: th.ident for n, th in live.items()} == idents0
            assert all(th.is_alive() for th in live.values())
            # λ-EWMA continuity: the tracker accumulates across epochs
            n = s.tracker.stats("accel").n
            assert n > chunks_seen
            chunks_seen = n
    finally:
        s.shutdown()
    assert all(not th.is_alive() for th in s.dispatchers().values())


def test_epoch_overlap_no_global_barrier():
    s = DynamicScheduler(groups3(), execs3(), alpha=0.5)
    s.start()
    try:
        h1 = s.submit_epoch((0, 40_000))
        h2 = s.submit_epoch((0, 40_000))
        r1, r2 = h1.result(timeout=30), h2.result(timeout=30)
        assert r1.iterations == r2.iterations == 40_000
        # epoch 2 started before epoch 1 finished: no inter-epoch barrier
        assert h2.started_at < h1.finished_at
    finally:
        s.shutdown()


def test_group_death_stays_excluded_across_epochs():
    s = DynamicScheduler(groups3(), execs3(fail=2), alpha=0.5)
    s.start()
    try:
        r0 = s.submit_epoch((0, 20_000)).result(timeout=30)
        assert "cpu1" in r0.failed_groups
        assert r0.iterations >= 20_000
        for _ in range(2):
            r = s.submit_epoch((0, 10_000)).result(timeout=30)
            assert r.iterations == 10_000
            assert "cpu1" not in r.per_group_items
            assert not r.failed_groups
        assert "cpu1" not in s.live_groups()
        assert "cpu1" not in s.specs and "cpu1" not in s.executors
    finally:
        s.shutdown()


def test_run_compat_tears_down_when_it_started_the_runtime():
    s = DynamicScheduler(groups3(), execs3(), alpha=0.5)
    res = s.run(0, 10_000)
    assert res.iterations == 10_000
    assert all(not th.is_alive() for th in s.dispatchers().values())


def test_elastic_leave_removes_group_everywhere():
    """Regression: leave() used to drop the group only from the
    partitioner, so scheduler.specs/executors resurrected it on the next
    epoch (or any rebuild from those dicts)."""
    s = DynamicScheduler(groups3(), execs3(), alpha=0.5)
    ctl = ElasticController(s)
    s.start()
    try:
        assert s.submit_epoch((0, 5_000)).result(timeout=30).iterations \
            == 5_000
        ctl.leave("cpu1")
        assert "cpu1" not in s.specs and "cpu1" not in s.executors
        assert "cpu1" not in s.partitioner.groups
        res = s.submit_epoch((0, 5_000)).result(timeout=30)
        assert res.iterations == 5_000
        assert "cpu1" not in res.per_group_items
    finally:
        s.shutdown()


def test_epoch_window_stays_bounded():
    """A long-running daemon submits one epoch per batch; finalized
    epochs must be pruned once every worker is past them, or the runtime
    leaks one handle (with its record list) per batch forever."""
    s = DynamicScheduler(groups3(), execs3(), alpha=0.5)
    s.start()
    try:
        for _ in range(12):
            assert s.submit_epoch((0, 1_000)).result(timeout=30) \
                .iterations == 1_000
            assert len(s._epochs) <= 2
    finally:
        s.shutdown()


def test_late_failure_requeue_is_absorbed_after_others_left(vclock):
    """A group that fails after every other dispatcher already left the
    epoch requeues its chunk into the epoch's space; a live dispatcher
    must scan back and drain it (work conservation), not let the epoch
    finalize short."""
    from repro.core.dispatch import ChunkExecutor, ChunkFailure

    import threading
    doomed_started = threading.Event()

    class LateFailExecutor(ChunkExecutor):
        # 0.25 *virtual* seconds: the fast group's entire space is 0.004
        # virtual seconds of work, so once both sleepers are registered
        # the fast group is guaranteed (not raced) to exhaust the space
        # and leave before this failure lands
        def execute(self, token, rec):
            doomed_started.set()
            vclock.sleep(0.25)
            raise ChunkFailure(f"group {token.group} died late")

    class GatedFastExecutor(SleepExecutor):
        # fast must not drain the space before doomed has even taken a
        # chunk — under the virtual clock fast's sleeps self-advance
        # instantly, so without this gate doomed can lose the startup
        # race and never execute at all
        def execute(self, token, rec):
            assert doomed_started.wait(10.0)
            return super().execute(token, rec)

    groups = {
        "fast": GroupSpec("fast", DeviceKind.BIG, init_throughput=1e6,
                          min_chunk=4),
        "doomed": GroupSpec("doomed", DeviceKind.BIG, init_throughput=1e6,
                            min_chunk=256),
    }
    execs = {"fast": GatedFastExecutor(rate=1e6, clock=vclock.now,
                                       sleep=vclock.sleep),
             "doomed": LateFailExecutor()}
    s = DynamicScheduler(groups, execs, alpha=0.5, clock=vclock.now)
    s.start()
    try:
        res = s.submit_epoch((0, 4_000)).result(timeout=30)
        assert "doomed" in res.failed_groups
        # the requeued chunk was re-executed by the survivor
        assert res.iterations == 4_000
        assert res.per_group_items.get("doomed", 0) == 0
    finally:
        s.shutdown()


def test_completion_failure_keeps_finished_records_and_chunks():
    """A failure inside the completion path (block/fetch of an in-flight
    chunk) must neither drop already-finished records nor lose the chunk
    that was popped from the pipeline when it failed."""
    from repro.core import JaxChunkExecutor
    from repro.core.dispatch import ChunkFailure
    from repro.core.types import Chunk, ChunkRecord, Token
    import numpy as np

    calls = {"n": 0}

    def fetch(outs):
        calls["n"] += 1
        if calls["n"] == 2:             # second completion dies mid-fetch
            raise ChunkFailure("device died during fetch")
        return float(np.asarray(outs).sum())

    # completion_mode="block": this test pins the legacy synchronous
    # sequencing (no opportunistic early completion); the poll path's
    # failure bookkeeping is covered in tests/test_dispatch_hotpath.py
    ex = JaxChunkExecutor(lambda x: x * 2.0,
                          lambda tok: np.ones(tok.chunk.size, np.float32),
                          fetch=fetch, async_depth=3,
                          completion_mode="block")
    toks = [Token(Chunk(i * 8, (i + 1) * 8, i), "a", DeviceKind.ACCEL)
            for i in range(3)]
    for tok in toks:
        assert ex.execute(tok, ChunkRecord(tok, tc1=1.0, tc2=1.0)) == []
    with pytest.raises(ChunkFailure):
        ex.drain()
    # record 0 completed before the failure: preserved, not discarded
    done = ex.completed()
    assert [r.token.chunk.seq for r in done] == [0]
    # chunk 1 (popped, failed) and chunk 2 (still queued) both requeueable
    assert sorted(c.seq for c in ex.abort()) == [1, 2]
    assert ex.completed() == [] and ex.abort() == []


def test_launch_failure_keeps_records_completed_in_same_call():
    """ChunkFailure raised while *launching* a new chunk (the serve
    engine's fail-injection path) must not discard records that completed
    earlier in the same execute() call."""
    from repro.core import JaxChunkExecutor
    from repro.core.dispatch import ChunkFailure
    from repro.core.types import Chunk, ChunkRecord, Token
    import numpy as np

    calls = {"n": 0}

    def step(x):
        calls["n"] += 1
        if calls["n"] == 3:
            raise ChunkFailure("device died at launch")
        return x * 2.0

    ex = JaxChunkExecutor(step,
                          lambda tok: np.ones(tok.chunk.size, np.float32),
                          async_depth=2, completion_mode="block")
    toks = [Token(Chunk(i * 8, (i + 1) * 8, i), "a", DeviceKind.ACCEL)
            for i in range(3)]
    assert ex.execute(toks[0], ChunkRecord(toks[0], tc1=1.0, tc2=1.0)) == []
    assert ex.execute(toks[1], ChunkRecord(toks[1], tc1=1.0, tc2=1.0)) == []
    # third call completes chunk 0 first, then dies launching chunk 2
    with pytest.raises(ChunkFailure):
        ex.execute(toks[2], ChunkRecord(toks[2], tc1=1.0, tc2=1.0))
    assert [r.token.chunk.seq for r in ex.completed()] == [0]
    assert [c.seq for c in ex.abort()] == [1]


def test_tc3_stamped_per_record_in_pipelined_drain():
    """Regression: _finalize used to stamp every record drained in one
    call with the same Tc3, inflating O_td for async_depth ≥ 2."""
    from repro.core import JaxChunkExecutor
    import numpy as np

    ex = JaxChunkExecutor(lambda x: x * 2.0,
                          lambda tok: np.ones(tok.chunk.size, np.float32),
                          async_depth=4, completion_mode="block")
    from repro.core.types import Chunk, ChunkRecord, Token

    recs = []
    for i in range(4):
        tok = Token(Chunk(i * 8, (i + 1) * 8, i), "a", DeviceKind.ACCEL)
        rec = ChunkRecord(tok, tc1=time.monotonic(), tc2=time.monotonic())
        recs.extend(ex.execute(tok, rec))
    drained = ex.drain()
    assert len(drained) == 4
    # each record's completion time is its own, stamped at completion:
    # strictly increasing, after its own tg5, before the scheduler ever
    # sees the batch
    for r in drained:
        assert r.tc3 >= r.tg5 > 0.0
    tc3s = [r.tc3 for r in drained]
    assert tc3s == sorted(tc3s) and len(set(tc3s)) == 4
