"""Hypothesis property tests on the system's invariants."""
import math

import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import (Chunk, ChunkRecord, DeviceKind, GroupSpec,
                        HeterogeneousPartitioner, IterationSpace,
                        OverheadLedger, ThroughputTracker, Token,
                        search_chunk)
from repro.core.simulate import SimConfig, simulate
from repro.core.platforms import IVY, EXYNOS


# ---------------------------------------------------------------------------
# work conservation: the partitioner hands out every iteration exactly once
# ---------------------------------------------------------------------------

@given(
    n=st.integers(1, 50_000),
    G=st.integers(1, 4096),
    lams=st.lists(st.floats(0.01, 1000.0), min_size=0, max_size=4),
    order_seed=st.integers(0, 2**16),
)
@settings(max_examples=60, deadline=None)
def test_partitioner_work_conservation(n, G, lams, order_seed):
    import random
    rng = random.Random(order_seed)
    groups = {"accel": GroupSpec("accel", DeviceKind.ACCEL, fixed_chunk=G,
                                 init_throughput=100.0)}
    for i, lam in enumerate(lams):
        groups[f"c{i}"] = GroupSpec(f"c{i}", DeviceKind.BIG,
                                    init_throughput=lam, min_chunk=1)
    tr = ThroughputTracker()
    space = IterationSpace(0, n)
    part = HeterogeneousPartitioner(space, groups, tr)
    names = list(groups)
    seen = []
    while True:
        name = rng.choice(names)
        tok = part.next_token(name)
        if tok is None:
            if space.remaining == 0:
                break
            continue
        seen.append(tok.chunk)
    total = sum(c.size for c in seen)
    assert total == n
    # ranges are disjoint and cover [0, n)
    seen.sort(key=lambda c: c.begin)
    pos = 0
    for c in seen:
        assert c.begin == pos
        pos = c.end
    assert pos == n


@given(
    lam_ref=st.floats(1.0, 1e6),
    lam_c=st.floats(1.0, 1e6),
    G=st.integers(1, 1 << 20),
)
@settings(max_examples=100, deadline=None)
def test_eq4_proportionality(lam_ref, lam_c, G):
    groups = {
        "a": GroupSpec("a", DeviceKind.ACCEL, fixed_chunk=G,
                       init_throughput=lam_ref),
        "c": GroupSpec("c", DeviceKind.BIG, init_throughput=lam_c,
                       min_chunk=1),
    }
    tr = ThroughputTracker()
    part = HeterogeneousPartitioner(IterationSpace(0, 1 << 40), groups, tr)
    size = part.chunk_size_for("c")
    assert size == max(1, int(round(G * lam_c / lam_ref)))


# ---------------------------------------------------------------------------
# ledger: fractions non-negative; device phases sum to <= device_time
# ---------------------------------------------------------------------------

@given(st.lists(
    st.tuples(st.floats(0, 1), st.floats(0, 1), st.floats(0, 1),
              st.floats(0, 1), st.floats(0, 1)),
    min_size=1, max_size=20))
@settings(max_examples=50, deadline=None)
def test_ledger_nonnegative(durations):
    led = OverheadLedger()
    t = 0.0
    for sp, hd, kl, ex, dh in durations:
        tc1 = t
        tc2 = tc1 + sp
        tg1 = tc2
        tg2 = tg1 + hd
        tg3 = tg2 + kl
        tg4 = tg3 + ex
        tg5 = tg4 + dh
        tc3 = tg5 + 0.001
        led.add(ChunkRecord(Token(Chunk(0, 10), "g", DeviceKind.ACCEL),
                            tc1=tc1, tc2=tc2, tc3=tc3, tg1=tg1, tg2=tg2,
                            tg3=tg3, tg4=tg4, tg5=tg5))
        t = tc3
    rep = led.report(max(t, 1e-9), "g")
    for k in ("O_sp", "O_hd", "O_kl", "O_dh", "O_td"):
        assert rep[k] >= 0.0
    assert rep["O_sp"] + rep["O_hd"] + rep["O_kl"] + rep["O_dh"] \
        + rep["O_td"] + rep["kernel_frac"] <= 1.0 + 1e-6


# ---------------------------------------------------------------------------
# chunk search: result is a tried multiple of the seed, never above max
# ---------------------------------------------------------------------------

@given(
    seed=st.integers(1, 2048),
    peak_at_mult=st.integers(1, 16),
    max_chunk=st.integers(1, 1 << 16),
)
@settings(max_examples=100, deadline=None)
def test_search_chunk_invariants(seed, peak_at_mult, max_chunk):
    peak_at = seed * peak_at_mult

    def f(c):
        occ = min(1.0, c / peak_at)
        pen = 1.0 if c <= peak_at else 1.0 / (1 + (c / peak_at - 1))
        return 100 * occ * pen

    tr = search_chunk(f, seed, max_chunk=max_chunk)
    if tr.tried:
        assert tr.best_chunk <= max_chunk
        assert tr.best_chunk % seed == 0
        assert tr.best_lambda == max(l for _, l in tr.tried)


# ---------------------------------------------------------------------------
# persistent runtime: invariants across ≥3 consecutive epochs
# ---------------------------------------------------------------------------

@given(
    # ≥3000 iterations/epoch: cpu1's death (chunk ≤ fail_after+1, ~64
    # items each) is guaranteed to land inside epoch 0, not a later one
    sizes=st.lists(st.integers(3_000, 8_000), min_size=3, max_size=4),
    kill_cpu1=st.booleans(),
    fail_after=st.integers(1, 3),
)
@settings(max_examples=10, deadline=None)
def test_epoch_reuse_invariants(sizes, kill_cpu1, fail_after):
    """Work conservation and λ-EWMA continuity hold across consecutive
    epochs on one runtime; a group death in epoch 0 stays excluded from
    every later epoch."""
    from repro.core import DynamicScheduler, SleepExecutor

    groups = {
        "accel": GroupSpec("accel", DeviceKind.ACCEL, fixed_chunk=256,
                           init_throughput=400_000),
        "cpu0": GroupSpec("cpu0", DeviceKind.BIG, init_throughput=100_000,
                          min_chunk=4),
        "cpu1": GroupSpec("cpu1", DeviceKind.BIG, init_throughput=100_000,
                          min_chunk=4),
    }
    execs = {
        "accel": SleepExecutor(rate=400_000),
        "cpu0": SleepExecutor(rate=100_000),
        "cpu1": SleepExecutor(
            rate=100_000, fail_after=fail_after if kill_cpu1 else None),
    }
    s = DynamicScheduler(groups, execs, alpha=0.5)
    s.start()
    try:
        idents = {n: th.ident for n, th in s.dispatchers().items()}
        chunk_counts = []
        for i, n in enumerate(sizes):
            res = s.submit_epoch((0, n)).result(timeout=60)
            # work conservation per epoch: every requested iteration ran
            # (== without failure; ≥ when a re-executed chunk repeats work)
            assert res.iterations >= n
            if not res.failed_groups:
                assert res.iterations == n
            assert sum(res.per_group_items.values()) == res.iterations
            if kill_cpu1 and i == 0:
                assert "cpu1" in res.failed_groups
            if i > 0:
                # dead group stays excluded in every later epoch
                if kill_cpu1:
                    assert "cpu1" not in res.per_group_items
                    assert "cpu1" not in s.live_groups()
                # λ-EWMA continuity: the tracker accumulates across epochs
                # instead of resetting with a fresh scheduler
                assert s.tracker.stats("accel").n > chunk_counts[-1]
                # surviving dispatcher threads are the original ones
                live = s.dispatchers()
                assert all(live[g].ident == idents[g] for g in live)
            chunk_counts.append(s.tracker.stats("accel").n)
    finally:
        s.shutdown()


# ---------------------------------------------------------------------------
# simulator invariants under random configurations
# ---------------------------------------------------------------------------

@given(
    n_big=st.integers(1, 4),
    n_little=st.integers(0, 4),
    priority=st.booleans(),
    ts=st.integers(1, 3),
    n=st.integers(1000, 200_000),
    plat=st.sampled_from([IVY, EXYNOS]),
)
@settings(max_examples=25, deadline=None)
def test_simulator_invariants(n_big, n_little, priority, ts, n, plat):
    if plat.n_little == 0:
        n_little = 0
    cfg = SimConfig(n_big=n_big, n_little=n_little, priority=priority,
                    timesteps=ts, n_iterations=n)
    r = simulate(plat, cfg)
    assert sum(r.per_device_items.values()) == n * ts
    assert r.time_ms > 0
    assert r.energy.total_j > 0
    assert r.edp == pytest.approx(r.energy.total_j * r.time_ms / 1e3)
    for k in ("O_sp", "O_hd", "O_kl", "O_dh", "O_td"):
        assert 0.0 <= r.overheads[k] <= 1.0
    # priority can only help (or leave unchanged) total time
    if priority:
        base = simulate(plat, SimConfig(
            n_big=n_big, n_little=n_little, priority=False,
            timesteps=ts, n_iterations=n))
        assert r.time_ms <= base.time_ms * 1.001
