"""Property tests for the federation router (bounded-load consistent
hashing). Skipped when hypothesis isn't installed — the example-based
coverage lives in tests/test_federation.py.
"""
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.federation import Router  # noqa: E402

KEYS = st.lists(st.text(alphabet="abcdefghij0123456789", min_size=1,
                        max_size=12),
                min_size=1, max_size=200, unique=True)
FLEET = st.integers(min_value=1, max_value=8)
BOUND = st.floats(min_value=1.05, max_value=2.0)


def _fresh_placements(router, keys):
    """Pure ring placement (no load feedback): each key's walk stops at
    its first runtime, so placement is a deterministic function of the
    ring alone."""
    return {k: router.place(k) for k in keys}


@settings(max_examples=50, deadline=None)
@given(keys=KEYS, n=FLEET, bound=BOUND)
def test_bounded_load_balance(keys, n, bound):
    """Water-filling unit loads never leaves a runtime past its bound:
    load_r <= max(w, bound * share_r * (total + w)) at every admit, so
    the final load obeys the final total's limit too."""
    router = Router([f"r{i}" for i in range(n)], bound=bound)
    loads = {}
    for k in keys:
        for _ in range(5):                 # 5 units per key
            rid = router.place(k, loads)
            assert rid is not None
            loads[rid] = loads.get(rid, 0.0) + 1.0
    total = sum(loads.values())
    for rid, load in loads.items():
        limit = max(1.0, bound * router.capacity_share(rid) * (total + 1))
        assert load <= limit + 1e-6


@settings(max_examples=50, deadline=None)
@given(keys=KEYS, n=st.integers(min_value=1, max_value=7))
def test_join_moves_keys_only_to_joiner(keys, n):
    """Adding a runtime remaps only keys whose walk now hits the new
    vnodes first — every moved key moves TO the joiner, never between
    survivors, and the expected moved fraction is ~1/(n+1)."""
    router = Router([f"r{i}" for i in range(n)])
    before = _fresh_placements(router, keys)
    router.add_runtime("joiner")
    after = _fresh_placements(router, keys)
    moved = {k for k in keys if before[k] != after[k]}
    assert all(after[k] == "joiner" for k in moved)
    # ~K/(n+1) expected; generous slack absorbs vnode variance without
    # letting a broken ring (rehash-everything) pass
    if len(keys) >= 50:
        assert len(moved) <= len(keys) * (2.5 / (n + 1)) + 5


@settings(max_examples=50, deadline=None)
@given(keys=KEYS, n=st.integers(min_value=2, max_value=8))
def test_leave_moves_only_the_departed_runtimes_keys(keys, n):
    router = Router([f"r{i}" for i in range(n)])
    before = _fresh_placements(router, keys)
    router.remove_runtime("r0")
    after = _fresh_placements(router, keys)
    for k in keys:
        if before[k] != "r0":
            assert after[k] == before[k]
        else:
            assert after[k] != "r0" and after[k] is not None


@settings(max_examples=50, deadline=None)
@given(keys=KEYS, n=FLEET, bound=BOUND,
       caps=st.lists(st.floats(min_value=0.1, max_value=10.0),
                     min_size=8, max_size=8))
def test_placement_deterministic_given_identical_state(keys, n, bound,
                                                       caps):
    """Two routers built from the same membership, capacities (gossip
    state), and loads place every key identically — N federation
    front-ends sharing a gossip view agree without coordination."""
    def build():
        r = Router([f"r{i}" for i in range(n)], bound=bound)
        for i in range(n):
            r.set_capacity(f"r{i}", caps[i])
        return r

    a, b = build(), build()
    placed_a = a.place_many(keys)
    placed_b = b.place_many(keys)
    assert placed_a == placed_b
    loads = {f"r{i}": float(i) for i in range(n)}
    for k in keys:
        assert a.place(k, dict(loads)) == b.place(k, dict(loads))
