"""Tests for the multi-tenant scheduling subsystem (repro.tenancy):
registry/spec parsing, DWRR weighted-fair drain + quota isolation on the
sharded queue, quota-aware per-tenant admission, per-tenant energy/EDP
attribution with soft-budget weight derating, JobService integration,
replay-driven restart of a live service, and automatic journal
compaction."""
import json
import threading
import time

import pytest

from repro.core import (Chunk, ChunkRecord, DeviceKind, DynamicScheduler,
                        GroupSpec, SleepExecutor, Token)
from repro.core.energy import EnergyModel, PowerSpec
from repro.core.scheduler import ScheduleResult
from repro.queue import (AdmissionController, Decision, Job, JobService,
                         JobState, JournalStore, QueueManager)
from repro.tenancy import (ShardedQueueManager, TenantAccountant,
                           TenantRegistry, TenantSpec)


# ---------------------------------------------------------------------------
# TenantSpec / TenantRegistry
# ---------------------------------------------------------------------------

def test_registry_parse_cli_form():
    reg = TenantRegistry.parse("gold:weight=10,free:weight=1:quota=8"
                               ":slo=2.0:energy=50")
    assert reg.names() == ["free", "gold"]
    gold, free = reg.get("gold"), reg.get("free")
    assert gold.weight == 10.0 and gold.max_inflight is None
    assert free.weight == 1.0 and free.max_inflight == 8
    assert free.slo_delay_s == 2.0 and free.energy_budget_j == 50.0


def test_registry_from_file(tmp_path):
    path = tmp_path / "tenants.json"
    path.write_text(json.dumps({"tenants": [
        {"name": "a", "weight": 3, "max_inflight": 4},
        {"name": "b", "slo_delay_s": 0.5},
    ]}))
    reg = TenantRegistry.from_file(str(path))
    assert reg.get("a").weight == 3.0 and reg.get("a").max_inflight == 4
    assert reg.get("b").weight == 1.0 and reg.get("b").slo_delay_s == 0.5


def test_registry_auto_registers_unknown_tenant():
    reg = TenantRegistry()
    spec = reg.get("walk-in")
    assert spec.weight == 1.0 and spec.max_inflight is None
    assert "walk-in" in reg


@pytest.mark.parametrize("kwargs", [
    {"name": ""}, {"name": "t", "weight": 0.0},
    {"name": "t", "weight": -1.0}, {"name": "t", "max_inflight": 0},
])
def test_spec_validation(kwargs):
    with pytest.raises(ValueError):
        TenantSpec(**kwargs)


def test_spec_parse_rejects_unknown_field():
    with pytest.raises(ValueError):
        TenantRegistry.parse("t:priority=3")


def test_job_rejects_empty_tenant():
    with pytest.raises(ValueError):
        Job(tenant="")


# ---------------------------------------------------------------------------
# ShardedQueueManager: DWRR drain
# ---------------------------------------------------------------------------

def _drain(q, n):
    out = []
    for _ in range(n):
        j = q.pop()
        if j is None:
            break
        out.append(j)
        q.mark_running(j)
        q.mark_finished(j, JobState.DONE)
    return out


def test_dwrr_share_tracks_weights_10_to_1():
    reg = TenantRegistry.parse("gold:weight=10,bronze:weight=1")
    q = ShardedQueueManager(reg, quantum=10)
    for _ in range(100):
        q.put(Job(items=10, tenant="gold"))
        q.put(Job(items=10, tenant="bronze"))
    drained = {"gold": 0, "bronze": 0}
    for j in _drain(q, 88):                # both stay backlogged throughout
        drained[j.tenant] += j.items
    assert drained["gold"] / drained["bronze"] == pytest.approx(10.0,
                                                                rel=0.15)


def test_dwrr_work_conservation_single_backlogged_tenant():
    reg = TenantRegistry.parse("gold:weight=10,bronze:weight=1")
    q = ShardedQueueManager(reg, quantum=8)
    for _ in range(5):
        q.put(Job(items=100, tenant="bronze"))
    # gold is idle: bronze drains at full rate, back to back
    assert [j.tenant for j in _drain(q, 5)] == ["bronze"] * 5
    assert q.pop() is None


def test_dwrr_idle_tenant_banks_no_credit():
    """A tenant idle for many rounds re-enters with deficit 0 — it cannot
    burst past its weight share on arrival (classic DWRR reset)."""
    reg = TenantRegistry.parse("a:weight=1,b:weight=1")
    q = ShardedQueueManager(reg, quantum=10)
    for _ in range(50):
        q.put(Job(items=10, tenant="a"))
    _drain(q, 20)                          # many a-only rounds pass b by
    for _ in range(50):
        q.put(Job(items=10, tenant="b"))
    window = _drain(q, 20)
    share_b = sum(j.items for j in window if j.tenant == "b") \
        / sum(j.items for j in window)
    assert 0.35 <= share_b <= 0.65         # ~half, not a catch-up burst


def test_dwrr_large_job_accumulates_deficit_across_rounds():
    reg = TenantRegistry.parse("small:weight=1,big:weight=1")
    q = ShardedQueueManager(reg, quantum=10)
    q.put(Job(items=500, tenant="big"))    # needs ~50 rounds of credit
    for _ in range(10):
        q.put(Job(items=10, tenant="small"))
    tenants = [j.tenant for j in _drain(q, 11)]
    assert "big" in tenants and tenants.count("small") == 10


def test_quota_caps_drain_until_slot_freed():
    reg = TenantRegistry.parse("capped:weight=1:quota=2")
    q = ShardedQueueManager(reg)
    for _ in range(5):
        q.put(Job(items=1, tenant="capped"))
    a, b = q.pop(), q.pop()
    assert a is not None and b is not None
    assert q.pop() is None                 # at quota, backlog waits
    assert q.outstanding("capped") == 2
    q.mark_running(a)
    q.mark_finished(a, JobState.DONE)
    assert q.pop() is not None             # freed slot resumes the drain


def test_cancel_of_popped_job_releases_quota_slot():
    """Cancelling a job in the popped-but-unbound window (two-phase pop:
    it is still ADMITTED until mark_running) must free its quota slot —
    otherwise N such cancels wedge a quota-N tenant forever."""
    reg = TenantRegistry.parse("capped:weight=1:quota=1")
    q = ShardedQueueManager(reg)
    a, b = Job(items=1, tenant="capped"), Job(items=1, tenant="capped")
    q.put(a), q.put(b)
    popped = q.pop()
    assert popped is a and q.outstanding("capped") == 1
    assert q.pop() is None                 # at quota
    assert q.cancel(a.job_id)              # cancelled before mark_running
    assert q.outstanding("capped") == 0
    assert q.pop() is b                    # slot released, drain resumes


def test_quota_gate_does_not_double_count_popped_jobs():
    """Popped jobs stay ADMITTED until mark_running; the admission quota
    must not count them as both outstanding and queued."""
    reg = TenantRegistry.parse("t:weight=1:quota=4")
    q = ShardedQueueManager(reg)
    adm = AdmissionController(q, slo_delay_s=100.0, registry=reg)
    adm.on_group_join("g0", 1000.0)
    for _ in range(2):
        assert adm.admit(Job(items=1, tenant="t"))
    a, b = q.pop(), q.pop()                # popped, not yet RUNNING
    assert q.outstanding("t") == 2 and q.queued("t") == 0
    # true unfinished work is 2 < 4: two more admits must pass
    assert adm.admit(Job(items=1, tenant="t")).decision == Decision.ADMIT
    assert adm.admit(Job(items=1, tenant="t")).decision == Decision.ADMIT
    assert adm.admit(Job(items=1, tenant="t")).decision == Decision.DEFER
    q.mark_running(a), q.mark_running(b)
    assert q.outstanding("t") == 2         # RUNNING still holds the slot


def test_pop_timeout_not_restarted_by_ineligible_notifies():
    """Puts to a quota-capped shard notify without making work eligible;
    a timed pop must still return near its deadline."""
    reg = TenantRegistry.parse("capped:weight=1:quota=1")
    q = ShardedQueueManager(reg)
    q.put(Job(items=1, tenant="capped"))
    assert q.pop() is not None             # tenant now at quota
    stop = threading.Event()

    def noisy_producer():
        while not stop.is_set():
            q.put(Job(items=1, tenant="capped"))
            time.sleep(0.02)

    th = threading.Thread(target=noisy_producer, daemon=True)
    th.start()
    t0 = time.monotonic()
    assert q.pop(timeout=0.2) is None
    elapsed = time.monotonic() - t0
    stop.set()
    th.join()
    assert elapsed < 1.0                   # bounded, not restarted forever


def test_quota_flood_bounded_by_deferred_pool_cap():
    """A flood against a quota-capped tenant is shed once the service's
    deferred pool is full — it cannot bank unbounded PENDING jobs that
    get re-gated every poll."""
    reg = TenantRegistry.parse("free:weight=1:quota=1:slo=0.1")
    q = ShardedQueueManager(reg)
    adm = AdmissionController(q, slo_delay_s=100.0, registry=reg)
    adm.on_group_join("g0", 10.0)
    svc = JobService(_make_sched, queue=q, admission=adm, max_deferred=5)
    decisions = [svc.submit(Job(items=1, tenant="free"))
                 for _ in range(50)]
    kinds = [d.decision for d in decisions]
    assert kinds[0] == Decision.ADMIT
    assert sum(k == Decision.DEFER for k in kinds) == 5
    shed = [d for d in decisions if d.decision == Decision.REJECT]
    assert len(shed) == 44                 # flood shed, pool bounded
    assert all("deferred pool" in d.reason for d in shed)
    assert len(svc._deferred) == 5


def test_registry_any_gating():
    assert not TenantRegistry.parse("a:weight=1,b:weight=2").any_gating()
    assert TenantRegistry.parse("a:weight=1:quota=4").any_gating()
    assert TenantRegistry.parse("a:slo=0.5").any_gating()


def test_quota_blocked_pop_wakes_on_mark_finished():
    reg = TenantRegistry.parse("capped:weight=1:quota=1")
    q = ShardedQueueManager(reg)
    q.put(Job(items=1, tenant="capped"))
    q.put(Job(items=1, tenant="capped"))
    first = q.pop()
    got = []
    started = threading.Event()

    def blocked_pop():
        started.set()
        got.append(q.pop(timeout=5.0))

    th = threading.Thread(target=blocked_pop)
    th.start()
    assert started.wait(5.0)
    q.mark_running(first)
    q.mark_finished(first, JobState.DONE)
    th.join(timeout=5.0)
    assert got and got[0] is not None


def test_priority_order_preserved_within_tenant():
    reg = TenantRegistry.parse("t:weight=1")
    q = ShardedQueueManager(reg)
    lo, hi = Job(priority=5, tenant="t"), Job(priority=0, tenant="t")
    q.put(lo), q.put(hi)
    assert q.pop() is hi and q.pop() is lo


def test_single_default_tenant_matches_unsharded_queue_order():
    import random
    rng = random.Random(7)
    spec = [(rng.randint(0, 3), rng.randint(1, 50)) for _ in range(40)]
    plain, sharded = QueueManager(), ShardedQueueManager()
    a = [Job(priority=p, items=n) for p, n in spec]
    b = [Job(priority=p, items=n) for p, n in spec]
    for j in a:
        plain.put(j)
    for j in b:
        sharded.put(j)
    order_a = [plain.pop().priority for _ in range(40)]
    order_b = [sharded.pop().priority for _ in range(40)]
    assert order_a == order_b


def test_requeue_routes_to_tenant_shard_and_introspection():
    reg = TenantRegistry.parse("a:weight=1,b:weight=1")
    q = ShardedQueueManager(reg)
    ja, jb = Job(items=10, tenant="a"), Job(items=20, tenant="b")
    q.put(ja), q.put(jb)
    assert q.backlog_by_tenant() == {"a": 10, "b": 20}
    assert q.depth("a") == 1 and q.depth() == 2
    j = q.pop()
    q.mark_running(j, "g0")
    assert q.inflight("g0") == 1
    q.mark_finished(j, JobState.REQUEUED)
    q.requeue(j)
    assert q.get(j.job_id) is j
    assert q.backlog_items() == 30
    assert q.counts().get("admitted") == 2
    assert q.cancel(ja.job_id) or q.cancel(jb.job_id)


def test_weight_derate_shifts_share():
    reg = TenantRegistry.parse("gold:weight=10,bronze:weight=1")
    q = ShardedQueueManager(reg, quantum=10)
    q.set_weight_derates({"gold": 0.1})    # effective 1:1
    for _ in range(100):
        q.put(Job(items=10, tenant="gold"))
        q.put(Job(items=10, tenant="bronze"))
    drained = {"gold": 0, "bronze": 0}
    for j in _drain(q, 40):
        drained[j.tenant] += j.items
    assert drained["gold"] == pytest.approx(drained["bronze"], rel=0.25)


# ---------------------------------------------------------------------------
# Quota-aware admission
# ---------------------------------------------------------------------------

def test_admission_defers_at_tenant_quota():
    reg = TenantRegistry.parse("free:weight=1:quota=3")
    q = ShardedQueueManager(reg)
    adm = AdmissionController(q, slo_delay_s=10.0, registry=reg)
    adm.on_group_join("g0", 100.0)
    decisions = [adm.admit(Job(items=1, tenant="free")) for _ in range(5)]
    kinds = [d.decision for d in decisions]
    assert kinds == [Decision.ADMIT] * 3 + [Decision.DEFER] * 2
    assert "quota" in decisions[3].reason
    assert adm.per_tenant["free"] == {"admitted": 3, "deferred": 2,
                                      "rejected": 0}


def test_admission_tenant_isolation_work_conservation():
    """A hostile tenant's backlog defers *its own* jobs; an underloaded
    tenant still admits against its fair-share capacity."""
    reg = TenantRegistry.parse("hog:weight=1,calm:weight=1")
    q = ShardedQueueManager(reg)
    adm = AdmissionController(q, slo_delay_s=1.0, defer_factor=50.0,
                              registry=reg)
    adm.on_group_join("g0", 100.0)         # 100 items/s
    # hog fills past its share: per-tenant delay gate kicks in
    hog_decisions = [adm.admit(Job(items=30, tenant="hog"))
                     for _ in range(6)]
    assert hog_decisions[0].decision == Decision.ADMIT
    assert any(d.decision == Decision.DEFER for d in hog_decisions)
    # calm (empty shard) admits: its projected delay uses its own
    # fair-share capacity and its own (empty) backlog, not hog's
    calm = adm.admit(Job(items=20, tenant="calm"))
    assert calm.decision == Decision.ADMIT
    assert calm.projected_delay_s <= 1.0


def test_admission_respects_per_tenant_slo_override():
    reg = TenantRegistry.parse("strict:weight=1:slo=0.01,lax:weight=1")
    q = ShardedQueueManager(reg)
    adm = AdmissionController(q, slo_delay_s=100.0, registry=reg)
    adm.on_group_join("g0", 10.0)
    # identical load: strict's 10ms SLO defers/rejects, lax's 100s admits
    strict = adm.admit(Job(items=5, tenant="strict"))
    lax = adm.admit(Job(items=5, tenant="lax"))
    assert strict.decision != Decision.ADMIT
    assert lax.decision == Decision.ADMIT


def test_admission_without_registry_unchanged():
    q = QueueManager()
    adm = AdmissionController(q, slo_delay_s=1.0)
    adm.on_group_join("g0", 100.0)
    assert adm.admit(Job(items=50)).decision == Decision.ADMIT
    assert adm.admit(Job(items=60)).decision == Decision.DEFER
    assert adm.per_tenant == {}


# ---------------------------------------------------------------------------
# Per-tenant accounting / energy budgets
# ---------------------------------------------------------------------------

def _result(groups_busy, total_time=1.0):
    """Synthetic ScheduleResult with one chunk per (group, busy_s)."""
    records = []
    pos = 0
    for g, busy in groups_busy.items():
        tok = Token(Chunk(pos, pos + 10, pos), g, DeviceKind.BIG)
        records.append(ChunkRecord(tok, tc1=0.0, tc2=0.0, tc3=busy,
                                   tg1=0.0, tg5=busy))
        pos += 10
    return ScheduleResult(
        total_time=total_time, iterations=pos, records=records,
        overheads={}, throughput={},
        per_group_items={g: 10 for g in groups_busy})


def test_accountant_attributes_by_item_share():
    reg = TenantRegistry.parse("a:weight=1,b:weight=1")
    acct = TenantAccountant(reg)
    jobs = [Job(items=30, tenant="a"), Job(items=10, tenant="b")]
    res = _result({"g0": 2.0, "g1": 2.0}, total_time=8.0)
    shares = acct.record_batch(jobs, res)
    assert shares == {"a": 0.75, "b": 0.25}
    a, b = acct.usage("a"), acct.usage("b")
    assert a.items == 30 and b.items == 10
    assert a.busy_s == pytest.approx(3.0) and b.busy_s == pytest.approx(1.0)
    assert a.wall_s == pytest.approx(6.0) and b.wall_s == pytest.approx(2.0)
    # records carry the share map for downstream consumers
    assert all(r.meta["tenant_shares"] == shares for r in res.records)


def test_accountant_energy_and_edp():
    reg = TenantRegistry.parse("a:weight=1,b:weight=1")
    em = EnergyModel({"g0": PowerSpec(active_w=10.0, idle_w=0.0)})
    acct = TenantAccountant(reg, energy_model=em)
    jobs = [Job(items=10, tenant="a"), Job(items=30, tenant="b")]
    acct.record_batch(jobs, _result({"g0": 1.0}, total_time=1.0))
    a, b = acct.usage("a"), acct.usage("b")
    assert a.energy_j + b.energy_j == pytest.approx(10.0)   # 10W × 1s
    assert b.energy_j == pytest.approx(3.0 * a.energy_j)
    assert a.edp == pytest.approx(a.energy_j * a.wall_s)


def test_energy_budget_derates_weight_with_floor():
    reg = TenantRegistry.parse("hog:weight=4:energy=1.0,ok:weight=1")
    em = EnergyModel({"g0": PowerSpec(active_w=100.0, idle_w=0.0)})
    acct = TenantAccountant(reg, energy_model=em, derate_floor=0.25)
    jobs = [Job(items=10, tenant="hog")]
    acct.record_batch(jobs, _result({"g0": 1.0}, total_time=1.0))  # 100 J
    derates = acct.derate_weights()
    assert derates == {"hog": 0.25}        # 1/100 floored at 0.25
    assert acct.usage("ok").energy_j == 0.0


def test_accountant_deoverlaps_pipelined_wall_time():
    """Two batches whose monotonic windows overlap must not both bill
    their full span — Σ wall_s tracks elapsed pipeline time."""
    reg = TenantRegistry.parse("a:weight=1")
    acct = TenantAccountant(reg)
    jobs = [Job(items=10, tenant="a")]
    acct.record_batch(jobs, _result({"g0": 1.0}, total_time=1.0),
                      window=(10.0, 11.0))
    # second batch started at 10.2 (overlapping) and ended at 11.5:
    # only the 0.5s past the accounted window is new wall time
    acct.record_batch(jobs, _result({"g0": 1.0}, total_time=1.3),
                      window=(10.2, 11.5))
    assert acct.usage("a").wall_s == pytest.approx(1.5)


def test_quota_enforced_per_tenant_on_unsharded_queue():
    """Registry + plain QueueManager: another tenant's backlog must not
    consume this tenant's quota, and RUNNING jobs must count."""
    reg = TenantRegistry.parse("a:weight=1:quota=2,b:weight=1")
    q = QueueManager()
    adm = AdmissionController(q, slo_delay_s=100.0, registry=reg)
    adm.on_group_join("g0", 1000.0)
    for _ in range(10):
        assert adm.admit(Job(items=1, tenant="b"))
    # b's 10 queued jobs don't touch a's quota of 2
    assert adm.admit(Job(items=1, tenant="a")).decision == Decision.ADMIT
    ja = adm.admit(Job(items=1, tenant="a"))
    assert ja.decision == Decision.ADMIT
    assert adm.admit(Job(items=1, tenant="a")).decision == Decision.DEFER


def test_energy_model_attribute_normalizes_shares():
    em = EnergyModel({"g0": PowerSpec(5.0, 1.0)})
    report = em.energy(2.0, {"g0": 1.0})
    split = em.attribute(report, {"a": 2.0, "b": 2.0})  # unnormalized
    assert split["a"] == pytest.approx(report.total_j / 2)
    assert sum(split.values()) == pytest.approx(report.total_j)


# ---------------------------------------------------------------------------
# JobService integration
# ---------------------------------------------------------------------------

def _make_sched():
    groups = {
        "accel": GroupSpec("accel", DeviceKind.ACCEL, fixed_chunk=64,
                           init_throughput=50_000),
        "cpu0": GroupSpec("cpu0", DeviceKind.BIG, init_throughput=10_000),
    }
    execs = {"accel": SleepExecutor(rate=50_000),
             "cpu0": SleepExecutor(rate=10_000)}
    return DynamicScheduler(groups, execs)


def test_service_two_tenant_drain_with_accounting():
    reg = TenantRegistry.parse("gold:weight=10,free:weight=1")
    q = ShardedQueueManager(reg)
    acct = TenantAccountant(reg)
    svc = JobService(_make_sched, queue=q, accountant=acct, batch_jobs=4)
    jobs = [Job(items=100, tenant=("gold" if i % 2 else "free"))
            for i in range(12)]
    for j in jobs:
        svc.submit(j)
    assert svc.run_until_idle(timeout_s=30)
    assert all(j.state == JobState.DONE for j in jobs)
    snap = acct.snapshot()
    assert snap["gold"]["items"] == 600 and snap["free"]["items"] == 600
    assert snap["gold"]["busy_s"] > 0 and snap["gold"]["wall_s"] > 0
    assert snap["gold"]["queue_delay_s"]["p95"] >= 0.0
    svc.close()


def test_service_attributes_requeued_batch_once():
    """A batch that fails and retries is attributed only when it finally
    completes — per-tenant items reflect delivered work, not attempts."""
    calls = {"n": 0}

    def flaky():
        calls["n"] += 1
        if calls["n"] == 1:        # every group dies on its first chunk
            return DynamicScheduler(
                {"g0": GroupSpec("g0", DeviceKind.BIG,
                                 init_throughput=1000)},
                {"g0": SleepExecutor(rate=1000, fail_after=0)})
        return _make_sched()

    reg = TenantRegistry.parse("t:weight=1")
    acct = TenantAccountant(reg)
    svc = JobService(flaky, queue=ShardedQueueManager(reg),
                     accountant=acct, batch_jobs=8)
    jobs = [Job(items=100, tenant="t") for _ in range(4)]
    for j in jobs:
        svc.submit(j)
    assert svc.run_until_idle(timeout_s=30)
    assert all(j.state == JobState.DONE for j in jobs)
    assert svc.stats.requeues >= 1
    assert acct.usage("t").items == 400    # once, despite the retry
    svc.close()


def test_service_survives_cancel_in_pop_window():
    """A job cancelled between pop and mark_running (two-phase pop keeps
    it cancellable) is dropped from the batch — not an IllegalTransition
    that kills the drain."""
    reg = TenantRegistry.parse("t:weight=1")
    q = ShardedQueueManager(reg)
    svc = JobService(_make_sched, queue=q, batch_jobs=4)
    jobs = [Job(items=50, tenant="t") for _ in range(4)]
    for j in jobs:
        svc.submit(j)
    batch = svc._pop_batch()
    assert len(batch) == 4
    assert q.cancel(batch[1].job_id)       # cancelled while popped
    rep = svc._submit_batch(batch)
    assert rep is None                     # batch still submitted
    assert svc.run_until_idle(timeout_s=30)
    assert jobs[1].state == JobState.CANCELLED
    assert all(j.state == JobState.DONE for j in jobs if j is not jobs[1])
    assert svc.stats.done == 3
    svc.close()


def test_service_applies_energy_derate_to_queue():
    reg = TenantRegistry.parse("hog:weight=8:energy=1e-9,ok:weight=1")
    em = EnergyModel({"accel": PowerSpec(8.0, 1.0),
                      "cpu0": PowerSpec(4.0, 1.0)})
    q = ShardedQueueManager(reg)
    acct = TenantAccountant(reg, energy_model=em)
    svc = JobService(_make_sched, queue=q, accountant=acct, batch_jobs=2)
    jobs = [Job(items=200, tenant="hog") for _ in range(4)]
    for j in jobs:
        svc.submit(j)
    assert svc.run_until_idle(timeout_s=30)
    # hog blew its (absurd) budget on batch 1 → its DWRR weight is derated
    assert q.weight_derate("hog") < 1.0
    assert acct.usage("hog").energy_j > 1e-9
    svc.close()


# ---------------------------------------------------------------------------
# Replay-driven restart: kill a live service mid-drain, recover the journal
# into a fresh live service
# ---------------------------------------------------------------------------

def test_recover_restarts_live_service_mid_drain(tmp_path):
    path = str(tmp_path / "journal.jsonl")
    reg = TenantRegistry.parse("a:weight=2,b:weight=1")

    def slow_sched():
        groups = {"g0": GroupSpec("g0", DeviceKind.BIG,
                                  init_throughput=2_000)}
        execs = {"g0": SleepExecutor(rate=2_000)}
        return DynamicScheduler(groups, execs)

    svc1 = JobService(slow_sched, queue=ShardedQueueManager(reg),
                      journal=JournalStore(path), batch_jobs=1,
                      poll_s=0.005)
    jobs = [Job(items=100, tenant=("a" if i % 2 else "b"))
            for i in range(10)]
    for j in jobs:
        svc1.submit(j)
    svc1.start()
    deadline = time.monotonic() + 20.0
    while svc1.stats.done == 0 and time.monotonic() < deadline:
        time.sleep(0.005)
    assert svc1.stats.done > 0
    # hard kill: stop the daemon thread but do NOT finalize in-flight
    # batches or close anything gracefully — the journal's last words are
    # a mix of done / running / admitted jobs, like a real crash
    svc1._stop.set()
    svc1._thread.join(timeout=10.0)
    if svc1._sched is not None:
        svc1._sched.shutdown()
    assert any(j.state != JobState.DONE for j in jobs)

    # fresh process: new queue, new journal handle on the same file,
    # daemon already live when recovery pours jobs back in
    svc2 = JobService(_make_sched, queue=ShardedQueueManager(reg),
                      journal=JournalStore(path), poll_s=0.005)
    svc2.start()
    restored = svc2.recover(path)
    assert restored, "crash left nothing to recover?"
    assert {j.tenant for j in restored} <= {"a", "b"}
    deadline = time.monotonic() + 30.0
    while time.monotonic() < deadline:
        if svc2.queue.depth() == 0 and not svc2._inflight:
            break
        time.sleep(0.01)
    svc2.close()

    # the journal's final word: every job DONE (at-least-once), none lost
    final = JournalStore.replay(path)
    assert len(final) == 10
    assert all(j.state == JobState.DONE for j in final.values())


def test_recover_fails_job_with_exhausted_attempts(tmp_path):
    path = str(tmp_path / "journal.jsonl")
    job = Job(items=4, max_attempts=1)
    with JournalStore(path) as js:
        job.transition(JobState.ADMITTED)
        job.transition(JobState.RUNNING)    # its one attempt dies here
        js.record(job)
    svc = JobService(_make_sched, journal=JournalStore(path))
    restored = svc.recover(path)
    assert restored == []
    assert JournalStore.replay(path)[job.job_id].state == JobState.FAILED


# ---------------------------------------------------------------------------
# Automatic journal compaction
# ---------------------------------------------------------------------------

def test_journal_auto_compacts_past_line_threshold(tmp_path):
    path = str(tmp_path / "journal.jsonl")
    js = JournalStore(path, auto_compact_lines=20)
    jobs = [Job(items=i + 1) for i in range(5)]
    for _ in range(10):                    # 50 records over 5 live jobs
        for j in jobs:
            js.record(j, "heartbeat")
    assert js.compactions >= 1
    n_lines = sum(1 for _ in open(path))
    assert n_lines <= 20                   # bounded, not 50
    final = JournalStore.replay(path)
    assert len(final) == 5
    assert sorted(j.items for j in final.values()) == [1, 2, 3, 4, 5]
    js.close()


def test_journal_auto_compact_no_thrash_when_live_exceeds_threshold(
        tmp_path):
    """A live set larger than the threshold must not trigger a full
    rewrite per record (moving trigger doubles past the kept size)."""
    path = str(tmp_path / "journal.jsonl")
    js = JournalStore(path, auto_compact_lines=4)
    jobs = [Job() for _ in range(10)]      # live set 10 > threshold 4
    for j in jobs:
        js.record(j)
    assert 1 <= js.compactions <= 4        # not one per record past 4
    assert len(JournalStore.replay(path)) == 10
    js.close()


def test_journal_counts_preexisting_lines(tmp_path):
    path = str(tmp_path / "journal.jsonl")
    with JournalStore(path) as js:
        for _ in range(30):
            js.record(Job())
    js2 = JournalStore(path, auto_compact_lines=20)
    js2.record(Job())                      # 31st line crosses threshold
    assert js2.compactions == 1
    assert len(JournalStore.replay(path)) == 31
    js2.close()


# ---------------------------------------------------------------------------
# Hypothesis property: DWRR fairness under random arrivals
# ---------------------------------------------------------------------------

try:                                       # optional dependency (pyproject
    from hypothesis import given, settings, strategies as st  # [test])
    HAS_HYPOTHESIS = True
except ImportError:                        # pragma: no cover
    HAS_HYPOTHESIS = False

    def given(**kw):                       # keep the decorator site valid
        return lambda fn: fn

    def settings(**kw):
        return lambda fn: fn

    class st:                              # type: ignore
        @staticmethod
        def lists(*a, **kw):
            return None

        @staticmethod
        def integers(*a, **kw):
            return None


@pytest.mark.skipif(not HAS_HYPOTHESIS, reason="hypothesis not installed")
@given(
    weights=st.lists(st.integers(1, 10), min_size=2, max_size=4),
    sizes=st.lists(st.integers(1, 50), min_size=4, max_size=40),
    quantum=st.integers(1, 128),
)
@settings(max_examples=40, deadline=None)
def test_dwrr_drained_share_converges_to_weight_share(weights, sizes,
                                                      quantum):
    """Over random arrivals, while every tenant stays backlogged: each
    tenant's drained-items share converges to its weight share (±ε from
    quantum granularity) and no backlogged tenant starves."""
    reg = TenantRegistry(
        TenantSpec(f"t{i}", weight=float(w)) for i, w in enumerate(weights))
    q = ShardedQueueManager(reg, quantum=quantum)
    names = [f"t{i}" for i in range(len(weights))]
    # every tenant gets the same random job mix, replicated until its
    # backlog is deep enough to stay non-empty through the whole window.
    # DWRR fairness is a multi-round property: each round serves a tenant
    # ~quantum×weight items, so the backlog must cover several rounds or
    # the window closes before the rotation completes even once
    per_tenant_items = max(sum(sizes) * 6, 8 * quantum * max(weights))
    for name in names:
        total = 0
        while total < per_tenant_items:
            for s in sizes:
                q.put(Job(items=s, tenant=name))
                total += s
    drained = {n: 0 for n in names}
    # drain while ALL tenants remain backlogged (stop at half of any
    # tenant's fair share-adjusted backlog, conservatively)
    while min(q.backlog_by_tenant().values()) > 0:
        j = q.pop()
        assert j is not None, "backlogged queue must always serve"
        drained[j.tenant] += j.items
        q.mark_running(j)
        q.mark_finished(j, JobState.DONE)
    total_drained = sum(drained.values())
    wsum = sum(weights)
    # granularity bound: one round's credit + one max job per tenant
    eps_items = quantum * max(weights) + max(sizes)
    for name, w in zip(names, weights):
        expected = total_drained * w / wsum
        assert abs(drained[name] - expected) <= eps_items + 0.25 * expected
        assert drained[name] > 0           # no starvation while backlogged
