"""Serve-path end-to-end test of the queue subsystem (acceptance test):
≥100 prioritized jobs through HeteroServeEngine.serve_jobs, one device
group killed mid-run, every job reaches DONE (the scheduler's chunk
requeue absorbs the dead group's in-flight work), and journal replay
reconstructs the final states."""
import pytest

from repro.configs.registry import get_reduced_config
from repro.core.types import DeviceKind
from repro.queue import Job, JobState, JournalStore
from repro.serve.engine import HeteroServeEngine
from repro.train.trainer import GroupDef

pytestmark = pytest.mark.slow


@pytest.fixture(scope="module")
def engine():
    cfg = get_reduced_config("stablelm-1.6b").replace(
        n_layers=2, dtype="float32")
    groups = [
        GroupDef("accel", DeviceKind.ACCEL, fixed_chunk=8, async_depth=2),
        GroupDef("cpu0", DeviceKind.BIG, slowdown=2.0, fail_after_chunks=2),
        GroupDef("cpu1", DeviceKind.BIG),
    ]
    return HeteroServeEngine(cfg, groups, prompt_len=8, decode_tokens=2)


def test_serve_jobs_e2e_with_group_kill_and_journal(engine, tmp_path):
    path = str(tmp_path / "serve.journal.jsonl")
    jobs = [Job(items=1, priority=i % 3) for i in range(100)]
    rep = engine.serve_jobs(jobs, batch_jobs=16, journal_path=path,
                            timeout_s=240.0)

    # every job completed despite cpu0 dying mid-run
    assert rep.drained
    assert all(j.state == JobState.DONE for j in jobs)
    assert rep.done == 100 and rep.failed == 0 and rep.cancelled == 0
    assert rep.dead_groups == ["cpu0"]
    # the dead group stopped receiving work; survivors absorbed it all
    assert sum(rep.per_group_items.values()) >= 100
    assert rep.per_group_items.get("accel", 0) > 0
    # queue-delay percentiles are populated and ordered
    qd = rep.queue_delay
    assert qd["p50"] <= qd["p95"] <= qd["p99"]
    assert qd["p99"] > 0.0

    # journal replay reconstructs the exact final state of every job
    final = JournalStore.replay(path)
    assert len(final) == 100
    for j in jobs:
        assert final[j.job_id].state == JobState.DONE
        assert final[j.job_id].attempts == j.attempts

    # crash-recovery view agrees: nothing left to requeue
    to_requeue, _ = JournalStore.recover(path)
    assert to_requeue == []


def test_serve_jobs_priorities_drain_high_first(engine):
    # without admission, pops are strict priority order: all priority-0
    # jobs start no later than the first priority-5 job
    jobs = [Job(items=1, priority=0) for _ in range(8)] + \
           [Job(items=1, priority=5) for _ in range(8)]
    rep = engine.serve_jobs(list(reversed(jobs)), batch_jobs=4,
                            timeout_s=120.0)
    assert rep.done == 16
    first_low = min(j.started_at for j in jobs if j.priority == 5)
    assert all(j.started_at <= first_low for j in jobs if j.priority == 0)
