import os
import sys

import pytest

# NOTE: never set XLA_FLAGS / host device count here — smoke tests and
# benches must see the single real CPU device (the 512-device trick is
# exclusively the dry-run launcher's, set before any jax import there).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.dirname(__file__))

from clock import VirtualClock  # noqa: E402


@pytest.fixture
def vclock(monkeypatch):
    """One virtual timeline for the whole stack: the job lifecycle clock
    is monkeypatched module-wide; scheduler / executor / service clocks
    are seams the test wires explicitly (``clock=vc.now``,
    ``sleep=vc.sleep``)."""
    vc = VirtualClock()
    import repro.queue.job as job_mod
    monkeypatch.setattr(job_mod, "now", vc.now)
    return vc
