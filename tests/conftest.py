import os
import sys

# NOTE: never set XLA_FLAGS / host device count here — smoke tests and
# benches must see the single real CPU device (the 512-device trick is
# exclusively the dry-run launcher's, set before any jax import there).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
