"""Hypothesis property tests for the latency-tier subsystem: starvation
freedom of the mixed-tier drain and item-count conservation across the
cancel/reclaim path. Skipped wholesale when hypothesis is absent (the
deterministic sweeps in tests/test_latency_tiers.py still run)."""
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import DeviceKind, GroupSpec
from repro.core.partitioner import HeterogeneousPartitioner
from repro.core.throughput import ThroughputTracker
from repro.core.types import TIERS, IterationSpace
from repro.queue import Job, QueueManager


@settings(max_examples=30, deadline=None)
@given(tiers=st.lists(st.sampled_from(TIERS), min_size=1, max_size=40),
       express_every=st.integers(min_value=1, max_value=4))
def test_property_no_starvation_mixed_tier_drain(tiers, express_every):
    """Interleaving express pops with normal pops drains EVERY job
    exactly once — urgent load cannot starve batch work out of the
    queue, and the express lane never takes non-urgent jobs."""
    q = QueueManager()
    jobs = [Job(tier=t, priority=i) for i, t in enumerate(tiers)]
    for j in jobs:
        q.put(j)
    popped, express_popped = [], []
    rounds = 0
    while True:
        rounds += 1
        assert rounds <= 3 * len(jobs) + 3, "drain did not terminate"
        if rounds % express_every == 0:
            got = q.pop_express(1)
            express_popped.extend(got)
            popped.extend(got)
            if got:
                continue
        j = q.pop()
        if j is None:
            break
        popped.append(j)
    assert sorted(j.job_id for j in popped) == \
        sorted(j.job_id for j in jobs)
    assert all(j.tier == "urgent" for j in express_popped)


@settings(max_examples=30, deadline=None)
@given(total=st.integers(min_value=1, max_value=5000),
       takes=st.integers(min_value=0, max_value=40),
       min_chunk=st.integers(min_value=1, max_value=4))
def test_property_reclaim_conserves_item_count(total, takes, min_chunk):
    """Partitioner take/steal then reclaim (the cancellation path): every
    item is either in a taken chunk or back in the space — none lost,
    none duplicated — and reclaim is idempotent."""
    specs = {
        "a": GroupSpec("a", DeviceKind.BIG, init_throughput=1000.0,
                       min_chunk=min_chunk),
        "b": GroupSpec("b", DeviceKind.BIG, init_throughput=250.0,
                       min_chunk=1),
    }
    space = IterationSpace(0, total)
    part = HeterogeneousPartitioner(space, specs, ThroughputTracker(0.5),
                                    base_quantum=64, chunk_mode="range")
    part.begin_epoch(space)
    taken = 0
    names = ["a", "b"]
    for i in range(takes):
        tok = part.next_token(names[i % 2], space)
        if tok is None:
            break
        taken += tok.chunk.size
    assert part.reclaim_space(space) >= 0
    assert taken + space.remaining == total
    assert part.reclaim_space(space) == 0
    assert taken + space.remaining == total
