"""Zero-contention dispatch hot path: range/steal partitioner vs. the
paper's lock-per-token path, event-driven completion, batched queue
drain, and DWRR burst credits."""
import random
import time

import pytest

from repro.core import (Chunk, ChunkRecord, DeviceKind, DynamicScheduler,
                        GroupSpec, HeterogeneousPartitioner, IterationSpace,
                        JaxChunkExecutor, SleepExecutor, ThroughputTracker,
                        Token)
from repro.core.dispatch import ChunkFailure
from repro.queue.job import Job
from repro.queue.manager import QueueManager
from repro.tenancy import ShardedQueueManager, TenantRegistry, TenantSpec


# ---------------------------------------------------------------------------
# contention regression: 8 dispatcher threads through one epoch
# ---------------------------------------------------------------------------

def test_8_group_epoch_host_overhead_bounded():
    """Eight SleepExecutor groups share one partitioner for a full epoch;
    aggregate per-chunk host overhead (Tc2−Tc1: the Filter₁ grant,
    including any lock wait) must stay under a generous bound — the
    lock-per-token path blows through it once 8 dispatchers convoy on
    the global lock."""
    n_groups, items = 8, 40_000
    groups = {
        f"g{i}": GroupSpec(f"g{i}", DeviceKind.BIG, init_throughput=50_000,
                           min_chunk=8)
        for i in range(n_groups)}
    execs = {f"g{i}": SleepExecutor(rate=50_000) for i in range(n_groups)}
    s = DynamicScheduler(groups, execs, alpha=0.5)
    res = s.run(0, items)
    assert res.iterations == items
    assert sum(res.per_group_items.values()) == items
    host = sum(r.tc2 - r.tc1 for r in res.records) / len(res.records)
    assert host < 1e-3, f"per-chunk host overhead {host * 1e6:.1f}µs"


# ---------------------------------------------------------------------------
# range/steal partitioning covers exactly the same iteration set as the
# lock-per-token path
# ---------------------------------------------------------------------------

def _drive_to_exhaustion(part, space, names, rng):
    """Random-order single-threaded drain; returns the issued chunks."""
    chunks = []
    while True:
        name = rng.choice(names)
        tok = part.next_token(name)
        if tok is None:
            if space.remaining == 0:
                break               # range mode: private ranges dry too
            continue
        chunks.append(tok.chunk)
    return chunks


def _coverage(chunks):
    seen = set()
    for c in chunks:
        span = set(range(c.begin, c.end))
        assert not (span & seen), f"chunk {c} overlaps earlier chunk"
        seen |= span
    return seen


def _make_groups(G, lams):
    groups = {"accel": GroupSpec("accel", DeviceKind.ACCEL, fixed_chunk=G,
                                 init_throughput=100.0)}
    for i, lam in enumerate(lams):
        groups[f"c{i}"] = GroupSpec(f"c{i}", DeviceKind.BIG,
                                    init_throughput=lam, min_chunk=1)
    return groups


def _warm(tracker, groups):
    """One synthetic measurement per group at exactly its seed λ: chunk
    sizing is unchanged, but the partitioner sees a *measured* group and
    activates λ-share range refills (cold groups refill one chunk)."""
    for g in groups.values():
        size = 1000
        tracker.update(ChunkRecord(Token(Chunk(0, size, 0), g.name, g.kind),
                                   tg1=0.0, tg5=size / g.init_throughput))


@pytest.mark.parametrize("n,G,lams,seed", [
    (1000, 640, [], 0),
    (50_000, 256, [10.0, 90.0], 1),
    (12_345, 100, [0.01, 1000.0, 5.0], 2),
    (777, 4096, [3.0], 3),
])
def test_range_mode_coverage_matches_paper_mode(n, G, lams, seed):
    covered = {}
    for mode in ("paper", "range"):
        groups = _make_groups(G, lams)
        tracker = ThroughputTracker()
        _warm(tracker, groups)
        part = HeterogeneousPartitioner(
            IterationSpace(0, n), groups, tracker, chunk_mode=mode)
        chunks = _drive_to_exhaustion(part, part.space,
                                      list(part.groups), random.Random(seed))
        assert sum(c.size for c in chunks) == n
        covered[mode] = _coverage(chunks)
        assert covered[mode] == set(range(n))
    assert covered["range"] == covered["paper"]


try:
    from hypothesis import given, settings, strategies as st

    @given(
        n=st.integers(1, 50_000),
        G=st.integers(1, 4096),
        lams=st.lists(st.floats(0.01, 1000.0), min_size=0, max_size=4),
        order_seed=st.integers(0, 2**16),
    )
    @settings(max_examples=40, deadline=None)
    def test_range_stealing_work_conservation_property(n, G, lams,
                                                       order_seed):
        """Property: the range/steal path hands out every iteration of
        [0, n) exactly once under arbitrary interleavings — the same
        contract the paper path is property-tested for."""
        groups = _make_groups(G, lams)
        tracker = ThroughputTracker()
        _warm(tracker, groups)
        part = HeterogeneousPartitioner(
            IterationSpace(0, n), groups, tracker, chunk_mode="range")
        chunks = _drive_to_exhaustion(part, part.space, list(part.groups),
                                      random.Random(order_seed))
        assert sum(c.size for c in chunks) == n
        assert _coverage(chunks) == set(range(n))
except ImportError:                      # pragma: no cover
    pass


def test_range_mode_steals_from_largest_range():
    """Once the space is fully assigned, a dry group steals the tail of
    the largest remaining range instead of idling."""
    groups = {
        "big": GroupSpec("big", DeviceKind.BIG, init_throughput=1e6),
        "small": GroupSpec("small", DeviceKind.BIG, init_throughput=1.0),
    }
    tracker = ThroughputTracker()
    _warm(tracker, groups)
    part = HeterogeneousPartitioner(IterationSpace(0, 1000), groups,
                                    tracker, chunk_mode="range")
    tok_big = part.next_token("big")
    assert part.space.remaining <= 1     # λ-share refill: big owns ~all
    chunks, small_chunks = [tok_big.chunk], []
    while True:                          # small lives entirely off steals
        tok = part.next_token("small")
        if tok is None:
            break
        small_chunks.append(tok.chunk)
    # small drained work that had been assigned to big's private range
    assert any(c.begin > tok_big.chunk.end for c in small_chunks)
    while True:
        tok = part.next_token("big")
        if tok is None:
            break
        chunks.append(tok.chunk)
    assert _coverage(chunks + small_chunks) == set(range(1000))


def test_range_mode_remove_group_returns_unconsumed_range():
    """A group removed (death / elastic leave) mid-range returns its
    unconsumed iterations to the space — count conservation, exactly
    like a chunk requeue."""
    groups = {
        "doomed": GroupSpec("doomed", DeviceKind.BIG, init_throughput=1e6),
        "live": GroupSpec("live", DeviceKind.BIG, init_throughput=1e6),
    }
    tracker = ThroughputTracker()
    _warm(tracker, groups)
    part = HeterogeneousPartitioner(IterationSpace(0, 1000), groups,
                                    tracker, chunk_mode="range")
    tok = part.next_token("doomed")
    consumed = tok.chunk.size
    part.remove_group("doomed")
    assert part.next_token("doomed") is None
    # every assigned-but-unconsumed iteration is back in the space:
    # only the one consumed chunk is gone
    assert part.space.remaining == 1000 - consumed
    total = consumed
    while True:
        t = part.next_token("live")
        if t is None:
            break
        total += t.chunk.size
    assert total == 1000


def test_contention_stats_range_mode_rarely_touches_global_lock():
    n = 100_000
    acquires = {}
    for mode in ("paper", "range"):
        groups = {"g": GroupSpec("g", DeviceKind.BIG, init_throughput=1.0)}
        tracker = ThroughputTracker()
        _warm(tracker, groups)
        part = HeterogeneousPartitioner(
            IterationSpace(0, n), groups, tracker, chunk_mode=mode)
        chunks = 0
        while part.next_token("g") is not None:
            chunks += 1
        stats = part.contention_stats()
        acquires[mode] = stats["lock_acquires"]
        if mode == "paper":             # one global acquire per grant
            assert stats["lock_acquires"] >= chunks
    assert acquires["range"] < acquires["paper"] / 4


# ---------------------------------------------------------------------------
# event-driven completion (readiness poll)
# ---------------------------------------------------------------------------

def _jax_exec(**kw):
    import numpy as np
    return JaxChunkExecutor(lambda x: x * 2.0,
                            lambda tok: np.ones(tok.chunk.size, np.float32),
                            **kw)


def _tok(i):
    return Token(Chunk(i * 8, (i + 1) * 8, i), "a", DeviceKind.ACCEL)


def test_poll_mode_completes_opportunistically():
    """With the readiness poll, a finished chunk is returned on the next
    execute() even though the pipeline is far from its depth cap — the
    old path sat on it until the cap forced a blocking wait."""
    ex = _jax_exec(async_depth=4)        # completion_mode="poll" default
    assert ex.execute(_tok(0), ChunkRecord(_tok(0), tc1=1., tc2=1.)) == []
    time.sleep(0.3)                      # tiny op: certainly ready now
    done = ex.execute(_tok(1), ChunkRecord(_tok(1), tc1=1., tc2=1.))
    assert [r.token.chunk.seq for r in done] == [0]
    assert len(ex.drain()) == 1


def test_poll_mode_completion_failure_bookkeeping():
    """Poll-mode mirror of the block-mode failure test: a fetch failure
    during opportunistic completion loses neither finished records nor
    the popped chunk."""
    calls = {"n": 0}

    def fetch(outs):
        calls["n"] += 1
        if calls["n"] == 2:
            raise ChunkFailure("device died during fetch")
        return None

    ex = _jax_exec(fetch=fetch, async_depth=4)
    assert ex.execute(_tok(0), ChunkRecord(_tok(0), tc1=1., tc2=1.)) == []
    time.sleep(0.2)
    done = ex.execute(_tok(1), ChunkRecord(_tok(1), tc1=1., tc2=1.))
    assert [r.token.chunk.seq for r in done] == [0]
    time.sleep(0.2)
    with pytest.raises(ChunkFailure):    # opportunistic completion of 1
        ex.execute(_tok(2), ChunkRecord(_tok(2), tc1=1., tc2=1.))
    assert ex.completed() == []
    assert [c.seq for c in ex.abort()] == [1]


def test_completion_mode_validated():
    with pytest.raises(ValueError):
        _jax_exec(completion_mode="spin")


def test_poll_and_block_schedule_same_result():
    for mode in ("poll", "block"):
        ex = _jax_exec(async_depth=3, completion_mode=mode,
                       fetch=lambda o: float(o.sum()))
        s = DynamicScheduler(
            {"a": GroupSpec("a", DeviceKind.ACCEL, fixed_chunk=64)},
            {"a": ex})
        res = s.run(0, 1000)
        assert res.iterations == 1000
        assert all(r.tc3 >= r.tg5 > 0 for r in res.records)
        assert all("result" in r.meta for r in res.records)


def test_sleep_executor_skips_zero_sleeps(monkeypatch):
    """time.sleep(0.0) is a real syscall; a simulated run with zero
    t_hd/t_kl/t_dh must not pay it up to four times per chunk."""
    import repro.core.dispatch as D
    calls = []
    monkeypatch.setattr(D.time, "sleep", lambda s: calls.append(s))
    tok = Token(Chunk(0, 10, 0), "g", DeviceKind.BIG)
    D.SleepExecutor(rate=1000.0).execute(tok, ChunkRecord(tok))
    assert calls == [10 / 1000.0]        # service sleep only
    calls.clear()
    D.SleepExecutor(rate=float("inf")).execute(tok, ChunkRecord(tok))
    assert calls == []                   # pure host path: no syscalls
    calls.clear()
    D.SleepExecutor(rate=1000.0, t_hd=0.001, t_dh=0.002).execute(
        tok, ChunkRecord(tok))
    assert calls == [0.001, 10 / 1000.0, 0.002]


# ---------------------------------------------------------------------------
# batched queue drain: pop_many
# ---------------------------------------------------------------------------

def test_queue_manager_pop_many_priority_order_and_cap():
    q = QueueManager()
    jobs = [Job(items=1, priority=p) for p in (2, 0, 1, 0, 2)]
    for j in jobs:
        q.put(j)
    batch = q.pop_many(3)
    assert [j.priority for j in batch] == [0, 0, 1]
    assert q.pop_many(10) == [jobs[0], jobs[4]]
    assert q.pop_many(4) == []           # empty, non-blocking


def test_queue_manager_pop_many_blocks_until_first_job():
    import threading
    q = QueueManager()
    job = Job(items=1)
    threading.Timer(0.05, lambda: q.put(job)).start()
    batch = q.pop_many(8, timeout=2.0)
    assert batch == [job]


def test_sharded_pop_many_preserves_dwrr_shares():
    """A whole batch formed in one DWRR pass charges deficits per item:
    drained share under 10:1 weights matches 10:1, exactly as with
    single pops."""
    reg = TenantRegistry([TenantSpec("gold", weight=10.0),
                          TenantSpec("free", weight=1.0)])
    q = ShardedQueueManager(reg, quantum=10)
    for _ in range(40):
        q.put(Job(items=10, tenant="gold"))
        q.put(Job(items=10, tenant="free"))
    drained = []
    while len(drained) < 44:
        batch = q.pop_many(11)
        assert batch
        drained.extend(batch)
    gold = sum(1 for j in drained if j.tenant == "gold")
    assert gold >= 36                    # ≈ 10/11 of the drained work
    # work conservation: the rest still drains once gold empties
    rest = q.pop_many(100)
    assert len(drained) + len(rest) == 80


def test_sharded_pop_many_single_tenant_matches_heap_order():
    q = ShardedQueueManager()
    jobs = [Job(items=1, priority=p) for p in (1, 0, 2)]
    for j in jobs:
        q.put(j)
    assert q.pop_many(5) == [jobs[1], jobs[0], jobs[2]]


# ---------------------------------------------------------------------------
# DWRR burst credits (TenantSpec.burst_quantum)
# ---------------------------------------------------------------------------

def test_burst_quantum_spec_parse_and_validation():
    reg = TenantRegistry.parse("spiky:weight=2:burst=40,steady")
    assert reg.get("spiky").burst_quantum == 40.0
    assert reg.get("steady").burst_quantum == 0.0
    with pytest.raises(ValueError):
        TenantSpec("bad", burst_quantum=-1.0)


def test_burst_quantum_caps_carried_deficit():
    """An emptied shard keeps at most burst_quantum of banked deficit;
    the default 0 reproduces the classic DWRR reset exactly."""
    for burst, expect in ((40.0, 40.0), (0.0, 0.0)):
        reg = TenantRegistry([TenantSpec("spiky", burst_quantum=burst),
                              TenantSpec("steady")])
        q = ShardedQueueManager(reg, quantum=64)
        q.put(Job(items=10, tenant="spiky"))
        q.put(Job(items=10, tenant="steady"))
        assert q.pop().tenant == "spiky"  # credit 64, leftover 54 banked
        assert q.pop().tenant == "steady"  # rotation passed empty spiky
        assert q._deficit["spiky"] == expect


def test_burst_credit_skips_rampup_after_idle_gap():
    """A spiky tenant with burst credit gets its next burst served ahead
    of one more competitor job than the classic-reset tenant — it does
    not re-pay the deficit ramp-up."""
    def steady_jobs_before_second_spiky(burst):
        reg = TenantRegistry([
            TenantSpec("spiky", burst_quantum=burst),
            TenantSpec("steady")])
        q = ShardedQueueManager(reg, quantum=10)
        q.put(Job(items=5, tenant="spiky"))
        for _ in range(20):
            q.put(Job(items=10, tenant="steady"))
        assert q.pop().tenant == "spiky"   # leftover deficit 5
        q.pop()                            # spiky empties; steady serves
        q.put(Job(items=15, tenant="spiky"))   # the next burst
        count = 0
        while True:
            j = q.pop()
            if j.tenant == "spiky":
                return count
            count += 1
    with_burst = steady_jobs_before_second_spiky(100.0)
    without = steady_jobs_before_second_spiky(0.0)
    assert with_burst < without


# ---------------------------------------------------------------------------
# end-to-end: batched finalize + range mode on the persistent runtime
# ---------------------------------------------------------------------------

def test_range_mode_death_requeue_conserves_work():
    """A group dying mid-epoch in range mode returns both its in-flight
    chunk and its unconsumed private range; survivors absorb the work."""
    groups = {
        "ok": GroupSpec("ok", DeviceKind.BIG, init_throughput=100_000,
                        min_chunk=4),
        "bad": GroupSpec("bad", DeviceKind.BIG, init_throughput=100_000,
                         min_chunk=4),
    }
    execs = {"ok": SleepExecutor(rate=100_000),
             "bad": SleepExecutor(rate=100_000, fail_after=2)}
    s = DynamicScheduler(groups, execs, alpha=0.5)
    res = s.run(0, 20_000)
    assert "bad" in res.failed_groups
    assert res.iterations >= 20_000
    assert sum(res.per_group_items.values()) == res.iterations


def test_finalize_batch_flushes_all_records():
    """Batched per-worker finalize must not drop or double-count records
    at epoch end (flush-on-exit path)."""
    s = DynamicScheduler(
        {"g": GroupSpec("g", DeviceKind.BIG, init_throughput=10_000,
                        min_chunk=4)},
        {"g": SleepExecutor(rate=10_000)}, alpha=0.5, finalize_batch=16)
    res = s.run(0, 5_000)
    assert res.iterations == 5_000
    assert sum(r.token.chunk.size for r in res.records) == 5_000
    assert s.tracker.stats("g").n == len(res.records)
