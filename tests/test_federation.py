"""Federation tier: routing, gossip, replication, failover, global quotas.

Runtimes here are SleepExecutor-backed JobService instances — the same
simulated-runtime harness the queue tests use, N of them behind one
FederatedService front door.
"""
import os
import time

import pytest

from repro import telemetry as telemetry_mod
from repro.core import DeviceKind, DynamicScheduler, GroupSpec, SleepExecutor
from repro.federation import (FederatedService, GossipBus, Heartbeat,
                              ReplicationRing, Router)
from repro.queue import Job, JobService, JobState, JournalStore
from repro.queue.admission import AdmissionController, Decision
from repro.tenancy import (ShardedQueueManager, TenantAccountant,
                           TenantRegistry)

RATE = 50_000.0


def make_fed(n, directory, registry=None, rate=RATE, telemetry=None,
             heartbeat_s=0.03, admission_for=None, **fed_kw):
    """N one-group simulated runtimes. ``admission_for`` ("all" or None)
    attaches a quota-aware admission gate per runtime."""

    def make_service(rid, journal, tel):
        name = f"{rid}/accel"

        def make_sched():
            groups = {name: GroupSpec(name, DeviceKind.ACCEL,
                                      fixed_chunk=64,
                                      init_throughput=rate)}
            return DynamicScheduler(groups,
                                    {name: SleepExecutor(rate=rate)},
                                    telemetry=tel)

        accountant = None
        queue = None
        admission = None
        if registry is not None:
            queue = ShardedQueueManager(registry, telemetry=tel)
            accountant = TenantAccountant(registry)
            if admission_for == "all":
                admission = AdmissionController(queue, registry=registry,
                                                telemetry=tel)
                admission.on_group_join(name, rate)
        return JobService(make_sched, queue=queue, admission=admission,
                          journal=journal, accountant=accountant,
                          batch_jobs=4, poll_s=0.002, telemetry=tel)

    rids = [f"r{i}" for i in range(n)]
    return FederatedService(
        make_service, rids, str(directory), tenants=registry,
        telemetry=telemetry if telemetry is not None else telemetry_mod.OFF,
        heartbeat_s=heartbeat_s, **fed_kw)


# ---------------------------------------------------------------------------
# federated drain
# ---------------------------------------------------------------------------

def test_federated_drain_completes_all_jobs(tmp_path):
    fed = make_fed(3, tmp_path)
    jobs = [Job(items=32, tenant=f"t{i % 9}") for i in range(30)]
    for j in jobs:
        fed.submit(j)
    assert fed.run_until_idle(timeout_s=30)
    fed.close()
    assert all(j.state == JobState.DONE for j in jobs)
    rep = fed.report()
    assert rep.done == 30 and rep.failed == 0
    # the work actually spanned runtimes
    active = [r for r, d in rep.per_runtime.items() if d["done"] > 0]
    assert len(active) >= 2
    assert rep.gossip_rounds == 0          # telemetry OFF -> no counter


def test_federated_report_counts_gossip_and_placements(tmp_path):
    tel = telemetry_mod.Telemetry()
    fed = make_fed(2, tmp_path, telemetry=tel)
    jobs = [Job(items=16, tenant=f"t{i}") for i in range(8)]
    for j in jobs:
        fed.submit(j)
    assert fed.run_until_idle(timeout_s=30)
    fed.close()
    assert fed.report().gossip_rounds >= 1
    snap = tel.snapshot()
    routed = {k: v for k, v in snap["counters"].items()
              if k.startswith("fed.routed")}
    assert sum(routed.values()) == 8
    # every routed counter carries its runtime label
    assert all('runtime="' in k for k in routed)


# ---------------------------------------------------------------------------
# kill / failover
# ---------------------------------------------------------------------------

def test_kill_runtime_mid_drain_loses_nothing(tmp_path):
    fed = make_fed(3, tmp_path, rate=2_000.0)
    jobs = [Job(items=40, tenant=f"t{i % 12}") for i in range(36)]
    for j in jobs:
        fed.submit(j)
    fed.start()
    deadline = time.monotonic() + 30.0
    while time.monotonic() < deadline:
        if sum(1 for j in jobs if j.state == JobState.DONE) >= 8:
            break
        time.sleep(0.005)
    victim_unfinished = [
        j for j in jobs if fed._placement[j.job_id] == "r1"
        and j.state != JobState.DONE]
    recovered = fed.kill_runtime("r1")
    assert {j.job_id for j in recovered} \
        == {j.job_id for j in victim_unfinished}
    assert fed.run_until_idle(timeout_s=30)
    fed.close()
    # zero loss: every job (original or re-materialized) is DONE
    final = fed._jobs
    assert len(final) == 36
    assert all(j.state == JobState.DONE for j in final.values())
    rep = fed.report()
    assert rep.failovers == 1 and rep.killed == ["r1"]
    assert rep.recovered == len(victim_unfinished)
    # the victim's replica was replayed, not its (dead) primary journal —
    # and the survivors did the work
    assert fed._nodes["r1"].alive is False
    assert all(fed._placement[j.job_id] != "r1" for j in recovered)


def test_kill_runtime_preserves_tier_and_deadline_metadata(tmp_path):
    fed = make_fed(2, tmp_path, rate=500.0)
    far = time.time() + 3600.0
    jobs = [Job(items=40, tenant=f"t{i}", tier="urgent", priority=2,
                deadline_s=far) for i in range(6)]
    for j in jobs:
        fed.submit(j)
    victims = [j for j in jobs if fed._placement[j.job_id] == "r0"]
    assert victims                        # 6 tenants: both runtimes used
    recovered = fed.kill_runtime("r0")
    by_id = {j.job_id: j for j in recovered}
    for v in victims:
        r = by_id[v.job_id]
        assert r.tier == "urgent" and r.priority == 2
        assert r.deadline_s == pytest.approx(far)
    assert fed.run_until_idle(timeout_s=30)
    fed.close()
    assert all(j.state == JobState.DONE for j in fed._jobs.values())


def test_kill_last_runtime_recovers_nothing(tmp_path):
    fed = make_fed(1, tmp_path)
    j = Job(items=16)
    fed.submit(j)
    assert fed.kill_runtime("r0") == []
    assert fed.alive_nodes() == []
    # further submissions are rejected, not silently dropped
    dec = fed.submit(Job(items=4))
    assert dec.decision == Decision.REJECT
    fed.close()


def test_survivor_walks_past_dead_peers(tmp_path):
    fed = make_fed(3, tmp_path)
    assert fed.run_until_idle(timeout_s=10)
    ring = fed.ring
    first = ring.peer_of("r0")
    fed.kill_runtime(first)                # r0's peer dies first
    fed.kill_runtime("r0")                 # handoff must skip the corpse
    [last] = [n.runtime_id for n in fed.alive_nodes()]
    assert last not in ("r0", first)
    fed.close()


# ---------------------------------------------------------------------------
# journal replication
# ---------------------------------------------------------------------------

def test_replica_matches_primary_after_drain(tmp_path):
    fed = make_fed(2, tmp_path)
    for i in range(10):
        fed.submit(Job(items=16, tenant=f"t{i}"))
    assert fed.run_until_idle(timeout_s=30)
    fed.close()
    for rid in ("r0", "r1"):
        with open(fed.ring.journal_path(rid)) as fh:
            primary = fh.read()
        with open(fed.ring.replica_path(rid)) as fh:
            replica = fh.read()
        assert replica == primary and primary


def test_replica_follows_compaction(tmp_path):
    ring = ReplicationRing(["a", "b"], str(tmp_path))
    js = JournalStore(ring.journal_path("a"))
    js.attach_mirror(ring.make_sink("a"))
    jobs = [Job(items=4) for _ in range(5)]
    for j in jobs:
        j.transition(JobState.ADMITTED)
        js.record(j)
        j.transition(JobState.RUNNING)
        js.record(j)
    js.compact()
    j = jobs[0]
    j.transition(JobState.DONE)
    js.record(j)                           # appends post-compaction
    js.close()
    with open(ring.journal_path("a")) as fh:
        primary = fh.read()
    with open(ring.replica_path("a")) as fh:
        replica = fh.read()
    assert replica == primary
    replay = JournalStore.replay(ring.replica_path("a"))
    assert replay[j.job_id].state == JobState.DONE


def test_mirror_failure_detaches_without_breaking_journal(tmp_path):
    class Exploding:
        def append(self, line):
            raise OSError("disk gone")

    js = JournalStore(str(tmp_path / "j.jsonl"))
    js.attach_mirror(Exploding())
    job = Job(items=4)
    job.transition(JobState.ADMITTED)
    js.record(job)                         # must not raise
    assert js._mirror is None              # detached after first failure
    js.record(job)
    js.close()
    assert len(JournalStore.replay(str(tmp_path / "j.jsonl"))) == 1


def test_recovery_source_prefers_replica(tmp_path):
    ring = ReplicationRing(["a", "b", "c"], str(tmp_path))
    assert ring.peer_of("a") == "b" and ring.peer_of("c") == "a"
    assert ring.recovery_source("a") == ring.journal_path("a")
    open(ring.replica_path("a"), "w").close()
    assert ring.recovery_source("a") == ring.replica_path("a")


# ---------------------------------------------------------------------------
# recover() double-replay guard (regression for the dedupe satellite)
# ---------------------------------------------------------------------------

def _sched_factory():
    groups = {"g0": GroupSpec("g0", DeviceKind.BIG,
                              init_throughput=50_000)}
    return DynamicScheduler(groups, {"g0": SleepExecutor(rate=50_000)})


def test_recover_twice_does_not_double_enqueue(tmp_path):
    path = str(tmp_path / "dead.jsonl")
    with JournalStore(path) as js:
        for _ in range(4):
            j = Job(items=8)
            j.transition(JobState.ADMITTED)
            js.record(j)
    svc = JobService(_sched_factory)
    assert len(svc.recover(path)) == 4
    assert svc.recover(path) == []         # replayed ids are remembered
    assert svc.queue.depth() == 4
    # a replica overlapping the primary (messy failover) dedupes too,
    # even for jobs the queue has already drained
    assert svc.run_until_idle(timeout_s=30)
    assert svc.recover(path) == []
    assert svc.queue.depth() == 0
    svc.close()


def test_crash_leaves_inflight_unfinalized(tmp_path):
    path = str(tmp_path / "crash.jsonl")
    svc = JobService(_sched_factory, journal=JournalStore(path),
                     batch_jobs=2, poll_s=0.002)
    # items sized so the batch is still mid-flight when we crash
    jobs = [Job(items=2000) for _ in range(2)]
    for j in jobs:
        svc.submit(j)
    svc.start()
    deadline = time.monotonic() + 10.0
    while time.monotonic() < deadline and not svc._inflight:
        time.sleep(0.002)
    svc.crash()
    assert svc._sched is None and svc._thread is None
    assert not any(j.state == JobState.DONE for j in jobs)
    # the journal still says RUNNING/ADMITTED -> a recovery replays them
    svc2 = JobService(_sched_factory)
    recovered = svc2.recover(path)
    assert {j.job_id for j in recovered} == {j.job_id for j in jobs}
    assert svc2.run_until_idle(timeout_s=30)
    svc2.close()
    assert all(j.state == JobState.DONE for j in recovered)


# ---------------------------------------------------------------------------
# global quotas and energy budgets
# ---------------------------------------------------------------------------

def test_global_quota_binds_fleet_wide(tmp_path):
    reg = TenantRegistry.parse("capped:weight=1:quota=4,open:weight=1")
    fed = make_fed(3, tmp_path, registry=reg, admission_for="all")
    decisions = [fed.submit(Job(items=8, tenant="capped"))
                 for _ in range(12)]
    admits = sum(d.decision == Decision.ADMIT for d in decisions)
    # without the gossip-aggregated gate each of the 3 runtimes would
    # admit 4 (= 12); globally the quota stays 4
    assert admits == 4
    assert sum(d.decision == Decision.DEFER for d in decisions) == 8
    assert fed.global_unfinished("capped") == 4
    # deferred jobs drain once capacity frees up: nothing is lost
    assert fed.run_until_idle(timeout_s=30)
    fed.close()
    assert all(j.state == JobState.DONE for j in fed._jobs.values())
    assert len(fed._jobs) == 12


def test_global_energy_budget_derates_every_runtime(tmp_path):
    reg = TenantRegistry.parse("hog:weight=1:energy=100,meek:weight=1")
    fed = make_fed(2, tmp_path, registry=reg)
    # fake fleet-wide spend: 2 runtimes each report 150 J for "hog"
    now = fed.bus.clock()
    for rid in ("r0", "r1"):
        fed.bus.publish(Heartbeat(runtime_id=rid, ts=now,
                                  capacity_items_s=1.0,
                                  energy_j={"hog": 150.0}))
    fed._apply_energy_budgets()
    for node in fed.alive_nodes():
        derates = node.service.accountant.derate_weights()
        assert derates["hog"] == pytest.approx(100.0 / 300.0)
        assert "meek" not in derates
        # and the queue saw it
        assert node.service.queue.effective_weight("hog") \
            == pytest.approx(1.0 * 100.0 / 300.0)
    fed.close()


def test_external_derates_min_merge_with_local():
    reg = TenantRegistry.parse("t:weight=1:energy=10")
    acct = TenantAccountant(reg)
    acct.set_external_derates({"t": 0.5})
    assert acct.derate_weights() == {"t": 0.5}
    # local attribution says 0.2 (spent 50 J on a 10 J budget): min wins
    acct._usage.setdefault("t", type(acct.usage("t"))()).energy_j = 50.0
    assert acct.derate_weights()["t"] == pytest.approx(0.2)
    # replacing the external map with a looser factor keeps local binding
    acct.set_external_derates({"t": 0.9})
    assert acct.derate_weights()["t"] == pytest.approx(0.2)


# ---------------------------------------------------------------------------
# gossip staleness
# ---------------------------------------------------------------------------

def test_stale_heartbeat_derates_linearly_to_floor():
    t = [0.0]
    bus = GossipBus(stale_after_s=1.0, clock=lambda: t[0])
    bus.publish(Heartbeat(runtime_id="a", ts=0.0, capacity_items_s=100.0))
    assert bus.effective_capacity("a") == pytest.approx(100.0)
    t[0] = 1.0                             # inside the window: full trust
    assert bus.effective_capacity("a") == pytest.approx(100.0)
    t[0] = 1.5                             # halfway through decay
    assert bus.effective_capacity("a") == pytest.approx(50.0)
    t[0] = 10.0                            # floored, never zero
    assert bus.effective_capacity("a") == pytest.approx(10.0)
    assert bus.effective_capacity("ghost") == 0.0
    bus.drop("a")
    assert bus.effective_capacity("a") == 0.0


def test_gossip_fleet_aggregates():
    bus = GossipBus()
    bus.publish(Heartbeat(runtime_id="a", ts=bus.clock(),
                          unfinished_jobs={"t": 3}, energy_j={"t": 5.0}))
    bus.publish(Heartbeat(runtime_id="b", ts=bus.clock(),
                          unfinished_jobs={"t": 2, "u": 1},
                          energy_j={"t": 7.0}))
    assert bus.unfinished("t") == 5 and bus.unfinished("u") == 1
    assert bus.energy("t") == pytest.approx(12.0)
    assert bus.tenants() == {"t", "u"}


# ---------------------------------------------------------------------------
# per-runtime telemetry namespace
# ---------------------------------------------------------------------------

def test_labeled_registry_separates_runtimes():
    tel = telemetry_mod.Telemetry()
    tel.labeled(runtime="r0").registry.counter("svc.batches").add(2)
    tel.labeled(runtime="r1").registry.counter("svc.batches").add(5)
    snap = tel.snapshot()
    assert snap["counters"]['svc.batches{runtime="r0"}'] == 2
    assert snap["counters"]['svc.batches{runtime="r1"}'] == 5


def test_labeled_tracer_namespaces_epoch_tags():
    tel = telemetry_mod.Telemetry()
    v0, v1 = tel.labeled(runtime="r0"), tel.labeled(runtime="r1")
    v0.tracer.tag_epoch(0, {"batch": "a"})
    v1.tracer.tag_epoch(0, {"batch": "b"})  # same epoch index, no clash
    assert v0.tracer.epoch_tag(0) == {"batch": "a"}
    assert v1.tracer.epoch_tag(0) == {"batch": "b"}


def test_resolve_passes_views_through():
    tel = telemetry_mod.Telemetry()
    view = tel.labeled(runtime="rX")
    assert telemetry_mod.resolve(view) is view
    assert telemetry_mod.resolve(telemetry_mod.OFF) is None


# ---------------------------------------------------------------------------
# router basics (the hypothesis suite deepens these)
# ---------------------------------------------------------------------------

def test_router_empty_and_membership():
    r = Router()
    assert r.place("k") is None
    r.add_runtime("a")
    assert r.place("k") == "a"
    r.add_runtime("a")                     # idempotent
    assert r.runtimes() == ["a"]
    r.remove_runtime("a")
    assert r.place("k") is None
    with pytest.raises(ValueError):
        Router(bound=1.0)


def test_router_bounded_load_spills_hot_key():
    r = Router(["a", "b", "c", "d"], bound=1.25)
    placed = r.place_many(["hot"] * 100, weight=1.0)
    assert len(placed) == 1                # place_many keys are unique
    # water-fill one hot key by hand: it must spread once over bound
    loads = {}
    hit = set()
    for _ in range(100):
        rid = r.place("hot", loads)
        hit.add(rid)
        loads[rid] = loads.get(rid, 0.0) + 1.0
    assert len(hit) == 4
    total = sum(loads.values())
    for rid, load in loads.items():
        assert load <= 1.25 * r.capacity_share(rid) * (total + 1) + 1.0


def test_router_capacity_share_attracts_proportionally():
    r = Router(["big", "small"], bound=1.1)
    r.set_capacity("big", 9.0)
    r.set_capacity("small", 1.0)
    loads = {}
    for i in range(200):
        rid = r.place(f"k{i}", loads)
        loads[rid] = loads.get(rid, 0.0) + 1.0
    assert loads["big"] > loads["small"] * 4


# ---------------------------------------------------------------------------
# replication failure paths (chaos-plane satellites): mirror detach +
# gossip-round heal, and racing kill_runtime calls
# ---------------------------------------------------------------------------

def test_mirror_fail_window_detaches_then_gossip_heals(tmp_path):
    from repro.chaos import ChaosInjector, FaultEvent, FaultPlan

    tel = telemetry_mod.Telemetry()
    plan = FaultPlan.compose(
        [FaultEvent(at_s=0.0, layer="federation", kind="mirror_fail",
                    target="r0", duration_s=0.4)], horizon_s=0.6)
    inj = ChaosInjector(plan, telemetry=tel)
    fed = make_fed(2, tmp_path, rate=5_000.0, telemetry=tel, chaos=inj)
    fed.start()
    # journal writes land inside the window on every runtime: r0's
    # mirror raises and detaches (the journal's contract for a bad sink)
    t0 = time.monotonic()
    i = 0
    while time.monotonic() - t0 < 0.45:
        fed.submit(Job(items=16, tenant=f"t{i % 16}"))
        i += 1
        time.sleep(0.01)
    assert fed._nodes["r0"].journal.mirror_detaches >= 1
    while not inj.done():
        time.sleep(0.01)
    fed.gossip_round()                     # window passed -> heal fires
    assert fed._nodes["r0"].journal.has_mirror()
    assert fed.run_until_idle(timeout_s=30)
    fed.close()
    assert all(j.state == JobState.DONE for j in fed._jobs.values())
    c = tel.snapshot()["counters"]
    assert c.get('fed.mirror_resyncs{runtime="r0"}', 0) >= 1
    # post-heal replica replays to the same per-job final states as the
    # primary (resync rewrote it from the journal's live state)
    ring = fed.ring
    primary = JournalStore.replay(ring.journal_path("r0"))
    replica = JournalStore.replay(ring.replica_path("r0"))
    assert {j: s.state for j, s in replica.items()} \
        == {j: s.state for j, s in primary.items()}


def _drain_some(fed, jobs, want=6, timeout=30.0):
    deadline = time.monotonic() + timeout
    while time.monotonic() < deadline:
        if sum(1 for j in jobs if j.state == JobState.DONE) >= want:
            return
        time.sleep(0.005)
    raise AssertionError("fixture never drained far enough")


def test_concurrent_kills_of_distinct_runtimes_lose_nothing(tmp_path):
    import threading

    fed = make_fed(3, tmp_path, rate=2_000.0)
    jobs = [Job(items=40, tenant=f"t{i % 12}") for i in range(36)]
    for j in jobs:
        fed.submit(j)
    fed.start()
    _drain_some(fed, jobs)
    # r1's replica lives on r2 and r2's on r0: killing both at once
    # exercises the kill serialization AND the survivor walk past a
    # dead peer (whichever kill loses the lock race hands off to r0)
    results = {}
    ts = [threading.Thread(
        target=lambda r=r: results.setdefault(r, fed.kill_runtime(r)))
        for r in ("r1", "r2")]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    assert fed.run_until_idle(timeout_s=30)
    fed.close()
    final = fed._jobs
    assert len(final) == 36
    assert all(j.state == JobState.DONE for j in final.values())
    assert sorted(fed._killed) == ["r1", "r2"]
    # every recovered job rematerialized onto the sole survivor
    for r in ("r1", "r2"):
        for j in results[r]:
            assert fed._placement[j.job_id] == "r0"
    # zero duplicate completions across the primaries (double-replay
    # guard): no job id carries two ``done`` records
    import json as json_mod
    done_counts = {}
    for p in tmp_path.glob("*.journal.jsonl"):
        for line in p.read_text().splitlines():
            try:
                rec = json_mod.loads(line)
            except ValueError:
                continue
            if rec.get("event") == "done":
                jid = rec["job"]["job_id"]
                done_counts[jid] = done_counts.get(jid, 0) + 1
    assert all(c == 1 for c in done_counts.values())


def test_racing_kills_of_same_runtime_fire_once(tmp_path):
    import threading

    fed = make_fed(3, tmp_path, rate=2_000.0)
    jobs = [Job(items=40, tenant=f"t{i % 12}") for i in range(24)]
    for j in jobs:
        fed.submit(j)
    fed.start()
    _drain_some(fed, jobs)
    results = []
    ts = [threading.Thread(
        target=lambda: results.append(fed.kill_runtime("r1")))
        for _ in range(2)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    # exactly one caller performed the failover; the loser saw a dead
    # runtime and recovered nothing (no double replay)
    assert sorted(len(r) for r in results)[0] == 0
    assert fed._killed == ["r1"]
    assert fed.report().failovers == 1
    assert fed.run_until_idle(timeout_s=30)
    fed.close()
    assert all(j.state == JobState.DONE for j in fed._jobs.values())
