"""Telemetry overhead: instrumented vs. uninstrumented dispatch hot path.

The observability layer's contract is that always-on instrumentation must
not reintroduce the host-side overhead the range partitioner removed: the
metrics hot path is per-thread shards (no shared lock) and a traced chunk
is one deque append. This benchmark runs the same zero-service
SleepExecutor workload as benchmarks/dispatch_overhead.py twice —

  * baseline:     ``telemetry=repro.telemetry.OFF`` (no instrumentation)
  * instrumented: a fresh ``Telemetry(sample_rate=1.0)`` (every chunk
                  metered AND traced — the worst case)

— and reports per-chunk host overhead (mean (Tc2−Tc1) + max(Tc3−Tg5, 0))
for both, plus the registry's own self-measured cost
(``snapshot()["self"]``). Each (mode, workers) cell is best-of-TRIALS to
keep scheduler warm-up and OS noise out of the ratio.

The w=8 ratio is asserted ≤ ``MAX_RATIO`` (1.15): a regression that drags
instrumentation cost back onto the hot path fails the benchmark run
outright instead of drifting silently.

Run:  PYTHONPATH=src python -m benchmarks.run --only telemetry_overhead
      PYTHONPATH=src python -m benchmarks.telemetry_overhead
"""
from __future__ import annotations

import sys
from pathlib import Path
from typing import List, Tuple

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro import telemetry as telemetry_mod
from repro.core import (DeviceKind, DynamicScheduler, GroupSpec,
                        SleepExecutor)
from repro.telemetry import Telemetry

WORKERS = (2, 4, 8)
ITEMS = 60_000
QUICK_WORKERS = (8,)
QUICK_ITEMS = 24_000
BASE_QUANTUM = 64
TRIALS = 5
#: acceptance ceiling on instrumented/uninstrumented host overhead at the
#: highest worker count
MAX_RATIO = 1.15


def _run_one(n_workers: int, items: int, telemetry) -> Tuple[float, float]:
    groups = {
        f"g{i}": GroupSpec(f"g{i}", DeviceKind.BIG, init_throughput=1.0,
                           min_chunk=8)
        for i in range(n_workers)}
    execs = {name: SleepExecutor(rate=float("inf")) for name in groups}
    sched = DynamicScheduler(groups, execs, alpha=0.5,
                             base_quantum=BASE_QUANTUM, chunk_mode="range",
                             telemetry=telemetry)
    res = sched.run(0, items)
    if res.iterations != items:
        raise RuntimeError(f"telemetry_overhead/w{n_workers}: covered "
                           f"{res.iterations} of {items} iterations")
    recs = res.records
    host = sum((r.tc2 - r.tc1) + max(r.tc3 - r.tg5, 0.0) for r in recs) \
        / len(recs)
    return host, res.total_time


def _measure(w: int, items: int):
    """Interleaved off/on trials so slow drift (thermal, other load) hits
    both sides alike; min-of-trials is the noise-floor statistic the
    ratio compares."""
    off_host = on_host = off_wall = on_wall = float("inf")
    tel: Telemetry = None
    for _ in range(TRIALS):
        h, t = _run_one(w, items, telemetry_mod.OFF)
        off_host, off_wall = min(off_host, h), min(off_wall, t)
        tel = Telemetry(sample_rate=1.0)
        h, t = _run_one(w, items, tel)
        on_host, on_wall = min(on_host, h), min(on_wall, t)
    return off_host, off_wall, on_host, on_wall, tel


def _rows(workers, items, enforce: bool = True) \
        -> List[Tuple[str, float, str]]:
    out: List[Tuple[str, float, str]] = []
    # warm both code paths once (interpreter specialization, thread-local
    # cell creation) so the first measured cell is not the cold one
    _run_one(2, 2_000, telemetry_mod.OFF)
    _run_one(2, 2_000, Telemetry(sample_rate=1.0))
    for w in workers:
        off_host, off_wall, on_host, on_wall, tel = _measure(w, items)
        ratio = on_host / max(off_host, 1e-12)
        if enforce and w == max(workers) and ratio > MAX_RATIO:
            # one re-measure before failing: the min-of-TRIALS statistic
            # still has single-digit-percent noise at smoke sizes, and a
            # genuine hot-path regression reproduces; a scheduler blip
            # does not
            off_host, off_wall, on_host, on_wall, tel = _measure(w, items)
            ratio = on_host / max(off_host, 1e-12)
        self_stats = tel.snapshot()["self"]
        out.append((f"telemetry_overhead/off/w{w}", off_host * 1e6,
                    f"wall_ms={off_wall * 1e3:.2f};items={items}"))
        out.append((f"telemetry_overhead/on/w{w}", on_host * 1e6,
                    f"wall_ms={on_wall * 1e3:.2f};items={items};"
                    f"registry_ns_per_op={self_stats['ns_per_op']:.0f};"
                    f"registry_ops={self_stats['ops']}"))
        out.append((f"telemetry_overhead/ratio/w{w}", ratio,
                    f"on_over_off_host_overhead=x{ratio:.3f};"
                    f"max_allowed=x{MAX_RATIO}"))
        if enforce and w == max(workers) and ratio > MAX_RATIO:
            raise RuntimeError(
                f"telemetry_overhead/w{w}: instrumented host overhead "
                f"{on_host * 1e6:.2f}us is x{ratio:.3f} of uninstrumented "
                f"{off_host * 1e6:.2f}us (> x{MAX_RATIO} budget)")
    return out


def rows_telemetry_overhead() -> List[Tuple[str, float, str]]:
    return _rows(WORKERS, ITEMS)


def rows_telemetry_overhead_quick() -> List[Tuple[str, float, str]]:
    """Small profile for scripts/smoke.sh — same assertion, smaller run."""
    return _rows(QUICK_WORKERS, QUICK_ITEMS)


ALL = [rows_telemetry_overhead]
QUICK = [rows_telemetry_overhead_quick]


if __name__ == "__main__":
    print("name,us_per_call,derived")
    for name, us, derived in rows_telemetry_overhead():
        print(f"{name},{us:.3f},{derived}")
