"""Dispatch hot-path overhead: lock-per-token vs. range/steal partitioner.

The paper's thesis is that host-side per-chunk costs (scheduling critical
section, dispatch, synchronous waits) dominate dynamic-scheduling overhead
as worker count grows. This benchmark measures exactly that on the real
threaded runtime with zero-service SleepExecutors (``rate=inf`` → every
sleep is skipped → dispatchers hammer the partitioner at full speed, the
worst-case contention pattern):

  * per-chunk host overhead — mean((Tc2−Tc1) + max(Tc3−Tg5, 0)): Filter₁
    grant latency (including any lock wait) plus host-resume latency
  * global-lock wait — the partitioner's instrumented lock-wait total
    (every token grant in ``chunk_mode="paper"``; refill/steal only in
    ``chunk_mode="range"``)

for worker counts 2/4/8, old path (``paper``: one global lock per token,
record-at-a-time finalize) vs. new path (``range``: private λ-share
ranges + work stealing, batched finalize).

The two paths must agree on the *schedule result*: identical iteration
coverage (work conservation) and consistent per-group accounting — any
mismatch raises, which is what makes the ``--quick`` profile a smoke-test
stage and not just a timer.

Run:  PYTHONPATH=src python -m benchmarks.run --only dispatch_overhead
      PYTHONPATH=src python -m benchmarks.dispatch_overhead
"""
from __future__ import annotations

import sys
from pathlib import Path
from typing import Dict, List, Tuple

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.core import (DeviceKind, DynamicScheduler, GroupSpec,
                        ScheduleResult, SleepExecutor)

WORKERS = (2, 4, 8)
ITEMS = 120_000
QUICK_WORKERS = (2, 8)
QUICK_ITEMS = 12_000
BASE_QUANTUM = 64                     # ~ITEMS/64 chunks: dense host traffic


def _build(n_workers: int, chunk_mode: str) -> DynamicScheduler:
    groups = {
        f"g{i}": GroupSpec(f"g{i}", DeviceKind.BIG, init_throughput=1.0,
                           min_chunk=8)
        for i in range(n_workers)}
    execs = {name: SleepExecutor(rate=float("inf")) for name in groups}
    return DynamicScheduler(groups, execs, alpha=0.5,
                            base_quantum=BASE_QUANTUM, chunk_mode=chunk_mode)


def _run_one(n_workers: int, items: int, chunk_mode: str) \
        -> Tuple[ScheduleResult, float, Dict[str, float]]:
    sched = _build(n_workers, chunk_mode)
    res = sched.run(0, items)
    recs = res.records
    if not recs:
        raise RuntimeError(f"{chunk_mode}/w{n_workers}: no records")
    host = sum((r.tc2 - r.tc1) + max(r.tc3 - r.tg5, 0.0) for r in recs) \
        / len(recs)
    return res, host, sched.partitioner.contention_stats()


def _check_schedule(res: ScheduleResult, items: int, label: str) -> None:
    """ScheduleResult semantics both paths must satisfy; raises on a
    violation so a hot-path regression fails the smoke run outright."""
    if res.iterations != items:
        raise RuntimeError(
            f"{label}: covered {res.iterations} of {items} iterations "
            f"(work conservation violated)")
    if sum(res.per_group_items.values()) != res.iterations:
        raise RuntimeError(f"{label}: per-group accounting mismatch")
    if len(res.records) == 0 or res.failed_groups:
        raise RuntimeError(f"{label}: unexpected records/failed_groups")
    covered = sum(r.token.chunk.size for r in res.records)
    if covered != res.iterations:
        raise RuntimeError(
            f"{label}: record chunks cover {covered} != {res.iterations}")


def _rows(workers, items) -> List[Tuple[str, float, str]]:
    out: List[Tuple[str, float, str]] = []
    for w in workers:
        per_mode: Dict[str, float] = {}
        for mode in ("paper", "range"):
            res, host, lock = _run_one(w, items, mode)
            _check_schedule(res, items, f"dispatch_overhead/{mode}/w{w}")
            per_mode[mode] = host
            derived = (f"lock_wait_ms={lock['lock_wait_s'] * 1e3:.3f};"
                       f"lock_acquires={int(lock['lock_acquires'])};"
                       f"chunks={len(res.records)};"
                       f"wall_ms={res.total_time * 1e3:.2f};items={items}")
            out.append((f"dispatch_overhead/{mode}/w{w}", host * 1e6,
                        derived))
        ratio = per_mode["paper"] / max(per_mode["range"], 1e-12)
        out.append((f"dispatch_overhead/speedup/w{w}", ratio,
                    f"paper_over_range_host_overhead=x{ratio:.2f}"))
    return out


def rows_dispatch_overhead() -> List[Tuple[str, float, str]]:
    return _rows(WORKERS, ITEMS)


def rows_dispatch_overhead_quick() -> List[Tuple[str, float, str]]:
    """Tiny profile for scripts/smoke.sh: same old/new schedule-result
    cross-check, sizes small enough for every smoke pass."""
    return _rows(QUICK_WORKERS, QUICK_ITEMS)


ALL = [rows_dispatch_overhead]
QUICK = [rows_dispatch_overhead_quick]


if __name__ == "__main__":
    print("name,us_per_call,derived")
    for name, us, derived in rows_dispatch_overhead():
        print(f"{name},{us:.3f},{derived}")
