"""HLO-walking cost model with while-loop trip-count scaling.

Why this exists: ``compiled.cost_analysis()`` counts a while-loop body ONCE
(verified in tests/test_hlo_cost.py), but all our models iterate layers and
attention/SSD chunks with ``lax.scan`` — so the roofline FLOPs/bytes must be
derived by walking the optimized HLO and multiplying loop bodies by their
trip counts.

Accounting:
  * FLOPs: dot (2·out_elems·contraction from the dot dnums), convolution
    (2·out_elems·window·Cin/feature_groups), reduce (~1/input elem), plus
    1/elem for elementwise ops — validated against cost_analysis on
    loop-free modules in tests/test_hlo_cost.py.
  * bytes: fusion-aware — the CPU backend barely fuses, while the TPU
    compiler fuses elementwise chains into their producers, so counting
    every CPU-HLO op's operands would wildly overstate HBM traffic. We count
    operand+result bytes only at *materialization boundaries*: dot/conv/
    reduce/sort, data movement (dynamic-(update-)slice, gather, scatter,
    concatenate, copy), fusions (their operands/results), and collectives.
    Pure elementwise/broadcast/compare ops are treated as fused (free).
  * collective bytes: operand bytes of all-gather / all-reduce /
    reduce-scatter / all-to-all / collective-permute, × enclosing trip
    counts.
  * trip counts: from each while condition's compare-against-constant
    (max int constant in the condition computation — validated on knowns).

Operands are printed as bare names in modern HLO, so the walker keeps a
symbol table (op name → result type) per computation to resolve operand
shapes.

The walked HLO is the *per-device* partitioned module, so all results are
per-chip already.
"""
from __future__ import annotations

import math
import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.*?)\s+"
    r"([a-z][\w\-]*)\((.*)$")
CALLS_RE = re.compile(r"calls=%?([\w\.\-]+)")
BODY_RE = re.compile(r"body=%?([\w\.\-]+)")
COND_RE = re.compile(r"condition=%?([\w\.\-]+)")
CONST_RE = re.compile(r"constant\((\d+)\)")
NAME_RE = re.compile(r"^%?([\w\.\-]+)$")

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

# ops whose operands/results genuinely move through HBM on TPU (elementwise
# chains fuse into these producers/consumers and are not counted separately).
# Slicing ops count only the *touched region*, not the full operand — a scan
# body dynamic-slicing one layer out of stacked weights reads one layer's
# bytes per iteration, not the whole stack.
MATERIALIZING = {
    "dot", "convolution", "reduce", "sort", "fusion",
    "dynamic-slice", "dynamic-update-slice", "gather", "scatter",
    "concatenate", "copy", "pad", "reverse", "slice",
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute", "custom-call",
}

# opcodes that do no arithmetic worth counting
ZERO_FLOP = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "reshape", "transpose", "broadcast", "slice", "dynamic-slice",
    "dynamic-update-slice", "concatenate", "copy", "copy-start",
    "copy-done", "iota", "reverse", "pad", "gather", "scatter",
    "while", "conditional", "call", "custom-call", "after-all",
    "infeed", "outfeed", "rng", "rng-bit-generator", "convert",
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute", "partition-id", "replica-id", "fusion",
    "optimization-barrier", "select", "compare",
}


def _elems(dims: str) -> int:
    if not dims:
        return 1
    n = 1
    for d in dims.split(","):
        n *= int(d)
    return n


def shape_bytes(type_str: str) -> int:
    return sum(DTYPE_BYTES[m.group(1)] * _elems(m.group(2))
               for m in SHAPE_RE.finditer(type_str)
               if m.group(1) in DTYPE_BYTES)


def shape_elems(type_str: str) -> int:
    return sum(_elems(m.group(2)) for m in SHAPE_RE.finditer(type_str)
               if m.group(1) in DTYPE_BYTES)


def shape_dims(type_str: str) -> List[int]:
    m = SHAPE_RE.search(type_str)
    if not m or not m.group(2):
        return []
    return [int(d) for d in m.group(2).split(",")]


@dataclass
class OpInfo:
    name: str
    out_type: str
    opcode: str
    rest: str
    operands: List[str] = field(default_factory=list)


def _parse_operands(rest: str) -> List[str]:
    """Names (or inline types) inside the top-level parens of op(...)."""
    depth = 1
    buf = []
    out = []
    for ch in rest:
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                break
        if depth >= 1:
            if ch == "," and depth == 1:
                out.append("".join(buf).strip())
                buf = []
            else:
                buf.append(ch)
    if buf:
        out.append("".join(buf).strip())
    return [o for o in out if o]


@dataclass
class CompCost:
    flops: float = 0.0
    bytes: float = 0.0
    coll_bytes: float = 0.0
    coll_detail: Dict[str, float] = field(default_factory=dict)

    def add_scaled(self, other: "CompCost", k: float = 1.0,
                   include_bytes: bool = True):
        self.flops += k * other.flops
        if include_bytes:
            self.bytes += k * other.bytes
        self.coll_bytes += k * other.coll_bytes
        for key, v in other.coll_detail.items():
            self.coll_detail[key] = self.coll_detail.get(key, 0) + k * v


def _split_computations(text: str) -> Dict[str, List[str]]:
    comps: Dict[str, List[str]] = {}
    cur: Optional[str] = None
    for line in text.splitlines():
        stripped = line.strip()
        if cur is None:
            if "(" in line and line.rstrip().endswith("{"):
                m = re.match(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(", line)
                if m:
                    cur = m.group(1)
                    comps[cur] = []
        else:
            if stripped == "}" or line.startswith("}"):
                cur = None
            else:
                comps[cur].append(line)
    return comps


class HloCostModel:
    def __init__(self, text: str):
        self.comps = _split_computations(text)
        self._ops: Dict[str, List[OpInfo]] = {}
        self._types: Dict[str, Dict[str, str]] = {}
        self._global_types: Dict[str, str] = {}
        for name, lines in self.comps.items():
            ops = []
            types: Dict[str, str] = {}
            for ln in lines:
                m = OP_RE.match(ln)
                if m:
                    op = OpInfo(m.group(1), m.group(2), m.group(3),
                                m.group(4))
                    op.operands = _parse_operands(op.rest)
                    ops.append(op)
                    types[op.name] = op.out_type
                    self._global_types[op.name] = op.out_type
            self._ops[name] = ops
            self._types[name] = types
        self.entry = self._find_entry(text)
        self._memo: Dict[str, CompCost] = {}

    def _find_entry(self, text: str) -> str:
        m = re.search(r"^ENTRY\s+%?([\w\.\-]+)", text, re.M)
        if m:
            return m.group(1)
        for name in self.comps:
            if "main" in name:
                return name
        return next(iter(self.comps))

    # ------------------------------------------------------------------
    def _resolve(self, comp: str, token: str) -> str:
        """Operand token -> type string ('' if unresolvable)."""
        if "[" in token:
            return token                       # inline type (old format)
        m = NAME_RE.match(token)
        if not m:
            return ""
        name = m.group(1)
        return self._types.get(comp, {}).get(name) \
            or self._global_types.get(name, "")

    def _operand_types(self, comp: str, op: OpInfo) -> List[str]:
        return [self._resolve(comp, t) for t in op.operands]

    def trip_count(self, cond_name: str) -> int:
        consts = [int(c) for ln in self.comps.get(cond_name, [])
                  for c in CONST_RE.findall(ln)]
        return max(consts) if consts else 1

    # ------------------------------------------------------------------
    def _dot_flops(self, comp: str, op: OpInfo) -> float:
        out_elems = shape_elems(op.out_type)
        otypes = self._operand_types(comp, op)
        lhs = shape_dims(otypes[0]) if otypes else []
        contract = 1
        mcon = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.rest)
        if mcon and mcon.group(1) and lhs:
            for i in mcon.group(1).split(","):
                if int(i) < len(lhs):
                    contract *= lhs[int(i)]
        return 2.0 * out_elems * contract

    def _conv_flops(self, comp: str, op: OpInfo) -> float:
        out_elems = shape_elems(op.out_type)
        window = 1
        mw = re.search(r"window=\{size=([\dx]+)", op.rest)
        if mw:
            for d in mw.group(1).split("x"):
                window *= int(d)
        fg = 1
        mg = re.search(r"feature_group_count=(\d+)", op.rest)
        if mg:
            fg = int(mg.group(1))
        otypes = self._operand_types(comp, op)
        cin = 1
        if len(otypes) >= 2:
            kdims = shape_dims(otypes[1])
            if len(kdims) >= 2:
                cin = kdims[-2]      # kernel layout ...,(in/fg),out
        return 2.0 * out_elems * window * cin

    # ------------------------------------------------------------------
    def _op_bytes(self, comp: str, op: OpInfo) -> float:
        """HBM traffic attributed to one op (TPU fusion-aware; see header)."""
        oc = op.opcode
        out_b = shape_bytes(op.out_type)
        if oc in ("dynamic-slice", "slice", "gather"):
            return 2.0 * out_b            # read touched region + write out
        if oc in ("dynamic-update-slice", "scatter"):
            otypes = self._operand_types(comp, op)
            upd = shape_bytes(otypes[1]) if len(otypes) > 1 else out_b
            return 2.0 * upd              # read + write the touched region
        if oc == "fusion":
            m = CALLS_RE.search(op.rest)
            inner = 0.0
            dus_sized = 0
            if m:
                callee = m.group(1)
                for iop in self._ops.get(callee, []):
                    if iop.opcode == "dynamic-update-slice":
                        dus_sized = max(dus_sized, shape_bytes(iop.out_type))
                    if iop.opcode in MATERIALIZING and iop.opcode != "fusion":
                        inner += self._op_bytes(callee, iop)
            if dus_sized and dus_sized >= 0.5 * out_b:
                # scan-stacking / in-place-update fusion: on TPU the output
                # buffer is aliased and only the updated slice is written
                # (the interior DUS rule already counted the touched region)
                return inner
            return out_b + inner
        if oc in MATERIALIZING:
            otypes = self._operand_types(comp, op)
            return out_b + sum(shape_bytes(t) for t in otypes)
        return 0.0

    def comp_cost(self, name: str, top_level: bool = True) -> CompCost:
        key = f"{name}|{top_level}"
        if key in self._memo:
            return self._memo[key]
        cost = CompCost()
        self._memo[key] = cost     # guard against recursive custom-calls
        for op in self._ops.get(name, []):
            oc = op.opcode
            otypes = self._operand_types(name, op)
            # ---- FLOPs ---------------------------------------------------
            if oc == "dot":
                cost.flops += self._dot_flops(name, op)
            elif oc == "convolution":
                cost.flops += self._conv_flops(name, op)
            elif oc == "fusion":
                m = CALLS_RE.search(op.rest)
                if m:
                    cost.add_scaled(
                        self.comp_cost(m.group(1), top_level=False),
                        include_bytes=False)
            elif oc == "while":
                body = BODY_RE.search(op.rest)
                cond = COND_RE.search(op.rest)
                trips = self.trip_count(cond.group(1)) if cond else 1
                if body:
                    cost.add_scaled(
                        self.comp_cost(body.group(1), top_level=True),
                        k=trips)
            elif oc in ("call", "conditional"):
                for m in re.finditer(r"(?:calls|to_apply)=%?([\w\.\-]+)",
                                     op.rest):
                    if m.group(1) in self.comps:
                        cost.add_scaled(
                            self.comp_cost(m.group(1), top_level=True))
            elif oc == "reduce":
                cost.flops += sum(shape_elems(t) for t in otypes)
            elif oc not in ZERO_FLOP:
                cost.flops += shape_elems(op.out_type)

            # ---- bytes (materialization boundaries only; see docstring) --
            if top_level and oc in MATERIALIZING:
                cost.bytes += self._op_bytes(name, op)

            # ---- collectives ---------------------------------------------
            if oc in COLLECTIVES:
                b = sum(shape_bytes(t) for t in otypes)
                cost.coll_bytes += b
                cost.coll_detail[oc] = cost.coll_detail.get(oc, 0) + b
        self._memo[key] = cost
        return cost

    def total(self) -> CompCost:
        self._memo.clear()
        return self.comp_cost(self.entry, top_level=True)


def analyze_text(text: str) -> Dict[str, float]:
    model = HloCostModel(text)
    c = model.total()
    return {"flops": c.flops, "bytes": c.bytes,
            "collective_bytes": c.coll_bytes,
            "collectives": dict(c.coll_detail)}


def analyze_file(path) -> Dict[str, float]:
    import gzip
    opener = gzip.open if str(path).endswith(".gz") else open
    with opener(path, "rt") as f:
        return analyze_text(f.read())
