"""Roofline analysis over the dry-run matrix.

For every (arch × shape × mesh) cell with a saved compiled-HLO artifact:

  compute   = HLO_FLOPs_per_chip / peak_FLOPs          (197 TF/s bf16, v5e)
  memory    = HLO_bytes_per_chip / HBM_bw              (819 GB/s)
  collective= collective_bytes_per_chip / ICI_bw       (50 GB/s/link)

(The walked HLO is the per-device partitioned module, so no ÷chips needed.)
Also reports MODEL_FLOPS (6·N·D train / 2·N_active·D inference), the useful-
compute ratio MODEL_FLOPS/(HLO_FLOPs·chips), the dominant term, and an
auto-generated "what would move it" note.

Usage:
  PYTHONPATH=src python -m benchmarks.roofline [--mesh single] [--update-md]
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))
sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

from benchmarks.hlo_cost import analyze_file                     # noqa: E402
from repro.configs.base import SHAPES_BY_NAME                    # noqa: E402
from repro.configs.registry import ARCHS                         # noqa: E402

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

RESULTS = Path(__file__).resolve().parents[1] / "results"


def model_flops(arch_id: str, shape_name: str) -> float:
    cfg = ARCHS[arch_id]
    shape = SHAPES_BY_NAME[shape_name]
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * (shape.seq_len - cfg.prefix_len)
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * (shape.seq_len - cfg.prefix_len)
        return 2.0 * n_active * tokens
    # decode: one token per sequence
    return 2.0 * n_active * shape.global_batch


def bottleneck_note(arch, shape, dom, terms, useful):
    if dom == "collective":
        return ("collective-bound: restructure sharding to cut per-layer "
                "gathers (wider FSDP prefetch, or TP-only for this shape)")
    if dom == "memory":
        if "decode" in shape:
            return ("HBM-bound (expected for decode: KV/state streaming); "
                    "quantize cache or raise batch to amortize weights")
        return ("HBM-bound: increase arithmetic intensity (larger "
                "microbatch per chip, fuse elementwise chains)")
    if useful < 0.5:
        return ("compute-bound but low useful ratio: remat/masked-attention "
                "recompute dominates — triangular schedule / flash-vjp")
    return "compute-bound near roofline: scale batch or accept"


def analyze_cell(path: Path) -> dict:
    meta = json.loads(path.read_text())
    if meta.get("status") != "ok":
        return meta
    hlo = Path(str(path).replace(".json", ".json.hlo.gz"))
    if not hlo.exists():
        meta["roofline"] = {"error": "no hlo artifact"}
        return meta
    w = analyze_file(hlo)
    chips = meta["n_devices"]
    t_comp = w["flops"] / PEAK_FLOPS
    t_mem = w["bytes"] / HBM_BW
    t_coll = w["collective_bytes"] / ICI_BW
    terms = {"compute": t_comp, "memory": t_mem, "collective": t_coll}
    dom = max(terms, key=terms.get)
    mf = model_flops(meta["arch"], meta["shape"])
    useful = mf / max(w["flops"] * chips, 1.0)
    bound = max(terms.values())
    t_model = mf / chips / PEAK_FLOPS
    meta["roofline"] = {
        "flops_per_chip": w["flops"],
        "bytes_per_chip": w["bytes"],
        "collective_bytes_per_chip": w["collective_bytes"],
        "collectives": w["collectives"],
        "t_compute_s": t_comp,
        "t_memory_s": t_mem,
        "t_collective_s": t_coll,
        "bound_s": bound,
        "dominant": dom,
        "model_flops": mf,
        "useful_ratio": useful,
        "roofline_fraction": t_model / bound if bound else 0.0,
        "note": bottleneck_note(meta["arch"], meta["shape"], dom, terms,
                                useful),
    }
    return meta


def fmt_s(x: float) -> str:
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x * 1e3:.1f}ms"
    return f"{x * 1e6:.0f}us"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--suffix", default="",
                    help="cell filename suffix filter (e.g. __bs)")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    rows = []
    cell_dir = RESULTS / "dryrun" / args.mesh
    for path in sorted(cell_dir.glob(f"*{args.suffix}.json")):
        if args.suffix == "" and "__bs" in path.name:
            continue
        m = analyze_cell(path)
        if "roofline" in m and "error" not in m["roofline"]:
            rows.append(m)

    out = {"mesh": args.mesh, "cells": [
        {"arch": m["arch"], "shape": m["shape"], **m["roofline"]}
        for m in rows]}
    out_path = Path(args.out) if args.out else \
        RESULTS / f"roofline_{args.mesh}{args.suffix}.json"
    out_path.write_text(json.dumps(out, indent=2))

    hdr = (f"{'arch':25s} {'shape':12s} {'compute':>9s} {'memory':>9s} "
           f"{'collect':>9s} {'dom':>10s} {'useful':>7s} {'roofl%':>7s}")
    print(hdr)
    print("-" * len(hdr))
    for m in rows:
        r = m["roofline"]
        print(f"{m['arch']:25s} {m['shape']:12s} "
              f"{fmt_s(r['t_compute_s']):>9s} {fmt_s(r['t_memory_s']):>9s} "
              f"{fmt_s(r['t_collective_s']):>9s} {r['dominant']:>10s} "
              f"{r['useful_ratio']:7.2f} "
              f"{r['roofline_fraction'] * 100:6.1f}%")
    print(f"\nwrote {out_path}")


if __name__ == "__main__":
    main()
