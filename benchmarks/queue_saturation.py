"""Queue-saturation benchmark: queue-delay percentiles vs. offered load.

An open-loop arrival process submits fixed-size jobs at a configurable
fraction of the system's aggregate capacity while a JobService daemon
drains them into SleepExecutor-backed DynamicScheduler runs (deterministic
service times, so the numbers characterize the *queue layer*, not model
compute). Below saturation the queue delay is flat; past it (offered load
> 1.0) delay grows until the admission controller's SLO gate starts
shedding load — the p50/p95/p99 rows plus done/deferred/rejected counts
show both regimes.

Run:  PYTHONPATH=src python -m benchmarks.run            (all benchmarks)
      PYTHONPATH=src python -m benchmarks.queue_saturation
"""
from __future__ import annotations

import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.core import DeviceKind, DynamicScheduler, GroupSpec, SleepExecutor
from repro.queue import (AdmissionController, Job, JobService, QueueManager)

# deterministic service rates (items/s); aggregate capacity ≈ their sum
ACCEL_RATE = 20_000.0
CPU_RATE = 5_000.0
JOB_ITEMS = 250                       # one job ≈ 10 ms of aggregate capacity
SLO_DELAY_S = 0.5
WINDOW_S = 1.5                        # arrival window per load point
LOADS = (0.5, 0.9, 1.2, 2.0)


def _make_scheduler() -> DynamicScheduler:
    groups = {
        "accel": GroupSpec("accel", DeviceKind.ACCEL, fixed_chunk=512,
                           init_throughput=ACCEL_RATE),
        "cpu0": GroupSpec("cpu0", DeviceKind.BIG, init_throughput=CPU_RATE,
                          min_chunk=8),
    }
    execs = {"accel": SleepExecutor(rate=ACCEL_RATE),
             "cpu0": SleepExecutor(rate=CPU_RATE)}
    return DynamicScheduler(groups, execs)


def _run_load(load: float):
    capacity_items_s = ACCEL_RATE + CPU_RATE
    jobs_per_s = load * capacity_items_s / JOB_ITEMS
    n_jobs = max(1, int(jobs_per_s * WINDOW_S))
    gap = 1.0 / jobs_per_s

    queue = QueueManager()
    admission = AdmissionController(queue, slo_delay_s=SLO_DELAY_S)
    admission.on_group_join("accel", ACCEL_RATE)
    admission.on_group_join("cpu0", CPU_RATE)
    service = JobService(_make_scheduler, queue=queue, admission=admission,
                         batch_jobs=8, poll_s=0.002)
    service.start()
    jobs = []
    try:
        for i in range(n_jobs):
            job = Job(items=JOB_ITEMS, priority=i % 3)
            jobs.append(job)
            service.submit(job)
            time.sleep(gap)
        service.retry_deferred()
        deadline = time.monotonic() + 30.0
        while time.monotonic() < deadline:
            if queue.depth() == 0 and all(j.terminal for j in jobs
                                          if j.state.value != "pending"):
                break
            time.sleep(0.01)
    finally:
        service.close()                   # stop daemon + runtime teardown
    return jobs, service, admission


def rows_queue_saturation():
    out = []
    for load in LOADS:
        jobs, service, admission = _run_load(load)
        pct = service.stats.delay_percentiles()
        derived = (f"p50={pct['p50'] * 1e3:.2f}ms;"
                   f"p95={pct['p95'] * 1e3:.2f}ms;"
                   f"p99={pct['p99'] * 1e3:.2f}ms;"
                   f"done={service.stats.done};"
                   f"deferred={admission.deferred};"
                   f"rejected={admission.rejected}")
        out.append((f"queue_saturation/load_{load:g}",
                    pct["p50"] * 1e6, derived))
    return out


ALL = [rows_queue_saturation]


if __name__ == "__main__":
    print("name,us_per_call,derived")
    for name, us, derived in rows_queue_saturation():
        print(f"{name},{us:.3f},{derived}")
