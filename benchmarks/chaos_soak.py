"""Chaos soak: seeded randomized fault schedules against a federated serve.

The robustness headline for the chaos plane (repro.chaos): N seeds, each
expanded by ``FaultPlan.generate`` into a layered fault schedule —
executor chunk exceptions / hangs / slowdowns, journal fsync stalls /
corrupt records / torn tails, federation gossip drops / delays /
partitions / mirror failures / runtime kills, queue clock skew /
swallowed arrival notifications — injected into a live 3-runtime
federated drain while jobs trickle in across the fault horizon.

Every seed must satisfy, or the benchmark hard-fails:

  * zero job loss — every submitted job reaches a terminal state
    (DONE / FAILED / CANCELLED; FAILED only via the bounded retry budget
    or attempts cap, i.e. a recorded verdict, not silence);
  * zero duplicate completions — no job id carries more than one
    ``done`` record across all primary journals (the dedup guard on
    failover replay, under torn/corrupted journals and mirror gaps);
  * bounded recovery — once the fault horizon closes, the fleet drains
    to idle within ``recovery_bound_s``.

Determinism is a row of its own: the same seed must produce a
byte-identical plan (``FaultPlan.to_json``), so any soak failure is
replayable with ``--chaos-seed`` on the serve CLI.

``--composed`` runs the hand-authored smoke drill instead (2 runtimes:
gossip delay on r1, an executor hang on r0's group, then r1 killed) —
the scripts/smoke.sh chaos stage, with ``--metrics-out`` emitting the
final telemetry snapshot for its validator.

Run:  PYTHONPATH=src python -m benchmarks.run --only chaos_soak
      PYTHONPATH=src python -m benchmarks.chaos_soak [--seeds N]
      PYTHONPATH=src python -m benchmarks.chaos_soak --composed \
          --metrics-out /tmp/chaos.jsonl
"""
from __future__ import annotations

import argparse
import json
import sys
import tempfile
import time
from pathlib import Path
from typing import Dict, List, Optional, Tuple

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro import telemetry as telemetry_mod
from repro.chaos import ChaosExecutor, ChaosInjector, FaultEvent, FaultPlan
from repro.core import (DeviceKind, DynamicScheduler, GroupSpec,
                        SleepExecutor)
from repro.core.throughput import ThroughputTracker
from repro.federation import FederatedService
from repro.queue import (AdmissionController, Job, JobService, JobState,
                         QueueManager)
from repro.queue import job as job_mod
from repro.runtime.fault_tolerance import Watchdog
from repro.telemetry.exporters import MetricsExporter

clock = time.monotonic

RATE = 3_000.0                      # items/s per simulated runtime
JOB_ITEMS = 40
TERMINAL = (JobState.DONE, JobState.FAILED, JobState.CANCELLED)


def _make_chaos_fed(n: int, directory: str, injector: ChaosInjector,
                    rate: float = RATE, batch_jobs: int = 4,
                    heartbeat_s: float = 0.05,
                    telemetry=None) -> FederatedService:
    """N simulated runtimes with the full fault surface wired: every
    executor wrapped in ChaosExecutor + Watchdog, every queue's arrival
    listeners guarded, every admission clock skewable, and the
    federation itself holding the injector (gossip faults, scheduled
    kills, journal write filters, mirror-failure sinks)."""

    def make_service(rid, journal, tel):
        name = f"{rid}/accel"
        tracker = ThroughputTracker(0.5)
        tracker.seed(name, rate)
        # tight watchdog: injected hangs run 0.3-0.8s, so a 0.25s floor
        # catches every one mid-sleep without tripping on honest chunks
        # (fixed_chunk=32 at `rate` is ~11ms, well under the floor)
        wd = Watchdog(tracker, timeout_factor=4.0, min_timeout_s=0.25)

        def make_sched():
            groups = {name: GroupSpec(name, DeviceKind.ACCEL,
                                      fixed_chunk=32,
                                      init_throughput=rate)}
            execs = {name: ChaosExecutor(SleepExecutor(rate=rate), name,
                                         injector, watchdog=wd)}
            sched = DynamicScheduler(groups, execs, telemetry=tel)
            sched.tracker = tracker
            return sched

        queue = injector.wrap_queue(QueueManager(), rid)
        # defer_factor=inf: a faulted group's empty-capacity window
        # DEFERs arrivals (the bounded retry budget re-offers them, and
        # exhaustion is a terminal FAILED verdict) instead of REJECTing
        # work the rebuild would have absorbed milliseconds later
        admission = AdmissionController(
            queue, tracker, slo_delay_s=10.0,
            defer_factor=float("inf"),
            clock=injector.skewed_clock(rid, base=lambda: job_mod.now()),
            telemetry=tel)
        admission.on_group_join(name, rate)
        return JobService(make_sched, queue=queue, admission=admission,
                          journal=journal, batch_jobs=batch_jobs,
                          poll_s=0.002, watchdog=wd, health_poll_s=0.05,
                          fallback_s=0.25, telemetry=tel)

    rids = [f"r{i}" for i in range(n)]
    return FederatedService(make_service, rids, directory,
                            telemetry=telemetry, heartbeat_s=heartbeat_s,
                            chaos=injector)


def _duplicate_done(directory: str) -> Dict[str, int]:
    """job_id -> ``done`` record count, for ids seen more than once
    across all *primary* journals (replicas mirror primaries and the
    merged ``*.recovery.jsonl`` files re-state them, so only primaries
    count). Unparseable lines are chaos corruption artifacts — skipped,
    exactly as ``replay_stats`` skips them."""
    counts: Dict[str, int] = {}
    for path in sorted(Path(directory).glob("*.journal.jsonl")):
        with open(path, "r", encoding="utf-8") as fh:
            for line in fh:
                line = line.strip()
                if not line:
                    continue
                try:
                    rec = json.loads(line)
                except (json.JSONDecodeError, ValueError):
                    continue
                if not isinstance(rec, dict) or rec.get("event") != "done":
                    continue
                jid = (rec.get("job") or {}).get("job_id")
                if jid:
                    counts[jid] = counts.get(jid, 0) + 1
    return {jid: c for jid, c in counts.items() if c > 1}


def run_seed(seed: int, runtimes: int = 3, n_jobs: int = 30,
             horizon_s: float = 1.5, rate: float = RATE,
             events_per_s: float = 2.0, recovery_bound_s: float = 30.0,
             plan: Optional[FaultPlan] = None, telemetry=None,
             directory: Optional[str] = None) -> Dict[str, float]:
    """One soak: generate (or accept) a plan, drain under it, enforce
    the zero-loss / zero-dupe / bounded-recovery invariants."""
    directory = directory or tempfile.mkdtemp(prefix=f"chaos{seed}-")
    rids = [f"r{i}" for i in range(runtimes)]
    if plan is None:
        plan = FaultPlan.generate(seed, horizon_s, rids,
                                  [f"{r}/accel" for r in rids],
                                  events_per_s=events_per_s)
    injector = ChaosInjector(plan, telemetry=telemetry)
    fed = _make_chaos_fed(runtimes, directory, injector, rate=rate,
                          telemetry=telemetry)
    tenants = [f"t{i}" for i in range(4 * runtimes)]
    jobs: List[Job] = []
    fed.start()                      # opens the injector's clock too
    t0 = clock()
    # trickle submissions across the fault horizon so faults land on a
    # live mix of queued / in-flight / finishing work, not a cold burst
    waves = 6
    for w in range(waves):
        for _ in range(n_jobs // waves + (w < n_jobs % waves)):
            j = Job(items=JOB_ITEMS, max_attempts=6,
                    tenant=tenants[len(jobs) % len(tenants)])
            jobs.append(j)
            fed.submit(j)
        time.sleep(max(0.0, t0 + (w + 1) * plan.horizon_s / waves
                       - clock()))
    while not injector.done():       # let the tail of the plan fire
        time.sleep(0.01)
    t_rec = clock()
    ok = fed.run_until_idle(timeout_s=recovery_bound_s)
    recovery_s = clock() - t_rec
    wall_s = clock() - t0
    fed.close()

    final = fed._jobs
    missing = [j.job_id for j in jobs if j.job_id not in final]
    nonterminal = [j.job_id for j in final.values()
                   if j.state not in TERMINAL]
    dupes = _duplicate_done(directory)
    if not ok or missing or nonterminal or dupes \
            or recovery_s > recovery_bound_s:
        raise RuntimeError(
            f"chaos_soak seed={seed} violated invariants: idle={ok} "
            f"missing={len(missing)} nonterminal={len(nonterminal)} "
            f"dupes={dupes} recovery_s={recovery_s:.2f} "
            f"(bound {recovery_bound_s}); plan={plan.to_json()}")
    states = {s: sum(1 for j in final.values() if j.state == s)
              for s in TERMINAL}
    return {"seed": seed, "events": len(plan.events),
            "injected": injector.injected,
            "kills": sum(1 for e in plan.events
                         if e.layer == "federation" and e.kind == "kill"),
            "jobs": len(jobs), "done": states[JobState.DONE],
            "failed": states[JobState.FAILED],
            "cancelled": states[JobState.CANCELLED],
            "wall_s": wall_s, "recovery_s": recovery_s,
            "items": len(jobs) * JOB_ITEMS}


# ---------------------------------------------------------------------------
# rows
# ---------------------------------------------------------------------------

def rows_plan_determinism(seed: int = 7) -> List[Tuple[str, float, str]]:
    """Same seed → byte-identical schedule (the replayability contract
    behind --chaos-seed); also times plan generation."""
    rids, groups = ["r0", "r1", "r2"], ["r0/accel", "r1/accel", "r2/accel"]
    t0 = clock()
    a = FaultPlan.generate(seed, 2.0, rids, groups).to_json()
    dt = clock() - t0
    b = FaultPlan.generate(seed, 2.0, rids, groups).to_json()
    c = FaultPlan.generate(seed + 1, 2.0, rids, groups).to_json()
    if a != b:
        raise RuntimeError("chaos_soak: same-seed plans differ")
    if a == c:
        raise RuntimeError("chaos_soak: different-seed plans identical")
    return [("chaos_soak/plan_determinism", dt * 1e6,
             f"seed={seed};events={len(FaultPlan.from_json(a).events)};"
             f"byte_identical=yes;cross_seed_distinct=yes")]


def rows_chaos_soak(n_seeds: int = 20, first_seed: int = 1,
                    runtimes: int = 3,
                    n_jobs: int = 30) -> List[Tuple[str, float, str]]:
    out: List[Tuple[str, float, str]] = []
    total_injected = total_kills = 0
    max_recovery = 0.0
    us_all: List[float] = []
    for seed in range(first_seed, first_seed + n_seeds):
        r = run_seed(seed, runtimes=runtimes, n_jobs=n_jobs)
        us = r["wall_s"] * 1e6 / r["items"]
        us_all.append(us)
        total_injected += r["injected"]
        total_kills += r["kills"]
        max_recovery = max(max_recovery, r["recovery_s"])
        out.append((f"chaos_soak/seed_{seed}", us,
                    f"events={r['events']};injected={r['injected']};"
                    f"kills={r['kills']};jobs={r['jobs']};"
                    f"done={r['done']};failed={r['failed']};"
                    f"cancelled={r['cancelled']};lost=0;dupes=0;"
                    f"recovery_s={r['recovery_s']:.3f}"))
    out.append(("chaos_soak/aggregate", sum(us_all) / len(us_all),
                f"seeds={n_seeds};runtimes={runtimes};"
                f"injected={total_injected};kills={total_kills};"
                f"lost=0;dupes=0;max_recovery_s={max_recovery:.3f}"))
    return out


def rows_chaos() -> List[Tuple[str, float, str]]:
    return rows_plan_determinism() + rows_chaos_soak()


ALL = [rows_chaos]


# ---------------------------------------------------------------------------
# composed smoke drill (scripts/smoke.sh chaos stage)
# ---------------------------------------------------------------------------

def composed_plan(horizon_s: float = 1.2) -> FaultPlan:
    """The hand-authored drill: gossip delayed from r1 while r0's group
    hangs long enough to trip the watchdog, then r1 is killed outright —
    three layers faulting in overlap, recovery still owes zero loss."""
    return FaultPlan.compose([
        FaultEvent(at_s=0.20, layer="federation", kind="gossip_delay",
                   target="r1", duration_s=0.40, magnitude=1.0),
        FaultEvent(at_s=0.35, layer="executor", kind="hang",
                   target="r0/accel", magnitude=0.40),
        FaultEvent(at_s=0.70, layer="federation", kind="kill",
                   target="r1"),
    ], horizon_s=horizon_s)


def run_composed(metrics_out: Optional[str] = None,
                 directory: Optional[str] = None) -> Dict[str, float]:
    telemetry = telemetry_mod.Telemetry()
    exporter = None
    if metrics_out:
        exporter = MetricsExporter(telemetry, metrics_path=metrics_out,
                                   interval_s=0.2).start()
    try:
        r = run_seed(-1, runtimes=2, n_jobs=24, plan=composed_plan(),
                     telemetry=telemetry, directory=directory)
    finally:
        if exporter is not None:
            exporter.stop()
    return r


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--seeds", type=int, default=20,
                    help="randomized schedules to soak (default 20)")
    ap.add_argument("--first-seed", type=int, default=1)
    ap.add_argument("--runtimes", type=int, default=3)
    ap.add_argument("--composed", action="store_true",
                    help="run the hand-authored 2-runtime smoke drill "
                         "(gossip delay + hang + kill) instead of the "
                         "seeded soak")
    ap.add_argument("--journal-dir", default=None, metavar="DIR",
                    help="keep journals under DIR (smoke validators "
                         "scan them); default is a fresh tempdir")
    ap.add_argument("--metrics-out", default=None, metavar="PATH",
                    help="JSONL telemetry feed (final snapshot flagged "
                         "final=true), composed mode only")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write rows as JSON (BENCH_N.json format)")
    args = ap.parse_args()
    if args.composed:
        r = run_composed(metrics_out=args.metrics_out,
                         directory=args.journal_dir)
        print(json.dumps({k: v for k, v in r.items()}, sort_keys=True))
        return
    rows = rows_plan_determinism() \
        + rows_chaos_soak(n_seeds=args.seeds, first_seed=args.first_seed,
                          runtimes=args.runtimes)
    print("name,us_per_call,derived")
    out = []
    for name, us, derived in rows:
        print(f"{name},{us:.3f},{derived}")
        out.append({"name": name, "us_per_call": round(us, 3),
                    "derived": derived})
    if args.json:
        with open(args.json, "w", encoding="utf-8") as fh:
            json.dump(out, fh, indent=2)
            fh.write("\n")


if __name__ == "__main__":
    main()
