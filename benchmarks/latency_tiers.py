"""Latency-tier benchmark: urgent cold-arrival latency under saturation.

A JobService daemon is saturated with batch-tier jobs (SleepExecutor
service times, so the numbers characterize the queue/scheduling layers),
then small urgent-tier jobs arrive cold at a fixed gap. Three runs:

- ``baseline``   — batch load only (express on): batch throughput floor.
- ``express_on`` — urgent arrivals with the express lane + preemption:
  cold-arrival p50/p95 should sit *within one batch boundary* (the time
  one full batch occupies the machine), and batch throughput should
  degrade only by the urgent work actually injected (≤ 10 %).
- ``express_off`` — the same arrivals forced through the normal
  pipeline-depth gate: p95 spans one-to-several batch boundaries, the
  cost this PR removes.

Run:  PYTHONPATH=src python -m benchmarks.run --only latency_tiers
      PYTHONPATH=src python -m benchmarks.latency_tiers
"""
from __future__ import annotations

import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.core import DeviceKind, DynamicScheduler, GroupSpec, SleepExecutor
from repro.queue import Job, JobService, QueueManager
from repro.queue import job as job_mod

ACCEL_RATE = 20_000.0                 # items/s, deterministic
FIXED_CHUNK = 256                     # 12.8 ms chunk boundary
JOB_ITEMS = 500
BATCH_JOBS = 4                        # 2000-item batches = 0.1 s each
BOUNDARY_S = JOB_ITEMS * BATCH_JOBS / ACCEL_RATE
N_BATCH = 150                         # 75k items ≈ 3.75 s of batch work
N_URGENT = 8
URGENT_ITEMS = 50                     # 2.5 ms of work per urgent job
URGENT_GAP_S = 0.15
REPS = 3                              # median-of-REPS batch throughput:
                                      # host sleep overshoot is bursty
                                      # (~0.1-2.6 ms/chunk tail), so single
                                      # windows carry up to ~5 % noise


def _make_scheduler() -> DynamicScheduler:
    return DynamicScheduler(
        {"accel": GroupSpec("accel", DeviceKind.ACCEL,
                            fixed_chunk=FIXED_CHUNK,
                            init_throughput=ACCEL_RATE)},
        {"accel": SleepExecutor(rate=ACCEL_RATE)})


def _pct(xs, q):
    if not xs:
        return 0.0
    ys = sorted(xs)
    i = min(len(ys) - 1, int(round(q * (len(ys) - 1))))
    return ys[i]


def _run(express: bool, inject: bool):
    queue = QueueManager()
    service = JobService(_make_scheduler, queue=queue,
                         batch_jobs=BATCH_JOBS, pipeline_depth=2,
                         poll_s=0.002, express=express)
    service.start()
    batch = [Job(items=JOB_ITEMS, tier="batch") for _ in range(N_BATCH)]
    urgents = []
    t0 = job_mod.now()          # job-lifecycle clock: finished_at's domain
    try:
        for j in batch:
            service.submit(j)
        if inject:
            for _ in range(N_URGENT):
                time.sleep(URGENT_GAP_S)
                u = Job(items=URGENT_ITEMS, tier="urgent")
                urgents.append(u)
                service.submit(u)
        deadline = time.monotonic() + 60.0
        while time.monotonic() < deadline:
            if all(j.terminal for j in batch + urgents):
                break
            time.sleep(0.005)
    finally:
        service.close()
    batch_window = max(j.finished_at for j in batch) - t0
    tput = sum(j.items for j in batch) / batch_window
    lat = [u.finished_at - u.created_at for u in urgents]
    return tput, lat, service.stats


def _median(xs):
    ys = sorted(xs)
    return ys[len(ys) // 2]


def rows_latency_tiers():
    tput0 = _median([_run(express=True, inject=False)[0]
                     for _ in range(REPS)])
    out = [("latency_tiers/baseline", BOUNDARY_S * 1e6,
            f"batch_tput={tput0:.0f}items/s;boundary={BOUNDARY_S * 1e3:.1f}ms")]
    for label, express in (("express_on", True), ("express_off", False)):
        tputs, lat = [], []
        for _ in range(REPS):
            t, ls, st = _run(express=express, inject=True)
            tputs.append(t)
            lat.extend(ls)
        tput = _median(tputs)
        p50, p95 = _pct(lat, 0.50), _pct(lat, 0.95)
        derived = (f"p50={p50 * 1e3:.1f}ms;"
                   f"p95={p95 * 1e3:.1f}ms;"
                   f"p95_boundaries={p95 / BOUNDARY_S:.2f};"
                   f"batch_tput={tput:.0f}items/s;"
                   f"tput_ratio={tput / tput0:.3f};"
                   f"express_batches={st.express_batches};"
                   f"done={st.done}")
        out.append((f"latency_tiers/{label}", p95 * 1e6, derived))
    return out


ALL = [rows_latency_tiers]


if __name__ == "__main__":
    print("name,us_per_call,derived")
    for name, us, derived in rows_latency_tiers():
        print(f"{name},{us:.3f},{derived}")
